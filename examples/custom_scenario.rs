//! Composing an N-tenant scenario with `ScenarioBuilder`, plus a tour of
//! the named catalog.
//!
//! The simulated testbed is not limited to the paper's fixed
//! T1/T2/T3 world: any mix of latency-sensitive / bandwidth-heavy /
//! compute-heavy tenants can share the host, each with its own spec,
//! SLO, activity schedule and placement.
//!
//! Run: `cargo run --release --example custom_scenario`

use predserve::controller::Levers;
use predserve::gpu::MigProfile;
use predserve::platform::{Scenario, ScenarioBuilder, SimWorld};
use predserve::tenants::{
    BwSpec, CompSpec, InterferenceSchedule, LsSpec, PlacementSpec, TenantWorkload,
};

fn main() {
    // --- 1. a hand-built 5-tenant scenario ---------------------------------
    // Two latency services with different SLOs, two ETL pipelines on the
    // hot switch, one trainer MPS-sharing the premium tenant's instance
    // (the naive co-placement the controller has to fix).
    let horizon = 300.0;
    let scenario = ScenarioBuilder::new("custom_demo", 42)
        .levers(Levers::full())
        .horizon(horizon)
        .tenant(TenantWorkload::latency_sensitive(
            "premium-api",
            LsSpec {
                arrival_rps: 70.0,
                slo_ms: 15.0,
                ..LsSpec::default()
            },
            PlacementSpec::dedicated_at(0, MigProfile::P4g40gb, 0),
        ))
        .tenant(TenantWorkload::compute_heavy(
            "trainer",
            CompSpec::default(),
            InterferenceSchedule::periodic(horizon, 120.0, 0.5, 30.0),
            PlacementSpec::shared_with(0),
        ))
        .tenant(TenantWorkload::latency_sensitive(
            "batch-api",
            LsSpec {
                arrival_rps: 20.0,
                slo_ms: 80.0,
                compute_ref_ms: 9.0,
                ..LsSpec::default()
            },
            PlacementSpec::dedicated_at(2, MigProfile::P3g40gb, 0),
        ))
        .tenant(TenantWorkload::bandwidth_heavy(
            "etl-ingest",
            BwSpec::default(),
            InterferenceSchedule::periodic(horizon, 150.0, 0.6, 0.0),
            PlacementSpec::dedicated_at(0, MigProfile::P3g40gb, 4),
        ))
        .tenant(TenantWorkload::bandwidth_heavy(
            "etl-export",
            BwSpec {
                read_gb: 2.5,
                ..BwSpec::default()
            },
            InterferenceSchedule::periodic(horizon, 150.0, 0.6, 75.0),
            PlacementSpec::dedicated_at(1, MigProfile::P3g40gb, 0),
        ))
        .spare(4, MigProfile::P3g40gb, 0)
        .build();

    let r = SimWorld::new(scenario).run();
    println!("custom 5-tenant run ({}):", r.label);
    for t in &r.per_tenant {
        println!(
            "  {:12} {:17} completed={:6} p99={:8.2} ms miss={:5.1}% gb={:7.1}",
            t.name,
            t.kind.label(),
            t.completed,
            t.p99_ms,
            t.miss_rate * 100.0,
            t.gb_moved
        );
    }
    assert_eq!(r.per_tenant.len(), 5);
    assert!(r.per_tenant.iter().all(|t| t.completed > 0));

    // --- 2. auto-placement --------------------------------------------------
    // No hand-written placements: declare the ask (min profile +
    // expected PCIe demand) and let the topology-aware allocator pick
    // slots at build time. `layout` records what it chose.
    let auto = ScenarioBuilder::new("auto_demo", 7)
        .levers(Levers::full())
        .horizon(120.0)
        .add_auto(TenantWorkload::latency_sensitive(
            "svc",
            LsSpec::default(),
            PlacementSpec::auto(MigProfile::P3g40gb, 3.0),
        ))
        .add_auto(TenantWorkload::bandwidth_heavy(
            "etl",
            BwSpec::default(),
            InterferenceSchedule::always_on(120.0),
            PlacementSpec::auto(MigProfile::P2g20gb, 4.0),
        ))
        .build();
    println!("\nauto-placed layout:\n{}", auto.layout.render());
    assert!(auto.tenants.iter().all(|t| !t.placement.is_auto()));

    // --- 3. the named catalog ----------------------------------------------
    println!("catalog smoke (90 s each):");
    for name in Scenario::CATALOG {
        let mut s = Scenario::by_name(name, 11, Levers::full()).unwrap();
        s.horizon = 90.0;
        let n = s.n_tenants();
        let r = SimWorld::new(s).run();
        println!(
            "  {:20} {n} tenants  primary p99={:7.2} ms miss={:5.1}%  completed={}",
            name,
            r.p99_ms,
            r.miss_rate * 100.0,
            r.completed
        );
    }
}
