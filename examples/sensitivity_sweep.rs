//! E3: sensitivity analysis — τ, Y, and guardrail bounds (plus an
//! admission-control demo, §2.3).
//!
//! Run: `cargo run --release --example sensitivity_sweep [-- --fast]`

use predserve::cli::Args;
use predserve::controller::admission::{admit, AdmissionRequest, Verdict};
use predserve::controller::{ControllerConfig, Levers};
use predserve::experiments::harness::Repeats;
use predserve::experiments::runs;
use predserve::gpu::MigProfile;
use predserve::platform::{Scenario, SimWorld};
use predserve::tenants::TenantId;

fn main() {
    let args = Args::from_env();
    let mut repeats = Repeats::fast();
    if !args.flag("fast") {
        repeats.count = 3;
        repeats.horizon_s = 1200.0;
    }
    println!("{}", runs::run_sensitivity(&repeats));

    // Admission control demo: ask for slots on a host under load.
    let mut world = SimWorld::new(Scenario::paper_single_host(11, Levers::full()));
    let (snap, view) = world.sample_for_bench();
    for (profile, gbps) in [
        (MigProfile::P1g10gb, 0.2),
        (MigProfile::P3g40gb, 2.0),
        (MigProfile::P7g80gb, 20.0),
    ] {
        let verdict = admit(
            &AdmissionRequest {
                tenant: TenantId(9),
                min_profile: profile,
                expected_pcie_gbps: gbps,
            },
            &snap,
            &view,
            &ControllerConfig::default(),
        );
        println!("admission ask {:8} @ {gbps:4.1} GB/s -> {verdict:?}", profile.name());
    }
    // A modest ask must be admittable on the mostly-idle host.
    let v = admit(
        &AdmissionRequest {
            tenant: TenantId(9),
            min_profile: MigProfile::P1g10gb,
            expected_pcie_gbps: 0.2,
        },
        &snap,
        &view,
        &ControllerConfig::default(),
    );
    assert!(matches!(v, Verdict::Admit { .. }));
}
