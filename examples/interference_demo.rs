//! E1 / Figure 3a: the controller reacting to dynamic interference.
//!
//! Runs the paper's main experiment on one seed, printing the T2/T3
//! interference schedule, the controller's escalation timeline
//! (guardrails → placement → MIG), and the before/after tail comparison
//! against the static baseline.
//!
//! Run: `cargo run --release --example interference_demo [-- --fast]`

use predserve::cli::Args;
use predserve::controller::Levers;
use predserve::platform::{Scenario, SimWorld};

fn main() {
    let args = Args::from_env();
    let horizon = if args.flag("fast") { 600.0 } else { 1800.0 };
    let seed = args.get_u64("seed", 11);

    let mut base_sc = Scenario::paper_single_host(seed, Levers::none());
    base_sc.horizon = horizon;
    println!("interference schedule (identical across configurations):");
    for i in base_sc.background_tenants() {
        let t = &base_sc.tenants[i];
        for p in t.schedule.phases.iter().take(8) {
            println!(
                "  {:10} {:17} ON  {:7.1}s .. {:7.1}s",
                t.name,
                t.kind().label(),
                p.on,
                p.off
            );
        }
    }

    let base = SimWorld::new(base_sc).run();
    let mut full_sc = Scenario::paper_single_host(seed, Levers::full());
    full_sc.horizon = horizon;
    let full = SimWorld::new(full_sc).run();

    println!("\ncontroller decision timeline (Figure 3a lanes):");
    for (t, kind, p99) in &full.timeline {
        println!("  t={t:7.1}s  action={kind:12}  p99-at-decision={p99:6.2} ms");
    }

    println!("\n                        static      full");
    println!(
        "SLO miss-rate        {:8.1}%  {:8.1}%   ({:.0}% reduction; paper: ~32%)",
        base.miss_rate * 100.0,
        full.miss_rate * 100.0,
        (1.0 - full.miss_rate / base.miss_rate.max(1e-9)) * 100.0
    );
    println!(
        "p99 latency (ms)     {:8.2}   {:8.2}   ({:.0}% better; paper: ~15%)",
        base.p99_ms,
        full.p99_ms,
        (1.0 - full.p99_ms / base.p99_ms) * 100.0
    );
    println!(
        "p999 latency (ms)    {:8.2}   {:8.2}",
        base.p999_ms, full.p999_ms
    );
    println!(
        "throughput (rps)     {:8.2}   {:8.2}   (cost {:.1}%; paper budget: <=5%)",
        base.rps,
        full.rps,
        (1.0 - full.rps / base.rps) * 100.0
    );
    assert!(full.p99_ms < base.p99_ms, "controller must improve the tail");
    assert!(full.rps >= 0.95 * base.rps, "throughput budget violated");
}
