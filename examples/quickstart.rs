//! Quickstart: a five-minute tour of the predserve public API.
//!
//! 1. Model the host (topology + MIG geometry).
//! 2. Watch the §2.5.1 processor-sharing fabric divide PCIe bandwidth.
//! 3. Run the paper's single-host scenario with and without the
//!    controller and compare SLO miss-rate / p99.
//!
//! Run: `cargo run --release --example quickstart`

use predserve::controller::Levers;
use predserve::fabric::ps::{ps_rates, FlowDemand};
use predserve::gpu::{A100Gpu, MigProfile};
use predserve::platform::{Scenario, SimWorld};
use predserve::topo::HostTopology;

fn main() {
    // --- 1. the host ------------------------------------------------------
    let topo = HostTopology::p4d();
    println!(
        "host: {} GPUs, {} PCIe switches, {} NUMA domains",
        topo.num_gpus,
        topo.switches.len(),
        topo.numa_nodes.len()
    );
    let mut gpu = A100Gpu::new(0);
    let t1 = gpu.create_at(MigProfile::P3g40gb, 0).unwrap();
    gpu.create_at(MigProfile::P3g40gb, 4).unwrap();
    println!(
        "gpu0 partitions: {:?}, free slices: {}, 4g placeable after freeing T1: {}",
        gpu.instances()
            .iter()
            .map(|i| i.profile.name())
            .collect::<Vec<_>>(),
        gpu.free_slices(),
        gpu.can_place_after_destroy(MigProfile::P4g40gb, t1),
    );

    // --- 2. the PS fabric (paper §2.5.1) -----------------------------------
    let flows = [
        FlowDemand { weight: 1.0, cap: None },        // latency tenant
        FlowDemand { weight: 1.0, cap: Some(0.5) },   // throttled ETL (cgroup io.max)
        FlowDemand { weight: 1.0, cap: None },        // trainer sync
    ];
    let rates = ps_rates(25.0, &flows);
    println!(
        "PS shares on a 25 GB/s uplink with one 0.5 GB/s throttle: {rates:?} \
         (throttled flow pinned, remainder redistributed)"
    );

    // --- 3. static baseline vs full controller -----------------------------
    for levers in [Levers::none(), Levers::full()] {
        let mut scenario = Scenario::paper_single_host(11, levers);
        scenario.horizon = 600.0;
        let r = SimWorld::new(scenario).run();
        println!(
            "{:12}  miss={:5.1}%  p99={:5.2} ms  throughput={:5.1} rps  moves/hr={:.1}",
            r.label,
            r.miss_rate * 100.0,
            r.p99_ms,
            r.rps,
            r.moves_per_hour
        );
    }
    println!("ok: the controller cut the miss-rate and the p99 tail at ~no throughput cost");
}
