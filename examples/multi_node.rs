//! E9: the 2-node (16-GPU) cluster experiment — the paper's second
//! contribution ("first SLO-safe, multi-tenant control demo on a
//! multi-node cloud cluster without fabric privileges").
//!
//! A Slurm-like leader launches one worker per node over real TCP; each
//! worker runs the full single-host controller over its own 8 simulated
//! A100s. The leader aggregates per-node and cluster-level metrics.
//!
//! Run: `cargo run --release --example multi_node [-- --nodes 2]`

use predserve::cli::Args;
use predserve::cluster::Leader;

fn main() -> anyhow::Result<()> {
    let args = Args::from_env();
    let nodes = args.get_usize("nodes", 2);
    let horizon = args.get_f64("horizon", 600.0);

    println!("launching {nodes}-node cluster ({} GPUs total)...", nodes * 8);
    let static_rep = Leader::run_cluster(nodes, 11, "static", horizon, "single")?;
    let full_rep = Leader::run_cluster(nodes, 11, "full", horizon, "single")?;

    println!("\nper-node results (full system):");
    for n in &full_rep.per_node {
        println!(
            "  {}: miss={:5.1}%  p99={:6.2} ms  rps={:6.1}",
            n.node,
            n.miss_rate * 100.0,
            n.p99_ms,
            n.rps
        );
    }
    println!("\ncluster aggregate         static      full");
    println!(
        "mean SLO miss-rate     {:8.1}%  {:8.1}%",
        static_rep.mean_miss_rate * 100.0,
        full_rep.mean_miss_rate * 100.0
    );
    println!(
        "mean p99 (ms)          {:8.2}   {:8.2}",
        static_rep.mean_p99_ms, full_rep.mean_p99_ms
    );
    println!(
        "total throughput (rps) {:8.1}   {:8.1}",
        static_rep.total_rps, full_rep.total_rps
    );
    assert!(
        full_rep.mean_p99_ms < static_rep.mean_p99_ms,
        "the policy must show similar improvements on the cluster (§4)"
    );
    println!("\nok: per-host control scales to the cluster with no fabric privileges");

    // Fleet-level dispatch: the leader auto-places one tenant list
    // across the nodes (no whole-host scenarios shipped).
    let n_tenants = nodes * 12;
    let fleet = Leader::run_fleet(nodes, 11, "full", horizon.min(300.0), n_tenants)?;
    println!(
        "\nfleet dispatch ({n_tenants} tenants over {nodes} nodes): mean p99={:.2} ms, {} queued, {} rejected",
        fleet.mean_p99_ms,
        fleet.queued.len(),
        fleet.rejected.len()
    );
    for n in &fleet.per_node {
        println!("  {}: p99={:6.2} ms  rps={:6.1}", n.node, n.p99_ms, n.rps);
    }
    Ok(())
}
