//! E9: the 2-node (16-GPU) cluster experiment — the paper's second
//! contribution ("first SLO-safe, multi-tenant control demo on a
//! multi-node cloud cluster without fabric privileges").
//!
//! A Slurm-like leader launches one worker per node over real TCP; each
//! worker runs the full single-host controller over its own 8 simulated
//! A100s. The leader aggregates per-node and cluster-level metrics.
//!
//! Run: `cargo run --release --example multi_node [-- --nodes 2]`

use predserve::cli::Args;
use predserve::cluster::Leader;

fn main() -> anyhow::Result<()> {
    let args = Args::from_env();
    let nodes = args.get_usize("nodes", 2);
    let horizon = args.get_f64("horizon", 600.0);

    println!("launching {nodes}-node cluster ({} GPUs total)...", nodes * 8);
    let static_rep = Leader::run_cluster(nodes, 11, "static", horizon, "single")?;
    let full_rep = Leader::run_cluster(nodes, 11, "full", horizon, "single")?;

    println!("\nper-node results (full system):");
    for (node, miss, p99, rps) in &full_rep.per_node {
        println!("  {node}: miss={:5.1}%  p99={p99:6.2} ms  rps={rps:6.1}", miss * 100.0);
    }
    println!("\ncluster aggregate         static      full");
    println!(
        "mean SLO miss-rate     {:8.1}%  {:8.1}%",
        static_rep.mean_miss_rate * 100.0,
        full_rep.mean_miss_rate * 100.0
    );
    println!(
        "mean p99 (ms)          {:8.2}   {:8.2}",
        static_rep.mean_p99_ms, full_rep.mean_p99_ms
    );
    println!(
        "total throughput (rps) {:8.1}   {:8.1}",
        static_rep.total_rps, full_rep.total_rps
    );
    assert!(
        full_rep.mean_p99_ms < static_rep.mean_p99_ms,
        "the policy must show similar improvements on the cluster (§4)"
    );
    println!("\nok: per-host control scales to the cluster with no fabric privileges");
    Ok(())
}
