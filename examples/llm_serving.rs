//! **End-to-end validation driver (E10)** — proves all three layers
//! compose: the L1 Pallas paged-attention kernel, lowered through the L2
//! JAX model into HLO text, executed by the L3 Rust serving engine via
//! PJRT, serving real batched requests with continuous batching and a
//! paged KV cache, reporting TTFT / e2e latency / throughput.
//!
//! Requires `make artifacts` first.
//!
//! Run: `cargo run --release --example llm_serving`

use predserve::serving::request::SamplingParams;
use predserve::serving::Engine;
use std::time::Instant;

fn main() -> anyhow::Result<()> {
    let mut engine = Engine::load_default()?;
    let spec = engine.spec();
    println!(
        "model: {} layers, d_model {}, {} heads ({} kv), vocab {}; paged KV: {} pages x {} tokens",
        spec.n_layers,
        spec.d_model,
        spec.n_heads,
        spec.n_kv_heads,
        spec.vocab_size,
        spec.num_pages,
        spec.page_size
    );

    // A small real workload: 24 requests with mixed prompt lengths and
    // generation budgets — more than the 4 batch rows, so continuous
    // batching has to cycle admissions.
    let prompts = [
        "predictable llm serving on gpu clusters",
        "noisy neighbors inflate tail latency",
        "dynamic mig reconfiguration",
        "pcie-aware placement avoids hot paths",
        "mps quotas and cgroup io.max guardrails",
        "dwell and cool-down prevent thrash",
    ];
    let t0 = Instant::now();
    for i in 0..24u64 {
        let prompt = prompts[(i as usize) % prompts.len()];
        engine.submit_text(
            prompt,
            SamplingParams {
                top_k: if i % 3 == 0 { 8 } else { 0 },
                seed: i,
                max_new_tokens: 6 + (i as usize % 10),
            },
        );
    }
    let done = engine.run_to_completion()?;
    let wall = t0.elapsed().as_secs_f64();

    for c in done.iter().take(6) {
        println!(
            "req {:2}  prompt_len={:2}  ttft={:7.2} ms  e2e={:7.2} ms  tpot={:5.2} ms  tokens={:2}",
            c.id.0,
            c.prompt_len,
            c.ttft_s * 1e3,
            c.e2e_s * 1e3,
            c.tpot_s * 1e3,
            c.generated.len()
        );
    }
    println!("... ({} total)", done.len());

    let s = &engine.stats;
    println!("\n--- serving report (real PJRT execution, CPU) ---");
    println!(
        "completed:          {} requests, {} tokens",
        s.completed, s.generated_tokens
    );
    println!(
        "TTFT    p50/p95/p99: {:.2} / {:.2} / {:.2} ms",
        s.ttft_us.quantile(0.50) as f64 / 1e3,
        s.ttft_us.quantile(0.95) as f64 / 1e3,
        s.ttft_us.quantile(0.99) as f64 / 1e3
    );
    println!(
        "e2e     p50/p95/p99: {:.2} / {:.2} / {:.2} ms",
        s.e2e_us.quantile(0.50) as f64 / 1e3,
        s.e2e_us.quantile(0.95) as f64 / 1e3,
        s.e2e_us.quantile(0.99) as f64 / 1e3
    );
    println!(
        "throughput:         {:.1} req/s, {:.0} tok/s",
        s.throughput_rps(wall),
        s.generated_tokens as f64 / wall
    );
    println!(
        "waves:              {} prefill, {} decode; model time {:.2}s / wall {:.2}s ({:.0}% in XLA)",
        s.prefill_waves,
        s.decode_steps,
        s.model_time_s,
        wall,
        100.0 * s.model_time_s / wall
    );
    assert_eq!(done.len(), 24, "all requests must complete");
    println!("ok: L1 pallas kernel -> L2 jax model -> HLO -> L3 rust engine, end to end");
    Ok(())
}
