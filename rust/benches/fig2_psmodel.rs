//! Regenerates **Figure 2** (PCIe contention model): per-tenant bandwidth
//! under processor sharing as co-active tenant count grows, with and
//! without per-flow caps g_i; plus the Claim-1 stability check.
use predserve::bench::banner;
use predserve::experiments::runs;
use predserve::model::queueing::ps_utilization_stable;

fn main() {
    banner("Figure 2 — PS contention model & caps");
    let (table, rows) = runs::run_fig2();
    println!("{table}");
    // Claim 1: sum of caps below capacity => stable.
    let (rho, stable) = ps_utilization_stable(&[2.0, 2.0, 2.0], 25.0);
    println!("Claim 1 check: caps 3x2 GB/s on B=25 GB/s -> rho={rho:.2}, stable={stable}");
    assert!(rows.len() == 8);
}
