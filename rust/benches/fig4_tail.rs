//! Regenerates **Figure 4**: latency distribution tails under PCIe/SM
//! contention (CCDF series to target/paper/), showing the heavy tail
//! under high contention and its mitigation by the full system.
use predserve::bench::banner;
use predserve::experiments::harness::Repeats;
use predserve::experiments::runs;

fn main() {
    banner("Figure 4 — tail distributions under contention");
    let repeats = Repeats::from_env();
    println!("{}", runs::run_fig4(&repeats));
}
