//! Regenerates **Table 4** (controller overheads): MIG reconfiguration
//! wall time, disruptive move frequency, controller CPU share.
use predserve::bench::{banner, bench_throughput};
use predserve::experiments::harness::Repeats;
use predserve::experiments::runs;

fn main() {
    banner("Table 4 — controller overheads");
    let repeats = Repeats::from_env();
    let sums = bench_throughput("full-system repeats", repeats.count as u64, "runs", || {
        runs::run_ablation(&repeats)
    });
    let full = sums.iter().find(|s| s.label == "Full System").unwrap();
    println!("\n{}", runs::render_table4(full));
}
