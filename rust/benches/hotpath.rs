//! Hot-path microbenchmarks (§Perf of EXPERIMENTS.md): the L3 components
//! that sit on the request/decision path, plus the end-to-end decode step
//! through PJRT. Emits `BENCH_hotpath.json` (machine-readable timings for
//! every microbench) — the repo's perf trajectory; CI uploads it as an
//! artifact.

use predserve::bench::{banner, BenchReport};
use predserve::controller::{Controller, ControllerConfig, Levers};
use predserve::fabric::ps::{ps_rates, FlowDemand};
use predserve::fabric::Fabric;
use predserve::platform::{Scenario, SimWorld};
use predserve::serving::PagedKvCache;
use predserve::sim::EventQueue;
use predserve::tenants::{ArrivalProcess, ArrivalState, TraceSpec};
use predserve::topo::{HostTopology, LinkId};
use predserve::util::histogram::Histogram;
use predserve::util::quantile::{P2Quantile, WindowQuantiles};
use predserve::util::rng::Pcg64;

fn main() {
    banner("hot-path microbenchmarks");
    let mut report = BenchReport::new("hotpath");

    // PS solver: 8 flows with mixed caps (the per-link solve cost).
    let flows: Vec<FlowDemand> = (0..8)
        .map(|i| FlowDemand {
            weight: 1.0 + i as f64 * 0.2,
            cap: if i % 2 == 0 { Some(2.0 + i as f64) } else { None },
        })
        .collect();
    report.bench_fn("fabric: ps_rates (8 flows, caps)", 300, || {
        std::hint::black_box(ps_rates(25.0, &flows));
    });

    // Fabric mutation + completion query on the incremental engine: the
    // per-event cost the dirty-link cache and completion calendar bound.
    let topo = HostTopology::p4d();
    let mut fabric = Fabric::new(&topo);
    let mut i = 0u64;
    report.bench_fn("fabric: start+next_completion+remove", 300, || {
        let id = fabric.start(LinkId((i % 4) as usize), 1.0, 1.0, None, 0);
        std::hint::black_box(fabric.next_completion());
        fabric.remove(id);
        i += 1;
    });

    // Steady-state advance over a populated fabric: cached rates, no
    // solver invocations, no allocations.
    let mut fabric2 = Fabric::new(&topo);
    for j in 0..48u64 {
        fabric2.start(
            LinkId((j % 6) as usize),
            1e12, // effectively never completes within the bench
            1.0 + (j % 3) as f64,
            (j % 4 == 0).then_some(2.0),
            (j % 8) as usize,
        );
    }
    fabric2.next_completion(); // prime the caches
    report.bench_fn("fabric: advance (48 flows, clean links)", 300, || {
        fabric2.advance(1e-6);
    });

    // Streaming quantiles.
    let mut p2 = P2Quantile::new(0.99);
    let mut rng = Pcg64::seeded(1);
    report.bench_fn("telemetry: P2 quantile observe", 200, || {
        p2.observe(rng.f64() * 20.0);
    });
    let mut win = WindowQuantiles::new(4096);
    for _ in 0..4096 {
        win.observe(rng.f64());
    }
    report.bench_fn("telemetry: window observe", 200, || {
        win.observe(rng.f64() * 20.0);
    });
    report.bench_fn("telemetry: window p99 query (4096)", 300, || {
        std::hint::black_box(win.quantile(0.99));
    });
    let mut h = Histogram::new();
    report.bench_fn("telemetry: histogram record", 200, || {
        h.record(rng.below(100_000));
    });

    // Event queue.
    let mut q: EventQueue<u32> = EventQueue::new();
    report.bench_fn("sim: event queue push+pop", 200, || {
        q.push_after(rng.f64(), 1);
        std::hint::black_box(q.pop());
    });

    // Trace replay: drain a 100k-event trace through the ArrivalState
    // cursor — the per-arrival cost of the trace-driven arrival path.
    let trace = {
        let mut trng = Pcg64::seeded(17);
        let mut gaps = Vec::with_capacity(100_000);
        for _ in 0..100_000 {
            gaps.push(trng.exp(50.0));
        }
        TraceSpec::from_gaps(gaps).unwrap()
    };
    let drained = trace.len() as u64;
    let mut replay = ArrivalState::new(ArrivalProcess::Trace(trace));
    let mut replay_rng = Pcg64::seeded(1);
    report.bench_throughput(
        "tenants: trace_replay drain (100k-event trace)",
        drained,
        "arrivals",
        || {
            let mut t = 0.0f64;
            while let Some(g) = replay.next_gap(t, &mut replay_rng) {
                t += g;
                replay.note_emitted();
            }
            std::hint::black_box(t)
        },
    );
    assert_eq!(replay.emitted(), drained, "trace replay lost arrivals");

    // KV cache alloc/append/release cycle.
    let mut cache = PagedKvCache::new(64, 16, 4);
    report.bench_fn("serving: kv alloc+append+release", 200, || {
        let id = cache.allocate(20).unwrap();
        cache.append_token(id).unwrap();
        cache.release(id).unwrap();
    });

    // Controller tick on a live snapshot/view (decision latency).
    let scenario = Scenario::paper_single_host(11, Levers::full());
    let mut world = SimWorld::new(scenario);
    let (snap, view) = world.sample_for_bench();
    let cfg = ControllerConfig {
        warmup_obs: 0, // measure the live decision path, not the warmup gate
        ..ControllerConfig::default()
    };
    let mut ctl = Controller::new(cfg);
    report.bench_fn("controller: on_observation tick", 300, || {
        std::hint::black_box(ctl.on_observation(&snap, &view));
    });

    // Whole-run simulation throughput.
    let r = report.bench_throughput("sim: full-system 1800s run", 1, "runs", || {
        SimWorld::new(Scenario::paper_single_host(11, Levers::full())).run()
    });
    println!(
        "  (run completed {} requests over {} events; {} fabric rate solves)",
        r.completed, r.sim_events, r.fabric_rate_recomputes
    );
    report.metric("sim: full-system run events", r.sim_events as f64);
    report.metric(
        "sim: full-system fabric rate recomputes",
        r.fabric_rate_recomputes as f64,
    );
    report.metric(
        "sim: fabric recomputes per event",
        r.fabric_rate_recomputes as f64 / r.sim_events.max(1) as f64,
    );

    // Release-mode differential oracle: the trace-replay path must
    // reproduce the closed-form Poisson path bit for bit here too (the
    // CI perf-smoke step doubles as the release-build check, exactly as
    // scale_sweep does for the fabric engines).
    let mut oracle = Scenario::paper_single_host(11, Levers::full());
    oracle.horizon = 120.0;
    let traced = oracle.with_presampled_traces();
    let a = SimWorld::new(oracle).run();
    let b = SimWorld::new(traced).run();
    assert_eq!(
        a.fingerprint(),
        b.fingerprint(),
        "trace-mode fingerprint diverged from the Poisson-presample oracle"
    );
    assert_eq!(a.sim_events, b.sim_events, "trace-mode event stream diverged");
    report.metric("sim: trace oracle fingerprint match", 1.0);

    // End-to-end decode step through PJRT (needs artifacts).
    match predserve::serving::Engine::load_default() {
        Ok(mut engine) => {
            use predserve::serving::request::SamplingParams;
            for i in 0..4 {
                engine.submit_text(
                    &format!("benchmark prompt {i}"),
                    SamplingParams {
                        top_k: 0,
                        seed: i,
                        max_new_tokens: 10_000, // keep rows busy
                    },
                );
            }
            // Prefill once, then measure steady-state decode steps.
            engine.step().unwrap();
            let t0 = std::time::Instant::now();
            let steps = 40;
            for _ in 0..steps {
                engine.step().unwrap();
            }
            let dt = t0.elapsed().as_secs_f64();
            println!(
                "serving: decode step (batch=4, PJRT)           {:10.2} ms/step  ({:.0} tok/s)",
                dt / steps as f64 * 1e3,
                4.0 * steps as f64 / dt
            );
            report.metric("serving: decode ms/step (batch=4)", dt / steps as f64 * 1e3);
        }
        Err(e) => println!("serving decode bench skipped (run `make artifacts`): {e}"),
    }

    report.write_json("BENCH_hotpath.json");
}
