//! Regenerates **Table 3** (E2 ablation): SLO miss-rate, p99, normalized
//! throughput for the five configurations, mean ± 95% CI over the repeat
//! set (7 × 1800 s by default; set PREDSERVE_FAST=1 for a 3 × 600 s smoke).
use predserve::bench::{banner, bench_throughput};
use predserve::experiments::harness::Repeats;
use predserve::experiments::runs;

fn main() {
    banner("Table 3 — ablation study (E2)");
    let repeats = Repeats::from_env();
    let runs_total = (repeats.count * 5) as u64;
    let sums = bench_throughput("ablation: 5 configs x repeats", runs_total, "runs", || {
        runs::run_ablation(&repeats)
    });
    println!("\n{}", runs::render_table3(&sums));
    println!("(paper columns reproduced from Table 3 for side-by-side comparison)");
}
