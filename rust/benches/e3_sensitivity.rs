//! Regenerates **E3** (sensitivity analysis): SLO threshold tau, the
//! persistence window Y, and the IO-throttle bounds.
use predserve::bench::banner;
use predserve::experiments::harness::Repeats;
use predserve::experiments::runs;

fn main() {
    banner("E3 — sensitivity analysis");
    let mut repeats = Repeats::fast();
    if std::env::var("PREDSERVE_FAST").is_err() {
        repeats.count = 3;
        repeats.horizon_s = 1200.0;
    }
    println!("{}", runs::run_sensitivity(&repeats));
}
