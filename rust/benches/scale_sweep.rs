//! Fabric-engine scaling sweep: whole-run wall time and PS-solver
//! invocation counts for the incremental engine vs the from-scratch
//! reference oracle, on generated dense scenarios from 24 to 256 tenants
//! — plus a sharded-engine sweep at 1024/4096 tenants comparing the
//! sharded conservative-PDES core against the single-queue reference
//! engine (both on the incremental fabric; the reference *fabric* is
//! O(links x flows) per recompute and would dominate at that scale).
//!
//! Every case runs the *same scenario* on both engines and panics if the
//! run fingerprints diverge — so the CI perf-smoke step doubles as a
//! release-mode differential check. Timings are reported, never gated.
//! Emits `BENCH_scale_sweep.json` alongside the human-readable table.

use predserve::bench::{banner, BenchReport};
use predserve::controller::Levers;
use predserve::fabric::FabricKind;
use predserve::platform::{RunResult, Scenario, SimWorld};
use std::time::Instant;

fn timed_run(scenario: Scenario, kind: FabricKind) -> (RunResult, f64) {
    let t0 = Instant::now();
    let r = SimWorld::new_with_fabric(scenario, kind).run();
    (r, t0.elapsed().as_secs_f64())
}

/// Same, with the flight recorder attached: `r.metrics` then carries the
/// per-shard stall/occupancy counters. The fingerprint asserts below
/// still compare against unrecorded runs, so the sweep doubles as a
/// release-mode check of the recorder's non-perturbation invariant.
fn timed_run_recorded(scenario: Scenario, kind: FabricKind) -> (RunResult, f64) {
    let t0 = Instant::now();
    let mut w = SimWorld::new_with_fabric(scenario, kind);
    w.enable_recording(predserve::trace::recorder::DEFAULT_CAPACITY);
    let (r, _) = w.run_recorded();
    (r, t0.elapsed().as_secs_f64())
}

fn main() {
    banner("fabric scale sweep (incremental vs reference oracle)");
    let mut report = BenchReport::new("scale_sweep");

    // (label, scenario builder): auto_pack_24 is the p4d-scale catalog
    // case; the larger Ns are generated dense-host hotspots. Horizons
    // shrink as N grows to keep the sweep's wall time bounded.
    type Mk = Box<dyn Fn() -> Scenario>;
    let cases: Vec<(&str, Mk)> = vec![
        (
            "N=24 (auto_pack_24, p4d)",
            Box::new(|| {
                let mut s = Scenario::auto_pack_24(11, Levers::full());
                s.horizon = 300.0;
                s
            }),
        ),
        (
            "N=64 (hotspot_64, 2 switches)",
            Box::new(|| {
                let mut s = Scenario::dense_hotspot(11, 64, Levers::full());
                s.horizon = 180.0;
                s
            }),
        ),
        (
            "N=128 (dense hotspot)",
            Box::new(|| {
                let mut s = Scenario::dense_hotspot(11, 128, Levers::full());
                s.horizon = 120.0;
                s
            }),
        ),
        (
            "N=256 (dense hotspot)",
            Box::new(|| {
                let mut s = Scenario::dense_hotspot(11, 256, Levers::full());
                s.horizon = 90.0;
                s
            }),
        ),
    ];

    println!(
        "{:32} {:>10} {:>12} {:>12} {:>8} {:>9} {:>9}",
        "case", "events", "solves/ev", "solves/ev", "solve", "wall s", "wall s"
    );
    println!(
        "{:32} {:>10} {:>12} {:>12} {:>8} {:>9} {:>9}",
        "", "", "(incr)", "(ref)", "ratio", "(incr)", "(ref)"
    );
    for (label, mk) in cases {
        let (inc, inc_s) = timed_run(mk(), FabricKind::Incremental);
        let (refr, ref_s) = timed_run(mk(), FabricKind::Reference);
        // The oracle contract, enforced in release mode on every sweep:
        // identical event streams, identical results, bit for bit.
        assert_eq!(
            inc.fingerprint(),
            refr.fingerprint(),
            "{label}: incremental and reference engines diverged"
        );
        assert_eq!(inc.sim_events, refr.sim_events, "{label}: event counts diverged");
        let ev = inc.sim_events.max(1) as f64;
        let inc_pe = inc.fabric_rate_recomputes as f64 / ev;
        let ref_pe = refr.fabric_rate_recomputes as f64 / ev;
        let ratio = refr.fabric_rate_recomputes as f64
            / (inc.fabric_rate_recomputes as f64).max(1.0);
        println!(
            "{label:32} {:>10} {inc_pe:>12.3} {ref_pe:>12.3} {ratio:>7.1}x {inc_s:>9.3} {ref_s:>9.3}",
            inc.sim_events
        );
        report.metric(&format!("{label}: events"), ev);
        report.metric(&format!("{label}: recomputes/event incremental"), inc_pe);
        report.metric(&format!("{label}: recomputes/event reference"), ref_pe);
        report.metric(&format!("{label}: recompute reduction"), ratio);
        report.metric(&format!("{label}: wall_s incremental"), inc_s);
        report.metric(&format!("{label}: wall_s reference"), ref_s);
        report.metric(&format!("{label}: wall speedup"), ref_s / inc_s.max(1e-9));
    }

    banner("sharded engine sweep (sharded PDES core vs single-queue reference)");
    println!(
        "{:32} {:>10} {:>7} {:>9} {:>9} {:>8} {:>10} {:>8}",
        "case", "events", "shards", "wall s", "wall s", "speedup", "cross", "windows"
    );
    println!(
        "{:32} {:>10} {:>7} {:>9} {:>9} {:>8} {:>10} {:>8}",
        "", "", "", "(single)", "(shard)", "", "shard %", ""
    );
    // Horizons shrink as N grows to keep the sweep's wall time bounded;
    // fingerprint equality is still asserted on every case, so this
    // section is also the release-mode engine-equivalence check at a
    // scale the unit tests never reach.
    for (n, horizon, shards) in [(1024usize, 30.0f64, 8usize), (4096, 20.0, 8)] {
        let mk = |shard_count: usize| {
            let mut s = Scenario::dense_hotspot(11, n, Levers::full());
            s.horizon = horizon;
            s.shards = shard_count;
            s
        };
        let (single, single_s) = timed_run(mk(1), FabricKind::Incremental);
        let (sharded, sharded_s) = timed_run_recorded(mk(shards), FabricKind::Incremental);
        let label = format!("N={n} (dense hotspot, sharded)");
        // The sharded core's contract: byte-identical to the reference
        // engine, bit for bit, or the run is wrong.
        assert_eq!(
            single.fingerprint(),
            sharded.fingerprint(),
            "{label}: sharded and single-queue engines diverged"
        );
        assert_eq!(
            single.sim_events, sharded.sim_events,
            "{label}: event counts diverged"
        );
        let speedup = single_s / sharded_s.max(1e-9);
        let cross_pct =
            100.0 * sharded.cross_shard_events as f64 / sharded.sim_events.max(1) as f64;
        println!(
            "{label:32} {:>10} {:>7} {single_s:>9.3} {sharded_s:>9.3} {speedup:>7.2}x {cross_pct:>9.1}% {:>8}",
            sharded.sim_events, sharded.shards, sharded.sync_windows
        );
        report.metric(&format!("{label}: events"), sharded.sim_events as f64);
        report.metric(&format!("{label}: wall_s single-queue"), single_s);
        report.metric(&format!("{label}: wall_s sharded"), sharded_s);
        report.metric(&format!("{label}: sharded speedup"), speedup);
        report.metric(&format!("{label}: cross-shard %"), cross_pct);
        report.metric(&format!("{label}: sync windows"), sharded.sync_windows as f64);
        // Flight-recorder registry: per-shard occupancy/stall and
        // engine-level counters — the parallelism-headroom numbers the
        // speculative-execution work item starts from.
        for (k, v) in &sharded.metrics {
            if k.starts_with("shard") || k.starts_with("engine.") {
                report.metric(&format!("{label}: {k}"), *v);
            }
        }
    }

    report.write_json("BENCH_scale_sweep.json");
}
