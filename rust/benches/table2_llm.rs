//! Regenerates **Table 2** (LLM serving case study): TTFT p99 and
//! normalized throughput for static MIG vs the full system under the
//! same T2/T3 interference, SLO TTFT p99 <= 200 ms.
use predserve::bench::{banner, bench_throughput};
use predserve::experiments::harness::Repeats;
use predserve::experiments::runs;

fn main() {
    banner("Table 2 — LLM serving (vLLM-like engine workload, TTFT)");
    let repeats = Repeats::from_env();
    let sums = bench_throughput("llm case: 2 configs x repeats", (repeats.count * 2) as u64, "runs", || {
        runs::run_table2(&repeats)
    });
    println!("\n{}", runs::render_table2(&sums));
}
