//! Regenerates **Figure 3**: (a) the controller's adaptive action
//! timeline under interference bursts; (b) the efficiency-compliance
//! scatter over the five configurations.
use predserve::bench::banner;
use predserve::experiments::harness::Repeats;
use predserve::experiments::runs;

fn main() {
    banner("Figure 3 — adaptive behavior & efficiency-compliance");
    let repeats = Repeats::from_env();
    println!("{}", runs::run_fig3(&repeats));
}
