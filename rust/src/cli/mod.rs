//! Minimal CLI argument parser (clap substitute).
//!
//! Supports `--flag`, `--key value`, `--key=value` and positional args.

use std::collections::BTreeMap;

/// Parsed command line.
#[derive(Clone, Debug, Default)]
pub struct Args {
    pub positional: Vec<String>,
    options: BTreeMap<String, String>,
    flags: Vec<String>,
}

impl Args {
    pub fn parse(argv: impl IntoIterator<Item = String>) -> Args {
        let mut out = Args::default();
        let mut it = argv.into_iter().peekable();
        while let Some(a) = it.next() {
            if let Some(rest) = a.strip_prefix("--") {
                if let Some((k, v)) = rest.split_once('=') {
                    out.options.insert(k.to_string(), v.to_string());
                } else if it
                    .peek()
                    .map(|n| !n.starts_with("--"))
                    .unwrap_or(false)
                {
                    let v = it.next().unwrap();
                    out.options.insert(rest.to_string(), v);
                } else {
                    out.flags.push(rest.to_string());
                }
            } else {
                out.positional.push(a);
            }
        }
        out
    }

    pub fn from_env() -> Args {
        Args::parse(std::env::args().skip(1))
    }

    pub fn flag(&self, name: &str) -> bool {
        self.flags.iter().any(|f| f == name)
    }

    pub fn get(&self, name: &str) -> Option<&str> {
        self.options.get(name).map(|s| s.as_str())
    }

    pub fn get_f64(&self, name: &str, default: f64) -> f64 {
        self.get(name)
            .and_then(|s| s.parse().ok())
            .unwrap_or(default)
    }

    pub fn get_u64(&self, name: &str, default: u64) -> u64 {
        self.get(name)
            .and_then(|s| s.parse().ok())
            .unwrap_or(default)
    }

    pub fn get_usize(&self, name: &str, default: usize) -> usize {
        self.get(name)
            .and_then(|s| s.parse().ok())
            .unwrap_or(default)
    }

    pub fn get_str<'a>(&'a self, name: &str, default: &'a str) -> &'a str {
        self.get(name).unwrap_or(default)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &str) -> Args {
        Args::parse(s.split_whitespace().map(String::from))
    }

    #[test]
    fn positional_and_options() {
        // Bare flags bind a following bare word as their value, so place
        // flags last or use `=` (documented behavior).
        let a = parse("sim extra --seed 7 --levers=full --fast");
        assert_eq!(a.positional, vec!["sim", "extra"]);
        assert_eq!(a.get_u64("seed", 0), 7);
        assert_eq!(a.get_str("levers", "none"), "full");
        assert!(a.flag("fast"));
    }

    #[test]
    fn defaults_apply() {
        let a = parse("run");
        assert_eq!(a.get_f64("horizon", 1800.0), 1800.0);
        assert!(!a.flag("fast"));
    }

    #[test]
    fn flag_before_positional() {
        let a = parse("--verbose cmd");
        // "--verbose cmd": cmd is consumed as the value of --verbose
        // (documented behavior: place flags after positionals or use =).
        assert_eq!(a.get_str("verbose", ""), "cmd");
    }
}
