//! Config system: JSON config files overriding scenario / controller
//! parameters (serde substitute; schema documented in README).
//!
//! Example:
//! ```json
//! {
//!   "controller": {"tau_ms": 12.5, "persistence_y": 3, "levers": "full"},
//!   "workload":   {"arrival_rps": 80.0, "slo_ms": 15.0},
//!   "run":        {"horizon_s": 1800.0, "sample_dt": 2.0, "seed": 11}
//! }
//! ```

use crate::controller::Levers;
use crate::platform::Scenario;
use crate::util::json::Json;
use anyhow::{anyhow, Result};

/// Parse a lever set name.
pub fn parse_levers(s: &str) -> Result<Levers> {
    Ok(match s {
        "full" => Levers::full(),
        "none" | "static" => Levers::none(),
        "mig" | "mig-only" => Levers::mig_only(),
        "placement" | "placement-only" => Levers::placement_only(),
        "guards" | "guards-only" => Levers::guards_only(),
        other => return Err(anyhow!("unknown lever set '{other}'")),
    })
}

/// Apply a parsed config JSON onto a scenario.
pub fn apply(scenario: &mut Scenario, j: &Json) -> Result<()> {
    let ctl = j.get("controller");
    if let Some(v) = ctl.get("tau_ms").as_f64() {
        scenario.controller.tau_ms = v;
    }
    if let Some(v) = ctl.get("persistence_y").as_f64() {
        scenario.controller.persistence_y = v as u32;
    }
    if let Some(v) = ctl.get("dwell_obs").as_f64() {
        scenario.controller.dwell_obs = v as u64;
    }
    if let Some(v) = ctl.get("cooldown_obs").as_f64() {
        scenario.controller.cooldown_obs = v as u64;
    }
    // Note: the admission thresholds (`safe_score`, `link_headroom`) are
    // deliberately NOT config-file keys — placements resolve at
    // `ScenarioBuilder::build` time, before a config file is applied, so
    // a post-build override would be silently inert. Scenarios tune them
    // through `ControllerConfig` (e.g. `ControllerConfig::dense_pack`).
    if let Some(s) = ctl.get("levers").as_str() {
        scenario.controller.levers = parse_levers(s)?;
    }
    let wl = j.get("workload");
    if let Some(v) = wl.get("arrival_rps").as_f64() {
        scenario.primary_spec_mut().arrival_rps = v;
    }
    if let Some(v) = wl.get("slo_ms").as_f64() {
        scenario.primary_spec_mut().slo_ms = v;
        scenario.controller.tau_ms = v;
    }
    let run = j.get("run");
    if let Some(v) = run.get("horizon_s").as_f64() {
        scenario.horizon = v;
    }
    if let Some(v) = run.get("sample_dt").as_f64() {
        scenario.sample_dt = v;
    }
    if let Some(v) = run.get("seed").as_f64() {
        scenario.seed = v as u64;
    }
    if let Some(v) = run.get("shards").as_f64() {
        let n = v as usize;
        if n < 1 {
            return Err(anyhow!("run.shards must be >= 1, got {v}"));
        }
        scenario.shards = n;
    }
    Ok(())
}

/// Load and apply a config file.
pub fn load_into(scenario: &mut Scenario, path: &str) -> Result<()> {
    let text = std::fs::read_to_string(path)?;
    let j = Json::parse(&text).map_err(|e| anyhow!("config parse: {e}"))?;
    apply(scenario, &j)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn apply_overrides() {
        let mut s = Scenario::paper_single_host(1, Levers::none());
        let j = Json::parse(
            r#"{"controller":{"tau_ms":12.5,"levers":"mig"},
                "workload":{"arrival_rps":50},
                "run":{"horizon_s":300,"seed":9}}"#,
        )
        .unwrap();
        apply(&mut s, &j).unwrap();
        assert_eq!(s.controller.tau_ms, 12.5);
        assert_eq!(s.controller.levers, Levers::mig_only());
        assert_eq!(s.primary_spec().arrival_rps, 50.0);
        assert_eq!(s.horizon, 300.0);
        assert_eq!(s.seed, 9);
    }

    #[test]
    fn bad_levers_rejected() {
        assert!(parse_levers("turbo").is_err());
        assert!(parse_levers("full").is_ok());
    }

    #[test]
    fn partial_config_ok() {
        let mut s = Scenario::paper_single_host(1, Levers::full());
        let before_tau = s.controller.tau_ms;
        apply(&mut s, &Json::parse("{}").unwrap()).unwrap();
        assert_eq!(s.controller.tau_ms, before_tau);
    }
}
