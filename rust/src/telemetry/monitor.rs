//! Per-tenant latency monitor: sliding window tails + lifetime histogram.

use super::signals::TailStats;
use crate::util::histogram::Histogram;
use crate::util::quantile::WindowQuantiles;

/// Tracks one tenant's request latencies.
///
/// * A sliding window (Algorithm 1's `W`) drives the controller decisions.
/// * A lifetime [`Histogram`] (microseconds) feeds the experiment reports
///   (Table 3 columns, Figure 4 distributions).
#[derive(Clone, Debug)]
pub struct TenantMonitor {
    pub slo_ms: f64,
    window: WindowQuantiles,
    lifetime: Histogram,
    window_completed: u64,
    window_started_at: f64,
    total_completed: u64,
    total_missed: u64,
}

impl TenantMonitor {
    pub fn new(slo_ms: f64, window_capacity: usize) -> TenantMonitor {
        TenantMonitor {
            slo_ms,
            window: WindowQuantiles::new(window_capacity),
            lifetime: Histogram::new(),
            window_completed: 0,
            window_started_at: 0.0,
            total_completed: 0,
            total_missed: 0,
        }
    }

    /// Record a completed request latency (ms).
    pub fn observe(&mut self, latency_ms: f64) {
        self.window.observe(latency_ms);
        self.lifetime.record((latency_ms * 1000.0) as u64);
        self.window_completed += 1;
        self.total_completed += 1;
        if latency_ms > self.slo_ms {
            self.total_missed += 1;
        }
    }

    /// Produce window tail stats and reset the per-interval counters.
    /// `now`/`dt` give the throughput denominator.
    pub fn sample(&mut self, now: f64) -> TailStats {
        let dt = (now - self.window_started_at).max(1e-9);
        let stats = TailStats {
            p50_ms: self.window.quantile(0.50).unwrap_or(0.0),
            p95_ms: self.window.quantile(0.95).unwrap_or(0.0),
            p99_ms: self.window.quantile(0.99).unwrap_or(0.0),
            p999_ms: self.window.quantile(0.999).unwrap_or(0.0),
            miss_rate: self.window.frac_above(self.slo_ms),
            completed: self.window_completed,
            rps: self.window_completed as f64 / dt,
        };
        self.window_completed = 0;
        self.window_started_at = now;
        stats
    }

    /// Lifetime histogram (microseconds).
    pub fn histogram(&self) -> &Histogram {
        &self.lifetime
    }

    /// Lifetime SLO miss-rate (the number reported in Table 3).
    pub fn lifetime_miss_rate(&self) -> f64 {
        if self.total_completed == 0 {
            return 0.0;
        }
        self.total_missed as f64 / self.total_completed as f64
    }

    pub fn total_completed(&self) -> u64 {
        self.total_completed
    }

    /// Lifetime p-quantile in ms.
    pub fn lifetime_quantile_ms(&self, q: f64) -> f64 {
        self.lifetime.quantile(q) as f64 / 1000.0
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn miss_rate_counts_violations() {
        let mut m = TenantMonitor::new(15.0, 64);
        for _ in 0..9 {
            m.observe(10.0);
        }
        m.observe(20.0);
        assert!((m.lifetime_miss_rate() - 0.1).abs() < 1e-12);
        let s = m.sample(1.0);
        assert!((s.miss_rate - 0.1).abs() < 1e-12);
        assert_eq!(s.completed, 10);
        assert!((s.rps - 10.0).abs() < 1e-9);
    }

    #[test]
    fn sample_resets_interval_counters() {
        let mut m = TenantMonitor::new(15.0, 64);
        m.observe(5.0);
        m.sample(1.0);
        let s2 = m.sample(2.0);
        assert_eq!(s2.completed, 0);
        assert_eq!(s2.rps, 0.0);
        // Window quantiles persist across samples (sliding window).
        assert!(s2.p50_ms > 0.0);
    }

    #[test]
    fn lifetime_quantiles_in_ms() {
        let mut m = TenantMonitor::new(15.0, 1024);
        for i in 1..=100 {
            m.observe(i as f64);
        }
        let p99 = m.lifetime_quantile_ms(0.99);
        assert!((p99 - 99.0).abs() / 99.0 < 0.05, "p99={p99}");
    }
}
