//! Telemetry: the NVML/DCGM-like monitoring domain of the controller.
//!
//! Every Δ seconds (§2.1) the platform produces a [`SignalSnapshot`]:
//! per-tenant latency tails + SLO miss-rate, PCIe byte rates, SM
//! utilization, host block-I/O and IRQ activity. The controller consumes
//! only this struct — it never reaches into the simulator, which is what
//! keeps it deployable against a real NVML backend (the paper's
//! "fabric-agnostic, VM-deployable" claim).

pub mod monitor;
pub mod signals;

pub use monitor::TenantMonitor;
pub use signals::{LinkSignal, SignalSnapshot, TailStats, TenantSignal};
