//! Signal schema shared by the sim and (hypothetically) real backends.

use crate::tenants::TenantId;
use crate::topo::LinkId;

/// Latency tail statistics over the current observation window.
#[derive(Clone, Copy, Debug, Default)]
pub struct TailStats {
    pub p50_ms: f64,
    pub p95_ms: f64,
    pub p99_ms: f64,
    pub p999_ms: f64,
    /// Fraction of requests in the window above the SLO threshold.
    pub miss_rate: f64,
    /// Completed requests in the window.
    pub completed: u64,
    /// Throughput (requests/s) over the window.
    pub rps: f64,
}

/// Per-tenant view.
#[derive(Clone, Debug)]
pub struct TenantSignal {
    pub tenant: TenantId,
    pub tails: TailStats,
    /// Time-to-first-token tails, present only for tenants serving LLM
    /// requests through the request-granularity engine
    /// (`LsSpec::llm`). Controllers with a TTFT objective read these;
    /// everyone else ignores them.
    pub ttft: Option<TailStats>,
    /// GB/s this tenant moved over PCIe since the last sample.
    pub pcie_gbps: f64,
    /// GB/s of host block I/O attributable to this tenant.
    pub block_io_gbps: f64,
    /// Is the tenant currently active (background tenants toggle)?
    pub active: bool,
    /// True when this signal is a held-last copy: the tenant's sensor
    /// dropped out (fault injection) and no fresh window backs these
    /// numbers. Controllers hold conservative behavior within a TTL and
    /// then stop proposing disruptive changes on stale data.
    pub stale: bool,
}

/// Per shared-link view (PCIe switch uplinks + NVMe paths).
#[derive(Clone, Copy, Debug)]
pub struct LinkSignal {
    pub link: LinkId,
    /// Mean utilization since the last sample (0..1).
    pub utilization: f64,
    /// GB/s through the link since the last sample.
    pub gbps: f64,
}

/// Everything the controller sees at one sampling tick (§2.1 signals).
#[derive(Clone, Debug)]
pub struct SignalSnapshot {
    /// Sample time (sim seconds).
    pub t: f64,
    /// Sampling interval Δ that produced the rates below.
    pub dt: f64,
    pub tenants: Vec<TenantSignal>,
    pub links: Vec<LinkSignal>,
    /// SM utilization per GPU (0..1), NVML style.
    pub gpu_sm_util: Vec<f64>,
    /// Block-I/O rate per NUMA domain (GB/s).
    pub numa_io_gbps: Vec<f64>,
    /// IRQ rate per NUMA domain (interrupts/s, synthetic: scales with NIC
    /// and storage activity).
    pub numa_irq_rate: Vec<f64>,
}

impl SignalSnapshot {
    pub fn tenant(&self, id: TenantId) -> Option<&TenantSignal> {
        self.tenants.iter().find(|t| t.tenant == id)
    }

    pub fn link(&self, id: LinkId) -> Option<&LinkSignal> {
        self.links.iter().find(|l| l.link == id)
    }
}

/// Synthetic per-NUMA IRQ-rate model (interrupts/s): a floor plus terms
/// scaling with the domain's storage and PCIe traffic. Single source of
/// truth shared by the simulated host's telemetry and the allocator's
/// planning snapshot — plan-time placement scores must not drift from
/// the scores the live controller computes.
pub fn synthetic_irq_rate(io_gbps: f64, pcie_gbps: f64) -> f64 {
    200.0 + 800.0 * io_gbps + 120.0 * pcie_gbps
}
