//! Deterministic fault injection: typed, timed adversities for the
//! simulated host and the cluster layer.
//!
//! Production is hostile — links flap, MIG reconfiguration stalls or
//! fails mid-flight (reconfigurable-machine scheduling on MIG treats
//! reconfig cost/failure as a first-class input), telemetry goes stale,
//! and fleet workers crash. A [`FaultPlan`] is a list of [`FaultSpec`]s
//! with explicit timestamps, attached to a scenario via
//! `ScenarioBuilder::faults` or `sim --faults FILE`. The platform
//! expands the plan into timed fault *edges* (inject / clear) that ride
//! the ordinary event queue, so fault runs are exactly as deterministic
//! as fault-free ones: same seed + same plan ⇒ same fingerprint.
//!
//! **Bit-compat contract:** an empty plan is invisible. No fault events
//! are seeded, no RNG stream is touched, and every catalog fingerprint
//! is byte-identical to a build without this module
//! (`prop_empty_fault_plan_is_byte_identical`). The only probabilistic
//! fault — [`FaultSpec::ReconfigFlaky`] — draws from a dedicated RNG
//! stream ([`FAULT_STREAM`]), and only when a disruptive action is
//! actually attempted inside a flaky window, so the workload streams
//! never shift.

use anyhow::{bail, Result};

use crate::util::json::Json;

/// Dedicated RNG stream for fault draws (`Pcg64::new(seed, FAULT_STREAM)`).
/// Streams 0-6 belong to the workload/trigger/reconfig paths; 100+ to
/// generated N-tenant scenarios; 1000 to schedules.
pub const FAULT_STREAM: u64 = 7;

/// One typed, timed fault.
#[derive(Clone, Debug, PartialEq)]
pub enum FaultSpec {
    /// Shared-link capacity drops to `factor ×` nominal at `at`, for
    /// `duration` seconds (congestion, lane downgrade, cable brownout).
    LinkDegrade {
        link: usize,
        factor: f64,
        at: f64,
        duration: f64,
    },
    /// Repeated link degradation: from `from` to `until`, every
    /// `period_s` the link drops to `factor ×` nominal for `down_s`.
    LinkFlap {
        link: usize,
        factor: f64,
        from: f64,
        until: f64,
        period_s: f64,
        down_s: f64,
    },
    /// Xid-style device loss on the tenant's slice at `at`: the
    /// in-flight request fails and re-queues, and the tenant pauses for
    /// `recovery_s` (driver reset + instance re-create).
    SliceFail {
        tenant: usize,
        at: f64,
        recovery_s: f64,
    },
    /// MIG/placement actions become fallible and slow inside the
    /// window: each disruptive actuation fails with `fail_prob`, and
    /// successful ones take `latency_ms` longer.
    ReconfigFlaky {
        fail_prob: f64,
        latency_ms: f64,
        at: f64,
        duration: f64,
    },
    /// Telemetry for one tenant goes stale: its monitor reports no fresh
    /// window from `at` for `duration` seconds (the controller sees the
    /// last-known signal flagged stale).
    SensorDropout {
        tenant: usize,
        at: f64,
        duration: f64,
    },
    /// Cluster runs only: the named worker node accepts work and then
    /// drops its connection. No effect on single-host sims.
    WorkerCrash { node: String },
}

impl FaultSpec {
    /// Stable tag used by the JSON plan format and trace exports.
    pub fn kind_str(&self) -> &'static str {
        match self {
            FaultSpec::LinkDegrade { .. } => "link_degrade",
            FaultSpec::LinkFlap { .. } => "link_flap",
            FaultSpec::SliceFail { .. } => "slice_fail",
            FaultSpec::ReconfigFlaky { .. } => "reconfig_flaky",
            FaultSpec::SensorDropout { .. } => "sensor_dropout",
            FaultSpec::WorkerCrash { .. } => "worker_crash",
        }
    }

    /// Compact kind code for fixed-size trace events.
    pub fn kind_code(&self) -> u8 {
        match self {
            FaultSpec::LinkDegrade { .. } => 0,
            FaultSpec::LinkFlap { .. } => 1,
            FaultSpec::SliceFail { .. } => 2,
            FaultSpec::ReconfigFlaky { .. } => 3,
            FaultSpec::SensorDropout { .. } => 4,
            FaultSpec::WorkerCrash { .. } => 5,
        }
    }

    /// The fault's subject (link index, tenant index, 0 for host-wide
    /// faults) for fixed-size trace events.
    pub fn subject(&self) -> u32 {
        match self {
            FaultSpec::LinkDegrade { link, .. } | FaultSpec::LinkFlap { link, .. } => *link as u32,
            FaultSpec::SliceFail { tenant, .. } | FaultSpec::SensorDropout { tenant, .. } => {
                *tenant as u32
            }
            FaultSpec::ReconfigFlaky { .. } | FaultSpec::WorkerCrash { .. } => 0,
        }
    }
}

/// One inject/clear edge a fault contributes to the event timeline.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct FaultEdge {
    /// Sim time of the edge (seconds).
    pub t: f64,
    /// Index into the plan's spec list.
    pub spec: usize,
    /// `true` = inject (fault begins), `false` = clear (fault ends).
    pub inject: bool,
}

/// A deterministic schedule of faults for one run.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct FaultPlan {
    pub specs: Vec<FaultSpec>,
}

impl FaultPlan {
    pub fn new(specs: Vec<FaultSpec>) -> FaultPlan {
        FaultPlan { specs }
    }

    /// An empty plan is the bit-compat identity: no events, no RNG.
    pub fn is_empty(&self) -> bool {
        self.specs.is_empty()
    }

    /// Nodes a cluster leader must treat as crash-scheduled. Sim-level
    /// expansion ignores these (they have no single-host meaning).
    pub fn crash_nodes(&self) -> Vec<String> {
        self.specs
            .iter()
            .filter_map(|s| match s {
                FaultSpec::WorkerCrash { node } => Some(node.clone()),
                _ => None,
            })
            .collect()
    }

    /// Structural validation, called from `ScenarioBuilder::build` and
    /// the CLI parser.
    pub fn validate(&self) -> Result<()> {
        for (i, s) in self.specs.iter().enumerate() {
            match s {
                FaultSpec::LinkDegrade {
                    factor,
                    at,
                    duration,
                    ..
                } => {
                    if !(0.0..=1.0).contains(factor) {
                        bail!("fault {i}: link_degrade factor must be in [0,1], got {factor}");
                    }
                    if *at < 0.0 || *duration <= 0.0 {
                        bail!("fault {i}: link_degrade needs at >= 0 and duration > 0");
                    }
                }
                FaultSpec::LinkFlap {
                    factor,
                    from,
                    until,
                    period_s,
                    down_s,
                    ..
                } => {
                    if !(0.0..=1.0).contains(factor) {
                        bail!("fault {i}: link_flap factor must be in [0,1], got {factor}");
                    }
                    if *from < 0.0 || *until <= *from {
                        bail!("fault {i}: link_flap needs 0 <= from < until");
                    }
                    if *period_s <= 0.0 || *down_s <= 0.0 || *down_s >= *period_s {
                        bail!("fault {i}: link_flap needs 0 < down_s < period_s");
                    }
                }
                FaultSpec::SliceFail { at, recovery_s, .. } => {
                    if *at < 0.0 || *recovery_s <= 0.0 {
                        bail!("fault {i}: slice_fail needs at >= 0 and recovery_s > 0");
                    }
                }
                FaultSpec::ReconfigFlaky {
                    fail_prob,
                    latency_ms,
                    at,
                    duration,
                } => {
                    if !(0.0..=1.0).contains(fail_prob) {
                        bail!("fault {i}: reconfig_flaky fail_prob must be in [0,1]");
                    }
                    if *latency_ms < 0.0 || *at < 0.0 || *duration <= 0.0 {
                        bail!("fault {i}: reconfig_flaky needs latency_ms >= 0, at >= 0, duration > 0");
                    }
                }
                FaultSpec::SensorDropout { at, duration, .. } => {
                    if *at < 0.0 || *duration <= 0.0 {
                        bail!("fault {i}: sensor_dropout needs at >= 0 and duration > 0");
                    }
                }
                FaultSpec::WorkerCrash { node } => {
                    if node.is_empty() {
                        bail!("fault {i}: worker_crash needs a node name");
                    }
                }
            }
        }
        Ok(())
    }

    /// Expand the plan into sorted inject/clear edges within `[0,
    /// horizon)`. Flaps unroll into one down/up pair per period.
    /// Ordering is fully deterministic: by time, then spec index, with
    /// clears before injects at exactly equal times (a back-to-back
    /// flap clears the previous down-window before opening the next).
    pub fn edges(&self, horizon: f64) -> Vec<FaultEdge> {
        let mut out: Vec<FaultEdge> = Vec::new();
        let mut push = |t: f64, spec: usize, inject: bool| {
            if t >= 0.0 && t < horizon {
                out.push(FaultEdge { t, spec, inject });
            }
        };
        for (i, s) in self.specs.iter().enumerate() {
            match s {
                FaultSpec::LinkDegrade { at, duration, .. }
                | FaultSpec::ReconfigFlaky { at, duration, .. }
                | FaultSpec::SensorDropout { at, duration, .. } => {
                    push(*at, i, true);
                    push(*at + *duration, i, false);
                }
                FaultSpec::LinkFlap {
                    from,
                    until,
                    period_s,
                    down_s,
                    ..
                } => {
                    let mut k = 0u32;
                    loop {
                        let down = *from + f64::from(k) * *period_s;
                        if down >= *until {
                            break;
                        }
                        push(down, i, true);
                        push((down + *down_s).min(*until), i, false);
                        k += 1;
                    }
                }
                FaultSpec::SliceFail { at, .. } => {
                    // Recovery is modeled as a pause; the clear edge is
                    // implicit in `PauseDone`, so only the hit is timed.
                    push(*at, i, true);
                }
                FaultSpec::WorkerCrash { .. } => {} // cluster-level only
            }
        }
        out.sort_by(|a, b| {
            a.t.total_cmp(&b.t)
                .then(a.inject.cmp(&b.inject)) // clears first on ties
                .then(a.spec.cmp(&b.spec))
        });
        out
    }

    /// Parse the `--faults FILE` JSON format:
    ///
    /// ```json
    /// {"faults": [
    ///   {"kind": "link_degrade", "link": 0, "factor": 0.25, "at": 600, "duration": 120},
    ///   {"kind": "link_flap", "link": 0, "factor": 0.25, "from": 600, "until": 1200,
    ///    "period_s": 120, "down_s": 20},
    ///   {"kind": "slice_fail", "tenant": 0, "at": 600, "recovery_s": 30},
    ///   {"kind": "reconfig_flaky", "fail_prob": 0.5, "latency_ms": 250, "at": 0, "duration": 1800},
    ///   {"kind": "sensor_dropout", "tenant": 0, "at": 600, "duration": 60},
    ///   {"kind": "worker_crash", "node": "node1"}
    /// ]}
    /// ```
    pub fn parse_json(src: &str) -> Result<FaultPlan> {
        let j = Json::parse(src).map_err(|e| anyhow::anyhow!("fault plan: {e}"))?;
        let Some(arr) = j.get("faults").as_arr() else {
            bail!("fault plan: top-level object needs a \"faults\" array");
        };
        let mut specs = Vec::with_capacity(arr.len());
        for (i, f) in arr.iter().enumerate() {
            let num = |key: &str| -> Result<f64> {
                f.get(key)
                    .as_f64()
                    .ok_or_else(|| anyhow::anyhow!("fault {i}: missing/invalid \"{key}\""))
            };
            let idx = |key: &str| -> Result<usize> {
                f.get(key)
                    .as_usize()
                    .ok_or_else(|| anyhow::anyhow!("fault {i}: missing/invalid \"{key}\""))
            };
            let spec = match f.get("kind").as_str() {
                Some("link_degrade") => FaultSpec::LinkDegrade {
                    link: idx("link")?,
                    factor: num("factor")?,
                    at: num("at")?,
                    duration: num("duration")?,
                },
                Some("link_flap") => FaultSpec::LinkFlap {
                    link: idx("link")?,
                    factor: num("factor")?,
                    from: num("from")?,
                    until: num("until")?,
                    period_s: num("period_s")?,
                    down_s: num("down_s")?,
                },
                Some("slice_fail") => FaultSpec::SliceFail {
                    tenant: idx("tenant")?,
                    at: num("at")?,
                    recovery_s: num("recovery_s")?,
                },
                Some("reconfig_flaky") => FaultSpec::ReconfigFlaky {
                    fail_prob: num("fail_prob")?,
                    latency_ms: num("latency_ms")?,
                    at: num("at")?,
                    duration: num("duration")?,
                },
                Some("sensor_dropout") => FaultSpec::SensorDropout {
                    tenant: idx("tenant")?,
                    at: num("at")?,
                    duration: num("duration")?,
                },
                Some("worker_crash") => FaultSpec::WorkerCrash {
                    node: f
                        .get("node")
                        .as_str()
                        .ok_or_else(|| anyhow::anyhow!("fault {i}: missing \"node\""))?
                        .to_string(),
                },
                Some(other) => bail!("fault {i}: unknown kind \"{other}\""),
                None => bail!("fault {i}: missing \"kind\""),
            };
            specs.push(spec);
        }
        let plan = FaultPlan { specs };
        plan.validate()?;
        Ok(plan)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn empty_plan_has_no_edges() {
        let p = FaultPlan::default();
        assert!(p.is_empty());
        assert!(p.edges(1800.0).is_empty());
        assert!(p.crash_nodes().is_empty());
        assert!(p.validate().is_ok());
    }

    #[test]
    fn degrade_expands_to_inject_and_clear() {
        let p = FaultPlan::new(vec![FaultSpec::LinkDegrade {
            link: 0,
            factor: 0.5,
            at: 100.0,
            duration: 50.0,
        }]);
        let e = p.edges(1800.0);
        assert_eq!(e.len(), 2);
        assert!(e[0].inject && e[0].t == 100.0);
        assert!(!e[1].inject && e[1].t == 150.0);
    }

    #[test]
    fn flap_unrolls_periods_and_respects_horizon() {
        let p = FaultPlan::new(vec![FaultSpec::LinkFlap {
            link: 1,
            factor: 0.25,
            from: 0.0,
            until: 300.0,
            period_s: 100.0,
            down_s: 20.0,
        }]);
        let e = p.edges(1800.0);
        // 3 periods: down at 0/100/200, up at 20/120/220.
        assert_eq!(e.len(), 6);
        assert_eq!(
            e.iter().filter(|x| x.inject).count(),
            3,
            "three down edges: {e:?}"
        );
        // Sorted by time.
        for w in e.windows(2) {
            assert!(w[0].t <= w[1].t);
        }
        // Edges beyond a short horizon are dropped.
        assert_eq!(p.edges(110.0).len(), 3); // down@0, up@20, down@100
    }

    #[test]
    fn worker_crash_is_cluster_only() {
        let p = FaultPlan::new(vec![FaultSpec::WorkerCrash {
            node: "node1".to_string(),
        }]);
        assert!(p.edges(1800.0).is_empty());
        assert_eq!(p.crash_nodes(), vec!["node1".to_string()]);
    }

    #[test]
    fn json_roundtrip_all_kinds() {
        let src = r#"{"faults": [
            {"kind": "link_degrade", "link": 0, "factor": 0.25, "at": 600, "duration": 120},
            {"kind": "link_flap", "link": 0, "factor": 0.25, "from": 600, "until": 1200,
             "period_s": 120, "down_s": 20},
            {"kind": "slice_fail", "tenant": 0, "at": 600, "recovery_s": 30},
            {"kind": "reconfig_flaky", "fail_prob": 0.5, "latency_ms": 250, "at": 0,
             "duration": 1800},
            {"kind": "sensor_dropout", "tenant": 0, "at": 600, "duration": 60},
            {"kind": "worker_crash", "node": "node1"}
        ]}"#;
        let p = FaultPlan::parse_json(src).unwrap();
        assert_eq!(p.specs.len(), 6);
        assert_eq!(p.specs[0].kind_str(), "link_degrade");
        assert_eq!(p.specs[5].kind_str(), "worker_crash");
        assert_eq!(p.crash_nodes(), vec!["node1".to_string()]);
    }

    #[test]
    fn json_rejects_bad_plans() {
        assert!(FaultPlan::parse_json("{}").is_err());
        assert!(FaultPlan::parse_json(r#"{"faults": [{"kind": "nope"}]}"#).is_err());
        // factor out of range
        assert!(FaultPlan::parse_json(
            r#"{"faults": [{"kind": "link_degrade", "link": 0, "factor": 2.0,
                "at": 0, "duration": 10}]}"#
        )
        .is_err());
        // down_s >= period_s
        assert!(FaultPlan::parse_json(
            r#"{"faults": [{"kind": "link_flap", "link": 0, "factor": 0.5, "from": 0,
                "until": 100, "period_s": 10, "down_s": 10}]}"#
        )
        .is_err());
    }

    #[test]
    fn edge_expansion_is_deterministic() {
        let p = FaultPlan::new(vec![
            FaultSpec::LinkFlap {
                link: 0,
                factor: 0.5,
                from: 10.0,
                until: 500.0,
                period_s: 60.0,
                down_s: 15.0,
            },
            FaultSpec::SensorDropout {
                tenant: 1,
                at: 30.0,
                duration: 45.0,
            },
        ]);
        assert_eq!(p.edges(1800.0), p.edges(1800.0));
    }
}
