//! Platform layer: composes topology + fabric + GPUs + tenants +
//! telemetry + controller into a runnable testbed.
//!
//! * [`scenario`] — experiment configuration (the §3.1 setup: workloads,
//!   schedules, SLOs, controller parameters, seeds).
//! * [`sim_platform`] — the discrete-event world that reproduces the
//!   paper's single-host testbed; the controller interacts with it only
//!   through `SignalSnapshot`/`PlannerView`/`Action` (fabric-agnostic).
//! * [`result`] — run outputs: tails, miss-rate, throughput, histograms,
//!   action timeline (the raw material for every table and figure).

pub mod scenario;
pub mod sim_platform;
pub mod result;

pub use result::RunResult;
pub use scenario::Scenario;
pub use sim_platform::SimWorld;
