//! Platform layer: composes topology + fabric + GPUs + tenants +
//! telemetry + controller into a runnable testbed.
//!
//! * [`scenario`] — experiment configuration as data: an N-tenant
//!   workload mix (`Vec<TenantWorkload>`) with schedules, SLOs,
//!   placements, controller parameters and seeds, built through
//!   [`ScenarioBuilder`] or the named catalog ([`Scenario::by_name`]).
//! * [`sim_platform`] — the discrete-event world that generalizes the
//!   paper's single-host testbed to arbitrary tenant mixes; the
//!   controller interacts with it only through
//!   `SignalSnapshot`/`PlannerView`/`Action` (fabric-agnostic).
//! * [`result`] — run outputs: tails, miss-rate, throughput, histograms,
//!   per-tenant stats, action timeline (the raw material for every table
//!   and figure).

pub mod result;
pub mod scenario;
pub mod sim_platform;

pub use result::{RunResult, TenantControllerStats, TenantRunStats};
pub use scenario::{Scenario, ScenarioBuilder};
pub use sim_platform::{arrival_stream, SimWorld};
