//! Run outputs: everything the experiment harness and benches consume.

use crate::util::histogram::Histogram;

/// Aggregated result of one simulated run.
#[derive(Clone, Debug)]
pub struct RunResult {
    /// Configuration label ("Full System", "Static MIG", ...).
    pub label: String,
    pub seed: u64,
    pub horizon_s: f64,
    /// Lifetime SLO miss-rate of T1 (Table 3 column 1).
    pub miss_rate: f64,
    /// Lifetime tail latencies in ms (Table 3 column 2 et al.).
    pub p50_ms: f64,
    pub p95_ms: f64,
    pub p99_ms: f64,
    pub p999_ms: f64,
    pub mean_ms: f64,
    /// Completed T1 requests and throughput.
    pub completed: u64,
    pub rps: f64,
    /// Full latency histogram (µs) — Figure 4 source.
    pub histogram: Histogram,
    /// Controller action counts by kind.
    pub actions: Vec<(String, usize)>,
    /// Disruptive moves per hour (Table 4).
    pub moves_per_hour: f64,
    /// MIG reconfiguration durations sampled during the run (Table 4).
    pub reconfig_durations_s: Vec<f64>,
    /// Controller CPU share estimate (Table 4): decision-path wall time
    /// divided by simulated time.
    pub controller_cpu_frac: f64,
    /// Action timeline for Figure 3a: (t, kind, p99_at_decision).
    pub timeline: Vec<(f64, String, f64)>,
    /// Mean SM utilization of the T1 GPU (Figure 3b efficiency axis).
    pub mean_sm_util: f64,
    /// p99 timeseries sampled at Δ (Figure 3a upper panel).
    pub p99_series: Vec<(f64, f64)>,
}

impl RunResult {
    /// SLO compliance = 1 - miss rate (Figure 3b y-axis).
    pub fn compliance(&self) -> f64 {
        1.0 - self.miss_rate
    }

    pub fn action_count(&self, kind: &str) -> usize {
        self.actions
            .iter()
            .find(|(k, _)| k == kind)
            .map(|(_, c)| *c)
            .unwrap_or(0)
    }
}
