//! Run outputs: everything the experiment harness and benches consume.

use crate::tenants::{TenantId, TenantKind};
use crate::util::histogram::Histogram;

/// Lifetime statistics for one tenant of a run.
#[derive(Clone, Debug)]
pub struct TenantRunStats {
    pub tenant: TenantId,
    pub name: String,
    pub kind: TenantKind,
    /// SLO threshold (ms); `f64::MAX` for background tenants.
    pub slo_ms: f64,
    /// Completed units: requests (latency-sensitive), ETL cycles
    /// (bandwidth-heavy), or training steps (compute-heavy).
    pub completed: u64,
    pub miss_rate: f64,
    pub p50_ms: f64,
    pub p95_ms: f64,
    pub p99_ms: f64,
    pub p999_ms: f64,
    pub rps: f64,
    /// Total GB this tenant moved across all shared links.
    pub gb_moved: f64,
    /// Arrivals the tenant's arrival process emitted: requests for a
    /// latency-sensitive tenant, cycle triggers for a trigger-driven
    /// bandwidth-heavy tenant; 0 for tenants without an arrival side.
    /// Deterministic, but excluded from `RunResult::fingerprint` so
    /// pre-trace fingerprints stay byte-identical.
    pub arrivals_emitted: u64,
    /// Sim time at which a closed `ArrivalProcess::Trace` ran out of
    /// gaps (`None` for open-ended processes, or when the run's horizon
    /// ended first). Excluded from the fingerprint like
    /// `arrivals_emitted`.
    pub trace_exhausted_at: Option<f64>,
    /// Lifetime p99 time-to-first-token (ms) for tenants serving LLM
    /// requests through the request-granularity engine (`LsSpec::llm`);
    /// `None` for every other tenant. Deterministic, but excluded from
    /// `RunResult::fingerprint` so pre-LLM fingerprints stay
    /// byte-identical.
    pub ttft_p99: Option<f64>,
    /// Lifetime p99 time-per-output-token (ms); `None` unless serving
    /// LLM requests. Excluded from the fingerprint like `ttft_p99`.
    pub tpot_p99: Option<f64>,
    /// Lifetime fraction of requests whose TTFT exceeded the workload's
    /// `ttft_slo_ms`; `None` unless serving LLM requests. Excluded from
    /// the fingerprint like `ttft_p99`.
    pub ttft_slo_miss_rate: Option<f64>,
}

/// Per-controller statistics for one protected latency-sensitive tenant
/// (one entry per controller in the run's control plane — exactly one on
/// the legacy single-primary path, one per LS tenant with
/// `protect_all_ls`).
#[derive(Clone, Debug)]
pub struct TenantControllerStats {
    pub tenant: TenantId,
    pub name: String,
    /// Tail threshold τ this controller enforced (ms).
    pub tau_ms: f64,
    /// Action counts by kind, from this controller's audit log.
    pub actions: Vec<(String, usize)>,
    /// Times this controller's proposal lost arbitration (edge "defer").
    pub deferrals: usize,
}

impl TenantControllerStats {
    /// Total committed actions across kinds.
    pub fn total_actions(&self) -> usize {
        self.actions.iter().map(|(_, c)| c).sum()
    }
}

/// Aggregated result of one simulated run.
#[derive(Clone, Debug)]
pub struct RunResult {
    /// Configuration label ("Full System", "Static MIG", ...).
    pub label: String,
    /// Scenario catalog name.
    pub scenario: String,
    pub seed: u64,
    pub horizon_s: f64,
    /// Lifetime SLO miss-rate of the primary tenant (Table 3 column 1).
    pub miss_rate: f64,
    /// Primary tenant lifetime tail latencies in ms (Table 3 et al.).
    pub p50_ms: f64,
    pub p95_ms: f64,
    pub p99_ms: f64,
    pub p999_ms: f64,
    pub mean_ms: f64,
    /// Completed primary requests and throughput.
    pub completed: u64,
    pub rps: f64,
    /// Full primary latency histogram (µs) — Figure 4 source.
    pub histogram: Histogram,
    /// Per-tenant lifetime stats for EVERY tenant in the scenario.
    pub per_tenant: Vec<TenantRunStats>,
    /// Total GB through each shared link (PS conservation checks).
    pub link_gb: Vec<f64>,
    /// Total GB through each cluster net link, indexed by
    /// `NetLinkId.0` (empty for single-host scenarios without a
    /// `ClusterTopology`). Deterministic, but excluded from
    /// `fingerprint()` so cluster-free fingerprints stay
    /// byte-identical — and because the controller cannot see (let
    /// alone actuate on) this contention domain yet.
    pub net_link_gb: Vec<f64>,
    /// Mean utilization of each cluster net link over the horizon
    /// (util-integral / horizon). Empty and excluded from the
    /// fingerprint like `net_link_gb`.
    pub net_link_util: Vec<f64>,
    /// Controller action counts by kind.
    pub actions: Vec<(String, usize)>,
    /// Disruptive moves per hour (Table 4).
    pub moves_per_hour: f64,
    /// MIG reconfiguration durations sampled during the run (Table 4).
    pub reconfig_durations_s: Vec<f64>,
    /// Controller CPU share estimate (Table 4): decision-path wall time
    /// divided by simulated time.
    pub controller_cpu_frac: f64,
    /// Action timeline for Figure 3a: (t, kind, p99_at_decision).
    pub timeline: Vec<(f64, String, f64)>,
    /// Mean SM utilization of tenant-hosting GPUs (Figure 3b efficiency).
    pub mean_sm_util: f64,
    /// Primary p99 timeseries sampled at Δ (Figure 3a upper panel).
    pub p99_series: Vec<(f64, f64)>,
    /// Per-controller stats: one entry per protected LS tenant (a single
    /// entry on the legacy single-primary path; empty without levers).
    pub controller_stats: Vec<TenantControllerStats>,
    /// Arbitration: ticks where two or more isolation upgrades competed.
    pub arb_conflicts: u64,
    /// Arbitration: total deferred proposals (losses + validation holds).
    pub arb_deferrals: u64,
    /// Total discrete events the run dispatched (perf trajectory).
    pub sim_events: u64,
    /// Per-link PS rate-vector recomputations the fabric performed — the
    /// incremental engine's headline counter (the reference oracle counts
    /// the same quantity, so `scale_sweep` can report the reduction).
    /// Deterministic, but deliberately excluded from `fingerprint()` so
    /// pre-refactor fingerprints stay byte-identical.
    pub fabric_rate_recomputes: u64,
    /// Simulation-engine shard count (1 = the single-queue reference).
    /// Like every field below, deterministic but excluded from
    /// `fingerprint()` — the whole point of the sharded core is that it
    /// changes *none* of the fingerprinted metrics.
    pub shards: usize,
    /// Events dispatched per shard (empty on the single-queue engine).
    /// Imbalance here means the switch-subtree partition is skewed.
    pub per_shard_events: Vec<u64>,
    /// Events whose requested time fell a numerical hair (≤
    /// `sim::PAST_EVENT_EPS_S`) in the past and were clamped to the
    /// clock. Expected 0; a nonzero value is an early-warning signal of
    /// causality drift (beyond the epsilon the engine panics instead).
    pub clamped_events: u64,
    /// Pushes that crossed a shard boundary (uplink rate changes,
    /// arbiter commits, fleet-level admission). 0 on the single queue.
    pub cross_shard_events: u64,
    /// Conservative lookahead windows the sharded run partitioned into
    /// (window width = the scenario's sampling interval Δ).
    pub sync_windows: u64,
    /// Flight-recorder metrics snapshot: sorted `(name, value)` pairs
    /// from the run's `MetricsRegistry` ("ctl.decisions",
    /// "shard0.occupancy", ...). Empty when recording is disabled.
    /// Deterministic, but excluded from `fingerprint()` like every other
    /// observability field — recording must not change what a run *is*.
    pub metrics: Vec<(String, f64)>,
    /// Fault edges injected over the run (0 when the scenario carries an
    /// empty `FaultPlan`). Like every counter below, deterministic but
    /// OUTSIDE `fingerprint()` — fault bookkeeping must never change what
    /// a fault-free run *is*.
    pub faults_injected: u64,
    /// Fault edges cleared (transient faults whose window ended in-horizon).
    pub faults_cleared: u64,
    /// Controller actions that came back `Failed`/`TimedOut` from the
    /// platform (injected reconfig failures, timeouts). Outside the
    /// fingerprint.
    pub action_failures: u64,
    /// Failed actions the FSM re-proposed under bounded exponential
    /// backoff. Outside the fingerprint.
    pub action_retries: u64,
    /// In-flight requests re-queued by `SliceFail` device loss. Outside
    /// the fingerprint.
    pub requests_requeued: u64,
    /// Controllers that exhausted their retry budget and degraded to
    /// guardrails-only mode. Outside the fingerprint.
    pub degraded_controllers: u64,
}

impl RunResult {
    /// SLO compliance = 1 - miss rate (Figure 3b y-axis).
    pub fn compliance(&self) -> f64 {
        1.0 - self.miss_rate
    }

    pub fn action_count(&self, kind: &str) -> usize {
        self.actions
            .iter()
            .find(|(k, _)| k == kind)
            .map(|(_, c)| *c)
            .unwrap_or(0)
    }

    pub fn tenant_stats(&self, id: TenantId) -> Option<&TenantRunStats> {
        self.per_tenant.iter().find(|t| t.tenant == id)
    }

    /// Bit-exact digest of every deterministic metric (determinism tests:
    /// same seed ⇒ identical fingerprint). Excludes wall-clock derived
    /// fields (`controller_cpu_frac`).
    pub fn fingerprint(&self) -> String {
        use std::fmt::Write;
        let mut s = String::new();
        let _ = write!(
            s,
            "{}|{}|{}|{:x}|{:x}|{:x}|{:x}|{:x}|{:x}",
            self.label,
            self.seed,
            self.completed,
            self.miss_rate.to_bits(),
            self.p50_ms.to_bits(),
            self.p95_ms.to_bits(),
            self.p99_ms.to_bits(),
            self.p999_ms.to_bits(),
            self.mean_sm_util.to_bits(),
        );
        for t in &self.per_tenant {
            let _ = write!(
                s,
                ";{}:{}:{:x}:{:x}:{:x}",
                t.name,
                t.completed,
                t.miss_rate.to_bits(),
                t.p99_ms.to_bits(),
                t.gb_moved.to_bits(),
            );
        }
        for (t, kind, p99) in &self.timeline {
            let _ = write!(s, ";@{:x}:{kind}:{:x}", t.to_bits(), p99.to_bits());
        }
        // Multi-primary runs also pin the control plane's determinism
        // surface. Guarded so single-primary fingerprints stay
        // byte-identical to the pre-arbiter format (the regression tests
        // rely on that).
        if self.controller_stats.len() > 1 || self.arb_deferrals > 0 {
            let _ = write!(s, ";arb:{}:{}", self.arb_conflicts, self.arb_deferrals);
            for cs in &self.controller_stats {
                let _ = write!(s, ";ctl{}:{}:{}", cs.tenant.0, cs.total_actions(), cs.deferrals);
                for (kind, count) in &cs.actions {
                    let _ = write!(s, ",{kind}={count}");
                }
            }
        }
        s
    }
}
