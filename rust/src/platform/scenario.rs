//! Scenario configuration: experiment setups as data.
//!
//! A [`Scenario`] drives the simulated testbed with an arbitrary mix of
//! tenants (`Vec<TenantWorkload>`): any count of latency-sensitive /
//! bandwidth-heavy / compute-heavy workloads, each with its own spec,
//! schedule, SLO, and placement. Scenarios are composed through
//! [`ScenarioBuilder`] or taken from the named catalog
//! ([`Scenario::by_name`]), which includes the paper's §3.1 three-tenant
//! setups plus larger N-tenant cases in the spirit of MIG-Serving /
//! ParvaGPU evaluations.
//!
//! Identical schedules across configurations (§3.2) come from deriving
//! them off `seed` only — the controller/lever settings never perturb
//! workload RNG streams.

use crate::alloc::{AllocPlan, AutoRequest, HostAllocator, PlanEntry, SlotOutcome};
use crate::controller::{ControllerConfig, Levers, SloKind};
use crate::faults::{FaultPlan, FaultSpec};
use crate::gpu::MigProfile;
use crate::tenants::{
    ArrivalProcess, BwSpec, CollectiveSpec, CompSpec, Envelope, InterferenceSchedule,
    LlmWorkloadSpec, LsSpec, PlacementSpec, TenantKind, TenantWorkload, TraceSpec, WorkloadSpec,
};
use crate::topo::{ClusterTopology, HostTopology};
use crate::util::rng::Pcg64;

/// Everything one run needs.
#[derive(Clone, Debug)]
pub struct Scenario {
    /// Catalog / display name.
    pub name: String,
    pub topo: HostTopology,
    /// The tenant mix, in placement order.
    pub tenants: Vec<TenantWorkload>,
    /// Pre-provisioned idle spare instances `(gpu, profile, start)` —
    /// the static layout's headroom the placement lever can use.
    pub spares: Vec<(usize, MigProfile, usize)>,
    /// Index of the controller's primary latency-sensitive tenant.
    pub primary: usize,
    /// Multi-primary control plane: run one controller per
    /// latency-sensitive tenant, coordinated by the arbiter
    /// (`controller::arbiter`). `false` (the default, and the setting for
    /// the paper's catalog entries) keeps the legacy single-primary path:
    /// only `primary` is actively protected, other LS tenants are
    /// monitored and reported.
    pub protect_all_ls: bool,
    /// Run horizon (sim seconds).
    pub horizon: f64,
    /// Controller sampling interval Δ (§2.1: 1-5 s).
    pub sample_dt: f64,
    pub controller: ControllerConfig,
    pub seed: u64,
    /// Reference service-rate profile for latency-sensitive
    /// `compute_ref_ms` (work is expressed as ms on this profile).
    pub mu_ref_profile: MigProfile,
    /// Placement/isolation pause for a pure move (s) — process restart +
    /// CUDA context, no `nvidia-smi mig` call.
    pub move_pause_s: f64,
    /// Latency noise ε: lognormal sigma added multiplicatively to compute.
    pub epsilon_sigma: f64,
    /// Simulation-engine shard count: 1 (the default) runs the
    /// single-queue reference engine; N > 1 runs the sharded
    /// conservative-PDES core (`sim::parallel`), which is byte-identical
    /// to the reference — this knob only affects wall-clock, never
    /// results. Settable via `--shards` on the CLI and
    /// `run.shards` in config files.
    pub shards: usize,
    /// The resolved placement layout (`ScenarioBuilder::build` records
    /// one for every scenario: pinned entries verbatim, auto entries as
    /// the allocator chose them). `predserve plan` prints it.
    pub layout: AllocPlan,
    /// Deterministic fault-injection plan (`crate::faults`). An empty
    /// plan is the default and is **byte-identical** to a world without
    /// fault support: no extra events, no extra RNG draws, same
    /// fingerprint.
    pub faults: FaultPlan,
    /// Multi-host cluster network (`crate::topo::ClusterTopology`).
    /// Structural option: `None` (the default, and every pre-cluster
    /// catalog entry) builds **no net fabric at all** — zero extra
    /// events, zero extra RNG draws, byte-identical fingerprints. The
    /// simulated host is cluster host 0; ring-collective trainers
    /// ([`crate::tenants::CollectiveSpec`]) span the other hosts'
    /// NIC/leaf/spine links.
    pub cluster: Option<ClusterTopology>,
}

impl Scenario {
    pub fn n_tenants(&self) -> usize {
        self.tenants.len()
    }

    /// Spec of the primary latency-sensitive tenant.
    pub fn primary_spec(&self) -> &LsSpec {
        self.tenants[self.primary]
            .spec
            .as_ls()
            .expect("primary tenant must be latency-sensitive")
    }

    pub fn primary_spec_mut(&mut self) -> &mut LsSpec {
        self.tenants[self.primary]
            .spec
            .as_ls_mut()
            .expect("primary tenant must be latency-sensitive")
    }

    /// Indexes of the background (non-latency-sensitive) tenants.
    pub fn background_tenants(&self) -> Vec<usize> {
        self.tenants
            .iter()
            .enumerate()
            .filter(|(_, t)| t.kind() != TenantKind::LatencySensitive)
            .map(|(i, _)| i)
            .collect()
    }

    /// Replace every background tenant's schedule (steady-contention
    /// experiments, ablation over interference intensity).
    pub fn set_background_schedules(&mut self, sched: InterferenceSchedule) {
        for t in self.tenants.iter_mut() {
            if t.kind() != TenantKind::LatencySensitive {
                t.schedule = sched.clone();
            }
        }
    }

    /// Differential-oracle construction: a clone of this scenario where
    /// every Poisson-driven tenant (latency-sensitive arrivals, and
    /// bandwidth-heavy cycle triggers that opted into a Poisson process)
    /// has its process replaced by the **explicit trace presampled from
    /// the exact seeded RNG stream the live world would consume** over
    /// `self.horizon`. Running both scenarios must produce byte-equal
    /// `RunResult::fingerprint`s — the proof that the trace replay path
    /// reproduces the closed-form Poisson path bit for bit.
    ///
    /// Call this *after* any horizon override: the presample must cover
    /// the horizon the run will actually use.
    pub fn with_presampled_traces(&self) -> Scenario {
        use crate::platform::sim_platform::arrival_stream;
        let (seed, horizon) = (self.seed, self.horizon);
        let mut s = self.clone();
        for (i, t) in s.tenants.iter_mut().enumerate() {
            let stream = arrival_stream(i, t.kind());
            match &mut t.spec {
                WorkloadSpec::LatencySensitive(spec) => {
                    if let ArrivalProcess::Poisson { rps } = spec.arrival_process() {
                        let mut rng = Pcg64::new(seed, stream);
                        spec.arrivals = Some(ArrivalProcess::Trace(
                            TraceSpec::presample_poisson(rps, horizon, &mut rng),
                        ));
                    }
                }
                WorkloadSpec::BandwidthHeavy(spec) => {
                    if let Some(ArrivalProcess::Poisson { rps }) = spec.arrivals {
                        let mut rng = Pcg64::new(seed, stream);
                        spec.arrivals = Some(ArrivalProcess::Trace(
                            TraceSpec::presample_poisson(rps, horizon, &mut rng),
                        ));
                    }
                }
                WorkloadSpec::ComputeHeavy(_) => {}
            }
        }
        s
    }

    /// Ablation counterpart: a clone where every *explicit* arrival
    /// process (trace or modulated) is replaced by a plain open-loop
    /// Poisson at its mean realized rate. `predserve trace` compares a
    /// trace scenario against this rate-matched baseline (ΔSLO-miss,
    /// Δp99 isolate the effect of the arrival *pattern* at equal load).
    pub fn rate_matched_poisson(&self) -> Scenario {
        let mut s = self.clone();
        for t in s.tenants.iter_mut() {
            match &mut t.spec {
                WorkloadSpec::LatencySensitive(spec) => {
                    if let Some(p) = &spec.arrivals {
                        let rps = p.mean_rps();
                        spec.arrivals = Some(ArrivalProcess::Poisson { rps });
                    }
                }
                WorkloadSpec::BandwidthHeavy(spec) => {
                    if let Some(p) = &spec.arrivals {
                        let rps = p.mean_rps();
                        spec.arrivals = Some(ArrivalProcess::Poisson { rps });
                    }
                }
                WorkloadSpec::ComputeHeavy(_) => {}
            }
        }
        s
    }

    // --- named catalog ----------------------------------------------------

    /// Catalog names accepted by [`Scenario::by_name`].
    pub const CATALOG: [&'static str; 17] = [
        "paper_single_host",
        "paper_llm_case",
        "steady_contention",
        "multi_ls_slo_mix",
        "pcie_hotspot",
        "diurnal_burst",
        "auto_pack_24",
        "dueling_primaries",
        "hotspot_64",
        "trace_burst_32",
        "diurnal_trace_mix",
        "llm_serving_mix",
        "llm_burst_ttft",
        "link_flap_recovery",
        "mig_reconfig_flaky",
        "fat_tree_allreduce_mix",
        "spine_hotspot",
    ];

    /// Look a scenario up by catalog name ("single" and "llm" are accepted
    /// as aliases for the two paper cases, matching the cluster protocol).
    pub fn by_name(name: &str, seed: u64, levers: Levers) -> Option<Scenario> {
        Some(match name {
            "paper_single_host" | "single" => Scenario::paper_single_host(seed, levers),
            "paper_llm_case" | "llm" => Scenario::paper_llm_case(seed, levers),
            // The on/off variants round-trip the names `steady_contention`
            // assigns to its Scenario (and hence to RunResult::scenario).
            "steady_contention" | "steady_contention_on" => {
                Scenario::steady_contention(seed, levers, true)
            }
            "steady_contention_off" => Scenario::steady_contention(seed, levers, false),
            "multi_ls_slo_mix" => Scenario::multi_ls_slo_mix(seed, levers),
            "pcie_hotspot" => Scenario::pcie_hotspot(seed, levers),
            "diurnal_burst" => Scenario::diurnal_burst(seed, levers),
            "auto_pack_24" => Scenario::auto_pack_24(seed, levers),
            "dueling_primaries" => Scenario::dueling_primaries(seed, levers),
            "hotspot_64" => Scenario::hotspot_64(seed, levers),
            "trace_burst_32" => Scenario::trace_burst_32(seed, levers),
            "diurnal_trace_mix" => Scenario::diurnal_trace_mix(seed, levers),
            "llm_serving_mix" => Scenario::llm_serving_mix(seed, levers),
            "llm_burst_ttft" => Scenario::llm_burst_ttft(seed, levers),
            "link_flap_recovery" => Scenario::link_flap_recovery(seed, levers),
            "mig_reconfig_flaky" => Scenario::mig_reconfig_flaky(seed, levers),
            "fat_tree_allreduce_mix" => Scenario::fat_tree_allreduce_mix(seed, levers),
            "spine_hotspot" => Scenario::spine_hotspot(seed, levers),
            _ => return None,
        })
    }

    /// The paper's §3.1 interference script: ETL and trainer schedules
    /// toggling with ~90s on / ~60s off periods — long enough for
    /// dwell/cool-down to matter, short enough for many transitions.
    /// Shared by every scenario that co-locates "the paper's two
    /// interferers" so their dynamics cannot silently drift apart.
    fn paper_interference_schedules(
        seed: u64,
        horizon: f64,
    ) -> (InterferenceSchedule, InterferenceSchedule) {
        let mut sched_rng = Pcg64::new(seed, 1000);
        let etl = InterferenceSchedule::generate(&mut sched_rng, horizon, 60.0, 90.0, 20.0);
        let train = InterferenceSchedule::generate(&mut sched_rng, horizon, 70.0, 80.0, 20.0);
        (etl, train)
    }

    /// The paper's main single-host experiment (E1): one latency-sensitive
    /// tenant (15 ms SLO) + bandwidth-heavy ETL + compute-heavy training
    /// under dynamic interference, Table 1 controller parameters.
    pub fn paper_single_host(seed: u64, levers: Levers) -> Scenario {
        let horizon = 1800.0;
        let (etl_schedule, train_schedule) = Scenario::paper_interference_schedules(seed, horizon);
        ScenarioBuilder::new("paper_single_host", seed)
            .levers(levers)
            .horizon(horizon)
            .tenant(TenantWorkload::latency_sensitive(
                "t1-inference",
                LsSpec::default(),
                PlacementSpec::dedicated_at(0, MigProfile::P4g40gb, 0),
            ))
            .tenant(TenantWorkload::bandwidth_heavy(
                "t2-etl",
                BwSpec::default(),
                etl_schedule,
                PlacementSpec::dedicated_at(0, MigProfile::P3g40gb, 4),
            ))
            .tenant(TenantWorkload::compute_heavy(
                "t3-train",
                CompSpec::default(),
                train_schedule,
                PlacementSpec::shared_with(0),
            ))
            .spare(1, MigProfile::P3g40gb, 0)
            .build()
    }

    /// The LLM case study (Table 2): the primary becomes a vLLM-style
    /// serving tenant measured on TTFT with a 200 ms p99 SLO. Prefill is
    /// compute-heavier and inputs (prompts/weights pages) are larger, so
    /// both PCIe and SM contention show up in TTFT.
    pub fn paper_llm_case(seed: u64, levers: Levers) -> Scenario {
        let mut s = Scenario::paper_single_host(seed, levers);
        s.name = "paper_llm_case".into();
        *s.primary_spec_mut() = LsSpec::llm_ttft();
        s.controller.tau_ms = 200.0;
        s
    }

    /// Chaos catalog: the paper's single-host case with the primary's
    /// PCIe uplink flapping to 25% capacity for 20 s out of every 120 s
    /// between t=600 and t=1200. Exercises the fault path end-to-end:
    /// `FaultInjected`/`FaultCleared` edges, fabric re-rating mid-flow,
    /// and the controller recovering the tail after each flap.
    pub fn link_flap_recovery(seed: u64, levers: Levers) -> Scenario {
        let mut s = Scenario::paper_single_host(seed, levers);
        s.name = "link_flap_recovery".into();
        let link = s.topo.link_of_gpu(s.tenants[s.primary].placement.gpu).0;
        s.faults = FaultPlan::new(vec![FaultSpec::LinkFlap {
            link,
            factor: 0.25,
            from: 600.0,
            until: 1200.0,
            period_s: 120.0,
            down_s: 20.0,
        }]);
        s
    }

    /// Chaos catalog: the paper's single-host case with a flaky MIG
    /// reconfig path — every disruptive isolation change fails with
    /// probability 0.5 (drawn off the dedicated fault RNG stream) and
    /// successful ones pay +250 ms of actuation latency, for the whole
    /// run. Exercises the controller's retry/backoff/degraded-mode
    /// hardening: a failed upgrade must not burn the dwell clock, and
    /// the audit must show retry → applied (or degraded) edges.
    pub fn mig_reconfig_flaky(seed: u64, levers: Levers) -> Scenario {
        let mut s = Scenario::paper_single_host(seed, levers);
        s.name = "mig_reconfig_flaky".into();
        let h = s.horizon;
        s.faults = FaultPlan::new(vec![FaultSpec::ReconfigFlaky {
            fail_prob: 0.5,
            latency_ms: 250.0,
            at: 0.0,
            duration: h,
        }]);
        s
    }

    /// Steady contention variants for Figure 4 (low vs high contention).
    pub fn steady_contention(seed: u64, levers: Levers, on: bool) -> Scenario {
        let mut s = Scenario::paper_single_host(seed, levers);
        s.name = format!("steady_contention_{}", if on { "on" } else { "off" });
        let h = s.horizon;
        s.set_background_schedules(if on {
            InterferenceSchedule::always_on(h)
        } else {
            InterferenceSchedule::always_off(h)
        });
        s
    }

    /// Two latency-sensitive tenants with distinct SLOs (interactive chat
    /// vs relaxed batch API) sharing the host with the paper's two
    /// interferers. A real multi-controller scenario since the
    /// multi-primary control plane landed: `protect_all_ls` gives *every*
    /// latency-sensitive tenant its own controller (τ = its SLO),
    /// coordinated by the arbiter; the batch service's tails are actively
    /// protected, not just reported.
    pub fn multi_ls_slo_mix(seed: u64, levers: Levers) -> Scenario {
        let horizon = 1800.0;
        let (etl_schedule, train_schedule) = Scenario::paper_interference_schedules(seed, horizon);
        let chat = LsSpec {
            arrival_rps: 60.0,
            slo_ms: 15.0,
            ..LsSpec::default()
        };
        let batch = LsSpec {
            arrival_rps: 25.0,
            slo_ms: 60.0,
            compute_ref_ms: 8.0,
            ..LsSpec::default()
        };
        ScenarioBuilder::new("multi_ls_slo_mix", seed)
            .levers(levers)
            .protect_all_ls()
            .horizon(horizon)
            .tenant(TenantWorkload::latency_sensitive(
                "chat-api",
                chat,
                PlacementSpec::dedicated_at(0, MigProfile::P4g40gb, 0),
            ))
            .tenant(TenantWorkload::latency_sensitive(
                "batch-api",
                batch,
                PlacementSpec::dedicated_at(2, MigProfile::P3g40gb, 0),
            ))
            .tenant(TenantWorkload::bandwidth_heavy(
                "etl",
                BwSpec::default(),
                etl_schedule,
                PlacementSpec::dedicated_at(0, MigProfile::P3g40gb, 4),
            ))
            .tenant(TenantWorkload::compute_heavy(
                "train",
                CompSpec::default(),
                train_schedule,
                PlacementSpec::shared_with(0),
            ))
            .spare(1, MigProfile::P3g40gb, 0)
            .build()
    }

    /// Many-interferer PCIe hot-spot: five bandwidth-heavy tenants crowd
    /// the primary's PCIe switch and NUMA-0 NVMe path (ParvaGPU-style
    /// dense co-location); the spare lives on the cool NUMA-1 switch so
    /// only a topology-aware move escapes the pressure.
    pub fn pcie_hotspot(seed: u64, levers: Levers) -> Scenario {
        let mut sched_rng = Pcg64::new(seed, 1000);
        let horizon = 1800.0;
        let mut b = ScenarioBuilder::new("pcie_hotspot", seed)
            .levers(levers)
            .horizon(horizon)
            .tenant(TenantWorkload::latency_sensitive(
                "frontend",
                LsSpec::default(),
                PlacementSpec::dedicated_at(0, MigProfile::P4g40gb, 0),
            ));
        // (gpu, start): three on the primary's switch (GPUs 0-1), two more
        // on switch 1 — every one of them on NUMA 0's NVMe path.
        let slots = [(0usize, 4usize), (1, 0), (1, 4), (2, 0), (3, 0)];
        for (i, (gpu, start)) in slots.into_iter().enumerate() {
            let schedule = InterferenceSchedule::generate(
                &mut sched_rng,
                horizon,
                30.0 + 10.0 * i as f64,
                120.0,
                20.0,
            );
            b = b.tenant(TenantWorkload::bandwidth_heavy(
                format!("etl-{i}"),
                BwSpec::default(),
                schedule,
                PlacementSpec::dedicated_at(gpu, MigProfile::P3g40gb, start),
            ));
        }
        b.spare(4, MigProfile::P3g40gb, 0).build()
    }

    /// Diurnal burst: background load waxes and wanes on deterministic
    /// phase-shifted periods (day/night ETL waves, scheduled training
    /// jobs), so contention arrives in coordinated bursts rather than
    /// independent toggles.
    pub fn diurnal_burst(seed: u64, levers: Levers) -> Scenario {
        let horizon = 1800.0;
        let period = 600.0;
        ScenarioBuilder::new("diurnal_burst", seed)
            .levers(levers)
            .horizon(horizon)
            .tenant(TenantWorkload::latency_sensitive(
                "serving",
                LsSpec::default(),
                PlacementSpec::dedicated_at(0, MigProfile::P4g40gb, 0),
            ))
            .tenant(TenantWorkload::compute_heavy(
                "train-shared",
                CompSpec::default(),
                InterferenceSchedule::periodic(horizon, period, 0.5, 120.0),
                PlacementSpec::shared_with(0),
            ))
            .tenant(TenantWorkload::bandwidth_heavy(
                "etl-day",
                BwSpec::default(),
                InterferenceSchedule::periodic(horizon, period, 0.45, 0.0),
                PlacementSpec::dedicated_at(0, MigProfile::P3g40gb, 4),
            ))
            .tenant(TenantWorkload::bandwidth_heavy(
                "etl-night",
                BwSpec::default(),
                InterferenceSchedule::periodic(horizon, period, 0.45, 300.0),
                PlacementSpec::dedicated_at(2, MigProfile::P3g40gb, 0),
            ))
            .tenant(TenantWorkload::compute_heavy(
                "train-batch",
                CompSpec {
                    step_ms: 200.0,
                    sync_gb: 0.25,
                    ..CompSpec::default()
                },
                InterferenceSchedule::periodic(horizon, period, 0.6, 450.0),
                PlacementSpec::dedicated_at(3, MigProfile::P3g40gb, 0),
            ))
            .spare(1, MigProfile::P3g40gb, 0)
            .build()
    }

    /// The fleet-level tenant list behind `auto_pack_24` and the cluster
    /// leader's fleet dispatch: `n` mixed tenants with **no hand-written
    /// placements** — every `PlacementSpec` is an auto request the
    /// allocator resolves. Deterministic in `(seed, n)`, so the leader
    /// and every worker derive the identical list.
    ///
    /// Mix by index: `i % 4 == 0` → latency-sensitive service (the first
    /// is the heavier frontend), `i % 4 ∈ {1, 2}` → ETL pipeline,
    /// `i % 4 == 3` → trainer.
    pub fn auto_pack_tenants(seed: u64, n: usize) -> Vec<TenantWorkload> {
        // Schedule coverage matches the catalog's 1800 s maximum (the
        // scenario's default run horizon is shorter); running past the
        // covered window idles the background tenants, same as every
        // other catalog entry.
        let horizon = 1800.0;
        let mut sched_rng = Pcg64::new(seed, 1000);
        let mut out = Vec::with_capacity(n);
        for i in 0..n {
            match i % 4 {
                0 => {
                    let (spec, min_profile) = if i == 0 {
                        (
                            LsSpec {
                                arrival_rps: 60.0,
                                ..LsSpec::default()
                            },
                            MigProfile::P3g40gb,
                        )
                    } else {
                        (
                            LsSpec {
                                arrival_rps: 25.0,
                                slo_ms: [15.0, 30.0, 60.0][(i / 4) % 3],
                                compute_ref_ms: 5.0,
                                ..LsSpec::default()
                            },
                            MigProfile::P2g20gb,
                        )
                    };
                    let est = WorkloadSpec::LatencySensitive(spec.clone()).expected_pcie_gbps();
                    out.push(TenantWorkload::latency_sensitive(
                        format!("svc-{i}"),
                        spec,
                        PlacementSpec::auto(min_profile, est),
                    ));
                }
                1 | 2 => {
                    // Lighter cycles than the paper's T2 so two dozen of
                    // them share the fabric without starving each other.
                    let spec = BwSpec {
                        read_gb: 1.0,
                        h2d_gb: 0.6,
                        d2h_gb: 0.3,
                        ..BwSpec::default()
                    };
                    let schedule = InterferenceSchedule::generate(
                        &mut sched_rng,
                        horizon,
                        40.0 + 5.0 * (i % 5) as f64,
                        90.0,
                        20.0,
                    );
                    let est = WorkloadSpec::BandwidthHeavy(spec.clone()).expected_pcie_gbps();
                    out.push(TenantWorkload::bandwidth_heavy(
                        format!("etl-{i}"),
                        spec,
                        schedule,
                        PlacementSpec::auto(MigProfile::P2g20gb, est),
                    ));
                }
                _ => {
                    let spec = CompSpec::default();
                    let schedule = InterferenceSchedule::generate(
                        &mut sched_rng,
                        horizon,
                        60.0,
                        120.0,
                        30.0,
                    );
                    let est = WorkloadSpec::ComputeHeavy(spec.clone()).expected_pcie_gbps();
                    out.push(TenantWorkload::compute_heavy(
                        format!("train-{i}"),
                        spec,
                        schedule,
                        PlacementSpec::auto(MigProfile::P1g10gb, est),
                    ));
                }
            }
        }
        out
    }

    /// ParvaGPU-scale dense co-location: 24 mixed tenants on the 8-GPU
    /// p4d host, **every placement chosen by the allocator** (zero
    /// hand-written `PlacementSpec`s). Uses the dense-pack admission
    /// configuration: link headroom stays the hard gate while the score
    /// ceiling (calibrated for one newcomer) is relaxed — candidate
    /// ordering keeps the layout topology-aware.
    pub fn auto_pack_24(seed: u64, levers: Levers) -> Scenario {
        let mut b = ScenarioBuilder::new("auto_pack_24", seed)
            .controller(ControllerConfig::dense_pack(levers))
            .horizon(900.0);
        for t in Scenario::auto_pack_tenants(seed, 24) {
            b = b.add_auto(t);
        }
        b.build()
    }

    /// The tenant list behind [`Scenario::dense_hotspot`]: `n` mixed
    /// tenants sized for dense Gen5 hosts — **every** placement an auto
    /// request. Lighter asks than [`Scenario::auto_pack_tenants`] so
    /// dozens of them can share two fat uplinks without the allocator
    /// refusing admission. Deterministic in `(seed, n)`.
    ///
    /// Mix by index: `i % 4 == 0` → latency-sensitive service (the first
    /// is the heavier frontend/primary), `i % 4 ∈ {1, 2}` → ETL pipeline,
    /// `i % 4 == 3` → trainer.
    pub fn hotspot_tenants(seed: u64, n: usize) -> Vec<TenantWorkload> {
        let horizon = 1800.0;
        let mut sched_rng = Pcg64::new(seed, 1000);
        let mut out = Vec::with_capacity(n);
        for i in 0..n {
            match i % 4 {
                0 => {
                    let spec = if i == 0 {
                        LsSpec {
                            arrival_rps: 30.0,
                            ..LsSpec::default()
                        }
                    } else {
                        LsSpec {
                            arrival_rps: 10.0,
                            slo_ms: [20.0, 40.0, 60.0][(i / 4) % 3],
                            compute_ref_ms: 5.0,
                            ..LsSpec::default()
                        }
                    };
                    let est = WorkloadSpec::LatencySensitive(spec.clone()).expected_pcie_gbps();
                    out.push(TenantWorkload::latency_sensitive(
                        format!("svc-{i}"),
                        spec,
                        PlacementSpec::auto(MigProfile::P2g20gb, est),
                    ));
                }
                1 | 2 => {
                    // Long transform phases keep each pipeline's sustained
                    // PCIe demand moderate — the hot spot comes from how
                    // many of them crowd one uplink, not from any single
                    // heavy tenant.
                    let spec = BwSpec {
                        read_gb: 0.8,
                        h2d_gb: 0.5,
                        d2h_gb: 0.25,
                        transform_ms: 200.0,
                        ..BwSpec::default()
                    };
                    let schedule = InterferenceSchedule::generate(
                        &mut sched_rng,
                        horizon,
                        40.0 + 5.0 * (i % 5) as f64,
                        90.0,
                        20.0,
                    );
                    let est = WorkloadSpec::BandwidthHeavy(spec.clone()).expected_pcie_gbps();
                    out.push(TenantWorkload::bandwidth_heavy(
                        format!("etl-{i}"),
                        spec,
                        schedule,
                        PlacementSpec::auto(MigProfile::P1g10gb, est),
                    ));
                }
                _ => {
                    let spec = CompSpec::default();
                    let schedule = InterferenceSchedule::generate(
                        &mut sched_rng,
                        horizon,
                        60.0,
                        120.0,
                        30.0,
                    );
                    let est = WorkloadSpec::ComputeHeavy(spec.clone()).expected_pcie_gbps();
                    out.push(TenantWorkload::compute_heavy(
                        format!("train-{i}"),
                        spec,
                        schedule,
                        PlacementSpec::auto(MigProfile::P1g10gb, est),
                    ));
                }
            }
        }
        out
    }

    /// Generated dense co-location scenario on a Gen5 host: `n` mixed
    /// auto-placed tenants ([`Scenario::hotspot_tenants`]) packed onto a
    /// [`HostTopology::dense`] host whose switch count is sized from the
    /// mix's slice demand (minimum two switches, so the contention story
    /// is always "many tenants, few uplinks"). The `scale_sweep` bench
    /// drives this from 24 to 256 tenants; the catalog pins `n = 64` as
    /// [`Scenario::hotspot_64`].
    pub fn dense_hotspot(seed: u64, n: usize, levers: Levers) -> Scenario {
        assert!(n >= 4, "dense_hotspot needs at least one tenant of each kind");
        const GPUS_PER_SWITCH: usize = 8;
        const SLICES_PER_GPU: usize = 7; // A100 MIG compute slices
        // Slice demand: 2 per latency-sensitive tenant (every 4th), 1
        // otherwise; keep ≥25% slice slack so admission always places.
        let slices = n + n.div_ceil(4);
        let switches = (slices * 5)
            .div_ceil(4 * GPUS_PER_SWITCH * SLICES_PER_GPU)
            .max(2);
        let topo = HostTopology::dense(switches, GPUS_PER_SWITCH, 64.0, 16.0);
        let mut b = ScenarioBuilder::new(format!("hotspot_{n}"), seed)
            .topo(topo)
            .controller(ControllerConfig::dense_pack(levers))
            .horizon(900.0);
        for t in Scenario::hotspot_tenants(seed, n) {
            b = b.add_auto(t);
        }
        b.build()
    }

    /// Catalog entry for the fabric-engine scale path: 64 auto-placed
    /// tenants (16 services, 32 ETL pipelines, 16 trainers) contending on
    /// **two** Gen5 PCIe switches (8 GPUs each) and their two NUMA NVMe
    /// paths. This is the shape the incremental fabric engine exists for
    /// — dozens of concurrent flows per link with continuous churn — and
    /// having it in the catalog keeps the scale path covered by the tier-1
    /// integration smoke, not just by benches.
    pub fn hotspot_64(seed: u64, levers: Levers) -> Scenario {
        Scenario::dense_hotspot(seed, 64, levers)
    }

    /// Arbitration stress case: two equally-entitled latency-sensitive
    /// services ("gold" and "silver"), each MPS-co-scheduled with its own
    /// trainer on the same PCIe switch, plus an ETL tenant hammering the
    /// NUMA-0 NVMe path — and exactly **one** spare instance on the cool
    /// switch. Under `protect_all_ls` both controllers escalate toward
    /// the same escape slot; the arbiter decides who goes first (worst
    /// tail-to-SLO ratio) and the loser's upgrade is deferred, not
    /// dropped. The periodic trainer schedules overlap most of the time
    /// so both tenants hurt simultaneously.
    pub fn dueling_primaries(seed: u64, levers: Levers) -> Scenario {
        let horizon = 1800.0;
        let gold = LsSpec::default(); // 80 rps, 15 ms SLO
        let silver = LsSpec {
            arrival_rps: 70.0,
            slo_ms: 15.0,
            ..LsSpec::default()
        };
        ScenarioBuilder::new("dueling_primaries", seed)
            .levers(levers)
            .protect_all_ls()
            .horizon(horizon)
            .tenant(TenantWorkload::latency_sensitive(
                "svc-gold",
                gold,
                PlacementSpec::dedicated_at(0, MigProfile::P4g40gb, 0),
            ))
            .tenant(TenantWorkload::latency_sensitive(
                "svc-silver",
                silver,
                PlacementSpec::dedicated_at(1, MigProfile::P3g40gb, 0),
            ))
            .tenant(TenantWorkload::bandwidth_heavy(
                "etl-storm",
                BwSpec::default(),
                InterferenceSchedule::periodic(horizon, 240.0, 0.7, 120.0),
                PlacementSpec::dedicated_at(2, MigProfile::P3g40gb, 0),
            ))
            .tenant(TenantWorkload::compute_heavy(
                "train-gold",
                CompSpec::default(),
                InterferenceSchedule::periodic(horizon, 300.0, 0.75, 0.0),
                PlacementSpec::shared_with(0),
            ))
            .tenant(TenantWorkload::compute_heavy(
                "train-silver",
                CompSpec::default(),
                InterferenceSchedule::periodic(horizon, 300.0, 0.75, 60.0),
                PlacementSpec::shared_with(1),
            ))
            .spare(4, MigProfile::P3g40gb, 0)
            .build()
    }

    /// Trace-replay stress case: 32 auto-placed tenants on a dense
    /// two-switch Gen5 host (the [`Scenario::hotspot_tenants`] mix) where
    /// every latency-sensitive service **replays a generated bursty
    /// trace** (two-state calm/burst process, mean rate matched to its
    /// nominal `arrival_rps`) while the ETL pipelines cycle on open-loop
    /// **Poisson triggers** instead of the closed loop. Bursts across
    /// many services align only by chance — exactly the heavy-tail
    /// arrival pressure the open-loop Poisson model cannot express.
    /// `predserve trace` runs this against its rate-matched Poisson twin.
    pub fn trace_burst_32(seed: u64, levers: Levers) -> Scenario {
        const N: usize = 32;
        let mut tenants = Scenario::hotspot_tenants(seed, N);
        // Traces come from their own stream (2000-block): workload RNG
        // streams stay untouched, and the schedule stream (1000) keeps
        // producing the exact hotspot_tenants schedules.
        let mut trace_rng = Pcg64::new(seed, 2000);
        for t in tenants.iter_mut() {
            match &mut t.spec {
                WorkloadSpec::LatencySensitive(spec) => {
                    // Calm at 0.5x / burst at 2.5x of the nominal rate,
                    // ~25% burst duty => mean ≈ 1.0x arrival_rps. Traces
                    // cover the catalog's 1800 s schedule window, so any
                    // shorter run horizon never exhausts them.
                    let trace = TraceSpec::bursty(
                        &mut trace_rng,
                        1800.0,
                        spec.arrival_rps * 0.5,
                        spec.arrival_rps * 2.5,
                        60.0,
                        20.0,
                    )
                    .expect("bursty trace generation");
                    spec.arrivals = Some(ArrivalProcess::Trace(trace));
                }
                WorkloadSpec::BandwidthHeavy(spec) => {
                    // Poisson ETL neighbors: cycle starts arrive at 1.5/s
                    // instead of back-to-back while the schedule is on.
                    spec.arrivals = Some(ArrivalProcess::Poisson { rps: 1.5 });
                }
                WorkloadSpec::ComputeHeavy(_) => {}
            }
        }
        let mut b = ScenarioBuilder::new("trace_burst_32", seed)
            .topo(HostTopology::dense(2, 8, 64.0, 16.0))
            .controller(ControllerConfig::dense_pack(levers))
            .horizon(900.0);
        for t in tenants {
            b = b.add_auto(t);
        }
        b.build()
    }

    /// The diurnal_burst case re-expressed through **arrival envelopes**:
    /// the serving tenant's request rate follows a deterministic diurnal
    /// sine ([`Envelope::Diurnal`], same 600 s period as the background
    /// waves) and the two ETL pipelines run always-on schedules whose
    /// cycle *triggers* are gated by phase-shifted square
    /// [`Envelope::Bursts`] — the day/night waves live in the arrival
    /// processes rather than in on/off toggles. The two trainers keep
    /// their periodic schedules (compute tenants have no arrival side).
    pub fn diurnal_trace_mix(seed: u64, levers: Levers) -> Scenario {
        let horizon = 1800.0;
        let period = 600.0;
        let serving = LsSpec {
            arrivals: Some(ArrivalProcess::Modulated {
                base_rps: 80.0,
                envelope: Envelope::Diurnal {
                    period_s: period,
                    amplitude: 0.5,
                    phase_s: 0.0,
                },
            }),
            ..LsSpec::default()
        };
        let etl_wave = |phase_s: f64| ArrivalProcess::Modulated {
            base_rps: 2.0,
            envelope: Envelope::Bursts {
                period_s: period,
                duty: 0.45,
                high: 1.0,
                low: 0.0,
                phase_s,
            },
        };
        ScenarioBuilder::new("diurnal_trace_mix", seed)
            .levers(levers)
            .horizon(horizon)
            .tenant(TenantWorkload::latency_sensitive(
                "serving",
                serving,
                PlacementSpec::dedicated_at(0, MigProfile::P4g40gb, 0),
            ))
            .tenant(TenantWorkload::compute_heavy(
                "train-shared",
                CompSpec::default(),
                InterferenceSchedule::periodic(horizon, period, 0.5, 120.0),
                PlacementSpec::shared_with(0),
            ))
            .tenant(
                TenantWorkload::bandwidth_heavy(
                    "etl-day",
                    BwSpec::default(),
                    InterferenceSchedule::always_on(horizon),
                    PlacementSpec::dedicated_at(0, MigProfile::P3g40gb, 4),
                )
                .arrivals(etl_wave(0.0)),
            )
            .tenant(
                TenantWorkload::bandwidth_heavy(
                    "etl-night",
                    BwSpec::default(),
                    InterferenceSchedule::always_on(horizon),
                    PlacementSpec::dedicated_at(2, MigProfile::P3g40gb, 0),
                )
                .arrivals(etl_wave(300.0)),
            )
            .tenant(TenantWorkload::compute_heavy(
                "train-batch",
                CompSpec {
                    step_ms: 200.0,
                    sync_gb: 0.25,
                    ..CompSpec::default()
                },
                InterferenceSchedule::periodic(horizon, period, 0.6, 450.0),
                PlacementSpec::dedicated_at(3, MigProfile::P3g40gb, 0),
            ))
            .spare(1, MigProfile::P3g40gb, 0)
            .build()
    }

    /// Request-granularity LLM serving under the paper's interference
    /// mix: the primary is a chat service whose arrivals flow through
    /// the simulated continuous-batching engine
    /// ([`crate::tenants::LlmWorkloadSpec`], `chat_7b` lengths) instead
    /// of the flat latency sample, co-located with the §3.1 ETL and
    /// MPS-shared trainer. Reports per-request TTFT/TPOT tails alongside
    /// the legacy end-to-end metrics; the controller stays on the
    /// end-to-end objective (τ = the e2e SLO).
    pub fn llm_serving_mix(seed: u64, levers: Levers) -> Scenario {
        let horizon = 1800.0;
        let (etl_schedule, train_schedule) = Scenario::paper_interference_schedules(seed, horizon);
        // ~1.5 req/s against a ~4-6 req/s continuous-batching capacity on
        // the 4g slice: loaded enough for queueing and KV pressure to
        // show in TTFT, light enough that bursts drain. The e2e SLO is a
        // whole-request bound (prefill + ~100 decode steps), not 15 ms.
        let ls = LsSpec {
            arrival_rps: 1.5,
            slo_ms: 5000.0,
            ..LsSpec::default()
        };
        let mut s = ScenarioBuilder::new("llm_serving_mix", seed)
            .levers(levers)
            .horizon(horizon)
            .tenant(TenantWorkload::llm(
                "chat-llm",
                ls,
                LlmWorkloadSpec::chat_7b(),
                PlacementSpec::dedicated_at(0, MigProfile::P4g40gb, 0),
            ))
            .tenant(TenantWorkload::bandwidth_heavy(
                "etl",
                BwSpec::default(),
                etl_schedule,
                PlacementSpec::dedicated_at(0, MigProfile::P3g40gb, 4),
            ))
            .tenant(TenantWorkload::compute_heavy(
                "train",
                CompSpec::default(),
                train_schedule,
                PlacementSpec::shared_with(0),
            ))
            .spare(1, MigProfile::P3g40gb, 0)
            .build();
        s.controller.tau_ms = 5000.0;
        s
    }

    /// The TTFT-objective counterpart of [`Scenario::llm_serving_mix`]:
    /// the chat service's arrivals ride a square burst envelope (mean
    /// rate = base, bursts at ~2.5x) and the controller targets the
    /// **TTFT** tail (`SloKind::Ttft`, τ = the workload's `ttft_slo_ms`)
    /// instead of end-to-end latency — prefill queueing behind decode
    /// waves and step-time inflation from the MPS trainer both land on
    /// TTFT first, so this is where the new objective earns its keep.
    pub fn llm_burst_ttft(seed: u64, levers: Levers) -> Scenario {
        let horizon = 1800.0;
        let (etl_schedule, train_schedule) = Scenario::paper_interference_schedules(seed, horizon);
        let llm = LlmWorkloadSpec::chat_7b();
        let ttft_slo_ms = llm.ttft_slo_ms;
        let ls = LsSpec {
            arrival_rps: 1.2,
            // duty 0.25 at 2.5x + 0.75 at 0.5x => mean 1.0x base_rps.
            arrivals: Some(ArrivalProcess::Modulated {
                base_rps: 1.2,
                envelope: Envelope::Bursts {
                    period_s: 240.0,
                    duty: 0.25,
                    high: 2.5,
                    low: 0.5,
                    phase_s: 0.0,
                },
            }),
            slo_ms: 5000.0,
            ..LsSpec::default()
        };
        let mut s = ScenarioBuilder::new("llm_burst_ttft", seed)
            .levers(levers)
            .horizon(horizon)
            .tenant(TenantWorkload::llm(
                "chat-llm",
                ls,
                llm,
                PlacementSpec::dedicated_at(0, MigProfile::P4g40gb, 0),
            ))
            .tenant(TenantWorkload::bandwidth_heavy(
                "etl",
                BwSpec::default(),
                etl_schedule,
                PlacementSpec::dedicated_at(0, MigProfile::P3g40gb, 4),
            ))
            .tenant(TenantWorkload::compute_heavy(
                "train",
                CompSpec::default(),
                train_schedule,
                PlacementSpec::shared_with(0),
            ))
            .spare(1, MigProfile::P3g40gb, 0)
            .build();
        s.controller.objective = SloKind::Ttft;
        s.controller.tau_ms = ttft_slo_ms;
        s
    }

    /// Cluster catalog: the paper's serving + ETL mix sharing host 0 of
    /// a degree-4 fat-tree with a **4-host ring trainer** (hosts 0-3,
    /// two leaves — segments 1→2 and 3→0 cross the spine tier). Every
    /// training step ends in a ring allreduce chained through the net
    /// fabric, so trainer cadence now depends on a contention domain the
    /// controller's placement lever cannot see.
    pub fn fat_tree_allreduce_mix(seed: u64, levers: Levers) -> Scenario {
        let horizon = 1800.0;
        let (etl_schedule, train_schedule) = Scenario::paper_interference_schedules(seed, horizon);
        ScenarioBuilder::new("fat_tree_allreduce_mix", seed)
            .levers(levers)
            .horizon(horizon)
            .cluster(ClusterTopology::fat_tree(4))
            .tenant(TenantWorkload::latency_sensitive(
                "serving",
                LsSpec::default(),
                PlacementSpec::dedicated_at(0, MigProfile::P4g40gb, 0),
            ))
            .tenant(TenantWorkload::bandwidth_heavy(
                "etl",
                BwSpec::default(),
                etl_schedule,
                PlacementSpec::dedicated_at(0, MigProfile::P3g40gb, 4),
            ))
            .tenant(TenantWorkload::collective(
                "ring-train",
                CompSpec::default(),
                CollectiveSpec::ring(vec![0, 1, 2, 3], 0.5, 1),
                train_schedule,
                PlacementSpec::dedicated_at(2, MigProfile::P3g40gb, 0),
            ))
            .spare(1, MigProfile::P3g40gb, 0)
            .build()
    }

    /// Cluster catalog: two always-on 2-host ring trainers on a 2×2
    /// leaf/spine fabric whose rings (hosts 0↔2 and 1↔3) both cross
    /// leaves — deterministic ECMP hashes both onto **spine 1**, so the
    /// two collectives contend for the same trunk pair for the whole
    /// run while the serving tenant rides host 0's PCIe fabric.
    pub fn spine_hotspot(seed: u64, levers: Levers) -> Scenario {
        let horizon = 1800.0;
        ScenarioBuilder::new("spine_hotspot", seed)
            .levers(levers)
            .horizon(horizon)
            .cluster(ClusterTopology::leaf_spine(2, 2, 2))
            .tenant(TenantWorkload::latency_sensitive(
                "serving",
                LsSpec::default(),
                PlacementSpec::dedicated_at(0, MigProfile::P4g40gb, 0),
            ))
            .tenant(TenantWorkload::collective(
                "ring-even",
                CompSpec::default(),
                CollectiveSpec::ring(vec![0, 2], 0.5, 1),
                InterferenceSchedule::always_on(horizon),
                PlacementSpec::dedicated_at(2, MigProfile::P3g40gb, 0),
            ))
            .tenant(TenantWorkload::collective(
                "ring-odd",
                CompSpec::default(),
                CollectiveSpec::ring(vec![1, 3], 0.5, 1),
                InterferenceSchedule::always_on(horizon),
                PlacementSpec::dedicated_at(3, MigProfile::P3g40gb, 0),
            ))
            .spare(1, MigProfile::P3g40gb, 0)
            .build()
    }
}

/// Composable scenario construction; see the README's "Defining a
/// scenario" section. `build()` validates the tenant mix (at least one
/// latency-sensitive tenant; MPS sharing must reference an earlier
/// tenant), resolves shared placements, and runs the topology-aware
/// allocator (`crate::alloc`) over every `PlacementSpec::auto` tenant.
///
/// # Example
///
/// ```
/// use predserve::controller::Levers;
/// use predserve::gpu::MigProfile;
/// use predserve::platform::ScenarioBuilder;
/// use predserve::tenants::{
///     CompSpec, InterferenceSchedule, LsSpec, PlacementSpec, TenantWorkload,
/// };
///
/// let scenario = ScenarioBuilder::new("example", 42)
///     .levers(Levers::full())
///     .protect_all_ls() // one controller per latency-sensitive tenant
///     .horizon(600.0)
///     .tenant(TenantWorkload::latency_sensitive(
///         "api",
///         LsSpec { arrival_rps: 70.0, slo_ms: 15.0, ..LsSpec::default() },
///         PlacementSpec::dedicated_at(0, MigProfile::P4g40gb, 0),
///     ))
///     .tenant(TenantWorkload::compute_heavy(
///         "trainer",
///         CompSpec::default(),
///         InterferenceSchedule::periodic(600.0, 120.0, 0.5, 30.0),
///         PlacementSpec::shared_with(0), // MPS on the api's instance
///     ))
///     .spare(4, MigProfile::P3g40gb, 0) // headroom for the placement lever
///     .build();
///
/// assert_eq!(scenario.n_tenants(), 2);
/// assert!(scenario.protect_all_ls);
/// assert!(scenario.layout.all_placed());
/// ```
#[derive(Clone, Debug)]
pub struct ScenarioBuilder {
    name: String,
    seed: u64,
    topo: HostTopology,
    tenants: Vec<TenantWorkload>,
    spares: Vec<(usize, MigProfile, usize)>,
    primary: Option<usize>,
    protect_all_ls: bool,
    horizon: f64,
    sample_dt: f64,
    controller: ControllerConfig,
    mu_ref_profile: MigProfile,
    move_pause_s: f64,
    epsilon_sigma: f64,
    shards: usize,
    faults: FaultPlan,
    cluster: Option<ClusterTopology>,
}

impl ScenarioBuilder {
    pub fn new(name: impl Into<String>, seed: u64) -> ScenarioBuilder {
        ScenarioBuilder {
            name: name.into(),
            seed,
            topo: HostTopology::p4d(),
            tenants: Vec::new(),
            spares: Vec::new(),
            primary: None,
            protect_all_ls: false,
            horizon: 1800.0,
            sample_dt: 2.0,
            controller: ControllerConfig::with_levers(Levers::full()),
            mu_ref_profile: MigProfile::P2g20gb,
            move_pause_s: 0.05,
            epsilon_sigma: 0.32,
            shards: 1,
            faults: FaultPlan::default(),
            cluster: None,
        }
    }

    /// Run on the sharded simulation engine with `n` shards (1 = the
    /// single-queue reference). Results are byte-identical either way;
    /// this only trades event-queue depth for merge overhead.
    pub fn shards(mut self, n: usize) -> Self {
        assert!(n >= 1, "shard count must be >= 1");
        self.shards = n;
        self
    }

    pub fn topo(mut self, topo: HostTopology) -> Self {
        self.topo = topo;
        self
    }

    /// Shorthand for `controller(ControllerConfig::with_levers(..))`.
    pub fn levers(mut self, levers: Levers) -> Self {
        self.controller = ControllerConfig::with_levers(levers);
        self
    }

    pub fn controller(mut self, cfg: ControllerConfig) -> Self {
        self.controller = cfg;
        self
    }

    pub fn horizon(mut self, horizon: f64) -> Self {
        self.horizon = horizon;
        self
    }

    pub fn sample_dt(mut self, dt: f64) -> Self {
        self.sample_dt = dt;
        self
    }

    pub fn epsilon_sigma(mut self, sigma: f64) -> Self {
        self.epsilon_sigma = sigma;
        self
    }

    pub fn mu_ref_profile(mut self, p: MigProfile) -> Self {
        self.mu_ref_profile = p;
        self
    }

    pub fn move_pause_s(mut self, s: f64) -> Self {
        self.move_pause_s = s;
        self
    }

    /// Append a tenant (index = insertion order).
    pub fn tenant(mut self, t: TenantWorkload) -> Self {
        self.tenants.push(t);
        self
    }

    /// Append an auto-placed tenant: its `PlacementSpec` must be an
    /// `auto` request, which `build()` resolves through the
    /// topology-aware allocator.
    pub fn add_auto(mut self, t: TenantWorkload) -> Self {
        assert!(
            t.placement.is_auto(),
            "add_auto requires a PlacementSpec::auto placement (tenant '{}')",
            t.name
        );
        self.tenants.push(t);
        self
    }

    /// Override tenant `tenant`'s arrival process — requests for a
    /// latency-sensitive tenant, cycle triggers for a bandwidth-heavy
    /// one (the chainable [`TenantWorkload::arrivals`] does the same at
    /// construction time). The process is validated in `build()`.
    pub fn arrivals(mut self, tenant: usize, process: ArrivalProcess) -> Self {
        assert!(
            tenant < self.tenants.len(),
            "arrivals({tenant}) out of range ({} tenants added so far)",
            self.tenants.len()
        );
        if self.tenants[tenant].spec.set_arrivals(process).is_err() {
            panic!(
                "tenant {tenant} ('{}') is compute-heavy; arrival processes only \
                 drive latency-sensitive requests or bandwidth-heavy cycle triggers",
                self.tenants[tenant].name
            );
        }
        self
    }

    /// Attach a request-granularity LLM serving model to latency-sensitive
    /// tenant `tenant` (the chainable [`TenantWorkload::llm`] constructor
    /// does the same at construction time): its arrivals route through the
    /// simulated continuous-batching engine and the run reports TTFT/TPOT
    /// tails for it. The spec is validated in `build()`.
    pub fn llm(mut self, tenant: usize, spec: LlmWorkloadSpec) -> Self {
        assert!(
            tenant < self.tenants.len(),
            "llm({tenant}) out of range ({} tenants added so far)",
            self.tenants.len()
        );
        match self.tenants[tenant].spec.as_ls_mut() {
            Some(ls) => ls.llm = Some(spec),
            None => panic!(
                "tenant {tenant} ('{}') is not latency-sensitive; the LLM \
                 serving engine only drives latency-sensitive requests",
                self.tenants[tenant].name
            ),
        }
        self
    }

    /// Pre-provision an idle spare instance.
    pub fn spare(mut self, gpu: usize, profile: MigProfile, start: usize) -> Self {
        self.spares.push((gpu, profile, start));
        self
    }

    /// Override the primary tenant (defaults to the first
    /// latency-sensitive tenant).
    pub fn primary(mut self, idx: usize) -> Self {
        self.primary = Some(idx);
        self
    }

    /// Protect *every* latency-sensitive tenant with its own controller
    /// (τ = the tenant's SLO; the designated primary keeps the scenario's
    /// τ), coordinated by the arbitration control plane. Without this,
    /// only the primary is actively controlled — the paper's
    /// single-primary setup.
    pub fn protect_all_ls(mut self) -> Self {
        self.protect_all_ls = true;
        self
    }

    /// Attach a deterministic fault-injection plan (`crate::faults`).
    /// The plan is validated in `build()`; an empty plan (the default)
    /// leaves the run byte-identical to a fault-free world.
    pub fn faults(mut self, plan: FaultPlan) -> Self {
        self.faults = plan;
        self
    }

    /// Attach a multi-host cluster network. Without one (the default)
    /// the built scenario carries no net fabric and is byte-identical
    /// to a pre-cluster world; with one, ring-collective trainers
    /// ([`CollectiveSpec`]) may span its hosts. Validated in `build()`.
    pub fn cluster(mut self, cluster: ClusterTopology) -> Self {
        self.cluster = Some(cluster);
        self
    }

    pub fn build(self) -> Scenario {
        assert!(!self.tenants.is_empty(), "scenario needs at least one tenant");
        // Validate MPS-shared placements; the actual gpu/profile/instance
        // of a sharer comes from its peer when `SimWorld::new` builds the
        // world (single resolution point — the sharer's own placement
        // fields are placeholders).
        for (i, t) in self.tenants.iter().enumerate() {
            if let Some(peer) = t.placement.share_with {
                assert!(
                    peer < i,
                    "tenant {i} shares with tenant {peer}, which must come earlier"
                );
                assert!(
                    self.tenants[peer].placement.share_with.is_none(),
                    "tenant {peer} is itself MPS-shared; chain sharing is not supported"
                );
                // The world only models MPS contention from compute-heavy
                // sharers (diagnosis + quota guardrails assume it); other
                // kinds would silently diverge from the controller's model.
                assert_eq!(
                    t.kind(),
                    TenantKind::ComputeHeavy,
                    "tenant {i} is an MPS sharer but not compute-heavy"
                );
            }
        }
        // Arrival processes fail here — at scenario build time, with the
        // typed `ArrivalError` in the message — never as a mid-sim panic.
        // (`TraceSpec` is valid by construction; this catches bad
        // Poisson rates and envelope parameters.)
        for (i, t) in self.tenants.iter().enumerate() {
            if let Some(p) = t.arrival_process() {
                p.validate().unwrap_or_else(|e| {
                    panic!("tenant {i} ({}): invalid arrival process: {e}", t.name)
                });
            }
        }
        // Same deal for LLM workload specs: a bad token distribution or
        // KV-cache geometry fails here, not as a mid-sim panic.
        for (i, t) in self.tenants.iter().enumerate() {
            if let Some(llm) = t.spec.as_ls().and_then(|ls| ls.llm.as_ref()) {
                llm.validate().unwrap_or_else(|e| {
                    panic!("tenant {i} ({}): invalid llm workload: {e}", t.name)
                });
            }
        }
        // Fault plans fail here too — at build time with the typed
        // message, never as a mid-sim panic.
        self.faults
            .validate()
            .unwrap_or_else(|e| panic!("scenario '{}': invalid fault plan: {e}", self.name));
        // Ring collectives need a cluster to route over, and the ring
        // must fit it — both fail here, never as a mid-sim panic.
        for (i, t) in self.tenants.iter().enumerate() {
            let Some(ring) = t.spec.as_comp().and_then(|c| c.collective.as_ref()) else {
                continue;
            };
            let cluster = self.cluster.as_ref().unwrap_or_else(|| {
                panic!(
                    "tenant {i} ({}) runs a ring collective but the scenario \
                     has no cluster topology (ScenarioBuilder::cluster)",
                    t.name
                )
            });
            ring.validate(cluster).unwrap_or_else(|e| {
                panic!("tenant {i} ({}): invalid ring collective: {e}", t.name)
            });
        }
        if let Some(p) = self.primary {
            assert!(
                p < self.tenants.len(),
                "primary index {p} out of range ({} tenants)",
                self.tenants.len()
            );
        }
        let primary = self.primary.unwrap_or_else(|| {
            self.tenants
                .iter()
                .position(|t| t.kind() == TenantKind::LatencySensitive)
                .expect("scenario needs a latency-sensitive tenant as primary")
        });
        assert_eq!(
            self.tenants[primary].kind(),
            TenantKind::LatencySensitive,
            "primary tenant must be latency-sensitive"
        );
        for (gpu, _, _) in &self.spares {
            assert!(*gpu < self.topo.num_gpus, "spare on unknown gpu {gpu}");
        }
        for (i, t) in self.tenants.iter().enumerate() {
            // Sharers carry placeholder placement fields (their real
            // placement is the peer's); auto placements are resolved
            // below.
            if t.placement.share_with.is_some() || t.placement.is_auto() {
                continue;
            }
            assert!(
                t.placement.gpu < self.topo.num_gpus,
                "tenant {i} placed on unknown gpu {}",
                t.placement.gpu
            );
        }

        let (tenants, layout) = self.resolve_placements();
        assert!(
            layout.all_placed(),
            "scenario '{}': admission could not place tenant(s) {:?} — \
             shrink the asks, relax the admission thresholds, or split the \
             list across hosts with the fleet allocator",
            self.name,
            layout
                .unplaced()
                .iter()
                .map(|e| format!("{} ({:?})", e.name, e.outcome))
                .collect::<Vec<_>>()
        );

        Scenario {
            name: self.name,
            topo: self.topo,
            tenants,
            spares: self.spares,
            primary,
            protect_all_ls: self.protect_all_ls,
            horizon: self.horizon,
            sample_dt: self.sample_dt,
            controller: self.controller,
            seed: self.seed,
            mu_ref_profile: self.mu_ref_profile,
            move_pause_s: self.move_pause_s,
            epsilon_sigma: self.epsilon_sigma,
            shards: self.shards,
            layout,
            faults: self.faults,
            cluster: self.cluster,
        }
    }

    /// Resolve every placement through one [`HostAllocator`] pass:
    /// pinned tenants commit verbatim (first-fit when `start` is `None`,
    /// so the plan records the slot the world will use), spares occupy
    /// their slices, and auto tenants are packed first-fit-decreasing
    /// through admission. Returns the (possibly rewritten) tenant list
    /// plus the layout plan.
    fn resolve_placements(&self) -> (Vec<TenantWorkload>, AllocPlan) {
        let n = self.tenants.len();
        let mut tenants = self.tenants.clone();
        let mut allocator = HostAllocator::new(self.topo.clone(), self.controller.clone());
        let mut entries: Vec<Option<PlanEntry>> = vec![None; n];

        // Pass 1: pinned and MPS-shared tenants, in tenant order (the
        // same order the world creates instances, so `start: None`
        // first-fits identically).
        for i in 0..n {
            let t = &tenants[i];
            if t.placement.is_auto() {
                continue;
            }
            let est = t.spec.expected_pcie_gbps();
            if let Some(peer) = t.placement.share_with {
                assert!(
                    !tenants[peer].placement.is_auto(),
                    "tenant {i} MPS-shares with auto-placed tenant {peer}; \
                     sharing onto an auto placement is not supported"
                );
                allocator.commit_shared(i, t.kind(), peer, est);
                entries[i] = Some(PlanEntry {
                    index: i,
                    name: t.name.clone(),
                    kind: t.kind(),
                    auto: false,
                    outcome: SlotOutcome::Shared { peer },
                    score: 0.0,
                    expected_pcie_gbps: est,
                });
                continue;
            }
            let p = t.placement;
            let start = allocator
                .commit_pinned(i, t.kind(), p.gpu, p.profile, p.start, est)
                .unwrap_or_else(|e| {
                    panic!("tenant {i} ({}) placement failed: {e}", t.name)
                });
            entries[i] = Some(PlanEntry {
                index: i,
                name: t.name.clone(),
                kind: t.kind(),
                auto: false,
                outcome: SlotOutcome::Placed {
                    gpu: p.gpu,
                    profile: p.profile,
                    start,
                },
                score: 0.0,
                expected_pcie_gbps: est,
            });
            tenants[i].placement.start = Some(start);
        }
        for &(gpu, profile, start) in &self.spares {
            allocator
                .commit_spare(gpu, profile, start)
                .unwrap_or_else(|e| panic!("spare on gpu{gpu} failed: {e}"));
        }

        // Pass 2: auto tenants, first-fit-decreasing through admission.
        let reqs: Vec<AutoRequest> = tenants
            .iter()
            .enumerate()
            .filter_map(|(i, t)| {
                t.placement.auto.map(|a| AutoRequest {
                    index: i,
                    name: t.name.clone(),
                    kind: t.kind(),
                    min_profile: a.min_profile,
                    expected_pcie_gbps: a.expected_pcie_gbps,
                })
            })
            .collect();
        let outcomes = allocator.pack(&reqs);
        for (req, (outcome, score)) in reqs.iter().zip(outcomes) {
            if let SlotOutcome::Placed {
                gpu,
                profile,
                start,
            } = outcome
            {
                tenants[req.index].placement = PlacementSpec::dedicated_at(gpu, profile, start);
            }
            entries[req.index] = Some(PlanEntry {
                index: req.index,
                name: req.name.clone(),
                kind: req.kind,
                auto: true,
                outcome,
                score,
                expected_pcie_gbps: req.expected_pcie_gbps,
            });
        }

        let layout = AllocPlan {
            entries: entries
                .into_iter()
                .map(|e| e.expect("every tenant planned"))
                .collect(),
            link_gbps: allocator.link_gbps().to_vec(),
            link_capacity: allocator.link_capacities(),
        };
        (tenants, layout)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn identical_seed_identical_schedules_across_levers() {
        // §3.2: comparisons use identical interference schedules.
        let a = Scenario::paper_single_host(7, Levers::full());
        let b = Scenario::paper_single_host(7, Levers::none());
        for (ta, tb) in a.tenants.iter().zip(&b.tenants) {
            assert_eq!(ta.schedule.phases, tb.schedule.phases);
        }
    }

    #[test]
    fn llm_case_overrides_slo() {
        let s = Scenario::paper_llm_case(1, Levers::full());
        assert_eq!(s.primary_spec().slo_ms, 200.0);
        assert_eq!(s.controller.tau_ms, 200.0);
        assert!(s.primary_spec().compute_ref_ms > 50.0);
    }

    #[test]
    fn schedules_have_toggles_within_horizon() {
        let s = Scenario::paper_single_host(3, Levers::full());
        let etl = &s.tenants[1].schedule;
        assert!(etl.phases.len() >= 3, "want several phases");
        assert!(etl.duty_cycle() > 0.3);
        assert!(etl.duty_cycle() < 0.9);
    }

    #[test]
    fn paper_world_keeps_three_tenant_shape() {
        let s = Scenario::paper_single_host(1, Levers::full());
        assert_eq!(s.n_tenants(), 3);
        assert_eq!(s.primary, 0);
        assert_eq!(s.tenants[0].kind(), TenantKind::LatencySensitive);
        assert_eq!(s.tenants[1].kind(), TenantKind::BandwidthHeavy);
        assert_eq!(s.tenants[2].kind(), TenantKind::ComputeHeavy);
        // The trainer is MPS-co-scheduled on the primary's instance.
        assert_eq!(s.tenants[2].placement.share_with, Some(0));
        assert_eq!(s.background_tenants(), vec![1, 2]);
    }

    #[test]
    fn catalog_resolves_every_name() {
        for name in Scenario::CATALOG {
            let s = Scenario::by_name(name, 5, Levers::full())
                .unwrap_or_else(|| panic!("catalog name {name} did not resolve"));
            assert!(s.n_tenants() >= 3, "{name} has {} tenants", s.n_tenants());
        }
        assert!(Scenario::by_name("single", 5, Levers::none()).is_some());
        assert!(Scenario::by_name("llm", 5, Levers::none()).is_some());
        assert!(Scenario::by_name("bogus", 5, Levers::none()).is_none());
    }

    #[test]
    fn chaos_catalog_entries_carry_fault_plans() {
        let flap = Scenario::link_flap_recovery(5, Levers::full());
        assert_eq!(flap.name, "link_flap_recovery");
        assert!(!flap.faults.is_empty());
        let flaky = Scenario::mig_reconfig_flaky(5, Levers::full());
        assert_eq!(flaky.name, "mig_reconfig_flaky");
        assert!(!flaky.faults.is_empty());
        // Every pre-existing entry keeps the bit-compat empty plan.
        assert!(Scenario::paper_single_host(5, Levers::full()).faults.is_empty());
        assert!(Scenario::llm_serving_mix(5, Levers::full()).faults.is_empty());
    }

    #[test]
    fn cluster_catalog_entries_carry_rings_and_topologies() {
        let ft = Scenario::fat_tree_allreduce_mix(5, Levers::full());
        let cluster = ft.cluster.as_ref().expect("fat-tree entry has a cluster");
        assert_eq!(cluster.num_hosts(), 8);
        let ring = ft.tenants[2]
            .spec
            .as_comp()
            .and_then(|c| c.collective.as_ref())
            .expect("trainer carries a ring");
        assert_eq!(ring.participants, vec![0, 1, 2, 3]);
        assert!(ring.validate(cluster).is_ok());

        let sh = Scenario::spine_hotspot(5, Levers::full());
        let cluster = sh.cluster.as_ref().expect("spine entry has a cluster");
        assert_eq!(cluster.num_hosts(), 4);
        // Both rings cross leaves and ECMP-hash onto the same spine —
        // the contention story is one shared trunk pair.
        for idx in [1usize, 2] {
            let ring = sh.tenants[idx]
                .spec
                .as_comp()
                .and_then(|c| c.collective.as_ref())
                .expect("trainer carries a ring");
            assert!(ring.validate(cluster).is_ok());
            let (a, b) = (ring.participants[0], ring.participants[1]);
            assert_ne!(cluster.leaf_of_host(a), cluster.leaf_of_host(b));
            assert_eq!(
                cluster.spine_for(cluster.leaf_of_host(a), cluster.leaf_of_host(b)),
                1
            );
        }
        // Every pre-cluster entry stays structurally cluster-free (the
        // byte-identical legacy path).
        assert!(Scenario::paper_single_host(5, Levers::full()).cluster.is_none());
        assert!(Scenario::hotspot_64(5, Levers::full()).cluster.is_none());
    }

    #[test]
    #[should_panic(expected = "no cluster topology")]
    fn build_rejects_rings_without_a_cluster() {
        ScenarioBuilder::new("ringless", 1)
            .tenant(TenantWorkload::latency_sensitive(
                "svc",
                LsSpec::default(),
                PlacementSpec::dedicated_at(0, MigProfile::P4g40gb, 0),
            ))
            .tenant(TenantWorkload::collective(
                "train",
                CompSpec::default(),
                CollectiveSpec::ring(vec![0, 1], 0.5, 1),
                InterferenceSchedule::always_on(100.0),
                PlacementSpec::dedicated_at(2, MigProfile::P3g40gb, 0),
            ))
            .build();
    }

    #[test]
    #[should_panic(expected = "invalid ring collective")]
    fn build_rejects_rings_that_do_not_fit_the_cluster() {
        ScenarioBuilder::new("bad-ring", 1)
            .cluster(ClusterTopology::leaf_spine(2, 2, 2))
            .tenant(TenantWorkload::latency_sensitive(
                "svc",
                LsSpec::default(),
                PlacementSpec::dedicated_at(0, MigProfile::P4g40gb, 0),
            ))
            .tenant(TenantWorkload::collective(
                "train",
                CompSpec::default(),
                CollectiveSpec::ring(vec![0, 99], 0.5, 1),
                InterferenceSchedule::always_on(100.0),
                PlacementSpec::dedicated_at(2, MigProfile::P3g40gb, 0),
            ))
            .build();
    }

    #[test]
    fn new_catalog_scenarios_have_at_least_four_tenants() {
        for name in ["multi_ls_slo_mix", "pcie_hotspot", "diurnal_burst"] {
            let s = Scenario::by_name(name, 9, Levers::full()).unwrap();
            assert!(
                s.n_tenants() >= 4,
                "{name}: {} tenants, want >= 4",
                s.n_tenants()
            );
            // Primary resolves to a latency-sensitive tenant.
            assert_eq!(s.tenants[s.primary].kind(), TenantKind::LatencySensitive);
        }
    }

    #[test]
    fn builder_keeps_share_links_for_the_world_to_resolve() {
        let s = Scenario::paper_single_host(2, Levers::none());
        assert_eq!(s.tenants[2].placement.share_with, Some(0));
    }

    #[test]
    #[should_panic(expected = "chain sharing")]
    fn builder_rejects_chained_mps_sharing() {
        ScenarioBuilder::new("chain", 1)
            .tenant(TenantWorkload::latency_sensitive(
                "svc",
                LsSpec::default(),
                PlacementSpec::dedicated_at(0, MigProfile::P4g40gb, 0),
            ))
            .tenant(TenantWorkload::compute_heavy(
                "a",
                CompSpec::default(),
                InterferenceSchedule::always_on(100.0),
                PlacementSpec::shared_with(0),
            ))
            .tenant(TenantWorkload::compute_heavy(
                "b",
                CompSpec::default(),
                InterferenceSchedule::always_on(100.0),
                PlacementSpec::shared_with(1),
            ))
            .build();
    }

    #[test]
    #[should_panic(expected = "latency-sensitive")]
    fn builder_requires_a_primary_ls_tenant() {
        ScenarioBuilder::new("no-ls", 1)
            .tenant(TenantWorkload::bandwidth_heavy(
                "etl",
                BwSpec::default(),
                InterferenceSchedule::always_on(100.0),
                PlacementSpec::dedicated(0, MigProfile::P3g40gb),
            ))
            .build();
    }

    #[test]
    fn auto_pack_24_fully_resolved_by_the_allocator() {
        let s = Scenario::auto_pack_24(11, Levers::full());
        assert_eq!(s.n_tenants(), 24);
        assert_eq!(s.tenants[s.primary].kind(), TenantKind::LatencySensitive);
        // Zero hand-written placements survive: every tenant has a
        // concrete allocator-chosen slot and no pending auto request.
        for (i, t) in s.tenants.iter().enumerate() {
            assert!(!t.placement.is_auto(), "tenant {i} unresolved");
            assert!(t.placement.start.is_some(), "tenant {i} has no slot");
            assert!(t.placement.gpu < s.topo.num_gpus);
        }
        assert_eq!(s.layout.entries.len(), 24);
        assert!(s.layout.all_placed());
        assert!(s.layout.entries.iter().all(|e| e.auto));
    }

    #[test]
    fn auto_pack_layout_deterministic_by_seed() {
        let a = Scenario::auto_pack_24(7, Levers::full());
        let b = Scenario::auto_pack_24(7, Levers::none());
        // Same seed ⇒ identical layout (levers don't perturb placement),
        // and identical schedules (§3.2).
        assert_eq!(a.layout.fingerprint(), b.layout.fingerprint());
        for (ta, tb) in a.tenants.iter().zip(&b.tenants) {
            assert_eq!(ta.schedule.phases, tb.schedule.phases);
        }
        let c = Scenario::auto_pack_24(7, Levers::full());
        assert_eq!(a.layout.fingerprint(), c.layout.fingerprint());
    }

    #[test]
    fn mixed_pinned_and_auto_build_resolves_autos_around_pins() {
        let s = ScenarioBuilder::new("mixed", 3)
            .tenant(TenantWorkload::latency_sensitive(
                "pinned-svc",
                LsSpec::default(),
                PlacementSpec::dedicated_at(0, MigProfile::P4g40gb, 0),
            ))
            .add_auto(TenantWorkload::bandwidth_heavy(
                "auto-etl",
                BwSpec::default(),
                InterferenceSchedule::always_on(300.0),
                PlacementSpec::auto(MigProfile::P2g20gb, 2.0),
            ))
            .spare(1, MigProfile::P3g40gb, 0)
            .build();
        assert!(!s.tenants[1].placement.is_auto());
        // The pinned tenant's slot is untouched.
        assert_eq!(s.tenants[0].placement.gpu, 0);
        assert_eq!(s.tenants[0].placement.start, Some(0));
        // The auto tenant landed on free slices (not the pin, not the
        // spare's slices on gpu1 start 0..3).
        let p = s.tenants[1].placement;
        let start = p.start.unwrap();
        if p.gpu == 0 {
            assert!(start >= 4, "overlaps the pinned 4g instance");
        }
        assert_eq!(s.layout.entries.len(), 2);
        assert!(!s.layout.entries[0].auto);
        assert!(s.layout.entries[1].auto);
    }

    #[test]
    #[should_panic(expected = "add_auto requires")]
    fn add_auto_rejects_pinned_placements() {
        let _ = ScenarioBuilder::new("bad", 1).add_auto(TenantWorkload::latency_sensitive(
            "svc",
            LsSpec::default(),
            PlacementSpec::dedicated(0, MigProfile::P3g40gb),
        ));
    }

    #[test]
    #[should_panic(expected = "could not place")]
    fn build_surfaces_unplaceable_tenants() {
        // 29 x 2g = 58 slices on a 56-slice host: admission must refuse
        // some, and build() reports them instead of overlapping slices.
        let mut b = ScenarioBuilder::new("overflow", 1)
            .controller(ControllerConfig::dense_pack(Levers::none()));
        for i in 0..29 {
            b = b.add_auto(TenantWorkload::latency_sensitive(
                format!("svc-{i}"),
                LsSpec::default(),
                PlacementSpec::auto(MigProfile::P2g20gb, 0.1),
            ));
        }
        b.build();
    }

    #[test]
    fn every_built_scenario_carries_a_layout() {
        for name in Scenario::CATALOG {
            let s = Scenario::by_name(name, 5, Levers::full()).unwrap();
            assert_eq!(s.layout.entries.len(), s.n_tenants(), "{name}");
            assert!(s.layout.all_placed(), "{name}");
            let rendered = s.layout.render();
            assert!(rendered.contains("link0"), "{name}: {rendered}");
        }
    }

    #[test]
    fn multi_controller_catalog_entries_protect_all_ls() {
        assert!(Scenario::multi_ls_slo_mix(3, Levers::full()).protect_all_ls);
        assert!(Scenario::dueling_primaries(3, Levers::full()).protect_all_ls);
        // The paper's scenarios keep the legacy single-primary default
        // (seed-identical RNG streams and event order).
        assert!(!Scenario::paper_single_host(3, Levers::full()).protect_all_ls);
        assert!(!Scenario::paper_llm_case(3, Levers::full()).protect_all_ls);
        assert!(!Scenario::pcie_hotspot(3, Levers::full()).protect_all_ls);
        assert!(!Scenario::auto_pack_24(3, Levers::full()).protect_all_ls);
        assert!(!Scenario::hotspot_64(3, Levers::full()).protect_all_ls);
    }

    #[test]
    fn hotspot_64_shape_two_switches_fully_auto_placed() {
        let s = Scenario::hotspot_64(11, Levers::full());
        assert_eq!(s.n_tenants(), 64);
        assert_eq!(s.topo.switches.len(), 2, "the contention story is two uplinks");
        assert_eq!(s.topo.num_gpus, 16);
        assert_eq!(s.tenants[s.primary].kind(), TenantKind::LatencySensitive);
        let mut kinds = (0usize, 0usize, 0usize);
        for (i, t) in s.tenants.iter().enumerate() {
            assert!(!t.placement.is_auto(), "tenant {i} unresolved");
            assert!(t.placement.start.is_some(), "tenant {i} has no slot");
            assert!(t.placement.gpu < s.topo.num_gpus);
            match t.kind() {
                TenantKind::LatencySensitive => kinds.0 += 1,
                TenantKind::BandwidthHeavy => kinds.1 += 1,
                TenantKind::ComputeHeavy => kinds.2 += 1,
            }
        }
        assert_eq!(kinds, (16, 32, 16));
        assert!(s.layout.all_placed());
        // Both uplinks carry real expected load — a hot spot on each.
        for sw in &s.topo.switches {
            let gbps = s.layout.link_gbps[sw.link.0];
            assert!(
                gbps > 0.4 * sw.bandwidth_gbps,
                "uplink {:?} barely loaded: {gbps} GB/s",
                sw.link
            );
        }
    }

    #[test]
    fn dense_hotspot_scales_topology_with_tenant_count() {
        // Covers every N the scale_sweep bench runs, so an admission
        // regression surfaces here instead of as a CI bench panic.
        for n in [24usize, 64, 128, 256] {
            let s = Scenario::dense_hotspot(5, n, Levers::none());
            assert_eq!(s.n_tenants(), n, "n={n}");
            assert!(s.layout.all_placed(), "n={n}: admission refused someone");
            assert!(s.topo.switches.len() >= 2);
        }
    }

    #[test]
    fn dueling_primaries_shape() {
        let s = Scenario::dueling_primaries(7, Levers::full());
        assert_eq!(s.n_tenants(), 5);
        assert_eq!(s.primary, 0);
        // Two LS services, each MPS-sharing with its own trainer.
        assert_eq!(s.tenants[0].kind(), TenantKind::LatencySensitive);
        assert_eq!(s.tenants[1].kind(), TenantKind::LatencySensitive);
        assert_eq!(s.tenants[3].placement.share_with, Some(0));
        assert_eq!(s.tenants[4].placement.share_with, Some(1));
        // Both LS tenants sit on the same PCIe switch; the spare is on
        // the other NUMA domain (the single contested escape slot).
        assert!(s.topo.share_switch(
            s.tenants[0].placement.gpu,
            s.tenants[1].placement.gpu
        ));
        assert_eq!(s.spares.len(), 1);
        assert_eq!(s.topo.numa_of_gpu(s.spares[0].0), 1);
    }

    #[test]
    fn trace_burst_32_shape_traces_on_ls_triggers_on_etl() {
        let s = Scenario::trace_burst_32(11, Levers::full());
        assert_eq!(s.n_tenants(), 32);
        assert_eq!(s.topo.switches.len(), 2);
        assert!(s.layout.all_placed());
        assert_eq!(s.tenants[s.primary].kind(), TenantKind::LatencySensitive);
        for (i, t) in s.tenants.iter().enumerate() {
            assert!(!t.placement.is_auto(), "tenant {i} unresolved");
            match t.kind() {
                TenantKind::LatencySensitive => {
                    let spec = t.spec.as_ls().unwrap();
                    let Some(ArrivalProcess::Trace(trace)) = &spec.arrivals else {
                        panic!("{}: LS tenant without a trace", t.name);
                    };
                    // Covers the schedule window, mean ≈ the nominal rate.
                    assert!(trace.span() > 1700.0, "{}: span {}", t.name, trace.span());
                    let ratio = trace.mean_rps() / spec.arrival_rps;
                    assert!(
                        (0.5..=2.0).contains(&ratio),
                        "{}: mean {} vs nominal {}",
                        t.name,
                        trace.mean_rps(),
                        spec.arrival_rps
                    );
                }
                TenantKind::BandwidthHeavy => match &t.spec.as_bw().unwrap().arrivals {
                    Some(ArrivalProcess::Poisson { rps }) => {
                        assert_eq!(*rps, 1.5, "{}", t.name)
                    }
                    other => panic!("{}: ETL without Poisson triggers ({other:?})", t.name),
                },
                TenantKind::ComputeHeavy => assert!(t.arrival_process().is_none()),
            }
        }
        // Deterministic: same seed, identical traces.
        let b = Scenario::trace_burst_32(11, Levers::none());
        for (ta, tb) in s.tenants.iter().zip(&b.tenants) {
            match (ta.arrival_process(), tb.arrival_process()) {
                (Some(pa), Some(pb)) => assert_eq!(pa, pb, "{}", ta.name),
                (None, None) => {}
                _ => panic!("{}: arrival process depends on levers", ta.name),
            }
        }
    }

    #[test]
    fn diurnal_trace_mix_reexpresses_waves_as_envelopes() {
        let s = Scenario::diurnal_trace_mix(7, Levers::full());
        assert_eq!(s.n_tenants(), 5);
        assert_eq!(s.primary, 0);
        // Serving rides a diurnal envelope at the background wave period.
        match s.tenants[0].arrival_process() {
            Some(ArrivalProcess::Modulated { base_rps, envelope }) => {
                assert_eq!(*base_rps, 80.0);
                assert!(matches!(
                    envelope,
                    Envelope::Diurnal { period_s, .. } if *period_s == 600.0
                ));
            }
            other => panic!("serving: wrong process {other:?}"),
        }
        // ETL waves live in burst envelopes, phase-shifted half a period,
        // over always-on schedules.
        for (idx, phase) in [(2usize, 0.0), (3, 300.0)] {
            assert_eq!(s.tenants[idx].kind(), TenantKind::BandwidthHeavy);
            assert!(s.tenants[idx].schedule.active_at(s.horizon / 2.0));
            match s.tenants[idx].arrival_process() {
                Some(ArrivalProcess::Modulated { envelope, .. }) => match envelope {
                    Envelope::Bursts { phase_s, low, .. } => {
                        assert_eq!(*phase_s, phase);
                        assert_eq!(*low, 0.0);
                    }
                    other => panic!("etl {idx}: wrong envelope {other:?}"),
                },
                other => panic!("etl {idx}: wrong process {other:?}"),
            }
        }
        // Trainers keep plain periodic schedules and no arrival side.
        assert!(s.tenants[1].arrival_process().is_none());
        assert!(s.tenants[4].arrival_process().is_none());
    }

    #[test]
    #[should_panic(expected = "invalid arrival process")]
    fn build_rejects_bad_poisson_rate_at_build_time() {
        ScenarioBuilder::new("bad-rate", 1)
            .tenant(TenantWorkload::latency_sensitive(
                "svc",
                LsSpec::default(),
                PlacementSpec::dedicated_at(0, MigProfile::P4g40gb, 0),
            ))
            .arrivals(0, ArrivalProcess::Poisson { rps: -3.0 })
            .build();
    }

    #[test]
    #[should_panic(expected = "invalid arrival process")]
    fn build_rejects_bad_envelope_at_build_time() {
        ScenarioBuilder::new("bad-envelope", 1)
            .tenant(TenantWorkload::latency_sensitive(
                "svc",
                LsSpec::default(),
                PlacementSpec::dedicated_at(0, MigProfile::P4g40gb, 0),
            ))
            .arrivals(
                0,
                ArrivalProcess::Modulated {
                    base_rps: 10.0,
                    envelope: Envelope::Diurnal {
                        period_s: -600.0,
                        amplitude: 0.5,
                        phase_s: 0.0,
                    },
                },
            )
            .build();
    }

    #[test]
    #[should_panic(expected = "compute-heavy")]
    fn builder_arrivals_rejects_compute_tenants() {
        let _ = ScenarioBuilder::new("bad-kind", 1)
            .tenant(TenantWorkload::latency_sensitive(
                "svc",
                LsSpec::default(),
                PlacementSpec::dedicated_at(0, MigProfile::P4g40gb, 0),
            ))
            .tenant(TenantWorkload::compute_heavy(
                "train",
                CompSpec::default(),
                InterferenceSchedule::always_on(100.0),
                PlacementSpec::shared_with(0),
            ))
            .arrivals(1, ArrivalProcess::Poisson { rps: 1.0 });
    }

    #[test]
    fn rate_matched_poisson_flattens_explicit_processes_only() {
        let s = Scenario::trace_burst_32(11, Levers::none());
        let flat = s.rate_matched_poisson();
        for (orig, t) in s.tenants.iter().zip(&flat.tenants) {
            match (orig.arrival_process(), t.arrival_process()) {
                (Some(p), Some(ArrivalProcess::Poisson { rps })) => {
                    assert_eq!(*rps, p.mean_rps(), "{}", t.name);
                }
                (None, None) => {}
                other => panic!("{}: unexpected process pair {other:?}", t.name),
            }
        }
        // Pre-trace scenarios are untouched (no explicit processes).
        let plain = Scenario::paper_single_host(3, Levers::none());
        let matched = plain.rate_matched_poisson();
        for t in &matched.tenants {
            assert!(t.arrival_process().is_none(), "{}", t.name);
        }
    }

    #[test]
    fn presampled_traces_cover_the_horizon_and_pin_the_stream() {
        let mut s = Scenario::paper_single_host(9, Levers::none());
        s.horizon = 45.0;
        let traced = s.with_presampled_traces();
        let spec = traced.tenants[0].spec.as_ls().unwrap();
        let Some(ArrivalProcess::Trace(trace)) = &spec.arrivals else {
            panic!("primary not presampled");
        };
        // The presample passes the horizon by exactly one arrival.
        assert!(trace.span() > 45.0);
        assert!(trace.span() - trace.gaps().last().unwrap() <= 45.0);
        // Closed-loop background tenants stay untouched.
        assert!(traced.tenants[1].arrival_process().is_none());
        assert!(traced.tenants[2].arrival_process().is_none());
        // Deterministic: presampling twice yields identical traces.
        let again = s.with_presampled_traces();
        assert_eq!(
            traced.tenants[0].arrival_process(),
            again.tenants[0].arrival_process()
        );
    }

    #[test]
    fn llm_serving_mix_shape() {
        let s = Scenario::llm_serving_mix(7, Levers::full());
        assert_eq!(s.n_tenants(), 3);
        assert_eq!(s.primary, 0);
        let spec = s.primary_spec();
        let llm = spec.llm.as_ref().expect("primary carries an LLM workload");
        assert!(llm.validate().is_ok());
        assert_eq!(s.controller.objective, SloKind::E2e);
        assert_eq!(s.controller.tau_ms, spec.slo_ms);
        // Background tenants are the paper's mix, schedules seed-pinned.
        assert_eq!(s.tenants[1].kind(), TenantKind::BandwidthHeavy);
        assert_eq!(s.tenants[2].kind(), TenantKind::ComputeHeavy);
        let b = Scenario::llm_serving_mix(7, Levers::none());
        for (ta, tb) in s.tenants.iter().zip(&b.tenants) {
            assert_eq!(ta.schedule.phases, tb.schedule.phases);
        }
    }

    #[test]
    fn llm_burst_ttft_targets_the_ttft_tail() {
        let s = Scenario::llm_burst_ttft(7, Levers::full());
        assert_eq!(s.n_tenants(), 3);
        let spec = s.primary_spec();
        let llm = spec.llm.as_ref().expect("primary carries an LLM workload");
        assert_eq!(s.controller.objective, SloKind::Ttft);
        assert_eq!(s.controller.tau_ms, llm.ttft_slo_ms);
        // Bursty arrivals with a mean-preserving envelope.
        match spec.arrival_process() {
            ArrivalProcess::Modulated { base_rps, envelope } => {
                assert_eq!(base_rps, 1.2);
                match envelope {
                    Envelope::Bursts { duty, high, low, .. } => {
                        let mean = duty * high + (1.0 - duty) * low;
                        assert!((mean - 1.0).abs() < 1e-12);
                    }
                    other => panic!("wrong envelope {other:?}"),
                }
            }
            other => panic!("wrong process {other:?}"),
        }
    }

    #[test]
    fn builder_llm_attaches_to_ls_tenants() {
        let s = ScenarioBuilder::new("attach", 3)
            .tenant(TenantWorkload::latency_sensitive(
                "svc",
                LsSpec::default(),
                PlacementSpec::dedicated_at(0, MigProfile::P4g40gb, 0),
            ))
            .llm(0, LlmWorkloadSpec::fixed(256, 32))
            .build();
        let llm = s.primary_spec().llm.as_ref().unwrap();
        assert_eq!(llm.prompt, crate::tenants::TokenDist::Fixed(256));
    }

    #[test]
    #[should_panic(expected = "not latency-sensitive")]
    fn builder_llm_rejects_background_tenants() {
        let _ = ScenarioBuilder::new("bad-llm", 1)
            .tenant(TenantWorkload::latency_sensitive(
                "svc",
                LsSpec::default(),
                PlacementSpec::dedicated_at(0, MigProfile::P4g40gb, 0),
            ))
            .tenant(TenantWorkload::bandwidth_heavy(
                "etl",
                BwSpec::default(),
                InterferenceSchedule::always_on(100.0),
                PlacementSpec::dedicated_at(0, MigProfile::P3g40gb, 4),
            ))
            .llm(1, LlmWorkloadSpec::chat_7b());
    }

    #[test]
    #[should_panic(expected = "invalid llm workload")]
    fn build_rejects_bad_llm_spec_at_build_time() {
        let mut bad = LlmWorkloadSpec::chat_7b();
        bad.ttft_slo_ms = 0.0;
        ScenarioBuilder::new("bad-llm-spec", 1)
            .tenant(TenantWorkload::latency_sensitive(
                "svc",
                LsSpec::default(),
                PlacementSpec::dedicated_at(0, MigProfile::P4g40gb, 0),
            ))
            .llm(0, bad)
            .build();
    }

    #[test]
    fn steady_contention_toggles_all_backgrounds() {
        let on = Scenario::steady_contention(3, Levers::none(), true);
        let off = Scenario::steady_contention(3, Levers::none(), false);
        for i in on.background_tenants() {
            assert!(on.tenants[i].schedule.active_at(on.horizon / 2.0));
            assert!(!off.tenants[i].schedule.active_at(off.horizon / 2.0));
        }
    }
}
