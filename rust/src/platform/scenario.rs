//! Scenario configuration: the paper's §3.1 experimental setup as data.

use crate::controller::{ControllerConfig, Levers};
use crate::gpu::MigProfile;
use crate::tenants::{InterferenceSchedule, T1Spec, T2Spec, T3Spec};
use crate::topo::HostTopology;
use crate::util::rng::Pcg64;

/// Everything one run needs. Identical schedules across configurations
/// (§3.2) come from deriving them off `seed` only — the controller/lever
/// settings do not perturb workload RNG streams.
#[derive(Clone, Debug)]
pub struct Scenario {
    pub topo: HostTopology,
    pub t1: T1Spec,
    pub t2: T2Spec,
    pub t3: T3Spec,
    pub t2_schedule: InterferenceSchedule,
    pub t3_schedule: InterferenceSchedule,
    /// Run horizon (sim seconds).
    pub horizon: f64,
    /// Controller sampling interval Δ (§2.1: 1-5 s).
    pub sample_dt: f64,
    pub controller: ControllerConfig,
    pub seed: u64,
    /// Reference service-rate profile for T1's `compute_ref_ms`
    /// (work is expressed as ms on this profile).
    pub mu_ref_profile: MigProfile,
    /// Placement/isolation pause for a pure move (s) — process restart +
    /// CUDA context, no `nvidia-smi mig` call.
    pub move_pause_s: f64,
    /// Latency noise ε: lognormal sigma added multiplicatively to compute.
    pub epsilon_sigma: f64,
}

impl Scenario {
    /// The paper's main single-host experiment (E1): dynamic interference,
    /// 15 ms SLO, Table 1 controller parameters.
    pub fn paper_single_host(seed: u64, levers: Levers) -> Scenario {
        let mut sched_rng = Pcg64::new(seed, 1000);
        let horizon = 1800.0;
        // T2/T3 toggle with ~90s on / ~60s off periods: long enough for
        // dwell/cool-down to matter, short enough for many transitions.
        let t2_schedule =
            InterferenceSchedule::generate(&mut sched_rng, horizon, 60.0, 90.0, 20.0);
        let t3_schedule =
            InterferenceSchedule::generate(&mut sched_rng, horizon, 70.0, 80.0, 20.0);
        Scenario {
            topo: HostTopology::p4d(),
            t1: T1Spec::default(),
            t2: T2Spec::default(),
            t3: T3Spec::default(),
            t2_schedule,
            t3_schedule,
            horizon,
            sample_dt: 2.0,
            controller: ControllerConfig::with_levers(levers),
            seed,
            mu_ref_profile: MigProfile::P2g20gb,
            move_pause_s: 0.05,
            epsilon_sigma: 0.32,
        }
    }

    /// The LLM case study (Table 2): T1 becomes a vLLM-style serving
    /// tenant measured on TTFT with a 200 ms p99 SLO. Prefill is
    /// compute-heavier and inputs (prompts/weights pages) are larger, so
    /// both PCIe and SM contention show up in TTFT.
    pub fn paper_llm_case(seed: u64, levers: Levers) -> Scenario {
        let mut s = Scenario::paper_single_host(seed, levers);
        s.t1 = T1Spec {
            arrival_rps: 4.0,
            slo_ms: 200.0,
            // Prompt+activation staging: bigger payloads than the non-LLM
            // case — vLLM prefill pulls prompt tensors across PCIe.
            // Utilization stays moderate (rho ~ 0.4 on the shared slice
            // under contention) so TTFT tails are contention-driven, not
            // saturation-driven.
            size_mix: vec![(0.60, 0.12), (0.30, 0.28), (0.10, 0.55)],
            compute_ref_ms: 55.0, // prefill on the reference slice
            compute_sigma: 0.22,
        };
        s.controller.tau_ms = 200.0;
        s
    }

    /// Steady contention variants for Figure 4 (low vs high contention).
    pub fn steady_contention(seed: u64, levers: Levers, on: bool) -> Scenario {
        let mut s = Scenario::paper_single_host(seed, levers);
        let h = s.horizon;
        s.t2_schedule = if on {
            InterferenceSchedule::always_on(h)
        } else {
            InterferenceSchedule::always_off(h)
        };
        s.t3_schedule = s.t2_schedule.clone();
        s
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn identical_seed_identical_schedules_across_levers() {
        // §3.2: comparisons use identical interference schedules.
        let a = Scenario::paper_single_host(7, Levers::full());
        let b = Scenario::paper_single_host(7, Levers::none());
        assert_eq!(a.t2_schedule.phases, b.t2_schedule.phases);
        assert_eq!(a.t3_schedule.phases, b.t3_schedule.phases);
    }

    #[test]
    fn llm_case_overrides_slo() {
        let s = Scenario::paper_llm_case(1, Levers::full());
        assert_eq!(s.t1.slo_ms, 200.0);
        assert_eq!(s.controller.tau_ms, 200.0);
        assert!(s.t1.compute_ref_ms > 50.0);
    }

    #[test]
    fn schedules_have_toggles_within_horizon() {
        let s = Scenario::paper_single_host(3, Levers::full());
        assert!(s.t2_schedule.phases.len() >= 3, "want several phases");
        assert!(s.t2_schedule.duty_cycle() > 0.3);
        assert!(s.t2_schedule.duty_cycle() < 0.9);
    }
}
