//! The simulated single-host testbed (discrete-event world).
//!
//! Generalizes the paper's §3.1 setup to N tenants: one p4d-style host
//! running any mix of latency-sensitive, bandwidth-heavy and
//! compute-heavy [`crate::tenants::TenantWorkload`]s, with the control
//! plane sampling signals every Δ and acting through the §2.2 decision
//! space. The paper's fixed T1/T2/T3 world is just the
//! `paper_single_host` catalog scenario. With
//! `Scenario::protect_all_ls`, every latency-sensitive tenant gets its
//! own controller behind the arbiter
//! ([`crate::controller::arbiter::Arbiter`]); otherwise only
//! `scenario.primary` is actively protected (the legacy single-primary
//! path, byte-identical to the pre-arbiter behavior).
//!
//! Interference channels (all emergent, none scripted):
//! * Bandwidth-heavy NVMe reads + H2D/D2H bursts share the PS fabric
//!   with latency-sensitive staging + H2D transfers (PCIe + NUMA I/O
//!   contention).
//! * A compute-heavy tenant MPS-co-scheduled on a latency-sensitive
//!   tenant's MIG instance (the naive-placement baseline) inflates its
//!   compute service times.
//! * Controller actions have real costs: MIG reconfigs pause the primary
//!   for ~18 s wall (Table 4), moves pause for ~2 s; paused requests
//!   queue and their waiting time lands in the latency distribution.
//!
//! The latency-sensitive request pipeline: host staging read (NUMA NVMe
//! link) → H2D (PCIe uplink of its GPU) → FIFO compute on its MIG
//! instance → done; latency = c_i·(μ_ref/μ(m))·contention·ε + transfer
//! components — exactly the §2.5.1 decomposition with the PS model
//! supplying b_i(t).

use std::collections::{BTreeMap, VecDeque};

use crate::controller::view::{InstanceView, TenantView};
use crate::controller::{
    Action, ActionOutcome, Arbiter, IsolationChange, PlannerView, Protected,
};
use crate::fabric::{FabricBackend, FabricKind, FlowId, NetFabricBackend};
use crate::faults::{FaultSpec, FAULT_STREAM};
use crate::gpu::{A100Gpu, InstanceId, MigProfile};
use crate::sim::{EngineKind, EventQueue, ShardMap, ShardedQueue, SimClock, COORD_SHARD};
use crate::telemetry::signals::{LinkSignal, SignalSnapshot, TenantSignal};
use crate::telemetry::TenantMonitor;
use crate::tenants::{ArrivalState, TenantId, TenantKind, WorkloadSpec};
use crate::trace::{CtlPhase, DecisionEdge, DecisionKind, Recorder, TraceEvent};
use crate::util::rng::Pcg64;

use super::result::{RunResult, TenantControllerStats, TenantRunStats};
use super::scenario::Scenario;

/// What a completing fabric flow was doing, tagged by tenant index.
#[derive(Clone, Copy, Debug)]
enum Purpose {
    /// Latency-sensitive host staging read for request `req`.
    Stage { tenant: usize, req: u64 },
    /// Latency-sensitive H2D transfer for request `req`.
    H2d { tenant: usize, req: u64 },
    /// Bandwidth-heavy cycle phases.
    CycleRead { tenant: usize },
    CycleH2d { tenant: usize },
    CycleD2h { tenant: usize },
    /// Compute-heavy gradient sync.
    StepSync { tenant: usize },
    /// LLM serving-step I/O (weight read + KV traffic) for a tenant with
    /// an attached [`crate::tenants::LlmWorkloadSpec`]: the PCIe leg of
    /// one prefill/decode wave. Compute overlaps after the flow drains.
    LlmStepIo { tenant: usize },
}

/// What a completing **net-fabric** flow was doing. The cluster network
/// carries exactly one traffic class so far: ring-allreduce segments of
/// cross-host trainers ([`crate::tenants::CollectiveSpec`]).
#[derive(Clone, Copy, Debug)]
enum NetPurpose {
    /// One ring segment of trainer `tenant`'s in-flight allreduce.
    RingSegment { tenant: usize },
}

/// Runtime state of the cluster-network layer. Present on the world iff
/// the scenario carries a [`crate::topo::ClusterTopology`] — like the
/// fault layer, the bit-compat guarantee for cluster-free scenarios is
/// **structural**: `None` means zero net events, zero extra RNG draws,
/// and an untouched event push order.
struct NetRt {
    fabric: NetFabricBackend,
    /// Lazy-advance clock, the net twin of `fabric_synced_at`.
    synced_at: f64,
    /// Completion-event version; stale `NetFlowsDone` events no-op.
    version: u64,
    flow_purpose: BTreeMap<FlowId, NetPurpose>,
    /// Per-net-link delta trackers for the trace signal series (read
    /// only while a recorder is attached — non-perturbation holds).
    last_gb: Vec<f64>,
    last_util: Vec<f64>,
}

/// Latency-sensitive request lifecycle state.
#[derive(Clone, Copy, Debug)]
enum ReqPhase {
    Staging,
    H2d,
    Queued,
    Computing,
}

#[derive(Clone, Copy, Debug)]
struct ReqState {
    arrival: f64,
    stage_gb: f64,
    h2d_gb: f64,
    compute_ref_ms: f64,
    phase: ReqPhase,
}

/// Placement record per tenant.
#[derive(Clone, Debug)]
struct Placement {
    gpu: usize,
    instance: InstanceId,
    profile: MigProfile,
    /// Tenant indices sharing the instance via MPS.
    peers: Vec<usize>,
    numa: usize,
}

/// Saved last-known-good config for rollback, tagged with the tenant
/// whose isolation change created it: only that tenant's controller may
/// restore it (the arbiter serializes in-flight changes, so ownership is
/// unique while a validation window is open).
#[derive(Clone, Debug)]
struct SavedConfig {
    owner: usize,
    gpus: Vec<A100Gpu>,
    placements: Vec<Placement>,
}

/// Runtime state of the fault-injection layer. Present on the world iff
/// the scenario's [`crate::faults::FaultPlan`] is non-empty — the
/// empty-plan bit-compat guarantee is structural, not careful: `None`
/// means zero fault events, zero extra RNG draws, zero extra branches
/// that touch workload state.
#[derive(Clone, Debug)]
struct FaultRt {
    /// Precomputed inject/clear edges over the run horizon, in firing
    /// order; `Event::FaultEdge` carries an index into this list.
    edges: Vec<crate::faults::FaultEdge>,
    /// Dedicated fault stream (`FAULT_STREAM`): drawn only when a
    /// disruptive action is attempted inside a flaky-reconfig window,
    /// so workload streams never shift.
    rng: Pcg64,
    /// Open flaky-reconfig windows `(fail_prob, latency_ms)`; the most
    /// recently injected window governs (they nest, LIFO).
    flaky: Vec<(f64, f64)>,
    /// Per-tenant count of open sensor-dropout windows (counts, not
    /// bools, so overlapping dropouts clear correctly).
    dropout: Vec<u32>,
    /// Held-last tenant signal served (flagged stale) while a dropout
    /// window is open.
    last_signals: Vec<Option<TenantSignal>>,
    /// Injected actuation latency (s) to fold into the *next* tenant
    /// pause — set by the flaky gate on a successful isolation change,
    /// consumed by `pause_tenant`.
    pending_extra_pause_s: f64,
    injected: u64,
    cleared: u64,
    /// Disruptive actuations killed by the flaky gate.
    action_failures: u64,
    /// In-flight requests failed and re-queued by `SliceFail` hits.
    requests_requeued: u64,
}

#[derive(Clone, Copy, Debug, PartialEq)]
enum CyclePhase {
    Read,
    H2d,
    Transform,
    D2h,
    Idle,
}

/// Discrete events, generic over the tenant index.
#[derive(Clone, Copy, Debug)]
enum Event {
    /// Next open-loop arrival: a request for a latency-sensitive tenant,
    /// or a cycle trigger for a trigger-driven bandwidth-heavy tenant
    /// (`BwSpec::arrivals`). Driven by the tenant's `ArrivalState`;
    /// closed traces stop scheduling these when they run out.
    Arrival { tenant: usize },
    FlowsDone { version: u64 },
    /// The cluster net fabric's twin of [`Event::FlowsDone`]: the
    /// earliest in-flight net flow (a ring segment) drains. Only
    /// scheduled on worlds with a cluster topology.
    NetFlowsDone { version: u64 },
    /// Latency-sensitive compute finished.
    ComputeDone { tenant: usize, req: u64 },
    /// Bandwidth-heavy GPU transform finished.
    CycleDone { tenant: usize },
    /// Compute-heavy training step finished.
    StepDone { tenant: usize },
    /// Background tenant schedule edge.
    Toggle { tenant: usize },
    Sample,
    PauseDone { tenant: usize },
    ThrottleExpire { tenant: usize, deadline_bits: u64 },
    /// LLM serving-step compute finished (scheduled when the step's PCIe
    /// I/O drains; only tenants with an attached `LlmWorkloadSpec` ever
    /// see one).
    LlmStepDone { tenant: usize },
    /// A fault-plan inject/clear edge fired (`idx` into the precomputed
    /// edge list). Only seeded when the scenario carries a non-empty
    /// [`crate::faults::FaultPlan`] — the empty-plan world never sees one.
    FaultEdge { idx: usize },
}

/// Per-tenant runtime state for a latency-sensitive tenant.
#[derive(Clone, Debug)]
struct LsRt {
    /// Arrival cursor over the tenant's effective process. Poisson
    /// tenants draw one `exp` from `arrival_rng` per arrival — the exact
    /// draw (and draw order) of the pre-trace inline code, so legacy
    /// scenarios replay bit-identically. Trace tenants never touch the
    /// RNG; closed traces end cleanly by scheduling nothing.
    arrival: ArrivalState,
    arrival_rng: Pcg64,
    size_rng: Pcg64,
    service_rng: Pcg64,
    next_req: u64,
    reqs: BTreeMap<u64, ReqState>,
    compute_queue: VecDeque<u64>,
    computing: Option<u64>,
    paused: bool,
    pause_backlog: Vec<u64>,
    /// Staging transfers waiting for a DMA slot (bounded I/O depth keeps
    /// post-pause backlog drains from exploding the PS flow set).
    stage_pending: VecDeque<u64>,
    inflight_transfers: usize,
    /// Request-granularity serving engine, present iff the tenant's
    /// `LsSpec` carries an [`crate::tenants::LlmWorkloadSpec`]. `None`
    /// keeps the flat staging→H2D→compute pipeline byte-identical to
    /// every pre-LLM scenario (no extra RNG draws, no extra events).
    llm: Option<Box<LlmRt>>,
}

/// Runtime state for a latency-sensitive tenant serving LLM requests
/// through the real continuous-batching stack
/// ([`crate::serving::SimServing`] = `Batcher` + `PagedKvCache` on
/// simulated time). One step (prefill or decode wave) is in flight at a
/// time: PCIe I/O (weights + KV traffic, contended on the fabric) then
/// MIG-μ-scaled compute.
#[derive(Clone, Debug)]
struct LlmRt {
    serving: crate::serving::SimServing,
    /// A step's I/O or compute is currently in flight.
    stepping: bool,
    /// Compute duration of the in-flight step, drawn at step start so
    /// the service-noise stream is consumed in step order.
    step_compute_s: f64,
    /// Time-to-first-token tail monitor (SLO = `ttft_slo_ms`).
    ttft_monitor: TenantMonitor,
    /// Time-per-output-token tail monitor (no SLO of its own).
    tpot_monitor: TenantMonitor,
}

/// Per-tenant runtime state for a bandwidth-heavy tenant.
#[derive(Clone, Debug)]
struct BwRt {
    rng: Pcg64,
    /// Cycle-trigger cursor (`BwSpec::arrivals`): `None` keeps the
    /// closed loop — back-to-back cycles while the schedule is on, no
    /// extra events, bit-identical to the pre-trace engine. Triggers
    /// draw from `arrival_rng` (its own stream, `base + 3`) so the cycle
    /// sampling stream stays untouched either way.
    arrival: Option<ArrivalState>,
    arrival_rng: Pcg64,
    phase: CyclePhase,
    cycle: (f64, f64, f64, f64),
    cycle_started: f64,
}

/// In-flight ring-allreduce state for a cross-host trainer: which round
/// and ring step the collective is on, and how many of the step's
/// segment flows are still draining. `None` between allreduces.
#[derive(Clone, Copy, Debug)]
struct RingRt {
    round: u32,
    ring_step: u32,
    inflight: u32,
}

/// Per-tenant runtime state for a compute-heavy tenant.
#[derive(Clone, Debug)]
struct CompRt {
    rng: Pcg64,
    stepping: bool,
    quota: f64,
    step_started: f64,
    /// In-flight allreduce of a cross-host trainer
    /// (`CompSpec::collective`); local trainers never set it.
    ring: Option<RingRt>,
}

#[derive(Clone, Debug)]
enum TenantRt {
    Ls(LsRt),
    Bw(BwRt),
    Comp(CompRt),
}

/// Workload RNG stream ids. The paper's three-tenant layout keeps its
/// historical stream numbers (so seeded runs replay the seed repo's
/// dynamics bit-for-bit); other (index, kind) combinations get a
/// disjoint block per tenant.
fn stream_base(index: usize, kind: TenantKind) -> u64 {
    match (index, kind) {
        (0, TenantKind::LatencySensitive) => 1, // +0 arrival, +1 size, +2 service
        (1, TenantKind::BandwidthHeavy) => 4,   // +0 cycle, +3 cycle triggers
        (2, TenantKind::ComputeHeavy) => 5,
        _ => 100 + 8 * index as u64,
    }
}

/// RNG stream id feeding tenant `index`'s arrival/trigger draws —
/// exposed so the differential oracle (tests, benches,
/// `Scenario::with_presampled_traces`) can presample the exact Poisson
/// stream the live world would consume. Latency-sensitive tenants draw
/// arrivals on their block's first stream; bandwidth-heavy cycle
/// triggers use a dedicated `base + 3` stream so the cycle-sampling
/// stream is identical with and without triggers.
pub fn arrival_stream(index: usize, kind: TenantKind) -> u64 {
    let base = stream_base(index, kind);
    match kind {
        TenantKind::BandwidthHeavy => base + 3,
        _ => base,
    }
}

const RECONFIG_STREAM: u64 = 6;

/// The world's clockwork: the single-queue reference engine, or the
/// sharded conservative-PDES engine plus the tenant→shard routing map.
/// Routing lives *here* — every `push_at` call site in the world stays
/// engine-agnostic, which is what keeps the two engines' push order
/// (and therefore their `(time, seq)` assignment) identical.
enum WorldQueue {
    Single(EventQueue<Event>),
    Sharded {
        q: ShardedQueue<Event>,
        map: ShardMap,
    },
}

impl WorldQueue {
    fn push_at(&mut self, at: f64, ev: Event) {
        match self {
            WorldQueue::Single(q) => q.push_at(at, ev),
            WorldQueue::Sharded { q, map } => {
                let shard = match ev {
                    Event::Arrival { tenant }
                    | Event::ComputeDone { tenant, .. }
                    | Event::CycleDone { tenant }
                    | Event::StepDone { tenant }
                    | Event::Toggle { tenant }
                    | Event::PauseDone { tenant }
                    | Event::ThrottleExpire { tenant, .. }
                    | Event::LlmStepDone { tenant } => map.shard_of(tenant),
                    // Host-global events — the arbiter's sampling tick,
                    // fabric completions (the PS uplink solve spans
                    // switch subtrees; the net solve spans hosts), and
                    // fault edges (links and flaky windows are
                    // host-wide) — live on the coordinator shard.
                    Event::FlowsDone { .. }
                    | Event::NetFlowsDone { .. }
                    | Event::Sample
                    | Event::FaultEdge { .. } => COORD_SHARD,
                };
                q.push_to(shard, at, ev);
            }
        }
    }

    fn pop(&mut self) -> Option<(SimClock, Event)> {
        match self {
            WorldQueue::Single(q) => q.pop(),
            WorldQueue::Sharded { q, .. } => q.pop(),
        }
    }

    fn peek_time(&self) -> Option<f64> {
        match self {
            WorldQueue::Single(q) => q.peek_time(),
            WorldQueue::Sharded { q, .. } => q.peek_time(),
        }
    }

    fn events_processed(&self) -> u64 {
        match self {
            WorldQueue::Single(q) => q.events_processed(),
            WorldQueue::Sharded { q, .. } => q.events_processed(),
        }
    }

    fn clamped_events(&self) -> u64 {
        match self {
            WorldQueue::Single(q) => q.clamped_events(),
            WorldQueue::Sharded { q, .. } => q.clamped_events(),
        }
    }

    /// (shards, per-shard dispatch counts, cross-shard pushes, windows)
    /// — all deterministic, all excluded from fingerprints.
    fn shard_stats(&self) -> (usize, Vec<u64>, u64, u64) {
        match self {
            WorldQueue::Single(_) => (1, Vec::new(), 0, 0),
            WorldQueue::Sharded { q, .. } => (
                q.shards(),
                q.per_shard_popped().to_vec(),
                q.cross_shard_events(),
                q.sync_windows(),
            ),
        }
    }

    /// Shard of the event being handled (`None` on the single queue) —
    /// read by the flight recorder only.
    fn current_shard(&self) -> Option<usize> {
        match self {
            WorldQueue::Single(_) => None,
            WorldQueue::Sharded { q, .. } => q.current_shard(),
        }
    }
}

/// The world.
pub struct SimWorld {
    pub scenario: Scenario,
    q: WorldQueue,
    fabric: FabricBackend,
    fabric_synced_at: f64,
    fabric_version: u64,
    flow_purpose: BTreeMap<FlowId, Purpose>,
    gpus: Vec<A100Gpu>,
    placements: Vec<Placement>,

    // Per-tenant runtime state (workload streams independent of
    // controller decisions).
    rt: Vec<TenantRt>,
    /// Background tenants toggle; latency-sensitive tenants stay true.
    active: Vec<bool>,
    /// Per-tenant cgroup io.max throttle (GB/s) and its expiry deadline.
    throttles: Vec<Option<f64>>,
    throttle_deadlines: Vec<Option<f64>>,
    reconfig_rng: Pcg64,

    // Telemetry.
    monitors: Vec<TenantMonitor>,
    last_link_gb: Vec<f64>,
    last_link_util_integral: Vec<f64>,
    last_owner_gb: Vec<f64>,
    last_sample_t: f64,
    sm_util_integral: f64,
    sm_util_samples: u64,
    p99_series: Vec<(f64, f64)>,

    // Control plane + bookkeeping. Legacy scenarios run a single-entry
    // arbiter (a transparent pass-through); `protect_all_ls` scenarios
    // run one controller per latency-sensitive tenant.
    control: Option<Arbiter>,
    controller_wall_s: f64,
    last_good: Option<SavedConfig>,
    reconfig_durations: Vec<f64>,

    // Cluster net fabric. `None` = no topology = byte-identical world
    // (the cluster twin of the fault layer's structural guarantee).
    net: Option<NetRt>,

    // Fault injection. `None` = empty plan = byte-identical world.
    faults: Option<FaultRt>,
    /// Retries/degradations routed back through the control plane
    /// (kept outside `FaultRt`: a defensive `Failed` outcome can occur
    /// without any fault plan).
    action_retries: u64,

    // Flight recorder. `None` = disabled: every emit site is a single
    // `Option` check and the run is byte-identical either way (the
    // non-perturbation property test pins this). The `trace_*` fields
    // mirror control-plane state into events by diffing — controllers
    // never see the recorder.
    recorder: Option<Recorder>,
    /// Audit entries per controller already mirrored into the trace.
    trace_audit_seen: Vec<usize>,
    /// Last-seen FSM phase per controller (span open/close detection).
    trace_ctl_phase: Vec<Option<CtlPhase>>,
    /// Last-seen (conflicts, deferrals) arbitration counters.
    trace_arb_last: (u64, u64),
}

impl SimWorld {
    /// Build the world from a scenario: create each tenant's MIG instance
    /// (or join an MPS-shared peer), then the pre-provisioned spares.
    /// The paper baseline: GPU0 = [4g.40gb: primary + trainer via MPS,
    /// 3g.40gb: ETL], spare 3g.40gb on GPU1.
    pub fn new(scenario: Scenario) -> SimWorld {
        Self::new_with_fabric(scenario, FabricKind::Incremental)
    }

    /// Build the world on an explicit fabric engine. Production paths use
    /// [`SimWorld::new`] (the incremental engine); the `Reference` kind
    /// exists for the differential oracle — fingerprint-regression tests
    /// and the `scale_sweep` bench run the same scenario on both engines
    /// and require bit-identical results. The simulation engine comes
    /// from `scenario.shards` (1 → the single-queue reference).
    pub fn new_with_fabric(scenario: Scenario, fabric_kind: FabricKind) -> SimWorld {
        let engine = match scenario.shards {
            0 | 1 => EngineKind::SingleQueue,
            n => EngineKind::Sharded { shards: n },
        };
        Self::new_with_engine(scenario, fabric_kind, engine)
    }

    /// Build the world on an explicit (fabric, simulation-engine) pair.
    /// `EngineKind::Sharded` runs the conservative-PDES core of
    /// [`crate::sim::parallel`]: per-shard queues partitioned along PCIe
    /// switch subtrees with a deterministic `(time, seq)` merge, so the
    /// result is byte-identical to `EngineKind::SingleQueue` (the
    /// shard-determinism property tests pin this).
    pub fn new_with_engine(
        scenario: Scenario,
        fabric_kind: FabricKind,
        engine: EngineKind,
    ) -> SimWorld {
        let seed = scenario.seed;
        let n = scenario.n_tenants();
        let mut gpus: Vec<A100Gpu> = (0..scenario.topo.num_gpus).map(A100Gpu::new).collect();

        // Instances in tenant order; MPS sharers reuse the peer's.
        let mut placements: Vec<Placement> = Vec::with_capacity(n);
        for (i, t) in scenario.tenants.iter().enumerate() {
            let p = t.placement;
            if let Some(peer) = p.share_with {
                assert!(peer < i, "share_with must reference an earlier tenant");
                let shared = placements[peer].clone();
                placements[peer].peers.push(i);
                placements.push(Placement {
                    gpu: shared.gpu,
                    instance: shared.instance,
                    profile: shared.profile,
                    peers: vec![peer],
                    numa: shared.numa,
                });
                continue;
            }
            let gpu = &mut gpus[p.gpu];
            let instance = match p.start {
                Some(s) => gpu.create_at(p.profile, s).unwrap_or_else(|e| {
                    panic!("tenant {i} ({}) placement failed: {e:?}", t.name)
                }),
                None => gpu.create(p.profile).unwrap_or_else(|e| {
                    panic!("tenant {i} ({}) placement failed: {e:?}", t.name)
                }),
            };
            placements.push(Placement {
                gpu: p.gpu,
                instance,
                profile: p.profile,
                peers: Vec::new(),
                numa: scenario.topo.numa_of_gpu(p.gpu),
            });
        }
        for &(gpu, profile, start) in &scenario.spares {
            gpus[gpu]
                .create_at(profile, start)
                .unwrap_or_else(|e| panic!("spare on gpu{gpu} failed: {e:?}"));
        }

        // Per-tenant runtime state + monitors, with seed-stable streams.
        let mut rt = Vec::with_capacity(n);
        let mut monitors = Vec::with_capacity(n);
        for (i, t) in scenario.tenants.iter().enumerate() {
            let base = stream_base(i, t.kind());
            match &t.spec {
                WorkloadSpec::LatencySensitive(spec) => {
                    let llm = spec.llm.as_ref().map(|l| {
                        Box::new(LlmRt {
                            serving: crate::serving::SimServing::new(l.clone()),
                            stepping: false,
                            step_compute_s: 0.0,
                            ttft_monitor: TenantMonitor::new(l.ttft_slo_ms, 4096),
                            tpot_monitor: TenantMonitor::new(f64::MAX, 4096),
                        })
                    });
                    rt.push(TenantRt::Ls(LsRt {
                        arrival: ArrivalState::new(spec.arrival_process()),
                        arrival_rng: Pcg64::new(seed, base),
                        size_rng: Pcg64::new(seed, base + 1),
                        service_rng: Pcg64::new(seed, base + 2),
                        next_req: 0,
                        reqs: BTreeMap::new(),
                        compute_queue: VecDeque::new(),
                        computing: None,
                        paused: false,
                        pause_backlog: Vec::new(),
                        stage_pending: VecDeque::new(),
                        inflight_transfers: 0,
                        llm,
                    }));
                    monitors.push(TenantMonitor::new(spec.slo_ms, 4096));
                }
                WorkloadSpec::BandwidthHeavy(spec) => {
                    rt.push(TenantRt::Bw(BwRt {
                        rng: Pcg64::new(seed, base),
                        arrival: spec.arrivals.clone().map(ArrivalState::new),
                        arrival_rng: Pcg64::new(seed, base + 3),
                        phase: CyclePhase::Idle,
                        cycle: (0.0, 0.0, 0.0, 0.0),
                        cycle_started: 0.0,
                    }));
                    monitors.push(TenantMonitor::new(f64::MAX, 64));
                }
                WorkloadSpec::ComputeHeavy(spec) => {
                    rt.push(TenantRt::Comp(CompRt {
                        rng: Pcg64::new(seed, base),
                        stepping: false,
                        quota: spec.mps_quota,
                        step_started: 0.0,
                        ring: None,
                    }));
                    monitors.push(TenantMonitor::new(f64::MAX, 64));
                }
            }
        }

        let fabric = FabricBackend::new(&scenario.topo, fabric_kind);
        let n_links = scenario.topo.num_links;
        let control = scenario.controller.levers.any().then(|| {
            if scenario.protect_all_ls {
                // One controller per latency-sensitive tenant. The
                // designated primary keeps the scenario's τ (authors may
                // have tuned it, e.g. the LLM/TTFT case); secondaries run
                // against their own SLO.
                let protected: Vec<Protected> = scenario
                    .tenants
                    .iter()
                    .enumerate()
                    .filter_map(|(i, t)| {
                        let spec = t.spec.as_ls()?;
                        // Under a TTFT objective an LLM secondary is
                        // judged against its TTFT SLO, not the e2e one.
                        let tau = match (scenario.controller.objective, &spec.llm) {
                            (crate::controller::SloKind::Ttft, Some(l)) => l.ttft_slo_ms,
                            _ => spec.slo_ms,
                        };
                        Some(Protected {
                            tenant: TenantId(i),
                            tau_ms: (i != scenario.primary).then_some(tau),
                            base_rps: spec.arrival_rps,
                        })
                    })
                    .collect();
                Arbiter::multi(&scenario.controller, &protected)
            } else {
                Arbiter::single(scenario.controller.clone(), TenantId(scenario.primary))
            }
        });

        // Each tenant keeps a bounded handful of outstanding events
        // (arrival + in-flight transfers + compute/cycle timers), so
        // pre-sizing by tenant count avoids early regrow churn in
        // fleet-scale worlds.
        let capacity = 16 * n + 64;
        let q = match engine {
            EngineKind::SingleQueue => WorldQueue::Single(EventQueue::with_capacity(capacity)),
            EngineKind::Sharded { shards } => {
                // Locality key: the PCIe switch subtree hosting the
                // tenant's GPU — tenants sharing a switch (and hence an
                // uplink) stay shard-local. MPS sharers inherit their
                // peer's GPU, so they land on the peer's shard.
                let locality: Vec<usize> = placements
                    .iter()
                    .map(|p| scenario.topo.switch_of_gpu(p.gpu).id.0)
                    .collect();
                let map = ShardMap::new(&locality, shards);
                // Lookahead = the sampling interval Δ: the shortest
                // causal path between switch subtrees outside the fabric
                // is the host-wide arbiter tick (fabric completions are
                // coordinator events and bound themselves).
                WorldQueue::Sharded {
                    q: ShardedQueue::new(shards, scenario.sample_dt, capacity),
                    map,
                }
            }
        };

        // The fault layer only exists for non-empty plans: `None` here
        // is what makes the empty-plan fingerprint guarantee structural.
        let faults = (!scenario.faults.is_empty()).then(|| FaultRt {
            edges: scenario.faults.edges(scenario.horizon),
            rng: Pcg64::new(seed, FAULT_STREAM),
            flaky: Vec::new(),
            dropout: vec![0; n],
            last_signals: vec![None; n],
            pending_extra_pause_s: 0.0,
            injected: 0,
            cleared: 0,
            action_failures: 0,
            requests_requeued: 0,
        });

        // The net layer mirrors the fault layer: built only when the
        // scenario carries a cluster topology, on the same fabric
        // engine kind as the PCIe tier (the differential oracle runs
        // both kinds over identical schedules).
        let net = scenario.cluster.as_ref().map(|c| {
            let net_fabric = NetFabricBackend::new(c, fabric_kind);
            let n_net = net_fabric.num_links();
            NetRt {
                fabric: net_fabric,
                synced_at: 0.0,
                version: 0,
                flow_purpose: BTreeMap::new(),
                last_gb: vec![0.0; n_net],
                last_util: vec![0.0; n_net],
            }
        });

        let mut w = SimWorld {
            q,
            fabric,
            fabric_synced_at: 0.0,
            fabric_version: 0,
            flow_purpose: BTreeMap::new(),
            gpus,
            placements,
            rt,
            active: vec![false; n],
            throttles: vec![None; n],
            throttle_deadlines: vec![None; n],
            reconfig_rng: Pcg64::new(seed, RECONFIG_STREAM),
            monitors,
            last_link_gb: vec![0.0; n_links],
            last_link_util_integral: vec![0.0; n_links],
            last_owner_gb: vec![0.0; n],
            last_sample_t: 0.0,
            sm_util_integral: 0.0,
            sm_util_samples: 0,
            p99_series: Vec::new(),
            control,
            controller_wall_s: 0.0,
            last_good: None,
            reconfig_durations: Vec::new(),
            net,
            faults,
            action_retries: 0,
            recorder: None,
            trace_audit_seen: Vec::new(),
            trace_ctl_phase: Vec::new(),
            trace_arb_last: (0, 0),
            scenario,
        };
        w.seed_events();
        w
    }

    fn seed_events(&mut self) {
        for i in 0..self.scenario.n_tenants() {
            match self.scenario.tenants[i].kind() {
                TenantKind::LatencySensitive => {
                    self.active[i] = true;
                    let gap = {
                        let (_, ls) = self.ls_parts(i);
                        ls.arrival.next_gap(0.0, &mut ls.arrival_rng)
                    };
                    // A trace can in principle be drained before the run
                    // starts only if it is empty — which the builders
                    // reject — so this schedules for every real tenant.
                    if let Some(gap) = gap {
                        self.q.push_at(gap, Event::Arrival { tenant: i });
                    }
                }
                TenantKind::BandwidthHeavy | TenantKind::ComputeHeavy => {
                    for p in self.scenario.tenants[i].schedule.phases.clone() {
                        self.q.push_at(p.on, Event::Toggle { tenant: i });
                        self.q.push_at(p.off, Event::Toggle { tenant: i });
                    }
                    // Trigger-driven ETL pipelines additionally seed
                    // their first cycle trigger (legacy closed-loop
                    // tenants schedule nothing extra — bit-compat).
                    if let TenantRt::Bw(bw) = &mut self.rt[i] {
                        if let Some(state) = bw.arrival.as_mut() {
                            if let Some(gap) = state.next_gap(0.0, &mut bw.arrival_rng) {
                                self.q.push_at(gap, Event::Arrival { tenant: i });
                            }
                        }
                    }
                }
            }
        }
        let dt = self.scenario.sample_dt;
        self.q.push_at(dt, Event::Sample);
        // Fault edges last: an empty plan seeds nothing, so the legacy
        // push order (and hence `(time, seq)` assignment) is untouched.
        let n_edges = self.faults.as_ref().map_or(0, |f| f.edges.len());
        for idx in 0..n_edges {
            let t = self.faults.as_ref().expect("checked above").edges[idx].t;
            self.q.push_at(t, Event::FaultEdge { idx });
        }
    }

    // --- per-tenant state accessors ----------------------------------------

    fn ls_parts(&mut self, i: usize) -> (&crate::tenants::LsSpec, &mut LsRt) {
        let spec = match &self.scenario.tenants[i].spec {
            WorkloadSpec::LatencySensitive(s) => s,
            other => panic!("tenant {i} is not latency-sensitive: {:?}", other.kind()),
        };
        let rt = match &mut self.rt[i] {
            TenantRt::Ls(l) => l,
            _ => unreachable!("rt/spec kind mismatch for tenant {i}"),
        };
        (spec, rt)
    }

    fn bw_parts(&mut self, i: usize) -> (&crate::tenants::BwSpec, &mut BwRt) {
        let spec = match &self.scenario.tenants[i].spec {
            WorkloadSpec::BandwidthHeavy(s) => s,
            other => panic!("tenant {i} is not bandwidth-heavy: {:?}", other.kind()),
        };
        let rt = match &mut self.rt[i] {
            TenantRt::Bw(b) => b,
            _ => unreachable!("rt/spec kind mismatch for tenant {i}"),
        };
        (spec, rt)
    }

    fn comp_parts(&mut self, i: usize) -> (&crate::tenants::CompSpec, &mut CompRt) {
        let spec = match &self.scenario.tenants[i].spec {
            WorkloadSpec::ComputeHeavy(s) => s,
            other => panic!("tenant {i} is not compute-heavy: {:?}", other.kind()),
        };
        let rt = match &mut self.rt[i] {
            TenantRt::Comp(c) => c,
            _ => unreachable!("rt/spec kind mismatch for tenant {i}"),
        };
        (spec, rt)
    }

    fn comp_quota(&self, i: usize) -> f64 {
        match &self.rt[i] {
            TenantRt::Comp(c) => c.quota,
            _ => 100.0,
        }
    }

    // --- fabric helpers -----------------------------------------------------

    fn sync_fabric(&mut self, now: f64) {
        let dt = now - self.fabric_synced_at;
        if dt > 0.0 {
            self.fabric.advance(dt);
            self.fabric_synced_at = now;
        }
    }

    fn reschedule_fabric(&mut self, now: f64) {
        self.fabric_version += 1;
        if let Some((dt, _)) = self.fabric.next_completion() {
            self.q.push_at(
                now + dt.max(0.0),
                Event::FlowsDone {
                    version: self.fabric_version,
                },
            );
        }
    }

    fn start_flow(
        &mut self,
        now: f64,
        link: crate::topo::LinkId,
        gb: f64,
        owner: usize,
        purpose: Purpose,
    ) {
        self.sync_fabric(now);
        let cap = self.throttles[owner];
        let id = self.fabric.start(link, gb.max(1e-6), 1.0, cap, owner);
        self.flow_purpose.insert(id, purpose);
        self.reschedule_fabric(now);
    }

    // --- cluster net fabric -------------------------------------------------
    //
    // Lazy-advance twins of the PCIe helpers above, acting on the
    // optional [`NetRt`]. Every helper is a no-op on cluster-free
    // worlds, so the legacy event stream is untouched byte for byte.

    fn sync_net(&mut self, now: f64) {
        let Some(net) = self.net.as_mut() else { return };
        let dt = now - net.synced_at;
        if dt > 0.0 {
            net.fabric.advance(dt);
            net.synced_at = now;
        }
    }

    fn reschedule_net(&mut self, now: f64) {
        let Some(net) = self.net.as_mut() else { return };
        net.version += 1;
        let version = net.version;
        let next = net.fabric.next_completion();
        if let Some((dt, _)) = next {
            self.q
                .push_at(now + dt.max(0.0), Event::NetFlowsDone { version });
        }
    }

    /// Launch a multi-hop net flow over `path`. Net flows carry no
    /// arbiter throttle cap: the controller's levers do not reach this
    /// contention domain (yet) — see `docs/ARCHITECTURE.md`.
    fn start_net_flow(
        &mut self,
        now: f64,
        path: &[crate::topo::NetLinkId],
        gb: f64,
        owner: usize,
        purpose: NetPurpose,
    ) {
        self.sync_net(now);
        let net = self.net.as_mut().expect("net flow on a cluster-free world");
        let id = net.fabric.start(path, gb.max(1e-6), 1.0, None, owner);
        net.flow_purpose.insert(id, purpose);
        self.reschedule_net(now);
    }

    /// (NVMe link, PCIe uplink) of a tenant's current placement.
    fn tenant_links(&self, i: usize) -> (crate::topo::LinkId, crate::topo::LinkId) {
        let p = &self.placements[i];
        let pcie = self.scenario.topo.link_of_gpu(p.gpu);
        let nvme = self.scenario.topo.numa_nodes[p.numa].nvme_link;
        (nvme, pcie)
    }

    // --- latency-sensitive pipeline ----------------------------------------

    /// One `Event::Arrival` fired: a request arrival for a
    /// latency-sensitive tenant, a cycle trigger for a trigger-driven
    /// bandwidth-heavy tenant.
    fn on_arrival(&mut self, now: f64, i: usize) {
        match self.scenario.tenants[i].kind() {
            TenantKind::LatencySensitive => self.on_ls_arrival(now, i),
            TenantKind::BandwidthHeavy => self.on_bw_trigger(now, i),
            // Compute-heavy tenants have no arrival side; nothing ever
            // schedules one.
            TenantKind::ComputeHeavy => {}
        }
    }

    fn on_ls_arrival(&mut self, now: f64, i: usize) {
        // Schedule the next arrival first (open-loop; identical draw
        // order to the pre-trace inline Poisson code). A closed trace
        // that has run out schedules nothing — the tenant ends cleanly.
        let gap = {
            let (_, ls) = self.ls_parts(i);
            ls.arrival.next_gap(now, &mut ls.arrival_rng)
        };
        if let Some(gap) = gap {
            self.q.push_at(now + gap, Event::Arrival { tenant: i });
        }

        let flat = {
            let (spec, ls) = self.ls_parts(i);
            ls.arrival.note_emitted();
            let id = ls.next_req;
            ls.next_req += 1;
            if let Some(lspec) = &spec.llm {
                // LLM tenant: the request enters the serving engine's
                // waiting queue (KV-page-gated admission) instead of the
                // flat staging→H2D→compute pipeline. Token dims come off
                // the same size stream the flat sampler would use.
                let dims = lspec.sample_dims(&mut ls.size_rng);
                ls.llm
                    .as_mut()
                    .expect("LlmRt exists iff spec.llm is set")
                    .serving
                    .submit(id, dims, now);
                None
            } else {
                let r = spec.sample(&mut ls.size_rng, id, now);
                ls.reqs.insert(
                    id,
                    ReqState {
                        arrival: now,
                        stage_gb: r.host_stage_gb,
                        h2d_gb: r.h2d_gb,
                        compute_ref_ms: r.compute_ref_ms,
                        phase: ReqPhase::Staging,
                    },
                );
                if ls.paused {
                    ls.pause_backlog.push(id);
                }
                Some((id, ls.paused))
            }
        };
        match flat {
            Some((id, paused)) => {
                if !paused {
                    self.begin_staging(now, i, id);
                }
            }
            None => {
                // Degenerate oversized prompts complete inside `submit`;
                // fold them in before (maybe) opening a step.
                self.drain_llm_completions(i);
                self.maybe_start_llm_step(now, i);
            }
        }
    }

    /// Trigger-driven bandwidth-heavy tenants: each trigger starts a
    /// cycle if the schedule is on and the pipeline is idle; otherwise
    /// it is dropped (open-loop semantics — triggers are not queued).
    fn on_bw_trigger(&mut self, now: f64, i: usize) {
        let gap = {
            let (_, bw) = self.bw_parts(i);
            let Some(state) = bw.arrival.as_mut() else {
                return; // closed-loop tenant: no triggers are scheduled
            };
            state.note_emitted();
            state.next_gap(now, &mut bw.arrival_rng)
        };
        if let Some(gap) = gap {
            self.q.push_at(now + gap, Event::Arrival { tenant: i });
        }
        self.begin_cycle(now, i);
    }

    /// Does tenant `i` gate its ETL cycles on an arrival process (vs the
    /// legacy closed loop)?
    fn bw_trigger_driven(&self, i: usize) -> bool {
        matches!(&self.rt[i], TenantRt::Bw(b) if b.arrival.is_some())
    }

    /// Bounded transfer concurrency (DMA engines / io_uring depth): also
    /// keeps post-pause backlog drains from creating thousands of PS flows.
    const MAX_INFLIGHT: usize = 8;

    fn begin_staging(&mut self, now: f64, i: usize, id: u64) {
        let gb = {
            let (_, ls) = self.ls_parts(i);
            if ls.inflight_transfers >= Self::MAX_INFLIGHT {
                ls.stage_pending.push_back(id);
                return;
            }
            ls.inflight_transfers += 1;
            ls.reqs[&id].stage_gb
        };
        let (nvme, _) = self.tenant_links(i);
        self.start_flow(now, nvme, gb, i, Purpose::Stage { tenant: i, req: id });
    }

    fn on_stage_done(&mut self, now: f64, i: usize, id: u64) {
        let gb = {
            let (_, ls) = self.ls_parts(i);
            if let Some(r) = ls.reqs.get_mut(&id) {
                r.phase = ReqPhase::H2d;
            }
            ls.reqs[&id].h2d_gb
        };
        let (_, pcie) = self.tenant_links(i);
        self.start_flow(now, pcie, gb, i, Purpose::H2d { tenant: i, req: id });
    }

    fn on_h2d_done(&mut self, now: f64, i: usize, id: u64) {
        let next_stage = {
            let (_, ls) = self.ls_parts(i);
            if let Some(r) = ls.reqs.get_mut(&id) {
                r.phase = ReqPhase::Queued;
            }
            ls.inflight_transfers = ls.inflight_transfers.saturating_sub(1);
            if !ls.paused {
                ls.stage_pending.pop_front()
            } else {
                None
            }
        };
        if let Some(next) = next_stage {
            self.begin_staging(now, i, next);
        }
        {
            let (_, ls) = self.ls_parts(i);
            ls.compute_queue.push_back(id);
        }
        self.maybe_start_compute(now, i);
    }

    /// Service time on the tenant's current instance: μ-scaling ×
    /// MPS-contention from active compute-heavy peers × lognormal ε.
    fn service_s(&mut self, i: usize, work_ref_ms: f64) -> f64 {
        self.scaled_service_s(i, work_ref_ms / 1000.0)
    }

    /// [`SimWorld::service_s`] with the reference work already in
    /// seconds (the LLM serving-step path). One ε draw per call,
    /// consumed on the tenant's service stream in issue order — the
    /// flat path's `(ms / 1000.0)` prefix keeps its exact legacy
    /// arithmetic through the shared tail here.
    fn scaled_service_s(&mut self, i: usize, work_ref_s: f64) -> f64 {
        let p = &self.placements[i];
        let mu = p.profile.mu() / self.scenario.mu_ref_profile.mu();
        let mut contention = 1.0;
        for &peer in &p.peers {
            if !self.active[peer] {
                continue;
            }
            if let WorkloadSpec::ComputeHeavy(spec) = &self.scenario.tenants[peer].spec {
                contention *= spec.contention_factor_at(self.comp_quota(peer));
            }
        }
        let sigma = self.scenario.epsilon_sigma;
        let (_, ls) = self.ls_parts(i);
        let eps = ls.service_rng.lognormal(0.0, sigma);
        work_ref_s / mu * contention * eps
    }

    fn maybe_start_compute(&mut self, now: f64, i: usize) {
        let (id, work) = {
            let (_, ls) = self.ls_parts(i);
            if ls.computing.is_some() || ls.paused {
                return;
            }
            let Some(id) = ls.compute_queue.pop_front() else {
                return;
            };
            (id, ls.reqs[&id].compute_ref_ms)
        };
        let st = self.service_s(i, work);
        {
            let (_, ls) = self.ls_parts(i);
            if let Some(r) = ls.reqs.get_mut(&id) {
                r.phase = ReqPhase::Computing;
            }
            ls.computing = Some(id);
        }
        self.q
            .push_at(now + st, Event::ComputeDone { tenant: i, req: id });
    }

    fn on_compute_done(&mut self, now: f64, i: usize, id: u64) {
        let latency_ms = {
            let (_, ls) = self.ls_parts(i);
            if ls.computing != Some(id) {
                return; // stale event after rollback/pause rebuild
            }
            ls.computing = None;
            ls.reqs.remove(&id).map(|r| (now - r.arrival) * 1000.0)
        };
        if let Some(ms) = latency_ms {
            self.monitors[i].observe(ms);
        }
        self.maybe_start_compute(now, i);
    }

    // --- LLM request-granularity serving ------------------------------------

    /// Start the next serving step (prefill or decode wave) for an LLM
    /// tenant if the engine has work and nothing is in flight. The
    /// step's PCIe leg (weight read + KV traffic) contends on the
    /// fabric first; μ-scaled compute is scheduled when it drains.
    fn maybe_start_llm_step(&mut self, now: f64, i: usize) {
        let start = {
            let (_, ls) = self.ls_parts(i);
            if ls.paused {
                return;
            }
            let Some(llm) = ls.llm.as_mut() else {
                return;
            };
            if llm.stepping {
                return;
            }
            let Some(start) = llm.serving.begin_step() else {
                return;
            };
            llm.stepping = true;
            start
        };
        // Step compute mirrors `service_s`: μ-scaling for the tenant's
        // MIG slice × MPS contention × lognormal ε, drawn at step start
        // so the service stream is consumed in step order.
        let compute_s = self.scaled_service_s(i, start.ref_compute_s);
        {
            let (_, ls) = self.ls_parts(i);
            let llm = ls.llm.as_mut().expect("llm rt checked above");
            llm.step_compute_s = compute_s;
        }
        let (_, pcie) = self.tenant_links(i);
        self.start_flow(now, pcie, start.io_gb, i, Purpose::LlmStepIo { tenant: i });
    }

    /// The step's PCIe I/O drained: run the compute leg.
    fn on_llm_step_io_done(&mut self, now: f64, i: usize) {
        let compute_s = {
            let (_, ls) = self.ls_parts(i);
            let Some(llm) = ls.llm.as_mut() else {
                return;
            };
            if !llm.stepping {
                return;
            }
            llm.step_compute_s
        };
        self.q.push_at(now + compute_s, Event::LlmStepDone { tenant: i });
    }

    /// Step compute finished: advance every row one token (or record the
    /// prefill), fold completions into the monitors, start the next step.
    fn on_llm_step_done(&mut self, now: f64, i: usize) {
        {
            let (_, ls) = self.ls_parts(i);
            let Some(llm) = ls.llm.as_mut() else {
                return;
            };
            if !llm.stepping {
                return;
            }
            llm.stepping = false;
            llm.serving.finish_step(now);
        }
        self.drain_llm_completions(i);
        self.maybe_start_llm_step(now, i);
    }

    /// Fold the serving engine's finished requests into the tenant's
    /// monitors: e2e latency feeds the legacy monitor (so completed /
    /// miss / p99 accounting is shared with flat tenants), TTFT and TPOT
    /// feed the serving-specific tails.
    fn drain_llm_completions(&mut self, i: usize) {
        let done = {
            let (_, ls) = self.ls_parts(i);
            let Some(llm) = ls.llm.as_mut() else {
                return;
            };
            let done = llm.serving.drain_completions();
            for c in &done {
                llm.ttft_monitor.observe(c.ttft_s * 1000.0);
                llm.tpot_monitor.observe(c.tpot_s * 1000.0);
            }
            done
        };
        for c in &done {
            self.monitors[i].observe(c.e2e_s * 1000.0);
        }
    }

    // --- bandwidth-heavy ETL cycle ------------------------------------------

    fn begin_cycle(&mut self, now: f64, i: usize) {
        if !self.active[i] {
            return;
        }
        let gb = {
            let (spec, bw) = self.bw_parts(i);
            if bw.phase != CyclePhase::Idle {
                return;
            }
            bw.cycle = spec.sample_cycle(&mut bw.rng);
            bw.phase = CyclePhase::Read;
            bw.cycle_started = now;
            bw.cycle.0
        };
        let (nvme, _) = self.tenant_links(i);
        self.start_flow(now, nvme, gb, i, Purpose::CycleRead { tenant: i });
    }

    fn on_cycle_flow_done(&mut self, now: f64, which: Purpose) {
        match which {
            Purpose::CycleRead { tenant: i } => {
                let gb = {
                    let (_, bw) = self.bw_parts(i);
                    bw.phase = CyclePhase::H2d;
                    bw.cycle.1
                };
                let (_, pcie) = self.tenant_links(i);
                self.start_flow(now, pcie, gb, i, Purpose::CycleH2d { tenant: i });
            }
            Purpose::CycleH2d { tenant: i } => {
                let transform_s = {
                    let (_, bw) = self.bw_parts(i);
                    bw.phase = CyclePhase::Transform;
                    bw.cycle.3
                };
                self.q
                    .push_at(now + transform_s, Event::CycleDone { tenant: i });
            }
            Purpose::CycleD2h { tenant: i } => {
                let started = {
                    let (_, bw) = self.bw_parts(i);
                    bw.phase = CyclePhase::Idle;
                    bw.cycle_started
                };
                self.monitors[i].observe((now - started) * 1000.0);
                // Closed loop: next cycle immediately if still active.
                // Trigger-driven pipelines instead wait for the next
                // arrival-process trigger.
                if !self.bw_trigger_driven(i) {
                    self.begin_cycle(now, i);
                }
            }
            _ => unreachable!(),
        }
    }

    fn on_transform_done(&mut self, now: f64, i: usize) {
        let gb = {
            let (_, bw) = self.bw_parts(i);
            if bw.phase != CyclePhase::Transform {
                return;
            }
            bw.phase = CyclePhase::D2h;
            bw.cycle.2
        };
        let (_, pcie) = self.tenant_links(i);
        self.start_flow(now, pcie, gb, i, Purpose::CycleD2h { tenant: i });
    }

    // --- compute-heavy training loop ----------------------------------------

    fn begin_step(&mut self, now: f64, i: usize) {
        if !self.active[i] {
            return;
        }
        let step_s = {
            let (spec, comp) = self.comp_parts(i);
            if comp.stepping {
                return;
            }
            comp.stepping = true;
            comp.step_started = now;
            let (step_s, _sync) = spec.sample_step(&mut comp.rng);
            step_s
        };
        self.q.push_at(now + step_s, Event::StepDone { tenant: i });
    }

    fn on_step_done(&mut self, now: f64, i: usize) {
        // Cross-host trainers chain a ring allreduce between compute
        // and gradient sync: the step is not over (and the monitor does
        // not observe) until the collective drains. `stepping` stays
        // true through the allreduce so a Toggle edge cannot
        // double-start the next compute step.
        let has_ring = {
            let (spec, _) = self.comp_parts(i);
            spec.collective.is_some()
        };
        if has_ring && self.active[i] {
            self.begin_allreduce(now, i);
            return;
        }
        let started = {
            let (_, comp) = self.comp_parts(i);
            comp.stepping = false;
            comp.step_started
        };
        self.monitors[i].observe((now - started) * 1000.0);
        if self.active[i] {
            // Gradient sync over the PCIe uplink of the tenant's GPU.
            let sync_gb = {
                let (spec, comp) = self.comp_parts(i);
                let (_s, sync_gb) = spec.sample_step(&mut comp.rng);
                sync_gb
            };
            let (_, pcie) = self.tenant_links(i);
            self.start_flow(now, pcie, sync_gb, i, Purpose::StepSync { tenant: i });
            self.begin_step(now, i);
        }
    }

    // --- ring collectives ---------------------------------------------------

    /// Kick off round 0 of a cross-host trainer's ring allreduce.
    fn begin_allreduce(&mut self, now: f64, i: usize) {
        {
            let (_, comp) = self.comp_parts(i);
            comp.ring = Some(RingRt {
                round: 0,
                ring_step: 0,
                inflight: 0,
            });
        }
        if let Some(rec) = self.recorder.as_mut() {
            rec.emit(
                now,
                TraceEvent::Collective {
                    tenant: i as u32,
                    round: 0,
                    begin: true,
                },
            );
        }
        self.start_ring_step(now, i);
    }

    /// Launch the N segment flows of the current ring step: segment `s`
    /// moves `bytes / N` from `participants[s]` to
    /// `participants[(s + 1) % N]` over the cluster route.
    fn start_ring_step(&mut self, now: f64, i: usize) {
        let (participants, seg_gb) = {
            let (spec, _) = self.comp_parts(i);
            let c = spec.collective.as_ref().expect("ring step without a collective");
            (c.participants.clone(), c.segment_gb())
        };
        let n = participants.len();
        {
            let (_, comp) = self.comp_parts(i);
            comp.ring
                .as_mut()
                .expect("ring step without ring state")
                .inflight = n as u32;
        }
        // Routes are pure topology lookups; resolve them all before the
        // fabric borrows start.
        let routes: Vec<Vec<crate::topo::NetLinkId>> = {
            let cluster = self
                .scenario
                .cluster
                .as_ref()
                .expect("collective validated against a cluster at build time");
            (0..n)
                .map(|s| cluster.route(participants[s], participants[(s + 1) % n]))
                .collect()
        };
        for path in &routes {
            self.start_net_flow(now, path, seg_gb, i, NetPurpose::RingSegment { tenant: i });
        }
    }

    /// One ring-segment flow of trainer `i` drained. Segments barrier
    /// per ring step; the last one advances the collective: next ring
    /// step, next round, or completion (which closes the trainer step).
    fn on_ring_segment_done(&mut self, now: f64, i: usize) {
        enum Next {
            Step,
            Round { ended: u32 },
            Done { ended: u32 },
        }
        let next = {
            let (spec, comp) = self.comp_parts(i);
            let c = spec.collective.as_ref().expect("segment without a collective");
            let Some(ring) = comp.ring.as_mut() else {
                return;
            };
            ring.inflight -= 1;
            if ring.inflight > 0 {
                None
            } else {
                ring.ring_step += 1;
                if ring.ring_step < c.ring_steps() {
                    Some(Next::Step)
                } else {
                    let ended = ring.round;
                    ring.round += 1;
                    ring.ring_step = 0;
                    if ring.round < c.rounds {
                        Some(Next::Round { ended })
                    } else {
                        Some(Next::Done { ended })
                    }
                }
            }
        };
        match next {
            None => {}
            Some(Next::Step) => self.start_ring_step(now, i),
            Some(Next::Round { ended }) => {
                if let Some(rec) = self.recorder.as_mut() {
                    rec.emit(
                        now,
                        TraceEvent::Collective {
                            tenant: i as u32,
                            round: ended,
                            begin: false,
                        },
                    );
                    rec.emit(
                        now,
                        TraceEvent::Collective {
                            tenant: i as u32,
                            round: ended + 1,
                            begin: true,
                        },
                    );
                }
                self.start_ring_step(now, i);
            }
            Some(Next::Done { ended }) => {
                if let Some(rec) = self.recorder.as_mut() {
                    rec.emit(
                        now,
                        TraceEvent::Collective {
                            tenant: i as u32,
                            round: ended,
                            begin: false,
                        },
                    );
                }
                self.finish_collective_step(now, i);
            }
        }
    }

    /// The allreduce drained: close the trainer step exactly like the
    /// legacy tail of [`SimWorld::on_step_done`] — observe the full
    /// step (compute + collective), then gradient-sync and re-step if
    /// still active. RNG draw order on the comp stream is preserved:
    /// one step draw per `begin_step`, one sync draw per step close.
    fn finish_collective_step(&mut self, now: f64, i: usize) {
        let started = {
            let (_, comp) = self.comp_parts(i);
            comp.ring = None;
            comp.stepping = false;
            comp.step_started
        };
        self.monitors[i].observe((now - started) * 1000.0);
        if self.active[i] {
            let sync_gb = {
                let (spec, comp) = self.comp_parts(i);
                let (_s, sync_gb) = spec.sample_step(&mut comp.rng);
                sync_gb
            };
            let (_, pcie) = self.tenant_links(i);
            self.start_flow(now, pcie, sync_gb, i, Purpose::StepSync { tenant: i });
            self.begin_step(now, i);
        }
    }

    // --- controller actuation ------------------------------------------------

    /// Is tenant `i` under active isolation control? Every latency-
    /// sensitive tenant with `protect_all_ls`; only `scenario.primary`
    /// on the legacy single-primary path.
    fn protected(&self, i: usize) -> bool {
        if i >= self.scenario.n_tenants() {
            return false;
        }
        if self.scenario.protect_all_ls {
            self.scenario.tenants[i].kind() == TenantKind::LatencySensitive
        } else {
            i == self.scenario.primary
        }
    }

    fn save_last_good(&mut self, owner: usize) {
        self.last_good = Some(SavedConfig {
            owner,
            gpus: self.gpus.clone(),
            placements: self.placements.clone(),
        });
    }

    fn pause_tenant(&mut self, now: f64, i: usize, duration: f64) {
        // A flaky-reconfig window's injected actuation latency stretches
        // the tenant-visible pause of the change that just succeeded
        // (zero whenever no fault plan is active).
        let extra = self
            .faults
            .as_mut()
            .map_or(0.0, |f| std::mem::take(&mut f.pending_extra_pause_s));
        let (_, ls) = self.ls_parts(i);
        ls.paused = true;
        // In-flight compute finishes (the scheduled event stands);
        // queued/incoming requests wait for PauseDone.
        self.q
            .push_at(now + duration + extra, Event::PauseDone { tenant: i });
    }

    /// Tenant-visible pause for a MIG reconfiguration. The full
    /// `nvidia-smi mig` wall time (18±6 s, Table 4) is logged separately;
    /// the tenant itself is only down for the bounded checkpoint/restore
    /// window at the end of the operation (§5: "we limit frequency and
    /// bound pauses") — new instances are created make-before-break on
    /// free slices while the old one keeps serving.
    fn bounded_pause(&self, reconfig_wall_s: f64) -> f64 {
        (0.12 * reconfig_wall_s).clamp(0.5, 2.5)
    }

    fn on_pause_done(&mut self, now: f64, i: usize) {
        let work = {
            let (_, ls) = self.ls_parts(i);
            ls.paused = false;
            // Pending transfers (pre-pause) keep FIFO priority over the
            // requests that arrived during the pause.
            let mut work: Vec<u64> = ls.stage_pending.drain(..).collect();
            work.extend(ls.pause_backlog.drain(..));
            work
        };
        for id in work {
            self.begin_staging(now, i, id); // cap re-queues the excess
        }
        self.maybe_start_compute(now, i);
        // LLM tenants queue arrivals inside the serving engine during the
        // pause; resume stepping (no-op for flat tenants).
        self.maybe_start_llm_step(now, i);
    }

    /// Injected actuation latency at or above this bound is reported as
    /// [`ActionOutcome::TimedOut`]: the blue/green cutover is abandoned
    /// (make-before-break, so the world is unchanged) instead of
    /// stalling the tenant for tens of seconds.
    const ACTION_TIMEOUT_MS: f64 = 10_000.0;

    /// Apply one controller action to the world, reporting what actually
    /// happened. Non-disruptive actions always apply; disruptive ones
    /// pass the flaky-reconfig gate (when a fault plan opened one) and
    /// report `Failed`/`TimedOut` so the control plane can retry with
    /// backoff instead of validating a change that never happened.
    fn apply_action(&mut self, now: f64, action: Action) -> ActionOutcome {
        match action {
            Action::SetIoThrottle { tenant, cap_gbps } => {
                let t = tenant.0;
                if t >= self.scenario.n_tenants() {
                    return ActionOutcome::Applied;
                }
                // cgroup io.max guardrails only bite on NVMe-gated
                // (bandwidth-heavy) pipelines. Throttling a
                // latency-sensitive neighbor would trade one tenant's SLO
                // for another's, and a block-I/O cap cannot touch a
                // trainer's pure-PCIe sync traffic on real hardware — the
                // seed world enforced both by restricting throttles to
                // the T2 slot; other kinds stay world no-ops.
                if self.scenario.tenants[t].kind() != TenantKind::BandwidthHeavy {
                    return ActionOutcome::Applied;
                }
                if let Some(rec) = self.recorder.as_mut() {
                    rec.emit(
                        now,
                        TraceEvent::Guardrail {
                            target: t as u32,
                            kind: DecisionKind::IoThrottle,
                            engaged: cap_gbps.is_some(),
                        },
                    );
                    rec.metrics.inc("ctl.guardrail_edges", 1);
                }
                self.throttles[t] = cap_gbps;
                self.sync_fabric(now);
                self.fabric.set_owner_cap(t, cap_gbps);
                self.reschedule_fabric(now);
                if cap_gbps.is_some() {
                    // Bounded window Z (§2.4): auto-expire.
                    let deadline = now + self.scenario.controller.throttle_window_s;
                    self.throttle_deadlines[t] = Some(deadline);
                    self.q.push_at(
                        deadline,
                        Event::ThrottleExpire {
                            tenant: t,
                            deadline_bits: deadline.to_bits(),
                        },
                    );
                } else {
                    self.throttle_deadlines[t] = None;
                }
                ActionOutcome::Applied
            }
            Action::SetMpsQuota { tenant, quota } => {
                let t = tenant.0;
                if t >= self.scenario.n_tenants() {
                    return ActionOutcome::Applied;
                }
                if let TenantRt::Comp(c) = &mut self.rt[t] {
                    c.quota = quota.clamp(0.0, 100.0);
                    if let Some(rec) = self.recorder.as_mut() {
                        rec.emit(
                            now,
                            TraceEvent::Guardrail {
                                target: t as u32,
                                kind: DecisionKind::MpsQuota,
                                engaged: true,
                            },
                        );
                        rec.metrics.inc("ctl.guardrail_edges", 1);
                    }
                }
                ActionOutcome::Applied
            }
            Action::PinCpu { tenant, numa } => {
                if let Some(p) = self.placements.get_mut(tenant.0) {
                    p.numa = numa.min(self.scenario.topo.numa_nodes.len() - 1);
                }
                ActionOutcome::Applied
            }
            Action::ChangeIsolation {
                tenant,
                change,
                relax: _,
            } => {
                if !self.protected(tenant.0) {
                    return ActionOutcome::Applied;
                }
                // Flaky-reconfig gate: inside an open window, each
                // disruptive actuation fails with `fail_prob` (drawn off
                // the dedicated fault stream — workload streams never
                // shift) and successful ones pay the injected latency.
                if let Some((fail_prob, latency_ms)) =
                    self.faults.as_ref().and_then(|f| f.flaky.last().copied())
                {
                    let frt = self.faults.as_mut().expect("flaky window implies fault rt");
                    if frt.rng.chance(fail_prob) {
                        frt.action_failures += 1;
                        return ActionOutcome::Failed {
                            reason: "mig reconfig failed (injected)",
                        };
                    }
                    if latency_ms >= Self::ACTION_TIMEOUT_MS {
                        frt.action_failures += 1;
                        return ActionOutcome::TimedOut;
                    }
                    frt.pending_extra_pause_s = latency_ms / 1000.0;
                }
                self.save_last_good(tenant.0);
                let applied = match change {
                    IsolationChange::Resize { to } => self.resize_tenant(now, tenant.0, to),
                    IsolationChange::MoveExisting { gpu, to } => {
                        self.move_tenant(now, tenant.0, gpu, to, false)
                    }
                    IsolationChange::CreateAndMove { gpu, to } => {
                        self.move_tenant(now, tenant.0, gpu, to, true)
                    }
                };
                if applied || self.faults.is_none() {
                    // Fault-free runs keep the legacy semantics for the
                    // (planner-unreachable) infeasible paths bit-for-bit:
                    // the controller validates and recovers via rollback.
                    ActionOutcome::Applied
                } else {
                    // The change never happened; drop any injected
                    // latency that was staged for its pause.
                    if let Some(f) = self.faults.as_mut() {
                        f.pending_extra_pause_s = 0.0;
                    }
                    ActionOutcome::Failed {
                        reason: "isolation change infeasible",
                    }
                }
            }
            Action::Rollback { tenant } => {
                if !self.protected(tenant.0) {
                    return ActionOutcome::Applied;
                }
                if let Some(saved) = self.last_good.take() {
                    if saved.owner != tenant.0 {
                        // Another tenant's change superseded this
                        // snapshot (cannot happen while the arbiter
                        // serializes validation windows; kept as a
                        // defensive invariant). Restoring it would stomp
                        // the newer change, so keep it for its owner.
                        self.last_good = Some(saved);
                        return ActionOutcome::Applied;
                    }
                    // Blue/green back to the last-known-good placement.
                    // Rollback is modeled reliable — the flaky gate only
                    // covers forward changes; a revert to a known-good
                    // partition layout is the recovery primitive itself.
                    self.gpus = saved.gpus;
                    self.placements = saved.placements;
                    self.pause_tenant(now, tenant.0, self.scenario.move_pause_s);
                }
                ActionOutcome::Applied
            }
        }
    }

    /// Resize = give the protected tenant a dedicated `to` instance on
    /// its current GPU, repartitioning as needed. If it was MPS-shared,
    /// each peer gets the biggest leftover slice. Returns whether the
    /// change actually happened (`false` = the world is unchanged).
    fn resize_tenant(&mut self, now: f64, tenant: usize, to: MigProfile) -> bool {
        let gpu_idx = self.placements[tenant].gpu;
        let old_peers = self.placements[tenant].peers.clone();
        let old_instance = self.placements[tenant].instance;

        let gpu = &mut self.gpus[gpu_idx];
        if gpu.destroy(old_instance).is_err() {
            return false;
        }
        let new_instance = match gpu.create(to) {
            Ok(id) => id,
            Err(_) => {
                // Cannot place: restore by recreating the old instance.
                let old_profile = self.placements[tenant].profile;
                if let Ok(id) = gpu.create(old_profile) {
                    self.placements[tenant].instance = id;
                    for &peer in &old_peers {
                        self.placements[peer].instance = id;
                    }
                }
                return false;
            }
        };
        self.placements[tenant].instance = new_instance;
        self.placements[tenant].profile = to;
        self.placements[tenant].peers.clear();

        // Re-home each displaced peer on the biggest profile that fits.
        for peer in old_peers {
            let profile = [
                MigProfile::P3g40gb,
                MigProfile::P2g20gb,
                MigProfile::P1g10gb,
            ]
            .into_iter()
            .find(|p| !self.gpus[gpu_idx].placements(*p).is_empty());
            if let Some(p) = profile {
                if let Ok(id) = self.gpus[gpu_idx].create(p) {
                    self.placements[peer] = Placement {
                        gpu: gpu_idx,
                        instance: id,
                        profile: p,
                        peers: vec![],
                        numa: self.placements[peer].numa,
                    };
                }
            }
        }

        let d = A100Gpu::reconfig_duration(&mut self.reconfig_rng);
        self.reconfig_durations.push(d);
        let pause = self.bounded_pause(d);
        self.pause_tenant(now, tenant, pause);
        true
    }

    /// Move a protected tenant to `gpu` — onto an existing free instance
    /// (cheap) or a freshly created one (MIG call on the target GPU, but
    /// the pause is still only the process move: creation happens on idle
    /// slices). Returns whether the move actually happened.
    fn move_tenant(
        &mut self,
        now: f64,
        tenant: usize,
        gpu: usize,
        to: MigProfile,
        create: bool,
    ) -> bool {
        let target = if create {
            match self.gpus[gpu].create(to) {
                Ok(id) => {
                    let d = A100Gpu::reconfig_duration(&mut self.reconfig_rng);
                    self.reconfig_durations.push(d);
                    id
                }
                Err(_) => return false,
            }
        } else {
            // Find the free instance with that profile.
            let occupied: Vec<InstanceId> = self
                .placements
                .iter()
                .filter(|p| p.gpu == gpu)
                .map(|p| p.instance)
                .collect();
            let Some(inst) = self.gpus[gpu]
                .instances()
                .iter()
                .find(|i| i.profile == to && !occupied.contains(&i.id))
            else {
                return false;
            };
            inst.id
        };

        // Leaving a shared instance: unlink peers.
        let old_peers = std::mem::take(&mut self.placements[tenant].peers);
        for peer in old_peers {
            self.placements[peer].peers.retain(|&x| x != tenant);
        }

        self.placements[tenant].gpu = gpu;
        self.placements[tenant].instance = target;
        self.placements[tenant].profile = to;
        // CPU affinity follows the GPU's NUMA domain (§2.3 pinning).
        self.placements[tenant].numa = self.scenario.topo.numa_of_gpu(gpu);

        // Make-before-break: instance creation runs on idle slices while
        // the tenant keeps serving; the only tenant-visible cost is the
        // blue/green traffic switchover.
        self.pause_tenant(now, tenant, self.scenario.move_pause_s);
        true
    }

    // --- fault injection -----------------------------------------------------

    /// One timed fault edge fired: mutate world state, bump the fault
    /// counters, and emit the trace twin. Only reachable when a
    /// non-empty plan seeded edges at world build.
    fn on_fault_edge(&mut self, now: f64, idx: usize) {
        let Some(frt) = self.faults.as_ref() else {
            return;
        };
        let edge = frt.edges[idx];
        let spec = self.scenario.faults.specs[edge.spec].clone();
        {
            let frt = self.faults.as_mut().expect("checked above");
            if edge.inject {
                frt.injected += 1;
            } else {
                frt.cleared += 1;
            }
        }
        if let Some(rec) = self.recorder.as_mut() {
            let (kind, subject) = (spec.kind_code(), spec.subject());
            rec.emit(
                now,
                if edge.inject {
                    TraceEvent::FaultInjected { kind, subject }
                } else {
                    TraceEvent::FaultCleared { kind, subject }
                },
            );
            rec.metrics.inc(
                if edge.inject { "faults.injected" } else { "faults.cleared" },
                1,
            );
        }
        match spec {
            FaultSpec::LinkDegrade { link, factor, .. }
            | FaultSpec::LinkFlap { link, factor, .. } => {
                if link >= self.scenario.topo.num_links {
                    return;
                }
                // Re-rate the shared link mid-flow: in-flight transfers
                // keep their remaining bytes and finish at the new rate
                // (the PS solve recomputes from the capacity change).
                let lid = crate::topo::LinkId(link);
                let base = self.scenario.topo.link_capacity(lid);
                let cap = if edge.inject {
                    (base * factor).max(1e-3)
                } else {
                    base
                };
                self.sync_fabric(now);
                self.fabric.set_link_capacity(lid, cap);
                self.reschedule_fabric(now);
            }
            FaultSpec::SliceFail {
                tenant, recovery_s, ..
            } => {
                if tenant >= self.scenario.n_tenants()
                    || self.scenario.tenants[tenant].kind() != TenantKind::LatencySensitive
                {
                    return;
                }
                // Xid-style device loss: the in-flight request fails and
                // re-queues under a fresh id (so the stale `ComputeDone`
                // no-ops instead of completing a dead request), then the
                // tenant pauses for the driver-reset window. Latency
                // keeps the original arrival — the re-run shows up in
                // the tail, exactly like a real retried request.
                let requeued = {
                    let (_, ls) = self.ls_parts(tenant);
                    match ls.computing.take() {
                        Some(old) => match ls.reqs.remove(&old) {
                            Some(mut r) => {
                                r.phase = ReqPhase::Queued;
                                let fresh = ls.next_req;
                                ls.next_req += 1;
                                ls.reqs.insert(fresh, r);
                                ls.compute_queue.push_front(fresh);
                                1
                            }
                            None => 0,
                        },
                        None => 0,
                    }
                };
                if let Some(f) = self.faults.as_mut() {
                    f.requests_requeued += requeued;
                }
                self.pause_tenant(now, tenant, recovery_s);
            }
            FaultSpec::ReconfigFlaky {
                fail_prob,
                latency_ms,
                ..
            } => {
                let f = self.faults.as_mut().expect("checked above");
                if edge.inject {
                    f.flaky.push((fail_prob, latency_ms));
                } else if let Some(pos) =
                    f.flaky.iter().rposition(|&w| w == (fail_prob, latency_ms))
                {
                    f.flaky.remove(pos);
                }
            }
            FaultSpec::SensorDropout { tenant, .. } => {
                let f = self.faults.as_mut().expect("checked above");
                if let Some(d) = f.dropout.get_mut(tenant) {
                    if edge.inject {
                        *d += 1;
                    } else {
                        *d = d.saturating_sub(1);
                    }
                }
            }
            // Cluster-level faults contribute no sim edges (`edges()`
            // skips them), so this arm is unreachable; kept total.
            FaultSpec::WorkerCrash { .. } => {}
        }
    }

    // --- telemetry -----------------------------------------------------------

    /// Allocated-slice efficiency: busy compute slices / allocated compute
    /// slices over tenant instances (the Figure 3b "resource efficiency"
    /// axis — static over-provisioned partitions idle their slices; the
    /// adaptive system sizes slices to demand). Returns the per-GPU
    /// ratios plus the host-wide aggregate.
    fn sm_util_by_gpu(&self) -> (Vec<f64>, f64) {
        let n_gpus = self.scenario.topo.num_gpus;
        let mut allocated = vec![0.0f64; n_gpus];
        let mut busy = vec![0.0f64; n_gpus];
        let mut seen = Vec::new();
        // Occupancy per (gpu, instance): sharers of one instance split its
        // slices evenly (a sharer's `peers` lists only its share target,
        // not its co-sharers, so count occupants directly).
        let occupancy = |gpu: usize, inst: InstanceId| -> f64 {
            self.placements
                .iter()
                .filter(|q| q.gpu == gpu && q.instance == inst)
                .count()
                .max(1) as f64
        };
        for (i, p) in self.placements.iter().enumerate() {
            if !seen.contains(&(p.gpu, p.instance)) {
                seen.push((p.gpu, p.instance));
                allocated[p.gpu] += p.profile.compute_slices() as f64;
            }
            let slices = p.profile.compute_slices() as f64;
            let share = 1.0 / occupancy(p.gpu, p.instance);
            let b = match &self.rt[i] {
                TenantRt::Ls(ls) => {
                    let llm_busy = ls.llm.as_ref().map_or(false, |l| l.stepping);
                    if ls.computing.is_some() || llm_busy {
                        slices * share
                    } else {
                        0.0
                    }
                }
                TenantRt::Bw(bw) => {
                    if self.active[i] && bw.phase == CyclePhase::Transform {
                        slices * share
                    } else {
                        0.0
                    }
                }
                TenantRt::Comp(c) => {
                    if self.active[i] {
                        slices * share * (c.quota / 100.0)
                    } else {
                        0.0
                    }
                }
            };
            busy[p.gpu] += b;
        }
        let per_gpu: Vec<f64> = allocated
            .iter()
            .zip(&busy)
            .map(|(&a, &b)| if a <= 0.0 { 0.0 } else { (b / a).min(1.0) })
            .collect();
        let total_alloc: f64 = allocated.iter().sum();
        let total_busy: f64 = busy.iter().sum();
        let host = if total_alloc <= 0.0 {
            0.0
        } else {
            (total_busy / total_alloc).min(1.0)
        };
        (per_gpu, host)
    }

    fn build_snapshot(&mut self, now: f64) -> SignalSnapshot {
        self.sync_fabric(now);
        let dt = (now - self.last_sample_t).max(1e-9);
        let topo = &self.scenario.topo;
        let n = self.scenario.n_tenants();

        let mut links = Vec::new();
        for l in 0..topo.num_links {
            let c = self.fabric.counters(crate::topo::LinkId(l));
            let gbps = (c.gb_total - self.last_link_gb[l]) / dt;
            let util = (c.util_integral - self.last_link_util_integral[l]) / dt;
            self.last_link_gb[l] = c.gb_total;
            self.last_link_util_integral[l] = c.util_integral;
            links.push(LinkSignal {
                link: crate::topo::LinkId(l),
                utilization: util.clamp(0.0, 1.0),
                gbps,
            });
        }

        let mut tenants = Vec::new();
        for t in 0..n {
            // Sensor dropout: serve the held-last signal flagged stale
            // and skip the live sample entirely — the monitor window and
            // traffic counters keep accumulating, so the first fresh
            // sample after the dropout covers the whole gap.
            let held = match self.faults.as_ref() {
                Some(f) if f.dropout[t] > 0 => f.last_signals[t].clone(),
                _ => None,
            };
            if let Some(mut sig) = held {
                sig.stale = true;
                tenants.push(sig);
                continue;
            }
            let gb = self.fabric.owner_gb(t);
            let gbps = (gb - self.last_owner_gb[t]) / dt;
            self.last_owner_gb[t] = gb;
            let tails = self.monitors[t].sample(now);
            // TTFT window tails for request-granularity LLM tenants
            // (None everywhere else — the controller's TTFT objective
            // falls back to e2e tails when unavailable).
            let ttft = match &mut self.rt[t] {
                TenantRt::Ls(ls) => ls.llm.as_mut().map(|l| l.ttft_monitor.sample(now)),
                _ => None,
            };
            let kind = self.scenario.tenants[t].kind();
            let active = match kind {
                TenantKind::LatencySensitive => true,
                _ => self.active[t],
            };
            // Bandwidth-heavy block I/O is its NVMe-side traffic.
            let nvme_share = if kind == TenantKind::BandwidthHeavy {
                gbps * 0.5
            } else {
                0.0
            };
            let sig = TenantSignal {
                tenant: TenantId(t),
                tails,
                ttft,
                pcie_gbps: gbps,
                block_io_gbps: nvme_share,
                active,
                stale: false,
            };
            if let Some(f) = self.faults.as_mut() {
                f.last_signals[t] = Some(sig.clone());
            }
            tenants.push(sig);
        }

        // SM utilization: time-weighted approximation via current state.
        // Each GPU reports its own busy/allocated ratio; the host-wide
        // aggregate feeds the Figure 3b efficiency metric.
        let (gpu_sm_util, sm_now) = self.sm_util_by_gpu();
        self.sm_util_integral += sm_now;
        self.sm_util_samples += 1;

        let numa_io_gbps: Vec<f64> = topo
            .numa_nodes
            .iter()
            .map(|n| links[n.nvme_link.0].gbps)
            .collect();
        let numa_irq_rate: Vec<f64> = numa_io_gbps
            .iter()
            .zip(topo.numa_nodes.iter())
            .map(|(io, n)| {
                // IRQ rate rises with storage + PCIe traffic in the domain.
                let pcie: f64 = topo
                    .switches
                    .iter()
                    .filter(|s| s.numa == n.id)
                    .map(|s| links[s.link.0].gbps)
                    .sum();
                crate::telemetry::signals::synthetic_irq_rate(*io, pcie)
            })
            .collect();

        self.last_sample_t = now;
        SignalSnapshot {
            t: now,
            dt,
            tenants,
            links,
            gpu_sm_util,
            numa_io_gbps,
            numa_irq_rate,
        }
    }

    fn build_view(&self) -> PlannerView {
        let mut tenants = Vec::new();
        for (i, p) in self.placements.iter().enumerate() {
            tenants.push(TenantView {
                tenant: TenantId(i),
                gpu: p.gpu,
                instance: p.instance,
                profile: p.profile,
                mps_peers: p.peers.iter().map(|&x| TenantId(x)).collect(),
                numa: p.numa,
                mps_quota: self.comp_quota(i),
                io_throttle_gbps: self.throttles[i],
            });
        }
        // Free existing instances anywhere on the host.
        let occupied: Vec<(usize, InstanceId)> = self
            .placements
            .iter()
            .map(|p| (p.gpu, p.instance))
            .collect();
        let mut free_instances = Vec::new();
        for g in &self.gpus {
            for inst in g.instances() {
                if !occupied.contains(&(g.index, inst.id)) {
                    free_instances.push(InstanceView {
                        gpu: g.index,
                        existing: Some(inst.id),
                        profile: inst.profile,
                    });
                }
            }
        }
        PlannerView {
            topo: self.scenario.topo.clone(),
            gpus: self.gpus.clone(),
            tenants,
            free_instances,
            primary_base_rps: self.scenario.primary_spec().arrival_rps,
        }
    }

    fn on_sample(&mut self, now: f64) {
        let primary = self.scenario.primary;
        // Interval length for the net signal series; read before
        // `build_snapshot` bumps `last_sample_t`.
        let signal_dt = now - self.last_sample_t;
        let snap = self.build_snapshot(now);
        if let Some(p) = snap.tenant(TenantId(primary)) {
            self.p99_series.push((now, p.tails.p99_ms));
        }
        // The net fabric advances on the same sample clock as the PCIe
        // fabric whether or not a recorder is attached — identical
        // advance chunking is what keeps recording non-perturbing.
        if self.net.is_some() {
            self.sync_net(now);
        }
        // Flight recorder: the per-Δ signal series. Observation-only — the
        // snapshot is already built, so recording cannot perturb the run.
        if let Some(rec) = self.recorder.as_mut() {
            for ts in &snap.tenants {
                rec.emit(
                    now,
                    TraceEvent::TenantSignal {
                        tenant: ts.tenant.0 as u32,
                        p99_ms: ts.tails.p99_ms,
                        miss_rate: ts.tails.miss_rate,
                        gbps: ts.pcie_gbps,
                        completed: ts.tails.completed,
                    },
                );
            }
            for ls in &snap.links {
                rec.emit(
                    now,
                    TraceEvent::LinkSignal {
                        link: ls.link.0 as u32,
                        gbps: ls.gbps,
                        utilization: ls.utilization,
                    },
                );
            }
            let util = if snap.gpu_sm_util.is_empty() {
                0.0
            } else {
                snap.gpu_sm_util.iter().sum::<f64>() / snap.gpu_sm_util.len() as f64
            };
            rec.emit(now, TraceEvent::SmUtil { util });
            rec.emit(now, TraceEvent::FabricSolves { recomputes: self.fabric.rate_recomputes() });
            // Net-link signal series (cluster scenarios only). These
            // deltas never enter `SignalSnapshot`: the cluster fabric
            // is the first contention domain the controller's levers
            // cannot see. Read-only against the already-synced fabric,
            // so non-perturbation holds.
            if let Some(net) = self.net.as_mut() {
                let dt = if signal_dt > 0.0 { signal_dt } else { f64::INFINITY };
                for l in 0..net.fabric.num_links() {
                    let c = net.fabric.counters(crate::topo::NetLinkId(l));
                    let gbps = (c.gb_total - net.last_gb[l]) / dt;
                    let utilization = (c.util_integral - net.last_util[l]) / dt;
                    net.last_gb[l] = c.gb_total;
                    net.last_util[l] = c.util_integral;
                    rec.emit(
                        now,
                        TraceEvent::NetLinkSignal {
                            link: l as u32,
                            gbps,
                            utilization,
                        },
                    );
                }
            }
            rec.metrics.inc("trace.signal_samples", 1);
        }
        if self.control.is_some() {
            let view = self.build_view();
            let wall = std::time::Instant::now();
            let actions = self
                .control
                .as_mut()
                .unwrap()
                .on_observation(&snap, &view);
            self.controller_wall_s += wall.elapsed().as_secs_f64();
            for a in actions {
                let outcome = self.apply_action(now, a.clone());
                // Close the loop: the control plane learns whether its
                // disruptive change actually landed. `Applied` (and every
                // non-disruptive action) is a no-op for the FSM beyond
                // clearing retry state — legacy runs are byte-identical.
                let fb = self
                    .control
                    .as_mut()
                    .expect("control checked above")
                    .on_action_outcome(now, &a, &outcome);
                match fb {
                    crate::controller::OutcomeFeedback::None => {}
                    crate::controller::OutcomeFeedback::Retried { attempt } => {
                        self.action_retries += 1;
                        if let Some(rec) = self.recorder.as_mut() {
                            let tenant = match &a {
                                Action::ChangeIsolation { tenant, .. }
                                | Action::Rollback { tenant }
                                | Action::SetIoThrottle { tenant, .. }
                                | Action::SetMpsQuota { tenant, .. }
                                | Action::PinCpu { tenant, .. } => tenant.0 as u32,
                            };
                            rec.emit(
                                now,
                                TraceEvent::ActionRetry {
                                    tenant,
                                    attempt: attempt.min(u32::from(u8::MAX)) as u8,
                                    kind: a.decision_kind(),
                                },
                            );
                            rec.metrics.inc("ctl.action_retries", 1);
                        }
                    }
                    crate::controller::OutcomeFeedback::Degraded => {
                        // The degraded-mode audit entry is mirrored into
                        // the trace like every other decision edge.
                        self.action_retries += 1;
                    }
                }
            }
            self.mirror_control_trace(now);
        }
        self.q.push_at(now + self.scenario.sample_dt, Event::Sample);
    }

    /// Mirror control-plane progress into the trace by diffing the audit
    /// logs, FSM phases, and arbitration counters against what was
    /// already emitted. Controllers never see the recorder — that is
    /// what makes non-perturbation structural rather than careful.
    fn mirror_control_trace(&mut self, now: f64) {
        let Some(rec) = self.recorder.as_mut() else {
            return;
        };
        let Some(plane) = self.control.as_ref() else {
            return;
        };
        let ctls = plane.controllers();
        if self.trace_audit_seen.len() < ctls.len() {
            self.trace_audit_seen.resize(ctls.len(), 0);
            self.trace_ctl_phase.resize(ctls.len(), None);
        }
        for (i, c) in ctls.iter().enumerate() {
            let tenant = c.primary().0 as u32;
            let entries = c.audit().entries();
            for e in &entries[self.trace_audit_seen[i]..] {
                rec.emit(
                    e.t,
                    TraceEvent::Decision {
                        tenant,
                        kind: e.action,
                        edge: e.edge,
                        p99_ms: e.p99_ms,
                    },
                );
                rec.metrics.inc("ctl.decisions", 1);
            }
            self.trace_audit_seen[i] = entries.len();
            let phase = match c.state() {
                crate::controller::CtlState::Validating { .. } => Some(CtlPhase::Validating),
                crate::controller::CtlState::Cooldown { .. } => Some(CtlPhase::Cooldown),
                crate::controller::CtlState::Stable => None,
            };
            if self.trace_ctl_phase[i] != phase {
                if let Some(p) = self.trace_ctl_phase[i] {
                    rec.emit(now, TraceEvent::CtlSpan { tenant, phase: p, begin: false });
                }
                if let Some(p) = phase {
                    rec.emit(now, TraceEvent::CtlSpan { tenant, phase: p, begin: true });
                }
                self.trace_ctl_phase[i] = phase;
            }
        }
        let stats = plane.stats();
        let (conflicts, deferrals) = (stats.conflicts, stats.deferrals);
        if (conflicts, deferrals) != self.trace_arb_last {
            self.trace_arb_last = (conflicts, deferrals);
            rec.emit(now, TraceEvent::ArbCounters { conflicts, deferrals });
        }
    }

    /// Build a (snapshot, view) pair from the current world state —
    /// used by benches to measure the controller tick in isolation.
    pub fn sample_for_bench(&mut self) -> (SignalSnapshot, PlannerView) {
        let snap = self.build_snapshot(1.0);
        let view = self.build_view();
        (snap, view)
    }

    // --- main loop -------------------------------------------------------------

    fn handle(&mut self, now: f64, ev: Event) {
        match ev {
            Event::Arrival { tenant } => self.on_arrival(now, tenant),
            Event::FlowsDone { version } => {
                if version != self.fabric_version {
                    return;
                }
                self.sync_fabric(now);
                // Collect every flow that has drained.
                let done: Vec<FlowId> = self
                    .flow_purpose
                    .keys()
                    .copied()
                    .filter(|id| self.fabric.remaining(*id).map(|r| r <= 1e-9).unwrap_or(false))
                    .collect();
                if !done.is_empty() {
                    if let Some(rec) = self.recorder.as_mut() {
                        rec.emit(now, TraceEvent::FlowsDone { flows: done.len() as u32 });
                        rec.metrics.inc("fabric.flow_completions", done.len() as u64);
                    }
                }
                for id in done {
                    self.fabric.remove(id);
                    let purpose = self.flow_purpose.remove(&id).unwrap_or_else(|| {
                        crate::util::invariant::InvariantError::new(
                            "every fabric flow has a recorded purpose",
                            format!(
                                "flow={} t={now:.6}s version={version} tracked_flows={}",
                                id.0,
                                self.flow_purpose.len()
                            ),
                        )
                        .panic()
                    });
                    match purpose {
                        Purpose::Stage { tenant, req } => self.on_stage_done(now, tenant, req),
                        Purpose::H2d { tenant, req } => self.on_h2d_done(now, tenant, req),
                        Purpose::CycleRead { .. }
                        | Purpose::CycleH2d { .. }
                        | Purpose::CycleD2h { .. } => self.on_cycle_flow_done(now, purpose),
                        Purpose::StepSync { .. } => {}
                        Purpose::LlmStepIo { tenant } => {
                            self.on_llm_step_io_done(now, tenant)
                        }
                    }
                }
                self.reschedule_fabric(now);
            }
            Event::NetFlowsDone { version } => {
                let Some(net) = self.net.as_ref() else { return };
                if version != net.version {
                    return;
                }
                self.sync_net(now);
                // Collect every net flow that has drained, drop the
                // fabric borrow, then dispatch — a segment completion
                // may start the next ring step's flows.
                let net = self.net.as_mut().expect("checked above");
                let done: Vec<FlowId> = net
                    .flow_purpose
                    .keys()
                    .copied()
                    .filter(|id| net.fabric.remaining(*id).map(|r| r <= 1e-9).unwrap_or(false))
                    .collect();
                let mut purposes = Vec::with_capacity(done.len());
                for id in &done {
                    net.fabric.remove(*id);
                    let purpose = net.flow_purpose.remove(id).unwrap_or_else(|| {
                        crate::util::invariant::InvariantError::new(
                            "every net flow has a recorded purpose",
                            format!(
                                "flow={} t={now:.6}s version={version} tracked_flows={}",
                                id.0,
                                net.flow_purpose.len()
                            ),
                        )
                        .panic()
                    });
                    purposes.push(purpose);
                }
                if !done.is_empty() {
                    if let Some(rec) = self.recorder.as_mut() {
                        rec.metrics.inc("netfabric.flow_completions", done.len() as u64);
                    }
                }
                for purpose in purposes {
                    match purpose {
                        NetPurpose::RingSegment { tenant } => {
                            self.on_ring_segment_done(now, tenant)
                        }
                    }
                }
                self.reschedule_net(now);
            }
            Event::ComputeDone { tenant, req } => self.on_compute_done(now, tenant, req),
            Event::CycleDone { tenant } => self.on_transform_done(now, tenant),
            Event::StepDone { tenant } => self.on_step_done(now, tenant),
            Event::Toggle { tenant } => {
                self.active[tenant] = self.scenario.tenants[tenant].schedule.active_at(now);
                if self.active[tenant] {
                    match self.scenario.tenants[tenant].kind() {
                        TenantKind::BandwidthHeavy => {
                            // Trigger-driven pipelines wait for the next
                            // trigger instead of starting on the toggle
                            // edge itself.
                            if !self.bw_trigger_driven(tenant) {
                                self.begin_cycle(now, tenant);
                            }
                        }
                        TenantKind::ComputeHeavy => self.begin_step(now, tenant),
                        TenantKind::LatencySensitive => {}
                    }
                }
                // When toggled off mid-cycle the current flows drain and
                // the cycle stops at the next Idle check.
            }
            Event::Sample => self.on_sample(now),
            Event::PauseDone { tenant } => self.on_pause_done(now, tenant),
            Event::LlmStepDone { tenant } => self.on_llm_step_done(now, tenant),
            Event::FaultEdge { idx } => self.on_fault_edge(now, idx),
            Event::ThrottleExpire {
                tenant,
                deadline_bits,
            } => {
                if self.throttle_deadlines[tenant].map(f64::to_bits) == Some(deadline_bits) {
                    self.throttles[tenant] = None;
                    self.throttle_deadlines[tenant] = None;
                    self.sync_fabric(now);
                    self.fabric.set_owner_cap(tenant, None);
                    self.reschedule_fabric(now);
                }
            }
        }
    }

    /// Attach a flight recorder with a preallocated ring of `capacity`
    /// events. Recording is observation-only: the run's fingerprint is
    /// byte-identical with and without it (property-tested).
    pub fn enable_recording(&mut self, capacity: usize) {
        self.recorder = Some(Recorder::new(capacity));
    }

    /// Run to the scenario horizon and aggregate results.
    pub fn run(self) -> RunResult {
        self.run_recorded().0
    }

    /// [`SimWorld::run`], returning the flight recorder (if one was
    /// attached via [`SimWorld::enable_recording`]) alongside the result.
    pub fn run_recorded(mut self) -> (RunResult, Option<Recorder>) {
        let horizon = self.scenario.horizon;
        // Sharded-window accounting (recording only): window edges are
        // detected from the queue's sync-window counter after each pop,
        // so the loop below never touches engine state. The event that
        // opens a window is popped before the edge is visible, so each
        // closing count includes that first event — deterministic, and
        // irrelevant at window granularity.
        let recording = self.recorder.is_some();
        let sharded = matches!(self.q, WorldQueue::Sharded { .. });
        let nshards = match &self.q {
            WorldQueue::Sharded { q, .. } => q.shards(),
            WorldQueue::Single(_) => 1,
        };
        let mut last_windows = 0u64;
        let mut last_popped = vec![0u64; nshards];
        let mut stall_windows = vec![0u64; nshards];
        let mut merge_switches = 0u64;
        let mut last_shard: Option<usize> = None;
        if recording && sharded {
            if let Some(rec) = self.recorder.as_mut() {
                for s in 0..nshards {
                    rec.emit(
                        0.0,
                        TraceEvent::ShardWindow {
                            shard: s as u32,
                            events: 0,
                            begin: true,
                        },
                    );
                }
            }
        }
        while let Some(t) = self.q.peek_time() {
            if t > horizon {
                break;
            }
            let (clock, ev) = self.q.pop().unwrap();
            let now = clock.secs();
            if recording && sharded {
                if let (Some(rec), WorldQueue::Sharded { q, .. }) =
                    (self.recorder.as_mut(), &self.q)
                {
                    let w = q.sync_windows();
                    if w != last_windows {
                        last_windows = w;
                        let popped = q.per_shard_popped().iter();
                        for (s, (&tot, last)) in popped.zip(last_popped.iter_mut()).enumerate() {
                            let delta = tot - *last;
                            *last = tot;
                            if delta == 0 {
                                stall_windows[s] += 1;
                            }
                            rec.emit(
                                now,
                                TraceEvent::ShardWindow {
                                    shard: s as u32,
                                    events: delta,
                                    begin: false,
                                },
                            );
                        }
                        rec.emit(now, TraceEvent::CrossShard { total: q.cross_shard_events() });
                        for s in 0..nshards {
                            rec.emit(
                                now,
                                TraceEvent::ShardWindow {
                                    shard: s as u32,
                                    events: 0,
                                    begin: true,
                                },
                            );
                        }
                    }
                    if let Some(s) = q.current_shard() {
                        if last_shard.is_some() && last_shard != Some(s) {
                            merge_switches += 1;
                        }
                        last_shard = Some(s);
                    }
                }
            }
            self.handle(now, ev);
        }
        // Close the trailing windows, fold the engine/world counters into
        // the registry, and detach the recorder before aggregation.
        let mut recorder = self.recorder.take();
        if let Some(rec) = recorder.as_mut() {
            let (_, per_shard, cross, windows) = self.q.shard_stats();
            if sharded {
                let total: u64 = per_shard.iter().sum();
                for (s, (&tot, &last)) in per_shard.iter().zip(last_popped.iter()).enumerate() {
                    rec.emit(
                        horizon,
                        TraceEvent::ShardWindow {
                            shard: s as u32,
                            events: tot - last,
                            begin: false,
                        },
                    );
                    rec.metrics.inc(&format!("shard{s}.events"), tot);
                    rec.metrics.inc(&format!("shard{s}.stall_windows"), stall_windows[s]);
                    rec.metrics.gauge(
                        &format!("shard{s}.occupancy"),
                        if total > 0 { tot as f64 / total as f64 } else { 0.0 },
                    );
                }
                rec.emit(horizon, TraceEvent::CrossShard { total: cross });
                rec.metrics.inc("engine.cross_shard", cross);
                rec.metrics.inc("engine.sync_windows", windows);
                rec.metrics.inc("engine.merge_switches", merge_switches);
            }
            rec.metrics.inc("sim.events", self.q.events_processed());
            rec.metrics.inc("fabric.rate_recomputes", self.fabric.rate_recomputes());
            rec.metrics.gauge("trace.events", rec.len() as f64);
        }
        let metrics = recorder
            .as_ref()
            .map(|r| r.metrics.snapshot())
            .unwrap_or_default();
        let mut result = self.finish(horizon);
        result.metrics = metrics;
        (result, recorder)
    }

    fn finish(self, horizon: f64) -> RunResult {
        let primary = self.scenario.primary;
        let m = &self.monitors[primary];
        let label = self.scenario.controller.levers.name().to_string();
        let (actions, timeline, moves_per_hour, controller_stats, arb) = match &self.control {
            Some(plane) => {
                // Merge every controller's audit: host-wide action counts
                // and one timeline ordered by decision time (stable, so a
                // single controller's timeline is exactly the pre-arbiter
                // one; same-t entries keep controller order).
                let mut counts: BTreeMap<String, usize> = BTreeMap::new();
                let mut timeline: Vec<(f64, String, f64)> = Vec::new();
                let mut moves = 0.0;
                let mut stats = Vec::new();
                for c in plane.controllers() {
                    let audit = c.audit();
                    let mut my_counts: BTreeMap<String, usize> = BTreeMap::new();
                    for e in audit.entries() {
                        // Deferred proposals never executed; retry and
                        // degraded entries are bookkeeping for an attempt
                        // already counted on its trigger edge.
                        if !matches!(
                            e.edge,
                            DecisionEdge::Defer | DecisionEdge::Retry | DecisionEdge::Degraded
                        ) {
                            *counts.entry(e.action.as_str().to_string()).or_insert(0) += 1;
                            *my_counts.entry(e.action.as_str().to_string()).or_insert(0) += 1;
                        }
                    }
                    timeline.extend(
                        audit
                            .timeline()
                            .into_iter()
                            .map(|(t, k, p)| (t, k.to_string(), p)),
                    );
                    moves += audit.moves_per_hour(horizon);
                    let id = c.primary();
                    stats.push(TenantControllerStats {
                        tenant: id,
                        name: self.scenario.tenants[id.0].name.clone(),
                        tau_ms: c.cfg.tau_ms,
                        actions: my_counts.into_iter().collect(),
                        deferrals: audit.count_edge("defer"),
                    });
                }
                timeline.sort_by(|a, b| a.0.total_cmp(&b.0));
                (
                    counts.into_iter().collect::<Vec<_>>(),
                    timeline,
                    moves,
                    stats,
                    plane.stats(),
                )
            }
            None => (
                Vec::new(),
                Vec::new(),
                0.0,
                Vec::new(),
                crate::controller::ArbStats::default(),
            ),
        };
        let per_tenant: Vec<TenantRunStats> = self
            .scenario
            .tenants
            .iter()
            .enumerate()
            .map(|(i, t)| {
                let mon = &self.monitors[i];
                let (arrivals_emitted, trace_exhausted_at) = match &self.rt[i] {
                    TenantRt::Ls(l) => (l.arrival.emitted(), l.arrival.exhausted_at()),
                    TenantRt::Bw(b) => b
                        .arrival
                        .as_ref()
                        .map(|a| (a.emitted(), a.exhausted_at()))
                        .unwrap_or((0, None)),
                    TenantRt::Comp(_) => (0, None),
                };
                let (ttft_p99, tpot_p99, ttft_slo_miss_rate) = match &self.rt[i] {
                    TenantRt::Ls(l) => match &l.llm {
                        Some(llm) => (
                            Some(llm.ttft_monitor.lifetime_quantile_ms(0.99)),
                            Some(llm.tpot_monitor.lifetime_quantile_ms(0.99)),
                            Some(llm.ttft_monitor.lifetime_miss_rate()),
                        ),
                        None => (None, None, None),
                    },
                    _ => (None, None, None),
                };
                TenantRunStats {
                    tenant: TenantId(i),
                    name: t.name.clone(),
                    kind: t.kind(),
                    slo_ms: t.spec.slo_ms(),
                    completed: mon.total_completed(),
                    miss_rate: mon.lifetime_miss_rate(),
                    p50_ms: mon.lifetime_quantile_ms(0.50),
                    p95_ms: mon.lifetime_quantile_ms(0.95),
                    p99_ms: mon.lifetime_quantile_ms(0.99),
                    p999_ms: mon.lifetime_quantile_ms(0.999),
                    rps: mon.total_completed() as f64 / horizon,
                    gb_moved: self.fabric.owner_gb(i),
                    arrivals_emitted,
                    trace_exhausted_at,
                    ttft_p99,
                    tpot_p99,
                    ttft_slo_miss_rate,
                }
            })
            .collect();
        let link_gb: Vec<f64> = (0..self.scenario.topo.num_links)
            .map(|l| self.fabric.counters(crate::topo::LinkId(l)).gb_total)
            .collect();
        // Cluster net-link totals (empty on single-host scenarios).
        // Deterministic but excluded from the fingerprint, like the
        // engine statistics below.
        let (net_link_gb, net_link_util): (Vec<f64>, Vec<f64>) = match &self.net {
            Some(net) => (0..net.fabric.num_links())
                .map(|l| {
                    let c = net.fabric.counters(crate::topo::NetLinkId(l));
                    (c.gb_total, c.util_integral / horizon)
                })
                .unzip(),
            None => (Vec::new(), Vec::new()),
        };
        let (shards, per_shard_events, cross_shard_events, sync_windows) = self.q.shard_stats();
        let clamped_events = self.q.clamped_events();
        let (faults_injected, faults_cleared, action_failures, requests_requeued) = self
            .faults
            .as_ref()
            .map_or((0, 0, 0, 0), |f| {
                (f.injected, f.cleared, f.action_failures, f.requests_requeued)
            });
        let degraded_controllers = self
            .control
            .as_ref()
            .map_or(0, |p| p.degraded_controllers());
        RunResult {
            label,
            scenario: self.scenario.name.clone(),
            seed: self.scenario.seed,
            horizon_s: horizon,
            miss_rate: m.lifetime_miss_rate(),
            p50_ms: m.lifetime_quantile_ms(0.50),
            p95_ms: m.lifetime_quantile_ms(0.95),
            p99_ms: m.lifetime_quantile_ms(0.99),
            p999_ms: m.lifetime_quantile_ms(0.999),
            mean_ms: m.histogram().mean() / 1000.0,
            completed: m.total_completed(),
            rps: m.total_completed() as f64 / horizon,
            histogram: m.histogram().clone(),
            per_tenant,
            link_gb,
            net_link_gb,
            net_link_util,
            actions,
            moves_per_hour,
            reconfig_durations_s: self.reconfig_durations.clone(),
            controller_cpu_frac: self.controller_wall_s / horizon,
            timeline,
            mean_sm_util: if self.sm_util_samples > 0 {
                self.sm_util_integral / self.sm_util_samples as f64
            } else {
                0.0
            },
            p99_series: self.p99_series,
            controller_stats,
            arb_conflicts: arb.conflicts,
            arb_deferrals: arb.deferrals,
            sim_events: self.q.events_processed(),
            fabric_rate_recomputes: self.fabric.rate_recomputes(),
            shards,
            per_shard_events,
            clamped_events,
            cross_shard_events,
            sync_windows,
            // Filled in by `run_recorded` from the registry snapshot.
            metrics: Vec::new(),
            faults_injected,
            faults_cleared,
            action_failures,
            action_retries: self.action_retries,
            requests_requeued,
            degraded_controllers,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::controller::Levers;
    use crate::tenants::InterferenceSchedule;

    fn short_scenario(seed: u64, levers: Levers) -> Scenario {
        let mut s = Scenario::paper_single_host(seed, levers);
        s.horizon = 120.0;
        s
    }

    #[test]
    fn baseline_run_completes_requests() {
        let r = SimWorld::new(short_scenario(1, Levers::none())).run();
        // ~80 rps * 120 s; allow wide tolerance for in-flight tail.
        assert!(r.completed > 8_500, "completed={}", r.completed);
        assert!(r.p99_ms > r.p50_ms);
        assert!(r.miss_rate >= 0.0 && r.miss_rate <= 1.0);
    }

    #[test]
    fn deterministic_same_seed() {
        let a = SimWorld::new(short_scenario(5, Levers::none())).run();
        let b = SimWorld::new(short_scenario(5, Levers::none())).run();
        assert_eq!(a.completed, b.completed);
        assert_eq!(a.p99_ms, b.p99_ms);
        assert_eq!(a.miss_rate, b.miss_rate);
        assert_eq!(a.fingerprint(), b.fingerprint());
    }

    #[test]
    fn different_seeds_differ() {
        let a = SimWorld::new(short_scenario(5, Levers::none())).run();
        let b = SimWorld::new(short_scenario(6, Levers::none())).run();
        assert_ne!(a.completed, b.completed);
    }

    #[test]
    fn contention_inflates_tail() {
        let mut quiet = short_scenario(2, Levers::none());
        quiet.set_background_schedules(InterferenceSchedule::always_off(120.0));
        let mut noisy = short_scenario(2, Levers::none());
        noisy.set_background_schedules(InterferenceSchedule::always_on(120.0));
        let rq = SimWorld::new(quiet).run();
        let rn = SimWorld::new(noisy).run();
        assert!(
            rn.p99_ms > rq.p99_ms * 1.2,
            "noisy p99 {} vs quiet {}",
            rn.p99_ms,
            rq.p99_ms
        );
    }

    #[test]
    fn controller_acts_under_contention() {
        let mut s = short_scenario(3, Levers::full());
        s.horizon = 600.0;
        s.set_background_schedules(InterferenceSchedule::always_on(600.0));
        let r = SimWorld::new(s).run();
        let total_actions: usize = r.actions.iter().map(|(_, c)| c).sum();
        assert!(total_actions > 0, "controller never acted: {:?}", r.actions);
    }

    #[test]
    fn full_controller_beats_baseline() {
        // The headline direction (E1) on a longer run.
        let mk = |levers| {
            let mut s = Scenario::paper_single_host(11, levers);
            s.horizon = 900.0;
            SimWorld::new(s).run()
        };
        let base = mk(Levers::none());
        let full = mk(Levers::full());
        assert!(
            full.p99_ms < base.p99_ms,
            "full {} !< base {}",
            full.p99_ms,
            base.p99_ms
        );
        assert!(
            full.miss_rate < base.miss_rate,
            "full miss {} !< base {}",
            full.miss_rate,
            base.miss_rate
        );
    }

    #[test]
    fn every_tenant_reports_stats() {
        // Steady contention: backgrounds are active from t=0, so every
        // tenant must produce work within the short horizon.
        let mut s = Scenario::steady_contention(4, Levers::none(), true);
        s.horizon = 120.0;
        let r = SimWorld::new(s).run();
        assert_eq!(r.per_tenant.len(), 3);
        // Primary is latency-sensitive with a real SLO and completions.
        let p = &r.per_tenant[0];
        assert_eq!(p.kind, TenantKind::LatencySensitive);
        assert!(p.slo_ms < f64::MAX);
        assert!(p.completed > 0 && p.p99_ms > 0.0);
        // Background tenants complete cycles/steps and move bytes.
        for t in &r.per_tenant[1..] {
            assert!(t.completed > 0, "{} never completed a unit", t.name);
            assert!(t.gb_moved > 0.0, "{} moved no bytes", t.name);
        }
    }

    #[test]
    fn four_tenant_scenario_runs_and_reports_all() {
        let mut s = Scenario::multi_ls_slo_mix(7, Levers::none());
        s.horizon = 120.0;
        let r = SimWorld::new(s).run();
        assert_eq!(r.per_tenant.len(), 4);
        // Both latency-sensitive services completed requests with their
        // own SLOs.
        let chat = &r.per_tenant[0];
        let batch = &r.per_tenant[1];
        assert!(chat.completed > 5_000, "chat completed {}", chat.completed);
        assert!(batch.completed > 2_000, "batch completed {}", batch.completed);
        assert_eq!(chat.slo_ms, 15.0);
        assert_eq!(batch.slo_ms, 60.0);
        assert!(chat.p99_ms > 0.0 && batch.p99_ms > 0.0);
    }

    #[test]
    fn legacy_single_primary_reports_one_controller() {
        let mut s = short_scenario(3, Levers::full());
        s.horizon = 240.0;
        let r = SimWorld::new(s).run();
        assert_eq!(r.controller_stats.len(), 1);
        assert_eq!(r.controller_stats[0].name, "t1-inference");
        assert_eq!(r.arb_conflicts, 0);
        assert_eq!(r.arb_deferrals, 0);
        // Single-primary fingerprints keep the pre-arbiter format.
        assert!(!r.fingerprint().contains(";arb"));
    }

    #[test]
    fn protect_all_ls_is_noop_for_single_ls_scenarios() {
        // paper_single_host has exactly one latency-sensitive tenant:
        // the multi-primary plane builds the same single controller, so
        // enabling it must not perturb the run at all.
        let mut a = short_scenario(9, Levers::full());
        a.horizon = 600.0;
        let mut b = a.clone();
        b.protect_all_ls = true;
        let ra = SimWorld::new(a).run();
        let rb = SimWorld::new(b).run();
        assert_eq!(ra.fingerprint(), rb.fingerprint());
    }

    #[test]
    fn multi_primary_reports_controller_stats_per_ls_tenant() {
        let mut s = Scenario::multi_ls_slo_mix(7, Levers::full());
        s.horizon = 120.0;
        let r = SimWorld::new(s).run();
        // One controller per latency-sensitive tenant, each against its
        // own τ (the primary keeps the scenario's τ).
        assert_eq!(r.controller_stats.len(), 2);
        assert_eq!(r.controller_stats[0].name, "chat-api");
        assert_eq!(r.controller_stats[0].tau_ms, 15.0);
        assert_eq!(r.controller_stats[1].name, "batch-api");
        assert_eq!(r.controller_stats[1].tau_ms, 60.0);
        // Arbitration counters reconcile with the per-controller audits.
        let deferred: usize = r.controller_stats.iter().map(|c| c.deferrals).sum();
        assert_eq!(deferred as u64, r.arb_deferrals);
    }

    #[test]
    fn trace_run_emits_exactly_trace_len_and_ends_cleanly() {
        use crate::tenants::{ArrivalProcess, TraceSpec};
        let mut s = short_scenario(5, Levers::none());
        // 200 arrivals, one every 250 ms: the trace spans 50 s of the
        // 120 s horizon, so it must exhaust cleanly mid-run.
        let trace = TraceSpec::from_gaps(vec![0.25; 200]).unwrap();
        s.tenants[0].spec.as_ls_mut().unwrap().arrivals =
            Some(ArrivalProcess::Trace(trace));
        let r = SimWorld::new(s).run();
        let t = &r.per_tenant[0];
        assert_eq!(t.arrivals_emitted, 200);
        let end = t.trace_exhausted_at.expect("closed trace must exhaust");
        assert!((end - 50.0).abs() < 1e-9, "exhausted at {end}");
        // Every request drains long before the horizon; nothing wraps.
        assert_eq!(t.completed, 200);
    }

    #[test]
    fn poisson_runs_report_arrival_counters_without_exhaustion() {
        let r = SimWorld::new(short_scenario(1, Levers::none())).run();
        let t = &r.per_tenant[0];
        // Open-loop Poisson: emitted >= completed (tail in flight), and
        // an open-ended process never exhausts.
        assert!(
            t.arrivals_emitted >= t.completed,
            "{} < {}",
            t.arrivals_emitted,
            t.completed
        );
        assert!(t.trace_exhausted_at.is_none());
        // Closed-loop ETL/trainer have no arrival side.
        assert_eq!(r.per_tenant[1].arrivals_emitted, 0);
        assert_eq!(r.per_tenant[2].arrivals_emitted, 0);
    }

    #[test]
    fn trigger_driven_etl_gates_cycles_on_the_trigger_process() {
        use crate::tenants::ArrivalProcess;
        let mut closed = short_scenario(2, Levers::none());
        closed.set_background_schedules(InterferenceSchedule::always_on(120.0));
        let mut gated = closed.clone();
        // Sparse Poisson triggers: ~1 cycle every 5 s, far slower than
        // the closed loop's back-to-back cycling.
        gated.tenants[1].spec.as_bw_mut().unwrap().arrivals =
            Some(ArrivalProcess::Poisson { rps: 0.2 });
        let rc = SimWorld::new(closed).run();
        let rg = SimWorld::new(gated).run();
        let (c, g) = (rc.per_tenant[1].completed, rg.per_tenant[1].completed);
        assert!(c > 0 && g > 0, "closed {c}, gated {g}");
        assert!(g * 2 < c, "gating did not slow the cycle loop: {g} vs {c}");
        let emitted = rg.per_tenant[1].arrivals_emitted;
        assert!(emitted > 0, "no triggers emitted");
        assert!(g <= emitted, "more cycles ({g}) than triggers ({emitted})");
        // The closed-loop run's cycle stream is untouched by the new
        // trigger plumbing (its own fingerprint is pinned elsewhere; the
        // counter here just confirms the legacy path reports zero).
        assert_eq!(rc.per_tenant[1].arrivals_emitted, 0);
    }

    #[test]
    fn six_tenant_hotspot_runs_deterministically() {
        let mk = || {
            let mut s = Scenario::pcie_hotspot(9, Levers::none());
            s.horizon = 90.0;
            SimWorld::new(s).run()
        };
        let a = mk();
        let b = mk();
        assert_eq!(a.per_tenant.len(), 6);
        assert_eq!(a.fingerprint(), b.fingerprint());
    }

    #[test]
    fn cluster_free_worlds_report_no_net_links() {
        let r = SimWorld::new(short_scenario(1, Levers::none())).run();
        assert!(r.net_link_gb.is_empty());
        assert!(r.net_link_util.is_empty());
    }

    #[test]
    fn ring_trainer_moves_bytes_over_the_net_fabric() {
        let mut s = Scenario::fat_tree_allreduce_mix(3, Levers::none());
        s.horizon = 120.0;
        let r = SimWorld::new(s).run();
        // fat_tree(4): 8 hosts * 4 endpoint links + 2 trunk directions
        // per (leaf, spine) pair = 32 + 16.
        assert_eq!(r.net_link_gb.len(), 48);
        assert_eq!(r.net_link_util.len(), 48);
        let total: f64 = r.net_link_gb.iter().sum();
        assert!(total > 0.0, "ring trainer moved no net bytes");
        for (l, u) in r.net_link_util.iter().enumerate() {
            assert!(
                (0.0..=1.0 + 1e-12).contains(u),
                "net link {l} utilization {u} out of range"
            );
        }
        // The trainer completed steps (each gated on its allreduce) and
        // still gradient-syncs over PCIe afterwards.
        let trainer = r
            .per_tenant
            .iter()
            .find(|t| t.name == "ring-train")
            .expect("trainer present");
        assert!(trainer.completed > 0, "trainer never finished a step");
        assert!(trainer.gb_moved > 0.0, "trainer never gradient-synced");
    }

    #[test]
    fn cluster_scenarios_run_deterministically() {
        for name in ["fat_tree_allreduce_mix", "spine_hotspot"] {
            let mk = || {
                let mut s = Scenario::by_name(name, 7, Levers::none()).unwrap();
                s.horizon = 120.0;
                SimWorld::new(s).run()
            };
            let a = mk();
            let b = mk();
            assert_eq!(a.fingerprint(), b.fingerprint(), "{name} not deterministic");
            assert_eq!(a.net_link_gb, b.net_link_gb, "{name} net bytes differ");
        }
    }

    #[test]
    fn spine_hotspot_rings_collide_on_the_spine() {
        let mut s = Scenario::spine_hotspot(11, Levers::none());
        s.horizon = 120.0;
        let r = SimWorld::new(s).run();
        let cluster = crate::topo::ClusterTopology::leaf_spine(2, 2, 2);
        // Both rings route every segment through spine 1; spine 0's
        // trunks must stay cold while spine 1 carries everything.
        let mut spine = [0.0f64; 2];
        for sp in 0..2 {
            for leaf in 0..2 {
                spine[sp] += r.net_link_gb[cluster.up(leaf, sp).0];
                spine[sp] += r.net_link_gb[cluster.down(sp, leaf).0];
            }
        }
        assert_eq!(spine[0], 0.0, "spine 0 should be idle");
        assert!(spine[1] > 0.0, "spine 1 should carry both rings");
    }
}
