//! The simulated single-host testbed (discrete-event world).
//!
//! Reproduces the paper's §3.1 setup: one p4d-style host running
//! T1 (latency-sensitive inference), T2 (bandwidth-heavy ETL) and
//! T3 (compute-heavy training), with the controller sampling signals
//! every Δ and acting through the §2.2 decision space.
//!
//! Interference channels (all emergent, none scripted):
//! * T2's NVMe reads + H2D/D2H bursts share the PS fabric with T1's
//!   staging + H2D transfers (PCIe + NUMA I/O contention).
//! * T3, when MPS-co-scheduled on T1's MIG instance (the naive-placement
//!   baseline), inflates T1's compute service times.
//! * Controller actions have real costs: MIG reconfigs pause T1 for
//!   ~18 s (Table 4), moves pause for ~2 s; paused requests queue and
//!   their waiting time lands in the latency distribution.
//!
//! The T1 request pipeline: host staging read (NUMA NVMe link) → H2D
//! (PCIe uplink of its GPU) → FIFO compute on its MIG instance → done;
//! latency = c_i·(μ_ref/μ(m))·contention·ε + transfer components — exactly
//! the §2.5.1 decomposition with the PS model supplying b_i(t).

use std::collections::{BTreeMap, VecDeque};

use crate::controller::{Action, Controller, IsolationChange, PlannerView};
use crate::controller::view::{InstanceView, TenantView};
use crate::fabric::{Fabric, FlowId};
use crate::gpu::{A100Gpu, InstanceId, MigProfile};
use crate::sim::EventQueue;
use crate::telemetry::signals::{LinkSignal, SignalSnapshot, TenantSignal};
use crate::telemetry::TenantMonitor;
use crate::tenants::spec::{T1, T2, T3};
use crate::tenants::TenantId;
use crate::util::rng::Pcg64;

use super::result::RunResult;
use super::scenario::Scenario;

const N_TENANTS: usize = 3;

/// What a completing fabric flow was doing.
#[derive(Clone, Copy, Debug)]
enum Purpose {
    T1Stage(u64),
    T1H2d(u64),
    T2Read,
    T2H2d,
    T2D2h,
    T3Sync,
}

/// T1 request lifecycle state.
#[derive(Clone, Copy, Debug)]
enum ReqPhase {
    Staging,
    H2d,
    Queued,
    Computing,
}

#[derive(Clone, Copy, Debug)]
struct ReqState {
    arrival: f64,
    stage_gb: f64,
    h2d_gb: f64,
    compute_ref_ms: f64,
    phase: ReqPhase,
}

/// Placement record per tenant.
#[derive(Clone, Debug)]
struct Placement {
    gpu: usize,
    instance: InstanceId,
    profile: MigProfile,
    /// Tenant indices sharing the instance via MPS.
    peers: Vec<usize>,
    numa: usize,
}

/// Saved last-known-good config for rollback.
#[derive(Clone, Debug)]
struct SavedConfig {
    gpus: Vec<A100Gpu>,
    placements: Vec<Placement>,
}

#[derive(Clone, Copy, Debug, PartialEq)]
enum T2Phase {
    Read,
    H2d,
    Transform,
    D2h,
    Idle,
}

/// Discrete events.
#[derive(Clone, Copy, Debug)]
enum Event {
    T1Arrival,
    FlowsDone { version: u64 },
    T1ComputeDone { req: u64 },
    T2TransformDone,
    T3StepDone,
    ToggleT2,
    ToggleT3,
    Sample,
    PauseDone,
    ThrottleExpire { deadline_bits: u64 },
}

/// The world.
pub struct SimWorld {
    pub scenario: Scenario,
    q: EventQueue<Event>,
    fabric: Fabric,
    fabric_synced_at: f64,
    fabric_version: u64,
    flow_purpose: BTreeMap<FlowId, Purpose>,
    gpus: Vec<A100Gpu>,
    placements: Vec<Placement>,

    // RNG streams (workload streams independent of controller decisions).
    arrival_rng: Pcg64,
    size_rng: Pcg64,
    service_rng: Pcg64,
    t2_rng: Pcg64,
    t3_rng: Pcg64,
    reconfig_rng: Pcg64,

    // T1 state.
    next_req: u64,
    reqs: BTreeMap<u64, ReqState>,
    compute_queue: VecDeque<u64>,
    computing: Option<u64>,
    paused: bool,
    pause_backlog: Vec<u64>,
    /// Staging transfers waiting for a DMA slot (bounded I/O depth keeps
    /// post-pause backlog drains from exploding the PS flow set).
    stage_pending: VecDeque<u64>,
    t1_inflight_transfers: usize,

    // T2 state.
    t2_active: bool,
    t2_phase: T2Phase,
    t2_cycle: (f64, f64, f64, f64),
    t2_throttle: Option<f64>,
    t2_throttle_deadline: Option<f64>,

    // T3 state.
    t3_active: bool,
    t3_stepping: bool,
    t3_quota: f64,

    // Telemetry.
    monitors: Vec<TenantMonitor>,
    last_link_gb: Vec<f64>,
    last_link_util_integral: Vec<f64>,
    last_owner_gb: Vec<f64>,
    last_sample_t: f64,
    sm_util_integral: f64,
    sm_util_samples: u64,
    p99_series: Vec<(f64, f64)>,

    // Controller + bookkeeping.
    controller: Option<Controller>,
    controller_wall_s: f64,
    last_good: Option<SavedConfig>,
    reconfig_durations: Vec<f64>,
}

impl SimWorld {
    /// Build the baseline world: GPU0 = [4g.40gb: T1+T3 via MPS,
    /// 3g.40gb: T2], spare 2g.20gb on GPU4 (other switch + other NUMA —
    /// the static layout's idle headroom the placement lever can use).
    pub fn new(scenario: Scenario) -> SimWorld {
        let seed = scenario.seed;
        let mut gpus: Vec<A100Gpu> = (0..scenario.topo.num_gpus).map(A100Gpu::new).collect();
        let shared = gpus[0].create_at(MigProfile::P4g40gb, 0).expect("4g@0");
        let t2_inst = gpus[0].create_at(MigProfile::P3g40gb, 4).expect("3g@4");
        // Static spare: pre-provisioned but unused. GPU1 sits under the
        // SAME PCIe switch as GPU0 (p4d pairs GPUs per switch), so a pure
        // placement move escapes the MPS co-scheduling but not the PCIe /
        // NUMA pressure — only dynamic MIG (create on a clean GPU) or
        // guardrails address those.
        let _spare = gpus[1].create_at(MigProfile::P3g40gb, 0).expect("3g@0 gpu1");

        let placements = vec![
            Placement {
                gpu: 0,
                instance: shared,
                profile: MigProfile::P4g40gb,
                peers: vec![2],
                numa: 0,
            },
            Placement {
                gpu: 0,
                instance: t2_inst,
                profile: MigProfile::P3g40gb,
                peers: vec![],
                numa: 0,
            },
            Placement {
                gpu: 0,
                instance: shared,
                profile: MigProfile::P4g40gb,
                peers: vec![0],
                numa: 0,
            },
        ];

        let fabric = Fabric::new(&scenario.topo);
        let n_links = scenario.topo.num_links;
        let monitors = vec![
            TenantMonitor::new(scenario.t1.slo_ms, 4096),
            TenantMonitor::new(f64::MAX, 64),
            TenantMonitor::new(f64::MAX, 64),
        ];
        let controller = scenario
            .controller
            .levers
            .any()
            .then(|| Controller::new(scenario.controller.clone()));

        let mut w = SimWorld {
            q: EventQueue::new(),
            fabric,
            fabric_synced_at: 0.0,
            fabric_version: 0,
            flow_purpose: BTreeMap::new(),
            gpus,
            placements,
            arrival_rng: Pcg64::new(seed, 1),
            size_rng: Pcg64::new(seed, 2),
            service_rng: Pcg64::new(seed, 3),
            t2_rng: Pcg64::new(seed, 4),
            t3_rng: Pcg64::new(seed, 5),
            reconfig_rng: Pcg64::new(seed, 6),
            next_req: 0,
            reqs: BTreeMap::new(),
            compute_queue: VecDeque::new(),
            computing: None,
            paused: false,
            pause_backlog: Vec::new(),
            stage_pending: VecDeque::new(),
            t1_inflight_transfers: 0,
            t2_active: false,
            t2_phase: T2Phase::Idle,
            t2_cycle: (0.0, 0.0, 0.0, 0.0),
            t2_throttle: None,
            t2_throttle_deadline: None,
            t3_active: false,
            t3_stepping: false,
            t3_quota: 100.0,
            monitors,
            last_link_gb: vec![0.0; n_links],
            last_link_util_integral: vec![0.0; n_links],
            last_owner_gb: vec![0.0; N_TENANTS],
            last_sample_t: 0.0,
            sm_util_integral: 0.0,
            sm_util_samples: 0,
            p99_series: Vec::new(),
            controller,
            controller_wall_s: 0.0,
            last_good: None,
            reconfig_durations: Vec::new(),
            scenario,
        };
        w.seed_events();
        w
    }

    fn seed_events(&mut self) {
        let gap = self.scenario.t1.next_gap(&mut self.arrival_rng);
        self.q.push_at(gap, Event::T1Arrival);
        for p in &self.scenario.t2_schedule.phases.clone() {
            self.q.push_at(p.on, Event::ToggleT2);
            self.q.push_at(p.off, Event::ToggleT2);
        }
        for p in &self.scenario.t3_schedule.phases.clone() {
            self.q.push_at(p.on, Event::ToggleT3);
            self.q.push_at(p.off, Event::ToggleT3);
        }
        let dt = self.scenario.sample_dt;
        self.q.push_at(dt, Event::Sample);
    }

    // --- fabric helpers ---------------------------------------------------

    fn sync_fabric(&mut self, now: f64) {
        let dt = now - self.fabric_synced_at;
        if dt > 0.0 {
            self.fabric.advance(dt);
            self.fabric_synced_at = now;
        }
    }

    fn reschedule_fabric(&mut self, now: f64) {
        self.fabric_version += 1;
        if let Some((dt, _)) = self.fabric.next_completion() {
            self.q.push_at(
                now + dt.max(0.0),
                Event::FlowsDone {
                    version: self.fabric_version,
                },
            );
        }
    }

    fn start_flow(&mut self, now: f64, link: crate::topo::LinkId, gb: f64, owner: usize, purpose: Purpose) {
        self.sync_fabric(now);
        let cap = if owner == 1 { self.t2_throttle } else { None };
        let id = self.fabric.start(link, gb.max(1e-6), 1.0, cap, owner);
        self.flow_purpose.insert(id, purpose);
        self.reschedule_fabric(now);
    }

    // --- T1 pipeline --------------------------------------------------------

    fn t1_links(&self) -> (crate::topo::LinkId, crate::topo::LinkId) {
        let p = &self.placements[0];
        let pcie = self.scenario.topo.link_of_gpu(p.gpu);
        let nvme = self.scenario.topo.numa_nodes[p.numa].nvme_link;
        (nvme, pcie)
    }

    fn on_t1_arrival(&mut self, now: f64) {
        // Schedule next arrival first (open-loop Poisson).
        let gap = self.scenario.t1.next_gap(&mut self.arrival_rng);
        self.q.push_at(now + gap, Event::T1Arrival);

        let id = self.next_req;
        self.next_req += 1;
        let r = self.scenario.t1.sample(&mut self.size_rng, id, now);
        self.reqs.insert(
            id,
            ReqState {
                arrival: now,
                stage_gb: r.host_stage_gb,
                h2d_gb: r.h2d_gb,
                compute_ref_ms: r.compute_ref_ms,
                phase: ReqPhase::Staging,
            },
        );
        if self.paused {
            self.pause_backlog.push(id);
            return;
        }
        self.begin_staging(now, id);
    }

    /// Bounded transfer concurrency (DMA engines / io_uring depth): also
    /// keeps post-pause backlog drains from creating thousands of PS flows.
    const MAX_INFLIGHT: usize = 8;

    fn begin_staging(&mut self, now: f64, id: u64) {
        if self.t1_inflight_transfers >= Self::MAX_INFLIGHT {
            self.stage_pending.push_back(id);
            return;
        }
        self.t1_inflight_transfers += 1;
        let (nvme, _) = self.t1_links();
        let gb = self.reqs[&id].stage_gb;
        self.start_flow(now, nvme, gb, 0, Purpose::T1Stage(id));
    }

    fn on_t1_stage_done(&mut self, now: f64, id: u64) {
        if let Some(r) = self.reqs.get_mut(&id) {
            r.phase = ReqPhase::H2d;
        }
        let (_, pcie) = self.t1_links();
        let gb = self.reqs[&id].h2d_gb;
        self.start_flow(now, pcie, gb, 0, Purpose::T1H2d(id));
    }

    fn on_t1_h2d_done(&mut self, now: f64, id: u64) {
        if let Some(r) = self.reqs.get_mut(&id) {
            r.phase = ReqPhase::Queued;
        }
        self.t1_inflight_transfers = self.t1_inflight_transfers.saturating_sub(1);
        if !self.paused {
            if let Some(next) = self.stage_pending.pop_front() {
                self.begin_staging(now, next);
            }
        }
        self.compute_queue.push_back(id);
        self.maybe_start_compute(now);
    }

    fn t1_service_s(&mut self, work_ref_ms: f64) -> f64 {
        let p = &self.placements[0];
        let mu = p.profile.mu() / self.scenario.mu_ref_profile.mu();
        // MPS-shared peer active => SM contention inflation.
        let shared_with_active_t3 = p.peers.contains(&2) && self.t3_active;
        let contention = if shared_with_active_t3 {
            let mut t3 = self.scenario.t3.clone();
            t3.mps_quota = self.t3_quota;
            t3.contention_factor()
        } else {
            1.0
        };
        let eps = self.service_rng.lognormal(0.0, self.scenario.epsilon_sigma);
        (work_ref_ms / 1000.0) / mu * contention * eps
    }

    fn maybe_start_compute(&mut self, now: f64) {
        if self.computing.is_some() || self.paused {
            return;
        }
        let Some(id) = self.compute_queue.pop_front() else {
            return;
        };
        let work = self.reqs[&id].compute_ref_ms;
        let st = self.t1_service_s(work);
        if let Some(r) = self.reqs.get_mut(&id) {
            r.phase = ReqPhase::Computing;
        }
        self.computing = Some(id);
        self.q.push_at(now + st, Event::T1ComputeDone { req: id });
    }

    fn on_t1_compute_done(&mut self, now: f64, id: u64) {
        if self.computing != Some(id) {
            return; // stale event after rollback/pause rebuild
        }
        self.computing = None;
        if let Some(r) = self.reqs.remove(&id) {
            let latency_ms = (now - r.arrival) * 1000.0;
            self.monitors[0].observe(latency_ms);
        }
        self.maybe_start_compute(now);
    }

    // --- T2 ETL cycle -------------------------------------------------------

    fn t2_links(&self) -> (crate::topo::LinkId, crate::topo::LinkId) {
        let p = &self.placements[1];
        let pcie = self.scenario.topo.link_of_gpu(p.gpu);
        let nvme = self.scenario.topo.numa_nodes[p.numa].nvme_link;
        (nvme, pcie)
    }

    fn t2_begin_cycle(&mut self, now: f64) {
        if !self.t2_active || self.t2_phase != T2Phase::Idle {
            return;
        }
        self.t2_cycle = self.scenario.t2.sample_cycle(&mut self.t2_rng);
        self.t2_phase = T2Phase::Read;
        let (nvme, _) = self.t2_links();
        let gb = self.t2_cycle.0;
        self.start_flow(now, nvme, gb, 1, Purpose::T2Read);
    }

    fn on_t2_flow_done(&mut self, now: f64, which: Purpose) {
        match which {
            Purpose::T2Read => {
                self.t2_phase = T2Phase::H2d;
                let (_, pcie) = self.t2_links();
                let gb = self.t2_cycle.1;
                self.start_flow(now, pcie, gb, 1, Purpose::T2H2d);
            }
            Purpose::T2H2d => {
                self.t2_phase = T2Phase::Transform;
                self.q.push_at(now + self.t2_cycle.3, Event::T2TransformDone);
            }
            Purpose::T2D2h => {
                self.t2_phase = T2Phase::Idle;
                self.t2_begin_cycle(now); // next cycle if still active
            }
            _ => unreachable!(),
        }
    }

    fn on_t2_transform_done(&mut self, now: f64) {
        if self.t2_phase != T2Phase::Transform {
            return;
        }
        self.t2_phase = T2Phase::D2h;
        let (_, pcie) = self.t2_links();
        let gb = self.t2_cycle.2;
        self.start_flow(now, pcie, gb, 1, Purpose::T2D2h);
    }

    // --- T3 training loop ---------------------------------------------------

    fn t3_begin_step(&mut self, now: f64) {
        if !self.t3_active || self.t3_stepping {
            return;
        }
        self.t3_stepping = true;
        let (step_s, _sync) = self.scenario.t3.sample_step(&mut self.t3_rng);
        self.q.push_at(now + step_s, Event::T3StepDone);
    }

    fn on_t3_step_done(&mut self, now: f64) {
        self.t3_stepping = false;
        if self.t3_active {
            // Gradient sync over the PCIe uplink of T3's GPU.
            let p = &self.placements[2];
            let link = self.scenario.topo.link_of_gpu(p.gpu);
            let (_s, sync_gb) = self.scenario.t3.sample_step(&mut self.t3_rng);
            self.start_flow(now, link, sync_gb, 2, Purpose::T3Sync);
            self.t3_begin_step(now);
        }
    }

    // --- controller actuation ------------------------------------------------

    fn save_last_good(&mut self) {
        self.last_good = Some(SavedConfig {
            gpus: self.gpus.clone(),
            placements: self.placements.clone(),
        });
    }

    fn pause_t1(&mut self, now: f64, duration: f64) {
        self.paused = true;
        // In-flight compute finishes (we let the scheduled event stand);
        // queued/incoming requests wait for PauseDone.
        self.q.push_at(now + duration, Event::PauseDone);
    }

    /// Tenant-visible pause for a MIG reconfiguration. The full
    /// `nvidia-smi mig` wall time (18±6 s, Table 4) is logged separately;
    /// the tenant itself is only down for the bounded checkpoint/restore
    /// window at the end of the operation (§5: "we limit frequency and
    /// bound pauses") — new instances are created make-before-break on
    /// free slices while the old one keeps serving.
    fn bounded_pause(&self, reconfig_wall_s: f64) -> f64 {
        (0.12 * reconfig_wall_s).clamp(0.5, 2.5)
    }

    fn on_pause_done(&mut self, now: f64) {
        self.paused = false;
        // Pending transfers (pre-pause) keep FIFO priority over the
        // requests that arrived during the pause.
        let mut work: Vec<u64> = self.stage_pending.drain(..).collect();
        work.extend(self.pause_backlog.drain(..));
        for id in work {
            self.begin_staging(now, id); // cap re-queues the excess
        }
        self.maybe_start_compute(now);
    }

    /// Apply one controller action to the world.
    fn apply_action(&mut self, now: f64, action: Action) {
        match action {
            Action::SetIoThrottle { tenant, cap_gbps } => {
                if tenant == T2 {
                    self.t2_throttle = cap_gbps;
                    self.sync_fabric(now);
                    self.fabric.set_owner_cap(1, cap_gbps);
                    self.reschedule_fabric(now);
                    if cap_gbps.is_some() {
                        // Bounded window Z (§2.4): auto-expire.
                        let deadline = now + self.scenario.controller.throttle_window_s;
                        self.t2_throttle_deadline = Some(deadline);
                        self.q.push_at(
                            deadline,
                            Event::ThrottleExpire {
                                deadline_bits: deadline.to_bits(),
                            },
                        );
                    } else {
                        self.t2_throttle_deadline = None;
                    }
                }
            }
            Action::SetMpsQuota { tenant, quota } => {
                if tenant == T3 {
                    self.t3_quota = quota.clamp(0.0, 100.0);
                }
            }
            Action::PinCpu { tenant, numa } => {
                if let Some(p) = self.placements.get_mut(tenant.0) {
                    p.numa = numa.min(self.scenario.topo.numa_nodes.len() - 1);
                }
            }
            Action::ChangeIsolation { tenant, change, relax: _ } => {
                if tenant != T1 {
                    return;
                }
                self.save_last_good();
                match change {
                    IsolationChange::Resize { to } => self.resize_t1(now, to),
                    IsolationChange::MoveExisting { gpu, to } => self.move_t1(now, gpu, to, false),
                    IsolationChange::CreateAndMove { gpu, to } => self.move_t1(now, gpu, to, true),
                }
            }
            Action::Rollback { tenant } => {
                if tenant != T1 {
                    return;
                }
                if let Some(saved) = self.last_good.take() {
                    // Blue/green back to the last-known-good placement.
                    self.gpus = saved.gpus;
                    self.placements = saved.placements;
                    self.pause_t1(now, self.scenario.move_pause_s);
                }
            }
        }
    }

    /// Resize = give T1 a dedicated `to` instance on its current GPU,
    /// repartitioning as needed. If T1 was MPS-shared, the peer (T3) gets
    /// the biggest leftover slice.
    fn resize_t1(&mut self, now: f64, to: MigProfile) {
        let gpu_idx = self.placements[0].gpu;
        let was_shared = !self.placements[0].peers.is_empty();
        let old_instance = self.placements[0].instance;

        let gpu = &mut self.gpus[gpu_idx];
        if gpu.destroy(old_instance).is_err() {
            return;
        }
        let new_t1 = match gpu.create(to) {
            Ok(id) => id,
            Err(_) => {
                // Cannot place: restore by recreating the old instance.
                let old_profile = self.placements[0].profile;
                if let Ok(id) = gpu.create(old_profile) {
                    self.placements[0].instance = id;
                    if was_shared {
                        self.placements[2].instance = id;
                    }
                }
                return;
            }
        };
        self.placements[0].instance = new_t1;
        self.placements[0].profile = to;
        self.placements[0].peers.clear();

        if was_shared {
            // Re-home T3 on the biggest profile that still fits.
            let t3_profile = [
                MigProfile::P3g40gb,
                MigProfile::P2g20gb,
                MigProfile::P1g10gb,
            ]
            .into_iter()
            .find(|p| !self.gpus[gpu_idx].placements(*p).is_empty());
            if let Some(p) = t3_profile {
                if let Ok(id) = self.gpus[gpu_idx].create(p) {
                    self.placements[2] = Placement {
                        gpu: gpu_idx,
                        instance: id,
                        profile: p,
                        peers: vec![],
                        numa: self.placements[2].numa,
                    };
                }
            }
        }

        let d = A100Gpu::reconfig_duration(&mut self.reconfig_rng);
        self.reconfig_durations.push(d);
        let pause = self.bounded_pause(d);
        self.pause_t1(now, pause);
    }

    /// Move T1 to `gpu` — onto an existing free instance (cheap) or a
    /// freshly created one (MIG call on the target GPU, but T1's pause is
    /// still only the process move: creation happens on idle slices).
    fn move_t1(&mut self, now: f64, gpu: usize, to: MigProfile, create: bool) {
        let target = if create {
            match self.gpus[gpu].create(to) {
                Ok(id) => {
                    let d = A100Gpu::reconfig_duration(&mut self.reconfig_rng);
                    self.reconfig_durations.push(d);
                    id
                }
                Err(_) => return,
            }
        } else {
            // Find the free instance with that profile.
            let occupied: Vec<InstanceId> = self
                .placements
                .iter()
                .filter(|p| p.gpu == gpu)
                .map(|p| p.instance)
                .collect();
            let Some(inst) = self.gpus[gpu]
                .instances()
                .iter()
                .find(|i| i.profile == to && !occupied.contains(&i.id))
            else {
                return;
            };
            inst.id
        };

        // Leaving a shared instance: unlink peers.
        let old_peers = std::mem::take(&mut self.placements[0].peers);
        for peer in old_peers {
            self.placements[peer].peers.retain(|&x| x != 0);
        }

        self.placements[0].gpu = gpu;
        self.placements[0].instance = target;
        self.placements[0].profile = to;
        // CPU affinity follows the GPU's NUMA domain (§2.3 pinning).
        self.placements[0].numa = self.scenario.topo.numa_of_gpu(gpu);

        // Make-before-break: instance creation runs on idle slices while
        // the tenant keeps serving; the only tenant-visible cost is the
        // blue/green traffic switchover.
        self.pause_t1(now, self.scenario.move_pause_s);
    }

    // --- telemetry -----------------------------------------------------------

    /// Allocated-slice efficiency: busy compute slices / allocated compute
    /// slices across all tenant instances (the Figure 3b "resource
    /// efficiency" axis — static over-provisioned partitions idle their
    /// slices; the adaptive system sizes slices to demand).
    fn instantaneous_sm_util(&self) -> f64 {
        let mut allocated = 0.0f64;
        let mut busy = 0.0f64;
        let mut seen = Vec::new();
        for (idx, p) in self.placements.iter().enumerate() {
            if !seen.contains(&(p.gpu, p.instance)) {
                seen.push((p.gpu, p.instance));
                allocated += p.profile.compute_slices() as f64;
            }
            let slices = p.profile.compute_slices() as f64;
            match idx {
                0 => {
                    if self.computing.is_some() {
                        // Shared instances split between peers.
                        busy += if p.peers.is_empty() { slices } else { slices / 2.0 };
                    }
                }
                1 => {
                    if self.t2_active && self.t2_phase == T2Phase::Transform {
                        busy += slices;
                    }
                }
                _ => {
                    if self.t3_active {
                        let share = if p.peers.is_empty() { 1.0 } else { 0.5 };
                        busy += slices * share * (self.t3_quota / 100.0);
                    }
                }
            }
        }
        if allocated <= 0.0 {
            0.0
        } else {
            (busy / allocated).min(1.0)
        }
    }

    fn build_snapshot(&mut self, now: f64) -> SignalSnapshot {
        self.sync_fabric(now);
        let dt = (now - self.last_sample_t).max(1e-9);
        let topo = &self.scenario.topo;

        let mut links = Vec::new();
        for l in 0..topo.num_links {
            let c = self.fabric.counters(crate::topo::LinkId(l));
            let gbps = (c.gb_total - self.last_link_gb[l]) / dt;
            let util = (c.util_integral - self.last_link_util_integral[l]) / dt;
            self.last_link_gb[l] = c.gb_total;
            self.last_link_util_integral[l] = c.util_integral;
            links.push(LinkSignal {
                link: crate::topo::LinkId(l),
                utilization: util.clamp(0.0, 1.0),
                gbps,
            });
        }

        let mut tenants = Vec::new();
        for t in 0..N_TENANTS {
            let gb = self.fabric.owner_gb(t);
            let gbps = (gb - self.last_owner_gb[t]) / dt;
            self.last_owner_gb[t] = gb;
            let tails = self.monitors[t].sample(now);
            let active = match t {
                0 => true,
                1 => self.t2_active,
                _ => self.t3_active,
            };
            // T2's block I/O is its NVMe-side traffic.
            let nvme_share = if t == 1 { gbps * 0.5 } else { 0.0 };
            tenants.push(TenantSignal {
                tenant: TenantId(t),
                tails,
                pcie_gbps: gbps,
                block_io_gbps: nvme_share,
                active,
            });
        }

        // SM utilization: time-weighted approximation via current state.
        let sm_now = self.instantaneous_sm_util();
        self.sm_util_integral += sm_now;
        self.sm_util_samples += 1;
        let mut gpu_sm_util = vec![0.0; topo.num_gpus];
        gpu_sm_util[self.placements[0].gpu] = sm_now;

        let numa_io_gbps: Vec<f64> = topo
            .numa_nodes
            .iter()
            .map(|n| links[n.nvme_link.0].gbps)
            .collect();
        let numa_irq_rate: Vec<f64> = numa_io_gbps
            .iter()
            .zip(topo.numa_nodes.iter())
            .map(|(io, n)| {
                // IRQ rate rises with storage + PCIe traffic in the domain.
                let pcie: f64 = topo
                    .switches
                    .iter()
                    .filter(|s| s.numa == n.id)
                    .map(|s| links[s.link.0].gbps)
                    .sum();
                200.0 + 800.0 * io + 120.0 * pcie
            })
            .collect();

        self.last_sample_t = now;
        SignalSnapshot {
            t: now,
            dt,
            tenants,
            links,
            gpu_sm_util,
            numa_io_gbps,
            numa_irq_rate,
        }
    }

    fn build_view(&self) -> PlannerView {
        let mut tenants = Vec::new();
        for (i, p) in self.placements.iter().enumerate() {
            tenants.push(TenantView {
                tenant: TenantId(i),
                gpu: p.gpu,
                instance: p.instance,
                profile: p.profile,
                mps_peers: p.peers.iter().map(|&x| TenantId(x)).collect(),
                numa: p.numa,
                mps_quota: if i == 2 { self.t3_quota } else { 100.0 },
                io_throttle_gbps: if i == 1 { self.t2_throttle } else { None },
            });
        }
        // Free existing instances anywhere on the host.
        let occupied: Vec<(usize, InstanceId)> = self
            .placements
            .iter()
            .map(|p| (p.gpu, p.instance))
            .collect();
        let mut free_instances = Vec::new();
        for g in &self.gpus {
            for inst in g.instances() {
                if !occupied.contains(&(g.index, inst.id)) {
                    free_instances.push(InstanceView {
                        gpu: g.index,
                        existing: Some(inst.id),
                        profile: inst.profile,
                    });
                }
            }
        }
        PlannerView {
            topo: self.scenario.topo.clone(),
            gpus: self.gpus.clone(),
            tenants,
            free_instances,
            t1_base_rps: self.scenario.t1.arrival_rps,
        }
    }

    fn on_sample(&mut self, now: f64) {
        let snap = self.build_snapshot(now);
        if let Some(t1) = snap.tenant(T1) {
            self.p99_series.push((now, t1.tails.p99_ms));
        }
        if self.controller.is_some() {
            let view = self.build_view();
            let wall = std::time::Instant::now();
            let actions = self
                .controller
                .as_mut()
                .unwrap()
                .on_observation(&snap, &view);
            self.controller_wall_s += wall.elapsed().as_secs_f64();
            for a in actions {
                self.apply_action(now, a);
            }
        }
        self.q.push_at(now + self.scenario.sample_dt, Event::Sample);
    }

    /// Build a (snapshot, view) pair from the current world state —
    /// used by benches to measure the controller tick in isolation.
    pub fn sample_for_bench(&mut self) -> (SignalSnapshot, PlannerView) {
        let snap = self.build_snapshot(1.0);
        let view = self.build_view();
        (snap, view)
    }

    // --- main loop -------------------------------------------------------------

    fn handle(&mut self, now: f64, ev: Event) {
        match ev {
            Event::T1Arrival => self.on_t1_arrival(now),
            Event::FlowsDone { version } => {
                if version != self.fabric_version {
                    return;
                }
                self.sync_fabric(now);
                // Collect every flow that has drained.
                let done: Vec<FlowId> = self
                    .flow_purpose
                    .keys()
                    .copied()
                    .filter(|id| self.fabric.remaining(*id).map(|r| r <= 1e-9).unwrap_or(false))
                    .collect();
                for id in done {
                    self.fabric.remove(id);
                    let purpose = self.flow_purpose.remove(&id).unwrap();
                    match purpose {
                        Purpose::T1Stage(r) => self.on_t1_stage_done(now, r),
                        Purpose::T1H2d(r) => self.on_t1_h2d_done(now, r),
                        Purpose::T2Read | Purpose::T2H2d | Purpose::T2D2h => {
                            self.on_t2_flow_done(now, purpose)
                        }
                        Purpose::T3Sync => {}
                    }
                }
                self.reschedule_fabric(now);
            }
            Event::T1ComputeDone { req } => self.on_t1_compute_done(now, req),
            Event::T2TransformDone => self.on_t2_transform_done(now),
            Event::T3StepDone => self.on_t3_step_done(now),
            Event::ToggleT2 => {
                self.t2_active = self.scenario.t2_schedule.active_at(now);
                if self.t2_active {
                    self.t2_begin_cycle(now);
                }
                // When toggled off mid-cycle the current flows drain and
                // the cycle stops at the next Idle check.
            }
            Event::ToggleT3 => {
                self.t3_active = self.scenario.t3_schedule.active_at(now);
                if self.t3_active {
                    self.t3_begin_step(now);
                }
            }
            Event::Sample => self.on_sample(now),
            Event::PauseDone => self.on_pause_done(now),
            Event::ThrottleExpire { deadline_bits } => {
                if self.t2_throttle_deadline.map(f64::to_bits) == Some(deadline_bits) {
                    self.t2_throttle = None;
                    self.t2_throttle_deadline = None;
                    self.sync_fabric(now);
                    self.fabric.set_owner_cap(1, None);
                    self.reschedule_fabric(now);
                }
            }
        }
    }

    /// Run to the scenario horizon and aggregate results.
    pub fn run(mut self) -> RunResult {
        let horizon = self.scenario.horizon;
        while let Some(t) = self.q.peek_time() {
            if t > horizon {
                break;
            }
            let (clock, ev) = self.q.pop().unwrap();
            self.handle(clock.secs(), ev);
        }
        self.finish(horizon)
    }

    fn finish(self, horizon: f64) -> RunResult {
        let m = &self.monitors[0];
        let label = self.scenario.controller.levers.name().to_string();
        let (actions, timeline, moves_per_hour) = match &self.controller {
            Some(c) => {
                let audit = c.audit();
                let mut counts: BTreeMap<String, usize> = BTreeMap::new();
                for e in audit.entries() {
                    *counts.entry(e.action.clone()).or_insert(0) += 1;
                }
                (
                    counts.into_iter().collect::<Vec<_>>(),
                    audit
                        .timeline()
                        .into_iter()
                        .map(|(t, k, p)| (t, k.to_string(), p))
                        .collect(),
                    audit.moves_per_hour(horizon),
                )
            }
            None => (Vec::new(), Vec::new(), 0.0),
        };
        RunResult {
            label,
            seed: self.scenario.seed,
            horizon_s: horizon,
            miss_rate: m.lifetime_miss_rate(),
            p50_ms: m.lifetime_quantile_ms(0.50),
            p95_ms: m.lifetime_quantile_ms(0.95),
            p99_ms: m.lifetime_quantile_ms(0.99),
            p999_ms: m.lifetime_quantile_ms(0.999),
            mean_ms: m.histogram().mean() / 1000.0,
            completed: m.total_completed(),
            rps: m.total_completed() as f64 / horizon,
            histogram: m.histogram().clone(),
            actions,
            moves_per_hour,
            reconfig_durations_s: self.reconfig_durations.clone(),
            controller_cpu_frac: self.controller_wall_s / horizon,
            timeline,
            mean_sm_util: if self.sm_util_samples > 0 {
                self.sm_util_integral / self.sm_util_samples as f64
            } else {
                0.0
            },
            p99_series: self.p99_series,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::controller::Levers;

    fn short_scenario(seed: u64, levers: Levers) -> Scenario {
        let mut s = Scenario::paper_single_host(seed, levers);
        s.horizon = 120.0;
        s
    }

    #[test]
    fn baseline_run_completes_requests() {
        let r = SimWorld::new(short_scenario(1, Levers::none())).run();
        // ~80 rps * 120 s; allow wide tolerance for in-flight tail.
        assert!(r.completed > 8_500, "completed={}", r.completed);
        assert!(r.p99_ms > r.p50_ms);
        assert!(r.miss_rate >= 0.0 && r.miss_rate <= 1.0);
    }

    #[test]
    fn deterministic_same_seed() {
        let a = SimWorld::new(short_scenario(5, Levers::none())).run();
        let b = SimWorld::new(short_scenario(5, Levers::none())).run();
        assert_eq!(a.completed, b.completed);
        assert_eq!(a.p99_ms, b.p99_ms);
        assert_eq!(a.miss_rate, b.miss_rate);
    }

    #[test]
    fn different_seeds_differ() {
        let a = SimWorld::new(short_scenario(5, Levers::none())).run();
        let b = SimWorld::new(short_scenario(6, Levers::none())).run();
        assert_ne!(a.completed, b.completed);
    }

    #[test]
    fn contention_inflates_tail() {
        let mut quiet = short_scenario(2, Levers::none());
        quiet.t2_schedule = crate::tenants::InterferenceSchedule::always_off(120.0);
        quiet.t3_schedule = crate::tenants::InterferenceSchedule::always_off(120.0);
        let mut noisy = short_scenario(2, Levers::none());
        noisy.t2_schedule = crate::tenants::InterferenceSchedule::always_on(120.0);
        noisy.t3_schedule = crate::tenants::InterferenceSchedule::always_on(120.0);
        let rq = SimWorld::new(quiet).run();
        let rn = SimWorld::new(noisy).run();
        assert!(
            rn.p99_ms > rq.p99_ms * 1.2,
            "noisy p99 {} vs quiet {}",
            rn.p99_ms,
            rq.p99_ms
        );
    }

    #[test]
    fn controller_acts_under_contention() {
        let mut s = short_scenario(3, Levers::full());
        s.horizon = 600.0;
        s.t2_schedule = crate::tenants::InterferenceSchedule::always_on(600.0);
        s.t3_schedule = crate::tenants::InterferenceSchedule::always_on(600.0);
        let r = SimWorld::new(s).run();
        let total_actions: usize = r.actions.iter().map(|(_, c)| c).sum();
        assert!(total_actions > 0, "controller never acted: {:?}", r.actions);
    }

    #[test]
    fn full_controller_beats_baseline() {
        // The headline direction (E1) on a longer run.
        let mk = |levers| {
            let mut s = Scenario::paper_single_host(11, levers);
            s.horizon = 900.0;
            SimWorld::new(s).run()
        };
        let base = mk(Levers::none());
        let full = mk(Levers::full());
        assert!(
            full.p99_ms < base.p99_ms,
            "full {} !< base {}",
            full.p99_ms,
            base.p99_ms
        );
        assert!(
            full.miss_rate < base.miss_rate,
            "full miss {} !< base {}",
            full.miss_rate,
            base.miss_rate
        );
    }
}
