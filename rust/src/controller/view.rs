//! Planner view: the placement/MIG state the controller plans against.
//!
//! Built by the platform at each sampling tick; the controller never
//! touches the simulator directly (fabric-agnosticism).

use crate::gpu::{A100Gpu, InstanceId, MigProfile};
use crate::tenants::TenantId;
use crate::topo::HostTopology;

/// One tenant's current placement.
#[derive(Clone, Debug)]
pub struct TenantView {
    pub tenant: TenantId,
    pub gpu: usize,
    pub instance: InstanceId,
    pub profile: MigProfile,
    /// Tenants sharing the same MIG instance via MPS (naive co-placement).
    pub mps_peers: Vec<TenantId>,
    /// NUMA domain the tenant's host threads are pinned to.
    pub numa: usize,
    /// Current MPS active-thread quota (100 = uncapped).
    pub mps_quota: f64,
    /// Current IO throttle (GB/s) if any.
    pub io_throttle_gbps: Option<f64>,
}

/// A MIG instance that could host the latency-sensitive tenant.
#[derive(Clone, Debug)]
pub struct InstanceView {
    pub gpu: usize,
    /// Existing unoccupied instance — `Some(id)`; `None` means the slot
    /// would have to be created on free slices (requires `dynamic_mig`).
    pub existing: Option<InstanceId>,
    pub profile: MigProfile,
}

/// Everything the planner needs.
#[derive(Clone, Debug)]
pub struct PlannerView {
    pub topo: HostTopology,
    pub gpus: Vec<A100Gpu>,
    pub tenants: Vec<TenantView>,
    /// Unoccupied existing instances (movable targets without reconfig).
    pub free_instances: Vec<InstanceView>,
    /// Expected baseline throughput of the primary tenant (req/s) for the
    /// ≥95% budget check.
    pub primary_base_rps: f64,
}

impl PlannerView {
    pub fn tenant(&self, id: TenantId) -> Option<&TenantView> {
        self.tenants.iter().find(|t| t.tenant == id)
    }

    /// Creatable placements for `profile`: GPUs with legal free slices
    /// (requires dynamic MIG).
    pub fn creatable_on(&self, profile: MigProfile) -> Vec<usize> {
        self.gpus
            .iter()
            .filter(|g| !g.placements(profile).is_empty())
            .map(|g| g.index)
            .collect()
    }
}
