//! Controller parameters — defaults are the paper's Table 1.

/// Which levers are enabled (the E2 ablation axis, Table 3).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Levers {
    pub dynamic_mig: bool,
    pub placement: bool,
    pub guardrails: bool,
}

impl Levers {
    /// Full system.
    pub fn full() -> Levers {
        Levers {
            dynamic_mig: true,
            placement: true,
            guardrails: true,
        }
    }

    /// Static baseline (controller observes but never acts).
    pub fn none() -> Levers {
        Levers {
            dynamic_mig: false,
            placement: false,
            guardrails: false,
        }
    }

    pub fn mig_only() -> Levers {
        Levers {
            dynamic_mig: true,
            placement: false,
            guardrails: false,
        }
    }

    pub fn placement_only() -> Levers {
        Levers {
            dynamic_mig: false,
            placement: true,
            guardrails: false,
        }
    }

    pub fn guards_only() -> Levers {
        Levers {
            dynamic_mig: false,
            placement: false,
            guardrails: true,
        }
    }

    pub fn any(&self) -> bool {
        self.dynamic_mig || self.placement || self.guardrails
    }

    pub fn name(&self) -> &'static str {
        match (self.dynamic_mig, self.placement, self.guardrails) {
            (true, true, true) => "Full System",
            (true, false, false) => "MIG-only",
            (false, true, false) => "Placement-only",
            (false, false, true) => "Guards-only",
            (false, false, false) => "Static MIG",
            _ => "Custom",
        }
    }
}

/// Which latency signal the FSM compares against τ.
///
/// `E2e` is the historical behavior (window p99 of end-to-end request
/// latency). `Ttft` targets the time-to-first-token tail of a
/// request-granularity LLM tenant (`TenantSignal::ttft`), falling back
/// to e2e tails for tenants that don't report TTFT; the throughput
/// guard always stays on the e2e window.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum SloKind {
    #[default]
    E2e,
    Ttft,
}

/// Table 1: Key Controller Parameters (plus the implementation-note knobs
/// of §2.4).
#[derive(Clone, Debug)]
pub struct ControllerConfig {
    /// Tail threshold τ: p99 latency that triggers a policy change (ms).
    pub tau_ms: f64,
    /// Persistence Y: consecutive windows the tail must exceed τ.
    pub persistence_y: u32,
    /// Dwell time: minimum observations between policy changes.
    pub dwell_obs: u64,
    /// Cool-down: grace observations after returning to performance mode.
    pub cooldown_obs: u64,
    /// MPS quota bounds (percent of active threads).
    pub mps_quota_min: f64,
    pub mps_quota_max: f64,
    /// cgroup IO throttle bounds (GB/s; paper: 100-500 MB/s).
    pub io_throttle_min_gbps: f64,
    pub io_throttle_max_gbps: f64,
    /// Bounded throttle window Z (seconds, §2.4 "tens of seconds").
    pub throttle_window_s: f64,
    /// Post-change validation window (observations) before persisting /
    /// rolling back (§2.4).
    pub validation_obs: u64,
    /// Relaxation: tail must be below `relax_frac·τ` for `stable_obs`
    /// observations (and throughput within budget) before shrinking.
    pub relax_frac: f64,
    pub stable_obs: u64,
    /// Throughput budget: actions must keep T ≥ (1-budget)·T_base (§2).
    pub throughput_budget: f64,
    /// Observations to ignore at startup (cold-start quantiles are noise).
    pub warmup_obs: u64,
    /// Minimum window miss-rate for a *disruptive* action to be worth a
    /// pause (keeps the Table-4 move budget under 5/hour).
    pub material_miss: f64,
    /// Enabled levers.
    pub levers: Levers,
    /// Placement-score margin: a move must beat the current placement by
    /// this factor to be worth a pause.
    pub placement_margin: f64,
    /// Admission (§2.3): placement-score ceiling above which a slot would
    /// endanger existing tenants' SLOs. Shared by `controller::admission`
    /// and the auto-placement allocator (`crate::alloc`).
    pub safe_score: f64,
    /// Admission (§2.3): link utilization ceiling after adding a
    /// newcomer's expected traffic (fraction of link capacity).
    pub link_headroom: f64,
    /// Latency signal compared against τ ([`SloKind::E2e`] keeps the
    /// historical behavior byte-for-byte).
    pub objective: SloKind,
    /// Fault hardening: failed disruptive actuations are retried with
    /// bounded exponential backoff this many times before the
    /// controller degrades to guardrails-only mode. The retry path
    /// never burns the dwell clock (a change that didn't happen isn't
    /// a change).
    pub max_action_retries: u32,
    /// Fault hardening: observations a held-last (stale) signal stays
    /// trustworthy. Within the TTL the controller behaves normally
    /// minus relaxation; beyond it, no disruptive proposals until a
    /// fresh signal arrives (guardrails stay armed).
    pub stale_ttl_obs: u64,
}

impl Default for ControllerConfig {
    fn default() -> Self {
        ControllerConfig {
            tau_ms: 15.0,
            persistence_y: 3,
            dwell_obs: 256,
            cooldown_obs: 128,
            mps_quota_min: 50.0,
            mps_quota_max: 100.0,
            io_throttle_min_gbps: 0.1,
            io_throttle_max_gbps: 0.5,
            throttle_window_s: 30.0,
            validation_obs: 64,
            relax_frac: 0.6,
            stable_obs: 512,
            throughput_budget: 0.05,
            warmup_obs: 30,
            material_miss: 0.02,
            levers: Levers::full(),
            placement_margin: 0.25,
            safe_score: 1.5,
            link_headroom: 0.85,
            objective: SloKind::E2e,
            max_action_retries: 3,
            stale_ttl_obs: 5,
        }
    }
}

impl ControllerConfig {
    pub fn with_levers(levers: Levers) -> ControllerConfig {
        ControllerConfig {
            levers,
            ..Default::default()
        }
    }

    /// Admission tuned for dense auto-packing scenarios (`crate::alloc`):
    /// the placement-score ceiling is effectively disabled — candidate
    /// *ordering* stays topology-aware, so tenants still spread away from
    /// hot switches/NUMA domains — while **PCIe uplink** headroom remains
    /// the hard gate. NVMe paths are deliberately not gated: storage
    /// oversubscription stretches ETL cycles under PS sharing instead of
    /// refusing tenants (the runtime io.max guardrail protects the
    /// primary), while the score's NUMA-I/O term still spreads
    /// storage-heavy tenants across domains. The default `safe_score` is
    /// calibrated for admitting one newcomer next to a protected primary
    /// and would cap a host at a handful of background tenants.
    pub fn dense_pack(levers: Levers) -> ControllerConfig {
        ControllerConfig {
            levers,
            safe_score: f64::MAX,
            ..Default::default()
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn defaults_match_table1() {
        let c = ControllerConfig::default();
        assert_eq!(c.tau_ms, 15.0);
        assert_eq!(c.persistence_y, 3);
        assert_eq!(c.dwell_obs, 256);
        assert_eq!(c.cooldown_obs, 128);
        assert_eq!(c.mps_quota_min, 50.0);
        assert_eq!(c.mps_quota_max, 100.0);
        // 100-500 MB/s.
        assert!((c.io_throttle_min_gbps - 0.1).abs() < 1e-12);
        assert!((c.io_throttle_max_gbps - 0.5).abs() < 1e-12);
        // Admission thresholds keep their historical values as defaults.
        assert_eq!(c.safe_score, 1.5);
        assert_eq!(c.link_headroom, 0.85);
    }

    #[test]
    fn lever_names() {
        assert_eq!(Levers::full().name(), "Full System");
        assert_eq!(Levers::none().name(), "Static MIG");
        assert_eq!(Levers::mig_only().name(), "MIG-only");
        assert_eq!(Levers::placement_only().name(), "Placement-only");
        assert_eq!(Levers::guards_only().name(), "Guards-only");
        assert!(!Levers::none().any());
    }
}
