//! Actions the controller can emit — the §2.2 decision space.

use crate::gpu::MigProfile;
use crate::tenants::TenantId;
use crate::trace::DecisionKind;

/// Isolation changes bundle the MIG/placement levers (§2.3 "upgrade the
/// tenant's isolation" = increase MIG share *or* migrate).
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum IsolationChange {
    /// Reconfigure the tenant's instance to a larger/smaller profile on
    /// its current GPU (dynamic MIG).
    Resize { to: MigProfile },
    /// Move the tenant to an existing free instance (placement lever; no
    /// MIG reconfiguration needed).
    MoveExisting { gpu: usize, to: MigProfile },
    /// Create a new instance on `gpu` (dynamic MIG + placement) and move
    /// the tenant into it.
    CreateAndMove { gpu: usize, to: MigProfile },
}

/// One actuation command.
#[derive(Clone, Debug, PartialEq)]
pub enum Action {
    /// Upgrade/relax/move the tenant's isolation.
    ChangeIsolation {
        tenant: TenantId,
        change: IsolationChange,
        /// True when this is a relaxation (shrink to free resources).
        relax: bool,
    },
    /// Cap a noisy peer's MPS active-thread percentage.
    SetMpsQuota { tenant: TenantId, quota: f64 },
    /// Apply (Some) or lift (None) a cgroup io.max throttle.
    SetIoThrottle {
        tenant: TenantId,
        cap_gbps: Option<f64>,
    },
    /// Pin the tenant's host threads to a NUMA domain away from IRQ-heavy
    /// cores (§2.3).
    PinCpu { tenant: TenantId, numa: usize },
    /// Revert to the last-known-good configuration (§2.4 rollback).
    Rollback { tenant: TenantId },
}

/// What actually happened when the platform applied an [`Action`].
///
/// Pre-fault-injection the platform could not fail, so every call was
/// an implicit `Applied`. Under a `FaultPlan` with `ReconfigFlaky`
/// windows, MIG/placement actuations become fallible and slow; the
/// controller FSM uses these outcomes to retry with bounded
/// exponential backoff *without* burning its dwell clock, and to fall
/// back to guardrails-only mode when retries are exhausted.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum ActionOutcome {
    /// The actuation took effect (or was a benign no-op).
    Applied,
    /// The actuation failed and left the host configuration unchanged.
    Failed { reason: &'static str },
    /// The actuation exceeded its deadline; treated like a failure for
    /// retry purposes but audited distinctly.
    TimedOut,
}

impl ActionOutcome {
    /// Did the host configuration change as requested?
    pub fn is_applied(&self) -> bool {
        matches!(self, ActionOutcome::Applied)
    }
}

impl Action {
    /// Does this action pause the tenant (and hence count against the
    /// dwell/cool-down budget)? Guardrails are "lightweight" — they do
    /// not interrupt anything.
    pub fn is_disruptive(&self) -> bool {
        matches!(
            self,
            Action::ChangeIsolation { .. } | Action::Rollback { .. }
        )
    }

    /// Short tag for audit logs / Figure 3a lanes (the rendered form of
    /// [`Action::decision_kind`]).
    pub fn kind(&self) -> &'static str {
        self.decision_kind().as_str()
    }

    /// Typed action-kind tag shared with the audit log and trace events.
    pub fn decision_kind(&self) -> DecisionKind {
        match self {
            Action::ChangeIsolation { relax: true, .. } => DecisionKind::Relax,
            Action::ChangeIsolation {
                change: IsolationChange::Resize { .. },
                ..
            } => DecisionKind::Mig,
            Action::ChangeIsolation { .. } => DecisionKind::Placement,
            Action::SetMpsQuota { .. } => DecisionKind::MpsQuota,
            Action::SetIoThrottle { .. } => DecisionKind::IoThrottle,
            Action::PinCpu { .. } => DecisionKind::PinCpu,
            Action::Rollback { .. } => DecisionKind::Rollback,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tenants::spec::T1;

    #[test]
    fn disruptive_classification() {
        assert!(Action::ChangeIsolation {
            tenant: T1,
            change: IsolationChange::Resize {
                to: MigProfile::P3g40gb
            },
            relax: false,
        }
        .is_disruptive());
        assert!(!Action::SetMpsQuota {
            tenant: T1,
            quota: 50.0
        }
        .is_disruptive());
        assert!(!Action::SetIoThrottle {
            tenant: T1,
            cap_gbps: Some(0.2)
        }
        .is_disruptive());
    }

    #[test]
    fn kinds_for_fig3_lanes() {
        let mig = Action::ChangeIsolation {
            tenant: T1,
            change: IsolationChange::Resize {
                to: MigProfile::P3g40gb,
            },
            relax: false,
        };
        assert_eq!(mig.kind(), "mig");
        let mv = Action::ChangeIsolation {
            tenant: T1,
            change: IsolationChange::MoveExisting {
                gpu: 2,
                to: MigProfile::P1g10gb,
            },
            relax: false,
        };
        assert_eq!(mv.kind(), "placement");
    }
}
