//! Lightweight guardrails: MPS quotas + bounded cgroup I/O throttles
//! (§2.2 "3", §2.4 implementation notes).

use super::config::ControllerConfig;
use crate::telemetry::SignalSnapshot;
use crate::tenants::TenantId;

/// Pick an `io.max`-style cap for a bandwidth-noisy tenant, within the
/// Table 1 bounds (100-500 MB/s). Proportional policy: cut the offender to
/// ~20% of its current rate, clamped to the bounds — aggressive enough to
/// free the link, bounded enough to avoid starving it (§2.4 "bounded
/// windows ... to reduce collateral damage").
pub fn pick_io_throttle(cfg: &ControllerConfig, snap: &SignalSnapshot, culprit: TenantId) -> f64 {
    let current = snap
        .tenant(culprit)
        .map(|t| t.pcie_gbps.max(t.block_io_gbps))
        .unwrap_or(cfg.io_throttle_max_gbps);
    (current * 0.2).clamp(cfg.io_throttle_min_gbps, cfg.io_throttle_max_gbps)
}

/// Tighten an MPS quota one notch (multiplicative decrease toward the
/// lower bound). Returns `None` when already at the bound — the signal to
/// escalate to isolation upgrades instead.
pub fn tighten_mps(cfg: &ControllerConfig, current_quota: f64) -> Option<f64> {
    let next = (current_quota * 0.7).max(cfg.mps_quota_min);
    if next >= current_quota - 1e-9 {
        None
    } else {
        Some(next)
    }
}

/// Relax an MPS quota one notch after recovery (additive increase).
pub fn relax_mps(cfg: &ControllerConfig, current_quota: f64) -> Option<f64> {
    let next = (current_quota + 15.0).min(cfg.mps_quota_max);
    if next <= current_quota + 1e-9 {
        None
    } else {
        Some(next)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::telemetry::signals::{SignalSnapshot, TailStats, TenantSignal};
    use crate::tenants::spec::T2;

    fn snap(t2_gbps: f64) -> SignalSnapshot {
        SignalSnapshot {
            t: 0.0,
            dt: 1.0,
            tenants: vec![TenantSignal {
                tenant: T2,
                tails: TailStats::default(),
                ttft: None,
                pcie_gbps: t2_gbps,
                block_io_gbps: 0.0,
                active: true,
                stale: false,
            }],
            links: vec![],
            gpu_sm_util: vec![],
            numa_io_gbps: vec![],
            numa_irq_rate: vec![],
        }
    }

    #[test]
    fn throttle_within_table1_bounds() {
        let cfg = ControllerConfig::default();
        for gbps in [0.05, 0.5, 2.0, 10.0, 100.0] {
            let cap = pick_io_throttle(&cfg, &snap(gbps), T2);
            assert!(
                (cfg.io_throttle_min_gbps..=cfg.io_throttle_max_gbps).contains(&cap),
                "cap {cap} out of bounds for rate {gbps}"
            );
        }
    }

    #[test]
    fn throttle_proportional_in_band() {
        let cfg = ControllerConfig::default();
        let cap = pick_io_throttle(&cfg, &snap(2.0), T2);
        assert!((cap - 0.4).abs() < 1e-12);
    }

    #[test]
    fn mps_tighten_hits_floor() {
        let cfg = ControllerConfig::default();
        let q1 = tighten_mps(&cfg, 100.0).unwrap();
        assert!((q1 - 70.0).abs() < 1e-9);
        let q2 = tighten_mps(&cfg, q1).unwrap();
        assert!((q2 - cfg.mps_quota_min).abs() < 1e-9);
        assert_eq!(tighten_mps(&cfg, q2), None);
    }

    #[test]
    fn mps_relax_hits_ceiling() {
        let cfg = ControllerConfig::default();
        let q = relax_mps(&cfg, 90.0).unwrap();
        assert!((q - 100.0).abs() < 1e-9);
        assert_eq!(relax_mps(&cfg, 100.0), None);
    }
}
