//! Admission control (§2.3 last sentence): "In cases where no safe
//! placement can be found for a new tenant without violating the SLOs of
//! existing tenants, an admission control mechanism will queue or reject
//! the new workload."

use crate::gpu::MigProfile;
use crate::telemetry::SignalSnapshot;
use crate::tenants::TenantId;

use super::config::ControllerConfig;
use super::placement::{self, ScoreWeights};
use super::view::PlannerView;

/// Resource ask of a tenant requesting admission.
#[derive(Clone, Copy, Debug)]
pub struct AdmissionRequest {
    pub tenant: TenantId,
    /// Smallest profile the workload can run on.
    pub min_profile: MigProfile,
    /// Expected sustained PCIe demand (GB/s).
    pub expected_pcie_gbps: f64,
}

/// Admission verdict.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum Verdict {
    /// Admit on this GPU / profile.
    Admit { gpu: usize, profile: MigProfile },
    /// No slot now, but capacity will exist once reconfiguration frees
    /// slices — hold in queue.
    Queue,
    /// Structurally impossible without violating existing SLOs.
    Reject,
}

/// Decide admission for `req` given the current host state. The safety
/// thresholds (`safe_score`, `link_headroom`) come from `cfg` so
/// scenarios and the auto-placement allocator can tune them per run.
pub fn admit(
    req: &AdmissionRequest,
    snap: &SignalSnapshot,
    view: &PlannerView,
    cfg: &ControllerConfig,
) -> Verdict {
    let w = ScoreWeights::default();
    let cands = placement::candidates(
        req.tenant,
        snap,
        view,
        &w,
        true,
        req.min_profile,
        crate::gpu::MigProfile::P7g80gb,
    );
    // Among safe candidates, admit on the *smallest* adequate profile —
    // admission should not squat a whole GPU when a 1g slice suffices
    // (the controller can always upgrade later).
    let mut safe: Vec<&super::placement::Candidate> = cands
        .iter()
        .filter(|c| {
            if c.score > cfg.safe_score {
                return false;
            }
            let link = view.topo.link_of_gpu(c.gpu);
            let cap = view.topo.link_capacity(link);
            let used = snap.link(link).map(|l| l.gbps).unwrap_or(0.0);
            (used + req.expected_pcie_gbps) / cap <= cfg.link_headroom
        })
        .collect();
    safe.sort_by(|a, b| {
        a.profile
            .cmp(&b.profile)
            .then(a.score.total_cmp(&b.score))
    });
    if let Some(c) = safe.first() {
        return Verdict::Admit {
            gpu: c.gpu,
            profile: c.profile,
        };
    }
    // Any candidate at all (even unsafe) means capacity exists: queue.
    if !cands.is_empty() {
        Verdict::Queue
    } else {
        Verdict::Reject
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gpu::A100Gpu;
    use crate::telemetry::signals::{LinkSignal, SignalSnapshot};
    use crate::topo::{HostTopology, LinkId};

    fn empty_snap() -> SignalSnapshot {
        SignalSnapshot {
            t: 0.0,
            dt: 1.0,
            tenants: vec![],
            links: (0..6)
                .map(|i| LinkSignal {
                    link: LinkId(i),
                    utilization: 0.0,
                    gbps: 0.0,
                })
                .collect(),
            gpu_sm_util: vec![0.0; 8],
            numa_io_gbps: vec![0.0, 0.0],
            numa_irq_rate: vec![0.0, 0.0],
        }
    }

    fn view_with_free_gpus() -> PlannerView {
        PlannerView {
            topo: HostTopology::p4d(),
            gpus: (0..8).map(A100Gpu::new).collect(),
            tenants: vec![],
            free_instances: vec![],
            primary_base_rps: 120.0,
        }
    }

    #[test]
    fn admits_on_idle_host() {
        let v = view_with_free_gpus();
        let req = AdmissionRequest {
            tenant: TenantId(9),
            min_profile: MigProfile::P2g20gb,
            expected_pcie_gbps: 2.0,
        };
        assert!(matches!(
            admit(&req, &empty_snap(), &v, &ControllerConfig::default()),
            Verdict::Admit { .. }
        ));
    }

    #[test]
    fn rejects_when_no_capacity() {
        let mut v = view_with_free_gpus();
        for g in v.gpus.iter_mut() {
            g.create_at(MigProfile::P7g80gb, 0).unwrap();
        }
        let req = AdmissionRequest {
            tenant: TenantId(9),
            min_profile: MigProfile::P1g10gb,
            expected_pcie_gbps: 0.5,
        };
        assert_eq!(
            admit(&req, &empty_snap(), &v, &ControllerConfig::default()),
            Verdict::Reject
        );
    }

    #[test]
    fn queues_when_links_saturated() {
        let v = view_with_free_gpus();
        let mut snap = empty_snap();
        for l in snap.links.iter_mut() {
            l.gbps = 24.0; // every PCIe link nearly full
            l.utilization = 0.96;
        }
        let req = AdmissionRequest {
            tenant: TenantId(9),
            min_profile: MigProfile::P1g10gb,
            expected_pcie_gbps: 5.0,
        };
        assert_eq!(
            admit(&req, &snap, &v, &ControllerConfig::default()),
            Verdict::Queue
        );
    }

    #[test]
    fn thresholds_are_tunable_per_config() {
        // A link_headroom of zero makes every candidate unsafe: the same
        // request that admits under defaults must now queue.
        let v = view_with_free_gpus();
        let req = AdmissionRequest {
            tenant: TenantId(9),
            min_profile: MigProfile::P1g10gb,
            expected_pcie_gbps: 1.0,
        };
        let strict = ControllerConfig {
            link_headroom: 0.0,
            ..Default::default()
        };
        assert_eq!(admit(&req, &empty_snap(), &v, &strict), Verdict::Queue);
        assert!(matches!(
            admit(&req, &empty_snap(), &v, &ControllerConfig::default()),
            Verdict::Admit { .. }
        ));
    }
}
