//! Root-cause diagnosis from secondary signals (§2.1, §2.3).
//!
//! "Upon triggering, the controller first attempts to diagnose the issue
//! using its secondary signals. If high PCIe or I/O pressure is detected,
//! it applies a cgroup I/O throttle ... If the primary cause appears to be
//! compute or memory contention, or if throttling does not resolve the
//! issue, the controller proceeds to upgrade the tenant's isolation."

use crate::telemetry::SignalSnapshot;
use crate::tenants::TenantId;

use super::view::PlannerView;

/// Diagnosed dominant interference cause.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Cause {
    /// Contention on the tenant's PCIe uplink (bandwidth-heavy neighbor).
    PciePressure { culprit: TenantId },
    /// Host block-I/O pressure on the tenant's NUMA domain.
    IoPressure { culprit: TenantId },
    /// SM/memory contention from an MPS-shared peer.
    ComputeContention { culprit: TenantId },
    /// Nothing stands out — tail inflation is endogenous (queueing) or the
    /// slice is simply too small.
    Unattributed,
}

/// Utilization above which a link counts as "hot".
pub const LINK_HOT: f64 = 0.55;
/// Per-tenant PCIe rate (GB/s) above which a neighbor counts as
/// bandwidth-heavy.
pub const NEIGHBOR_HEAVY_GBPS: f64 = 1.0;

/// Rank causes for `primary`'s tail violation by signal excess.
pub fn diagnose(primary: TenantId, snap: &SignalSnapshot, view: &PlannerView) -> Cause {
    let Some(me) = view.tenant(primary) else {
        return Cause::Unattributed;
    };
    let my_link = view.topo.link_of_gpu(me.gpu);
    let my_numa = me.numa;

    // Score each candidate cause; pick the largest.
    let mut best = (0.0f64, Cause::Unattributed);

    // PCIe: my uplink is hot AND a neighbor is pushing serious bytes
    // through it.
    if let Some(link) = snap.link(my_link) {
        if link.utilization > LINK_HOT {
            for t in &snap.tenants {
                if t.tenant == primary || !t.active {
                    continue;
                }
                let Some(tv) = view.tenant(t.tenant) else {
                    continue;
                };
                let shares_link = view.topo.link_of_gpu(tv.gpu) == my_link;
                if shares_link && t.pcie_gbps > NEIGHBOR_HEAVY_GBPS {
                    let score = link.utilization * t.pcie_gbps;
                    if score > best.0 {
                        best = (score, Cause::PciePressure { culprit: t.tenant });
                    }
                }
            }
        }
    }

    // Block I/O on my NUMA domain.
    if let Some(&io) = snap.numa_io_gbps.get(my_numa) {
        if io > 0.5 {
            for t in &snap.tenants {
                if t.tenant == primary || !t.active {
                    continue;
                }
                let Some(tv) = view.tenant(t.tenant) else {
                    continue;
                };
                if tv.numa == my_numa && t.block_io_gbps > 0.25 {
                    let score = 0.8 * io * t.block_io_gbps;
                    if score > best.0 {
                        best = (score, Cause::IoPressure { culprit: t.tenant });
                    }
                }
            }
        }
    }

    // SM contention: an active MPS peer on my instance.
    for peer in &me.mps_peers {
        if let Some(p) = snap.tenant(*peer) {
            if p.active {
                // Shared-instance compute contention dominates when present:
                // MIG would have isolated it, so weight it above any
                // plausible PCIe score (util × GB/s tops out well below 10
                // on a 25 GB/s Gen4 uplink... after throttling).
                let util = snap.gpu_sm_util.get(me.gpu).copied().unwrap_or(0.0);
                let score = 10.0 + util;
                if score > best.0 {
                    best = (score, Cause::ComputeContention { culprit: *peer });
                }
            }
        }
    }

    best.1
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gpu::{A100Gpu, InstanceId, MigProfile};
    use crate::telemetry::signals::{LinkSignal, TailStats, TenantSignal};
    use crate::tenants::spec::{T1, T2, T3};
    use crate::topo::{HostTopology, LinkId};

    fn view(shared_with_t3: bool, t2_gpu: usize) -> PlannerView {
        let topo = HostTopology::p4d();
        let mut gpus: Vec<A100Gpu> = (0..8).map(A100Gpu::new).collect();
        gpus[0].create_at(MigProfile::P4g40gb, 0).unwrap();
        gpus[0].create_at(MigProfile::P3g40gb, 4).unwrap();
        PlannerView {
            topo,
            gpus,
            tenants: vec![
                super::super::view::TenantView {
                    tenant: T1,
                    gpu: 0,
                    instance: InstanceId(1),
                    profile: MigProfile::P4g40gb,
                    mps_peers: if shared_with_t3 { vec![T3] } else { vec![] },
                    numa: 0,
                    mps_quota: 100.0,
                    io_throttle_gbps: None,
                },
                super::super::view::TenantView {
                    tenant: T2,
                    gpu: t2_gpu,
                    instance: InstanceId(2),
                    profile: MigProfile::P3g40gb,
                    mps_peers: vec![],
                    numa: 0,
                    mps_quota: 100.0,
                    io_throttle_gbps: None,
                },
            ],
            free_instances: vec![],
            primary_base_rps: 120.0,
        }
    }

    fn snap(link0_util: f64, t2_pcie: f64, t2_io: f64, t3_active: bool) -> SignalSnapshot {
        SignalSnapshot {
            t: 100.0,
            dt: 2.0,
            tenants: vec![
                TenantSignal {
                    tenant: T1,
                    tails: TailStats::default(),
                    ttft: None,
                    pcie_gbps: 0.5,
                    block_io_gbps: 0.1,
                    active: true,
                    stale: false,
                },
                TenantSignal {
                    tenant: T2,
                    tails: TailStats::default(),
                    ttft: None,
                    pcie_gbps: t2_pcie,
                    block_io_gbps: t2_io,
                    active: t2_pcie > 0.0,
                    stale: false,
                },
                TenantSignal {
                    tenant: T3,
                    tails: TailStats::default(),
                    ttft: None,
                    pcie_gbps: 0.05,
                    block_io_gbps: 0.0,
                    active: t3_active,
                    stale: false,
                },
            ],
            links: vec![LinkSignal {
                link: LinkId(0),
                utilization: link0_util,
                gbps: link0_util * 25.0,
            }],
            gpu_sm_util: vec![0.9; 8],
            numa_io_gbps: vec![t2_io, 0.0],
            numa_irq_rate: vec![500.0, 100.0],
        }
    }

    #[test]
    fn pcie_pressure_detected() {
        let v = view(false, 0);
        let s = snap(0.9, 8.0, 0.2, false);
        assert_eq!(diagnose(T1, &s, &v), Cause::PciePressure { culprit: T2 });
    }

    #[test]
    fn compute_contention_dominates_when_shared() {
        let v = view(true, 0);
        let s = snap(0.9, 8.0, 0.2, true);
        assert_eq!(
            diagnose(T1, &s, &v),
            Cause::ComputeContention { culprit: T3 }
        );
    }

    #[test]
    fn inactive_t3_not_blamed() {
        let v = view(true, 0);
        let s = snap(0.3, 0.0, 0.0, false);
        assert_eq!(diagnose(T1, &s, &v), Cause::Unattributed);
    }

    #[test]
    fn remote_t2_not_blamed_for_pcie() {
        // T2 on GPU 4 (different switch) cannot be the PCIe culprit.
        let v = view(false, 4);
        let s = snap(0.9, 8.0, 0.0, false);
        assert!(!matches!(
            diagnose(T1, &s, &v),
            Cause::PciePressure { .. }
        ));
    }

    #[test]
    fn io_pressure_detected_without_link_heat() {
        let v = view(false, 4); // T2 elsewhere on PCIe but same NUMA? gpu4 => numa1
        // Put T2 on numa0 via its view: gpu 2 is numa 0, switch 1.
        let v2 = view(false, 2);
        let s = snap(0.2, 0.3, 3.0, false);
        let _ = v;
        assert_eq!(diagnose(T1, &s, &v2), Cause::IoPressure { culprit: T2 });
    }
}
