//! Multi-primary control plane: one [`Controller`] per protected
//! latency-sensitive tenant, coordinated by a deterministic arbiter.
//!
//! The paper's controller protects a single designated tenant. Real hosts
//! run several latency-sensitive services at once (the `multi_ls_slo_mix`
//! / `dueling_primaries` scenarios), and their controllers can want
//! *conflicting* isolation upgrades on the shared GPUs — both chasing the
//! same spare instance, or two MIG reconfigurations whose pauses and
//! post-change validation windows would confound each other's p99
//! attribution (MIG-Serving and ParvaGPU both hit this per-tenant
//! conflict-resolution problem on reconfigurable GPUs).
//!
//! Arbitration policy (all deterministic):
//!
//! 1. **Mandatory rollbacks** (validation failures) always commit — a
//!    controller may always restore its own last-known-good config.
//! 2. **Guardrails** are non-disruptive and commit immediately;
//!    same-tick duplicates targeting one tenant are reconciled to the
//!    most *protective* value (tightest IO cap / lowest MPS quota). The
//!    arbiter also tracks which controller owns each active guardrail:
//!    a relaxation may only lift guards its own controller applied, so
//!    a stable tenant's relax path can never undo the protection a
//!    still-violating tenant's controller put in place.
//! 3. **Disruptive isolation changes** (upgrades and relaxation
//!    shrinks) are serialized host-wide: at most one commits per tick,
//!    and none while any controller's change is under validation
//!    (post-change p99 shifts stay attributable, and the platform's
//!    last-known-good snapshot always belongs to exactly one in-flight
//!    change). Upgrades outrank relaxes; among upgrades the worst
//!    tail-to-SLO ratio (`p99 / τ`) wins, ties broken by tenant index.
//!    Every loser is deferred with its dwell/cool-down state intact —
//!    never silently dropped. Deferrals land in the loser's audit log
//!    (edge `"defer"`) and in the run's arbitration counters.
//!
//! A deferred controller re-enters `evaluate` next tick and re-plans
//! against the *post-winner* host state, so a deferred upgrade is
//! eventually applied (or superseded by a better plan) once the winner's
//! validation window closes.
//!
//! With exactly one controller the arbiter is a transparent pass-through:
//! single-primary scenarios keep their seed-identical action sequence.

use std::collections::BTreeMap;

use crate::telemetry::SignalSnapshot;
use crate::tenants::TenantId;

use super::actions::{Action, ActionOutcome};
use super::config::ControllerConfig;
use super::fsm::{Controller, CtlState, OutcomeFeedback, Proposal, ProposalClass};
use super::view::PlannerView;

/// One tenant the control plane protects.
#[derive(Clone, Copy, Debug)]
pub struct Protected {
    pub tenant: TenantId,
    /// Tail threshold τ for this tenant's controller. `None` keeps the
    /// shared `ControllerConfig::tau_ms` (the designated primary keeps
    /// any author-tuned τ; secondary tenants use their own SLO).
    pub tau_ms: Option<f64>,
    /// Baseline throughput for the ≥95% budget check.
    pub base_rps: f64,
}

/// Aggregate arbitration counters for a run.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct ArbStats {
    /// Ticks where two or more disruptive isolation changes competed.
    pub conflicts: u64,
    /// Total deferred proposals (arbitration losses + validation holds).
    pub deferrals: u64,
}

/// Guardrail flavor, for ownership tracking.
const GUARD_IO: u8 = 0;
const GUARD_MPS: u8 = 1;

/// The multi-primary control plane.
pub struct Arbiter {
    controllers: Vec<Controller>,
    stats: ArbStats,
    /// Which controller (index) owns the active guardrail on a target
    /// tenant: `(target tenant, GUARD_IO | GUARD_MPS) → controller`.
    /// Only the owner's relaxation path may lift or loosen it.
    guard_owner: BTreeMap<(usize, u8), usize>,
}

impl Arbiter {
    /// Legacy single-primary plane: one controller, `cfg` used verbatim.
    /// Behaviorally identical to driving that controller directly.
    pub fn single(cfg: ControllerConfig, primary: TenantId) -> Arbiter {
        Arbiter {
            controllers: vec![Controller::for_primary(cfg, primary)],
            stats: ArbStats::default(),
            guard_owner: BTreeMap::new(),
        }
    }

    /// One controller per protected tenant. Each gets a clone of `cfg`
    /// with its own τ and throughput baseline.
    pub fn multi(cfg: &ControllerConfig, protected: &[Protected]) -> Arbiter {
        let controllers = protected
            .iter()
            .map(|p| {
                let mut c = cfg.clone();
                if let Some(tau) = p.tau_ms {
                    c.tau_ms = tau;
                }
                Controller::for_primary(c, p.tenant).with_base_rps(p.base_rps)
            })
            .collect();
        Arbiter {
            controllers,
            stats: ArbStats::default(),
            guard_owner: BTreeMap::new(),
        }
    }

    pub fn controllers(&self) -> &[Controller] {
        &self.controllers
    }

    pub fn stats(&self) -> ArbStats {
        self.stats
    }

    /// Is more than one tenant under active control?
    pub fn is_multi(&self) -> bool {
        self.controllers.len() > 1
    }

    /// One control-plane tick: every controller evaluates against the
    /// same snapshot/view, then the arbiter decides what commits. Returns
    /// the actions the platform must apply, in order.
    pub fn on_observation(&mut self, snap: &SignalSnapshot, view: &PlannerView) -> Vec<Action> {
        let mut proposals: Vec<(usize, Proposal)> = Vec::new();
        for (k, c) in self.controllers.iter_mut().enumerate() {
            if let Some(p) = c.evaluate(snap, view) {
                proposals.push((k, p));
            }
        }
        let mut out: Vec<Action> = Vec::new();

        // 1. Mandatory rollbacks, in tenant order.
        let mut rolled_back: Option<TenantId> = None;
        for (k, p) in &proposals {
            if p.class == ProposalClass::Mandatory {
                out.extend(self.controllers[*k].commit(snap.t, p));
                rolled_back.get_or_insert(self.controllers[*k].primary());
            }
        }

        // Host-wide serialization: is any change still under validation
        // after this tick's bookkeeping? (A controller that just finished
        // validating moved to Cooldown in `evaluate`, freeing the slot.)
        // A rollback that committed *this tick* also blocks the slot:
        // everyone else planned against the pre-rollback view, and a
        // simultaneous reconfig would confound the restored tenant's p99.
        let validating_tenant = self
            .controllers
            .iter()
            .find(|c| matches!(c.state(), CtlState::Validating { .. }))
            .map(|c| c.primary())
            .or(rolled_back);

        // 2. Guardrails commit immediately; guardrail *relaxations* are
        // filtered by ownership (a controller may only loosen guards it
        // applied itself). Disruptive proposals — upgrades AND
        // relaxation shrinks — go into one pool for step 3. A Relax
        // proposal is by construction either all guard actions or a
        // single disruptive shrink (`evaluate` only plans the shrink
        // when no guard has anything to give back).
        let mut guard_actions: Vec<Action> = Vec::new();
        let mut disruptive: Vec<usize> = Vec::new();
        for (i, (k, p)) in proposals.iter().enumerate() {
            match p.class {
                ProposalClass::Guardrail => {
                    self.note_guard_owner(*k, &p.actions);
                    guard_actions.extend(self.controllers[*k].commit(snap.t, p));
                }
                ProposalClass::Relax if p.is_disruptive() => disruptive.push(i),
                ProposalClass::Relax => {
                    let kept = self.own_guard_relaxes(*k, &p.actions);
                    if kept.is_empty() {
                        // Every action would loosen another controller's
                        // protection: drop the bundle without consuming
                        // the relax bookkeeping — the owners relax their
                        // own guards once *their* tenants are stable.
                        continue;
                    }
                    self.clear_lifted_owners(&kept);
                    // Re-derive the audit kind from what actually
                    // survived the ownership filter.
                    let kind = kept[0].decision_kind();
                    let filtered = Proposal {
                        actions: kept,
                        kind,
                        ..p.clone()
                    };
                    guard_actions.extend(self.controllers[*k].commit(snap.t, &filtered));
                }
                ProposalClass::Upgrade => disruptive.push(i),
                ProposalClass::Mandatory => {}
            }
        }
        out.extend(reconcile_guards(guard_actions));

        // 3. Disruptive pool: at most one isolation change commits per
        // tick. Upgrades outrank relaxes; among upgrades the worst
        // tail-to-SLO ratio wins, ties broken by tenant index.
        if !disruptive.is_empty() {
            if disruptive.len() > 1 {
                self.stats.conflicts += 1;
            }
            if let Some(w) = validating_tenant {
                for &i in &disruptive {
                    let (k, p) = &proposals[i];
                    self.stats.deferrals += 1;
                    self.controllers[*k].defer(snap.t, p, w);
                }
            } else {
                let winner = disruptive
                    .iter()
                    .copied()
                    .max_by(|&a, &b| {
                        let (ka, pa) = &proposals[a];
                        let (kb, pb) = &proposals[b];
                        let rank = |p: &Proposal| u8::from(p.class == ProposalClass::Upgrade);
                        // Upgrades beat relaxes; higher ratio wins; on
                        // ties the lower tenant index wins (max_by keeps
                        // the later element on Equal, so compare indices
                        // in reverse).
                        rank(pa)
                            .cmp(&rank(pb))
                            .then(pa.ratio.total_cmp(&pb.ratio))
                            .then(kb.cmp(ka))
                    })
                    .expect("non-empty disruptive set");
                let winner_tenant = {
                    let (k, p) = &proposals[winner];
                    let acts = self.controllers[*k].commit(snap.t, p);
                    out.extend(acts);
                    self.controllers[*k].primary()
                };
                for &i in &disruptive {
                    if i == winner {
                        continue;
                    }
                    let (k, p) = &proposals[i];
                    self.stats.deferrals += 1;
                    self.controllers[*k].defer(snap.t, p, winner_tenant);
                }
            }
        }

        out
    }

    /// Route a platform actuation outcome back to the controller that
    /// committed the action (disruptive actions carry their protected
    /// tenant). A failed disruptive change restores that controller's
    /// pre-commit state — clearing its `Validating` window, which
    /// releases the host-wide serialization slot on the next tick
    /// (`validating_tenant` is recomputed from controller states).
    pub fn on_action_outcome(
        &mut self,
        t: f64,
        action: &Action,
        outcome: &ActionOutcome,
    ) -> OutcomeFeedback {
        let tenant = match action {
            Action::ChangeIsolation { tenant, .. } | Action::Rollback { tenant } => *tenant,
            _ => return OutcomeFeedback::None,
        };
        match self.controllers.iter_mut().find(|c| c.primary() == tenant) {
            Some(c) => c.on_action_outcome(t, action, outcome),
            None => OutcomeFeedback::None,
        }
    }

    /// How many controllers have degraded to guardrails-only mode.
    pub fn degraded_controllers(&self) -> u64 {
        self.controllers.iter().filter(|c| c.is_degraded()).count() as u64
    }

    /// Record guardrail ownership: the controller whose trigger applied
    /// a throttle/quota is the only one allowed to loosen it later.
    /// Same-tick duplicates overwrite in controller order (reconciled to
    /// the most protective value anyway).
    fn note_guard_owner(&mut self, k: usize, actions: &[Action]) {
        for a in actions {
            match a {
                Action::SetIoThrottle {
                    tenant,
                    cap_gbps: Some(_),
                } => {
                    self.guard_owner.insert((tenant.0, GUARD_IO), k);
                }
                Action::SetMpsQuota { tenant, .. } => {
                    self.guard_owner.insert((tenant.0, GUARD_MPS), k);
                }
                _ => {}
            }
        }
    }

    /// Keep only the relax actions controller `k` is allowed to take:
    /// guards it owns, or guards nobody claimed (e.g. expired throttles
    /// a new tick re-observes).
    fn own_guard_relaxes(&self, k: usize, actions: &[Action]) -> Vec<Action> {
        actions
            .iter()
            .filter(|a| {
                let key = match a {
                    Action::SetIoThrottle {
                        tenant,
                        cap_gbps: None,
                    } => (tenant.0, GUARD_IO),
                    Action::SetMpsQuota { tenant, .. } => (tenant.0, GUARD_MPS),
                    _ => return true,
                };
                match self.guard_owner.get(&key) {
                    Some(&owner) => owner == k,
                    None => true,
                }
            })
            .cloned()
            .collect()
    }

    /// A lifted IO throttle releases its ownership (the next tightener,
    /// whoever it is, becomes the new owner). MPS ownership stays with
    /// the tightener until someone re-tightens — relaxing is stepwise.
    fn clear_lifted_owners(&mut self, actions: &[Action]) {
        for a in actions {
            if let Action::SetIoThrottle {
                tenant,
                cap_gbps: None,
            } = a
            {
                self.guard_owner.remove(&(tenant.0, GUARD_IO));
            }
        }
    }
}

/// Collapse same-tick guardrail duplicates onto one tenant to the most
/// protective value: the tightest IO cap (`Some` beats `None`) and the
/// lowest MPS quota. Order of first occurrence is preserved, so a single
/// controller's action list passes through untouched.
fn reconcile_guards(actions: Vec<Action>) -> Vec<Action> {
    let mut out: Vec<Action> = Vec::new();
    for a in actions {
        match a {
            Action::SetIoThrottle { tenant, cap_gbps } => {
                if let Some(Action::SetIoThrottle { cap_gbps: prev, .. }) =
                    out.iter_mut().find(
                        |x| matches!(x, Action::SetIoThrottle { tenant: t, .. } if *t == tenant),
                    )
                {
                    *prev = match (*prev, cap_gbps) {
                        (Some(a), Some(b)) => Some(a.min(b)),
                        (Some(a), None) | (None, Some(a)) => Some(a),
                        (None, None) => None,
                    };
                } else {
                    out.push(Action::SetIoThrottle { tenant, cap_gbps });
                }
            }
            Action::SetMpsQuota { tenant, quota } => {
                if let Some(Action::SetMpsQuota { quota: prev, .. }) = out.iter_mut().find(
                    |x| matches!(x, Action::SetMpsQuota { tenant: t, .. } if *t == tenant),
                ) {
                    *prev = prev.min(quota);
                } else {
                    out.push(Action::SetMpsQuota { tenant, quota });
                }
            }
            other => out.push(other),
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::controller::config::Levers;
    use crate::gpu::{A100Gpu, InstanceId, MigProfile};
    use crate::telemetry::signals::{LinkSignal, TailStats, TenantSignal};
    use crate::topo::{HostTopology, LinkId};

    use super::super::view::{InstanceView, TenantView};

    /// Two latency-sensitive tenants (0 and 1) on different GPUs of the
    /// same switch, one free spare instance on gpu2 both would like.
    fn duel_view() -> PlannerView {
        let topo = HostTopology::p4d();
        let mut gpus: Vec<A100Gpu> = (0..8).map(A100Gpu::new).collect();
        gpus[0].create_at(MigProfile::P4g40gb, 0).unwrap();
        gpus[1].create_at(MigProfile::P3g40gb, 0).unwrap();
        let spare = gpus[2].create_at(MigProfile::P3g40gb, 0).unwrap();
        let tenant = |id: usize, gpu: usize, profile| TenantView {
            tenant: TenantId(id),
            gpu,
            instance: InstanceId(1),
            profile,
            mps_peers: vec![],
            numa: 0,
            mps_quota: 100.0,
            io_throttle_gbps: None,
        };
        PlannerView {
            topo,
            gpus,
            tenants: vec![
                tenant(0, 0, MigProfile::P4g40gb),
                tenant(1, 1, MigProfile::P3g40gb),
            ],
            free_instances: vec![InstanceView {
                gpu: 2,
                existing: Some(spare),
                profile: MigProfile::P3g40gb,
            }],
            primary_base_rps: 120.0,
        }
    }

    /// Both tenants violating with heavy PCIe pressure on their shared
    /// uplink from a third (bandwidth-heavy) tenant — both controllers
    /// diagnose PciePressure but have no guardrail lever, so both
    /// escalate straight to a placement move toward the gpu2 spare.
    fn duel_snap(p99_a: f64, p99_b: f64) -> SignalSnapshot {
        let ls = |id: usize, p99: f64| TenantSignal {
            tenant: TenantId(id),
            tails: TailStats {
                p50_ms: p99 * 0.5,
                p95_ms: p99 * 0.9,
                p99_ms: p99,
                p999_ms: p99 * 1.2,
                miss_rate: if p99 > 15.0 { 0.2 } else { 0.0 },
                completed: 240,
                rps: 120.0,
            },
            ttft: None,
            pcie_gbps: 0.5,
            block_io_gbps: 0.0,
            active: true,
            stale: false,
        };
        SignalSnapshot {
            t: 0.0,
            dt: 2.0,
            tenants: vec![ls(0, p99_a), ls(1, p99_b)],
            links: (0..6)
                .map(|i| LinkSignal {
                    link: LinkId(i),
                    utilization: if i == 0 { 0.9 } else { 0.05 },
                    gbps: 0.0,
                })
                .collect(),
            gpu_sm_util: vec![0.9; 8],
            numa_io_gbps: vec![0.0, 0.0],
            numa_irq_rate: vec![100.0, 50.0],
        }
    }

    fn duel_arbiter() -> Arbiter {
        let mut cfg = ControllerConfig::with_levers(Levers::placement_only());
        cfg.warmup_obs = 0;
        cfg.dwell_obs = 4;
        cfg.validation_obs = 8;
        Arbiter::multi(
            &cfg,
            &[
                Protected {
                    tenant: TenantId(0),
                    tau_ms: None,
                    base_rps: 120.0,
                },
                Protected {
                    tenant: TenantId(1),
                    tau_ms: Some(15.0),
                    base_rps: 120.0,
                },
            ],
        )
    }

    #[test]
    fn worst_ratio_wins_and_loser_is_deferred() {
        let mut arb = duel_arbiter();
        let view = duel_view();
        // Tenant 1 hurts worse relative to τ: it must win the spare.
        let snap = duel_snap(20.0, 30.0);
        let mut first = Vec::new();
        for _ in 0..10 {
            first = arb.on_observation(&snap, &view);
            if !first.is_empty() {
                break;
            }
        }
        assert_eq!(first.len(), 1, "exactly one upgrade commits: {first:?}");
        assert!(
            matches!(first[0], Action::ChangeIsolation { tenant, .. } if tenant == TenantId(1)),
            "worst-ratio tenant wins, got {first:?}"
        );
        let stats = arb.stats();
        assert_eq!(stats.conflicts, 1, "one contested tick");
        assert!(stats.deferrals >= 1, "loser recorded as deferred");
        // The loser's audit log carries the deferral; the winner's the
        // trigger.
        assert!(arb.controllers()[0].audit().count_edge("defer") >= 1);
        assert_eq!(arb.controllers()[1].audit().count_edge("trigger"), 1);
    }

    #[test]
    fn tie_breaks_by_tenant_index() {
        let mut arb = duel_arbiter();
        let view = duel_view();
        let snap = duel_snap(30.0, 30.0); // identical ratios
        let mut acts = Vec::new();
        for _ in 0..10 {
            acts = arb.on_observation(&snap, &view);
            if !acts.is_empty() {
                break;
            }
        }
        assert!(
            matches!(acts[0], Action::ChangeIsolation { tenant, .. } if tenant == TenantId(0)),
            "tie must go to the lower tenant index, got {acts:?}"
        );
    }

    #[test]
    fn deferred_upgrade_applies_after_winner_validation_expires() {
        let mut arb = duel_arbiter();
        let view = duel_view();
        let snap = duel_snap(20.0, 30.0);
        let mut committed: Vec<(usize, Vec<Action>)> = Vec::new();
        for tick in 0..40 {
            let acts = arb.on_observation(&snap, &view);
            if !acts.is_empty() {
                committed.push((tick, acts));
            }
        }
        // The winner's upgrade lands first; while it validates, the
        // loser is deferred every tick; once the winner's window closes
        // (validation_obs = 8) the loser's upgrade commits.
        let upgrade_tenants: Vec<TenantId> = committed
            .iter()
            .flat_map(|(_, acts)| acts.iter())
            .filter_map(|a| match a {
                Action::ChangeIsolation { tenant, .. } => Some(*tenant),
                _ => None,
            })
            .collect();
        assert!(
            upgrade_tenants.contains(&TenantId(1)),
            "winner committed: {committed:?}"
        );
        assert!(
            upgrade_tenants.contains(&TenantId(0)),
            "deferred upgrade never applied: {committed:?}"
        );
        let w = upgrade_tenants.iter().position(|t| *t == TenantId(1));
        let l = upgrade_tenants.iter().position(|t| *t == TenantId(0));
        assert!(w < l, "winner must commit before the deferred loser");
        assert!(arb.stats().deferrals >= 1);
        assert!(arb.controllers()[0].audit().count_edge("defer") >= 1);
    }

    #[test]
    fn arbitration_is_deterministic() {
        let run = || {
            let mut arb = duel_arbiter();
            let view = duel_view();
            let snap = duel_snap(22.0, 28.0);
            let mut log = Vec::new();
            for _ in 0..60 {
                log.push(format!("{:?}", arb.on_observation(&snap, &view)));
            }
            (log, arb.stats())
        };
        let (la, sa) = run();
        let (lb, sb) = run();
        assert_eq!(la, lb);
        assert_eq!(sa, sb);
    }

    #[test]
    fn reconcile_keeps_most_protective_guard() {
        let t = TenantId(2);
        let out = reconcile_guards(vec![
            Action::SetIoThrottle {
                tenant: t,
                cap_gbps: Some(0.4),
            },
            // Another controller relaxing the same tenant in the same
            // tick must not undo the protection...
            Action::SetIoThrottle {
                tenant: t,
                cap_gbps: None,
            },
            // ...and tighter caps win.
            Action::SetIoThrottle {
                tenant: t,
                cap_gbps: Some(0.2),
            },
            Action::SetMpsQuota {
                tenant: t,
                quota: 70.0,
            },
            Action::SetMpsQuota {
                tenant: t,
                quota: 85.0,
            },
        ]);
        assert_eq!(out.len(), 2);
        let io_ok = matches!(
            out[0],
            Action::SetIoThrottle { cap_gbps: Some(c), .. } if (c - 0.2).abs() < 1e-12
        );
        assert!(io_ok, "{:?}", out[0]);
        let mps_ok = matches!(
            out[1],
            Action::SetMpsQuota { quota, .. } if (quota - 70.0).abs() < 1e-12
        );
        assert!(mps_ok, "{:?}", out[1]);
    }

    #[test]
    fn relaxation_cannot_lift_foreign_guards() {
        let mut arb = duel_arbiter();
        let etl = TenantId(2);
        // Controller 0's trigger throttled the ETL tenant; controller 1
        // tightened a quota on tenant 3.
        arb.note_guard_owner(
            0,
            &[Action::SetIoThrottle {
                tenant: etl,
                cap_gbps: Some(0.3),
            }],
        );
        arb.note_guard_owner(
            1,
            &[Action::SetMpsQuota {
                tenant: TenantId(3),
                quota: 70.0,
            }],
        );
        // Controller 1's relax bundle: lifting 0's throttle is filtered
        // out; loosening its own quota passes.
        let kept = arb.own_guard_relaxes(
            1,
            &[
                Action::SetIoThrottle {
                    tenant: etl,
                    cap_gbps: None,
                },
                Action::SetMpsQuota {
                    tenant: TenantId(3),
                    quota: 85.0,
                },
            ],
        );
        assert_eq!(kept.len(), 1);
        assert!(matches!(kept[0], Action::SetMpsQuota { .. }));
        // The owner itself may lift its throttle, which releases the
        // ownership for whoever tightens next.
        let lift = [Action::SetIoThrottle {
            tenant: etl,
            cap_gbps: None,
        }];
        assert_eq!(arb.own_guard_relaxes(0, &lift).len(), 1);
        arb.clear_lifted_owners(&lift);
        assert_eq!(
            arb.own_guard_relaxes(1, &lift).len(),
            1,
            "unowned guards are anyone's to lift"
        );
    }

    #[test]
    fn single_controller_plane_is_pass_through() {
        // One controller: the arbiter must emit exactly what the bare
        // controller would.
        let mut cfg = ControllerConfig::with_levers(Levers::placement_only());
        cfg.warmup_obs = 0;
        cfg.dwell_obs = 4;
        let mut arb = Arbiter::single(cfg.clone(), TenantId(0));
        let mut bare = Controller::for_primary(cfg, TenantId(0));
        let view = duel_view();
        let snap = duel_snap(25.0, 5.0);
        for _ in 0..50 {
            assert_eq!(
                arb.on_observation(&snap, &view),
                bare.on_observation(&snap, &view)
            );
        }
        assert_eq!(arb.stats(), ArbStats::default());
        assert!(!arb.is_multi());
    }
}
