//! Topology-aware placement heuristic (§2.2.1).
//!
//! "We query GPU and PCIe topology ... to form a simple placement score
//! for each candidate MIG instance. The score penalizes (i) sharing a
//! PCIe root complex with a bandwidth-heavy tenant, (ii) colocating with
//! a NUMA domain exhibiting high block I/O, and (iii) recent IRQ bursts
//! on adjacent CPU cores."

use crate::gpu::MigProfile;
use crate::telemetry::SignalSnapshot;
use crate::tenants::TenantId;

use super::view::{InstanceView, PlannerView};

/// Score weights (α, β, γ) for the three penalty terms, plus a slice-size
/// bonus so bigger candidate profiles win ties.
#[derive(Clone, Copy, Debug)]
pub struct ScoreWeights {
    pub alpha_pcie: f64,
    pub beta_numa_io: f64,
    pub gamma_irq: f64,
    /// Penalty per unit of *lost* service rate μ relative to the largest
    /// candidate (placement must not silently starve compute).
    pub mu_loss: f64,
}

impl Default for ScoreWeights {
    fn default() -> Self {
        ScoreWeights {
            alpha_pcie: 1.0,
            beta_numa_io: 0.6,
            gamma_irq: 0.002,
            mu_loss: 0.8,
        }
    }
}

/// A scored candidate placement.
#[derive(Clone, Debug)]
pub struct Candidate {
    pub gpu: usize,
    pub profile: MigProfile,
    /// Existing free instance (no reconfig) vs must-create (dynamic MIG).
    pub existing: bool,
    pub score: f64,
}

/// Penalty score for placing `tenant` on `gpu` (lower is better).
pub fn placement_score(
    tenant: TenantId,
    gpu: usize,
    profile: MigProfile,
    snap: &SignalSnapshot,
    view: &PlannerView,
    w: &ScoreWeights,
) -> f64 {
    let link = view.topo.link_of_gpu(gpu);
    let numa = view.topo.numa_of_gpu(gpu);

    // (i) bandwidth-heavy tenants sharing the candidate's root complex.
    let mut pcie_pen = 0.0;
    for t in &snap.tenants {
        if t.tenant == tenant || !t.active {
            continue;
        }
        if let Some(tv) = view.tenant(t.tenant) {
            if view.topo.link_of_gpu(tv.gpu) == link {
                pcie_pen += t.pcie_gbps;
            }
        }
    }

    // (ii) NUMA-domain block I/O.
    let io_pen = snap.numa_io_gbps.get(numa).copied().unwrap_or(0.0);

    // (iii) IRQ bursts on adjacent cores.
    let irq_pen = snap.numa_irq_rate.get(numa).copied().unwrap_or(0.0);

    // Slice-size term: losing μ vs the full GPU costs score.
    let mu_pen = (MigProfile::P7g80gb.mu() - profile.mu()) / MigProfile::P7g80gb.mu();

    w.alpha_pcie * pcie_pen + w.beta_numa_io * io_pen + w.gamma_irq * irq_pen + w.mu_loss * mu_pen
}

/// Enumerate and score candidate placements for `tenant`.
///
/// * Existing free instances are always candidates (a pure placement
///   move, no `nvidia-smi mig` call).
/// * If `allow_create`, profiles creatable on free slices are candidates
///   too (dynamic-MIG + placement combined — used for upgrades).
///
/// Returned sorted by ascending score (best first).
pub fn candidates(
    tenant: TenantId,
    snap: &SignalSnapshot,
    view: &PlannerView,
    w: &ScoreWeights,
    allow_create: bool,
    min_profile: MigProfile,
    max_profile: MigProfile,
) -> Vec<Candidate> {
    let mut out = Vec::new();
    for inst in &view.free_instances {
        if inst.profile < min_profile || inst.profile > max_profile {
            continue;
        }
        out.push(Candidate {
            gpu: inst.gpu,
            profile: inst.profile,
            existing: true,
            score: placement_score(tenant, inst.gpu, inst.profile, snap, view, w),
        });
    }
    if allow_create {
        for profile in MigProfile::ALL {
            if profile < min_profile || profile > max_profile {
                continue;
            }
            for gpu in view.creatable_on(profile) {
                out.push(Candidate {
                    gpu,
                    profile,
                    existing: false,
                    // Creation implies an 18s reconfig pause; nudge the
                    // score so equal-quality existing instances win.
                    score: placement_score(tenant, gpu, profile, snap, view, w) + 0.05,
                });
            }
        }
    }
    out.sort_by(|a, b| a.score.total_cmp(&b.score));
    out
}

/// Score of the tenant's *current* placement (for the improvement-margin
/// test: only move when the best candidate wins by a clear margin).
pub fn current_score(
    tenant: TenantId,
    snap: &SignalSnapshot,
    view: &PlannerView,
    w: &ScoreWeights,
) -> Option<f64> {
    let tv = view.tenant(tenant)?;
    let mut s = placement_score(tenant, tv.gpu, tv.profile, snap, view, w);
    // An active MPS peer on the same instance is the worst hot spot of
    // all — naive co-placement. Penalize accordingly so the planner
    // prefers any dedicated candidate.
    for peer in &tv.mps_peers {
        if snap.tenant(*peer).map(|p| p.active).unwrap_or(false) {
            s += 2.0;
        }
    }
    Some(s)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gpu::{A100Gpu, InstanceId};
    use crate::telemetry::signals::{LinkSignal, TailStats, TenantSignal};
    use crate::tenants::spec::{T1, T2};
    use crate::topo::{HostTopology, LinkId};

    fn mk_view() -> PlannerView {
        let topo = HostTopology::p4d();
        let mut gpus: Vec<A100Gpu> = (0..8).map(A100Gpu::new).collect();
        gpus[0].create_at(MigProfile::P4g40gb, 0).unwrap(); // T1 (+T3)
        gpus[0].create_at(MigProfile::P3g40gb, 4).unwrap(); // T2
        gpus[2].create_at(MigProfile::P2g20gb, 0).unwrap(); // spare
        PlannerView {
            topo,
            gpus,
            tenants: vec![
                super::super::view::TenantView {
                    tenant: T1,
                    gpu: 0,
                    instance: InstanceId(1),
                    profile: MigProfile::P4g40gb,
                    mps_peers: vec![],
                    numa: 0,
                    mps_quota: 100.0,
                    io_throttle_gbps: None,
                },
                super::super::view::TenantView {
                    tenant: T2,
                    gpu: 0,
                    instance: InstanceId(2),
                    profile: MigProfile::P3g40gb,
                    mps_peers: vec![],
                    numa: 0,
                    mps_quota: 100.0,
                    io_throttle_gbps: None,
                },
            ],
            free_instances: vec![InstanceView {
                gpu: 2,
                existing: Some(InstanceId(1)),
                profile: MigProfile::P2g20gb,
            }],
            primary_base_rps: 120.0,
        }
    }

    fn mk_snap(t2_pcie: f64, numa0_io: f64) -> SignalSnapshot {
        SignalSnapshot {
            t: 10.0,
            dt: 2.0,
            tenants: vec![
                TenantSignal {
                    tenant: T1,
                    tails: TailStats::default(),
                    ttft: None,
                    pcie_gbps: 0.4,
                    block_io_gbps: 0.0,
                    active: true,
                    stale: false,
                },
                TenantSignal {
                    tenant: T2,
                    tails: TailStats::default(),
                    ttft: None,
                    pcie_gbps: t2_pcie,
                    block_io_gbps: numa0_io,
                    active: true,
                    stale: false,
                },
            ],
            links: (0..6)
                .map(|i| LinkSignal {
                    link: LinkId(i),
                    utilization: if i == 0 { 0.9 } else { 0.05 },
                    gbps: 0.0,
                })
                .collect(),
            gpu_sm_util: vec![0.5; 8],
            numa_io_gbps: vec![numa0_io, 0.0],
            numa_irq_rate: vec![800.0, 50.0],
        }
    }

    #[test]
    fn hot_switch_penalized() {
        let view = mk_view();
        let snap = mk_snap(10.0, 2.0);
        let w = ScoreWeights::default();
        let s_gpu0 = placement_score(T1, 0, MigProfile::P2g20gb, &snap, &view, &w);
        let s_gpu2 = placement_score(T1, 2, MigProfile::P2g20gb, &snap, &view, &w);
        let s_gpu4 = placement_score(T1, 4, MigProfile::P2g20gb, &snap, &view, &w);
        assert!(s_gpu0 > s_gpu2, "same switch as T2 must score worse");
        // gpu4 is on NUMA 1: avoids T2's block-I/O too.
        assert!(s_gpu4 < s_gpu2, "other NUMA should beat same-NUMA");
    }

    #[test]
    fn bigger_profile_preferred_all_else_equal() {
        let view = mk_view();
        let snap = mk_snap(0.0, 0.0);
        let w = ScoreWeights::default();
        let small = placement_score(T1, 4, MigProfile::P1g10gb, &snap, &view, &w);
        let big = placement_score(T1, 4, MigProfile::P3g40gb, &snap, &view, &w);
        assert!(big < small);
    }

    #[test]
    fn candidates_sorted_and_respect_min_profile() {
        let view = mk_view();
        let snap = mk_snap(10.0, 2.0);
        let w = ScoreWeights::default();
        let cands = candidates(T1, &snap, &view, &w, true, MigProfile::P2g20gb, MigProfile::P7g80gb);
        assert!(!cands.is_empty());
        for c in &cands {
            assert!(c.profile >= MigProfile::P2g20gb);
        }
        for pair in cands.windows(2) {
            assert!(pair[0].score <= pair[1].score);
        }
    }

    #[test]
    fn existing_instance_beats_create_on_equal_topology() {
        let view = mk_view();
        let snap = mk_snap(0.0, 0.0);
        let w = ScoreWeights::default();
        let cands = candidates(T1, &snap, &view, &w, true, MigProfile::P2g20gb, MigProfile::P7g80gb);
        let existing = cands
            .iter()
            .find(|c| c.existing && c.gpu == 2 && c.profile == MigProfile::P2g20gb)
            .unwrap();
        let created = cands
            .iter()
            .find(|c| !c.existing && c.gpu == 2 && c.profile == MigProfile::P2g20gb);
        if let Some(created) = created {
            assert!(existing.score < created.score);
        }
    }
}
