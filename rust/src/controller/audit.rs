//! Decision audit log (§2.4: "log all decisions with signal snapshots for
//! audit") — also the data source for Table 4 (move frequency, reconfig
//! durations) and the Figure 3a action timeline.
//!
//! Entries are typed: [`DecisionKind`] / [`DecisionEdge`] are shared with
//! the flight-recorder trace events, so an audit entry and its
//! `TraceEvent::Decision` twin can never disagree on tags. The legacy
//! stringly lookups (`count_kind("mig")`, `count_edge("defer")`) remain
//! as thin shims over `as_str()`.

use crate::trace::{DecisionEdge, DecisionKind};

/// One logged controller decision.
#[derive(Clone, Debug)]
pub struct Decision {
    /// Sim/wall time (seconds).
    pub t: f64,
    /// Observation counter at decision time.
    pub obs: u64,
    /// FSM edge the decision was recorded on.
    pub edge: DecisionEdge,
    /// Typed action kind.
    pub action: DecisionKind,
    /// p99 at decision time (the primary signal snapshot).
    pub p99_ms: f64,
    /// Free-form context (diagnosed cause, comparison values).
    pub detail: String,
}

impl Decision {
    pub fn new(
        t: f64,
        obs: u64,
        edge: DecisionEdge,
        action: DecisionKind,
        p99_ms: f64,
        detail: String,
    ) -> Decision {
        Decision {
            t,
            obs,
            edge,
            action,
            p99_ms,
            detail,
        }
    }
}

/// Append-only decision log with Table-4-style aggregations.
#[derive(Clone, Debug, Default)]
pub struct AuditLog {
    entries: Vec<Decision>,
}

impl AuditLog {
    pub fn new() -> AuditLog {
        AuditLog::default()
    }

    pub fn record(&mut self, d: Decision) {
        self.entries.push(d);
    }

    pub fn entries(&self) -> &[Decision] {
        &self.entries
    }

    /// Stringly shim over the typed kinds ("mig", "placement", ...) —
    /// kept for callers that count by legacy tag.
    pub fn count_kind(&self, kind: &str) -> usize {
        self.entries
            .iter()
            .filter(|e| e.action.as_str() == kind)
            .count()
    }

    /// Entries on one FSM edge ("trigger", "defer", "validate-fail", …) —
    /// the arbitration counters sum `count_edge("defer")` per controller.
    pub fn count_edge(&self, edge: &str) -> usize {
        self.entries
            .iter()
            .filter(|e| e.edge.as_str() == edge)
            .count()
    }

    /// Disruptive moves (placement + mig + rollback) per hour over a run of
    /// `duration_s` — Table 4 reports "< 5 /hr". Deferred proposals carry
    /// a disruptive action kind but never executed, so they don't count;
    /// neither do retry/degraded bookkeeping entries (the attempt they
    /// describe was already counted on its trigger edge).
    pub fn moves_per_hour(&self, duration_s: f64) -> f64 {
        if duration_s <= 0.0 {
            return 0.0;
        }
        let moves = self
            .entries
            .iter()
            .filter(|e| {
                !matches!(
                    e.edge,
                    DecisionEdge::Defer | DecisionEdge::Retry | DecisionEdge::Degraded
                ) && matches!(
                        e.action,
                        DecisionKind::Mig
                            | DecisionKind::Placement
                            | DecisionKind::Rollback
                            | DecisionKind::Relax
                    )
            })
            .count();
        moves as f64 / (duration_s / 3600.0)
    }

    /// Timeline rows for Figure 3a: (t, action kind, p99 at decision).
    pub fn timeline(&self) -> Vec<(f64, DecisionKind, f64)> {
        self.entries
            .iter()
            .filter(|e| e.edge == DecisionEdge::Trigger || e.edge == DecisionEdge::Stable)
            .map(|e| (e.t, e.action, e.p99_ms))
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counts_and_rates() {
        let mut log = AuditLog::new();
        log.record(Decision::new(
            10.0,
            5,
            DecisionEdge::Trigger,
            DecisionKind::IoThrottle,
            20.0,
            String::new(),
        ));
        log.record(Decision::new(
            60.0,
            30,
            DecisionEdge::Trigger,
            DecisionKind::Mig,
            21.0,
            String::new(),
        ));
        log.record(Decision::new(
            90.0,
            45,
            DecisionEdge::ValidateOk,
            DecisionKind::Persist,
            14.0,
            String::new(),
        ));
        // A deferred move never executed: must not count toward the rate.
        log.record(Decision::new(
            95.0,
            48,
            DecisionEdge::Defer,
            DecisionKind::Placement,
            21.0,
            String::new(),
        ));
        // The stringly shims still answer by legacy tag.
        assert_eq!(log.count_kind("mig"), 1);
        assert_eq!(log.count_kind("io_throttle"), 1);
        assert_eq!(log.count_edge("defer"), 1);
        // 1 disruptive move in 1800 s = 2/hr.
        assert!((log.moves_per_hour(1800.0) - 2.0).abs() < 1e-12);
        let tl = log.timeline();
        assert_eq!(tl.len(), 2);
        assert_eq!(tl[1].1, DecisionKind::Mig);
    }
}
