//! The decision state machine (Algorithm 1 + §2.3 policy).
//!
//! Escalation ladder on persistent tail violations (Figure 3a):
//! guardrails → placement move → MIG resize; each disruptive action opens
//! a validation window with rollback, then a cool-down. A relaxation path
//! shrinks isolation again after sustained stability (and returns
//! guardrails to their defaults).
//!
//! The tick is split into two halves so the multi-primary control plane
//! ([`super::arbiter::Arbiter`]) can interpose between *wanting* and
//! *doing*:
//!
//! * [`Controller::evaluate`] advances per-tick bookkeeping (observation
//!   counter, persistence, validation/cool-down edges) and returns a
//!   [`Proposal`] describing what the controller wants to do — without
//!   committing any action-linked state.
//! * [`Controller::commit`] applies the state transition tied to actually
//!   emitting the proposal (dwell clocks, persistence reset, the
//!   `Validating` window, audit record) and returns the actions.
//! * [`Controller::defer`] records an arbitration loss in the audit log
//!   and leaves all decision state untouched, so a deferred upgrade is
//!   re-planned — against the *current* host state — on the next tick.
//!
//! [`Controller::on_observation`] is `evaluate` + `commit` fused, which
//! is exactly the pre-arbiter single-primary behavior.

use crate::gpu::MigProfile;
use crate::telemetry::SignalSnapshot;
use crate::tenants::spec::T1;
use crate::tenants::TenantId;
use crate::trace::{DecisionEdge, DecisionKind};
use crate::util::ewma::Persistence;

use super::actions::{Action, ActionOutcome, IsolationChange};
use super::audit::{AuditLog, Decision};
use super::config::{ControllerConfig, SloKind};
use super::diagnose::{diagnose, Cause};
use super::guardrails;
use super::placement::{self, ScoreWeights};
use super::view::PlannerView;

/// Controller FSM state (the `W`/`C`/`T_cd` of Algorithm 1 live in
/// [`Controller`]).
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum CtlState {
    Stable,
    /// A disruptive change was applied; watching the post-change window.
    Validating { started_obs: u64, prev_p99: f64 },
    /// Grace period after a change persisted / rolled back.
    Cooldown { until_obs: u64 },
}

/// How a [`Proposal`] interacts with arbitration.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ProposalClass {
    /// Validation-mandated rollback: the FSM edge already happened, the
    /// action must reach the platform. Never arbitrated.
    Mandatory,
    /// Lightweight guardrail (MPS quota / IO throttle): non-disruptive,
    /// commits immediately (the arbiter only reconciles duplicates).
    Guardrail,
    /// Disruptive isolation upgrade (move / resize): subject to
    /// arbitration when several controllers compete.
    Upgrade,
    /// Relaxation bundle after sustained stability. May contain a
    /// disruptive shrink, which is held while another tenant's change is
    /// still under validation.
    Relax,
}

/// What one controller wants to do this tick, before arbitration.
#[derive(Clone, Debug)]
pub struct Proposal {
    /// Actions to apply if the proposal wins, in order.
    pub actions: Vec<Action>,
    pub class: ProposalClass,
    /// Audit fields recorded on commit.
    pub edge: DecisionEdge,
    pub kind: DecisionKind,
    pub detail: String,
    /// p99 at decision time (also the `prev_p99` a validation window
    /// compares against for upgrades).
    pub p99_ms: f64,
    /// Arbitration priority: tail-to-SLO ratio `p99 / τ` — the tenant
    /// hurting worst relative to its own SLO wins (ties: tenant index).
    pub ratio: f64,
}

impl Proposal {
    /// Does committing this proposal pause a tenant somewhere?
    pub fn is_disruptive(&self) -> bool {
        self.actions.iter().any(Action::is_disruptive)
    }
}

/// What the control plane did in response to a reported actuation
/// outcome — the platform uses this to emit `ActionRetry` trace events
/// and count degraded controllers.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum OutcomeFeedback {
    /// Nothing to do (success, or a non-disruptive action).
    None,
    /// The failure was absorbed: decision state restored, a backed-off
    /// retry scheduled (`attempt` = consecutive failures so far).
    Retried { attempt: u32 },
    /// Retries exhausted: the controller degraded to guardrails-only.
    Degraded,
}

/// Decision state snapshotted at commit time so a *failed* disruptive
/// actuation can be un-committed: the change never happened, so the
/// dwell clock must not burn and the validation window must not open.
#[derive(Clone, Copy, Debug)]
struct PreCommit {
    last_disruptive_obs: i64,
    state: CtlState,
    stable_streak: u64,
    guard_attempts: u32,
    p99_ms: f64,
}

/// The multi-tenancy controller.
pub struct Controller {
    pub cfg: ControllerConfig,
    state: CtlState,
    obs: u64,
    last_disruptive_obs: i64,
    last_guard_obs: i64,
    persistence: Persistence,
    stable_streak: u64,
    /// Guardrail attempts since the last isolation change — "throttling
    /// does not resolve the issue" escalation memory (§2.3).
    guard_attempts: u32,
    weights: ScoreWeights,
    audit: AuditLog,
    primary: TenantId,
    /// Baseline throughput for the ≥95% budget check. `None` falls back
    /// to `PlannerView::primary_base_rps` (the single-primary path);
    /// secondary controllers in a multi-primary plane carry their own.
    base_rps: Option<f64>,
    /// Stash for un-committing a disruptive change the platform failed.
    pre_commit: Option<PreCommit>,
    /// Consecutive failed disruptive actuations (reset on success).
    retry_attempts: u32,
    /// No disruptive proposal before this observation (exponential
    /// backoff after a failed actuation).
    retry_next_obs: u64,
    /// Retries exhausted: guardrails-only for the rest of the run.
    degraded: bool,
    /// Consecutive observations the primary's signal has been a
    /// held-last (stale) copy — sensor-dropout fault handling.
    stale_streak: u64,
}

impl Controller {
    /// Controller protecting the canonical primary slot (`T1`).
    pub fn new(cfg: ControllerConfig) -> Controller {
        Controller::for_primary(cfg, T1)
    }

    /// Controller protecting an arbitrary latency-sensitive tenant (the
    /// N-tenant scenarios choose the primary per scenario).
    pub fn for_primary(cfg: ControllerConfig, primary: TenantId) -> Controller {
        Controller {
            persistence: Persistence::new(cfg.tau_ms, cfg.persistence_y),
            cfg,
            state: CtlState::Stable,
            obs: 0,
            last_disruptive_obs: i64::MIN / 2,
            last_guard_obs: i64::MIN / 2,
            stable_streak: 0,
            guard_attempts: 0,
            weights: ScoreWeights::default(),
            audit: AuditLog::new(),
            primary,
            base_rps: None,
            pre_commit: None,
            retry_attempts: 0,
            retry_next_obs: 0,
            degraded: false,
            stale_streak: 0,
        }
    }

    /// Set this controller's own baseline throughput (req/s) for the
    /// throughput-budget check — used by the multi-primary control plane,
    /// where `PlannerView::primary_base_rps` describes a different tenant.
    pub fn with_base_rps(mut self, rps: f64) -> Controller {
        self.base_rps = Some(rps);
        self
    }

    /// Which tenant this controller protects.
    pub fn primary(&self) -> TenantId {
        self.primary
    }

    pub fn state(&self) -> CtlState {
        self.state
    }

    pub fn audit(&self) -> &AuditLog {
        &self.audit
    }

    pub fn observations(&self) -> u64 {
        self.obs
    }

    /// Has this controller fallen back to guardrails-only mode after
    /// exhausting its actuation retries?
    pub fn is_degraded(&self) -> bool {
        self.degraded
    }

    /// Consecutive failed disruptive actuations (0 after any success).
    pub fn retry_attempts(&self) -> u32 {
        self.retry_attempts
    }

    fn dwell_ok(&self) -> bool {
        self.obs as i64 - self.last_disruptive_obs >= self.cfg.dwell_obs as i64
    }

    /// May this controller plan a *disruptive* change right now? False
    /// while degraded, inside a retry backoff window, or when the
    /// primary's signal has been stale past its TTL (guardrails stay
    /// available on all three paths — protection never disarms).
    fn may_disrupt(&self) -> bool {
        !self.degraded
            && self.obs >= self.retry_next_obs
            && self.stale_streak <= self.cfg.stale_ttl_obs
    }

    fn guard_dwell_ok(&self) -> bool {
        // Guardrails are lightweight; allow them 4× as often as
        // disruptive changes but still rate-limited.
        self.obs as i64 - self.last_guard_obs >= (self.cfg.dwell_obs / 4) as i64
    }

    fn throughput_ok(&self, snap: &SignalSnapshot, view: &PlannerView) -> bool {
        let Some(t1) = snap.tenant(self.primary) else {
            return false;
        };
        let base = self.base_rps.unwrap_or(view.primary_base_rps);
        t1.tails.rps >= (1.0 - self.cfg.throughput_budget) * base
    }

    /// One observation tick (Algorithm 1 `OnObservation`). Returns the
    /// actions the platform must apply, in order. Equivalent to
    /// [`Controller::evaluate`] immediately followed by
    /// [`Controller::commit`] — the single-primary path.
    pub fn on_observation(&mut self, snap: &SignalSnapshot, view: &PlannerView) -> Vec<Action> {
        match self.evaluate(snap, view) {
            Some(p) => self.commit(snap.t, &p),
            None => Vec::new(),
        }
    }

    /// First half of a tick: advance per-observation bookkeeping
    /// (observation counter, persistence streak, validation/cool-down
    /// edges — including their audit entries, since those transitions
    /// are mandatory) and decide what this controller *wants* to do.
    /// Proposal-linked state (dwell clocks, persistence reset, the
    /// `Validating` window, the trigger/stable audit record) is NOT
    /// touched — that happens in [`Controller::commit`], or not at all
    /// if the arbiter defers.
    pub fn evaluate(&mut self, snap: &SignalSnapshot, view: &PlannerView) -> Option<Proposal> {
        self.obs += 1;
        let t1sig = snap.tenant(self.primary)?;
        // Sensor-dropout handling: the platform holds the last-known
        // signal and flags it stale. Within `stale_ttl_obs` the
        // controller trusts the held values (minus relaxation); past
        // the TTL, `may_disrupt` blocks isolation changes until a
        // fresh window arrives.
        if t1sig.stale {
            self.stale_streak += 1;
        } else {
            self.stale_streak = 0;
        }
        // The objective tail: TTFT for request-granularity LLM tenants
        // under `SloKind::Ttft` (falling back to e2e tails when the
        // tenant reports none), e2e otherwise. The throughput-budget
        // check always stays on the e2e window.
        let obj = match self.cfg.objective {
            SloKind::Ttft => t1sig.ttft.as_ref().unwrap_or(&t1sig.tails),
            SloKind::E2e => &t1sig.tails,
        };
        let p99 = obj.p99_ms;
        let ratio = p99 / self.cfg.tau_ms;
        let triggered = self.persistence.observe(p99) && obj.completed > 0;
        if p99 <= self.cfg.tau_ms * self.cfg.relax_frac && obj.completed > 0 {
            self.stable_streak += 1;
        } else {
            self.stable_streak = 0;
        }

        // --- validation / cooldown bookkeeping -----------------------------
        match self.state {
            CtlState::Validating { started_obs, prev_p99 } => {
                if self.obs - started_obs >= self.cfg.validation_obs {
                    if p99 > prev_p99 * 1.02 && obj.completed > 0 {
                        // Post-change p99 worsened: roll back (§2.4). The
                        // FSM edge is taken here — a rollback is mandatory
                        // and never arbitrated away.
                        self.state = CtlState::Cooldown {
                            until_obs: self.obs + self.cfg.cooldown_obs,
                        };
                        let act = Action::Rollback {
                            tenant: self.primary,
                        };
                        let kind = act.decision_kind();
                        self.audit.record(Decision::new(
                            snap.t,
                            self.obs,
                            DecisionEdge::ValidateFail,
                            kind,
                            p99,
                            format!("p99 {p99:.2} > pre-change {prev_p99:.2}"),
                        ));
                        return Some(Proposal {
                            actions: vec![act],
                            class: ProposalClass::Mandatory,
                            edge: DecisionEdge::ValidateFail,
                            kind,
                            detail: String::new(),
                            p99_ms: p99,
                            ratio,
                        });
                    }
                    self.audit.record(Decision::new(
                        snap.t,
                        self.obs,
                        DecisionEdge::ValidateOk,
                        DecisionKind::Persist,
                        p99,
                        format!("p99 {p99:.2} vs pre-change {prev_p99:.2}"),
                    ));
                    self.state = CtlState::Cooldown {
                        until_obs: self.obs + self.cfg.cooldown_obs,
                    };
                }
                return None;
            }
            CtlState::Cooldown { until_obs } => {
                if self.obs >= until_obs {
                    self.state = CtlState::Stable;
                } else {
                    return None; // is_cooling_down(): no actions.
                }
            }
            CtlState::Stable => {}
        }

        if !self.cfg.levers.any() {
            return None; // static baseline: observe only.
        }
        // Warmup: tiny cold-start windows produce noisy quantiles; never
        // act on them (a real deployment samples for a minute first).
        if self.obs < self.cfg.warmup_obs {
            return None;
        }

        // --- escalation on persistent violation ----------------------------
        if triggered {
            let cause = diagnose(self.primary, snap, view);
            // Rung 1: guardrails (lightweight, non-disruptive).
            if self.cfg.levers.guardrails && self.guard_dwell_ok() {
                if let Some(act) = self.try_guardrail(cause, snap, view) {
                    return Some(Proposal {
                        edge: DecisionEdge::Trigger,
                        kind: act.decision_kind(),
                        detail: format!("{cause:?}"),
                        actions: vec![act],
                        class: ProposalClass::Guardrail,
                        p99_ms: p99,
                        ratio,
                    });
                }
            }
            // Rungs 2-3: isolation upgrade (move first, then resize —
            // §2.2.1), once guards are exhausted/ineffective/disabled.
            // Disruptive changes additionally require a *material* SLO
            // problem (window miss-rate above 2%): a p99 hovering a hair
            // over τ is not worth a pause, and this is what keeps the
            // Table-4 move budget under 5/hour.
            let material = obj.miss_rate > self.cfg.material_miss;
            if self.dwell_ok() && material && self.may_disrupt() {
                if let Some(act) = self.plan_isolation_upgrade(cause, snap, view) {
                    return Some(Proposal {
                        edge: DecisionEdge::Trigger,
                        kind: act.decision_kind(),
                        detail: format!("{cause:?}"),
                        actions: vec![act],
                        class: ProposalClass::Upgrade,
                        p99_ms: p99,
                        ratio,
                    });
                }
            }
            return None;
        }

        // --- relaxation path -----------------------------------------------
        // Never relax on a held-last signal: "stable" numbers from a
        // dropped-out sensor prove nothing.
        if self.stable_streak >= self.cfg.stable_obs
            && self.dwell_ok()
            && !t1sig.stale
            && self.throughput_ok(snap, view)
        {
            let mut acts = Vec::new();
            // Return guardrails toward defaults first (cheap). Propose a
            // lift for *every* active throttle: under multi-primary
            // arbitration, ownership filtering keeps only the ones this
            // controller applied — a first-match scan could wedge on a
            // foreign guard forever. (Single-primary runs never hold more
            // than one throttle at once: the guard dwell outlasts the
            // bounded throttle window.)
            if self.cfg.levers.guardrails {
                for tv in view.tenants.iter().filter(|t| t.io_throttle_gbps.is_some()) {
                    acts.push(Action::SetIoThrottle {
                        tenant: tv.tenant,
                        cap_gbps: None,
                    });
                }
                for tv in &view.tenants {
                    if tv.tenant != self.primary && tv.mps_quota < self.cfg.mps_quota_max {
                        if let Some(q) = guardrails::relax_mps(&self.cfg, tv.mps_quota) {
                            acts.push(Action::SetMpsQuota {
                                tenant: tv.tenant,
                                quota: q,
                            });
                        }
                    }
                }
            }
            if acts.is_empty() && self.cfg.levers.dynamic_mig && self.may_disrupt() {
                if let Some(act) = self.plan_relax(snap, view) {
                    acts.push(act);
                }
            }
            if !acts.is_empty() {
                return Some(Proposal {
                    edge: DecisionEdge::Stable,
                    kind: acts[0].decision_kind(),
                    detail: "relaxation".to_string(),
                    actions: acts,
                    class: ProposalClass::Relax,
                    p99_ms: p99,
                    ratio,
                });
            }
        }

        None
    }

    /// Second half of a tick: take the state transition tied to actually
    /// emitting `p` (dwell clocks, persistence reset, validation window,
    /// audit record) and return its actions for the platform.
    pub fn commit(&mut self, t: f64, p: &Proposal) -> Vec<Action> {
        // Snapshot the decision state a *failed* actuation must restore
        // (`on_action_outcome`). Only disruptive classes can fail.
        let saved = PreCommit {
            last_disruptive_obs: self.last_disruptive_obs,
            state: self.state,
            stable_streak: self.stable_streak,
            guard_attempts: self.guard_attempts,
            p99_ms: p.p99_ms,
        };
        match p.class {
            // Rollbacks took their FSM edge (and audit entry) in
            // `evaluate`; nothing further to record.
            ProposalClass::Mandatory => return p.actions.clone(),
            ProposalClass::Guardrail => {
                self.last_guard_obs = self.obs as i64;
                self.guard_attempts += 1;
                self.persistence.reset(); // give the guard Y windows to work
            }
            ProposalClass::Upgrade => {
                self.pre_commit = Some(saved);
                self.last_disruptive_obs = self.obs as i64;
                self.guard_attempts = 0;
                self.persistence.reset();
                self.state = CtlState::Validating {
                    started_obs: self.obs,
                    prev_p99: p.p99_ms,
                };
            }
            ProposalClass::Relax => {
                self.pre_commit = Some(saved);
                self.stable_streak = 0;
                self.last_disruptive_obs = self.obs as i64;
                self.state = CtlState::Cooldown {
                    until_obs: self.obs + self.cfg.cooldown_obs,
                };
            }
        }
        self.audit.record(Decision::new(
            t,
            self.obs,
            p.edge,
            p.kind,
            p.p99_ms,
            p.detail.clone(),
        ));
        p.actions.clone()
    }

    /// Record an arbitration loss: the proposal is *deferred*, not
    /// dropped. Decision state stays untouched (persistence keeps firing,
    /// the dwell clock is not consumed), so the controller re-plans
    /// against the post-winner host state on a later tick.
    pub fn defer(&mut self, t: f64, p: &Proposal, winner: TenantId) {
        self.audit.record(Decision::new(
            t,
            self.obs,
            DecisionEdge::Defer,
            p.kind,
            p.p99_ms,
            format!("lost arbitration to tenant {}", winner.0),
        ));
    }

    /// Platform feedback for a committed action (fault hardening). On
    /// success, clears the retry counter. On failure/timeout of a
    /// disruptive change, restores the pre-commit decision state — the
    /// change never happened, so the dwell clock is un-burned and the
    /// `Validating` window closed (which releases the arbiter's
    /// host-wide serialization slot next tick) — then schedules a
    /// bounded-exponential-backoff retry, or degrades to
    /// guardrails-only mode once `cfg.max_action_retries` consecutive
    /// failures pile up. The persistence streak stays reset either
    /// way: the violation must re-fire for Y windows before the retry
    /// lands, which paces retries under sustained pressure.
    ///
    /// The audit edge is never silent: every absorbed failure records
    /// `retry`, exhaustion records `degraded`.
    pub fn on_action_outcome(
        &mut self,
        t: f64,
        action: &Action,
        outcome: &ActionOutcome,
    ) -> OutcomeFeedback {
        if outcome.is_applied() {
            if action.is_disruptive() {
                self.pre_commit = None;
                self.retry_attempts = 0;
            }
            return OutcomeFeedback::None;
        }
        if !action.is_disruptive() {
            return OutcomeFeedback::None; // guardrails cannot fail today
        }
        let p99 = match self.pre_commit.take() {
            Some(saved) => {
                self.last_disruptive_obs = saved.last_disruptive_obs;
                self.state = saved.state;
                self.stable_streak = saved.stable_streak;
                self.guard_attempts = saved.guard_attempts;
                saved.p99_ms
            }
            // Mandatory rollbacks carry no stash (they are modeled as
            // reliable); audit the failure without a state restore.
            None => 0.0,
        };
        self.retry_attempts += 1;
        let reason = match outcome {
            ActionOutcome::Failed { reason } => *reason,
            ActionOutcome::TimedOut => "timed out",
            ActionOutcome::Applied => unreachable!("applied handled above"),
        };
        let kind = action.decision_kind();
        if self.retry_attempts > self.cfg.max_action_retries {
            self.degraded = true;
            self.audit.record(Decision::new(
                t,
                self.obs,
                DecisionEdge::Degraded,
                kind,
                p99,
                format!(
                    "{reason}; {} consecutive failures — guardrails-only",
                    self.retry_attempts
                ),
            ));
            return OutcomeFeedback::Degraded;
        }
        // Bounded exponential backoff: 2, 4, 8, ... observations,
        // capped at 64 — composes with dwell (which was restored) and
        // with persistence (which must re-fire).
        let backoff = 1u64 << self.retry_attempts.min(6);
        self.retry_next_obs = self.obs + backoff;
        self.audit.record(Decision::new(
            t,
            self.obs,
            DecisionEdge::Retry,
            kind,
            p99,
            format!(
                "{reason}; attempt {}; backoff {backoff} obs",
                self.retry_attempts
            ),
        ));
        OutcomeFeedback::Retried {
            attempt: self.retry_attempts,
        }
    }

    /// Rung 1: choose a guardrail for the diagnosed cause.
    fn try_guardrail(
        &self,
        cause: Cause,
        snap: &SignalSnapshot,
        view: &PlannerView,
    ) -> Option<Action> {
        match cause {
            Cause::PciePressure { culprit } | Cause::IoPressure { culprit } => {
                let already = view
                    .tenant(culprit)
                    .and_then(|t| t.io_throttle_gbps)
                    .is_some();
                if already {
                    return None; // throttle in place and still violating.
                }
                Some(Action::SetIoThrottle {
                    tenant: culprit,
                    cap_gbps: Some(guardrails::pick_io_throttle(&self.cfg, snap, culprit)),
                })
            }
            Cause::ComputeContention { culprit } => {
                let quota = view.tenant(culprit).map(|t| t.mps_quota)?;
                let next = guardrails::tighten_mps(&self.cfg, quota)?;
                Some(Action::SetMpsQuota {
                    tenant: culprit,
                    quota: next,
                })
            }
            Cause::Unattributed => None,
        }
    }

    /// Rungs 2-3 (§2.2.1): intra-host move to the least-penalized instance
    /// first; enlarge the MIG slice only if no move is good enough.
    fn plan_isolation_upgrade(
        &self,
        cause: Cause,
        snap: &SignalSnapshot,
        view: &PlannerView,
    ) -> Option<Action> {
        let me = view.tenant(self.primary)?;
        let cur_score = placement::current_score(self.primary, snap, view, &self.weights)?;

        // Greedy one-notch isolation bound (§2.5.2: upgrades step through
        // M; never jump to max isolation): a shared instance counts as
        // roughly half its profile for budgeting purposes.
        let shared = !me.mps_peers.is_empty();
        let effective = if shared {
            MigProfile::P2g20gb
        } else {
            me.profile
        };
        let max_profile = effective.upgrade().unwrap_or(effective);

        // Placement rung: consider existing instances always; creatable
        // slots only with dynamic MIG.
        if self.cfg.levers.placement {
            let min_profile = MigProfile::P1g10gb;
            let cands = placement::candidates(
                self.primary,
                snap,
                view,
                &self.weights,
                self.cfg.levers.dynamic_mig,
                min_profile,
                max_profile,
            );
            if let Some(best) = cands.first() {
                if best.score < cur_score - self.cfg.placement_margin {
                    let change = if best.existing {
                        IsolationChange::MoveExisting {
                            gpu: best.gpu,
                            to: best.profile,
                        }
                    } else {
                        IsolationChange::CreateAndMove {
                            gpu: best.gpu,
                            to: best.profile,
                        }
                    };
                    return Some(Action::ChangeIsolation {
                        tenant: self.primary,
                        change,
                        relax: false,
                    });
                }
            }
        }

        // MIG rung: dedicate/enlarge in place.
        if self.cfg.levers.dynamic_mig {
            let shared = !me.mps_peers.is_empty();
            let gpu = &view.gpus[me.gpu];
            if shared {
                // Carve a dedicated slice out of the shared instance: pick
                // the biggest profile that fits in the freed slices while
                // leaving at least one slice for the peer.
                let freed = me.profile.compute_slices();
                let target = [MigProfile::P3g40gb, MigProfile::P2g20gb, MigProfile::P1g10gb]
                    .into_iter()
                    .find(|p| p.compute_slices() + 1 <= freed)?;
                return Some(Action::ChangeIsolation {
                    tenant: self.primary,
                    change: IsolationChange::Resize { to: target },
                    relax: false,
                });
            }
            if matches!(cause, Cause::ComputeContention { .. } | Cause::Unattributed)
                || self.guard_attempts > 0
                || !self.cfg.levers.guardrails
            {
                if let Some(bigger) = me.profile.upgrade() {
                    if gpu.can_place_after_destroy(bigger, me.instance) {
                        return Some(Action::ChangeIsolation {
                            tenant: self.primary,
                            change: IsolationChange::Resize { to: bigger },
                            relax: false,
                        });
                    }
                }
            }
        }
        None
    }

    /// Relaxation: shrink one step if the smaller profile's placement
    /// score stays below a conservative threshold (§2.2.1 last sentence).
    fn plan_relax(&self, snap: &SignalSnapshot, view: &PlannerView) -> Option<Action> {
        let me = view.tenant(self.primary)?;
        if !me.mps_peers.is_empty() {
            return None; // already shared: nothing to give back.
        }
        let smaller = me.profile.relax()?;
        if smaller < MigProfile::P2g20gb {
            return None; // conservative floor for the latency tenant.
        }
        let score =
            placement::placement_score(self.primary, me.gpu, smaller, snap, view, &self.weights);
        if score > 1.0 {
            return None; // §2.2.1: only relax when the score stays low.
        }
        Some(Action::ChangeIsolation {
            tenant: self.primary,
            change: IsolationChange::Resize { to: smaller },
            relax: true,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::controller::config::Levers;
    use crate::gpu::{A100Gpu, InstanceId};
    use crate::telemetry::signals::{LinkSignal, TailStats, TenantSignal};
    use crate::tenants::spec::{T2, T3};
    use crate::topo::{HostTopology, LinkId};

    fn mk_view(shared: bool) -> PlannerView {
        let topo = HostTopology::p4d();
        let mut gpus: Vec<A100Gpu> = (0..8).map(A100Gpu::new).collect();
        gpus[0].create_at(MigProfile::P4g40gb, 0).unwrap();
        gpus[0].create_at(MigProfile::P3g40gb, 4).unwrap();
        gpus[2].create_at(MigProfile::P2g20gb, 0).unwrap();
        PlannerView {
            topo,
            gpus,
            tenants: vec![
                super::super::view::TenantView {
                    tenant: T1,
                    gpu: 0,
                    instance: InstanceId(1),
                    profile: MigProfile::P4g40gb,
                    mps_peers: if shared { vec![T3] } else { vec![] },
                    numa: 0,
                    mps_quota: 100.0,
                    io_throttle_gbps: None,
                },
                super::super::view::TenantView {
                    tenant: T2,
                    gpu: 0,
                    instance: InstanceId(2),
                    profile: MigProfile::P3g40gb,
                    mps_peers: vec![],
                    numa: 0,
                    mps_quota: 100.0,
                    io_throttle_gbps: None,
                },
                super::super::view::TenantView {
                    tenant: T3,
                    gpu: 0,
                    instance: InstanceId(1),
                    profile: MigProfile::P4g40gb,
                    mps_peers: if shared { vec![T1] } else { vec![] },
                    numa: 0,
                    mps_quota: 100.0,
                    io_throttle_gbps: None,
                },
            ],
            free_instances: vec![super::super::view::InstanceView {
                gpu: 2,
                existing: Some(InstanceId(1)),
                profile: MigProfile::P2g20gb,
            }],
            primary_base_rps: 120.0,
        }
    }

    fn mk_snap(p99: f64, t2_active: bool, t3_active: bool) -> SignalSnapshot {
        SignalSnapshot {
            t: 0.0,
            dt: 2.0,
            tenants: vec![
                TenantSignal {
                    tenant: T1,
                    tails: TailStats {
                        p50_ms: p99 * 0.5,
                        p95_ms: p99 * 0.9,
                        p99_ms: p99,
                        p999_ms: p99 * 1.2,
                        miss_rate: if p99 > 15.0 { 0.2 } else { 0.0 },
                        completed: 240,
                        rps: 120.0,
                    },
                    ttft: None,
                    pcie_gbps: 0.5,
                    block_io_gbps: 0.1,
                    active: true,
                    stale: false,
                },
                TenantSignal {
                    tenant: T2,
                    tails: TailStats::default(),
                    ttft: None,
                    pcie_gbps: if t2_active { 8.0 } else { 0.0 },
                    block_io_gbps: if t2_active { 2.0 } else { 0.0 },
                    active: t2_active,
                    stale: false,
                },
                TenantSignal {
                    tenant: T3,
                    tails: TailStats::default(),
                    ttft: None,
                    pcie_gbps: 0.05,
                    block_io_gbps: 0.0,
                    active: t3_active,
                    stale: false,
                },
            ],
            links: (0..6)
                .map(|i| LinkSignal {
                    link: LinkId(i),
                    utilization: if i == 0 && t2_active { 0.9 } else { 0.05 },
                    gbps: 0.0,
                })
                .collect(),
            gpu_sm_util: vec![0.9; 8],
            numa_io_gbps: vec![if t2_active { 2.0 } else { 0.0 }, 0.0],
            numa_irq_rate: vec![400.0, 50.0],
        }
    }

    fn no_warmup(mut cfg: ControllerConfig) -> ControllerConfig {
        cfg.warmup_obs = 0;
        cfg
    }

    fn run_until_action(
        ctl: &mut Controller,
        snap: &SignalSnapshot,
        view: &PlannerView,
        max_obs: usize,
    ) -> Option<Vec<Action>> {
        for _ in 0..max_obs {
            let acts = ctl.on_observation(snap, view);
            if !acts.is_empty() {
                return Some(acts);
            }
        }
        None
    }

    #[test]
    fn baseline_never_acts() {
        let mut ctl = Controller::new(no_warmup(ControllerConfig::with_levers(Levers::none())));
        let view = mk_view(true);
        let snap = mk_snap(25.0, true, true);
        assert!(run_until_action(&mut ctl, &snap, &view, 2000).is_none());
    }

    #[test]
    fn persistence_gates_trigger() {
        let mut ctl = Controller::new(no_warmup(ControllerConfig::default()));
        let view = mk_view(true);
        let hot = mk_snap(25.0, true, true);
        // First two violations: no action (Y = 3).
        assert!(ctl.on_observation(&hot, &view).is_empty());
        assert!(ctl.on_observation(&hot, &view).is_empty());
        // Third consecutive violation triggers the first rung.
        let acts = ctl.on_observation(&hot, &view);
        assert_eq!(acts.len(), 1);
    }

    #[test]
    fn first_action_is_guardrail_under_compute_contention() {
        let mut ctl = Controller::new(no_warmup(ControllerConfig::default()));
        let view = mk_view(true);
        let hot = mk_snap(25.0, false, true); // only T3 active
        let acts = run_until_action(&mut ctl, &hot, &view, 10).unwrap();
        assert!(
            matches!(acts[0], Action::SetMpsQuota { tenant, .. } if tenant == T3),
            "expected MPS quota first, got {acts:?}"
        );
    }

    #[test]
    fn io_throttle_for_pcie_pressure() {
        let mut ctl = Controller::new(no_warmup(ControllerConfig::default()));
        let view = mk_view(false); // dedicated: no compute contention
        let hot = mk_snap(25.0, true, false);
        let acts = run_until_action(&mut ctl, &hot, &view, 10).unwrap();
        assert!(
            matches!(acts[0], Action::SetIoThrottle { tenant, cap_gbps: Some(_) } if tenant == T2),
            "expected IO throttle, got {acts:?}"
        );
    }

    #[test]
    fn guards_escalate_to_isolation() {
        let mut cfg = ControllerConfig::default();
        cfg.dwell_obs = 8; // speed the test up
        let mut ctl = Controller::new(no_warmup(cfg));
        let mut view = mk_view(true);
        let hot = mk_snap(25.0, true, true);
        let mut kinds = Vec::new();
        for _ in 0..400 {
            for a in ctl.on_observation(&hot, &view) {
                kinds.push(a.kind());
                // Reflect guardrail state so the controller sees its own
                // actions (platform behavior).
                match a {
                    Action::SetMpsQuota { tenant, quota } => {
                        for tv in view.tenants.iter_mut() {
                            if tv.tenant == tenant {
                                tv.mps_quota = quota;
                            }
                        }
                    }
                    Action::SetIoThrottle { tenant, cap_gbps } => {
                        for tv in view.tenants.iter_mut() {
                            if tv.tenant == tenant {
                                tv.io_throttle_gbps = cap_gbps;
                            }
                        }
                    }
                    _ => {}
                }
            }
            if kinds.iter().any(|k| *k == "placement" || *k == "mig") {
                break;
            }
        }
        assert!(
            kinds.iter().any(|k| *k == "mps_quota" || *k == "io_throttle"),
            "guardrails first: {kinds:?}"
        );
        assert!(
            kinds.iter().any(|k| *k == "placement" || *k == "mig"),
            "must escalate: {kinds:?}"
        );
    }

    #[test]
    fn mig_only_dedicates_shared_instance() {
        let mut cfg = ControllerConfig::with_levers(Levers::mig_only());
        cfg.dwell_obs = 4;
        let mut ctl = Controller::new(no_warmup(cfg));
        let view = mk_view(true);
        let hot = mk_snap(25.0, true, true);
        let acts = run_until_action(&mut ctl, &hot, &view, 20).unwrap();
        assert!(
            matches!(
                acts[0],
                Action::ChangeIsolation {
                    change: IsolationChange::Resize {
                        to: MigProfile::P3g40gb
                    },
                    relax: false,
                    ..
                }
            ),
            "expected dedicate-resize, got {acts:?}"
        );
    }

    #[test]
    fn placement_only_moves_to_spare() {
        let mut cfg = ControllerConfig::with_levers(Levers::placement_only());
        cfg.dwell_obs = 4;
        let mut ctl = Controller::new(no_warmup(cfg));
        let view = mk_view(true);
        let hot = mk_snap(25.0, true, true);
        let acts = run_until_action(&mut ctl, &hot, &view, 20).unwrap();
        assert!(
            matches!(
                acts[0],
                Action::ChangeIsolation {
                    change: IsolationChange::MoveExisting { gpu: 2, .. },
                    ..
                }
            ),
            "expected move to spare on gpu2, got {acts:?}"
        );
    }

    #[test]
    fn dwell_blocks_consecutive_disruptive_actions() {
        let mut cfg = ControllerConfig::with_levers(Levers::mig_only());
        cfg.dwell_obs = 50;
        cfg.validation_obs = 4;
        let mut ctl = Controller::new(no_warmup(cfg));
        let view = mk_view(true);
        let hot = mk_snap(25.0, true, true);
        let mut action_obs = Vec::new();
        for _ in 0..300 {
            if !ctl.on_observation(&hot, &view).is_empty() {
                action_obs.push(ctl.observations());
            }
        }
        for w in action_obs.windows(2) {
            assert!(
                w[1] - w[0] >= 50,
                "dwell violated: actions at {action_obs:?}"
            );
        }
    }

    #[test]
    fn validation_rolls_back_when_worse() {
        let mut cfg = ControllerConfig::with_levers(Levers::mig_only());
        cfg.dwell_obs = 4;
        cfg.validation_obs = 8;
        let mut ctl = Controller::new(no_warmup(cfg));
        let view = mk_view(true);
        let hot = mk_snap(25.0, true, true);
        let acts = run_until_action(&mut ctl, &hot, &view, 20).unwrap();
        assert!(acts[0].is_disruptive());
        // Post-change, things get WORSE (30 > 25): expect rollback after
        // the validation window.
        let worse = mk_snap(30.0, true, true);
        let acts2 = run_until_action(&mut ctl, &worse, &view, 20).unwrap();
        assert!(matches!(acts2[0], Action::Rollback { .. }), "{acts2:?}");
    }

    #[test]
    fn validation_persists_when_better() {
        let mut cfg = ControllerConfig::with_levers(Levers::mig_only());
        cfg.dwell_obs = 4;
        cfg.validation_obs = 8;
        let mut ctl = Controller::new(no_warmup(cfg));
        let view = mk_view(true);
        let hot = mk_snap(25.0, true, true);
        run_until_action(&mut ctl, &hot, &view, 20).unwrap();
        let better = mk_snap(12.0, true, true);
        for _ in 0..20 {
            let acts = ctl.on_observation(&better, &view);
            assert!(acts.is_empty(), "unexpected action {acts:?}");
        }
        assert!(matches!(ctl.state(), CtlState::Cooldown { .. }));
    }

    #[test]
    fn relaxation_after_sustained_stability() {
        let mut cfg = ControllerConfig::default();
        cfg.stable_obs = 16;
        cfg.dwell_obs = 4;
        let mut ctl = Controller::new(no_warmup(cfg));
        // T1 dedicated on a big profile, everything quiet.
        let mut view = mk_view(false);
        view.tenants[0].profile = MigProfile::P4g40gb;
        view.tenants[1].io_throttle_gbps = Some(0.2); // leftover throttle
        let calm = mk_snap(6.0, false, false);
        let acts = run_until_action(&mut ctl, &calm, &view, 64).unwrap();
        // First relaxation action lifts the leftover throttle.
        assert!(
            matches!(acts[0], Action::SetIoThrottle { cap_gbps: None, .. }),
            "{acts:?}"
        );
    }

    #[test]
    fn relaxation_respects_throughput_budget() {
        let mut cfg = ControllerConfig::default();
        cfg.stable_obs = 16;
        cfg.dwell_obs = 4;
        let mut ctl = Controller::new(no_warmup(cfg));
        let mut view = mk_view(false);
        view.tenants[1].io_throttle_gbps = Some(0.2);
        let mut calm = mk_snap(6.0, false, false);
        // Throughput collapsed below 95% of base: must NOT relax.
        for t in calm.tenants.iter_mut() {
            if t.tenant == T1 {
                t.tails.rps = 100.0; // < 0.95 * 120
            }
        }
        assert!(run_until_action(&mut ctl, &calm, &view, 128).is_none());
    }
}
