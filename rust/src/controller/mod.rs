//! The paper's contribution: the host-level multi-tenancy controller.
//!
//! A conservative control loop (§2.3, Algorithm 1) that watches per-tenant
//! tails and system signals and escalates through three levers:
//!
//! 1. **Guardrails** — MPS active-thread quotas on compute-noisy peers and
//!    bounded cgroup-`io.max` throttles on I/O-noisy peers (§2.2 "3").
//! 2. **PCIe-aware placement** — migrate the tenant to the least-penalized
//!    MIG instance using the topology score of §2.2.1.
//! 3. **Dynamic MIG reconfiguration** — enlarge (or, when stable, shrink)
//!    the tenant's MIG profile (§2.2 "1").
//!
//! Actions are gated by persistence (`p99 > τ` for Y windows), dwell time,
//! cool-down, and a post-change validation window with rollback to the
//! last-known-good configuration (§2.4).
//!
//! Hosts with several latency-sensitive tenants run one controller per
//! protected tenant under the [`arbiter`] — the multi-primary control
//! plane that resolves conflicting isolation upgrades deterministically
//! (worst tail-to-SLO ratio wins; losers are deferred, never dropped).
//!
//! The controller is *pure* with respect to the platform: it consumes a
//! [`crate::telemetry::SignalSnapshot`] plus a [`view::PlannerView`] and
//! emits [`actions::Action`]s. That separation is the "fabric-agnostic,
//! VM-deployable" property — the same decision logic drives the simulated
//! host and the local serving engine. See `docs/ARCHITECTURE.md` for the
//! full control-loop data flow.

pub mod config;
pub mod actions;
pub mod view;
pub mod diagnose;
pub mod placement;
pub mod guardrails;
pub mod fsm;
pub mod arbiter;
pub mod audit;
pub mod admission;

pub use actions::{Action, ActionOutcome, IsolationChange};
pub use arbiter::{ArbStats, Arbiter, Protected};
pub use audit::{AuditLog, Decision};
pub use config::{ControllerConfig, Levers, SloKind};
pub use fsm::{Controller, CtlState, OutcomeFeedback, Proposal, ProposalClass};
pub use view::{InstanceView, PlannerView, TenantView};
