//! Experiment harness: runs the paper's evaluation (E1-E3, Tables 2-4,
//! Figures 2-4) and prints paper-vs-measured reports.
//!
//! Every bench in `rust/benches/` and every example is a thin wrapper
//! over these functions, so the tables can also be regenerated from the
//! CLI (`predserve experiment <id>`).

pub mod harness;
pub mod report;
pub mod runs;

pub use harness::{repeat_runs, ConfigSummary, Repeats};
pub use report::{fmt_row, markdown_table};
