//! Experiment runners — one per paper table/figure (the module map and
//! paper-section index live in `docs/ARCHITECTURE.md`), plus the
//! arbitration ablation for the multi-primary control plane.

use crate::controller::Levers;
use crate::fabric::ps::{ps_rates, FlowDemand};
use crate::platform::{Scenario, SimWorld};
use crate::tenants::InterferenceSchedule;
use crate::trace::{recorder::DEFAULT_CAPACITY, render_timeline, TimelineRow};

use super::harness::{repeat_runs, ConfigSummary, Repeats};
use super::report::{fmt_row, markdown_table, write_series};

/// `predserve report --timeline`: run `scenario` with the flight
/// recorder attached and render the per-tenant p99-vs-SLO timeline with
/// committed controller decisions overlaid, plus a one-line registry
/// summary.
pub fn run_timeline_report(scenario: Scenario, width: usize) -> String {
    let mut world = SimWorld::new(scenario);
    world.enable_recording(DEFAULT_CAPACITY);
    let (r, rec) = world.run_recorded();
    let rec = rec.expect("recording was enabled");
    let rows: Vec<TimelineRow> = r
        .per_tenant
        .iter()
        .filter(|t| t.slo_ms < f64::MAX)
        .map(|t| TimelineRow {
            name: t.name.clone(),
            slo_ms: t.slo_ms,
            tenant: t.tenant.0 as u32,
        })
        .collect();
    let mut out = format!("{} [{}] seed {}\n", r.label, r.scenario, r.seed);
    out.push_str(&render_timeline(&rec.events(), &rows, r.horizon_s, width));
    out.push_str(&format!(
        "decisions={} guardrail-edges={} trace-events={} dropped={}\n",
        rec.metrics.counter("ctl.decisions"),
        rec.metrics.counter("ctl.guardrail_edges"),
        rec.len(),
        rec.metrics.dropped_events(),
    ));
    out
}

/// The five E2 configurations in paper order (Table 3).
pub fn ablation_levers() -> [(&'static str, Levers); 5] {
    [
        ("Static MIG", Levers::none()),
        ("Guards-only", Levers::guards_only()),
        ("Placement-only", Levers::placement_only()),
        ("MIG-only", Levers::mig_only()),
        ("Full System", Levers::full()),
    ]
}

/// E2 / Table 3: the ablation study.
pub fn run_ablation(repeats: &Repeats) -> Vec<ConfigSummary> {
    ablation_levers()
        .into_iter()
        .map(|(label, lv)| repeat_runs(label, lv, repeats, Scenario::paper_single_host))
        .collect()
}

/// Paper's Table 3 reference values: (label, miss%, p99, norm tput).
pub const TABLE3_PAPER: [(&str, f64, f64, f64); 5] = [
    ("Static MIG", 16.4, 20.0, 1.00),
    ("Guards-only", 14.5, 19.0, 0.99),
    ("Placement-only", 13.0, 17.8, 0.98),
    ("MIG-only", 12.2, 17.2, 0.98),
    ("Full System", 11.1, 16.5, 0.97),
];

/// Render Table 3 with paper-vs-measured columns. Throughput is
/// normalized to the Static MIG run, as in the paper.
pub fn render_table3(sums: &[ConfigSummary]) -> String {
    let base_rps = sums
        .iter()
        .find(|s| s.label == "Static MIG")
        .map(|s| s.rps.mean)
        .unwrap_or(1.0);
    let rows: Vec<Vec<String>> = sums
        .iter()
        .map(|s| {
            let paper = TABLE3_PAPER
                .iter()
                .find(|(l, ..)| *l == s.label)
                .copied()
                .unwrap_or((s.label.as_str(), f64::NAN, f64::NAN, f64::NAN));
            vec![
                s.label.clone(),
                format!("{}%", fmt_row(s.miss_rate_pct.mean, s.miss_rate_pct.ci95, 1)),
                format!("{:.1}%", paper.1),
                fmt_row(s.p99_ms.mean, s.p99_ms.ci95, 1),
                format!("{:.1}", paper.2),
                format!("{:.2}", s.rps.mean / base_rps),
                format!("{:.2}", paper.3),
            ]
        })
        .collect();
    markdown_table(
        &[
            "Configuration",
            "SLO miss (meas.)",
            "SLO miss (paper)",
            "p99 ms (meas.)",
            "p99 ms (paper)",
            "Norm. tput (meas.)",
            "Norm. tput (paper)",
        ],
        &rows,
    )
}

/// Table 2 (LLM case study): static vs full on the TTFT workload.
pub fn run_table2(repeats: &Repeats) -> Vec<ConfigSummary> {
    [("Static MIG", Levers::none()), ("Full System", Levers::full())]
        .into_iter()
        .map(|(label, lv)| repeat_runs(label, lv, repeats, Scenario::paper_llm_case))
        .collect()
}

pub fn render_table2(sums: &[ConfigSummary]) -> String {
    let base_rps = sums
        .iter()
        .find(|s| s.label == "Static MIG")
        .map(|s| s.rps.mean)
        .unwrap_or(1.0);
    let paper = [("Static MIG", 232.0, 1.00), ("Full System", 199.0, 0.96)];
    let rows: Vec<Vec<String>> = sums
        .iter()
        .map(|s| {
            let p = paper.iter().find(|(l, ..)| *l == s.label).unwrap();
            vec![
                s.label.clone(),
                fmt_row(s.p99_ms.mean, s.p99_ms.ci95, 0),
                format!("{:.0}", p.1),
                format!("{:.2}", s.rps.mean / base_rps),
                format!("{:.2}", p.2),
            ]
        })
        .collect();
    markdown_table(
        &[
            "Configuration",
            "TTFT p99 ms (meas.)",
            "TTFT p99 ms (paper)",
            "Norm. tput (meas.)",
            "Norm. tput (paper)",
        ],
        &rows,
    )
}

/// Table 4 (controller overheads) from the Full System runs.
pub fn render_table4(full: &ConfigSummary) -> String {
    let rows = vec![
        vec![
            "MIG reconfig time (s)".to_string(),
            fmt_row(full.reconfig_s.mean, full.reconfig_s.ci95, 0),
            "18 ± 6".to_string(),
        ],
        vec![
            "Move frequency (/hr)".to_string(),
            format!("{:.1}", full.moves_per_hour.mean),
            "< 5".to_string(),
        ],
        vec![
            "Controller CPU (%)".to_string(),
            format!("{:.3}", full.controller_cpu_pct.mean),
            "< 2%".to_string(),
        ],
    ];
    markdown_table(&["Metric", "Measured", "Paper"], &rows)
}

/// Figure 2: PS bandwidth sharing curves — per-tenant bandwidth vs number
/// of co-active tenants, with and without caps. Writes CSV, returns the
/// rendered rows.
pub fn run_fig2() -> (String, Vec<Vec<f64>>) {
    let capacity = 25.0;
    let mut rows = Vec::new();
    for n in 1..=8usize {
        let uncapped: Vec<FlowDemand> = (0..n)
            .map(|_| FlowDemand {
                weight: 1.0,
                cap: None,
            })
            .collect();
        let share = ps_rates(capacity, &uncapped)[0];
        // One capped "noisy" tenant (g = 2 GB/s) + n-1 fair tenants.
        let mut capped = uncapped.clone();
        capped[0].cap = Some(2.0);
        let rates = ps_rates(capacity, &capped);
        let victim = if n > 1 { rates[1] } else { rates[0] };
        rows.push(vec![n as f64, share, rates[0], victim]);
    }
    let path = write_series(
        "fig2_ps_model",
        "tenants,fair_share_gbps,capped_offender_gbps,victim_share_gbps",
        &rows,
    )
    .unwrap_or_default();
    let table = markdown_table(
        &["co-active tenants", "fair share GB/s", "offender (g=2) GB/s", "victim GB/s"],
        &rows
            .iter()
            .map(|r| {
                vec![
                    format!("{}", r[0] as usize),
                    format!("{:.2}", r[1]),
                    format!("{:.2}", r[2]),
                    format!("{:.2}", r[3]),
                ]
            })
            .collect::<Vec<_>>(),
    );
    (format!("{table}\n(series: {path})\n"), rows)
}

/// Figure 3a: one Full System run's action timeline + p99 series.
/// Figure 3b: compliance vs efficiency scatter across the 5 configs.
pub fn run_fig3(repeats: &Repeats) -> String {
    let mut out = String::new();
    // 3a: single representative seed.
    let mut scenario = Scenario::paper_single_host(repeats.seeds[0], Levers::full());
    scenario.horizon = repeats.horizon_s;
    let r = crate::platform::SimWorld::new(scenario).run();
    let series: Vec<Vec<f64>> = r.p99_series.iter().map(|(t, p)| vec![*t, *p]).collect();
    let p1 = write_series("fig3a_p99_series", "t_s,p99_ms", &series).unwrap_or_default();
    out.push_str(&format!(
        "Fig 3a: p99 timeline -> {p1}; controller actions:\n"
    ));
    for (t, kind, p99) in &r.timeline {
        out.push_str(&format!("  t={t:7.1}s  {kind:12}  (p99 at decision {p99:.1} ms)\n"));
    }
    // 3b: scatter.
    let sums = run_ablation(repeats);
    let rows: Vec<Vec<f64>> = sums
        .iter()
        .map(|s| {
            vec![
                s.mean_sm_util.mean,
                100.0 - s.miss_rate_pct.mean,
            ]
        })
        .collect();
    let p2 = write_series("fig3b_efficiency_compliance", "sm_util,slo_compliance_pct", &rows)
        .unwrap_or_default();
    out.push_str(&format!("Fig 3b: efficiency-compliance scatter -> {p2}\n"));
    for (s, row) in sums.iter().zip(&rows) {
        out.push_str(&format!(
            "  {:16} util={:.2} compliance={:.1}%\n",
            s.label, row[0], row[1]
        ));
    }
    out
}

/// Figure 4: latency distribution under low/high contention, static vs
/// full. Emits CCDF series and the p99 markers.
pub fn run_fig4(repeats: &Repeats) -> String {
    let mut out = String::new();
    let cases = [
        ("low_contention_static", Levers::none(), false),
        ("high_contention_static", Levers::none(), true),
        ("high_contention_full", Levers::full(), true),
    ];
    for (name, lv, on) in cases {
        let mut scenario = Scenario::steady_contention(repeats.seeds[0], lv, on);
        scenario.horizon = repeats.horizon_s;
        let r = crate::platform::SimWorld::new(scenario).run();
        let ccdf: Vec<Vec<f64>> = r
            .histogram
            .ccdf()
            .into_iter()
            .map(|(us, p)| vec![us as f64 / 1000.0, p])
            .collect();
        let path = write_series(&format!("fig4_{name}"), "latency_ms,ccdf", &ccdf)
            .unwrap_or_default();
        out.push_str(&format!(
            "{name:24} p99={:6.2} ms p999={:7.2} ms miss={:5.1}% -> {path}\n",
            r.p99_ms,
            r.p999_ms,
            r.miss_rate * 100.0
        ));
    }
    out
}

/// Arbitration ablation: single-primary (only the designated primary is
/// actively protected; other latency-sensitive tenants are monitored
/// only) vs the multi-primary control plane (`protect_all_ls`: one
/// controller per LS tenant + arbiter) on the multi-LS catalog
/// scenarios. Reports per-LS-tenant SLO miss rates plus the committed
/// action and arbitration-deferral counts, averaged over the repeat set.
pub fn run_arbitration(repeats: &Repeats) -> String {
    let mut rows: Vec<Vec<String>> = Vec::new();
    for name in ["multi_ls_slo_mix", "dueling_primaries"] {
        for protect in [false, true] {
            let mode = if protect { "multi-primary" } else { "single-primary" };
            // (miss%, actions, deferrals) per LS tenant, summed over seeds.
            let mut per_ls: Vec<(String, f64, usize, usize)> = Vec::new();
            let mut conflicts = 0u64;
            let mut deferrals = 0u64;
            let mut runs = 0usize;
            for &seed in repeats.active_seeds() {
                let mut s = Scenario::by_name(name, seed, Levers::full())
                    .expect("catalog name must resolve");
                s.protect_all_ls = protect;
                s.horizon = repeats.horizon_s;
                let r = crate::platform::SimWorld::new(s).run();
                conflicts += r.arb_conflicts;
                deferrals += r.arb_deferrals;
                runs += 1;
                let mut k = 0;
                for t in &r.per_tenant {
                    if t.slo_ms >= f64::MAX {
                        continue; // background tenant
                    }
                    let ctl = r.controller_stats.iter().find(|c| c.tenant == t.tenant);
                    let acts = ctl.map(|c| c.total_actions()).unwrap_or(0);
                    let defs = ctl.map(|c| c.deferrals).unwrap_or(0);
                    if k == per_ls.len() {
                        per_ls.push((t.name.clone(), 0.0, 0, 0));
                    }
                    per_ls[k].1 += t.miss_rate * 100.0;
                    per_ls[k].2 += acts;
                    per_ls[k].3 += defs;
                    k += 1;
                }
            }
            let n = runs.max(1) as f64;
            for (tenant, miss_sum, acts, defs) in &per_ls {
                rows.push(vec![
                    name.to_string(),
                    mode.to_string(),
                    tenant.clone(),
                    format!("{:.1}%", miss_sum / n),
                    format!("{:.1}", *acts as f64 / n),
                    format!("{:.1}", *defs as f64 / n),
                ]);
            }
            rows.push(vec![
                name.to_string(),
                mode.to_string(),
                "(host total)".to_string(),
                "-".to_string(),
                format!("conflicts {:.1}", conflicts as f64 / n),
                format!("deferrals {:.1}", deferrals as f64 / n),
            ]);
        }
    }
    markdown_table(
        &[
            "Scenario",
            "Control plane",
            "LS tenant",
            "SLO miss",
            "actions/run",
            "deferrals/run",
        ],
        &rows,
    )
}

/// Trace-replay ablation (`predserve trace`): each trace-driven catalog
/// scenario vs its **rate-matched Poisson twin**
/// ([`Scenario::rate_matched_poisson`] — identical mean load, open-loop
/// Poisson pattern). Per LS tenant: SLO-miss and p99 under both arrival
/// patterns plus the deltas (trace − poisson), averaged over the repeat
/// set. Isolates what the arrival *pattern* — bursts, diurnal envelopes
/// — does to tails at equal offered load.
pub fn run_trace(repeats: &Repeats) -> String {
    let mut rows: Vec<Vec<String>> = Vec::new();
    for name in ["trace_burst_32", "diurnal_trace_mix"] {
        // Per-LS-tenant sums over seeds:
        // (name, trace miss%, poisson miss%, trace p99, poisson p99, arrivals).
        let mut per_ls: Vec<(String, f64, f64, f64, f64, u64)> = Vec::new();
        let mut runs = 0usize;
        for &seed in repeats.active_seeds() {
            let mut s = Scenario::by_name(name, seed, Levers::full())
                .expect("catalog name must resolve");
            s.horizon = repeats.horizon_s;
            let matched = s.rate_matched_poisson();
            let rt = crate::platform::SimWorld::new(s).run();
            let rp = crate::platform::SimWorld::new(matched).run();
            runs += 1;
            let mut k = 0;
            for (tt, tp) in rt.per_tenant.iter().zip(&rp.per_tenant) {
                if tt.slo_ms >= f64::MAX {
                    continue; // background tenant
                }
                if k == per_ls.len() {
                    per_ls.push((tt.name.clone(), 0.0, 0.0, 0.0, 0.0, 0));
                }
                per_ls[k].1 += tt.miss_rate * 100.0;
                per_ls[k].2 += tp.miss_rate * 100.0;
                per_ls[k].3 += tt.p99_ms;
                per_ls[k].4 += tp.p99_ms;
                per_ls[k].5 += tt.arrivals_emitted;
                k += 1;
            }
        }
        let n = runs.max(1) as f64;
        for (tenant, miss_t, miss_p, p99_t, p99_p, emitted) in &per_ls {
            rows.push(vec![
                name.to_string(),
                tenant.clone(),
                format!("{:.0}", *emitted as f64 / n),
                format!("{:.2}%", miss_t / n),
                format!("{:.2}%", miss_p / n),
                format!("{:+.2}pp", (miss_t - miss_p) / n),
                format!("{:.2}", p99_t / n),
                format!("{:.2}", p99_p / n),
                format!("{:+.2}", (p99_t - p99_p) / n),
            ]);
        }
    }
    markdown_table(
        &[
            "Scenario",
            "LS tenant",
            "arrivals/run",
            "miss (trace)",
            "miss (poisson)",
            "Δmiss",
            "p99 ms (trace)",
            "p99 ms (poisson)",
            "Δp99 ms",
        ],
        &rows,
    )
}

/// E3: sensitivity sweep over τ and Y (+ guardrail bounds).
pub fn run_sensitivity(repeats: &Repeats) -> String {
    let mut rows = Vec::new();
    for tau in [10.0, 12.5, 15.0, 20.0, 25.0] {
        let sum = repeat_runs("full", Levers::full(), repeats, |seed, lv| {
            let mut s = Scenario::paper_single_host(seed, lv);
            s.controller.tau_ms = tau;
            s
        });
        let actions: usize = sum
            .runs
            .iter()
            .map(|r| r.actions.iter().map(|(_, c)| c).sum::<usize>())
            .sum();
        rows.push(vec![
            format!("τ={tau}ms"),
            format!("{}%", fmt_row(sum.miss_rate_pct.mean, sum.miss_rate_pct.ci95, 1)),
            fmt_row(sum.p99_ms.mean, sum.p99_ms.ci95, 1),
            format!("{:.1}", actions as f64 / sum.runs.len() as f64),
        ]);
    }
    for y in [1u32, 2, 3, 5, 8] {
        let sum = repeat_runs("full", Levers::full(), repeats, |seed, lv| {
            let mut s = Scenario::paper_single_host(seed, lv);
            s.controller.persistence_y = y;
            s
        });
        let actions: usize = sum
            .runs
            .iter()
            .map(|r| r.actions.iter().map(|(_, c)| c).sum::<usize>())
            .sum();
        rows.push(vec![
            format!("Y={y}"),
            format!("{}%", fmt_row(sum.miss_rate_pct.mean, sum.miss_rate_pct.ci95, 1)),
            fmt_row(sum.p99_ms.mean, sum.p99_ms.ci95, 1),
            format!("{:.1}", actions as f64 / sum.runs.len() as f64),
        ]);
    }
    for (lo, hi, label) in [(0.05, 0.25, "IO 50-250MB/s"), (0.1, 0.5, "IO 100-500MB/s"), (0.25, 1.0, "IO 250MB-1GB/s")] {
        let sum = repeat_runs("full", Levers::full(), repeats, |seed, lv| {
            let mut s = Scenario::paper_single_host(seed, lv);
            s.controller.io_throttle_min_gbps = lo;
            s.controller.io_throttle_max_gbps = hi;
            s
        });
        rows.push(vec![
            label.to_string(),
            format!("{}%", fmt_row(sum.miss_rate_pct.mean, sum.miss_rate_pct.ci95, 1)),
            fmt_row(sum.p99_ms.mean, sum.p99_ms.ci95, 1),
            "-".to_string(),
        ]);
    }
    markdown_table(
        &["Parameter", "SLO miss", "p99 (ms)", "actions/run"],
        &rows,
    )
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny() -> Repeats {
        Repeats {
            seeds: [11, 12, 13, 14, 15, 16, 17],
            count: 1,
            horizon_s: 60.0,
        }
    }

    #[test]
    fn ablation_produces_five_configs() {
        let sums = run_ablation(&tiny());
        assert_eq!(sums.len(), 5);
        let t = render_table3(&sums);
        assert!(t.contains("Static MIG"));
        assert!(t.contains("Full System"));
        assert!(t.contains("16.4%")); // paper reference present
    }

    #[test]
    fn fig2_monotone_sharing() {
        let (_, rows) = run_fig2();
        // Fair share decreases with tenant count; victim share with a
        // capped offender exceeds the uncapped fair share.
        for w in rows.windows(2) {
            assert!(w[1][1] <= w[0][1] + 1e-9);
        }
        let n4 = &rows[3];
        assert!(n4[3] > n4[1], "victim {} !> fair {}", n4[3], n4[1]);
        assert!((n4[2] - 2.0).abs() < 1e-9, "offender capped at 2");
    }

    #[test]
    fn arbitration_ablation_renders_both_modes() {
        let t = run_arbitration(&tiny());
        assert!(t.contains("single-primary") && t.contains("multi-primary"));
        assert!(t.contains("multi_ls_slo_mix") && t.contains("dueling_primaries"));
        assert!(t.contains("chat-api") && t.contains("svc-gold"));
        assert!(t.contains("(host total)"));
    }

    #[test]
    fn trace_ablation_renders_both_scenarios_and_deltas() {
        let t = run_trace(&tiny());
        assert!(t.contains("trace_burst_32") && t.contains("diurnal_trace_mix"));
        // Every LS tenant of both scenarios shows up.
        assert!(t.contains("svc-0") && t.contains("serving"));
        assert!(t.contains("Δmiss") && t.contains("Δp99"));
        // Rate-matched comparisons are deterministic end to end.
        assert_eq!(t, run_trace(&tiny()));
    }

    #[test]
    fn table4_renders() {
        let sums = run_ablation(&tiny());
        let full = sums.iter().find(|s| s.label == "Full System").unwrap();
        let t = render_table4(full);
        assert!(t.contains("MIG reconfig time"));
        assert!(t.contains("< 5"));
    }
}
