//! Report formatting: markdown tables with paper-vs-measured columns.

/// Format one `value ± ci` cell.
pub fn fmt_row(mean: f64, ci: f64, decimals: usize) -> String {
    format!("{:.d$} ± {:.d$}", mean, ci, d = decimals)
}

/// Render a markdown table.
pub fn markdown_table(header: &[&str], rows: &[Vec<String>]) -> String {
    let mut out = String::new();
    out.push_str("| ");
    out.push_str(&header.join(" | "));
    out.push_str(" |\n|");
    for _ in header {
        out.push_str("---|");
    }
    out.push('\n');
    for row in rows {
        out.push_str("| ");
        out.push_str(&row.join(" | "));
        out.push_str(" |\n");
    }
    out
}

/// Write a CSV series (figure data) to `target/paper/<name>.csv`.
pub fn write_series(name: &str, header: &str, rows: &[Vec<f64>]) -> std::io::Result<String> {
    let dir = std::path::Path::new("target/paper");
    std::fs::create_dir_all(dir)?;
    let path = dir.join(format!("{name}.csv"));
    let mut body = String::from(header);
    body.push('\n');
    for r in rows {
        let cells: Vec<String> = r.iter().map(|x| format!("{x}")).collect();
        body.push_str(&cells.join(","));
        body.push('\n');
    }
    std::fs::write(&path, body)?;
    Ok(path.display().to_string())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table_renders() {
        let t = markdown_table(
            &["Configuration", "p99 (ms)"],
            &[vec!["Static MIG".into(), "20.0 ± 1.2".into()]],
        );
        assert!(t.contains("| Configuration | p99 (ms) |"));
        assert!(t.contains("| Static MIG | 20.0 ± 1.2 |"));
    }

    #[test]
    fn fmt_matches_paper_style() {
        assert_eq!(fmt_row(16.5, 0.7, 1), "16.5 ± 0.7");
    }
}
