//! Repeat-run machinery: fixed seeds, mean ± 95% CI (§3.1: 7 repeats).

use crate::controller::Levers;
use crate::platform::{RunResult, Scenario, SimWorld};
use crate::sim::parallel::scoped_parallel_map;
use crate::util::stats::Summary;

/// Repeat policy. The paper uses 7 fixed seeds; `fast()` trims for CI
/// and smoke runs (`PREDSERVE_FAST=1`).
#[derive(Clone, Copy, Debug)]
pub struct Repeats {
    pub seeds: [u64; 7],
    pub count: usize,
    pub horizon_s: f64,
}

impl Repeats {
    pub fn paper() -> Repeats {
        Repeats {
            seeds: [11, 12, 13, 14, 15, 16, 17],
            count: 7,
            horizon_s: 1800.0,
        }
    }

    pub fn fast() -> Repeats {
        Repeats {
            seeds: [11, 12, 13, 14, 15, 16, 17],
            count: 3,
            horizon_s: 600.0,
        }
    }

    /// Honor `PREDSERVE_FAST` for quick smoke regeneration.
    pub fn from_env() -> Repeats {
        if std::env::var("PREDSERVE_FAST").map(|v| v == "1").unwrap_or(false) {
            Repeats::fast()
        } else {
            Repeats::paper()
        }
    }

    pub fn active_seeds(&self) -> &[u64] {
        &self.seeds[..self.count]
    }
}

/// Aggregated metrics for one configuration across repeats.
#[derive(Clone, Debug)]
pub struct ConfigSummary {
    pub label: String,
    pub miss_rate_pct: Summary,
    pub p95_ms: Summary,
    pub p99_ms: Summary,
    pub p999_ms: Summary,
    pub rps: Summary,
    pub moves_per_hour: Summary,
    pub mean_sm_util: Summary,
    pub reconfig_s: Summary,
    pub controller_cpu_pct: Summary,
    pub runs: Vec<RunResult>,
}

impl ConfigSummary {
    pub fn of(label: &str, runs: Vec<RunResult>) -> ConfigSummary {
        let take = |f: &dyn Fn(&RunResult) -> f64| {
            Summary::of(&runs.iter().map(|r| f(r)).collect::<Vec<_>>())
        };
        let reconfigs: Vec<f64> = runs
            .iter()
            .flat_map(|r| r.reconfig_durations_s.iter().copied())
            .collect();
        ConfigSummary {
            label: label.to_string(),
            miss_rate_pct: take(&|r| r.miss_rate * 100.0),
            p95_ms: take(&|r| r.p95_ms),
            p99_ms: take(&|r| r.p99_ms),
            p999_ms: take(&|r| r.p999_ms),
            rps: take(&|r| r.rps),
            moves_per_hour: take(&|r| r.moves_per_hour),
            mean_sm_util: take(&|r| r.mean_sm_util),
            reconfig_s: Summary::of(&reconfigs),
            controller_cpu_pct: take(&|r| r.controller_cpu_frac * 100.0),
            runs,
        }
    }
}

/// Run `levers` over the repeat set on the scenario produced by `mk`.
///
/// Repeat seeds are RNG-independent worlds, so the runs execute on
/// scoped worker threads ([`scoped_parallel_map`]); the map preserves
/// seed order, so the resulting `ConfigSummary` is byte-identical to
/// the old sequential loop.
pub fn repeat_runs(
    label: &str,
    levers: Levers,
    repeats: &Repeats,
    mk: impl Fn(u64, Levers) -> Scenario,
) -> ConfigSummary {
    let scenarios: Vec<Scenario> = repeats
        .active_seeds()
        .iter()
        .map(|&seed| {
            let mut scenario = mk(seed, levers);
            scenario.horizon = repeats.horizon_s;
            scenario
        })
        .collect();
    let runs = scoped_parallel_map(scenarios, |s| SimWorld::new(s).run());
    ConfigSummary::of(label, runs)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn config_summary_aggregates() {
        let repeats = Repeats {
            seeds: [1, 2, 3, 4, 5, 6, 7],
            count: 2,
            horizon_s: 60.0,
        };
        let s = repeat_runs("Static MIG", Levers::none(), &repeats, |seed, lv| {
            Scenario::paper_single_host(seed, lv)
        });
        assert_eq!(s.runs.len(), 2);
        assert_eq!(s.miss_rate_pct.n, 2);
        assert!(s.p99_ms.mean > 0.0);
        assert!(s.rps.mean > 0.0);
    }

    #[test]
    fn fast_env_toggle() {
        let r = Repeats::fast();
        assert_eq!(r.count, 3);
        assert_eq!(Repeats::paper().count, 7);
    }
}
