//! Leader: Slurm-like launcher + aggregator for the 2-node experiment
//! (the paper's contribution (2): "First SLO-safe, multi-tenant control
//! demo on a multi-node (16-GPU) cloud cluster without fabric
//! privileges"). Control stays per-host; the leader only dispatches
//! work and aggregates results.

use std::net::TcpListener;
use std::thread;

use anyhow::{anyhow, Result};

use super::proto::{read_msg, write_msg, Msg};
use super::worker::Worker;

/// Aggregated cluster results.
#[derive(Clone, Debug)]
pub struct ClusterReport {
    pub per_node: Vec<(String, f64, f64, f64)>, // (node, miss, p99, rps)
    pub mean_miss_rate: f64,
    pub mean_p99_ms: f64,
    pub total_completed: u64,
    pub total_rps: f64,
}

/// The cluster leader.
pub struct Leader;

impl Leader {
    /// Launch `nodes` in-process workers connected over real TCP
    /// (localhost), dispatch the same scenario to every node, and
    /// aggregate. This is the Slurm-like `srun` of the repro: every node
    /// runs its own controller over its own 8 GPUs.
    pub fn run_cluster(
        nodes: usize,
        seed: u64,
        levers: &str,
        horizon_s: f64,
        workload: &str,
    ) -> Result<ClusterReport> {
        let listener = TcpListener::bind("127.0.0.1:0")?;
        let addr = listener.local_addr()?;

        // Launch workers.
        let mut joins = Vec::new();
        for n in 0..nodes {
            let node = format!("node{n}");
            let addr_s = addr.to_string();
            joins.push(thread::spawn(move || {
                let w = Worker::new(node);
                w.serve(&addr_s)
            }));
        }

        // Accept connections, dispatch, gather.
        let mut results = Vec::new();
        let mut streams = Vec::new();
        for n in 0..nodes {
            let (mut stream, _) = listener.accept()?;
            let hello = read_msg(&mut stream)?;
            let node = match hello {
                Msg::Hello { node, gpus } => {
                    assert_eq!(gpus, 8, "p4d node must expose 8 GPUs");
                    node
                }
                other => return Err(anyhow!("expected Hello, got {other:?}")),
            };
            // Distinct seed per node: independent hosts, same config.
            write_msg(
                &mut stream,
                &Msg::RunScenario {
                    seed: seed + n as u64,
                    levers: levers.to_string(),
                    horizon_s,
                    workload: workload.to_string(),
                },
            )?;
            streams.push((node, stream));
        }
        for (node, stream) in streams.iter_mut() {
            match read_msg(stream)? {
                Msg::RunDone {
                    miss_rate,
                    p99_ms,
                    rps,
                    completed,
                    ..
                } => results.push((node.clone(), miss_rate, p99_ms, rps, completed)),
                other => return Err(anyhow!("expected RunDone, got {other:?}")),
            }
            write_msg(stream, &Msg::Shutdown)?;
        }
        for j in joins {
            j.join().map_err(|_| anyhow!("worker panicked"))??;
        }

        let n = results.len() as f64;
        Ok(ClusterReport {
            mean_miss_rate: results.iter().map(|r| r.1).sum::<f64>() / n,
            mean_p99_ms: results.iter().map(|r| r.2).sum::<f64>() / n,
            total_rps: results.iter().map(|r| r.3).sum::<f64>(),
            total_completed: results.iter().map(|r| r.4).sum::<u64>(),
            per_node: results
                .into_iter()
                .map(|(node, m, p, r, _)| (node, m, p, r))
                .collect(),
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn two_node_cluster_roundtrip() {
        let report = Leader::run_cluster(2, 21, "static", 45.0, "single").unwrap();
        assert_eq!(report.per_node.len(), 2);
        assert!(report.total_completed > 4_000);
        assert!(report.mean_p99_ms > 0.0);
        // Distinct nodes reported.
        assert_ne!(report.per_node[0].0, report.per_node[1].0);
    }
}
