//! Leader: Slurm-like launcher + aggregator for the multi-node
//! experiment (the paper's contribution (2): "First SLO-safe,
//! multi-tenant control demo on a multi-node (16-GPU) cloud cluster
//! without fabric privileges"). Control stays per-host; the leader only
//! dispatches work and aggregates results.
//!
//! Two dispatch modes:
//! * [`Leader::run_cluster`] — the classic E9 experiment: the same
//!   whole-host catalog scenario on every node, distinct seeds.
//! * [`Leader::run_fleet`] — fleet-level dispatch: one tenant list split
//!   across the nodes by the topology-aware [`crate::alloc`] allocator;
//!   each worker receives only its assigned tenants + slots, and tenants
//!   no node could take are reported queued/rejected, never dropped.

use std::net::TcpListener;
use std::thread;
use std::time::Duration;

use anyhow::{anyhow, Result};

use crate::alloc::{AutoRequest, FleetAllocator, FleetPlan};
use crate::controller::{ControllerConfig, Levers};
use crate::faults::FaultPlan;
use crate::platform::Scenario;
use crate::tenants::{TenantKind, TenantWorkload};
use crate::topo::HostTopology;

use super::proto::{read_msg, write_msg, Msg};
use super::worker::Worker;

/// One node's run result. A fleet run must survive individual node loss
/// (crash, timeout, malformed reply), so a report row is either stats or
/// a typed failure — a dead node is *reported*, never silently dropped
/// from `per_node`.
#[derive(Clone, Debug)]
pub enum NodeReport {
    /// The node completed its run and replied.
    Ok {
        node: String,
        miss_rate: f64,
        p99_ms: f64,
        rps: f64,
        completed: u64,
    },
    /// The node crashed, timed out, or replied with garbage; `reason` is
    /// the transport/protocol diagnosis.
    Failed { node: String, reason: String },
}

impl NodeReport {
    pub fn node(&self) -> &str {
        match self {
            NodeReport::Ok { node, .. } | NodeReport::Failed { node, .. } => node,
        }
    }

    pub fn is_ok(&self) -> bool {
        matches!(self, NodeReport::Ok { .. })
    }

    pub fn failure(&self) -> Option<&str> {
        match self {
            NodeReport::Failed { reason, .. } => Some(reason.as_str()),
            NodeReport::Ok { .. } => None,
        }
    }
}

/// Fleet-run robustness knobs.
#[derive(Clone, Debug)]
pub struct ClusterOpts {
    /// Per-node reply deadline (seconds), enforced as a socket read
    /// timeout on the leader side. A worker that neither replies nor
    /// drops its connection within this window is declared
    /// [`NodeReport::Failed`] instead of hanging the whole experiment.
    /// CLI: `--node-timeout SECS`.
    pub node_timeout_s: f64,
    /// Nodes scheduled to crash on dispatch — populated from a scenario's
    /// `FaultSpec::WorkerCrash` entries via [`ClusterOpts::from_fault_plan`].
    pub crash_nodes: Vec<String>,
}

impl Default for ClusterOpts {
    fn default() -> ClusterOpts {
        ClusterOpts {
            node_timeout_s: 300.0,
            crash_nodes: Vec::new(),
        }
    }
}

impl ClusterOpts {
    /// Extract the cluster-level faults (worker crashes) from a plan; the
    /// sim-level specs are ignored here — they ride inside each node's
    /// scenario, not the dispatch layer.
    pub fn from_fault_plan(plan: &FaultPlan) -> ClusterOpts {
        ClusterOpts {
            crash_nodes: plan.crash_nodes(),
            ..ClusterOpts::default()
        }
    }

    pub fn node_timeout(mut self, secs: f64) -> ClusterOpts {
        self.node_timeout_s = secs;
        self
    }

    fn read_timeout(&self) -> Option<Duration> {
        (self.node_timeout_s > 0.0 && self.node_timeout_s.is_finite())
            .then(|| Duration::from_secs_f64(self.node_timeout_s))
    }
}

/// Aggregated cluster results.
#[derive(Clone, Debug)]
pub struct ClusterReport {
    pub per_node: Vec<NodeReport>,
    /// Means/totals below aggregate the `Ok` nodes only.
    pub mean_miss_rate: f64,
    pub mean_p99_ms: f64,
    pub total_completed: u64,
    pub total_rps: f64,
    /// Nodes that crashed/timed out (count of `NodeReport::Failed` rows).
    pub failed_nodes: usize,
    /// Fleet dispatch only: tenant names no node could safely place now.
    pub queued: Vec<String>,
    /// Fleet dispatch only: tenant names structurally impossible anywhere.
    pub rejected: Vec<String>,
}

impl ClusterReport {
    fn aggregate(per_node: Vec<NodeReport>) -> ClusterReport {
        let mut n = 0u64;
        let (mut miss, mut p99, mut rps_sum) = (0.0, 0.0, 0.0);
        let mut completed_sum = 0u64;
        for r in &per_node {
            if let NodeReport::Ok {
                miss_rate,
                p99_ms,
                rps,
                completed,
                ..
            } = r
            {
                n += 1;
                miss += miss_rate;
                p99 += p99_ms;
                rps_sum += rps;
                completed_sum += completed;
            }
        }
        let denom = if n > 0 { n as f64 } else { 1.0 };
        ClusterReport {
            mean_miss_rate: miss / denom,
            mean_p99_ms: p99 / denom,
            total_rps: rps_sum,
            total_completed: completed_sum,
            failed_nodes: per_node.iter().filter(|r| !r.is_ok()).count(),
            per_node,
            queued: Vec::new(),
            rejected: Vec::new(),
        }
    }
}

/// The cluster leader.
pub struct Leader;

impl Leader {
    /// Launch workers over real TCP (localhost) and collect their
    /// registrations. Returns the accepted `(node, stream)` pairs plus
    /// the worker join handles. Nodes named in `opts.crash_nodes` are
    /// launched as [`Worker::crashing`] — the fault harness for
    /// `FaultSpec::WorkerCrash`. Accepted streams carry the per-node
    /// read deadline so a hung worker cannot stall the leader forever.
    #[allow(clippy::type_complexity)]
    fn launch(
        nodes: usize,
        opts: &ClusterOpts,
    ) -> Result<(
        Vec<(String, std::net::TcpStream)>,
        Vec<thread::JoinHandle<Result<()>>>,
    )> {
        let listener = TcpListener::bind("127.0.0.1:0")?;
        let addr = listener.local_addr()?;
        let mut joins = Vec::new();
        for n in 0..nodes {
            let node = format!("node{n}");
            let crash = opts.crash_nodes.iter().any(|c| *c == node);
            let addr_s = addr.to_string();
            joins.push(thread::spawn(move || {
                let w = if crash {
                    Worker::crashing(node)
                } else {
                    Worker::new(node)
                };
                w.serve(&addr_s)
            }));
        }
        let mut streams = Vec::new();
        for _ in 0..nodes {
            let (mut stream, _) = listener.accept()?;
            stream.set_read_timeout(opts.read_timeout())?;
            match read_msg(&mut stream)? {
                Msg::Hello { node, gpus } => {
                    if gpus != 8 {
                        return Err(anyhow!("p4d node '{node}' must expose 8 GPUs, got {gpus}"));
                    }
                    streams.push((node, stream));
                }
                other => return Err(anyhow!("expected Hello, got {other:?}")),
            }
        }
        Ok((streams, joins))
    }

    /// Gather one `RunDone` per node, send `Shutdown`, join the workers.
    /// Graceful partial-fleet degradation: a node that crashed, timed
    /// out, or replied with a malformed frame becomes a
    /// [`NodeReport::Failed`] row — the surviving nodes' results are
    /// still collected and aggregated.
    fn gather(
        mut streams: Vec<(String, std::net::TcpStream)>,
        joins: Vec<thread::JoinHandle<Result<()>>>,
    ) -> Vec<NodeReport> {
        let mut reports = Vec::new();
        for (node, stream) in streams.iter_mut() {
            let report = match read_msg(stream) {
                Ok(Msg::RunDone {
                    scenario,
                    miss_rate,
                    p99_ms,
                    rps,
                    completed,
                    ..
                }) => {
                    // Workers report refusals in-band (see worker.rs):
                    // surface them as failures, not as zero-rps stats.
                    if scenario.starts_with("error:") {
                        NodeReport::Failed {
                            node: node.clone(),
                            reason: scenario,
                        }
                    } else {
                        NodeReport::Ok {
                            node: node.clone(),
                            miss_rate,
                            p99_ms,
                            rps,
                            completed,
                        }
                    }
                }
                Ok(other) => NodeReport::Failed {
                    node: node.clone(),
                    reason: format!("expected RunDone, got {other:?}"),
                },
                Err(e) => NodeReport::Failed {
                    node: node.clone(),
                    reason: e.to_string(),
                },
            };
            if let Some(reason) = report.failure() {
                crate::log_warn!("cluster.leader", "{node}: degraded — {reason}");
            }
            // Best-effort: a crashed peer already hung up, and that is
            // exactly the case this path exists for.
            let _ = write_msg(stream, &Msg::Shutdown);
            reports.push(report);
        }
        for j in joins {
            match j.join() {
                Ok(Ok(())) => {}
                Ok(Err(e)) => crate::log_warn!("cluster.leader", "worker exited with error: {e}"),
                Err(_) => crate::log_warn!("cluster.leader", "worker thread panicked"),
            }
        }
        reports
    }

    /// Launch `nodes` in-process workers, dispatch the same scenario to
    /// every node, and aggregate. This is the Slurm-like `srun` of the
    /// repro: every node runs its own controller over its own 8 GPUs.
    /// `shards` selects each worker's simulation engine (1 = single-queue
    /// reference; sharded runs are bit-identical, so the report does not
    /// depend on it).
    pub fn run_cluster(
        nodes: usize,
        seed: u64,
        levers: &str,
        horizon_s: f64,
        workload: &str,
        shards: usize,
    ) -> Result<ClusterReport> {
        Leader::run_cluster_opts(
            nodes,
            seed,
            levers,
            horizon_s,
            workload,
            shards,
            &ClusterOpts::default(),
        )
    }

    /// [`Leader::run_cluster`] with explicit robustness knobs (node
    /// deadline, scheduled worker crashes).
    pub fn run_cluster_opts(
        nodes: usize,
        seed: u64,
        levers: &str,
        horizon_s: f64,
        workload: &str,
        shards: usize,
        opts: &ClusterOpts,
    ) -> Result<ClusterReport> {
        let (mut streams, joins) = Leader::launch(nodes, opts)?;
        for (n, (_, stream)) in streams.iter_mut().enumerate() {
            // Distinct seed per node: independent hosts, same config.
            write_msg(
                stream,
                &Msg::RunScenario {
                    seed: seed + n as u64,
                    levers: levers.to_string(),
                    horizon_s,
                    workload: workload.to_string(),
                    shards,
                },
            )?;
        }
        Ok(ClusterReport::aggregate(Leader::gather(streams, joins)))
    }

    /// Compute the fleet plan for `n_tenants` auto-placed tenants over
    /// `nodes` p4d hosts — the same allocator the workers' scenario
    /// builder uses, so leader and worker never disagree on a slot.
    /// Returns the fleet tenant list alongside the plan (plan entries
    /// reference tenants by index into it).
    pub fn plan_fleet(
        nodes: usize,
        seed: u64,
        n_tenants: usize,
    ) -> (Vec<TenantWorkload>, FleetPlan) {
        let tenants = Scenario::auto_pack_tenants(seed, n_tenants);
        let reqs = AutoRequest::from_workloads(&tenants);
        let plan = FleetAllocator::new(
            nodes,
            HostTopology::p4d(),
            ControllerConfig::dense_pack(Levers::full()),
        )
        .pack(&reqs);
        (tenants, plan)
    }

    /// Fleet-level dispatch: place one `n_tenants`-tenant list across
    /// the nodes with the topology-aware allocator, send every worker
    /// only its share, and aggregate. Tenants admission queued/rejected
    /// fleet-wide are reported on the `ClusterReport`.
    pub fn run_fleet(
        nodes: usize,
        seed: u64,
        levers: &str,
        horizon_s: f64,
        n_tenants: usize,
    ) -> Result<ClusterReport> {
        Leader::run_fleet_opts(nodes, seed, levers, horizon_s, n_tenants, &ClusterOpts::default())
    }

    /// [`Leader::run_fleet`] with explicit robustness knobs.
    pub fn run_fleet_opts(
        nodes: usize,
        seed: u64,
        levers: &str,
        horizon_s: f64,
        n_tenants: usize,
        opts: &ClusterOpts,
    ) -> Result<ClusterReport> {
        let (tenants, plan) = Leader::plan_fleet(nodes, seed, n_tenants);
        for h in &plan.hosts {
            let has_ls = h
                .assigned
                .iter()
                .any(|a| tenants[a.tenant].kind() == TenantKind::LatencySensitive);
            if !has_ls {
                return Err(anyhow!(
                    "fleet plan gave node{} no latency-sensitive tenant; \
                     grow the tenant list or shrink the fleet",
                    h.node
                ));
            }
        }

        let (mut streams, joins) = Leader::launch(nodes, opts)?;
        // Workers connect concurrently, so accept order is a thread race:
        // match each worker to its planned host by the self-reported
        // name ("node{n}"), never by arrival order. The per-node world
        // seed keeps tenant RNG streams independent across hosts.
        for (node, stream) in streams.iter_mut() {
            let host = plan
                .hosts
                .iter()
                .find(|h| format!("node{}", h.node) == *node)
                .ok_or_else(|| anyhow!("no planned host for worker '{node}'"))?;
            write_msg(
                stream,
                &Msg::RunTenantSet {
                    seed,
                    world_seed: seed + host.node as u64,
                    levers: levers.to_string(),
                    horizon_s,
                    fleet: "auto_pack".to_string(),
                    count: n_tenants,
                    assigned: host.assigned.clone(),
                },
            )?;
        }
        let mut report = ClusterReport::aggregate(Leader::gather(streams, joins));
        report.queued = plan.queued.iter().map(|&i| tenants[i].name.clone()).collect();
        report.rejected = plan
            .rejected
            .iter()
            .map(|&i| tenants[i].name.clone())
            .collect();
        Ok(report)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn two_node_cluster_roundtrip() {
        let report = Leader::run_cluster(2, 21, "static", 45.0, "single", 2).unwrap();
        assert_eq!(report.per_node.len(), 2);
        assert_eq!(report.failed_nodes, 0);
        assert!(report.per_node.iter().all(|r| r.is_ok()));
        assert!(report.total_completed > 4_000);
        assert!(report.mean_p99_ms > 0.0);
        // Distinct nodes reported.
        assert_ne!(report.per_node[0].node(), report.per_node[1].node());
    }

    #[test]
    fn worker_crash_degrades_to_partial_fleet_report() {
        use crate::faults::FaultSpec;
        // One node scheduled to die on dispatch (FaultSpec::WorkerCrash):
        // the run must complete, reporting Failed for exactly that node
        // and real stats for the survivor.
        let plan = FaultPlan::new(vec![FaultSpec::WorkerCrash {
            node: "node1".into(),
        }]);
        let opts = ClusterOpts::from_fault_plan(&plan).node_timeout(60.0);
        let report =
            Leader::run_cluster_opts(2, 21, "static", 45.0, "single", 1, &opts).unwrap();
        assert_eq!(report.per_node.len(), 2);
        assert_eq!(report.failed_nodes, 1);
        for r in &report.per_node {
            if r.node() == "node1" {
                assert!(!r.is_ok(), "crashed node must be reported Failed");
                assert!(r.failure().is_some());
            } else {
                assert!(r.is_ok(), "surviving node degraded: {:?}", r.failure());
            }
        }
        // Aggregates cover the surviving node only — and it did real work.
        assert!(report.total_completed > 2_000);
        assert!(report.mean_p99_ms > 0.0);
    }

    #[test]
    fn cluster_dispatch_runs_cluster_fabric_scenarios() {
        // Catalog entries carrying a ClusterTopology (cross-host ring
        // trainers) dispatch through the same wire path as single-host
        // ones; every node builds its own net fabric and completes.
        let report =
            Leader::run_cluster(2, 13, "static", 45.0, "fat_tree_allreduce_mix", 1).unwrap();
        assert_eq!(report.per_node.len(), 2);
        assert_eq!(report.failed_nodes, 0);
        assert!(report.total_completed > 1_000);
        assert!(report.mean_p99_ms > 0.0);
    }

    #[test]
    fn fleet_plan_covers_every_tenant_once() {
        let (tenants, plan) = Leader::plan_fleet(2, 11, 24);
        assert_eq!(tenants.len(), 24);
        let assigned: usize = plan.hosts.iter().map(|h| h.assigned.len()).sum();
        assert_eq!(assigned + plan.queued.len() + plan.rejected.len(), 24);
        let mut seen = std::collections::BTreeSet::new();
        for h in &plan.hosts {
            for a in &h.assigned {
                assert!(seen.insert(a.tenant));
            }
        }
        // The 24-tenant list fits comfortably on 16 GPUs.
        assert_eq!(assigned, 24, "queued={:?}", plan.queued);
    }

    #[test]
    fn two_node_fleet_dispatch_roundtrip() {
        let report = Leader::run_fleet(2, 33, "static", 45.0, 24).unwrap();
        assert_eq!(report.per_node.len(), 2);
        assert!(report.queued.is_empty(), "queued {:?}", report.queued);
        assert!(report.rejected.is_empty());
        assert!(report.total_completed > 1_000);
        assert!(report.mean_p99_ms > 0.0);
    }
}
