//! Leader: Slurm-like launcher + aggregator for the multi-node
//! experiment (the paper's contribution (2): "First SLO-safe,
//! multi-tenant control demo on a multi-node (16-GPU) cloud cluster
//! without fabric privileges"). Control stays per-host; the leader only
//! dispatches work and aggregates results.
//!
//! Two dispatch modes:
//! * [`Leader::run_cluster`] — the classic E9 experiment: the same
//!   whole-host catalog scenario on every node, distinct seeds.
//! * [`Leader::run_fleet`] — fleet-level dispatch: one tenant list split
//!   across the nodes by the topology-aware [`crate::alloc`] allocator;
//!   each worker receives only its assigned tenants + slots, and tenants
//!   no node could take are reported queued/rejected, never dropped.

use std::net::TcpListener;
use std::thread;

use anyhow::{anyhow, Result};

use crate::alloc::{AutoRequest, FleetAllocator, FleetPlan};
use crate::controller::{ControllerConfig, Levers};
use crate::platform::Scenario;
use crate::tenants::{TenantKind, TenantWorkload};
use crate::topo::HostTopology;

use super::proto::{read_msg, write_msg, Msg};
use super::worker::Worker;

/// One node's aggregated run result.
#[derive(Clone, Debug)]
pub struct NodeReport {
    pub node: String,
    pub miss_rate: f64,
    pub p99_ms: f64,
    pub rps: f64,
}

/// Aggregated cluster results.
#[derive(Clone, Debug)]
pub struct ClusterReport {
    pub per_node: Vec<NodeReport>,
    pub mean_miss_rate: f64,
    pub mean_p99_ms: f64,
    pub total_completed: u64,
    pub total_rps: f64,
    /// Fleet dispatch only: tenant names no node could safely place now.
    pub queued: Vec<String>,
    /// Fleet dispatch only: tenant names structurally impossible anywhere.
    pub rejected: Vec<String>,
}

impl ClusterReport {
    fn aggregate(results: Vec<(String, f64, f64, f64, u64)>) -> ClusterReport {
        let n = results.len() as f64;
        ClusterReport {
            mean_miss_rate: results.iter().map(|r| r.1).sum::<f64>() / n,
            mean_p99_ms: results.iter().map(|r| r.2).sum::<f64>() / n,
            total_rps: results.iter().map(|r| r.3).sum::<f64>(),
            total_completed: results.iter().map(|r| r.4).sum::<u64>(),
            per_node: results
                .into_iter()
                .map(|(node, miss_rate, p99_ms, rps, _)| NodeReport {
                    node,
                    miss_rate,
                    p99_ms,
                    rps,
                })
                .collect(),
            queued: Vec::new(),
            rejected: Vec::new(),
        }
    }
}

/// The cluster leader.
pub struct Leader;

impl Leader {
    /// Launch workers over real TCP (localhost) and collect their
    /// registrations. Returns the accepted `(node, stream)` pairs plus
    /// the worker join handles.
    #[allow(clippy::type_complexity)]
    fn launch(
        nodes: usize,
    ) -> Result<(
        Vec<(String, std::net::TcpStream)>,
        Vec<thread::JoinHandle<Result<()>>>,
    )> {
        let listener = TcpListener::bind("127.0.0.1:0")?;
        let addr = listener.local_addr()?;
        let mut joins = Vec::new();
        for n in 0..nodes {
            let node = format!("node{n}");
            let addr_s = addr.to_string();
            joins.push(thread::spawn(move || {
                let w = Worker::new(node);
                w.serve(&addr_s)
            }));
        }
        let mut streams = Vec::new();
        for _ in 0..nodes {
            let (mut stream, _) = listener.accept()?;
            match read_msg(&mut stream)? {
                Msg::Hello { node, gpus } => {
                    assert_eq!(gpus, 8, "p4d node must expose 8 GPUs");
                    streams.push((node, stream));
                }
                other => return Err(anyhow!("expected Hello, got {other:?}")),
            }
        }
        Ok((streams, joins))
    }

    /// Gather one `RunDone` per node, send `Shutdown`, join the workers.
    fn gather(
        mut streams: Vec<(String, std::net::TcpStream)>,
        joins: Vec<thread::JoinHandle<Result<()>>>,
    ) -> Result<Vec<(String, f64, f64, f64, u64)>> {
        let mut results = Vec::new();
        for (node, stream) in streams.iter_mut() {
            match read_msg(stream)? {
                Msg::RunDone {
                    miss_rate,
                    p99_ms,
                    rps,
                    completed,
                    ..
                } => results.push((node.clone(), miss_rate, p99_ms, rps, completed)),
                other => return Err(anyhow!("expected RunDone, got {other:?}")),
            }
            write_msg(stream, &Msg::Shutdown)?;
        }
        for j in joins {
            j.join().map_err(|_| anyhow!("worker panicked"))??;
        }
        Ok(results)
    }

    /// Launch `nodes` in-process workers, dispatch the same scenario to
    /// every node, and aggregate. This is the Slurm-like `srun` of the
    /// repro: every node runs its own controller over its own 8 GPUs.
    /// `shards` selects each worker's simulation engine (1 = single-queue
    /// reference; sharded runs are bit-identical, so the report does not
    /// depend on it).
    pub fn run_cluster(
        nodes: usize,
        seed: u64,
        levers: &str,
        horizon_s: f64,
        workload: &str,
        shards: usize,
    ) -> Result<ClusterReport> {
        let (mut streams, joins) = Leader::launch(nodes)?;
        for (n, (_, stream)) in streams.iter_mut().enumerate() {
            // Distinct seed per node: independent hosts, same config.
            write_msg(
                stream,
                &Msg::RunScenario {
                    seed: seed + n as u64,
                    levers: levers.to_string(),
                    horizon_s,
                    workload: workload.to_string(),
                    shards,
                },
            )?;
        }
        Ok(ClusterReport::aggregate(Leader::gather(streams, joins)?))
    }

    /// Compute the fleet plan for `n_tenants` auto-placed tenants over
    /// `nodes` p4d hosts — the same allocator the workers' scenario
    /// builder uses, so leader and worker never disagree on a slot.
    /// Returns the fleet tenant list alongside the plan (plan entries
    /// reference tenants by index into it).
    pub fn plan_fleet(
        nodes: usize,
        seed: u64,
        n_tenants: usize,
    ) -> (Vec<TenantWorkload>, FleetPlan) {
        let tenants = Scenario::auto_pack_tenants(seed, n_tenants);
        let reqs = AutoRequest::from_workloads(&tenants);
        let plan = FleetAllocator::new(
            nodes,
            HostTopology::p4d(),
            ControllerConfig::dense_pack(Levers::full()),
        )
        .pack(&reqs);
        (tenants, plan)
    }

    /// Fleet-level dispatch: place one `n_tenants`-tenant list across
    /// the nodes with the topology-aware allocator, send every worker
    /// only its share, and aggregate. Tenants admission queued/rejected
    /// fleet-wide are reported on the `ClusterReport`.
    pub fn run_fleet(
        nodes: usize,
        seed: u64,
        levers: &str,
        horizon_s: f64,
        n_tenants: usize,
    ) -> Result<ClusterReport> {
        let (tenants, plan) = Leader::plan_fleet(nodes, seed, n_tenants);
        for h in &plan.hosts {
            let has_ls = h
                .assigned
                .iter()
                .any(|a| tenants[a.tenant].kind() == TenantKind::LatencySensitive);
            if !has_ls {
                return Err(anyhow!(
                    "fleet plan gave node{} no latency-sensitive tenant; \
                     grow the tenant list or shrink the fleet",
                    h.node
                ));
            }
        }

        let (mut streams, joins) = Leader::launch(nodes)?;
        // Workers connect concurrently, so accept order is a thread race:
        // match each worker to its planned host by the self-reported
        // name ("node{n}"), never by arrival order. The per-node world
        // seed keeps tenant RNG streams independent across hosts.
        for (node, stream) in streams.iter_mut() {
            let host = plan
                .hosts
                .iter()
                .find(|h| format!("node{}", h.node) == *node)
                .ok_or_else(|| anyhow!("no planned host for worker '{node}'"))?;
            write_msg(
                stream,
                &Msg::RunTenantSet {
                    seed,
                    world_seed: seed + host.node as u64,
                    levers: levers.to_string(),
                    horizon_s,
                    fleet: "auto_pack".to_string(),
                    count: n_tenants,
                    assigned: host.assigned.clone(),
                },
            )?;
        }
        let mut report = ClusterReport::aggregate(Leader::gather(streams, joins)?);
        report.queued = plan.queued.iter().map(|&i| tenants[i].name.clone()).collect();
        report.rejected = plan
            .rejected
            .iter()
            .map(|&i| tenants[i].name.clone())
            .collect();
        Ok(report)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn two_node_cluster_roundtrip() {
        let report = Leader::run_cluster(2, 21, "static", 45.0, "single", 2).unwrap();
        assert_eq!(report.per_node.len(), 2);
        assert!(report.total_completed > 4_000);
        assert!(report.mean_p99_ms > 0.0);
        // Distinct nodes reported.
        assert_ne!(report.per_node[0].node, report.per_node[1].node);
    }

    #[test]
    fn fleet_plan_covers_every_tenant_once() {
        let (tenants, plan) = Leader::plan_fleet(2, 11, 24);
        assert_eq!(tenants.len(), 24);
        let assigned: usize = plan.hosts.iter().map(|h| h.assigned.len()).sum();
        assert_eq!(assigned + plan.queued.len() + plan.rejected.len(), 24);
        let mut seen = std::collections::BTreeSet::new();
        for h in &plan.hosts {
            for a in &h.assigned {
                assert!(seen.insert(a.tenant));
            }
        }
        // The 24-tenant list fits comfortably on 16 GPUs.
        assert_eq!(assigned, 24, "queued={:?}", plan.queued);
    }

    #[test]
    fn two_node_fleet_dispatch_roundtrip() {
        let report = Leader::run_fleet(2, 33, "static", 45.0, 24).unwrap();
        assert_eq!(report.per_node.len(), 2);
        assert!(report.queued.is_empty(), "queued {:?}", report.queued);
        assert!(report.rejected.is_empty());
        assert!(report.total_completed > 1_000);
        assert!(report.mean_p99_ms > 0.0);
    }
}
