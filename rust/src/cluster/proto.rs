//! Wire protocol: length-prefixed JSON messages over TCP.

use crate::alloc::Assignment;
use crate::gpu::MigProfile;
use crate::util::json::Json;
use std::io::{Read, Write};

/// Typed wire-protocol failure. A leader surviving a flaky fleet needs
/// to tell *transport* loss (`Io` — the peer died mid-frame, retryable
/// against another node) from *protocol* corruption (`Malformed` /
/// `UnknownType` — a buggy or hostile peer; never retry, just fail that
/// node). The old `anyhow!` strings could not be matched on, and the
/// worker used to `panic!`/`assert!` its way out of malformed frames —
/// a single bad message would take the whole node down.
#[derive(Debug)]
pub enum ProtoError {
    /// Socket-level failure (EOF, reset, read timeout). The peer is gone
    /// or unreachable — degrade that node, keep the fleet.
    Io(std::io::Error),
    /// Length prefix beyond the 1 MiB frame cap — refuse before
    /// allocating (a corrupted prefix must not become an OOM).
    Oversize { len: usize },
    /// Frame body is not UTF-8.
    BadUtf8,
    /// Frame body is not parseable JSON.
    BadJson(String),
    /// Structurally valid JSON missing or mistyping a required field.
    Malformed { field: &'static str },
    /// A `type` tag this build does not understand.
    UnknownType(String),
}

impl std::fmt::Display for ProtoError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            ProtoError::Io(e) => write!(f, "wire io error: {e}"),
            ProtoError::Oversize { len } => write!(f, "oversized message ({len} bytes)"),
            ProtoError::BadUtf8 => write!(f, "message body is not utf-8"),
            ProtoError::BadJson(e) => write!(f, "bad message json: {e}"),
            ProtoError::Malformed { field } => write!(f, "malformed message: bad field '{field}'"),
            ProtoError::UnknownType(t) => write!(f, "unknown message type '{t}'"),
        }
    }
}

impl std::error::Error for ProtoError {
    fn source(&self) -> Option<&(dyn std::error::Error + 'static)> {
        match self {
            ProtoError::Io(e) => Some(e),
            _ => None,
        }
    }
}

impl From<std::io::Error> for ProtoError {
    fn from(e: std::io::Error) -> ProtoError {
        ProtoError::Io(e)
    }
}

/// Cluster messages.
#[derive(Clone, Debug, PartialEq)]
pub enum Msg {
    /// Leader → worker: run a scenario.
    RunScenario {
        seed: u64,
        levers: String,
        horizon_s: f64,
        /// "single" (E1 world) or "llm" (Table 2 world).
        workload: String,
        /// Simulation-engine shard count (1 = single-queue reference).
        /// Sharded runs are bit-identical to the reference, so this is a
        /// pure performance lever; older leaders that omit it get 1.
        shards: usize,
    },
    /// Leader → worker: run this node's share of a fleet-level tenant
    /// list. The worker re-derives the full list deterministically from
    /// `(fleet, seed, count)` and instantiates only the `assigned`
    /// tenants at the leader-allocated slots (fleet dispatch never ships
    /// whole-host scenarios).
    RunTenantSet {
        /// Fleet-list seed: every node derives the identical list from it.
        seed: u64,
        /// Per-node world seed (leader sends `seed + node`), so tenant
        /// RNG streams stay independent across hosts.
        world_seed: u64,
        levers: String,
        horizon_s: f64,
        /// Fleet tenant-list name (e.g. "auto_pack").
        fleet: String,
        /// Total tenants in the fleet list.
        count: usize,
        /// This node's tenants: fleet index + allocated MIG slot.
        assigned: Vec<Assignment>,
    },
    /// Worker → leader: run finished.
    RunDone {
        node: String,
        /// Echo of the requested workload name when it resolved, or the
        /// name of the fallback scenario that actually ran — a leader
        /// detects a typo'd workload by `scenario != requested`.
        scenario: String,
        miss_rate: f64,
        p99_ms: f64,
        p95_ms: f64,
        rps: f64,
        completed: u64,
        moves_per_hour: f64,
    },
    /// Leader → worker: shut down.
    Shutdown,
    /// Worker → leader: hello (registration).
    Hello { node: String, gpus: usize },
}

impl Msg {
    pub fn to_json(&self) -> Json {
        match self {
            Msg::RunScenario {
                seed,
                levers,
                horizon_s,
                workload,
                shards,
            } => Json::obj(vec![
                ("type", Json::Str("run".into())),
                ("seed", Json::Num(*seed as f64)),
                ("levers", Json::Str(levers.clone())),
                ("horizon_s", Json::Num(*horizon_s)),
                ("workload", Json::Str(workload.clone())),
                ("shards", Json::Num(*shards as f64)),
            ]),
            Msg::RunTenantSet {
                seed,
                world_seed,
                levers,
                horizon_s,
                fleet,
                count,
                assigned,
            } => Json::obj(vec![
                ("type", Json::Str("run_tenants".into())),
                // Seeds travel as strings: a u64 through f64 JSON loses
                // precision above 2^53, and a rounded fleet seed would
                // make the worker derive a *different* tenant list than
                // the leader planned (silent slot mismatch).
                ("seed", Json::Str(seed.to_string())),
                ("world_seed", Json::Str(world_seed.to_string())),
                ("levers", Json::Str(levers.clone())),
                ("horizon_s", Json::Num(*horizon_s)),
                ("fleet", Json::Str(fleet.clone())),
                ("count", Json::Num(*count as f64)),
                (
                    "assigned",
                    Json::Arr(
                        assigned
                            .iter()
                            .map(|a| {
                                Json::obj(vec![
                                    ("tenant", Json::Num(a.tenant as f64)),
                                    ("gpu", Json::Num(a.gpu as f64)),
                                    ("profile", Json::Str(a.profile.name().into())),
                                    ("start", Json::Num(a.start as f64)),
                                ])
                            })
                            .collect(),
                    ),
                ),
            ]),
            Msg::RunDone {
                node,
                scenario,
                miss_rate,
                p99_ms,
                p95_ms,
                rps,
                completed,
                moves_per_hour,
            } => Json::obj(vec![
                ("type", Json::Str("done".into())),
                ("node", Json::Str(node.clone())),
                ("scenario", Json::Str(scenario.clone())),
                ("miss_rate", Json::Num(*miss_rate)),
                ("p99_ms", Json::Num(*p99_ms)),
                ("p95_ms", Json::Num(*p95_ms)),
                ("rps", Json::Num(*rps)),
                ("completed", Json::Num(*completed as f64)),
                ("moves_per_hour", Json::Num(*moves_per_hour)),
            ]),
            Msg::Shutdown => Json::obj(vec![("type", Json::Str("shutdown".into()))]),
            Msg::Hello { node, gpus } => Json::obj(vec![
                ("type", Json::Str("hello".into())),
                ("node", Json::Str(node.clone())),
                ("gpus", Json::Num(*gpus as f64)),
            ]),
        }
    }

    pub fn from_json(j: &Json) -> Result<Msg, ProtoError> {
        let ty = j
            .get("type")
            .as_str()
            .ok_or(ProtoError::Malformed { field: "type" })?;
        Ok(match ty {
            "run" => Msg::RunScenario {
                seed: j.get("seed").as_f64().unwrap_or(0.0) as u64,
                levers: j.get("levers").as_str().unwrap_or("full").to_string(),
                horizon_s: j.get("horizon_s").as_f64().unwrap_or(600.0),
                workload: j.get("workload").as_str().unwrap_or("single").to_string(),
                // Pre-sharding leaders omit the field: reference engine.
                shards: j.get("shards").as_usize().unwrap_or(1).max(1),
            },
            "run_tenants" => {
                let mut assigned = Vec::new();
                for a in j.get("assigned").as_arr().unwrap_or(&[]) {
                    let profile = a
                        .get("profile")
                        .as_str()
                        .and_then(MigProfile::from_name)
                        .ok_or(ProtoError::Malformed { field: "profile" })?;
                    assigned.push(Assignment {
                        tenant: a
                            .get("tenant")
                            .as_usize()
                            .ok_or(ProtoError::Malformed { field: "tenant" })?,
                        gpu: a
                            .get("gpu")
                            .as_usize()
                            .ok_or(ProtoError::Malformed { field: "gpu" })?,
                        profile,
                        start: a
                            .get("start")
                            .as_usize()
                            .ok_or(ProtoError::Malformed { field: "start" })?,
                    });
                }
                // Seeds arrive as exact strings (see to_json); accept a
                // numeric fallback for hand-written messages.
                let seed_of = |key: &str| -> Option<u64> {
                    j.get(key)
                        .as_str()
                        .and_then(|s| s.parse().ok())
                        .or_else(|| j.get(key).as_f64().map(|v| v as u64))
                };
                let seed = seed_of("seed").ok_or(ProtoError::Malformed { field: "seed" })?;
                Msg::RunTenantSet {
                    seed,
                    // Older leaders omit it: fall back to the list seed.
                    world_seed: seed_of("world_seed").unwrap_or(seed),
                    levers: j.get("levers").as_str().unwrap_or("full").to_string(),
                    horizon_s: j.get("horizon_s").as_f64().unwrap_or(600.0),
                    fleet: j.get("fleet").as_str().unwrap_or("auto_pack").to_string(),
                    // Required: a defaulted count would make the worker
                    // derive an empty fleet list and panic on the first
                    // assignment lookup.
                    count: j
                        .get("count")
                        .as_usize()
                        .ok_or(ProtoError::Malformed { field: "count" })?,
                    assigned,
                }
            }
            "done" => Msg::RunDone {
                node: j.get("node").as_str().unwrap_or("?").to_string(),
                scenario: j.get("scenario").as_str().unwrap_or("?").to_string(),
                miss_rate: j.get("miss_rate").as_f64().unwrap_or(0.0),
                p99_ms: j.get("p99_ms").as_f64().unwrap_or(0.0),
                p95_ms: j.get("p95_ms").as_f64().unwrap_or(0.0),
                rps: j.get("rps").as_f64().unwrap_or(0.0),
                completed: j.get("completed").as_f64().unwrap_or(0.0) as u64,
                moves_per_hour: j.get("moves_per_hour").as_f64().unwrap_or(0.0),
            },
            "shutdown" => Msg::Shutdown,
            "hello" => Msg::Hello {
                node: j.get("node").as_str().unwrap_or("?").to_string(),
                gpus: j.get("gpus").as_usize().unwrap_or(0),
            },
            other => return Err(ProtoError::UnknownType(other.to_string())),
        })
    }
}

/// Write a length-prefixed message.
pub fn write_msg<W: Write>(w: &mut W, msg: &Msg) -> Result<(), ProtoError> {
    let body = msg.to_json().to_string().into_bytes();
    w.write_all(&(body.len() as u32).to_be_bytes())?;
    w.write_all(&body)?;
    w.flush()?;
    Ok(())
}

/// Read a length-prefixed message.
pub fn read_msg<R: Read>(r: &mut R) -> Result<Msg, ProtoError> {
    let mut len_buf = [0u8; 4];
    r.read_exact(&mut len_buf)?;
    let len = u32::from_be_bytes(len_buf) as usize;
    if len > 1 << 20 {
        return Err(ProtoError::Oversize { len });
    }
    let mut body = vec![0u8; len];
    r.read_exact(&mut body)?;
    let text = String::from_utf8(body).map_err(|_| ProtoError::BadUtf8)?;
    let j = Json::parse(&text).map_err(|e| ProtoError::BadJson(e.to_string()))?;
    Msg::from_json(&j)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_all_variants() {
        let msgs = vec![
            Msg::Hello {
                node: "node0".into(),
                gpus: 8,
            },
            Msg::RunScenario {
                seed: 7,
                levers: "full".into(),
                horizon_s: 600.0,
                workload: "llm".into(),
                shards: 4,
            },
            Msg::RunTenantSet {
                // Above 2^53: pins the exact-u64 (string) seed transport.
                seed: (1u64 << 53) + 1,
                world_seed: (1u64 << 53) + 2,
                levers: "full".into(),
                horizon_s: 300.0,
                fleet: "auto_pack".into(),
                count: 24,
                assigned: vec![
                    Assignment {
                        tenant: 0,
                        gpu: 0,
                        profile: MigProfile::P3g40gb,
                        start: 0,
                    },
                    Assignment {
                        tenant: 5,
                        gpu: 3,
                        profile: MigProfile::P1g10gb,
                        start: 6,
                    },
                ],
            },
            Msg::RunTenantSet {
                seed: 1,
                world_seed: 1,
                levers: "static".into(),
                horizon_s: 60.0,
                fleet: "auto_pack".into(),
                count: 0,
                assigned: vec![],
            },
            Msg::RunDone {
                node: "node1".into(),
                scenario: "paper_single_host".into(),
                miss_rate: 0.11,
                p99_ms: 16.5,
                p95_ms: 12.0,
                rps: 79.9,
                completed: 144_000,
                moves_per_hour: 3.0,
            },
            Msg::Shutdown,
        ];
        for m in msgs {
            let mut buf = Vec::new();
            write_msg(&mut buf, &m).unwrap();
            let got = read_msg(&mut &buf[..]).unwrap();
            assert_eq!(got, m);
        }
    }

    #[test]
    fn run_without_shards_field_defaults_to_reference_engine() {
        // Wire compatibility: a pre-sharding leader never sends "shards".
        let j = Json::parse(
            r#"{"type":"run","seed":3,"levers":"full","horizon_s":60,"workload":"single"}"#,
        )
        .unwrap();
        match Msg::from_json(&j).unwrap() {
            Msg::RunScenario { shards, .. } => assert_eq!(shards, 1),
            other => panic!("unexpected {other:?}"),
        }
    }

    #[test]
    fn rejects_oversized() {
        let mut buf = Vec::new();
        buf.extend_from_slice(&(u32::MAX).to_be_bytes());
        match read_msg(&mut &buf[..]) {
            Err(ProtoError::Oversize { len }) => assert_eq!(len, u32::MAX as usize),
            other => panic!("expected Oversize, got {other:?}"),
        }
    }

    fn frame(body: &[u8]) -> Vec<u8> {
        let mut buf = Vec::new();
        buf.extend_from_slice(&(body.len() as u32).to_be_bytes());
        buf.extend_from_slice(body);
        buf
    }

    #[test]
    fn malformed_frames_yield_typed_errors_not_panics() {
        // Truncated frame: transport-level.
        let mut buf = frame(b"{\"type\":\"run\"}");
        buf.truncate(buf.len() - 3);
        assert!(matches!(read_msg(&mut &buf[..]), Err(ProtoError::Io(_))));
        // Invalid UTF-8 body.
        let buf = frame(&[0xff, 0xfe, 0xfd]);
        assert!(matches!(read_msg(&mut &buf[..]), Err(ProtoError::BadUtf8)));
        // Valid UTF-8, broken JSON.
        let buf = frame(b"{nope");
        assert!(matches!(read_msg(&mut &buf[..]), Err(ProtoError::BadJson(_))));
        // Valid JSON missing the type tag.
        let buf = frame(b"{\"seed\":1}");
        assert!(matches!(
            read_msg(&mut &buf[..]),
            Err(ProtoError::Malformed { field: "type" })
        ));
        // Unknown type tag.
        let buf = frame(b"{\"type\":\"explode\"}");
        match read_msg(&mut &buf[..]) {
            Err(ProtoError::UnknownType(t)) => assert_eq!(t, "explode"),
            other => panic!("expected UnknownType, got {other:?}"),
        }
        // run_tenants with a bad assignment: field-level diagnosis.
        let buf = frame(
            b"{\"type\":\"run_tenants\",\"seed\":\"1\",\"count\":2,\
              \"assigned\":[{\"tenant\":0,\"gpu\":0,\"profile\":\"bogus\",\"start\":0}]}",
        );
        assert!(matches!(
            read_msg(&mut &buf[..]),
            Err(ProtoError::Malformed { field: "profile" })
        ));
    }
}
