//! Worker: one node of the cluster. Owns a simulated p4d host (8 GPUs)
//! with its own host-level controller and runs scenarios on demand.

use std::net::TcpStream;

use anyhow::Result;

use crate::controller::Levers;
use crate::platform::{Scenario, SimWorld};

use super::proto::{read_msg, write_msg, Msg};

/// A cluster worker process/thread.
pub struct Worker {
    pub node: String,
}

fn levers_from_str(s: &str) -> Levers {
    match s {
        "none" | "static" => Levers::none(),
        "guards" => Levers::guards_only(),
        "placement" => Levers::placement_only(),
        "mig" => Levers::mig_only(),
        _ => Levers::full(),
    }
}

impl Worker {
    pub fn new(node: impl Into<String>) -> Worker {
        Worker { node: node.into() }
    }

    /// Execute one scenario request locally. `workload` is any catalog
    /// name (see [`Scenario::CATALOG`]); unknown names fall back to the
    /// paper's single-host world (wire-protocol compatibility), with a
    /// warning so a typo'd experiment name cannot pass silently.
    pub fn run_scenario(&self, seed: u64, levers: &str, horizon_s: f64, workload: &str) -> Msg {
        let lv = levers_from_str(levers);
        // Echo contract: a recognized request echoes the REQUESTED name
        // verbatim (aliases included), so leaders can detect fallback
        // with a plain equality check; only the unknown-name fallback
        // echoes the name of what actually ran.
        let (mut scenario, ran) = match Scenario::by_name(workload, seed, lv) {
            Some(s) => (s, workload.to_string()),
            None => {
                crate::log_warn!(
                    "cluster.worker",
                    "unknown workload '{workload}', falling back to paper_single_host"
                );
                (
                    Scenario::paper_single_host(seed, lv),
                    "paper_single_host".to_string(),
                )
            }
        };
        scenario.horizon = horizon_s;
        let r = SimWorld::new(scenario).run();
        Msg::RunDone {
            node: self.node.clone(),
            scenario: ran,
            miss_rate: r.miss_rate,
            p99_ms: r.p99_ms,
            p95_ms: r.p95_ms,
            rps: r.rps,
            completed: r.completed,
            moves_per_hour: r.moves_per_hour,
        }
    }

    /// Connect to the leader and serve until `Shutdown`.
    pub fn serve(&self, leader_addr: &str) -> Result<()> {
        let mut stream = TcpStream::connect(leader_addr)?;
        write_msg(
            &mut stream,
            &Msg::Hello {
                node: self.node.clone(),
                gpus: 8,
            },
        )?;
        loop {
            match read_msg(&mut stream)? {
                Msg::RunScenario {
                    seed,
                    levers,
                    horizon_s,
                    workload,
                } => {
                    let done = self.run_scenario(seed, &levers, horizon_s, &workload);
                    write_msg(&mut stream, &done)?;
                }
                Msg::Shutdown => return Ok(()),
                other => {
                    crate::log_warn!("cluster.worker", "unexpected message {other:?}");
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn local_run_produces_stats() {
        let w = Worker::new("test-node");
        let msg = w.run_scenario(3, "static", 60.0, "single");
        match msg {
            Msg::RunDone {
                node,
                completed,
                p99_ms,
                ..
            } => {
                assert_eq!(node, "test-node");
                assert!(completed > 3_000);
                assert!(p99_ms > 0.0);
            }
            _ => panic!("expected RunDone"),
        }
    }

    #[test]
    fn catalog_workloads_run_on_workers() {
        let w = Worker::new("cat-node");
        for name in ["multi_ls_slo_mix", "pcie_hotspot", "diurnal_burst"] {
            match w.run_scenario(3, "static", 45.0, name) {
                Msg::RunDone {
                    completed,
                    scenario,
                    ..
                } => {
                    assert!(completed > 500, "{name}: completed {completed}");
                    // The worker echoes what it actually ran.
                    assert_eq!(scenario, name);
                }
                other => panic!("unexpected {other:?}"),
            }
        }
    }

    #[test]
    fn typoed_workload_is_detectable_from_the_echo() {
        let w = Worker::new("typo-node");
        match w.run_scenario(3, "static", 45.0, "pcie_hotpsot") {
            Msg::RunDone { scenario, .. } => {
                // Falls back for wire compatibility, but the echoed name
                // exposes the mismatch to the caller.
                assert_eq!(scenario, "paper_single_host");
            }
            other => panic!("unexpected {other:?}"),
        }
    }

    #[test]
    fn lever_parsing() {
        assert_eq!(levers_from_str("mig"), Levers::mig_only());
        assert_eq!(levers_from_str("bogus-default"), Levers::full());
        assert_eq!(levers_from_str("static"), Levers::none());
    }
}
