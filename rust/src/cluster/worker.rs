//! Worker: one node of the cluster. Owns a simulated p4d host (8 GPUs)
//! with its own host-level controller and runs scenarios on demand.

use std::net::TcpStream;

use anyhow::Result;

use crate::alloc::Assignment;
use crate::controller::{ControllerConfig, Levers};
use crate::platform::{Scenario, ScenarioBuilder, SimWorld};
use crate::tenants::PlacementSpec;

use super::proto::{read_msg, write_msg, Msg};

/// A cluster worker process/thread.
pub struct Worker {
    pub node: String,
    /// Fault-injection hook (`FaultSpec::WorkerCrash`): accept the first
    /// dispatched run, then drop the connection without replying —
    /// modelling a node that dies mid-job. The leader sees EOF where a
    /// `RunDone` was due and must degrade to a partial-fleet report.
    crash_on_dispatch: bool,
}

fn levers_from_str(s: &str) -> Levers {
    match s {
        "none" | "static" => Levers::none(),
        "guards" => Levers::guards_only(),
        "placement" => Levers::placement_only(),
        "mig" => Levers::mig_only(),
        _ => Levers::full(),
    }
}

impl Worker {
    pub fn new(node: impl Into<String>) -> Worker {
        Worker {
            node: node.into(),
            crash_on_dispatch: false,
        }
    }

    /// A worker scheduled to crash on its first dispatch (see
    /// [`Worker::crash_on_dispatch`]). Only the test/fault harness builds
    /// these; a production worker is always `new`.
    pub fn crashing(node: impl Into<String>) -> Worker {
        Worker {
            node: node.into(),
            crash_on_dispatch: true,
        }
    }

    /// Execute one scenario request locally. `workload` is any catalog
    /// name (see [`Scenario::CATALOG`]); unknown names fall back to the
    /// paper's single-host world (wire-protocol compatibility), with a
    /// warning so a typo'd experiment name cannot pass silently.
    /// `shards` selects the simulation engine (1 = single-queue
    /// reference); sharded runs are bit-identical, so the reply is the
    /// same either way — only wall-clock changes.
    pub fn run_scenario(
        &self,
        seed: u64,
        levers: &str,
        horizon_s: f64,
        workload: &str,
        shards: usize,
    ) -> Msg {
        let lv = levers_from_str(levers);
        // Echo contract: a recognized request echoes the REQUESTED name
        // verbatim (aliases included), so leaders can detect fallback
        // with a plain equality check; only the unknown-name fallback
        // echoes the name of what actually ran.
        let (mut scenario, ran) = match Scenario::by_name(workload, seed, lv) {
            Some(s) => (s, workload.to_string()),
            None => {
                crate::log_warn!(
                    "cluster.worker",
                    "unknown workload '{workload}', falling back to paper_single_host"
                );
                (
                    Scenario::paper_single_host(seed, lv),
                    "paper_single_host".to_string(),
                )
            }
        };
        scenario.horizon = horizon_s;
        scenario.shards = shards.max(1);
        let r = SimWorld::new(scenario).run();
        Msg::RunDone {
            node: self.node.clone(),
            scenario: ran,
            miss_rate: r.miss_rate,
            p99_ms: r.p99_ms,
            p95_ms: r.p95_ms,
            rps: r.rps,
            completed: r.completed,
            moves_per_hour: r.moves_per_hour,
        }
    }

    /// Execute this node's share of a fleet-level tenant list. The full
    /// list is re-derived deterministically from `(fleet, seed, count)` —
    /// the wire carries only indices + allocated slots — then the
    /// assigned tenants are instantiated at exactly the leader-chosen
    /// placements (the leader's allocator already packed them, so the
    /// builder has nothing left to auto-place). `world_seed` drives this
    /// node's tenant RNG streams and differs per node; `seed` only names
    /// the shared fleet list.
    #[allow(clippy::too_many_arguments)]
    pub fn run_tenant_set(
        &self,
        seed: u64,
        world_seed: u64,
        levers: &str,
        horizon_s: f64,
        fleet: &str,
        count: usize,
        assigned: &[Assignment],
    ) -> Msg {
        let lv = levers_from_str(levers);
        // Unlike the whole-host path (where a fallback scenario is still
        // a coherent experiment), substituting a different fleet list
        // would run the wrong tenants at slots planned for others —
        // refuse the dispatch with an unmistakable error report instead.
        if fleet != "auto_pack" {
            crate::log_warn!(
                "cluster.worker",
                "unknown fleet list '{fleet}'; refusing dispatch"
            );
            return Msg::RunDone {
                node: self.node.clone(),
                scenario: format!("error:unknown_fleet:{fleet}"),
                miss_rate: 1.0,
                p99_ms: 0.0,
                p95_ms: 0.0,
                rps: 0.0,
                completed: 0,
                moves_per_hour: 0.0,
            };
        }
        let all = Scenario::auto_pack_tenants(seed, count);
        let mut b = ScenarioBuilder::new(format!("fleet_{fleet}"), world_seed)
            .controller(ControllerConfig::dense_pack(lv))
            .horizon(horizon_s);
        for a in assigned {
            // A leader bug (or corrupted frame that slipped past the
            // parser) must not panic the node: report it as an error run
            // the leader can see and degrade on.
            if a.tenant >= all.len() {
                crate::log_warn!(
                    "cluster.worker",
                    "assignment index {} beyond fleet list of {}; refusing dispatch",
                    a.tenant,
                    all.len()
                );
                return Msg::RunDone {
                    node: self.node.clone(),
                    scenario: format!("error:assignment_out_of_range:{}", a.tenant),
                    miss_rate: 1.0,
                    p99_ms: 0.0,
                    p95_ms: 0.0,
                    rps: 0.0,
                    completed: 0,
                    moves_per_hour: 0.0,
                };
            }
            let mut t = all[a.tenant].clone();
            t.placement = PlacementSpec::dedicated_at(a.gpu, a.profile, a.start);
            b = b.tenant(t);
        }
        let scenario = b.build();
        let r = SimWorld::new(scenario).run();
        Msg::RunDone {
            node: self.node.clone(),
            scenario: format!("fleet_{fleet}[{}]", assigned.len()),
            miss_rate: r.miss_rate,
            p99_ms: r.p99_ms,
            p95_ms: r.p95_ms,
            rps: r.rps,
            completed: r.completed,
            moves_per_hour: r.moves_per_hour,
        }
    }

    /// Connect to the leader and serve until `Shutdown`. A literal
    /// socket address gets a bounded connect (30 s) so a worker aimed at
    /// a dead leader fails fast instead of hanging in SYN retries.
    pub fn serve(&self, leader_addr: &str) -> Result<()> {
        let mut stream = match leader_addr.parse::<std::net::SocketAddr>() {
            Ok(sa) => TcpStream::connect_timeout(&sa, std::time::Duration::from_secs(30))?,
            Err(_) => TcpStream::connect(leader_addr)?,
        };
        write_msg(
            &mut stream,
            &Msg::Hello {
                node: self.node.clone(),
                gpus: 8,
            },
        )?;
        loop {
            match read_msg(&mut stream)? {
                Msg::RunScenario {
                    seed,
                    levers,
                    horizon_s,
                    workload,
                    shards,
                } => {
                    if self.crash_on_dispatch {
                        crate::log_warn!("cluster.worker", "{}: injected crash on dispatch", self.node);
                        return Ok(());
                    }
                    let done = self.run_scenario(seed, &levers, horizon_s, &workload, shards);
                    write_msg(&mut stream, &done)?;
                }
                Msg::RunTenantSet {
                    seed,
                    world_seed,
                    levers,
                    horizon_s,
                    fleet,
                    count,
                    assigned,
                } => {
                    if self.crash_on_dispatch {
                        crate::log_warn!("cluster.worker", "{}: injected crash on dispatch", self.node);
                        return Ok(());
                    }
                    let done = self.run_tenant_set(
                        seed, world_seed, &levers, horizon_s, &fleet, count, &assigned,
                    );
                    write_msg(&mut stream, &done)?;
                }
                Msg::Shutdown => return Ok(()),
                other => {
                    crate::log_warn!("cluster.worker", "unexpected message {other:?}");
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn local_run_produces_stats() {
        let w = Worker::new("test-node");
        let msg = w.run_scenario(3, "static", 60.0, "single", 1);
        match msg {
            Msg::RunDone {
                node,
                completed,
                p99_ms,
                ..
            } => {
                assert_eq!(node, "test-node");
                assert!(completed > 3_000);
                assert!(p99_ms > 0.0);
            }
            _ => panic!("expected RunDone"),
        }
    }

    #[test]
    fn catalog_workloads_run_on_workers() {
        let w = Worker::new("cat-node");
        for name in ["multi_ls_slo_mix", "pcie_hotspot", "diurnal_burst"] {
            match w.run_scenario(3, "static", 45.0, name, 1) {
                Msg::RunDone {
                    completed,
                    scenario,
                    ..
                } => {
                    assert!(completed > 500, "{name}: completed {completed}");
                    // The worker echoes what it actually ran.
                    assert_eq!(scenario, name);
                }
                other => panic!("unexpected {other:?}"),
            }
        }
    }

    #[test]
    fn typoed_workload_is_detectable_from_the_echo() {
        let w = Worker::new("typo-node");
        match w.run_scenario(3, "static", 45.0, "pcie_hotpsot", 1) {
            Msg::RunDone { scenario, .. } => {
                // Falls back for wire compatibility, but the echoed name
                // exposes the mismatch to the caller.
                assert_eq!(scenario, "paper_single_host");
            }
            other => panic!("unexpected {other:?}"),
        }
    }

    #[test]
    fn sharded_worker_run_is_bit_identical_to_reference() {
        // The shard count is a pure performance lever: every metric in
        // the RunDone reply must match the reference engine exactly.
        let w = Worker::new("shard-node");
        let reference = w.run_scenario(3, "static", 45.0, "pcie_hotspot", 1);
        let sharded = w.run_scenario(3, "static", 45.0, "pcie_hotspot", 4);
        assert_eq!(reference, sharded);
    }

    #[test]
    fn worker_runs_a_fleet_tenant_subset() {
        use crate::alloc::{AutoRequest, FleetAllocator};
        use crate::topo::HostTopology;
        let count = 8;
        let tenants = Scenario::auto_pack_tenants(5, count);
        let reqs = AutoRequest::from_workloads(&tenants);
        let plan = FleetAllocator::new(
            1,
            HostTopology::p4d(),
            ControllerConfig::dense_pack(Levers::none()),
        )
        .pack(&reqs);
        let assigned = &plan.hosts[0].assigned;
        assert_eq!(assigned.len(), count, "8 small tenants fit one host");
        let w = Worker::new("fleet-node");
        match w.run_tenant_set(5, 6, "static", 60.0, "auto_pack", count, assigned) {
            Msg::RunDone {
                node,
                completed,
                scenario,
                ..
            } => {
                assert_eq!(node, "fleet-node");
                assert!(completed > 500, "completed {completed}");
                assert!(scenario.starts_with("fleet_auto_pack"), "{scenario}");
            }
            other => panic!("unexpected {other:?}"),
        }
    }

    #[test]
    fn unknown_fleet_list_is_refused_not_substituted() {
        // Running a different tenant list at slots planned for another
        // would be silently wrong; the worker must refuse.
        let w = Worker::new("strict-node");
        match w.run_tenant_set(5, 5, "static", 30.0, "trace_pack", 8, &[]) {
            Msg::RunDone {
                scenario,
                completed,
                miss_rate,
                ..
            } => {
                assert_eq!(scenario, "error:unknown_fleet:trace_pack");
                assert_eq!(completed, 0);
                assert_eq!(miss_rate, 1.0);
            }
            other => panic!("unexpected {other:?}"),
        }
    }

    #[test]
    fn out_of_range_assignment_is_refused_not_panicked() {
        use crate::alloc::Assignment;
        use crate::gpu::MigProfile;
        let w = Worker::new("bounds-node");
        let bad = [Assignment {
            tenant: 99, // fleet list only has 4
            gpu: 0,
            profile: MigProfile::P1g10gb,
            start: 0,
        }];
        match w.run_tenant_set(5, 5, "static", 30.0, "auto_pack", 4, &bad) {
            Msg::RunDone {
                scenario,
                completed,
                ..
            } => {
                assert_eq!(scenario, "error:assignment_out_of_range:99");
                assert_eq!(completed, 0);
            }
            other => panic!("unexpected {other:?}"),
        }
    }

    #[test]
    fn lever_parsing() {
        assert_eq!(levers_from_str("mig"), Levers::mig_only());
        assert_eq!(levers_from_str("bogus-default"), Levers::full());
        assert_eq!(levers_from_str("static"), Levers::none());
    }
}
