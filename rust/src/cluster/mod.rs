//! Multi-node cluster runtime (the paper's 2-node / 16-GPU deployment,
//! §3.1, orchestrated there by Slurm; here by a Slurm-like launcher).
//!
//! * Each **worker** owns one simulated host (or a local serving engine)
//!   and runs its own host-level controller — the paper's design point:
//!   control is per-host, no fabric privileges needed.
//! * The **leader** launches workers, routes work with
//!   [`crate::serving::Router`] semantics, and aggregates per-host
//!   results into cluster-level tables.
//!
//! Transport is length-prefixed JSON over TCP (`std::net`; the vendor
//! set is offline-first, so no tokio — see `docs/ARCHITECTURE.md`).

pub mod proto;
pub mod worker;
pub mod leader;

pub use leader::{ClusterOpts, ClusterReport, Leader, NodeReport};
pub use proto::{read_msg, write_msg, Msg, ProtoError};
pub use worker::Worker;
