//! Exponential moving averages + hysteresis bands.
//!
//! §2 of the paper: "Signals are smoothed with exponential moving averages
//! and hysteresis to reduce spurious triggers." These are the exact
//! primitives the controller's monitoring domain uses.

/// Exponentially-weighted moving average with configurable smoothing factor.
#[derive(Clone, Debug)]
pub struct Ewma {
    alpha: f64,
    value: Option<f64>,
}

impl Ewma {
    /// `alpha` in (0, 1]: weight of the newest observation.
    pub fn new(alpha: f64) -> Self {
        assert!(alpha > 0.0 && alpha <= 1.0, "alpha out of range: {alpha}");
        Ewma { alpha, value: None }
    }

    /// EWMA whose step response reaches ~63% after `n` observations
    /// (alpha = 2/(n+1), the usual span parameterization).
    pub fn with_span(n: usize) -> Self {
        Self::new(2.0 / (n as f64 + 1.0))
    }

    pub fn observe(&mut self, x: f64) -> f64 {
        let v = match self.value {
            None => x,
            Some(prev) => prev + self.alpha * (x - prev),
        };
        self.value = Some(v);
        v
    }

    pub fn value(&self) -> Option<f64> {
        self.value
    }

    pub fn value_or(&self, default: f64) -> f64 {
        self.value.unwrap_or(default)
    }

    pub fn reset(&mut self) {
        self.value = None;
    }
}

/// Two-threshold hysteresis: asserts when the smoothed signal crosses
/// `high`, deasserts only after it falls below `low` (< high). Prevents the
/// trigger from chattering when the tail hovers around τ.
#[derive(Clone, Debug)]
pub struct Hysteresis {
    low: f64,
    high: f64,
    active: bool,
}

impl Hysteresis {
    pub fn new(low: f64, high: f64) -> Self {
        assert!(low <= high, "hysteresis band inverted: {low} > {high}");
        Hysteresis {
            low,
            high,
            active: false,
        }
    }

    /// Symmetric band around a threshold: `threshold*(1±margin_frac)`.
    pub fn around(threshold: f64, margin_frac: f64) -> Self {
        Self::new(threshold * (1.0 - margin_frac), threshold * (1.0 + margin_frac))
    }

    /// Update with a new (already smoothed) observation.
    pub fn observe(&mut self, x: f64) -> bool {
        if self.active {
            if x < self.low {
                self.active = false;
            }
        } else if x > self.high {
            self.active = true;
        }
        self.active
    }

    pub fn is_active(&self) -> bool {
        self.active
    }
}

/// Counts consecutive observations above a threshold — the paper's
/// "p99 > τ for Y consecutive windows" persistence condition.
#[derive(Clone, Debug)]
pub struct Persistence {
    threshold: f64,
    required: u32,
    streak: u32,
}

impl Persistence {
    pub fn new(threshold: f64, required: u32) -> Self {
        Persistence {
            threshold,
            required,
            streak: 0,
        }
    }

    /// Returns true when the condition has held for >= `required`
    /// consecutive observations.
    pub fn observe(&mut self, x: f64) -> bool {
        if x > self.threshold {
            self.streak = self.streak.saturating_add(1);
        } else {
            self.streak = 0;
        }
        self.streak >= self.required
    }

    pub fn streak(&self) -> u32 {
        self.streak
    }

    pub fn reset(&mut self) {
        self.streak = 0;
    }

    pub fn threshold(&self) -> f64 {
        self.threshold
    }

    pub fn set_threshold(&mut self, t: f64) {
        self.threshold = t;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ewma_first_observation_passthrough() {
        let mut e = Ewma::new(0.3);
        assert_eq!(e.observe(10.0), 10.0);
    }

    #[test]
    fn ewma_converges_to_constant() {
        let mut e = Ewma::new(0.5);
        for _ in 0..64 {
            e.observe(3.0);
        }
        assert!((e.value().unwrap() - 3.0).abs() < 1e-9);
    }

    #[test]
    fn ewma_span_weighting() {
        // span=1 => alpha=1 => tracks input exactly.
        let mut e = Ewma::with_span(1);
        e.observe(1.0);
        assert_eq!(e.observe(9.0), 9.0);
    }

    #[test]
    #[should_panic]
    fn ewma_rejects_zero_alpha() {
        Ewma::new(0.0);
    }

    #[test]
    fn hysteresis_latches() {
        let mut h = Hysteresis::new(10.0, 20.0);
        assert!(!h.observe(15.0)); // below high: stays off
        assert!(h.observe(25.0)); // crosses high: on
        assert!(h.observe(15.0)); // inside band: stays on
        assert!(!h.observe(5.0)); // below low: off
    }

    #[test]
    fn hysteresis_around_builds_band() {
        let mut h = Hysteresis::around(100.0, 0.1);
        assert!(h.observe(111.0));
        assert!(h.observe(95.0)); // still >= 90
        assert!(!h.observe(89.0));
    }

    #[test]
    fn persistence_requires_consecutive() {
        let mut p = Persistence::new(15.0, 3);
        assert!(!p.observe(16.0));
        assert!(!p.observe(16.0));
        assert!(p.observe(16.0));
        p.observe(14.0); // resets
        assert_eq!(p.streak(), 0);
        assert!(!p.observe(16.0));
    }
}
