//! Log-bucketed latency histogram (HdrHistogram-style, base-2 sub-bucketed).
//!
//! Used for the full latency distributions behind Figure 4 and for the
//! p95/p99/p999 columns of the result tables. Values are recorded in
//! microseconds (u64); relative quantile error is bounded by the
//! sub-bucket resolution (1/32 ≈ 3%, plenty for the paper's tables which
//! report 0.1 ms granularity).

const SUB_BITS: u32 = 5; // 32 sub-buckets per octave => <= ~3.1% rel. error
const SUB: usize = 1 << SUB_BITS;

/// Fixed-footprint log-linear histogram over u64 values.
#[derive(Clone, Debug)]
pub struct Histogram {
    counts: Vec<u64>,
    total: u64,
    min: u64,
    max: u64,
    sum: u128,
}

impl Default for Histogram {
    fn default() -> Self {
        Self::new()
    }
}

impl Histogram {
    pub fn new() -> Self {
        // 64 octaves x 32 sub-buckets covers the full u64 range.
        Histogram {
            counts: vec![0; 64 * SUB],
            total: 0,
            min: u64::MAX,
            max: 0,
            sum: 0,
        }
    }

    #[inline]
    fn index(value: u64) -> usize {
        if value < SUB as u64 {
            return value as usize;
        }
        let msb = 63 - value.leading_zeros();
        let shift = msb - SUB_BITS;
        let sub = (value >> shift) as usize & (SUB - 1);
        ((shift + 1) as usize) * SUB + sub
    }

    /// Lower edge and width of bucket `i` (the bucket covers the
    /// integer values `[lo, lo + width)`).
    fn bucket_range(i: usize) -> (u64, u64) {
        let octave = i / SUB;
        let sub = (i % SUB) as u64;
        if octave == 0 {
            return (sub, 1);
        }
        let shift = (octave - 1) as u32;
        (((SUB as u64) + sub) << shift, 1u64 << shift)
    }

    /// Representative value reported for quantiles: midpoint of the
    /// bucket.
    fn bucket_mid(i: usize) -> u64 {
        let octave = i / SUB;
        let sub = (i % SUB) as u64;
        if octave == 0 {
            return sub;
        }
        let shift = (octave - 1) as u32;
        let lo = ((SUB as u64) + sub) << shift;
        let width = 1u64 << shift;
        lo + width / 2
    }

    #[inline]
    pub fn record(&mut self, value: u64) {
        self.counts[Self::index(value)] += 1;
        self.total += 1;
        self.min = self.min.min(value);
        self.max = self.max.max(value);
        self.sum += value as u128;
    }

    pub fn count(&self) -> u64 {
        self.total
    }

    pub fn min(&self) -> u64 {
        if self.total == 0 {
            0
        } else {
            self.min
        }
    }

    pub fn max(&self) -> u64 {
        self.max
    }

    pub fn mean(&self) -> f64 {
        if self.total == 0 {
            0.0
        } else {
            self.sum as f64 / self.total as f64
        }
    }

    /// Nearest-rank quantile, `q` in [0, 1] — the same rank convention
    /// as `WindowQuantiles::quantile` and `P2Quantile::value`
    /// ([`crate::util::quantile::nearest_rank_index`]), resolved at
    /// bucket granularity.
    pub fn quantile(&self, q: f64) -> u64 {
        if self.total == 0 {
            return 0;
        }
        let rank = crate::util::quantile::nearest_rank_index(q, self.total as usize) as u64 + 1;
        let mut seen = 0u64;
        for (i, &c) in self.counts.iter().enumerate() {
            seen += c;
            if seen >= rank {
                return Self::bucket_mid(i).clamp(self.min, self.max);
            }
        }
        self.max
    }

    /// Fraction of recorded values strictly greater than `threshold` —
    /// the same "strictly above" convention as
    /// `WindowQuantiles::frac_above` (which is exact). Buckets entirely
    /// above the threshold count in full; the threshold's own bucket
    /// contributes the fraction of its integer values in
    /// `(threshold, bucket_end)` (uniform-within-bucket assumption)
    /// instead of the old all-or-nothing midpoint attribution, bounding
    /// the divergence from the exact estimator by the sub-bucket
    /// resolution rather than a whole bucket's mass. Exact for
    /// thresholds in the unit-width first octave.
    pub fn frac_above(&self, threshold: u64) -> f64 {
        if self.total == 0 {
            return 0.0;
        }
        let t_idx = Self::index(threshold);
        let mut above = 0.0f64;
        for (i, &c) in self.counts.iter().enumerate() {
            if c == 0 {
                continue;
            }
            if i > t_idx {
                above += c as f64;
            } else if i == t_idx {
                let (lo, width) = Self::bucket_range(i);
                // Integer values strictly above `threshold` within
                // [lo, lo + width): those in [threshold + 1, lo + width).
                let above_in_bucket = (lo + width - 1).saturating_sub(threshold);
                above += c as f64 * above_in_bucket as f64 / width as f64;
            }
        }
        above / self.total as f64
    }

    /// Merge another histogram into this one (per-repeat aggregation).
    pub fn merge(&mut self, other: &Histogram) {
        for (a, b) in self.counts.iter_mut().zip(&other.counts) {
            *a += b;
        }
        self.total += other.total;
        self.min = self.min.min(other.min);
        self.max = self.max.max(other.max);
        self.sum += other.sum;
    }

    pub fn clear(&mut self) {
        self.counts.iter_mut().for_each(|c| *c = 0);
        self.total = 0;
        self.min = u64::MAX;
        self.max = 0;
        self.sum = 0;
    }

    /// Export non-empty buckets as (bucket_mid, count) — the series behind
    /// the Figure 4 distribution plot.
    pub fn series(&self) -> Vec<(u64, u64)> {
        self.counts
            .iter()
            .enumerate()
            .filter(|(_, &c)| c > 0)
            .map(|(i, &c)| (Self::bucket_mid(i), c))
            .collect()
    }

    /// CCDF points (value, P(X > value)) for tail plots.
    pub fn ccdf(&self) -> Vec<(u64, f64)> {
        let mut out = Vec::new();
        let mut above = self.total;
        for (i, &c) in self.counts.iter().enumerate() {
            if c > 0 {
                above -= c;
                out.push((Self::bucket_mid(i), above as f64 / self.total as f64));
            }
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Pcg64;

    #[test]
    fn index_monotone_nonoverlapping() {
        let mut last = 0usize;
        for v in [0u64, 1, 31, 32, 33, 63, 64, 100, 1000, 1 << 20, u64::MAX / 2] {
            let i = Histogram::index(v);
            assert!(i >= last, "index not monotone at {v}");
            last = i;
        }
    }

    #[test]
    fn quantile_within_relative_error() {
        let mut rng = Pcg64::seeded(21);
        let mut h = Histogram::new();
        let mut xs = Vec::new();
        for _ in 0..100_000 {
            let x = (rng.lognormal(9.0, 0.7)) as u64; // ~8ms scale in us
            h.record(x);
            xs.push(x);
        }
        xs.sort_unstable();
        for q in [0.5, 0.95, 0.99, 0.999] {
            let exact = xs[((q * xs.len() as f64) as usize).min(xs.len() - 1)] as f64;
            let est = h.quantile(q) as f64;
            let rel = (est - exact).abs() / exact;
            assert!(rel < 0.05, "q={q} exact={exact} est={est} rel={rel}");
        }
    }

    #[test]
    fn mean_min_max_exact() {
        let mut h = Histogram::new();
        for v in [10u64, 20, 30] {
            h.record(v);
        }
        assert_eq!(h.min(), 10);
        assert_eq!(h.max(), 30);
        assert!((h.mean() - 20.0).abs() < 1e-9);
    }

    #[test]
    fn merge_adds_counts() {
        let mut a = Histogram::new();
        let mut b = Histogram::new();
        a.record(5);
        b.record(500);
        a.merge(&b);
        assert_eq!(a.count(), 2);
        assert_eq!(a.min(), 5);
        assert_eq!(a.max(), 500);
    }

    #[test]
    fn frac_above_boundaries() {
        let mut h = Histogram::new();
        for v in [10u64, 20, 30, 40] {
            h.record(v);
        }
        assert_eq!(h.frac_above(0), 1.0);
        assert_eq!(h.frac_above(u64::MAX / 2), 0.0);
        let f = h.frac_above(25);
        assert!((f - 0.5).abs() < 0.26, "f={f}"); // bucket-resolution bound
    }

    #[test]
    fn ccdf_is_monotone_decreasing() {
        let mut rng = Pcg64::seeded(22);
        let mut h = Histogram::new();
        for _ in 0..10_000 {
            h.record(rng.below(100_000));
        }
        let ccdf = h.ccdf();
        for w in ccdf.windows(2) {
            assert!(w[1].1 <= w[0].1);
        }
        assert!((ccdf.last().unwrap().1 - 0.0).abs() < 1e-12);
    }

    #[test]
    fn empty_histogram_safe() {
        let h = Histogram::new();
        assert_eq!(h.quantile(0.99), 0);
        assert_eq!(h.mean(), 0.0);
        assert_eq!(h.frac_above(10), 0.0);
    }
}
