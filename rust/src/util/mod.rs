//! Dependency-light utility substrate.
//!
//! The offline vendor set ships only `xla` + `anyhow`, so everything a
//! production serving stack would normally pull from crates.io is built
//! here: a seedable PRNG ([`rng`]), streaming statistics
//! ([`ewma`], [`quantile`], [`histogram`], [`stats`]), a JSON
//! parser/writer ([`json`]), a structured logger ([`log`]), and a small
//! property-testing framework ([`proptest_lite`]) standing in for
//! `proptest` on the coordinator invariants.

pub mod ewma;
pub mod histogram;
pub mod invariant;
pub mod json;
pub mod log;
pub mod proptest_lite;
pub mod quantile;
pub mod rng;
pub mod stats;
