//! Streaming tail-quantile estimation.
//!
//! The controller samples per-tenant p95/p99/p999 every Δ seconds (§2.1).
//! Two estimators are provided:
//!
//! * [`P2Quantile`] — the P² algorithm (Jain & Chlamtac 1985): O(1) memory,
//!   O(1) update; used on the controller hot path.
//! * [`WindowQuantiles`] — exact quantiles over a sliding window of the
//!   last N observations; used where the window semantics of Algorithm 1
//!   ("quantile(W, 0.99)") must be exact, and as the oracle the P² tests
//!   compare against.

/// 0-based index of the nearest-rank q-quantile over `n` sorted
/// observations: `ceil(q·n)` clamped to `[1, n]`, minus one.
///
/// This is THE quantile convention of the codebase — shared by
/// [`WindowQuantiles::quantile`], [`P2Quantile::value`]'s small-sample
/// fallback, and `Histogram::quantile`'s rank computation, so the three
/// estimators cannot drift apart near bucket/rank boundaries (the SLO
/// miss-rate the controller acts on and the one the report prints must
/// agree).
#[inline]
pub fn nearest_rank_index(q: f64, n: usize) -> usize {
    debug_assert!(n > 0, "nearest_rank_index needs at least one observation");
    ((q * n as f64).ceil() as usize).clamp(1, n) - 1
}

/// P² single-quantile estimator with five markers.
#[derive(Clone, Debug)]
pub struct P2Quantile {
    p: f64,
    // Marker heights and positions (1-based as in the paper).
    q: [f64; 5],
    n: [f64; 5],
    np: [f64; 5],
    dn: [f64; 5],
    count: usize,
    init: [f64; 5],
}

impl P2Quantile {
    pub fn new(p: f64) -> Self {
        assert!(p > 0.0 && p < 1.0);
        P2Quantile {
            p,
            q: [0.0; 5],
            n: [1.0, 2.0, 3.0, 4.0, 5.0],
            np: [1.0, 1.0 + 2.0 * p, 1.0 + 4.0 * p, 3.0 + 2.0 * p, 5.0],
            dn: [0.0, p / 2.0, p, (1.0 + p) / 2.0, 1.0],
            count: 0,
            init: [0.0; 5],
        }
    }

    pub fn observe(&mut self, x: f64) {
        if self.count < 5 {
            self.init[self.count] = x;
            self.count += 1;
            if self.count == 5 {
                self.init.sort_by(|a, b| a.partial_cmp(b).unwrap());
                self.q = self.init;
            }
            return;
        }
        self.count += 1;

        // Find cell k such that q[k] <= x < q[k+1]; adjust extremes.
        let k = if x < self.q[0] {
            self.q[0] = x;
            0
        } else if x >= self.q[4] {
            self.q[4] = x;
            3
        } else {
            let mut k = 0;
            for i in 0..4 {
                if x >= self.q[i] && x < self.q[i + 1] {
                    k = i;
                    break;
                }
            }
            k
        };

        for i in (k + 1)..5 {
            self.n[i] += 1.0;
        }
        for i in 0..5 {
            self.np[i] += self.dn[i];
        }

        // Adjust interior markers with parabolic (falling back to linear)
        // interpolation.
        for i in 1..4 {
            let d = self.np[i] - self.n[i];
            if (d >= 1.0 && self.n[i + 1] - self.n[i] > 1.0)
                || (d <= -1.0 && self.n[i - 1] - self.n[i] < -1.0)
            {
                let ds = d.signum();
                let qp = self.parabolic(i, ds);
                self.q[i] = if self.q[i - 1] < qp && qp < self.q[i + 1] {
                    qp
                } else {
                    self.linear(i, ds)
                };
                self.n[i] += ds;
            }
        }
    }

    fn parabolic(&self, i: usize, d: f64) -> f64 {
        let (qm, q0, qp) = (self.q[i - 1], self.q[i], self.q[i + 1]);
        let (nm, n0, np) = (self.n[i - 1], self.n[i], self.n[i + 1]);
        q0 + d / (np - nm)
            * ((n0 - nm + d) * (qp - q0) / (np - n0) + (np - n0 - d) * (q0 - qm) / (n0 - nm))
    }

    fn linear(&self, i: usize, d: f64) -> f64 {
        let j = (i as f64 + d) as usize;
        self.q[i] + d * (self.q[j] - self.q[i]) / (self.n[j] - self.n[i])
    }

    /// Current estimate; for < 5 observations falls back to the exact
    /// order statistic over what we have.
    pub fn value(&self) -> f64 {
        if self.count == 0 {
            return 0.0;
        }
        if self.count < 5 {
            let mut v = self.init[..self.count].to_vec();
            v.sort_by(|a, b| a.partial_cmp(b).unwrap());
            return v[nearest_rank_index(self.p, v.len())];
        }
        self.q[2]
    }

    pub fn count(&self) -> usize {
        self.count
    }
}

/// Exact quantiles over a fixed-capacity sliding window (ring buffer).
///
/// `quantile()` sorts a scratch copy — O(N log N) per query, fine at the
/// controller's 1-5 s sampling cadence with windows of a few thousand.
#[derive(Clone, Debug)]
pub struct WindowQuantiles {
    buf: Vec<f64>,
    head: usize,
    full: bool,
    /// Requested window size. Deliberately stored instead of using
    /// `buf.capacity()`: `Vec::with_capacity` only guarantees *at least*
    /// the requested capacity, so keying the ring wrap-around off the
    /// Vec's actual capacity would silently grow the window beyond the
    /// requested size — and make its length allocator-dependent.
    cap: usize,
    scratch: Vec<f64>,
}

impl WindowQuantiles {
    pub fn new(capacity: usize) -> Self {
        assert!(capacity > 0);
        WindowQuantiles {
            buf: Vec::with_capacity(capacity),
            head: 0,
            full: false,
            cap: capacity,
            scratch: Vec::with_capacity(capacity),
        }
    }

    pub fn observe(&mut self, x: f64) {
        if self.full {
            self.buf[self.head] = x;
            self.head = (self.head + 1) % self.cap;
        } else {
            self.buf.push(x);
            if self.buf.len() == self.cap {
                self.full = true;
            }
        }
    }

    /// The requested window size (not the backing Vec's capacity).
    pub fn capacity(&self) -> usize {
        self.cap
    }

    pub fn len(&self) -> usize {
        self.buf.len()
    }

    pub fn is_empty(&self) -> bool {
        self.buf.is_empty()
    }

    pub fn clear(&mut self) {
        self.buf.clear();
        self.head = 0;
        self.full = false;
    }

    /// Exact q-quantile (nearest-rank, matching `quantile(W, q)` in
    /// Algorithm 1). Returns None if the window is empty.
    ///
    /// Uses `select_nth_unstable` (introselect, O(n)) instead of a full
    /// sort — the telemetry sampler queries four quantiles per tick, and
    /// this cut the whole-run simulation wall time ~8% (EXPERIMENTS.md
    /// §Perf).
    pub fn quantile(&mut self, q: f64) -> Option<f64> {
        if self.buf.is_empty() {
            return None;
        }
        self.scratch.clear();
        self.scratch.extend_from_slice(&self.buf);
        let idx = nearest_rank_index(q, self.scratch.len());
        let (_, v, _) = self
            .scratch
            .select_nth_unstable_by(idx, |a, b| a.partial_cmp(b).unwrap());
        Some(*v)
    }

    /// Fraction of window observations strictly above `threshold` — the
    /// empirical SLO miss-rate over the window.
    pub fn frac_above(&self, threshold: f64) -> f64 {
        if self.buf.is_empty() {
            return 0.0;
        }
        self.buf.iter().filter(|&&x| x > threshold).count() as f64 / self.buf.len() as f64
    }

    pub fn mean(&self) -> f64 {
        if self.buf.is_empty() {
            return 0.0;
        }
        self.buf.iter().sum::<f64>() / self.buf.len() as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Pcg64;

    #[test]
    fn p2_matches_exact_on_uniform() {
        let mut rng = Pcg64::seeded(11);
        let mut p2 = P2Quantile::new(0.99);
        let mut xs = Vec::new();
        for _ in 0..50_000 {
            let x = rng.f64();
            p2.observe(x);
            xs.push(x);
        }
        xs.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let exact = xs[(0.99 * xs.len() as f64) as usize];
        assert!(
            (p2.value() - exact).abs() < 0.01,
            "p2={} exact={}",
            p2.value(),
            exact
        );
    }

    #[test]
    fn p2_matches_exact_on_lognormal_tail() {
        let mut rng = Pcg64::seeded(12);
        let mut p2 = P2Quantile::new(0.99);
        let mut xs = Vec::new();
        for _ in 0..100_000 {
            let x = rng.lognormal(2.0, 0.5);
            p2.observe(x);
            xs.push(x);
        }
        xs.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let exact = xs[(0.99 * xs.len() as f64) as usize];
        let rel = (p2.value() - exact).abs() / exact;
        assert!(rel < 0.05, "p2={} exact={} rel={}", p2.value(), exact, rel);
    }

    #[test]
    fn p2_few_observations_fallback() {
        let mut p2 = P2Quantile::new(0.5);
        p2.observe(3.0);
        p2.observe(1.0);
        p2.observe(2.0);
        assert_eq!(p2.value(), 2.0);
    }

    #[test]
    fn window_exact_quantile() {
        let mut w = WindowQuantiles::new(100);
        for i in 1..=100 {
            w.observe(i as f64);
        }
        assert_eq!(w.quantile(0.5), Some(50.0));
        assert_eq!(w.quantile(0.99), Some(99.0));
        assert_eq!(w.quantile(1.0), Some(100.0));
    }

    #[test]
    fn window_evicts_oldest() {
        let mut w = WindowQuantiles::new(3);
        for x in [1.0, 2.0, 3.0, 100.0] {
            w.observe(x);
        }
        // Window now holds {2, 3, 100}.
        assert_eq!(w.quantile(0.5), Some(3.0));
        assert_eq!(w.len(), 3);
    }

    #[test]
    fn window_frac_above() {
        let mut w = WindowQuantiles::new(10);
        for x in [10.0, 20.0, 30.0, 40.0] {
            w.observe(x);
        }
        assert!((w.frac_above(25.0) - 0.5).abs() < 1e-12);
        assert_eq!(w.frac_above(100.0), 0.0);
    }

    #[test]
    fn window_empty_returns_none() {
        let mut w = WindowQuantiles::new(4);
        assert_eq!(w.quantile(0.99), None);
    }

    #[test]
    fn window_never_exceeds_requested_capacity() {
        // Regression: the ring wrap-around must key off the *requested*
        // capacity, not `Vec::capacity()` (which is only a lower bound and
        // may over-allocate) — otherwise the window silently grows and its
        // contents become allocator-dependent.
        for cap in [1usize, 3, 5, 7, 100] {
            let mut w = WindowQuantiles::new(cap);
            assert_eq!(w.capacity(), cap);
            for i in 0..(cap * 4 + 3) {
                w.observe(i as f64);
                assert!(w.len() <= cap, "cap {cap}: window grew to {}", w.len());
            }
            assert_eq!(w.len(), cap);
            assert_eq!(w.capacity(), cap);
        }
    }

    #[test]
    fn window_eviction_is_exact_fifo_after_many_wraps() {
        let cap = 5;
        let mut w = WindowQuantiles::new(cap);
        for i in 1..=23 {
            w.observe(i as f64);
        }
        // Window must hold exactly the last 5 observations: 19..=23.
        assert_eq!(w.len(), cap);
        assert_eq!(w.quantile(0.2), Some(19.0));
        assert_eq!(w.quantile(0.5), Some(21.0));
        assert_eq!(w.quantile(1.0), Some(23.0));
        assert_eq!(w.frac_above(21.5), 2.0 / 5.0);
    }
}
