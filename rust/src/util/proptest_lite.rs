//! Property-based testing (in-repo `proptest` substitute).
//!
//! Generators are closures over [`Pcg64`]; [`check`] runs N seeded cases
//! and, on failure, retries with progressively "smaller" inputs by
//! re-generating under a shrink budget and reporting the smallest failing
//! seed. Simpler than real proptest shrinking, but failures always print a
//! reproducible `(seed, case)` pair.
//!
//! Used by rust/tests/properties.rs on the coordinator invariants
//! (routing, batching, KV-cache state, dwell/cool-down, PS conservation).

use crate::util::rng::Pcg64;

/// Configuration for a property run.
#[derive(Clone, Copy, Debug)]
pub struct Config {
    pub cases: u64,
    pub seed: u64,
}

impl Default for Config {
    fn default() -> Self {
        Config {
            cases: 256,
            seed: 0x5eed,
        }
    }
}

/// Run `prop` on `cfg.cases` generated inputs. Panics with the failing
/// seed/case on the first counterexample.
pub fn check<T, G, P>(cfg: Config, name: &str, mut gen: G, mut prop: P)
where
    T: std::fmt::Debug,
    G: FnMut(&mut Pcg64) -> T,
    P: FnMut(&T) -> Result<(), String>,
{
    for case in 0..cfg.cases {
        let mut rng = Pcg64::new(cfg.seed.wrapping_add(case), case);
        let input = gen(&mut rng);
        if let Err(msg) = prop(&input) {
            panic!(
                "property '{name}' failed at case {case} (seed {}):\n  {msg}\n  input: {input:?}",
                cfg.seed.wrapping_add(case)
            );
        }
    }
}

/// Convenience: run with defaults.
pub fn quick<T, G, P>(name: &str, gen: G, prop: P)
where
    T: std::fmt::Debug,
    G: FnMut(&mut Pcg64) -> T,
    P: FnMut(&T) -> Result<(), String>,
{
    check(Config::default(), name, gen, prop);
}

/// Generator helpers.
pub mod gen {
    use crate::util::rng::Pcg64;

    pub fn vec_f64(rng: &mut Pcg64, max_len: usize, lo: f64, hi: f64) -> Vec<f64> {
        let n = rng.below(max_len as u64 + 1) as usize;
        (0..n).map(|_| rng.range_f64(lo, hi)).collect()
    }

    pub fn vec_u64(rng: &mut Pcg64, max_len: usize, lo: u64, hi: u64) -> Vec<u64> {
        let n = rng.below(max_len as u64 + 1) as usize;
        (0..n).map(|_| rng.range_u64(lo, hi)).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_completes() {
        quick(
            "sort is idempotent",
            |rng| gen::vec_f64(rng, 32, 0.0, 100.0),
            |xs| {
                let mut a = xs.clone();
                a.sort_by(|x, y| x.partial_cmp(y).unwrap());
                let mut b = a.clone();
                b.sort_by(|x, y| x.partial_cmp(y).unwrap());
                if a == b {
                    Ok(())
                } else {
                    Err("not idempotent".into())
                }
            },
        );
    }

    #[test]
    #[should_panic(expected = "always fails")]
    fn failing_property_panics_with_seed() {
        check(
            Config { cases: 4, seed: 1 },
            "always fails",
            |rng| rng.below(10),
            |_| Err("nope".into()),
        );
    }
}
