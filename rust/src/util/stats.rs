//! Summary statistics across repeated runs: mean ± 95% confidence interval.
//!
//! §3.1: "Experiments were repeated 7 times with fixed seeds; we report
//! means with 95% confidence intervals." The CI uses the Student-t
//! critical value for small n (7 repeats ⇒ 6 dof ⇒ t = 2.447).

/// Mean, standard deviation and 95% CI half-width over a sample.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct Summary {
    pub n: usize,
    pub mean: f64,
    pub std: f64,
    /// Half-width of the 95% confidence interval of the mean.
    pub ci95: f64,
}

impl Summary {
    pub fn of(xs: &[f64]) -> Summary {
        let n = xs.len();
        if n == 0 {
            return Summary {
                n: 0,
                mean: 0.0,
                std: 0.0,
                ci95: 0.0,
            };
        }
        let mean = xs.iter().sum::<f64>() / n as f64;
        if n == 1 {
            return Summary {
                n,
                mean,
                std: 0.0,
                ci95: 0.0,
            };
        }
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / (n as f64 - 1.0);
        let std = var.sqrt();
        let se = std / (n as f64).sqrt();
        Summary {
            n,
            mean,
            std,
            ci95: t_crit_95(n - 1) * se,
        }
    }

    /// Format as `mean ± ci` with the given precision, e.g. `16.5 ± 0.7`.
    pub fn fmt(&self, decimals: usize) -> String {
        format!(
            "{:.d$} ± {:.d$}",
            self.mean,
            self.ci95,
            d = decimals
        )
    }
}

/// Two-sided 95% Student-t critical value for `dof` degrees of freedom.
/// Table through 30 dof, then the normal approximation.
pub fn t_crit_95(dof: usize) -> f64 {
    const TABLE: [f64; 31] = [
        f64::NAN,
        12.706, 4.303, 3.182, 2.776, 2.571, 2.447, 2.365, 2.306, 2.262, 2.228,
        2.201, 2.179, 2.160, 2.145, 2.131, 2.120, 2.110, 2.101, 2.093, 2.086,
        2.080, 2.074, 2.069, 2.064, 2.060, 2.056, 2.052, 2.048, 2.045, 2.042,
    ];
    if dof == 0 {
        return f64::NAN;
    }
    if dof <= 30 {
        TABLE[dof]
    } else {
        1.960
    }
}

/// Welford online mean/variance — used by telemetry counters that cannot
/// buffer samples.
#[derive(Clone, Copy, Debug, Default)]
pub struct Welford {
    n: u64,
    mean: f64,
    m2: f64,
}

impl Welford {
    pub fn observe(&mut self, x: f64) {
        self.n += 1;
        let d = x - self.mean;
        self.mean += d / self.n as f64;
        self.m2 += d * (x - self.mean);
    }

    pub fn n(&self) -> u64 {
        self.n
    }

    pub fn mean(&self) -> f64 {
        self.mean
    }

    pub fn variance(&self) -> f64 {
        if self.n < 2 {
            0.0
        } else {
            self.m2 / (self.n as f64 - 1.0)
        }
    }

    pub fn std(&self) -> f64 {
        self.variance().sqrt()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn summary_basic() {
        let s = Summary::of(&[1.0, 2.0, 3.0]);
        assert!((s.mean - 2.0).abs() < 1e-12);
        assert!((s.std - 1.0).abs() < 1e-12);
        // t(2 dof) = 4.303, se = 1/sqrt(3)
        assert!((s.ci95 - 4.303 / 3f64.sqrt()).abs() < 1e-9);
    }

    #[test]
    fn summary_seven_repeats_uses_t6() {
        let xs = [10.0, 11.0, 9.0, 10.5, 9.5, 10.2, 9.8];
        let s = Summary::of(&xs);
        assert_eq!(s.n, 7);
        let mean = xs.iter().sum::<f64>() / 7.0;
        assert!((s.mean - mean).abs() < 1e-12);
        assert!(s.ci95 > 0.0);
    }

    #[test]
    fn summary_degenerate() {
        assert_eq!(Summary::of(&[]).n, 0);
        let one = Summary::of(&[5.0]);
        assert_eq!(one.mean, 5.0);
        assert_eq!(one.ci95, 0.0);
    }

    #[test]
    fn fmt_matches_paper_style() {
        let s = Summary {
            n: 7,
            mean: 16.5,
            std: 0.0,
            ci95: 0.7,
        };
        assert_eq!(s.fmt(1), "16.5 ± 0.7");
    }

    #[test]
    fn welford_matches_batch() {
        let xs = [3.0, 1.0, 4.0, 1.0, 5.0, 9.0, 2.0, 6.0];
        let mut w = Welford::default();
        for &x in &xs {
            w.observe(x);
        }
        let s = Summary::of(&xs);
        assert!((w.mean() - s.mean).abs() < 1e-12);
        assert!((w.std() - s.std).abs() < 1e-12);
    }

    #[test]
    fn t_crit_monotone_decreasing() {
        assert!(t_crit_95(1) > t_crit_95(6));
        assert!(t_crit_95(6) > t_crit_95(30));
        assert!((t_crit_95(100) - 1.96).abs() < 1e-9);
    }
}
