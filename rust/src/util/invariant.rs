//! Typed internal-invariant violations.
//!
//! A desync between two pieces of engine state (a fabric flow without a
//! recorded purpose, a batcher row that vanished mid-step) is a bug in
//! *this* codebase, not a user error — but a bare `unwrap()` reports it
//! as `called Option::unwrap() on a None value`, throwing away exactly
//! the context (which tenant? which flow? at what sim time?) needed to
//! diagnose it. [`InvariantError`] carries that context; paths that
//! already return `anyhow::Result` propagate it as an error, and
//! hot-path code that cannot (the sim event loop) fails through
//! [`InvariantError::panic`] so the message still names the broken
//! invariant.

use std::fmt;

/// A violated internal invariant, with enough context to diagnose the
/// desync that produced it.
#[derive(Debug, Clone)]
pub struct InvariantError {
    /// The invariant that failed, e.g. `"fabric flow has a recorded purpose"`.
    pub invariant: String,
    /// Where/when it failed: tenant, flow, row, sim time, ...
    pub context: String,
}

impl InvariantError {
    pub fn new(invariant: impl Into<String>, context: impl Into<String>) -> InvariantError {
        InvariantError {
            invariant: invariant.into(),
            context: context.into(),
        }
    }

    /// Fail a non-`Result` path (the sim event loop) with the full
    /// diagnostic instead of a bare unwrap panic.
    pub fn panic(self) -> ! {
        panic!("{self}")
    }
}

impl fmt::Display for InvariantError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(
            f,
            "internal invariant violated: {} [{}]",
            self.invariant, self.context
        )
    }
}

impl std::error::Error for InvariantError {}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn display_names_invariant_and_context() {
        let e = InvariantError::new(
            "fabric flow has a recorded purpose",
            "flow=7 tenant=2 t=12.5s",
        );
        let s = e.to_string();
        assert!(s.contains("internal invariant violated"));
        assert!(s.contains("recorded purpose"));
        assert!(s.contains("flow=7"));
    }

    #[test]
    #[should_panic(expected = "internal invariant violated: row occupied [row=3]")]
    fn panic_carries_message() {
        InvariantError::new("row occupied", "row=3").panic();
    }

    #[test]
    fn converts_into_anyhow() {
        let e = InvariantError::new("kv table row exists", "seq=9");
        let a: anyhow::Error = e.into();
        assert!(a.to_string().contains("kv table row exists"));
    }
}
