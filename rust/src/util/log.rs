//! Structured event logging with levels and an in-memory sink for tests.
//!
//! §2.4: "We gate actions behind feature flags, log all decisions with
//! signal snapshots for audit". The controller's audit trail
//! (controller::audit) is built on this logger; stderr output is gated by
//! `PREDSERVE_LOG` (error|warn|info|debug|trace).

use std::sync::atomic::{AtomicU8, Ordering};
use std::sync::{Mutex, OnceLock};

#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord)]
pub enum Level {
    Error = 0,
    Warn = 1,
    Info = 2,
    Debug = 3,
    Trace = 4,
}

impl Level {
    pub fn as_str(self) -> &'static str {
        match self {
            Level::Error => "ERROR",
            Level::Warn => "WARN",
            Level::Info => "INFO",
            Level::Debug => "DEBUG",
            Level::Trace => "TRACE",
        }
    }

    fn from_env(s: &str) -> Level {
        match s.to_ascii_lowercase().as_str() {
            "error" => Level::Error,
            "warn" => Level::Warn,
            "debug" => Level::Debug,
            "trace" => Level::Trace,
            _ => Level::Info,
        }
    }
}

static MAX_LEVEL: AtomicU8 = AtomicU8::new(255); // 255 = uninitialized
static CAPTURE: OnceLock<Mutex<Vec<String>>> = OnceLock::new();

fn max_level() -> u8 {
    let v = MAX_LEVEL.load(Ordering::Relaxed);
    if v != 255 {
        return v;
    }
    let lvl = std::env::var("PREDSERVE_LOG")
        .map(|s| Level::from_env(&s))
        .unwrap_or(Level::Warn) as u8;
    MAX_LEVEL.store(lvl, Ordering::Relaxed);
    lvl
}

/// Override the level programmatically (CLI `--log-level`).
pub fn set_level(level: Level) {
    MAX_LEVEL.store(level as u8, Ordering::Relaxed);
}

/// Route log lines into an in-memory buffer (tests assert on decisions).
pub fn capture() {
    CAPTURE.get_or_init(|| Mutex::new(Vec::new()));
}

/// Drain captured lines.
pub fn drain_captured() -> Vec<String> {
    CAPTURE
        .get()
        .map(|m| std::mem::take(&mut *m.lock().unwrap()))
        .unwrap_or_default()
}

pub fn enabled(level: Level) -> bool {
    (level as u8) <= max_level()
}

pub fn log(level: Level, module: &str, msg: &str) {
    if !enabled(level) {
        return;
    }
    let line = format!("[{}] {}: {}", level.as_str(), module, msg);
    if let Some(buf) = CAPTURE.get() {
        buf.lock().unwrap().push(line);
    } else {
        eprintln!("{line}");
    }
}

#[macro_export]
macro_rules! log_info {
    ($module:expr, $($arg:tt)*) => {
        $crate::util::log::log($crate::util::log::Level::Info, $module, &format!($($arg)*))
    };
}

#[macro_export]
macro_rules! log_warn {
    ($module:expr, $($arg:tt)*) => {
        $crate::util::log::log($crate::util::log::Level::Warn, $module, &format!($($arg)*))
    };
}

#[macro_export]
macro_rules! log_debug {
    ($module:expr, $($arg:tt)*) => {
        $crate::util::log::log($crate::util::log::Level::Debug, $module, &format!($($arg)*))
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn level_ordering() {
        assert!(Level::Error < Level::Trace);
        assert_eq!(Level::from_env("DEBUG"), Level::Debug);
        assert_eq!(Level::from_env("bogus"), Level::Info);
    }

    #[test]
    fn capture_collects_lines() {
        capture();
        set_level(Level::Info);
        log(Level::Info, "test", "hello");
        log(Level::Trace, "test", "filtered");
        let lines = drain_captured();
        assert!(lines.iter().any(|l| l.contains("hello")));
        assert!(!lines.iter().any(|l| l.contains("filtered")));
    }
}
