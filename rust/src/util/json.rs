//! Minimal JSON parser/writer (serde substitute for the offline build).
//!
//! Parses the AOT `artifacts/manifest.json` ABI, the experiment config
//! files, and the cluster wire protocol. Full JSON grammar (RFC 8259)
//! minus `\u` surrogate-pair edge handling beyond the BMP.

use std::collections::BTreeMap;
use std::fmt;

/// A JSON value. Object keys are ordered (BTreeMap) so serialization is
/// deterministic — golden tests depend on it.
#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

#[derive(Debug)]
pub struct JsonError {
    pub msg: String,
    pub offset: usize,
}

impl fmt::Display for JsonError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "json error at byte {}: {}", self.offset, self.msg)
    }
}

impl std::error::Error for JsonError {}

impl Json {
    pub fn parse(s: &str) -> Result<Json, JsonError> {
        let mut p = Parser {
            bytes: s.as_bytes(),
            pos: 0,
        };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.pos != p.bytes.len() {
            return Err(p.err("trailing data"));
        }
        Ok(v)
    }

    // -- typed accessors ---------------------------------------------------

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|n| n as usize)
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(a) => Some(a),
            _ => None,
        }
    }

    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(o) => Some(o),
            _ => None,
        }
    }

    /// Object field access; `Json::Null` if missing or not an object.
    pub fn get(&self, key: &str) -> &Json {
        static NULL: Json = Json::Null;
        match self {
            Json::Obj(o) => o.get(key).unwrap_or(&NULL),
            _ => &NULL,
        }
    }

    /// Path access: `j.at(&["artifacts", "decode", "file"])`.
    pub fn at(&self, path: &[&str]) -> &Json {
        let mut cur = self;
        for k in path {
            cur = cur.get(k);
        }
        cur
    }

    // -- construction helpers ----------------------------------------------

    pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
        Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }

    pub fn arr_f64(xs: &[f64]) -> Json {
        Json::Arr(xs.iter().map(|&x| Json::Num(x)).collect())
    }

    pub fn arr_usize(xs: &[usize]) -> Json {
        Json::Arr(xs.iter().map(|&x| Json::Num(x as f64)).collect())
    }

    // -- serialization -----------------------------------------------------

    pub fn to_string(&self) -> String {
        let mut out = String::new();
        self.write(&mut out);
        out
    }

    fn write(&self, out: &mut String) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(true) => out.push_str("true"),
            Json::Bool(false) => out.push_str("false"),
            Json::Num(n) => {
                if n.fract() == 0.0 && n.abs() < 1e15 {
                    out.push_str(&format!("{}", *n as i64));
                } else {
                    out.push_str(&format!("{}", n));
                }
            }
            Json::Str(s) => write_escaped(s, out),
            Json::Arr(a) => {
                out.push('[');
                for (i, v) in a.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    v.write(out);
                }
                out.push(']');
            }
            Json::Obj(o) => {
                out.push('{');
                for (i, (k, v)) in o.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    write_escaped(k, out);
                    out.push(':');
                    v.write(out);
                }
                out.push('}');
            }
        }
    }
}

fn write_escaped(s: &str, out: &mut String) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out.push('"');
}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> JsonError {
        JsonError {
            msg: msg.to_string(),
            offset: self.pos,
        }
    }

    fn skip_ws(&mut self) {
        while self.pos < self.bytes.len()
            && matches!(self.bytes[self.pos], b' ' | b'\t' | b'\n' | b'\r')
        {
            self.pos += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn expect(&mut self, b: u8) -> Result<(), JsonError> {
        if self.peek() == Some(b) {
            self.pos += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected '{}'", b as char)))
        }
    }

    fn value(&mut self) -> Result<Json, JsonError> {
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'n') => self.lit("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(self.err("unexpected character")),
        }
    }

    fn lit(&mut self, word: &str, v: Json) -> Result<Json, JsonError> {
        if self.bytes[self.pos..].starts_with(word.as_bytes()) {
            self.pos += word.len();
            Ok(v)
        } else {
            Err(self.err("bad literal"))
        }
    }

    fn number(&mut self) -> Result<Json, JsonError> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while self
            .peek()
            .map(|c| c.is_ascii_digit() || matches!(c, b'.' | b'e' | b'E' | b'+' | b'-'))
            .unwrap_or(false)
        {
            self.pos += 1;
        }
        let s = std::str::from_utf8(&self.bytes[start..self.pos]).unwrap();
        s.parse::<f64>()
            .map(Json::Num)
            .map_err(|_| self.err("bad number"))
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            match self.peek() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => {
                    self.pos += 1;
                    return Ok(out);
                }
                Some(b'\\') => {
                    self.pos += 1;
                    match self.peek() {
                        Some(b'"') => out.push('"'),
                        Some(b'\\') => out.push('\\'),
                        Some(b'/') => out.push('/'),
                        Some(b'n') => out.push('\n'),
                        Some(b't') => out.push('\t'),
                        Some(b'r') => out.push('\r'),
                        Some(b'b') => out.push('\u{8}'),
                        Some(b'f') => out.push('\u{c}'),
                        Some(b'u') => {
                            if self.pos + 4 >= self.bytes.len() {
                                return Err(self.err("bad \\u escape"));
                            }
                            let hex =
                                std::str::from_utf8(&self.bytes[self.pos + 1..self.pos + 5])
                                    .map_err(|_| self.err("bad \\u escape"))?;
                            let code = u32::from_str_radix(hex, 16)
                                .map_err(|_| self.err("bad \\u escape"))?;
                            out.push(char::from_u32(code).unwrap_or('\u{fffd}'));
                            self.pos += 4;
                        }
                        _ => return Err(self.err("bad escape")),
                    }
                    self.pos += 1;
                }
                Some(_) => {
                    // Copy a full UTF-8 scalar.
                    let s = std::str::from_utf8(&self.bytes[self.pos..])
                        .map_err(|_| self.err("invalid utf8"))?;
                    let c = s.chars().next().unwrap();
                    out.push(c);
                    self.pos += c.len_utf8();
                }
            }
        }
    }

    fn array(&mut self) -> Result<Json, JsonError> {
        self.expect(b'[')?;
        let mut items = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(items));
        }
        loop {
            self.skip_ws();
            items.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b']') => {
                    self.pos += 1;
                    return Ok(Json::Arr(items));
                }
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn object(&mut self) -> Result<Json, JsonError> {
        self.expect(b'{')?;
        let mut map = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(map));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            self.skip_ws();
            let val = self.value()?;
            map.insert(key, val);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b'}') => {
                    self.pos += 1;
                    return Ok(Json::Obj(map));
                }
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_scalars() {
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse("true").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse("-2.5e1").unwrap(), Json::Num(-25.0));
        assert_eq!(
            Json::parse("\"a\\nb\"").unwrap(),
            Json::Str("a\nb".to_string())
        );
    }

    #[test]
    fn parses_nested() {
        let j = Json::parse(r#"{"a": [1, {"b": "c"}], "d": null}"#).unwrap();
        assert_eq!(j.at(&["a"]).as_arr().unwrap().len(), 2);
        assert_eq!(j.get("a").as_arr().unwrap()[0].as_f64(), Some(1.0));
        assert_eq!(
            j.get("a").as_arr().unwrap()[1].get("b").as_str(),
            Some("c")
        );
        assert_eq!(*j.get("d"), Json::Null);
        assert_eq!(*j.get("missing"), Json::Null);
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("1 2").is_err());
        assert!(Json::parse("\"unterminated").is_err());
    }

    #[test]
    fn roundtrip() {
        let src = r#"{"arr":[1,2.5,"x"],"b":false,"n":null,"o":{"k":"v"}}"#;
        let j = Json::parse(src).unwrap();
        assert_eq!(Json::parse(&j.to_string()).unwrap(), j);
    }

    #[test]
    fn integers_serialize_without_fraction() {
        assert_eq!(Json::Num(42.0).to_string(), "42");
        assert_eq!(Json::Num(0.5).to_string(), "0.5");
    }

    #[test]
    fn unicode_escape() {
        assert_eq!(
            Json::parse("\"\\u0041\"").unwrap(),
            Json::Str("A".to_string())
        );
    }

    #[test]
    fn parses_real_manifest_shape() {
        let src = r#"{"format":1,"params":[{"name":"embed","shape":[288,128]}]}"#;
        let j = Json::parse(src).unwrap();
        assert_eq!(j.get("format").as_usize(), Some(1));
        let p = &j.get("params").as_arr().unwrap()[0];
        assert_eq!(p.get("name").as_str(), Some("embed"));
        assert_eq!(p.get("shape").as_arr().unwrap()[1].as_usize(), Some(128));
    }
}
