//! Deterministic PRNG (PCG64-DXSM) plus the distributions the simulator
//! needs. Substitutes the `rand` crate (offline vendor set); determinism is
//! load-bearing: every experiment run is keyed by an explicit seed so the
//! 7-repeat confidence intervals of the paper's §3.1 are reproducible.

/// Permuted congruential generator, 128-bit state ("DXSM" output function).
///
/// Reference: O'Neill, PCG: A Family of Simple Fast Space-Efficient
/// Statistically Good Algorithms for Random Number Generation.
#[derive(Clone, Debug)]
pub struct Pcg64 {
    state: u128,
    inc: u128,
}

const PCG_MULT: u128 = 0x2360_ed05_1fc6_5da4_4385_df64_9fcc_f645;

impl Pcg64 {
    /// Create a generator from a seed and a stream id. Distinct streams are
    /// statistically independent — the experiment harness gives each tenant
    /// and each repeat its own stream.
    pub fn new(seed: u64, stream: u64) -> Self {
        let mut rng = Pcg64 {
            state: 0,
            inc: ((stream as u128) << 1) | 1,
        };
        rng.next_u64();
        rng.state = rng.state.wrapping_add(seed as u128);
        rng.next_u64();
        rng
    }

    /// Convenience: stream 0.
    pub fn seeded(seed: u64) -> Self {
        Self::new(seed, 0)
    }

    /// Derive an independent child generator (splittable-PRNG style).
    pub fn split(&mut self, tag: u64) -> Pcg64 {
        let seed = self.next_u64() ^ tag.wrapping_mul(0x9e37_79b9_7f4a_7c15);
        Pcg64::new(seed, tag)
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        self.state = self
            .state
            .wrapping_mul(PCG_MULT)
            .wrapping_add(self.inc);
        // DXSM output permutation.
        let mut hi = (self.state >> 64) as u64;
        let lo = (self.state as u64) | 1;
        hi ^= hi >> 32;
        hi = hi.wrapping_mul(0xda94_2042_e4dd_58b5);
        hi ^= hi >> 48;
        hi.wrapping_mul(lo)
    }

    /// Uniform in `[0, 1)`.
    #[inline]
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform integer in `[0, n)`. `n` must be > 0.
    #[inline]
    pub fn below(&mut self, n: u64) -> u64 {
        debug_assert!(n > 0);
        // Lemire's multiply-shift rejection method.
        let mut x = self.next_u64();
        let mut m = (x as u128) * (n as u128);
        let mut l = m as u64;
        if l < n {
            let t = n.wrapping_neg() % n;
            while l < t {
                x = self.next_u64();
                m = (x as u128) * (n as u128);
                l = m as u64;
            }
        }
        (m >> 64) as u64
    }

    /// Uniform in `[lo, hi)`.
    #[inline]
    pub fn range_f64(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.f64()
    }

    /// Uniform integer in `[lo, hi]` inclusive.
    #[inline]
    pub fn range_u64(&mut self, lo: u64, hi: u64) -> u64 {
        lo + self.below(hi - lo + 1)
    }

    /// Bernoulli trial.
    #[inline]
    pub fn chance(&mut self, p: f64) -> bool {
        self.f64() < p
    }

    /// Exponential with rate `lambda` (mean `1/lambda`) — Poisson
    /// inter-arrival times for the tenant workload generators.
    #[inline]
    pub fn exp(&mut self, lambda: f64) -> f64 {
        debug_assert!(lambda > 0.0);
        let u = 1.0 - self.f64(); // (0, 1]
        -u.ln() / lambda
    }

    /// Standard normal via Box-Muller (cached second value dropped to stay
    /// allocation-free and branch-simple on the hot path).
    #[inline]
    pub fn normal(&mut self) -> f64 {
        let u1 = 1.0 - self.f64();
        let u2 = self.f64();
        (-2.0 * u1.ln()).sqrt() * (std::f64::consts::TAU * u2).cos()
    }

    /// Normal with mean/stddev.
    #[inline]
    pub fn normal_ms(&mut self, mean: f64, std: f64) -> f64 {
        mean + std * self.normal()
    }

    /// Log-normal parameterized by the *underlying* normal's mu/sigma.
    /// Heavy-tailed service demands (the paper's "realistic mixture" of
    /// input sizes, §3.1) are drawn from this.
    #[inline]
    pub fn lognormal(&mut self, mu: f64, sigma: f64) -> f64 {
        (mu + sigma * self.normal()).exp()
    }

    /// Pareto (type I) with scale `xm` and shape `alpha` — bursty background
    /// I/O sizes.
    #[inline]
    pub fn pareto(&mut self, xm: f64, alpha: f64) -> f64 {
        let u = 1.0 - self.f64();
        xm / u.powf(1.0 / alpha)
    }

    /// Fisher-Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i as u64 + 1) as usize;
            xs.swap(i, j);
        }
    }

    /// Pick a uniformly random element.
    pub fn choose<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        &xs[self.below(xs.len() as u64) as usize]
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_for_same_seed() {
        let mut a = Pcg64::new(42, 7);
        let mut b = Pcg64::new(42, 7);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_streams_differ() {
        let mut a = Pcg64::new(42, 1);
        let mut b = Pcg64::new(42, 2);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert_eq!(same, 0);
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut r = Pcg64::seeded(1);
        for _ in 0..10_000 {
            let x = r.f64();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn below_is_in_range_and_covers() {
        let mut r = Pcg64::seeded(2);
        let mut seen = [false; 10];
        for _ in 0..1_000 {
            let x = r.below(10) as usize;
            assert!(x < 10);
            seen[x] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn exp_mean_close() {
        let mut r = Pcg64::seeded(3);
        let n = 200_000;
        let mean: f64 = (0..n).map(|_| r.exp(4.0)).sum::<f64>() / n as f64;
        assert!((mean - 0.25).abs() < 0.01, "mean={mean}");
    }

    #[test]
    fn normal_moments() {
        let mut r = Pcg64::seeded(4);
        let n = 200_000;
        let xs: Vec<f64> = (0..n).map(|_| r.normal()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.02, "mean={mean}");
        assert!((var - 1.0).abs() < 0.05, "var={var}");
    }

    #[test]
    fn pareto_at_least_scale() {
        let mut r = Pcg64::seeded(5);
        for _ in 0..10_000 {
            assert!(r.pareto(2.0, 1.5) >= 2.0);
        }
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Pcg64::seeded(6);
        let mut v: Vec<u32> = (0..50).collect();
        r.shuffle(&mut v);
        let mut s = v.clone();
        s.sort_unstable();
        assert_eq!(s, (0..50).collect::<Vec<_>>());
    }

    #[test]
    fn split_children_independent() {
        let mut parent = Pcg64::seeded(7);
        let mut c1 = parent.split(1);
        let mut c2 = parent.split(2);
        let same = (0..64).filter(|_| c1.next_u64() == c2.next_u64()).count();
        assert_eq!(same, 0);
    }
}
