//! `predserve` CLI — leader entrypoint.
//!
//! Subcommands:
//!   serve        run the LLM serving engine on the AOT artifacts
//!   sim          run one simulated single-host scenario
//!   plan         print the auto-placement layout for a scenario (or a
//!                fleet split with --nodes N) without running it
//!   ablation     regenerate Table 3 (E2)
//!   llm          regenerate Table 2 (LLM TTFT case study)
//!   overheads    regenerate Table 4
//!   sensitivity  regenerate E3
//!   arbitration  single-primary vs multi-primary control plane ablation
//!   trace        trace-replay vs rate-matched Poisson ablation on the
//!                trace-driven catalog scenarios (per-tenant ΔSLO-miss,
//!                Δp99)
//!   trace-export run a scenario with the flight recorder attached and
//!                write the Chrome trace-event JSON (`chrome://tracing`/
//!                Perfetto-loadable; `.jsonl` out paths stream JSONL)
//!   report       `--timeline`: per-tenant p99-vs-SLO ASCII timeline
//!                with controller decisions overlaid
//!   figures      regenerate Figure 2/3/4 series (CSV under target/paper/)
//!   cluster      run the 2-node (16-GPU) cluster experiment (E9); with
//!                --fleet, the leader splits one auto-placed tenant list
//!                across the workers instead

use anyhow::Result;
use predserve::cli::Args;
use predserve::cluster::Leader;
use predserve::config;
use predserve::experiments::harness::Repeats;
use predserve::experiments::runs;
use predserve::platform::{RunResult, Scenario, SimWorld};
use predserve::serving::request::SamplingParams;
use predserve::serving::Engine;

const USAGE: &str = "usage: predserve <serve|sim|plan|scenarios|ablation|llm|overheads|sensitivity|arbitration|trace|trace-export|report|figures|cluster> [--scenario NAME] [--seed N] [--levers full|static|mig|placement|guards] [--horizon SECS] [--shards N] [--llm] [--config FILE] [--arrivals-trace FILE] [--faults FILE] [--record-trace FILE] [--out FILE] [--timeline] [--width N] [--fast] [--prompt TEXT] [--nodes N] [--node-timeout SECS] [--fleet] [--tenants N]";

/// Attach a fault plan from `--faults FILE` (JSON, see
/// `docs/ARCHITECTURE.md` "Fault injection & recovery") to a scenario.
/// Cluster-level specs (`worker_crash`) are ignored by single-host runs.
fn apply_faults(args: &Args, scenario: &mut Scenario) -> Result<()> {
    if let Some(path) = args.get("faults") {
        let text = std::fs::read_to_string(path).map_err(|e| anyhow::anyhow!("{path}: {e}"))?;
        let plan = predserve::faults::FaultPlan::parse_json(&text)
            .map_err(|e| anyhow::anyhow!("{path}: {e}"))?;
        println!(
            "fault plan {path}: {} spec(s), {} timed edge(s) in horizon",
            plan.specs.len(),
            plan.edges(scenario.horizon).len()
        );
        scenario.faults = plan;
    }
    Ok(())
}

/// Resolve a catalog scenario from the shared CLI knobs (--scenario,
/// --seed, --levers, --config, --horizon, --shards, --faults).
fn scenario_from_args(args: &Args, default_name: &str) -> Result<Scenario> {
    let levers = config::parse_levers(args.get_str("levers", "full"))?;
    let name = args.get_str("scenario", default_name);
    let mut scenario = Scenario::by_name(name, args.get_u64("seed", 11), levers).ok_or_else(|| {
        anyhow::anyhow!(
            "unknown scenario '{name}' (catalog: {})",
            Scenario::CATALOG.join(", ")
        )
    })?;
    if let Some(path) = args.get("config") {
        config::load_into(&mut scenario, path)?;
    }
    scenario.horizon = args.get_f64("horizon", scenario.horizon);
    scenario.shards = args.get_usize("shards", scenario.shards).max(1);
    apply_faults(args, &mut scenario)?;
    Ok(scenario)
}

/// Write recorded flight-recorder events to `path`: JSONL when the path
/// ends in `.jsonl`, Chrome trace-event JSON otherwise.
fn write_trace(path: &str, rec: &predserve::trace::Recorder, r: &RunResult) -> Result<()> {
    let events = rec.events();
    let text = if path.ends_with(".jsonl") {
        predserve::trace::jsonl(&events)
    } else {
        let names: Vec<String> = r.per_tenant.iter().map(|t| t.name.clone()).collect();
        predserve::trace::chrome_trace(&events, &names, r.horizon_s).to_string()
    };
    std::fs::write(path, text).map_err(|e| anyhow::anyhow!("{path}: {e}"))?;
    println!(
        "wrote {} trace events to {path} (ring dropped {})",
        events.len(),
        rec.metrics.dropped_events()
    );
    Ok(())
}

fn repeats(args: &Args) -> Repeats {
    let mut r = if args.flag("fast") {
        Repeats::fast()
    } else {
        Repeats::from_env()
    };
    if let Some(h) = args.get("horizon") {
        r.horizon_s = h.parse().unwrap_or(r.horizon_s);
    }
    r
}

fn main() -> Result<()> {
    let args = Args::from_env();
    let cmd = args.positional.first().map(String::as_str).unwrap_or("");
    match cmd {
        "serve" => {
            let mut engine = Engine::load_default()?;
            let prompt = args.get_str("prompt", "predictable llm serving on gpu clusters");
            let n = args.get_usize("requests", 8);
            println!("loaded AOT model: {:?}", engine.spec());
            for i in 0..n {
                engine.submit_text(
                    &format!("{prompt} #{i}"),
                    SamplingParams {
                        top_k: args.get_usize("top-k", 0),
                        seed: i as u64,
                        max_new_tokens: args.get_usize("max-new-tokens", 16),
                    },
                );
            }
            let done = engine.run_to_completion()?;
            for c in &done {
                println!(
                    "req {:3}  ttft={:6.2} ms  e2e={:6.2} ms  tokens={:2}  text={:?}",
                    c.id.0,
                    c.ttft_s * 1e3,
                    c.e2e_s * 1e3,
                    c.generated.len(),
                    engine.tokenizer.decode(&c.generated)
                );
            }
            let s = &engine.stats;
            println!(
                "completed={} ttft_p99={:.2} ms decode_steps={} prefill_waves={} model_time={:.2}s",
                s.completed,
                s.ttft_us.quantile(0.99) as f64 / 1000.0,
                s.decode_steps,
                s.prefill_waves,
                s.model_time_s
            );
        }
        "sim" => {
            let levers = config::parse_levers(args.get_str("levers", "full"))?;
            let name = args.get_str("scenario", "paper_single_host");
            let mut scenario = Scenario::by_name(name, args.get_u64("seed", 11), levers)
                .ok_or_else(|| {
                    anyhow::anyhow!(
                        "unknown scenario '{name}' (catalog: {})",
                        Scenario::CATALOG.join(", ")
                    )
                })?;
            if let Some(path) = args.get("config") {
                config::load_into(&mut scenario, path)?;
            }
            if let Some(path) = args.get("arrivals-trace") {
                // Replay an external trace (JSON or CSV line format) as
                // the primary tenant's arrival schedule.
                use predserve::tenants::{ArrivalProcess, TraceSpec};
                let text = std::fs::read_to_string(path)
                    .map_err(|e| anyhow::anyhow!("{path}: {e}"))?;
                let trace = if text.trim_start().starts_with('{') {
                    TraceSpec::parse_json(&text)
                } else {
                    TraceSpec::parse_csv(&text)
                }
                .map_err(|e| anyhow::anyhow!("{path}: {e}"))?;
                println!(
                    "replaying {path}: {} arrivals over {:.1}s (mean {:.2} rps)",
                    trace.len(),
                    trace.span(),
                    trace.mean_rps()
                );
                let primary = scenario.primary;
                scenario.tenants[primary]
                    .spec
                    .as_ls_mut()
                    .expect("primary tenant must be latency-sensitive")
                    .arrivals = Some(ArrivalProcess::Trace(trace));
            }
            if args.flag("llm") {
                // Serve the primary at request granularity: attach the
                // default chat workload unless the scenario already
                // carries one (llm_serving_mix / llm_burst_ttft do).
                use predserve::tenants::LlmWorkloadSpec;
                let primary = scenario.primary;
                let ls = scenario.tenants[primary]
                    .spec
                    .as_ls_mut()
                    .expect("primary tenant must be latency-sensitive");
                if ls.llm.is_none() {
                    ls.llm = Some(LlmWorkloadSpec::chat_7b());
                }
            }
            scenario.horizon = args.get_f64("horizon", scenario.horizon);
            scenario.shards = args.get_usize("shards", scenario.shards).max(1);
            apply_faults(&args, &mut scenario)?;
            let record_path = args.get("record-trace").map(str::to_string);
            let mut world = SimWorld::new(scenario);
            if record_path.is_some() {
                world.enable_recording(predserve::trace::recorder::DEFAULT_CAPACITY);
            }
            let (r, rec) = world.run_recorded();
            if let (Some(path), Some(rec)) = (record_path.as_deref(), rec.as_ref()) {
                write_trace(path, rec, &r)?;
            }
            if r.shards > 1 {
                let per: Vec<String> = r.per_shard_events.iter().map(u64::to_string).collect();
                println!(
                    "engine: {} shards, events/shard=[{}], cross-shard={}, sync windows={}",
                    r.shards,
                    per.join(", "),
                    r.cross_shard_events,
                    r.sync_windows
                );
            }
            println!(
                "{} [{}]: miss={:.1}% p95={:.2} p99={:.2} p999={:.2} ms rps={:.1} moves/hr={:.1}",
                r.label,
                r.scenario,
                r.miss_rate * 100.0,
                r.p95_ms,
                r.p99_ms,
                r.p999_ms,
                r.rps,
                r.moves_per_hour
            );
            println!("per-tenant lifetime stats:");
            for t in &r.per_tenant {
                let slo = if t.slo_ms < f64::MAX {
                    format!("{:.0} ms SLO, miss={:.1}%", t.slo_ms, t.miss_rate * 100.0)
                } else {
                    "background".to_string()
                };
                println!(
                    "  {:16} {:18} completed={:8} p99={:9.2} ms rate={:7.1}/s gb={:8.1}  ({slo})",
                    t.name,
                    t.kind.label(),
                    t.completed,
                    t.p99_ms,
                    t.rps,
                    t.gb_moved
                );
                if let (Some(ttft), Some(tpot)) = (t.ttft_p99, t.tpot_p99) {
                    println!(
                        "  {:16} llm serving: ttft_p99={:.1} ms tpot_p99={:.2} ms ttft_slo_miss={:.1}%",
                        "",
                        ttft,
                        tpot,
                        t.ttft_slo_miss_rate.unwrap_or(0.0) * 100.0
                    );
                }
            }
            for t in &r.per_tenant {
                if let Some(ts) = t.trace_exhausted_at {
                    println!(
                        "  note: {} replayed its whole trace ({} arrivals, exhausted at t={ts:.1}s)",
                        t.name, t.arrivals_emitted
                    );
                }
            }
            if !r.controller_stats.is_empty() {
                println!(
                    "control plane: {} controller(s), arbitration conflicts={} deferrals={}",
                    r.controller_stats.len(),
                    r.arb_conflicts,
                    r.arb_deferrals
                );
                for c in &r.controller_stats {
                    let kinds: Vec<String> = c
                        .actions
                        .iter()
                        .map(|(k, n)| format!("{k}={n}"))
                        .collect();
                    println!(
                        "  {:16} tau={:6.1} ms actions={:3} deferred={:3}  [{}]",
                        c.name,
                        c.tau_ms,
                        c.total_actions(),
                        c.deferrals,
                        kinds.join(", ")
                    );
                }
            }
            if !r.net_link_gb.is_empty() {
                // Cluster net fabric: top links by traffic (the busiest
                // trunks expose ECMP hotspots at a glance).
                let total: f64 = r.net_link_gb.iter().sum();
                let busy = r
                    .net_link_gb
                    .iter()
                    .zip(&r.net_link_util)
                    .enumerate()
                    .filter(|(_, (gb, _))| **gb > 0.0)
                    .count();
                println!(
                    "cluster net fabric: {} links ({} carried traffic), total={:.1} GB",
                    r.net_link_gb.len(),
                    busy,
                    total
                );
                let mut ranked: Vec<(usize, f64, f64)> = r
                    .net_link_gb
                    .iter()
                    .zip(&r.net_link_util)
                    .enumerate()
                    .map(|(l, (gb, u))| (l, *gb, *u))
                    .collect();
                ranked.sort_by(|a, b| b.1.total_cmp(&a.1));
                for (l, gb, u) in ranked.iter().take(4) {
                    if *gb > 0.0 {
                        println!("  netlink{l:<4} gb={gb:8.1} mean_util={:5.1}%", u * 100.0);
                    }
                }
            }
            if r.faults_injected > 0 || r.action_failures > 0 || r.action_retries > 0 {
                println!(
                    "faults: injected={} cleared={} action_failures={} retries={} requeued={} degraded_controllers={}",
                    r.faults_injected,
                    r.faults_cleared,
                    r.action_failures,
                    r.action_retries,
                    r.requests_requeued,
                    r.degraded_controllers
                );
            }
            for (t, kind, p99) in &r.timeline {
                println!("  t={t:7.1}s {kind:12} p99={p99:.1}ms");
            }
        }
        "plan" => {
            let nodes = args.get_usize("nodes", 1);
            let seed = args.get_u64("seed", 11);
            if args.flag("fleet") || nodes > 1 {
                let n_tenants = args.get_usize("tenants", nodes * 12);
                let (tenants, plan) = Leader::plan_fleet(nodes, seed, n_tenants);
                println!(
                    "fleet plan: {} tenants over {nodes} node(s) — {} placed, {} queued, {} rejected",
                    n_tenants,
                    plan.placed(),
                    plan.queued.len(),
                    plan.rejected.len()
                );
                for h in &plan.hosts {
                    println!("node{}:", h.node);
                    for a in &h.assigned {
                        println!(
                            "  {:16} gpu{} {} @{}",
                            tenants[a.tenant].name, a.gpu, a.profile, a.start
                        );
                    }
                }
                for &i in &plan.queued {
                    println!("queued:   {}", tenants[i].name);
                }
                for &i in &plan.rejected {
                    println!("rejected: {}", tenants[i].name);
                }
            } else {
                let levers = config::parse_levers(args.get_str("levers", "full"))?;
                let name = args.get_str("scenario", "auto_pack_24");
                let scenario = Scenario::by_name(name, seed, levers).ok_or_else(|| {
                    anyhow::anyhow!(
                        "unknown scenario '{name}' (catalog: {})",
                        Scenario::CATALOG.join(", ")
                    )
                })?;
                println!("{name} (seed {seed}) placement layout:");
                print!("{}", scenario.layout.render());
            }
        }
        "scenarios" => {
            println!("scenario catalog:");
            for name in Scenario::CATALOG {
                let s = Scenario::by_name(name, 11, config::parse_levers("full")?)
                    .expect("catalog name must resolve");
                let kinds: Vec<&str> = s.tenants.iter().map(|t| t.kind().label()).collect();
                println!("  {:20} {} tenants: {}", name, s.n_tenants(), kinds.join(", "));
            }
        }
        "ablation" => {
            let sums = runs::run_ablation(&repeats(&args));
            println!("{}", runs::render_table3(&sums));
        }
        "llm" => {
            let sums = runs::run_table2(&repeats(&args));
            println!("{}", runs::render_table2(&sums));
        }
        "overheads" => {
            let sums = runs::run_ablation(&repeats(&args));
            let full = sums
                .iter()
                .find(|s| s.label == "Full System")
                .expect("full system summary");
            println!("{}", runs::render_table4(full));
        }
        "sensitivity" => {
            println!("{}", runs::run_sensitivity(&repeats(&args)));
        }
        "arbitration" => {
            println!("{}", runs::run_arbitration(&repeats(&args)));
        }
        "trace" => {
            println!("{}", runs::run_trace(&repeats(&args)));
        }
        "trace-export" => {
            let scenario = scenario_from_args(&args, "hotspot_64")?;
            let out = args.get_str("out", "run.trace.json").to_string();
            let mut world = SimWorld::new(scenario);
            world.enable_recording(predserve::trace::recorder::DEFAULT_CAPACITY);
            let (r, rec) = world.run_recorded();
            let rec = rec.expect("recording was enabled");
            write_trace(&out, &rec, &r)?;
            for (k, v) in &r.metrics {
                println!("  {k} = {v}");
            }
        }
        "report" => {
            if !args.flag("timeline") {
                anyhow::bail!("report: pass --timeline (the only report implemented); {USAGE}");
            }
            let scenario = scenario_from_args(&args, "paper_single_host")?;
            print!(
                "{}",
                runs::run_timeline_report(scenario, args.get_usize("width", 100))
            );
        }
        "figures" => {
            let r = repeats(&args);
            let (fig2, _) = runs::run_fig2();
            println!("Figure 2 (PS contention model):\n{fig2}");
            println!("Figure 3:\n{}", runs::run_fig3(&r));
            println!("Figure 4:\n{}", runs::run_fig4(&r));
        }
        "cluster" => {
            use predserve::cluster::{ClusterOpts, NodeReport};
            let nodes = args.get_usize("nodes", 2);
            // Cluster-level faults (worker_crash) come off the same
            // --faults plan the sim uses; the sim-level specs in it are
            // each node's business, not the dispatch layer's.
            let mut opts = match args.get("faults") {
                Some(path) => {
                    let text = std::fs::read_to_string(path)
                        .map_err(|e| anyhow::anyhow!("{path}: {e}"))?;
                    let plan = predserve::faults::FaultPlan::parse_json(&text)
                        .map_err(|e| anyhow::anyhow!("{path}: {e}"))?;
                    ClusterOpts::from_fault_plan(&plan)
                }
                None => ClusterOpts::default(),
            };
            opts.node_timeout_s = args.get_f64("node-timeout", opts.node_timeout_s);
            let report = if args.flag("fleet") {
                let n_tenants = args.get_usize("tenants", nodes * 12);
                Leader::run_fleet_opts(
                    nodes,
                    args.get_u64("seed", 11),
                    args.get_str("levers", "full"),
                    args.get_f64("horizon", 600.0),
                    n_tenants,
                    &opts,
                )?
            } else {
                Leader::run_cluster_opts(
                    nodes,
                    args.get_u64("seed", 11),
                    args.get_str("levers", "full"),
                    args.get_f64("horizon", 600.0),
                    args.get_str("workload", "single"),
                    args.get_usize("shards", 1).max(1),
                    &opts,
                )?
            };
            println!(
                "cluster({} nodes, {} GPUs): mean miss={:.1}% mean p99={:.2} ms total rps={:.1} failed nodes={}",
                nodes,
                nodes * 8,
                report.mean_miss_rate * 100.0,
                report.mean_p99_ms,
                report.total_rps,
                report.failed_nodes
            );
            for n in &report.per_node {
                match n {
                    NodeReport::Ok {
                        node,
                        miss_rate,
                        p99_ms,
                        rps,
                        ..
                    } => println!(
                        "  {}: miss={:.1}% p99={:.2} ms rps={:.1}",
                        node,
                        miss_rate * 100.0,
                        p99_ms,
                        rps
                    ),
                    NodeReport::Failed { node, reason } => {
                        println!("  {node}: FAILED ({reason})")
                    }
                }
            }
            for t in &report.queued {
                println!("  queued (no safe slot fleet-wide): {t}");
            }
            for t in &report.rejected {
                println!("  rejected (no capacity fleet-wide): {t}");
            }
        }
        _ => {
            eprintln!("{USAGE}");
            std::process::exit(2);
        }
    }
    Ok(())
}
