//! # predserve — Predictable LLM Serving on GPU Clusters
//!
//! Reproduction of "Predictable LLM Serving on GPU Clusters" (CS.DC 2025):
//! a host-level multi-tenancy controller that combines **dynamic MIG
//! reconfiguration**, **PCIe-aware placement**, and **lightweight
//! guardrails** (MPS quotas, cgroup I/O throttles) to keep tail latency of
//! a latency-sensitive tenant inside its SLO on shared A100 hosts, plus a
//! vLLM-like serving engine for the paper's LLM/TTFT case study.
//!
//! The crate is the L3 of a three-layer stack (architecture notes and
//! the module map live in `docs/ARCHITECTURE.md`):
//!
//! * **L3 (this crate)** — the controller, the simulated testbed (A100/MIG
//!   geometry, PCIe processor-sharing fabric, NUMA topology, tenants,
//!   NVML-like telemetry), the vLLM-like serving engine, the 2-node
//!   cluster runtime, and the experiment/bench harnesses.
//! * **L2** — a JAX decoder model (`python/compile/model.py`) AOT-lowered
//!   to HLO text artifacts.
//! * **L1** — Pallas kernels (`python/compile/kernels/`) for paged
//!   attention and the fused SwiGLU MLP, lowered into the same HLO.
//!
//! Python never runs on the request path: [`runtime`] loads the AOT
//! artifacts through the PJRT C API (`xla` crate) once at startup.

pub mod util;
pub mod config;
pub mod cli;
pub mod topo;
pub mod gpu;
pub mod sim;
pub mod fabric;
pub mod faults;
pub mod tenants;
pub mod telemetry;
pub mod trace;
pub mod controller;
pub mod alloc;
pub mod platform;
pub mod serving;
pub mod runtime;
pub mod cluster;
pub mod model;
pub mod experiments;
pub mod bench;
