//! Weighted processor-sharing rate allocation with per-flow caps.
//!
//! Implements §2.5.1: `b_i = min(B·w_i / Σ_j w_j, g_i)` — with the standard
//! water-filling refinement so that bandwidth a capped flow cannot use is
//! redistributed to the uncapped flows (equal weights recover equal
//! sharing; caps recover explicit host throttles).

/// One flow's demand on a shared link.
#[derive(Clone, Copy, Debug)]
pub struct FlowDemand {
    /// PS weight w_i (> 0; equal weights = equal sharing).
    pub weight: f64,
    /// Optional host-level throttle g_i in the same units as capacity.
    pub cap: Option<f64>,
}

/// Compute the PS rate vector for `flows` on a link of `capacity`.
///
/// Water-filling: repeatedly give every unfixed flow its weighted share of
/// the remaining capacity; any flow whose share exceeds its cap is fixed
/// at the cap and removed from the pool. Terminates in ≤ n rounds.
///
/// Allocates its working buffers; the incremental fabric engine calls
/// [`ps_rates_into`] with reusable scratch instead (identical arithmetic,
/// zero allocations in steady state).
pub fn ps_rates(capacity: f64, flows: &[FlowDemand]) -> Vec<f64> {
    let mut rates = Vec::new();
    let mut fixed = Vec::new();
    ps_rates_into(capacity, flows, &mut fixed, &mut rates);
    rates
}

/// [`ps_rates`] into caller-provided buffers: `rates` receives the rate
/// vector (cleared and resized), `fixed` is solver scratch. The sequence
/// of floating-point operations is exactly `ps_rates`'s, so the results
/// are bit-identical — the reference-oracle differential tests rely on
/// that.
pub fn ps_rates_into(
    capacity: f64,
    flows: &[FlowDemand],
    fixed: &mut Vec<bool>,
    rates: &mut Vec<f64>,
) {
    let n = flows.len();
    rates.clear();
    rates.resize(n, 0.0);
    if n == 0 || capacity <= 0.0 {
        return;
    }
    fixed.clear();
    fixed.resize(n, false);
    let mut cap_left = capacity;
    loop {
        let w_total: f64 = flows
            .iter()
            .zip(fixed.iter())
            .filter(|(_, &f)| !f)
            .map(|(d, _)| d.weight)
            .sum();
        if w_total <= 0.0 || cap_left <= 0.0 {
            break;
        }
        let mut any_fixed = false;
        for i in 0..n {
            if fixed[i] {
                continue;
            }
            let share = cap_left * flows[i].weight / w_total;
            if let Some(cap) = flows[i].cap {
                if cap < share {
                    rates[i] = cap;
                    fixed[i] = true;
                    cap_left -= cap;
                    any_fixed = true;
                }
            }
        }
        if !any_fixed {
            // No more caps bind: distribute the remainder proportionally.
            for i in 0..n {
                if !fixed[i] {
                    rates[i] = cap_left * flows[i].weight / w_total;
                }
            }
            break;
        }
    }
}

/// Utilization ρ = Σ min(g_j, fair share) / B under the current flow set —
/// the stability quantity of Claim 1 (Σ g_j < B ⇒ ρ < 1).
pub fn utilization(capacity: f64, flows: &[FlowDemand]) -> f64 {
    if capacity <= 0.0 {
        return 0.0;
    }
    ps_rates(capacity, flows).iter().sum::<f64>() / capacity
}

#[cfg(test)]
mod tests {
    use super::*;

    fn d(weight: f64, cap: Option<f64>) -> FlowDemand {
        FlowDemand { weight, cap }
    }

    #[test]
    fn equal_weights_equal_share() {
        let r = ps_rates(24.0, &[d(1.0, None), d(1.0, None), d(1.0, None)]);
        for x in &r {
            assert!((x - 8.0).abs() < 1e-12);
        }
    }

    #[test]
    fn weighted_share() {
        let r = ps_rates(30.0, &[d(2.0, None), d(1.0, None)]);
        assert!((r[0] - 20.0).abs() < 1e-12);
        assert!((r[1] - 10.0).abs() < 1e-12);
    }

    #[test]
    fn cap_binds_and_redistributes() {
        // Paper's g_i: the capped flow gets its throttle; the rest goes to
        // the uncapped flow (NOT wasted).
        let r = ps_rates(20.0, &[d(1.0, Some(4.0)), d(1.0, None)]);
        assert!((r[0] - 4.0).abs() < 1e-12);
        assert!((r[1] - 16.0).abs() < 1e-12);
    }

    #[test]
    fn cascade_of_caps() {
        let r = ps_rates(30.0, &[d(1.0, Some(2.0)), d(1.0, Some(8.0)), d(1.0, None)]);
        assert!((r[0] - 2.0).abs() < 1e-12);
        assert!((r[1] - 8.0).abs() < 1e-12);
        assert!((r[2] - 20.0).abs() < 1e-12);
    }

    #[test]
    fn loose_caps_do_not_bind() {
        let r = ps_rates(10.0, &[d(1.0, Some(100.0)), d(1.0, Some(100.0))]);
        assert!((r[0] - 5.0).abs() < 1e-12);
        assert!((r[1] - 5.0).abs() < 1e-12);
    }

    #[test]
    fn conservation_never_exceeds_capacity() {
        use crate::util::rng::Pcg64;
        let mut rng = Pcg64::seeded(31);
        for _ in 0..500 {
            let n = 1 + rng.below(8) as usize;
            let flows: Vec<FlowDemand> = (0..n)
                .map(|_| FlowDemand {
                    weight: rng.range_f64(0.1, 4.0),
                    cap: if rng.chance(0.5) {
                        Some(rng.range_f64(0.5, 10.0))
                    } else {
                        None
                    },
                })
                .collect();
            let cap = rng.range_f64(1.0, 40.0);
            let rates = ps_rates(cap, &flows);
            let total: f64 = rates.iter().sum();
            assert!(total <= cap + 1e-9, "total {total} > capacity {cap}");
            for (r, f) in rates.iter().zip(&flows) {
                assert!(*r >= -1e-12);
                if let Some(g) = f.cap {
                    assert!(*r <= g + 1e-9, "rate {r} exceeds cap {g}");
                }
            }
        }
    }

    #[test]
    fn work_conserving_when_uncapped_flow_present() {
        use crate::util::rng::Pcg64;
        let mut rng = Pcg64::seeded(32);
        for _ in 0..200 {
            let n = 1 + rng.below(6) as usize;
            let mut flows: Vec<FlowDemand> = (0..n)
                .map(|_| FlowDemand {
                    weight: rng.range_f64(0.1, 4.0),
                    cap: Some(rng.range_f64(0.5, 5.0)),
                })
                .collect();
            flows.push(d(1.0, None)); // one uncapped flow
            let cap = rng.range_f64(5.0, 40.0);
            let total: f64 = ps_rates(cap, &flows).iter().sum();
            assert!((total - cap).abs() < 1e-9, "not work conserving: {total} vs {cap}");
        }
    }

    #[test]
    fn utilization_below_one_when_caps_sum_below_capacity() {
        // Claim 1(iii): Σ g_j < B ⇒ ρ < 1.
        let flows = [d(1.0, Some(3.0)), d(1.0, Some(4.0))];
        let rho = utilization(10.0, &flows);
        assert!(rho < 1.0);
        assert!((rho - 0.7).abs() < 1e-12);
    }

    #[test]
    fn empty_and_degenerate() {
        assert!(ps_rates(10.0, &[]).is_empty());
        assert_eq!(ps_rates(0.0, &[d(1.0, None)]), vec![0.0]);
    }

    #[test]
    fn into_variant_matches_allocating_variant_bitwise() {
        // The scratch-buffer path must be arithmetically indistinguishable
        // from the allocating one, including across buffer reuse.
        use crate::util::rng::Pcg64;
        let mut rng = Pcg64::seeded(33);
        let mut fixed = Vec::new();
        let mut out = Vec::new();
        for _ in 0..300 {
            let n = rng.below(9) as usize;
            let flows: Vec<FlowDemand> = (0..n)
                .map(|_| FlowDemand {
                    weight: rng.range_f64(0.05, 4.0),
                    cap: rng.chance(0.5).then(|| rng.range_f64(0.2, 12.0)),
                })
                .collect();
            let cap = rng.range_f64(0.0, 40.0);
            ps_rates_into(cap, &flows, &mut fixed, &mut out);
            let alloc = ps_rates(cap, &flows);
            assert_eq!(out.len(), alloc.len());
            for (a, b) in out.iter().zip(&alloc) {
                assert_eq!(a.to_bits(), b.to_bits());
            }
        }
    }
}
