//! Incremental cluster-network fabric, bit-compatible with
//! [`super::net_reference::NetReferenceFabric`].
//!
//! # Bit-compatibility contract
//!
//! Every query returns *exactly* the bits the reference returns on the
//! same mutation history. The trick is the same as the PCIe engine's
//! (PR 4), lifted from links to link *components*: a mutation dirties
//! the links on the affected flow's path; a solve expands each dirty
//! link to the transitively-connected component of links sharing flows
//! and re-runs the shared path solver
//! ([`super::netpath::net_rates_into`]) on just that component's flows,
//! in ascending id order. Rate allocation in one component never reads
//! state from another (fixing a flow only mutates its own path's
//! books), so the per-flow arithmetic is bit-identical to a full solve
//! — the solver module's `disjoint_components_solve_independently` test
//! and the cross-engine differential oracle pin this down.
//!
//! Completions reuse the PCIe engine's [`super::calendar`]: a multi-link
//! flow posts its candidate on every link it crosses; duplicates are
//! harmless because the earliest entry carries the same `(dt, flow)`
//! either way.

use std::collections::BTreeMap;

use super::calendar::CompletionCalendar;
use super::netpath::{net_rates_into, NetFlowDemand, NetSolveScratch};
use super::transfer::{FlowId, LinkCounters};
use crate::topo::{ClusterTopology, NetLinkId};

#[derive(Clone, Debug)]
struct NetFlow {
    path: Vec<usize>,
    weight: f64,
    cap: Option<f64>,
    remaining: f64,
    owner: usize,
    /// Cached allocation from the last component solve.
    rate: f64,
}

#[derive(Clone, Debug, Default)]
struct NetLinkState {
    /// Flows crossing this link, ascending id (starts append monotone ids).
    flow_ids: Vec<FlowId>,
    dirty: bool,
    /// Cached Σ rates over `flow_ids`, refreshed on component solves.
    link_rate: f64,
    counters: LinkCounters,
}

/// The production net-fabric engine.
#[derive(Clone, Debug)]
pub struct NetFabric {
    capacities: Vec<f64>,
    links: Vec<NetLinkState>,
    flows: BTreeMap<FlowId, NetFlow>,
    next_id: u64,
    owner_gb: Vec<f64>,
    calendar: CompletionCalendar,
    any_dirty: bool,
    rate_recomputes: u64,
    // Reusable scratch.
    scratch: NetSolveScratch,
    rates_scratch: Vec<f64>,
    comp_links: Vec<usize>,
    comp_flows: Vec<FlowId>,
    link_seen: Vec<bool>,
    adv_best: Vec<Option<(f64, FlowId)>>,
}

impl NetFabric {
    pub fn new(cluster: &ClusterTopology) -> NetFabric {
        let capacities: Vec<f64> = (0..cluster.num_net_links)
            .map(|l| cluster.capacity(NetLinkId(l)))
            .collect();
        let n = capacities.len();
        NetFabric {
            capacities,
            links: vec![NetLinkState::default(); n],
            flows: BTreeMap::new(),
            next_id: 1,
            owner_gb: Vec::new(),
            calendar: CompletionCalendar::new(n),
            any_dirty: false,
            rate_recomputes: 0,
            scratch: NetSolveScratch::default(),
            rates_scratch: Vec::new(),
            comp_links: Vec::new(),
            comp_flows: Vec::new(),
            link_seen: vec![false; n],
            adv_best: Vec::new(),
        }
    }

    pub fn start(
        &mut self,
        path: &[NetLinkId],
        gb: f64,
        weight: f64,
        cap: Option<f64>,
        owner: usize,
    ) -> FlowId {
        assert!(!path.is_empty(), "a net flow needs a path");
        assert!(gb > 0.0 && weight > 0.0);
        let id = FlowId(self.next_id);
        self.next_id += 1;
        let path_idx: Vec<usize> = path
            .iter()
            .map(|l| {
                assert!(l.0 < self.capacities.len(), "unknown net link {l:?}");
                l.0
            })
            .collect();
        for &l in &path_idx {
            // Ids are monotone, so appending keeps the vec sorted.
            self.links[l].flow_ids.push(id);
            self.links[l].dirty = true;
        }
        self.any_dirty = true;
        if owner >= self.owner_gb.len() {
            self.owner_gb.resize(owner + 1, 0.0);
        }
        self.flows.insert(
            id,
            NetFlow {
                path: path_idx,
                weight,
                cap,
                remaining: gb,
                owner,
                rate: 0.0,
            },
        );
        id
    }

    pub fn remove(&mut self, id: FlowId) {
        let Some(f) = self.flows.remove(&id) else {
            return;
        };
        for &l in &f.path {
            let link = &mut self.links[l];
            if let Ok(pos) = link.flow_ids.binary_search(&id) {
                link.flow_ids.remove(pos);
            }
            link.dirty = true;
        }
        self.any_dirty = true;
    }

    pub fn set_owner_cap(&mut self, owner: usize, cap: Option<f64>) {
        for f in self.flows.values_mut() {
            if f.owner == owner {
                f.cap = cap;
                for &l in &f.path {
                    self.links[l].dirty = true;
                }
                self.any_dirty = true;
            }
        }
    }

    pub fn set_link_capacity(&mut self, link: NetLinkId, gbps: f64) {
        assert!(link.0 < self.capacities.len(), "unknown net link {link:?}");
        self.capacities[link.0] = gbps;
        self.links[link.0].dirty = true;
        self.any_dirty = true;
    }

    pub fn flow_exists(&self, id: FlowId) -> bool {
        self.flows.contains_key(&id)
    }

    pub fn active_flows(&self) -> usize {
        self.flows.len()
    }

    /// Re-solve every dirty connected component, refresh cached rates,
    /// per-link rate sums, and calendar slots; clear the dirty flags.
    fn solve_dirty(&mut self) {
        if !self.any_dirty {
            return;
        }
        for start in 0..self.links.len() {
            if self.links[start].dirty {
                self.solve_component(start);
            }
        }
        self.any_dirty = false;
    }

    fn solve_component(&mut self, start: usize) {
        // Expand `start` to its connected component: links joined by
        // flows whose paths cross both.
        self.comp_links.clear();
        self.comp_flows.clear();
        self.comp_links.push(start);
        self.link_seen[start] = true;
        let mut li = 0;
        while li < self.comp_links.len() {
            let l = self.comp_links[li];
            li += 1;
            for &fid in &self.links[l].flow_ids {
                if self.comp_flows.contains(&fid) {
                    continue;
                }
                self.comp_flows.push(fid);
                for &pl in &self.flows[&fid].path {
                    if !self.link_seen[pl] {
                        self.link_seen[pl] = true;
                        self.comp_links.push(pl);
                    }
                }
            }
        }
        // Ascending flow order: required by the solver's determinism
        // contract (matches the reference's BTreeMap iteration).
        self.comp_flows.sort_unstable();

        if !self.comp_flows.is_empty() {
            let demands: Vec<NetFlowDemand> = self
                .comp_flows
                .iter()
                .map(|id| {
                    let f = &self.flows[id];
                    NetFlowDemand {
                        weight: f.weight,
                        cap: f.cap,
                        path: &f.path,
                    }
                })
                .collect();
            net_rates_into(
                &self.capacities,
                &demands,
                &mut self.scratch,
                &mut self.rates_scratch,
            );
            drop(demands);
            for (k, id) in self.comp_flows.iter().enumerate() {
                self.flows.get_mut(id).expect("component flow exists").rate =
                    self.rates_scratch[k];
            }
            self.rate_recomputes += 1;
        }

        for k in 0..self.comp_links.len() {
            let l = self.comp_links[k];
            // Σ rates in ascending flow order — the same order the
            // reference sums when it integrates utilization.
            let mut rate = 0.0;
            let mut best: Option<(f64, FlowId)> = None;
            for &fid in &self.links[l].flow_ids {
                let f = &self.flows[&fid];
                rate += f.rate;
                if f.rate > 0.0 {
                    let dt = f.remaining / f.rate;
                    if best.map(|(b, _)| dt < b).unwrap_or(true) {
                        best = Some((dt, fid));
                    }
                }
            }
            self.links[l].link_rate = rate;
            self.links[l].dirty = false;
            self.link_seen[l] = false;
            self.calendar.set(l, best);
        }
    }

    pub fn advance(&mut self, dt: f64) {
        assert!(dt >= 0.0);
        self.solve_dirty();
        self.adv_best.clear();
        self.adv_best.resize(self.links.len(), None);
        for (id, f) in self.flows.iter_mut() {
            let moved = (f.rate * dt).min(f.remaining);
            f.remaining -= moved;
            for &l in &f.path {
                self.links[l].counters.gb_total += moved;
            }
            self.owner_gb[f.owner] += moved;
            if f.rate > 0.0 {
                let cdt = f.remaining / f.rate;
                for &l in &f.path {
                    match self.adv_best[l] {
                        Some((b, _)) if b <= cdt => {}
                        _ => self.adv_best[l] = Some((cdt, *id)),
                    }
                }
            }
        }
        for l in 0..self.links.len() {
            let cap = self.capacities[l];
            let link = &mut self.links[l];
            if cap > 0.0 && !link.flow_ids.is_empty() {
                link.counters.util_integral += (link.link_rate / cap) * dt;
            }
        }
        for (l, best) in self.adv_best.iter().enumerate() {
            self.calendar.set(l, *best);
        }
    }

    pub fn next_completion(&mut self) -> Option<(f64, FlowId)> {
        self.solve_dirty();
        self.calendar.earliest()
    }

    pub fn remaining(&self, id: FlowId) -> Option<f64> {
        self.flows.get(&id).map(|f| f.remaining)
    }

    pub fn counters(&self, link: NetLinkId) -> LinkCounters {
        self.links[link.0].counters
    }

    pub fn owner_gb(&self, owner: usize) -> f64 {
        self.owner_gb.get(owner).copied().unwrap_or(0.0)
    }

    pub fn capacity(&self, link: NetLinkId) -> f64 {
        self.capacities[link.0]
    }

    pub fn num_links(&self) -> usize {
        self.capacities.len()
    }

    /// Component solves performed (telemetry; counted per non-empty
    /// component, so not comparable 1:1 with the reference's full-solve
    /// count).
    pub fn rate_recomputes(&self) -> u64 {
        self.rate_recomputes
    }
}

#[cfg(test)]
mod tests {
    use super::super::net_reference::NetReferenceFabric;
    use super::*;

    fn two_leaf() -> ClusterTopology {
        ClusterTopology::leaf_spine(2, 2, 2)
    }

    #[test]
    fn matches_reference_on_a_small_history() {
        let c = two_leaf();
        let mut inc = NetFabric::new(&c);
        let mut refr = NetReferenceFabric::new(&c);
        let a_i = inc.start(&c.route(0, 2), 10.0, 1.0, None, 0);
        let a_r = refr.start(&c.route(0, 2), 10.0, 1.0, None, 0);
        assert_eq!(a_i, a_r);
        let b_i = inc.start(&c.route(1, 3), 6.0, 2.0, Some(4.0), 1);
        let b_r = refr.start(&c.route(1, 3), 6.0, 2.0, Some(4.0), 1);
        assert_eq!(b_i, b_r);
        for step in 0..6 {
            let ni = inc.next_completion();
            let nr = refr.next_completion();
            match (ni, nr) {
                (None, None) => break,
                (Some((di, fi)), Some((dr, fr))) => {
                    assert_eq!(di.to_bits(), dr.to_bits(), "step {step}");
                    assert_eq!(fi, fr);
                    let dt = di * 0.5;
                    inc.advance(dt);
                    refr.advance(dt);
                    for id in [a_i, b_i] {
                        match (inc.remaining(id), refr.remaining(id)) {
                            (Some(x), Some(y)) => assert_eq!(x.to_bits(), y.to_bits()),
                            (None, None) => {}
                            other => panic!("presence mismatch: {other:?}"),
                        }
                    }
                }
                other => panic!("completion mismatch: {other:?}"),
            }
        }
        for l in 0..c.num_net_links {
            let ci = inc.counters(NetLinkId(l));
            let cr = refr.counters(NetLinkId(l));
            assert_eq!(ci.gb_total.to_bits(), cr.gb_total.to_bits());
            assert_eq!(ci.util_integral.to_bits(), cr.util_integral.to_bits());
        }
    }

    #[test]
    fn drained_flow_completes_exactly() {
        let c = two_leaf();
        let mut fab = NetFabric::new(&c);
        let id = fab.start(&c.route(0, 1), 2.5, 1.0, None, 0);
        let (dt, done) = fab.next_completion().unwrap();
        assert_eq!(done, id);
        assert_eq!(dt.to_bits(), 0.2f64.to_bits());
        fab.advance(dt);
        assert!(fab.remaining(id).unwrap() <= 1e-12);
        fab.remove(id);
        assert!(fab.next_completion().is_none());
        assert_eq!(fab.active_flows(), 0);
    }

    #[test]
    fn remove_dirties_and_respeeds_survivors() {
        let c = two_leaf();
        let mut fab = NetFabric::new(&c);
        // Two flows sharing host 0's NIC egress: 6.25 each.
        let a = fab.start(&c.route(0, 1), 10.0, 1.0, None, 0);
        let b = fab.start(&c.route(0, 2), 10.0, 1.0, None, 1);
        fab.advance(0.1);
        let after_shared = fab.remaining(b).unwrap();
        assert!((10.0 - after_shared - 0.625).abs() < 1e-12);
        fab.remove(a);
        fab.advance(0.1);
        // Survivor now runs at full NIC rate.
        assert!((after_shared - fab.remaining(b).unwrap() - 1.25).abs() < 1e-12);
    }

    #[test]
    fn owner_cap_applies_and_lifts() {
        let c = two_leaf();
        let mut fab = NetFabric::new(&c);
        let id = fab.start(&c.route(0, 1), 10.0, 1.0, None, 7);
        fab.set_owner_cap(7, Some(2.5));
        let (dt, _) = fab.next_completion().unwrap();
        assert_eq!(dt.to_bits(), 4.0f64.to_bits());
        fab.set_owner_cap(7, None);
        let (dt, _) = fab.next_completion().unwrap();
        assert_eq!(dt.to_bits(), 0.8f64.to_bits());
        let _ = id;
    }

    #[test]
    fn degraded_trunk_slows_cross_leaf_flows() {
        let c = two_leaf();
        let mut fab = NetFabric::new(&c);
        let id = fab.start(&c.route(0, 2), 10.0, 1.0, None, 0);
        fab.set_link_capacity(c.up(0, c.spine_for(0, 1)), 5.0);
        let (dt, _) = fab.next_completion().unwrap();
        assert_eq!(dt.to_bits(), 2.0f64.to_bits());
        let _ = id;
    }

    #[test]
    fn bytes_are_counted_on_every_path_link() {
        let c = two_leaf();
        let mut fab = NetFabric::new(&c);
        let _ = fab.start(&c.route(0, 2), 100.0, 1.0, None, 2);
        fab.advance(0.4);
        let moved = 12.5 * 0.4;
        for l in c.route(0, 2) {
            assert!((fab.counters(l).gb_total - moved).abs() < 1e-12);
        }
        assert_eq!(fab.counters(c.host_tx(1)).gb_total, 0.0);
        assert!((fab.owner_gb(2) - moved).abs() < 1e-12);
    }
}
