//! The pre-incremental fluid-flow fabric, kept verbatim as a test oracle.
//!
//! [`ReferenceFabric`] is the original `Fabric` implementation: every
//! query (`rates`, `next_completion`, `advance`, `utilization`) recomputes
//! the full per-link PS allocation from scratch, with per-call `Vec` /
//! `BTreeMap` allocations. It is deliberately **not** optimized — its job
//! is to define the semantics the incremental engine
//! ([`super::transfer::Fabric`]) must reproduce *bit-for-bit*:
//!
//! * the differential property tests drive both engines through random
//!   start/remove/cap/advance schedules and require identical rates,
//!   completions, counters, and remaining bytes (`to_bits` equality);
//! * the catalog fingerprint regression runs whole scenarios on each
//!   backend (`SimWorld::new_with_fabric`) and requires identical
//!   `RunResult::fingerprint()`s — which pins the incremental engine to
//!   the pre-refactor fingerprints byte for byte;
//! * the `scale_sweep` bench runs it side by side with the incremental
//!   engine to report the recompute and wall-time reduction.
//!
//! Do not "fix" or speed this module up: any observable change here
//! changes what the oracle certifies.

use super::ps::{ps_rates, FlowDemand};
use super::transfer::{FlowId, LinkCounters};
use crate::topo::{HostTopology, LinkId};
use std::cell::Cell;
use std::collections::BTreeMap;

#[derive(Clone, Debug)]
struct Flow {
    link: LinkId,
    weight: f64,
    cap: Option<f64>,
    /// Remaining payload in GB.
    remaining: f64,
    /// Opaque owner tag (tenant index) for telemetry attribution.
    owner: usize,
}

/// All shared links on a host plus the active flows crossing them —
/// recompute-from-scratch semantics (the original engine).
#[derive(Clone, Debug)]
pub struct ReferenceFabric {
    capacities: Vec<f64>,
    flows: BTreeMap<FlowId, Flow>,
    next_id: u64,
    counters: Vec<LinkCounters>,
    /// Per-owner cumulative GB (tenant attribution).
    owner_gb: BTreeMap<usize, f64>,
    /// Per-link PS solver invocations (`Cell` so the original `&self`
    /// query signatures stay untouched). One increment per non-empty
    /// link per `rates()` call — the quantity the incremental engine's
    /// `rate_recomputes()` counts too, so the two are comparable.
    solver_calls: Cell<u64>,
}

impl ReferenceFabric {
    pub fn new(topo: &HostTopology) -> ReferenceFabric {
        let mut capacities = vec![0.0; topo.num_links];
        for s in &topo.switches {
            capacities[s.link.0] = s.bandwidth_gbps;
        }
        for n in &topo.numa_nodes {
            capacities[n.nvme_link.0] = n.nvme_gbps;
        }
        ReferenceFabric {
            counters: vec![LinkCounters::default(); capacities.len()],
            capacities,
            flows: BTreeMap::new(),
            next_id: 1,
            owner_gb: BTreeMap::new(),
            solver_calls: Cell::new(0),
        }
    }

    /// Start a transfer of `gb` on `link`. Returns its id.
    pub fn start(
        &mut self,
        link: LinkId,
        gb: f64,
        weight: f64,
        cap: Option<f64>,
        owner: usize,
    ) -> FlowId {
        debug_assert!(gb > 0.0 && weight > 0.0);
        let id = FlowId(self.next_id);
        self.next_id += 1;
        self.flows.insert(
            id,
            Flow {
                link,
                weight,
                cap,
                remaining: gb,
                owner,
            },
        );
        id
    }

    /// Remove a flow (normally after it completes). Returns the owner.
    pub fn remove(&mut self, id: FlowId) -> Option<usize> {
        self.flows.remove(&id).map(|f| f.owner)
    }

    /// Apply/remove a throttle g_i on every flow owned by `owner`.
    pub fn set_owner_cap(&mut self, owner: usize, cap: Option<f64>) {
        for f in self.flows.values_mut() {
            if f.owner == owner {
                f.cap = cap;
            }
        }
    }

    /// Change a link's capacity in place (fault injection). The oracle
    /// recomputes every rate from scratch, so no invalidation needed.
    pub fn set_link_capacity(&mut self, link: LinkId, gbps: f64) {
        debug_assert!(gbps > 0.0);
        self.capacities[link.0] = gbps;
    }

    pub fn flow_exists(&self, id: FlowId) -> bool {
        self.flows.contains_key(&id)
    }

    pub fn active_flows(&self) -> usize {
        self.flows.len()
    }

    /// Current rate of each flow (GB/s), keyed by flow id — full
    /// from-scratch recompute with per-link allocations.
    pub fn rates(&self) -> BTreeMap<FlowId, f64> {
        let mut out = BTreeMap::new();
        for link in 0..self.capacities.len() {
            let ids: Vec<FlowId> = self
                .flows
                .iter()
                .filter(|(_, f)| f.link.0 == link)
                .map(|(&id, _)| id)
                .collect();
            if ids.is_empty() {
                continue;
            }
            self.solver_calls.set(self.solver_calls.get() + 1);
            let demands: Vec<FlowDemand> = ids
                .iter()
                .map(|id| {
                    let f = &self.flows[id];
                    FlowDemand {
                        weight: f.weight,
                        cap: f.cap,
                    }
                })
                .collect();
            let rates = ps_rates(self.capacities[link], &demands);
            for (id, r) in ids.into_iter().zip(rates) {
                out.insert(id, r);
            }
        }
        out
    }

    /// Instantaneous rate of one flow.
    pub fn rate_of(&self, id: FlowId) -> f64 {
        *self.rates().get(&id).unwrap_or(&0.0)
    }

    /// Earliest (dt, flow) completion under current rates, if any flow is
    /// active and draining.
    pub fn next_completion(&self) -> Option<(f64, FlowId)> {
        let rates = self.rates();
        let mut best: Option<(f64, FlowId)> = None;
        for (&id, f) in &self.flows {
            let r = rates[&id];
            if r <= 0.0 {
                continue;
            }
            let dt = f.remaining / r;
            if best.map(|(bt, _)| dt < bt).unwrap_or(true) {
                best = Some((dt, id));
            }
        }
        best
    }

    /// Advance all flows by `dt` seconds at current rates, accumulating
    /// telemetry counters.
    pub fn advance(&mut self, dt: f64) {
        if dt <= 0.0 {
            return;
        }
        let rates = self.rates();
        for (&id, f) in self.flows.iter_mut() {
            let r = rates[&id];
            let moved = (r * dt).min(f.remaining);
            f.remaining -= moved;
            self.counters[f.link.0].gb_total += moved;
            *self.owner_gb.entry(f.owner).or_insert(0.0) += moved;
        }
        for link in 0..self.capacities.len() {
            let cap = self.capacities[link];
            if cap <= 0.0 {
                continue;
            }
            let link_rate: f64 = self
                .flows
                .iter()
                .filter(|(_, f)| f.link.0 == link)
                .map(|(id, _)| rates[id])
                .sum();
            self.counters[link].util_integral += (link_rate / cap) * dt;
        }
    }

    /// Link utilization right now (0..1).
    pub fn utilization(&self, link: LinkId) -> f64 {
        let cap = self.capacities[link.0];
        if cap <= 0.0 {
            return 0.0;
        }
        let rates = self.rates();
        let total: f64 = self
            .flows
            .iter()
            .filter(|(_, f)| f.link == link)
            .map(|(id, _)| rates[id])
            .sum();
        total / cap
    }

    pub fn counters(&self, link: LinkId) -> LinkCounters {
        self.counters[link.0]
    }

    pub fn owner_gb(&self, owner: usize) -> f64 {
        *self.owner_gb.get(&owner).unwrap_or(&0.0)
    }

    pub fn capacity(&self, link: LinkId) -> f64 {
        self.capacities[link.0]
    }

    /// Remaining GB of a flow (tests / introspection).
    pub fn remaining(&self, id: FlowId) -> Option<f64> {
        self.flows.get(&id).map(|f| f.remaining)
    }

    /// Total per-link PS solves so far (comparable with
    /// [`super::transfer::Fabric::rate_recomputes`]).
    pub fn rate_recomputes(&self) -> u64 {
        self.solver_calls.get()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn oracle_counts_solver_calls() {
        let topo = HostTopology::p4d();
        let mut f = ReferenceFabric::new(&topo);
        f.start(LinkId(0), 10.0, 1.0, None, 0);
        f.start(LinkId(1), 10.0, 1.0, None, 1);
        assert_eq!(f.rate_recomputes(), 0);
        let _ = f.rates();
        // One solve per non-empty link.
        assert_eq!(f.rate_recomputes(), 2);
        let _ = f.next_completion();
        assert_eq!(f.rate_recomputes(), 4);
        f.advance(0.1);
        assert_eq!(f.rate_recomputes(), 6);
    }
}
