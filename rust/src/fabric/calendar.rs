//! Completion calendar: a versioned min-heap over per-link earliest
//! completions.
//!
//! Each link owns at most one *slot* — its earliest `(dt, flow)`
//! completion candidate under current rates, or `None` when nothing on
//! the link is draining. The calendar answers "which flow on the whole
//! host completes first?" in O(log links) without rescanning every flow,
//! the same way the sim world versions its pending `FlowsDone` events:
//! every slot update bumps the link's version and pushes a stamped heap
//! entry; stale entries (version mismatch) are discarded lazily at query
//! time.
//!
//! Ordering matches the original global scan exactly: candidates compare
//! by `dt` (`total_cmp`) and ties break toward the lowest [`FlowId`] —
//! the first-minimum-wins behavior of the reference engine's linear pass.
//!
//! Because `dt` values shrink as simulated time advances, fresh entries
//! sink *below* nothing — they surface at the top while stale ones get
//! buried. A compaction pass rebuilds the heap from the live slots
//! whenever the stale backlog outgrows a small multiple of the link
//! count, keeping memory O(links) over arbitrarily long runs.

use super::transfer::FlowId;
use std::cmp::Ordering;
use std::collections::BinaryHeap;

/// One heap entry: a link's candidate at the version it was computed.
#[derive(Clone, Copy, Debug)]
struct Entry {
    dt: f64,
    flow: FlowId,
    link: usize,
    version: u64,
}

impl PartialEq for Entry {
    fn eq(&self, other: &Self) -> bool {
        self.cmp(other) == Ordering::Equal
    }
}
impl Eq for Entry {}

impl Ord for Entry {
    fn cmp(&self, other: &Self) -> Ordering {
        // Reverse for a min-heap on (dt, flow id): BinaryHeap is a
        // max-heap, so compare other-to-self.
        other
            .dt
            .total_cmp(&self.dt)
            .then_with(|| other.flow.cmp(&self.flow))
            .then_with(|| other.link.cmp(&self.link))
            .then_with(|| other.version.cmp(&self.version))
    }
}
impl PartialOrd for Entry {
    fn partial_cmp(&self, other: &Self) -> Option<Ordering> {
        Some(self.cmp(other))
    }
}

/// Per-link earliest-completion tracker with O(log links) global minimum.
#[derive(Clone, Debug)]
pub struct CompletionCalendar {
    /// Current candidate per link (`None` = nothing draining).
    slots: Vec<Option<(f64, FlowId)>>,
    /// Version stamp per link; heap entries from older versions are stale.
    versions: Vec<u64>,
    heap: BinaryHeap<Entry>,
}

impl CompletionCalendar {
    pub fn new(num_links: usize) -> CompletionCalendar {
        CompletionCalendar {
            slots: vec![None; num_links],
            versions: vec![0; num_links],
            heap: BinaryHeap::with_capacity(num_links * 2 + 8),
        }
    }

    /// Replace `link`'s candidate. No-ops (no version bump, no heap push)
    /// when the candidate is bit-identical to the current slot.
    pub fn set(&mut self, link: usize, candidate: Option<(f64, FlowId)>) {
        let same = match (self.slots[link], candidate) {
            (None, None) => true,
            (Some((a, fa)), Some((b, fb))) => a.to_bits() == b.to_bits() && fa == fb,
            _ => false,
        };
        if same {
            return;
        }
        self.slots[link] = candidate;
        self.versions[link] += 1;
        if let Some((dt, flow)) = candidate {
            if self.heap.len() >= self.compact_threshold() {
                // Rebuilding from the slots already re-inserts this
                // link's just-written candidate — no separate push.
                self.compact();
            } else {
                self.heap.push(Entry {
                    dt,
                    flow,
                    link,
                    version: self.versions[link],
                });
            }
        }
    }

    /// Current candidate of one link (tests / introspection).
    pub fn slot(&self, link: usize) -> Option<(f64, FlowId)> {
        self.slots[link]
    }

    /// Host-wide earliest completion: minimum over all link slots by
    /// `(dt, flow id)`. Pops stale heap entries lazily; the returned
    /// entry stays in the heap (peek semantics).
    pub fn earliest(&mut self) -> Option<(f64, FlowId)> {
        while let Some(top) = self.heap.peek() {
            if self.versions[top.link] == top.version {
                return Some((top.dt, top.flow));
            }
            self.heap.pop();
        }
        None
    }

    fn compact_threshold(&self) -> usize {
        self.slots.len() * 4 + 16
    }

    /// Rebuild the heap from the live slots (drops every stale entry).
    fn compact(&mut self) {
        self.heap.clear();
        for (link, slot) in self.slots.iter().enumerate() {
            if let Some((dt, flow)) = *slot {
                self.heap.push(Entry {
                    dt,
                    flow,
                    link,
                    version: self.versions[link],
                });
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn earliest_is_min_over_slots() {
        let mut c = CompletionCalendar::new(3);
        assert_eq!(c.earliest(), None);
        c.set(0, Some((2.0, FlowId(7))));
        c.set(1, Some((1.0, FlowId(9))));
        c.set(2, Some((3.0, FlowId(2))));
        assert_eq!(c.earliest(), Some((1.0, FlowId(9))));
    }

    #[test]
    fn ties_break_to_lowest_flow_id() {
        let mut c = CompletionCalendar::new(2);
        c.set(0, Some((1.5, FlowId(12))));
        c.set(1, Some((1.5, FlowId(4))));
        assert_eq!(c.earliest(), Some((1.5, FlowId(4))));
    }

    #[test]
    fn updates_supersede_stale_entries() {
        let mut c = CompletionCalendar::new(2);
        c.set(0, Some((1.0, FlowId(1))));
        c.set(1, Some((5.0, FlowId(2))));
        assert_eq!(c.earliest(), Some((1.0, FlowId(1))));
        // Link 0's flow completes; its new candidate is later than link 1.
        c.set(0, Some((9.0, FlowId(3))));
        assert_eq!(c.earliest(), Some((5.0, FlowId(2))));
        // Link 1 empties entirely.
        c.set(1, None);
        assert_eq!(c.earliest(), Some((9.0, FlowId(3))));
        c.set(0, None);
        assert_eq!(c.earliest(), None);
    }

    #[test]
    fn heap_stays_bounded_under_churn() {
        let mut c = CompletionCalendar::new(4);
        for i in 0..10_000u64 {
            let link = (i % 4) as usize;
            // Shrinking dts emulate time advancing: new entries surface on
            // top, stale ones get buried until compaction reclaims them.
            let dt = 10_000.0 - i as f64;
            c.set(link, Some((dt, FlowId(i + 1))));
            let (got_dt, _) = c.earliest().unwrap();
            assert_eq!(got_dt, dt);
        }
        assert!(
            c.heap.len() <= c.compact_threshold(),
            "heap grew unboundedly: {}",
            c.heap.len()
        );
    }

    #[test]
    fn bitwise_identical_reset_is_a_noop() {
        let mut c = CompletionCalendar::new(1);
        c.set(0, Some((1.0, FlowId(1))));
        let v = c.versions[0];
        c.set(0, Some((1.0, FlowId(1))));
        assert_eq!(c.versions[0], v, "identical candidate must not churn");
    }
}
