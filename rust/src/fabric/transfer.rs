//! Fluid-flow transfer engine over the PS links.
//!
//! The simulator advances in events; between events every active flow
//! progresses at its current PS rate. Whenever the flow set (or a throttle)
//! changes, rates are recomputed and the earliest completion time shifts —
//! the sim world re-queries [`Fabric::next_completion`] after every
//! mutation and versions its pending completion events.

use super::ps::{ps_rates, FlowDemand};
use crate::topo::{HostTopology, LinkId};
use std::collections::BTreeMap;

/// Identifies an active transfer.
#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub struct FlowId(pub u64);

#[derive(Clone, Debug)]
struct Flow {
    link: LinkId,
    weight: f64,
    cap: Option<f64>,
    /// Remaining payload in GB.
    remaining: f64,
    /// Opaque owner tag (tenant index) for telemetry attribution.
    owner: usize,
}

/// Cumulative per-link counters (the "PCIe counters (bytes/s)" the
/// controller samples, §2.1).
#[derive(Clone, Copy, Debug, Default)]
pub struct LinkCounters {
    /// Total GB moved through the link.
    pub gb_total: f64,
    /// Time-integral of utilization (for mean-utilization queries).
    pub util_integral: f64,
}

/// All shared links on a host plus the active flows crossing them.
#[derive(Clone, Debug)]
pub struct Fabric {
    capacities: Vec<f64>,
    flows: BTreeMap<FlowId, Flow>,
    next_id: u64,
    counters: Vec<LinkCounters>,
    /// Per-owner cumulative GB (tenant attribution).
    owner_gb: BTreeMap<usize, f64>,
}

impl Fabric {
    pub fn new(topo: &HostTopology) -> Fabric {
        let mut capacities = vec![0.0; topo.num_links];
        for s in &topo.switches {
            capacities[s.link.0] = s.bandwidth_gbps;
        }
        for n in &topo.numa_nodes {
            capacities[n.nvme_link.0] = n.nvme_gbps;
        }
        Fabric {
            counters: vec![LinkCounters::default(); capacities.len()],
            capacities,
            flows: BTreeMap::new(),
            next_id: 1,
            owner_gb: BTreeMap::new(),
        }
    }

    /// Start a transfer of `gb` on `link`. Returns its id.
    pub fn start(
        &mut self,
        link: LinkId,
        gb: f64,
        weight: f64,
        cap: Option<f64>,
        owner: usize,
    ) -> FlowId {
        debug_assert!(gb > 0.0 && weight > 0.0);
        let id = FlowId(self.next_id);
        self.next_id += 1;
        self.flows.insert(
            id,
            Flow {
                link,
                weight,
                cap,
                remaining: gb,
                owner,
            },
        );
        id
    }

    /// Remove a flow (normally after it completes). Returns the owner.
    pub fn remove(&mut self, id: FlowId) -> Option<usize> {
        self.flows.remove(&id).map(|f| f.owner)
    }

    /// Apply/remove a throttle g_i on every flow owned by `owner`
    /// (the cgroup `io.max` guardrail acts per-tenant, not per-flow).
    pub fn set_owner_cap(&mut self, owner: usize, cap: Option<f64>) {
        for f in self.flows.values_mut() {
            if f.owner == owner {
                f.cap = cap;
            }
        }
    }

    pub fn flow_exists(&self, id: FlowId) -> bool {
        self.flows.contains_key(&id)
    }

    pub fn active_flows(&self) -> usize {
        self.flows.len()
    }

    /// Current rate of each flow (GB/s), keyed by flow id.
    pub fn rates(&self) -> BTreeMap<FlowId, f64> {
        let mut out = BTreeMap::new();
        for link in 0..self.capacities.len() {
            let ids: Vec<FlowId> = self
                .flows
                .iter()
                .filter(|(_, f)| f.link.0 == link)
                .map(|(&id, _)| id)
                .collect();
            if ids.is_empty() {
                continue;
            }
            let demands: Vec<FlowDemand> = ids
                .iter()
                .map(|id| {
                    let f = &self.flows[id];
                    FlowDemand {
                        weight: f.weight,
                        cap: f.cap,
                    }
                })
                .collect();
            let rates = ps_rates(self.capacities[link], &demands);
            for (id, r) in ids.into_iter().zip(rates) {
                out.insert(id, r);
            }
        }
        out
    }

    /// Instantaneous rate of one flow.
    pub fn rate_of(&self, id: FlowId) -> f64 {
        *self.rates().get(&id).unwrap_or(&0.0)
    }

    /// Earliest (dt, flow) completion under current rates, if any flow is
    /// active and draining.
    pub fn next_completion(&self) -> Option<(f64, FlowId)> {
        let rates = self.rates();
        let mut best: Option<(f64, FlowId)> = None;
        for (&id, f) in &self.flows {
            let r = rates[&id];
            if r <= 0.0 {
                continue;
            }
            let dt = f.remaining / r;
            if best.map(|(bt, _)| dt < bt).unwrap_or(true) {
                best = Some((dt, id));
            }
        }
        best
    }

    /// Advance all flows by `dt` seconds at current rates, accumulating
    /// telemetry counters. Flows that hit zero are left at zero remaining
    /// (the caller removes them when their completion event fires).
    pub fn advance(&mut self, dt: f64) {
        if dt <= 0.0 {
            return;
        }
        let rates = self.rates();
        for (&id, f) in self.flows.iter_mut() {
            let r = rates[&id];
            let moved = (r * dt).min(f.remaining);
            f.remaining -= moved;
            self.counters[f.link.0].gb_total += moved;
            *self.owner_gb.entry(f.owner).or_insert(0.0) += moved;
        }
        for link in 0..self.capacities.len() {
            let cap = self.capacities[link];
            if cap <= 0.0 {
                continue;
            }
            let link_rate: f64 = self
                .flows
                .iter()
                .filter(|(_, f)| f.link.0 == link)
                .map(|(id, _)| rates[id])
                .sum();
            self.counters[link].util_integral += (link_rate / cap) * dt;
        }
    }

    /// Link utilization right now (0..1).
    pub fn utilization(&self, link: LinkId) -> f64 {
        let cap = self.capacities[link.0];
        if cap <= 0.0 {
            return 0.0;
        }
        let rates = self.rates();
        let total: f64 = self
            .flows
            .iter()
            .filter(|(_, f)| f.link == link)
            .map(|(id, _)| rates[id])
            .sum();
        total / cap
    }

    pub fn counters(&self, link: LinkId) -> LinkCounters {
        self.counters[link.0]
    }

    pub fn owner_gb(&self, owner: usize) -> f64 {
        *self.owner_gb.get(&owner).unwrap_or(&0.0)
    }

    pub fn capacity(&self, link: LinkId) -> f64 {
        self.capacities[link.0]
    }

    /// Remaining GB of a flow (tests / introspection).
    pub fn remaining(&self, id: FlowId) -> Option<f64> {
        self.flows.get(&id).map(|f| f.remaining)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::topo::HostTopology;

    fn fabric() -> Fabric {
        Fabric::new(&HostTopology::p4d())
    }

    #[test]
    fn single_flow_full_rate() {
        let mut f = fabric();
        let id = f.start(LinkId(0), 50.0, 1.0, None, 0);
        assert!((f.rate_of(id) - 25.0).abs() < 1e-12);
        let (dt, done) = f.next_completion().unwrap();
        assert_eq!(done, id);
        assert!((dt - 2.0).abs() < 1e-12);
    }

    #[test]
    fn two_flows_share_then_speed_up() {
        let mut f = fabric();
        let a = f.start(LinkId(0), 25.0, 1.0, None, 0);
        let b = f.start(LinkId(0), 12.5, 1.0, None, 1);
        // Equal share: 12.5 each; b finishes first at t=1.
        let (dt, first) = f.next_completion().unwrap();
        assert_eq!(first, b);
        assert!((dt - 1.0).abs() < 1e-12);
        f.advance(dt);
        f.remove(b);
        // a has 12.5 GB left, now at full 25 GB/s => 0.5 s more.
        let (dt2, second) = f.next_completion().unwrap();
        assert_eq!(second, a);
        assert!((dt2 - 0.5).abs() < 1e-9);
    }

    #[test]
    fn throttle_slows_owner() {
        let mut f = fabric();
        let a = f.start(LinkId(0), 100.0, 1.0, None, 2);
        f.set_owner_cap(2, Some(5.0));
        assert!((f.rate_of(a) - 5.0).abs() < 1e-12);
        f.set_owner_cap(2, None);
        assert!((f.rate_of(a) - 25.0).abs() < 1e-12);
    }

    #[test]
    fn links_are_independent() {
        let mut f = fabric();
        let a = f.start(LinkId(0), 10.0, 1.0, None, 0);
        let b = f.start(LinkId(1), 10.0, 1.0, None, 1);
        assert!((f.rate_of(a) - 25.0).abs() < 1e-12);
        assert!((f.rate_of(b) - 25.0).abs() < 1e-12);
    }

    #[test]
    fn counters_accumulate() {
        let mut f = fabric();
        f.start(LinkId(0), 10.0, 1.0, None, 7);
        f.advance(0.2); // 5 GB moved
        let c = f.counters(LinkId(0));
        assert!((c.gb_total - 5.0).abs() < 1e-9);
        assert!((f.owner_gb(7) - 5.0).abs() < 1e-9);
        assert!((f.utilization(LinkId(0)) - 1.0).abs() < 1e-12);
    }

    #[test]
    fn nvme_link_capacity() {
        let mut f = fabric();
        let a = f.start(LinkId(4), 16.0, 1.0, None, 0);
        assert!((f.rate_of(a) - 8.0).abs() < 1e-12);
    }

    #[test]
    fn advance_does_not_overshoot() {
        let mut f = fabric();
        let a = f.start(LinkId(0), 10.0, 1.0, None, 0);
        f.advance(100.0);
        assert!((f.remaining(a).unwrap() - 0.0).abs() < 1e-12);
        let c = f.counters(LinkId(0));
        assert!((c.gb_total - 10.0).abs() < 1e-9);
    }
}
