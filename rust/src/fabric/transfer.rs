//! Incremental fluid-flow transfer engine over the PS links.
//!
//! The simulator advances in events; between events every active flow
//! progresses at its current PS rate. Whenever the flow set (or a
//! throttle) changes, rates shift and the earliest completion time moves
//! — the sim world re-queries [`Fabric::next_completion`] after every
//! mutation and versions its pending completion events.
//!
//! Unlike the from-scratch reference engine
//! ([`super::reference::ReferenceFabric`]), this implementation keeps
//! **per-link state** so a mutation touches only the link it lands on:
//!
//! * each link owns its flow-id set plus a cached PS rate vector and a
//!   dirty flag — `start`/`remove`/`set_owner_cap` just mark the affected
//!   link(s) dirty, and the water-filling solver
//!   ([`super::ps::ps_rates_into`], into reusable scratch buffers — no
//!   allocations in steady state) re-runs only for dirty links at the
//!   next query;
//! * [`Fabric::advance`] applies the cached rates — it never re-solves a
//!   clean link — and accumulates the per-link/per-owner service
//!   integrals (counters, `owner_gb`) in the same pass;
//! * a [`super::calendar::CompletionCalendar`] (versioned min-heap over
//!   per-link earliest completions) answers
//!   [`Fabric::next_completion`] in O(log links): `advance` refreshes
//!   every link's candidate while it is already touching the flows, and
//!   solving a dirty link refreshes just that link's slot.
//!
//! **Bit-compatibility contract.** All observable outputs — rates,
//! completion picks (including the lowest-`FlowId` tie-break), counters,
//! `owner_gb`, remaining bytes — are bit-identical to the reference
//! engine's. That requires preserving the reference's floating-point
//! operation *order*: per-link demand vectors iterate flows in ascending
//! `FlowId` order, service accounting applies at the same `advance`
//! segment boundaries (cached rates are constant between solves, so each
//! segment multiplies the same rate bits), and `owner_gb` accumulates in
//! global `FlowId` order across links. The differential property tests
//! and the catalog fingerprint regression enforce the contract against
//! the oracle; do not reorder these loops without re-running them.

use super::calendar::CompletionCalendar;
use super::ps::{ps_rates_into, FlowDemand};
use crate::topo::{HostTopology, LinkId};
use std::collections::BTreeMap;

/// Identifies an active transfer.
#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub struct FlowId(pub u64);

#[derive(Clone, Debug)]
struct Flow {
    link: LinkId,
    weight: f64,
    cap: Option<f64>,
    /// Remaining payload in GB.
    remaining: f64,
    /// Opaque owner tag (tenant index) for telemetry attribution.
    owner: usize,
    /// Cached PS rate (GB/s); valid while the flow's link is clean.
    rate: f64,
}

/// Cumulative per-link counters (the "PCIe counters (bytes/s)" the
/// controller samples, §2.1).
#[derive(Clone, Copy, Debug, Default)]
pub struct LinkCounters {
    /// Total GB moved through the link.
    pub gb_total: f64,
    /// Time-integral of utilization (for mean-utilization queries).
    pub util_integral: f64,
}

/// One shared-bandwidth domain's incremental state.
#[derive(Clone, Debug)]
struct LinkState {
    capacity: f64,
    /// Flows on this link, ascending by id (ids are handed out
    /// monotonically, so `start` appends and order is maintained for
    /// free — the solver must see demands in id order for bit-identical
    /// water-filling).
    flow_ids: Vec<FlowId>,
    /// Set by mutations; cleared by the next solve.
    dirty: bool,
    /// Cached Σ rates over `flow_ids` (in id order), for utilization and
    /// the util-integral accumulation.
    link_rate: f64,
    counters: LinkCounters,
    /// Solver scratch, reused across solves (allocation-free steady
    /// state).
    demands: Vec<FlowDemand>,
    rates: Vec<f64>,
}

/// All shared links on a host plus the active flows crossing them.
#[derive(Clone, Debug)]
pub struct Fabric {
    links: Vec<LinkState>,
    /// Global flow table in id order — the iteration order service
    /// accounting and the rate map preserve.
    flows: BTreeMap<FlowId, Flow>,
    next_id: u64,
    /// Per-owner cumulative GB, indexed by owner tag (grown on demand).
    owner_gb: Vec<f64>,
    calendar: CompletionCalendar,
    /// Water-filling scratch shared across links.
    fixed_scratch: Vec<bool>,
    /// Per-link earliest-completion candidates gathered during `advance`.
    adv_best: Vec<Option<(f64, FlowId)>>,
    rate_recomputes: u64,
}

impl Fabric {
    pub fn new(topo: &HostTopology) -> Fabric {
        let mut capacities = vec![0.0; topo.num_links];
        for s in &topo.switches {
            capacities[s.link.0] = s.bandwidth_gbps;
        }
        for n in &topo.numa_nodes {
            capacities[n.nvme_link.0] = n.nvme_gbps;
        }
        let links = capacities
            .iter()
            .map(|&capacity| LinkState {
                capacity,
                flow_ids: Vec::new(),
                dirty: false,
                link_rate: 0.0,
                counters: LinkCounters::default(),
                demands: Vec::new(),
                rates: Vec::new(),
            })
            .collect();
        Fabric {
            links,
            flows: BTreeMap::new(),
            next_id: 1,
            owner_gb: Vec::new(),
            calendar: CompletionCalendar::new(capacities.len()),
            fixed_scratch: Vec::new(),
            adv_best: vec![None; capacities.len()],
            rate_recomputes: 0,
        }
    }

    /// Start a transfer of `gb` on `link`. Returns its id. O(1): only
    /// the target link is invalidated.
    pub fn start(
        &mut self,
        link: LinkId,
        gb: f64,
        weight: f64,
        cap: Option<f64>,
        owner: usize,
    ) -> FlowId {
        debug_assert!(gb > 0.0 && weight > 0.0);
        let id = FlowId(self.next_id);
        self.next_id += 1;
        if owner >= self.owner_gb.len() {
            self.owner_gb.resize(owner + 1, 0.0);
        }
        self.flows.insert(
            id,
            Flow {
                link,
                weight,
                cap,
                remaining: gb,
                owner,
                rate: 0.0,
            },
        );
        let l = &mut self.links[link.0];
        l.flow_ids.push(id); // ids are monotone: stays sorted
        l.dirty = true;
        id
    }

    /// Remove a flow (normally after it completes). Returns the owner.
    /// O(flows on its link): only that link is invalidated.
    pub fn remove(&mut self, id: FlowId) -> Option<usize> {
        let f = self.flows.remove(&id)?;
        let l = &mut self.links[f.link.0];
        if let Ok(pos) = l.flow_ids.binary_search(&id) {
            l.flow_ids.remove(pos);
        }
        l.dirty = true;
        Some(f.owner)
    }

    /// Apply/remove a throttle g_i on every flow owned by `owner`
    /// (the cgroup `io.max` guardrail acts per-tenant, not per-flow).
    /// Invalidates only the links carrying that owner's flows.
    pub fn set_owner_cap(&mut self, owner: usize, cap: Option<f64>) {
        let Fabric { links, flows, .. } = self;
        for f in flows.values_mut() {
            if f.owner == owner {
                f.cap = cap;
                links[f.link.0].dirty = true;
            }
        }
    }

    /// Change a link's capacity in place (fault injection: link
    /// degradation / flaps). Invalidates only that link; in-flight
    /// flows keep their remaining bytes and re-share the new capacity
    /// at the next solve.
    pub fn set_link_capacity(&mut self, link: LinkId, gbps: f64) {
        debug_assert!(gbps > 0.0);
        let l = &mut self.links[link.0];
        l.capacity = gbps;
        l.dirty = true;
    }

    pub fn flow_exists(&self, id: FlowId) -> bool {
        self.flows.contains_key(&id)
    }

    pub fn active_flows(&self) -> usize {
        self.flows.len()
    }

    /// Re-run the water-filling solver for one link's flow set, caching
    /// the per-flow rates and the link-rate sum. The demand vector is
    /// built in ascending id order — the same order the reference engine
    /// feeds the solver — into reusable scratch.
    fn solve(link: &mut LinkState, flows: &mut BTreeMap<FlowId, Flow>, fixed: &mut Vec<bool>) {
        link.demands.clear();
        for id in &link.flow_ids {
            let f = &flows[id];
            link.demands.push(FlowDemand {
                weight: f.weight,
                cap: f.cap,
            });
        }
        ps_rates_into(link.capacity, &link.demands, fixed, &mut link.rates);
        let mut sum = 0.0;
        for (id, &r) in link.flow_ids.iter().zip(link.rates.iter()) {
            flows.get_mut(id).expect("link flow in table").rate = r;
            sum += r;
        }
        link.link_rate = sum;
        link.dirty = false;
    }

    /// Solve `l` if dirty and refresh its calendar slot. Empty-link
    /// solves (clearing state after the last flow left) are not counted:
    /// the reference oracle's counter only ticks for non-empty links, and
    /// the two must stay comparable.
    fn ensure_link(&mut self, l: usize) {
        if !self.links[l].dirty {
            return;
        }
        Self::solve(&mut self.links[l], &mut self.flows, &mut self.fixed_scratch);
        if !self.links[l].flow_ids.is_empty() {
            self.rate_recomputes += 1;
        }
        self.refresh_calendar(l);
    }

    /// Recompute link `l`'s earliest-completion candidate from current
    /// remainings/rates: first minimum in ascending id order (strict `<`),
    /// matching the reference engine's global-scan tie-break.
    fn refresh_calendar(&mut self, l: usize) {
        let link = &self.links[l];
        let mut best: Option<(f64, FlowId)> = None;
        for id in &link.flow_ids {
            let f = &self.flows[id];
            if f.rate <= 0.0 {
                continue;
            }
            let dt = f.remaining / f.rate;
            if best.map(|(bt, _)| dt < bt).unwrap_or(true) {
                best = Some((dt, *id));
            }
        }
        self.calendar.set(l, best);
    }

    /// Current rate of each flow (GB/s), keyed by flow id.
    pub fn rates(&mut self) -> BTreeMap<FlowId, f64> {
        for l in 0..self.links.len() {
            self.ensure_link(l);
        }
        self.flows.iter().map(|(&id, f)| (id, f.rate)).collect()
    }

    /// Instantaneous rate of one flow.
    pub fn rate_of(&mut self, id: FlowId) -> f64 {
        let Some(f) = self.flows.get(&id) else {
            return 0.0;
        };
        let l = f.link.0;
        self.ensure_link(l);
        self.flows[&id].rate
    }

    /// Earliest (dt, flow) completion under current rates, if any flow is
    /// active and draining. O(log links) via the calendar: only links
    /// dirtied since the last query are re-solved/rescanned.
    pub fn next_completion(&mut self) -> Option<(f64, FlowId)> {
        for l in 0..self.links.len() {
            self.ensure_link(l);
        }
        self.calendar.earliest()
    }

    /// Advance all flows by `dt` seconds at current rates, accumulating
    /// the per-link/per-owner service integrals. Flows that hit zero are
    /// left at zero remaining (the caller removes them when their
    /// completion event fires). Allocation-free; clean links keep their
    /// cached rate vectors, and every link's completion candidate is
    /// refreshed in the same pass.
    pub fn advance(&mut self, dt: f64) {
        if dt <= 0.0 {
            return;
        }
        let Fabric {
            links,
            flows,
            owner_gb,
            calendar,
            adv_best,
            fixed_scratch,
            rate_recomputes,
            ..
        } = self;
        // Rates must reflect every mutation since the last solve — the
        // reference engine recomputes from scratch at this point. As in
        // `ensure_link`, empty-link solves are free of charge: the
        // reference counter never ticks for links without flows.
        for link in links.iter_mut() {
            if link.dirty {
                Self::solve(link, flows, fixed_scratch);
                if !link.flow_ids.is_empty() {
                    *rate_recomputes += 1;
                }
            }
        }
        for b in adv_best.iter_mut() {
            *b = None;
        }
        // Global id order: the reference engine interleaves links the
        // same way, which fixes the `owner_gb` accumulation order for
        // owners with flows on several links.
        for (&id, f) in flows.iter_mut() {
            let moved = (f.rate * dt).min(f.remaining);
            f.remaining -= moved;
            links[f.link.0].counters.gb_total += moved;
            owner_gb[f.owner] += moved;
            if f.rate > 0.0 {
                let cdt = f.remaining / f.rate;
                let b = &mut adv_best[f.link.0];
                if b.map(|(bt, _)| cdt < bt).unwrap_or(true) {
                    *b = Some((cdt, id));
                }
            }
        }
        for link in links.iter_mut() {
            // Empty links would add an exact 0.0 — skipping them is a
            // bitwise no-op (the reference adds the zero).
            if link.capacity > 0.0 && !link.flow_ids.is_empty() {
                link.counters.util_integral += (link.link_rate / link.capacity) * dt;
            }
        }
        for (l, best) in adv_best.iter().enumerate() {
            calendar.set(l, *best);
        }
    }

    /// Link utilization right now (0..1).
    pub fn utilization(&mut self, link: LinkId) -> f64 {
        let l = link.0;
        if self.links[l].capacity <= 0.0 {
            return 0.0;
        }
        self.ensure_link(l);
        self.links[l].link_rate / self.links[l].capacity
    }

    pub fn counters(&self, link: LinkId) -> LinkCounters {
        self.links[link.0].counters
    }

    pub fn owner_gb(&self, owner: usize) -> f64 {
        self.owner_gb.get(owner).copied().unwrap_or(0.0)
    }

    pub fn capacity(&self, link: LinkId) -> f64 {
        self.links[link.0].capacity
    }

    /// Remaining GB of a flow (tests / introspection).
    pub fn remaining(&self, id: FlowId) -> Option<f64> {
        self.flows.get(&id).map(|f| f.remaining)
    }

    /// Per-link PS solver invocations so far — the perf-trajectory
    /// counter the `scale_sweep` bench and the tier-1 recompute-ratio
    /// test compare against
    /// [`super::reference::ReferenceFabric::rate_recomputes`].
    pub fn rate_recomputes(&self) -> u64 {
        self.rate_recomputes
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::topo::HostTopology;

    fn fabric() -> Fabric {
        Fabric::new(&HostTopology::p4d())
    }

    #[test]
    fn single_flow_full_rate() {
        let mut f = fabric();
        let id = f.start(LinkId(0), 50.0, 1.0, None, 0);
        assert!((f.rate_of(id) - 25.0).abs() < 1e-12);
        let (dt, done) = f.next_completion().unwrap();
        assert_eq!(done, id);
        assert!((dt - 2.0).abs() < 1e-12);
    }

    #[test]
    fn two_flows_share_then_speed_up() {
        let mut f = fabric();
        let a = f.start(LinkId(0), 25.0, 1.0, None, 0);
        let b = f.start(LinkId(0), 12.5, 1.0, None, 1);
        // Equal share: 12.5 each; b finishes first at t=1.
        let (dt, first) = f.next_completion().unwrap();
        assert_eq!(first, b);
        assert!((dt - 1.0).abs() < 1e-12);
        f.advance(dt);
        f.remove(b);
        // a has 12.5 GB left, now at full 25 GB/s => 0.5 s more.
        let (dt2, second) = f.next_completion().unwrap();
        assert_eq!(second, a);
        assert!((dt2 - 0.5).abs() < 1e-9);
    }

    #[test]
    fn throttle_slows_owner() {
        let mut f = fabric();
        let a = f.start(LinkId(0), 100.0, 1.0, None, 2);
        f.set_owner_cap(2, Some(5.0));
        assert!((f.rate_of(a) - 5.0).abs() < 1e-12);
        f.set_owner_cap(2, None);
        assert!((f.rate_of(a) - 25.0).abs() < 1e-12);
    }

    #[test]
    fn links_are_independent() {
        let mut f = fabric();
        let a = f.start(LinkId(0), 10.0, 1.0, None, 0);
        let b = f.start(LinkId(1), 10.0, 1.0, None, 1);
        assert!((f.rate_of(a) - 25.0).abs() < 1e-12);
        assert!((f.rate_of(b) - 25.0).abs() < 1e-12);
    }

    #[test]
    fn counters_accumulate() {
        let mut f = fabric();
        f.start(LinkId(0), 10.0, 1.0, None, 7);
        f.advance(0.2); // 5 GB moved
        let c = f.counters(LinkId(0));
        assert!((c.gb_total - 5.0).abs() < 1e-9);
        assert!((f.owner_gb(7) - 5.0).abs() < 1e-9);
        assert!((f.utilization(LinkId(0)) - 1.0).abs() < 1e-12);
    }

    #[test]
    fn nvme_link_capacity() {
        let mut f = fabric();
        let a = f.start(LinkId(4), 16.0, 1.0, None, 0);
        assert!((f.rate_of(a) - 8.0).abs() < 1e-12);
    }

    #[test]
    fn advance_does_not_overshoot() {
        let mut f = fabric();
        let a = f.start(LinkId(0), 10.0, 1.0, None, 0);
        f.advance(100.0);
        assert!((f.remaining(a).unwrap() - 0.0).abs() < 1e-12);
        let c = f.counters(LinkId(0));
        assert!((c.gb_total - 10.0).abs() < 1e-9);
    }

    #[test]
    fn mutations_only_resolve_the_affected_link() {
        let mut f = fabric();
        let a = f.start(LinkId(0), 10.0, 1.0, None, 0);
        let a2 = f.start(LinkId(0), 10.0, 1.0, None, 0);
        f.start(LinkId(1), 10.0, 1.0, None, 1);
        // First query pays one solve per dirty (mutated) link.
        f.next_completion();
        assert_eq!(f.rate_recomputes(), 2);
        // Steady state: clean links cost nothing.
        f.next_completion();
        f.advance(0.01);
        f.next_completion();
        assert_eq!(f.rate_recomputes(), 2);
        // A mutation on link 0 re-solves only link 0.
        f.remove(a);
        f.next_completion();
        assert_eq!(f.rate_recomputes(), 3);
        // Removing a link's *last* flow clears state without a counted
        // solve — the reference counter never ticks for empty links, and
        // the two counters must stay comparable.
        f.remove(a2);
        f.next_completion();
        assert_eq!(f.rate_recomputes(), 3);
        // An owner cap on link 1's tenant re-solves only link 1.
        f.set_owner_cap(1, Some(2.0));
        f.next_completion();
        assert_eq!(f.rate_recomputes(), 4);
    }

    #[test]
    fn completion_ties_break_to_lowest_flow_id_across_links() {
        let mut f = fabric();
        // Same dt on two different links: 25 GB at 25 GB/s vs 8 GB at
        // 8 GB/s — both complete in exactly 1 s.
        let a = f.start(LinkId(0), 25.0, 1.0, None, 0);
        let _b = f.start(LinkId(4), 8.0, 1.0, None, 1);
        let (dt, first) = f.next_completion().unwrap();
        assert_eq!(dt, 1.0);
        assert_eq!(first, a, "lowest id must win exact ties");
    }

    #[test]
    fn drained_flow_reports_zero_dt_until_removed() {
        let mut f = fabric();
        let a = f.start(LinkId(0), 5.0, 1.0, None, 0);
        f.advance(10.0); // long past completion
        let (dt, id) = f.next_completion().unwrap();
        assert_eq!(id, a);
        assert_eq!(dt, 0.0);
        f.remove(a);
        assert!(f.next_completion().is_none());
    }
}
