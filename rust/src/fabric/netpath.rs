//! Weighted max-min rate allocation for flows that traverse a *sequence*
//! of links (the cluster-network generalization of [`super::ps`]).
//!
//! A net flow occupies every link on its path simultaneously; its rate is
//! bounded by its bottleneck. The allocation is weighted max-min fairness
//! via iterative water-filling (the classic bottleneck algorithm):
//! repeatedly find the lowest saturation level θ — either a link's
//! `cap_left / Σ unfixed weights` or a flow throttle's `g_i / w_i` — fix
//! the flows it freezes at `w_i·θ` (or `g_i`), subtract them from every
//! link they cross, and repeat. Each round fixes at least one flow, so
//! the loop terminates in ≤ n rounds. A single-link flow set reduces to
//! exactly the [`super::ps`] allocation.
//!
//! **Determinism contract.** Both net engines
//! ([`super::net_reference::NetReferenceFabric`] and
//! [`super::netfabric::NetFabric`]) call *this function* — the reference
//! on the full flow set, the incremental engine per connected component
//! of links sharing flows. Bottlenecks in disjoint components never
//! interact (fixing a flow only mutates state on its own path), so the
//! per-flow arithmetic is bit-identical either way as long as flows are
//! presented in ascending id order and links are scanned in ascending
//! index order — which this function requires and the differential
//! oracle enforces. Do not reorder the scans.

/// One path-flow's demand on the net fabric.
#[derive(Clone, Copy, Debug)]
pub struct NetFlowDemand<'a> {
    /// PS weight w_i (> 0).
    pub weight: f64,
    /// Optional end-to-end rate throttle g_i (same units as capacity).
    pub cap: Option<f64>,
    /// Link indices the flow traverses, pairwise distinct.
    pub path: &'a [usize],
}

/// Reusable solver scratch, sized to the link-id space on first use.
#[derive(Clone, Debug, Default)]
pub struct NetSolveScratch {
    cap_left: Vec<f64>,
    w_sum: Vec<f64>,
    active: Vec<bool>,
    touched: Vec<usize>,
    fixed: Vec<bool>,
}

/// Compute the weighted max-min rate vector for `flows` over links of
/// `capacities`. `rates[i]` receives flow `i`'s rate; `scratch` is
/// reusable working memory (allocation-free in steady state). Flows must
/// be presented in ascending flow-id order for cross-engine bit identity.
pub fn net_rates_into(
    capacities: &[f64],
    flows: &[NetFlowDemand<'_>],
    scratch: &mut NetSolveScratch,
    rates: &mut Vec<f64>,
) {
    let n = flows.len();
    rates.clear();
    rates.resize(n, 0.0);
    if n == 0 {
        return;
    }
    if scratch.active.len() < capacities.len() {
        scratch.cap_left.resize(capacities.len(), 0.0);
        scratch.w_sum.resize(capacities.len(), 0.0);
        scratch.active.resize(capacities.len(), false);
    }
    scratch.touched.clear();
    for f in flows {
        debug_assert!(f.weight > 0.0 && !f.path.is_empty());
        for &l in f.path {
            if !scratch.active[l] {
                scratch.active[l] = true;
                scratch.touched.push(l);
                scratch.cap_left[l] = capacities[l];
                scratch.w_sum[l] = 0.0;
            }
        }
    }
    // Ascending link order: the scan order below is part of the
    // determinism contract.
    scratch.touched.sort_unstable();
    // Weight sums accumulate in flow order (ascending id) per link.
    for f in flows {
        for &l in f.path {
            scratch.w_sum[l] += f.weight;
        }
    }
    scratch.fixed.clear();
    scratch.fixed.resize(n, false);

    let mut unfixed = n;
    while unfixed > 0 {
        // Lowest saturation level θ: links first (ascending index), then
        // flow throttles (ascending flow order), strict `<` throughout —
        // first minimum wins, exactly like the single-link solver's
        // tie-breaks.
        let mut best = f64::INFINITY;
        let mut best_link: Option<usize> = None;
        let mut best_flow: Option<usize> = None;
        for &l in &scratch.touched {
            if scratch.w_sum[l] > 0.0 {
                let theta = scratch.cap_left[l] / scratch.w_sum[l];
                if theta < best {
                    best = theta;
                    best_link = Some(l);
                    best_flow = None;
                }
            }
        }
        for (i, f) in flows.iter().enumerate() {
            if scratch.fixed[i] {
                continue;
            }
            if let Some(cap) = f.cap {
                let theta = cap / f.weight;
                if theta < best {
                    best = theta;
                    best_link = None;
                    best_flow = Some(i);
                }
            }
        }
        match (best_link, best_flow) {
            (_, Some(i)) => {
                // A throttle binds first: that flow freezes at its cap.
                let r = flows[i].cap.expect("cap candidate carries a cap");
                rates[i] = r;
                scratch.fixed[i] = true;
                unfixed -= 1;
                for &l in flows[i].path {
                    scratch.cap_left[l] -= r;
                    scratch.w_sum[l] -= flows[i].weight;
                }
            }
            (Some(bl), None) => {
                // A link saturates: every unfixed flow crossing it
                // freezes at its weighted share of the level.
                for (i, f) in flows.iter().enumerate() {
                    if scratch.fixed[i] || !f.path.contains(&bl) {
                        continue;
                    }
                    let r = f.weight * best;
                    rates[i] = r;
                    scratch.fixed[i] = true;
                    unfixed -= 1;
                    for &l in f.path {
                        scratch.cap_left[l] -= r;
                        scratch.w_sum[l] -= f.weight;
                    }
                }
            }
            (None, None) => {
                // Unreachable for well-formed flows (every unfixed flow
                // keeps a positive weight on each of its links); kept
                // total so a degenerate input cannot spin.
                break;
            }
        }
    }
    for l in scratch.touched.drain(..) {
        scratch.active[l] = false;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn solve(capacities: &[f64], flows: &[NetFlowDemand<'_>]) -> Vec<f64> {
        let mut scratch = NetSolveScratch::default();
        let mut rates = Vec::new();
        net_rates_into(capacities, flows, &mut scratch, &mut rates);
        rates
    }

    #[test]
    fn single_flow_runs_at_its_bottleneck() {
        let caps = [25.0, 12.5, 25.0];
        let path = [0usize, 1, 2];
        let r = solve(
            &caps,
            &[NetFlowDemand {
                weight: 1.0,
                cap: None,
                path: &path,
            }],
        );
        assert_eq!(r[0].to_bits(), 12.5f64.to_bits());
    }

    #[test]
    fn single_link_reduces_to_ps() {
        // Two equal flows on one shared link: equal split, like ps_rates.
        let caps = [24.0];
        let p = [0usize];
        let flows = [
            NetFlowDemand { weight: 1.0, cap: None, path: &p },
            NetFlowDemand { weight: 1.0, cap: None, path: &p },
            NetFlowDemand { weight: 1.0, cap: None, path: &p },
        ];
        let r = solve(&caps, &flows);
        for x in &r {
            assert!((x - 8.0).abs() < 1e-12);
        }
    }

    #[test]
    fn bottleneck_share_redistributes_elsewhere() {
        // Flow A crosses links 0→1, flow B crosses link 1 only, flow C
        // crosses link 0 only. Link 1 (10) is the tight one: A and B get
        // 5 each; C then soaks up the rest of link 0 (25 - 5 = 20).
        let caps = [25.0, 10.0];
        let (pa, pb, pc) = ([0usize, 1], [1usize], [0usize]);
        let flows = [
            NetFlowDemand { weight: 1.0, cap: None, path: &pa },
            NetFlowDemand { weight: 1.0, cap: None, path: &pb },
            NetFlowDemand { weight: 1.0, cap: None, path: &pc },
        ];
        let r = solve(&caps, &flows);
        assert!((r[0] - 5.0).abs() < 1e-12);
        assert!((r[1] - 5.0).abs() < 1e-12);
        assert!((r[2] - 20.0).abs() < 1e-12);
    }

    #[test]
    fn throttle_binds_and_redistributes() {
        let caps = [20.0];
        let p = [0usize];
        let flows = [
            NetFlowDemand { weight: 1.0, cap: Some(4.0), path: &p },
            NetFlowDemand { weight: 1.0, cap: None, path: &p },
        ];
        let r = solve(&caps, &flows);
        assert!((r[0] - 4.0).abs() < 1e-12);
        assert!((r[1] - 16.0).abs() < 1e-12);
    }

    #[test]
    fn weighted_share_on_the_bottleneck() {
        let caps = [30.0];
        let p = [0usize];
        let flows = [
            NetFlowDemand { weight: 2.0, cap: None, path: &p },
            NetFlowDemand { weight: 1.0, cap: None, path: &p },
        ];
        let r = solve(&caps, &flows);
        assert!((r[0] - 20.0).abs() < 1e-12);
        assert!((r[1] - 10.0).abs() < 1e-12);
    }

    #[test]
    fn disjoint_components_solve_independently() {
        // The property the incremental engine's per-component solve rests
        // on: rates in one component are bit-identical whether or not the
        // other component's flows are present.
        let caps = [25.0, 10.0, 12.5, 8.0];
        let (pa, pb) = ([0usize, 1], [2usize, 3]);
        let both = [
            NetFlowDemand { weight: 1.0, cap: None, path: &pa },
            NetFlowDemand { weight: 1.5, cap: Some(6.0), path: &pb },
        ];
        let r_both = solve(&caps, &both);
        let r_a = solve(&caps, &both[..1]);
        let r_b = solve(&caps, &both[1..]);
        assert_eq!(r_both[0].to_bits(), r_a[0].to_bits());
        assert_eq!(r_both[1].to_bits(), r_b[0].to_bits());
    }

    #[test]
    fn conservation_under_random_paths() {
        use crate::util::rng::Pcg64;
        let mut rng = Pcg64::seeded(41);
        let mut scratch = NetSolveScratch::default();
        let mut rates = Vec::new();
        for _ in 0..300 {
            let n_links = 2 + rng.below(6) as usize;
            let caps: Vec<f64> = (0..n_links).map(|_| rng.range_f64(1.0, 30.0)).collect();
            let n_flows = 1 + rng.below(8) as usize;
            let paths: Vec<Vec<usize>> = (0..n_flows)
                .map(|_| {
                    let len = 1 + rng.below(n_links as u64) as usize;
                    let mut p: Vec<usize> = (0..n_links).collect();
                    // Deterministic shuffle-by-draw: pick `len` distinct links.
                    let mut out = Vec::new();
                    for _ in 0..len {
                        let k = rng.below(p.len() as u64) as usize;
                        out.push(p.remove(k));
                    }
                    out
                })
                .collect();
            let flows: Vec<NetFlowDemand> = paths
                .iter()
                .map(|p| NetFlowDemand {
                    weight: rng.range_f64(0.1, 4.0),
                    cap: rng.chance(0.4).then(|| rng.range_f64(0.5, 10.0)),
                    path: p,
                })
                .collect();
            net_rates_into(&caps, &flows, &mut scratch, &mut rates);
            // No link over capacity; no flow negative or over its cap.
            for (l, &c) in caps.iter().enumerate() {
                let total: f64 = flows
                    .iter()
                    .zip(&rates)
                    .filter(|(f, _)| f.path.contains(&l))
                    .map(|(_, r)| *r)
                    .sum();
                assert!(total <= c + 1e-9, "link {l}: {total} > {c}");
            }
            for (f, r) in flows.iter().zip(&rates) {
                assert!(*r >= -1e-12);
                if let Some(g) = f.cap {
                    assert!(*r <= g + 1e-9);
                }
            }
        }
    }

    #[test]
    fn empty_and_degenerate() {
        assert!(solve(&[10.0], &[]).is_empty());
        let p = [0usize];
        let r = solve(
            &[0.0],
            &[NetFlowDemand { weight: 1.0, cap: None, path: &p }],
        );
        assert_eq!(r, vec![0.0]);
    }
}
