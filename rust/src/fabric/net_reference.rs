//! Reference cluster-network fabric: the semantics oracle for
//! [`super::netfabric::NetFabric`].
//!
//! Deliberately naive — every query re-solves the *entire* flow set with
//! the shared path solver ([`super::netpath::net_rates_into`]) and scans
//! every flow for the next completion. O(flows · links) per query, no
//! caching, no dirty tracking. Its job is to define what the incremental
//! engine must compute, bit for bit; the differential oracle
//! (`prop_net_fabric_incremental_matches_reference_bitwise`) holds the
//! two together.
//!
//! Do not "fix" or speed this module up: its value is that the code is
//! short enough to audit by eye against the model in §2.5.1 generalized
//! to multi-link paths.

use std::cell::Cell;
use std::collections::BTreeMap;

use super::netpath::{net_rates_into, NetFlowDemand, NetSolveScratch};
use super::transfer::{FlowId, LinkCounters};
use crate::topo::{ClusterTopology, NetLinkId};

#[derive(Clone, Debug)]
struct NetFlow {
    path: Vec<usize>,
    weight: f64,
    cap: Option<f64>,
    remaining: f64,
    owner: usize,
}

/// The straightforward net-fabric implementation.
#[derive(Clone, Debug)]
pub struct NetReferenceFabric {
    capacities: Vec<f64>,
    flows: BTreeMap<FlowId, NetFlow>,
    next_id: u64,
    counters: Vec<LinkCounters>,
    owner_gb: BTreeMap<usize, f64>,
    /// Full-solve count (telemetry; interior-mutable because `rates` is
    /// conceptually a read).
    solver_calls: Cell<u64>,
}

impl NetReferenceFabric {
    pub fn new(cluster: &ClusterTopology) -> NetReferenceFabric {
        let capacities: Vec<f64> = (0..cluster.num_net_links)
            .map(|l| cluster.capacity(NetLinkId(l)))
            .collect();
        let n = capacities.len();
        NetReferenceFabric {
            capacities,
            flows: BTreeMap::new(),
            next_id: 1,
            counters: vec![LinkCounters::default(); n],
            owner_gb: BTreeMap::new(),
            solver_calls: Cell::new(0),
        }
    }

    /// Start a flow of `gb` gigabytes over `path` for tenant `owner`.
    pub fn start(
        &mut self,
        path: &[NetLinkId],
        gb: f64,
        weight: f64,
        cap: Option<f64>,
        owner: usize,
    ) -> FlowId {
        assert!(!path.is_empty(), "a net flow needs a path");
        assert!(gb > 0.0 && weight > 0.0);
        for l in path {
            assert!(l.0 < self.capacities.len(), "unknown net link {l:?}");
        }
        let id = FlowId(self.next_id);
        self.next_id += 1;
        self.flows.insert(
            id,
            NetFlow {
                path: path.iter().map(|l| l.0).collect(),
                weight,
                cap,
                remaining: gb,
                owner,
            },
        );
        id
    }

    pub fn remove(&mut self, id: FlowId) {
        self.flows.remove(&id);
    }

    /// Throttle every flow owned by `owner` to `cap` GB/s end to end
    /// (`None` lifts the throttle).
    pub fn set_owner_cap(&mut self, owner: usize, cap: Option<f64>) {
        for f in self.flows.values_mut() {
            if f.owner == owner {
                f.cap = cap;
            }
        }
    }

    pub fn set_link_capacity(&mut self, link: NetLinkId, gbps: f64) {
        assert!(link.0 < self.capacities.len(), "unknown net link {link:?}");
        self.capacities[link.0] = gbps;
    }

    pub fn flow_exists(&self, id: FlowId) -> bool {
        self.flows.contains_key(&id)
    }

    pub fn active_flows(&self) -> usize {
        self.flows.len()
    }

    /// Current rate of every flow — one full solve over the whole fabric.
    pub fn rates(&self) -> BTreeMap<FlowId, f64> {
        if self.flows.is_empty() {
            return BTreeMap::new();
        }
        self.solver_calls.set(self.solver_calls.get() + 1);
        let demands: Vec<NetFlowDemand> = self
            .flows
            .values()
            .map(|f| NetFlowDemand {
                weight: f.weight,
                cap: f.cap,
                path: &f.path,
            })
            .collect();
        let mut scratch = NetSolveScratch::default();
        let mut rates = Vec::new();
        net_rates_into(&self.capacities, &demands, &mut scratch, &mut rates);
        self.flows.keys().copied().zip(rates).collect()
    }

    pub fn rate_of(&self, id: FlowId) -> Option<f64> {
        self.rates().get(&id).copied()
    }

    /// Time until the next flow drains, with the flow that drains —
    /// strict `<` scan in ascending id order, like the PCIe reference.
    pub fn next_completion(&self) -> Option<(f64, FlowId)> {
        let rates = self.rates();
        let mut best: Option<(f64, FlowId)> = None;
        for (id, f) in &self.flows {
            let r = rates[id];
            if r <= 0.0 {
                continue;
            }
            let dt = f.remaining / r;
            if best.map(|(b, _)| dt < b).unwrap_or(true) {
                best = Some((dt, *id));
            }
        }
        best
    }

    /// Move `dt` seconds of traffic at the current rates.
    pub fn advance(&mut self, dt: f64) {
        assert!(dt >= 0.0);
        let rates = self.rates();
        for (id, f) in self.flows.iter_mut() {
            let moved = (rates[id] * dt).min(f.remaining);
            f.remaining -= moved;
            for &l in &f.path {
                self.counters[l].gb_total += moved;
            }
            *self.owner_gb.entry(f.owner).or_insert(0.0) += moved;
        }
        for l in 0..self.capacities.len() {
            let cap = self.capacities[l];
            if cap <= 0.0 {
                continue;
            }
            let link_rate: f64 = self
                .flows
                .iter()
                .filter(|(_, f)| f.path.contains(&l))
                .map(|(id, _)| rates[id])
                .sum();
            self.counters[l].util_integral += (link_rate / cap) * dt;
        }
    }

    pub fn remaining(&self, id: FlowId) -> Option<f64> {
        self.flows.get(&id).map(|f| f.remaining)
    }

    pub fn counters(&self, link: NetLinkId) -> LinkCounters {
        self.counters[link.0]
    }

    pub fn owner_gb(&self, owner: usize) -> f64 {
        self.owner_gb.get(&owner).copied().unwrap_or(0.0)
    }

    pub fn capacity(&self, link: NetLinkId) -> f64 {
        self.capacities[link.0]
    }

    pub fn num_links(&self) -> usize {
        self.capacities.len()
    }

    /// Full solves performed so far (telemetry only — not part of the
    /// bit-compat surface; the incremental engine counts differently).
    pub fn rate_recomputes(&self) -> u64 {
        self.solver_calls.get()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn two_leaf() -> ClusterTopology {
        ClusterTopology::leaf_spine(2, 2, 2)
    }

    #[test]
    fn lone_flow_runs_at_nic_line_rate() {
        let c = two_leaf();
        let mut fab = NetReferenceFabric::new(&c);
        let id = fab.start(&c.route(0, 2), 25.0, 1.0, None, 0);
        assert_eq!(fab.rate_of(id).unwrap().to_bits(), 12.5f64.to_bits());
        let (dt, done) = fab.next_completion().unwrap();
        assert_eq!(done, id);
        assert_eq!(dt.to_bits(), 2.0f64.to_bits());
    }

    #[test]
    fn colliding_flows_split_the_shared_trunk() {
        let c = two_leaf();
        let mut fab = NetReferenceFabric::new(&c);
        // 0→2 and 1→3 both pick spine 1, sharing up(0,1): 25 GB/s trunk
        // isn't the bottleneck, the NICs are — so no contention here.
        let a = fab.start(&c.route(0, 2), 10.0, 1.0, None, 0);
        let b = fab.start(&c.route(1, 3), 10.0, 1.0, None, 1);
        assert_eq!(c.spine_for(0, 1), 1);
        let rates = fab.rates();
        assert_eq!(rates[&a].to_bits(), 12.5f64.to_bits());
        assert_eq!(rates[&b].to_bits(), 12.5f64.to_bits());
        // Degrade the shared trunk below 2×NIC: now the two flows split it.
        fab.set_link_capacity(c.up(0, 1), 10.0);
        let rates = fab.rates();
        assert!((rates[&a] - 5.0).abs() < 1e-12);
        assert!((rates[&b] - 5.0).abs() < 1e-12);
    }

    #[test]
    fn advance_moves_bytes_and_counts_per_link() {
        let c = two_leaf();
        let mut fab = NetReferenceFabric::new(&c);
        let id = fab.start(&c.route(0, 1), 5.0, 1.0, None, 3);
        fab.advance(0.2);
        let moved = 12.5 * 0.2;
        assert!((fab.remaining(id).unwrap() - (5.0 - moved)).abs() < 1e-12);
        // Every link on the path saw the same bytes.
        for l in c.route(0, 1) {
            assert!((fab.counters(l).gb_total - moved).abs() < 1e-12);
        }
        // Links off the path saw none.
        assert_eq!(fab.counters(c.host_tx(2)).gb_total, 0.0);
        assert!((fab.owner_gb(3) - moved).abs() < 1e-12);
    }

    #[test]
    fn owner_cap_throttles_end_to_end() {
        let c = two_leaf();
        let mut fab = NetReferenceFabric::new(&c);
        let id = fab.start(&c.route(0, 1), 5.0, 1.0, None, 0);
        fab.set_owner_cap(0, Some(2.0));
        assert_eq!(fab.rate_of(id).unwrap().to_bits(), 2.0f64.to_bits());
        fab.set_owner_cap(0, None);
        assert_eq!(fab.rate_of(id).unwrap().to_bits(), 12.5f64.to_bits());
    }

    #[test]
    fn completion_drains_exactly() {
        let c = two_leaf();
        let mut fab = NetReferenceFabric::new(&c);
        let id = fab.start(&c.route(0, 1), 2.5, 1.0, None, 0);
        let (dt, _) = fab.next_completion().unwrap();
        fab.advance(dt);
        assert!(fab.remaining(id).unwrap() <= 1e-12);
        assert!(fab.next_completion().is_none());
    }
}
