//! Shared-bandwidth fabric: the paper's §2.5.1 processor-sharing model.
//!
//! "We model the PCIe fabric as a single processor-sharing (PS) server of
//! capacity B. When a set A(t) of tenants is active, tenant i receives
//! instantaneous bandwidth b_i(t) = min(B·w_i / Σ_j w_j, g_i)."
//!
//! [`ps`] implements that allocation exactly (weighted PS with optional
//! per-flow caps, via water-filling) for every shared-bandwidth domain on
//! the host (PCIe upstream links, NUMA-local NVMe paths). [`transfer`]
//! runs fluid-flow transfers over it for the discrete-event simulator.

pub mod ps;
pub mod transfer;

pub use ps::{ps_rates, FlowDemand};
pub use transfer::{Fabric, FlowId, LinkCounters};
