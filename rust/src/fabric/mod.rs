//! Shared-bandwidth fabric: the paper's §2.5.1 processor-sharing model.
//!
//! "We model the PCIe fabric as a single processor-sharing (PS) server of
//! capacity B. When a set A(t) of tenants is active, tenant i receives
//! instantaneous bandwidth b_i(t) = min(B·w_i / Σ_j w_j, g_i)."
//!
//! [`ps`] implements that allocation exactly (weighted PS with optional
//! per-flow caps, via water-filling) for every shared-bandwidth domain on
//! the host (PCIe upstream links, NUMA-local NVMe paths).
//!
//! Two engines run fluid-flow transfers over it:
//!
//! * [`transfer::Fabric`] — the **incremental per-link engine** on the
//!   simulator's hot path: dirty-link invalidation with cached PS rate
//!   vectors, allocation-free steady state, and a versioned completion
//!   [`calendar`] for O(log links) `next_completion`.
//! * [`reference::ReferenceFabric`] — the original recompute-everything
//!   implementation, kept verbatim as the differential-test oracle and
//!   the `scale_sweep` baseline. The incremental engine must match it
//!   bit for bit.
//!
//! [`FabricBackend`] lets the simulated world run on either engine
//! (`SimWorld::new_with_fabric`); production paths always use the
//! incremental one.
//!
//! The same split repeats one tier up for the **cluster network** (PR
//! 10): [`netpath`] generalizes the PS allocation to flows that traverse
//! a *sequence* of links (host uplink + NIC + leaf/spine trunks),
//! [`net_reference::NetReferenceFabric`] defines the semantics, and
//! [`netfabric::NetFabric`] is the incremental engine behind
//! [`NetFabricBackend`]. Scenarios without a
//! [`crate::topo::ClusterTopology`] build no net fabric at all.

pub mod calendar;
pub mod net_reference;
pub mod netfabric;
pub mod netpath;
pub mod ps;
pub mod reference;
pub mod transfer;

pub use net_reference::NetReferenceFabric;
pub use netfabric::NetFabric;
pub use netpath::{net_rates_into, NetFlowDemand, NetSolveScratch};
pub use ps::{ps_rates, ps_rates_into, FlowDemand};
pub use reference::ReferenceFabric;
pub use transfer::{Fabric, FlowId, LinkCounters};

use crate::topo::{ClusterTopology, HostTopology, LinkId, NetLinkId};

/// Which fluid-flow engine a world should run on.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum FabricKind {
    /// The incremental per-link engine (the default everywhere).
    Incremental,
    /// The from-scratch oracle — differential tests and baselines only.
    Reference,
}

/// A fluid-flow engine behind a single dispatch point, so the simulated
/// world can be driven bit-identically by either implementation. The
/// method set is exactly what the sim platform touches on its hot path.
#[derive(Clone, Debug)]
pub enum FabricBackend {
    Incremental(Fabric),
    Reference(ReferenceFabric),
}

impl FabricBackend {
    pub fn new(topo: &HostTopology, kind: FabricKind) -> FabricBackend {
        match kind {
            FabricKind::Incremental => FabricBackend::Incremental(Fabric::new(topo)),
            FabricKind::Reference => FabricBackend::Reference(ReferenceFabric::new(topo)),
        }
    }

    #[inline]
    pub fn start(
        &mut self,
        link: LinkId,
        gb: f64,
        weight: f64,
        cap: Option<f64>,
        owner: usize,
    ) -> FlowId {
        match self {
            FabricBackend::Incremental(f) => f.start(link, gb, weight, cap, owner),
            FabricBackend::Reference(f) => f.start(link, gb, weight, cap, owner),
        }
    }

    #[inline]
    pub fn remove(&mut self, id: FlowId) -> Option<usize> {
        match self {
            FabricBackend::Incremental(f) => f.remove(id),
            FabricBackend::Reference(f) => f.remove(id),
        }
    }

    #[inline]
    pub fn set_owner_cap(&mut self, owner: usize, cap: Option<f64>) {
        match self {
            FabricBackend::Incremental(f) => f.set_owner_cap(owner, cap),
            FabricBackend::Reference(f) => f.set_owner_cap(owner, cap),
        }
    }

    #[inline]
    pub fn advance(&mut self, dt: f64) {
        match self {
            FabricBackend::Incremental(f) => f.advance(dt),
            FabricBackend::Reference(f) => f.advance(dt),
        }
    }

    #[inline]
    pub fn next_completion(&mut self) -> Option<(f64, FlowId)> {
        match self {
            FabricBackend::Incremental(f) => f.next_completion(),
            FabricBackend::Reference(f) => f.next_completion(),
        }
    }

    #[inline]
    pub fn remaining(&self, id: FlowId) -> Option<f64> {
        match self {
            FabricBackend::Incremental(f) => f.remaining(id),
            FabricBackend::Reference(f) => f.remaining(id),
        }
    }

    #[inline]
    pub fn counters(&self, link: LinkId) -> LinkCounters {
        match self {
            FabricBackend::Incremental(f) => f.counters(link),
            FabricBackend::Reference(f) => f.counters(link),
        }
    }

    #[inline]
    pub fn owner_gb(&self, owner: usize) -> f64 {
        match self {
            FabricBackend::Incremental(f) => f.owner_gb(owner),
            FabricBackend::Reference(f) => f.owner_gb(owner),
        }
    }

    #[inline]
    pub fn capacity(&self, link: LinkId) -> f64 {
        match self {
            FabricBackend::Incremental(f) => f.capacity(link),
            FabricBackend::Reference(f) => f.capacity(link),
        }
    }

    /// Change a link's capacity in place (fault injection: degradation
    /// and flap edges). Both engines re-share in-flight flows over the
    /// new capacity at their next solve.
    #[inline]
    pub fn set_link_capacity(&mut self, link: LinkId, gbps: f64) {
        match self {
            FabricBackend::Incremental(f) => f.set_link_capacity(link, gbps),
            FabricBackend::Reference(f) => f.set_link_capacity(link, gbps),
        }
    }

    #[inline]
    pub fn flow_exists(&self, id: FlowId) -> bool {
        match self {
            FabricBackend::Incremental(f) => f.flow_exists(id),
            FabricBackend::Reference(f) => f.flow_exists(id),
        }
    }

    #[inline]
    pub fn active_flows(&self) -> usize {
        match self {
            FabricBackend::Incremental(f) => f.active_flows(),
            FabricBackend::Reference(f) => f.active_flows(),
        }
    }

    /// Per-link PS solver invocations — the perf-trajectory counter
    /// surfaced in `RunResult::fabric_rate_recomputes`.
    #[inline]
    pub fn rate_recomputes(&self) -> u64 {
        match self {
            FabricBackend::Incremental(f) => f.rate_recomputes(),
            FabricBackend::Reference(f) => f.rate_recomputes(),
        }
    }
}

/// The cluster-network twin of [`FabricBackend`]: one dispatch point so
/// the world (and the differential oracles) can drive either net engine
/// bit-identically. Built only when a scenario carries a
/// [`ClusterTopology`].
#[derive(Clone, Debug)]
pub enum NetFabricBackend {
    Incremental(NetFabric),
    Reference(NetReferenceFabric),
}

impl NetFabricBackend {
    pub fn new(cluster: &ClusterTopology, kind: FabricKind) -> NetFabricBackend {
        match kind {
            FabricKind::Incremental => NetFabricBackend::Incremental(NetFabric::new(cluster)),
            FabricKind::Reference => NetFabricBackend::Reference(NetReferenceFabric::new(cluster)),
        }
    }

    #[inline]
    pub fn start(
        &mut self,
        path: &[NetLinkId],
        gb: f64,
        weight: f64,
        cap: Option<f64>,
        owner: usize,
    ) -> FlowId {
        match self {
            NetFabricBackend::Incremental(f) => f.start(path, gb, weight, cap, owner),
            NetFabricBackend::Reference(f) => f.start(path, gb, weight, cap, owner),
        }
    }

    #[inline]
    pub fn remove(&mut self, id: FlowId) {
        match self {
            NetFabricBackend::Incremental(f) => f.remove(id),
            NetFabricBackend::Reference(f) => f.remove(id),
        }
    }

    #[inline]
    pub fn set_owner_cap(&mut self, owner: usize, cap: Option<f64>) {
        match self {
            NetFabricBackend::Incremental(f) => f.set_owner_cap(owner, cap),
            NetFabricBackend::Reference(f) => f.set_owner_cap(owner, cap),
        }
    }

    #[inline]
    pub fn advance(&mut self, dt: f64) {
        match self {
            NetFabricBackend::Incremental(f) => f.advance(dt),
            NetFabricBackend::Reference(f) => f.advance(dt),
        }
    }

    #[inline]
    pub fn next_completion(&mut self) -> Option<(f64, FlowId)> {
        match self {
            NetFabricBackend::Incremental(f) => f.next_completion(),
            NetFabricBackend::Reference(f) => f.next_completion(),
        }
    }

    #[inline]
    pub fn remaining(&self, id: FlowId) -> Option<f64> {
        match self {
            NetFabricBackend::Incremental(f) => f.remaining(id),
            NetFabricBackend::Reference(f) => f.remaining(id),
        }
    }

    #[inline]
    pub fn counters(&self, link: NetLinkId) -> LinkCounters {
        match self {
            NetFabricBackend::Incremental(f) => f.counters(link),
            NetFabricBackend::Reference(f) => f.counters(link),
        }
    }

    #[inline]
    pub fn owner_gb(&self, owner: usize) -> f64 {
        match self {
            NetFabricBackend::Incremental(f) => f.owner_gb(owner),
            NetFabricBackend::Reference(f) => f.owner_gb(owner),
        }
    }

    #[inline]
    pub fn capacity(&self, link: NetLinkId) -> f64 {
        match self {
            NetFabricBackend::Incremental(f) => f.capacity(link),
            NetFabricBackend::Reference(f) => f.capacity(link),
        }
    }

    #[inline]
    pub fn set_link_capacity(&mut self, link: NetLinkId, gbps: f64) {
        match self {
            NetFabricBackend::Incremental(f) => f.set_link_capacity(link, gbps),
            NetFabricBackend::Reference(f) => f.set_link_capacity(link, gbps),
        }
    }

    #[inline]
    pub fn flow_exists(&self, id: FlowId) -> bool {
        match self {
            NetFabricBackend::Incremental(f) => f.flow_exists(id),
            NetFabricBackend::Reference(f) => f.flow_exists(id),
        }
    }

    #[inline]
    pub fn active_flows(&self) -> usize {
        match self {
            NetFabricBackend::Incremental(f) => f.active_flows(),
            NetFabricBackend::Reference(f) => f.active_flows(),
        }
    }

    #[inline]
    pub fn num_links(&self) -> usize {
        match self {
            NetFabricBackend::Incremental(f) => f.num_links(),
            NetFabricBackend::Reference(f) => f.num_links(),
        }
    }

    #[inline]
    pub fn rate_recomputes(&self) -> u64 {
        match self {
            NetFabricBackend::Incremental(f) => f.rate_recomputes(),
            NetFabricBackend::Reference(f) => f.rate_recomputes(),
        }
    }
}
