//! Tenant → shard partitioning for the sharded simulation core.
//!
//! Shard boundaries follow structure the world already guarantees:
//! within a host, PCIe switch subtrees couple only through the uplink PS
//! solve and the host-wide arbiter tick (see ARCHITECTURE.md "Parallel
//! core"), so the natural unit of locality is the switch hosting a
//! tenant's GPU. [`ShardMap::new`] takes one *locality key* per tenant
//! (the switch index) and assigns whole keys to shards — tenants that
//! share a switch always land on the same shard, keeping every
//! intra-subtree interaction shard-local.
//!
//! The assignment is a pure function of `(keys, shards)`: keys are
//! visited in ascending order and each goes to the currently
//! least-loaded shard (ties to the lowest shard index). Determinism
//! here is load-bearing — the map decides which per-shard queue each
//! event lives in, and the merge layer's bit-identity argument assumes
//! the same scenario always yields the same routing.

/// Shard that hosts world-global events (the arbiter's `Sample` tick and
/// fabric `FlowsDone` completions): these are causally host-wide, so
/// they live on one designated coordinator shard.
pub const COORD_SHARD: usize = 0;

/// Deterministic tenant → shard assignment.
#[derive(Clone, Debug)]
pub struct ShardMap {
    shards: usize,
    of_tenant: Vec<usize>,
    tenants_per_shard: Vec<usize>,
}

impl ShardMap {
    /// Build a map over `locality[i]` = the locality key (PCIe switch
    /// index) of tenant `i`. Whole keys are packed onto the
    /// least-loaded shard in ascending key order.
    pub fn new(locality: &[usize], shards: usize) -> ShardMap {
        assert!(shards >= 1, "shard count must be >= 1");
        let mut keys: Vec<usize> = locality.to_vec();
        keys.sort_unstable();
        keys.dedup();

        // key -> shard, greedily balancing by tenant count.
        let mut load = vec![0usize; shards];
        let mut key_shard = Vec::with_capacity(keys.len());
        for &k in &keys {
            let members = locality.iter().filter(|&&l| l == k).count();
            let target = (0..shards).min_by_key(|&s| (load[s], s)).unwrap();
            load[target] += members;
            key_shard.push((k, target));
        }
        let of_tenant = locality
            .iter()
            .map(|l| {
                key_shard
                    .iter()
                    .find(|(k, _)| k == l)
                    .map(|&(_, s)| s)
                    .unwrap()
            })
            .collect();
        ShardMap {
            shards,
            of_tenant,
            tenants_per_shard: load,
        }
    }

    pub fn shards(&self) -> usize {
        self.shards
    }

    pub fn shard_of(&self, tenant: usize) -> usize {
        self.of_tenant[tenant]
    }

    pub fn tenants_on(&self, shard: usize) -> usize {
        self.tenants_per_shard[shard]
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn single_shard_maps_everyone_to_zero() {
        let m = ShardMap::new(&[0, 1, 2, 1, 0], 1);
        for t in 0..5 {
            assert_eq!(m.shard_of(t), 0);
        }
        assert_eq!(m.tenants_on(0), 5);
    }

    #[test]
    fn same_switch_same_shard() {
        let locality = [0, 0, 1, 1, 2, 2, 3, 3];
        let m = ShardMap::new(&locality, 4);
        for (a, &ka) in locality.iter().enumerate() {
            for (b, &kb) in locality.iter().enumerate() {
                if ka == kb {
                    assert_eq!(m.shard_of(a), m.shard_of(b));
                }
            }
        }
    }

    #[test]
    fn balanced_when_keys_divide_evenly() {
        let locality: Vec<usize> = (0..16).map(|t| t / 2).collect(); // 8 keys x 2
        let m = ShardMap::new(&locality, 4);
        for s in 0..4 {
            assert_eq!(m.tenants_on(s), 4);
        }
    }

    #[test]
    fn deterministic_in_inputs() {
        let locality = [3, 1, 4, 1, 5, 9, 2, 6, 5, 3];
        let a = ShardMap::new(&locality, 3);
        let b = ShardMap::new(&locality, 3);
        for t in 0..locality.len() {
            assert_eq!(a.shard_of(t), b.shard_of(t));
        }
    }

    #[test]
    fn more_shards_than_keys_leaves_spares_empty() {
        let m = ShardMap::new(&[0, 0, 0], 4);
        assert_eq!(m.tenants_on(m.shard_of(0)), 3);
        let used: usize = (0..4).map(|s| m.tenants_on(s)).sum();
        assert_eq!(used, 3);
    }

    #[test]
    #[should_panic(expected = "shard count")]
    fn zero_shards_rejected() {
        ShardMap::new(&[0], 0);
    }

    #[test]
    fn coordinator_shard_is_shard_zero() {
        // The event router pins Sample / FlowsDone / NetFlowsDone /
        // FaultEdge to this constant; it is part of the bit-identity
        // contract and must never drift.
        assert_eq!(COORD_SHARD, 0);
    }

    #[test]
    fn greedy_packing_visits_keys_ascending_onto_least_loaded() {
        // Keys in ascending order: key 0 (3 tenants) fills shard 0,
        // then keys 1 and 2 both land on the lighter shard 1.
        let m = ShardMap::new(&[0, 0, 0, 1, 2], 2);
        assert_eq!(m.shard_of(0), 0);
        assert_eq!(m.shard_of(1), 0);
        assert_eq!(m.shard_of(2), 0);
        assert_eq!(m.shard_of(3), 1);
        assert_eq!(m.shard_of(4), 1);
        assert_eq!(m.tenants_on(0), 3);
        assert_eq!(m.tenants_on(1), 2);
    }

    #[test]
    fn loads_account_for_every_tenant_with_bounded_spread() {
        let locality = [3, 1, 4, 1, 5, 9, 2, 6, 5, 3, 5];
        let m = ShardMap::new(&locality, 3);
        let loads: Vec<usize> = (0..3).map(|s| m.tenants_on(s)).collect();
        assert_eq!(loads.iter().sum::<usize>(), locality.len());
        // Greedy least-loaded packing: the spread is bounded by the
        // largest key group (key 5 appears three times here).
        assert!(loads.iter().max().unwrap() - loads.iter().min().unwrap() <= 3);
        // Per-tenant routing stays consistent with the load table.
        for t in 0..locality.len() {
            assert!(m.shard_of(t) < m.shards());
        }
    }
}
