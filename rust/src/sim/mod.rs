//! Discrete-event simulation engine.
//!
//! A deterministic event queue with stable tie-breaking and event
//! versioning (fluid-flow completions get invalidated when the PS rate
//! allocation changes — see [`crate::fabric`]). The testbed world that
//! composes fabric + GPUs + tenants + controller lives in
//! [`crate::platform::sim_platform`]; this module is only the clockwork.

pub mod engine;

pub use engine::{EventQueue, SimClock};
