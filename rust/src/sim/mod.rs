//! Discrete-event simulation engine.
//!
//! A deterministic event queue with stable tie-breaking and event
//! versioning (fluid-flow completions get invalidated when the PS rate
//! allocation changes — see [`crate::fabric`]). The testbed world that
//! composes fabric + GPUs + tenants + controller lives in
//! [`crate::platform::sim_platform`]; this module is only the clockwork.

pub mod engine;
pub mod parallel;
pub mod shard;

pub use engine::{EventQueue, SimClock, PAST_EVENT_EPS_S};
pub use parallel::{EngineKind, ShardedQueue};
pub use shard::{ShardMap, COORD_SHARD};
