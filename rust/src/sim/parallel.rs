//! Sharded conservative-synchronization simulation core.
//!
//! The single-queue engine ([`EventQueue`]) is the *reference*: its
//! `(time, seq)` order is the determinism contract every regression
//! fingerprint is pinned to. This module shards that clockwork in the
//! conservative-PDES (rustasim) shape while staying **byte-identical**
//! to the reference:
//!
//! * **Per-shard event queues.** Each shard owns a min-heap over the
//!   same `Entry` ordering the reference uses (time by `total_cmp`,
//!   ties by insertion seq). Shards are tenant partitions along PCIe
//!   switch subtrees ([`crate::sim::shard`]); world-global events
//!   (arbiter `Sample` ticks, fabric `FlowsDone`) live on the
//!   coordinator shard.
//! * **Deterministic merge.** One *global* insertion-sequence counter
//!   spans all shards, and [`ShardedQueue::pop`] always returns the
//!   globally minimal `(time, seq)` entry across shard heads. Handlers
//!   therefore observe events in exactly the reference order, so they
//!   perform pushes in exactly the reference order, so seq assignment —
//!   and hence every later pop — is reproduced exactly. By induction a
//!   sharded run is bit-identical to the single-queue run; the
//!   differential property tests and the catalog fingerprint regression
//!   enforce this against the reference engine.
//! * **Lookahead-bounded windows.** The queue tracks conservative
//!   synchronization windows of width `lookahead` (the coupling bound:
//!   within a host, shards interact only through the PS uplink solve
//!   and the arbiter tick, so the sampling interval Δ bounds how far a
//!   shard may run ahead before it must observe cross-shard state).
//!   Cross-shard pushes — an event scheduled onto a different shard
//!   than the one whose event is being handled — are counted, and the
//!   epsilon-clamp policy of [`resolve_event_time`] turns any
//!   cross-shard event landing behind the local clock into a panic
//!   instead of a silent reorder. Window and cross-shard counters are
//!   reported on `RunResult` (excluded from fingerprints).
//!
//! Wall-clock wins come from heap locality: K heaps of N/K events make
//! every push/pop O(log(N/K)) with hotter cache lines, which is what
//! `scale_sweep` measures at 4096 tenants. Embarrassingly parallel
//! *fleet* work (hosts are RNG-independent since the fleet allocator
//! landed) can additionally use [`scoped_parallel_map`] for real
//! thread-level parallelism without touching the per-host determinism
//! story.

use std::collections::BinaryHeap;

use super::engine::{resolve_event_time, Entry, SimClock};

/// Which simulation clockwork a world runs on.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum EngineKind {
    /// The single-queue reference engine (the determinism oracle).
    SingleQueue,
    /// Sharded engine with `shards` per-shard queues and a
    /// deterministic merge. `Sharded { shards: 1 }` is a valid
    /// degenerate configuration (one shard plus the merge layer) and
    /// must also be bit-identical to the reference.
    Sharded { shards: usize },
}

impl Default for EngineKind {
    fn default() -> Self {
        EngineKind::SingleQueue
    }
}

/// Min-heap event queue sharded into per-shard heaps with a global
/// deterministic merge. See the module docs for the bit-identity
/// argument.
pub struct ShardedQueue<E> {
    heaps: Vec<BinaryHeap<Entry<E>>>,
    /// Global insertion sequence — spans all shards so the merged order
    /// is exactly the reference `EventQueue` order.
    seq: u64,
    now: f64,
    popped: u64,
    clamped: u64,
    pushed_per_shard: Vec<u64>,
    popped_per_shard: Vec<u64>,
    /// Shard whose event is currently being handled (set by `pop`).
    current_shard: Option<usize>,
    /// Pushes that crossed a shard boundary while handling an event.
    cross_shard: u64,
    /// Conservative-synchronization window accounting.
    lookahead: f64,
    window_end: f64,
    windows: u64,
}

impl<E> ShardedQueue<E> {
    /// `lookahead` is the coupling bound in sim-seconds (the world uses
    /// its sampling interval Δ — the shortest path by which one shard's
    /// state can influence another through the arbiter tick).
    pub fn new(shards: usize, lookahead: f64, capacity: usize) -> Self {
        assert!(shards >= 1, "shard count must be >= 1");
        assert!(
            lookahead.is_finite() && lookahead > 0.0,
            "lookahead must be finite and > 0, got {lookahead}"
        );
        let per = capacity / shards + 1;
        ShardedQueue {
            heaps: (0..shards).map(|_| BinaryHeap::with_capacity(per)).collect(),
            seq: 0,
            now: 0.0,
            popped: 0,
            clamped: 0,
            pushed_per_shard: vec![0; shards],
            popped_per_shard: vec![0; shards],
            current_shard: None,
            cross_shard: 0,
            lookahead,
            window_end: 0.0,
            windows: 0,
        }
    }

    pub fn shards(&self) -> usize {
        self.heaps.len()
    }

    /// Current simulated time (time of the last popped event).
    pub fn now(&self) -> SimClock {
        SimClock(self.now)
    }

    /// Schedule `event` on `shard` at absolute time `at`, under the same
    /// epsilon-clamp/panic policy as the reference queue. The seq
    /// counter is global: pushes interleave across shards exactly as
    /// they would into the single reference heap.
    pub fn push_to(&mut self, shard: usize, at: f64, event: E) {
        let t = resolve_event_time(at, self.now, &mut self.clamped);
        if let Some(cur) = self.current_shard {
            if cur != shard {
                self.cross_shard += 1;
            }
        }
        self.heaps[shard].push(Entry {
            time: t,
            seq: self.seq,
            event,
        });
        self.seq += 1;
        self.pushed_per_shard[shard] += 1;
    }

    /// Shard holding the globally minimal `(time, seq)` entry. The heap
    /// `Entry` ordering is a max-order on reversed keys, so the shard
    /// whose head is `max` by `Entry`'s `Ord` is the one with the
    /// earliest event.
    fn min_shard(&self) -> Option<usize> {
        let mut best: Option<(usize, &Entry<E>)> = None;
        for (s, h) in self.heaps.iter().enumerate() {
            if let Some(head) = h.peek() {
                best = match best {
                    Some((_, b)) if b >= head => best,
                    _ => Some((s, head)),
                };
            }
        }
        best.map(|(s, _)| s)
    }

    /// Pop the globally next event, advancing the clock and the window
    /// accounting. Returns `None` when every shard is drained.
    pub fn pop(&mut self) -> Option<(SimClock, E)> {
        let s = self.min_shard()?;
        let e = self.heaps[s].pop().expect("min_shard returned empty heap");
        debug_assert!(e.time >= self.now, "time went backwards");
        self.now = e.time;
        self.popped += 1;
        self.popped_per_shard[s] += 1;
        self.current_shard = Some(s);
        if e.time >= self.window_end {
            self.windows += 1;
            self.window_end = e.time + self.lookahead;
        }
        Some((SimClock(e.time), e.event))
    }

    /// Time of the next event without popping.
    pub fn peek_time(&self) -> Option<f64> {
        self.heaps
            .iter()
            .filter_map(|h| h.peek().map(|e| e.time))
            .min_by(|a, b| a.total_cmp(b))
    }

    pub fn len(&self) -> usize {
        self.heaps.iter().map(BinaryHeap::len).sum()
    }

    pub fn is_empty(&self) -> bool {
        self.heaps.iter().all(BinaryHeap::is_empty)
    }

    /// Total events dispatched across all shards.
    pub fn events_processed(&self) -> u64 {
        self.popped
    }

    /// Events clamped under the epsilon policy (see `EventQueue`).
    pub fn clamped_events(&self) -> u64 {
        self.clamped
    }

    /// Events dispatched per shard (perf/imbalance telemetry).
    pub fn per_shard_popped(&self) -> &[u64] {
        &self.popped_per_shard
    }

    /// Pushes that crossed a shard boundary (one shard's handler
    /// scheduling work for another shard — uplink rate changes, arbiter
    /// commits, fleet-level admission).
    pub fn cross_shard_events(&self) -> u64 {
        self.cross_shard
    }

    /// Conservative lookahead windows the run partitioned into.
    pub fn sync_windows(&self) -> u64 {
        self.windows
    }

    /// Shard whose event is currently being handled (`None` before the
    /// first pop) — the flight recorder reads this to count merge
    /// switches between consecutive dispatches.
    pub fn current_shard(&self) -> Option<usize> {
        self.current_shard
    }
}

/// Order-preserving parallel map over independent work items using
/// scoped OS threads (no external dependencies). Results come back in
/// input order, so deterministic pipelines stay deterministic; use only
/// for items with no shared mutable state (e.g. RNG-independent fleet
/// hosts, repeat seeds).
pub fn scoped_parallel_map<T, R, F>(items: Vec<T>, f: F) -> Vec<R>
where
    T: Send,
    R: Send,
    F: Fn(T) -> R + Sync,
{
    let mut out: Vec<Option<R>> = Vec::new();
    out.resize_with(items.len(), || None);
    std::thread::scope(|scope| {
        let mut handles = Vec::new();
        for (i, item) in items.into_iter().enumerate() {
            let f = &f;
            handles.push((i, scope.spawn(move || f(item))));
        }
        for (i, h) in handles {
            out[i] = Some(h.join().expect("parallel map worker panicked"));
        }
    });
    out.into_iter().map(|r| r.expect("slot unfilled")).collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim::engine::EventQueue;
    use crate::util::rng::Pcg64;

    /// Differential helper: replay a recorded push schedule against both
    /// engines and assert identical pops.
    fn assert_matches_reference(shards: usize, schedule: &[(usize, f64)]) {
        let mut reference: EventQueue<usize> = EventQueue::new();
        let mut sharded: ShardedQueue<usize> = ShardedQueue::new(shards, 1.0, 16);
        for (id, &(shard, at)) in schedule.iter().enumerate() {
            reference.push_at(at, id);
            sharded.push_to(shard % shards, at, id);
        }
        loop {
            let a = reference.pop();
            let b = sharded.pop();
            match (a, b) {
                (None, None) => break,
                (Some((ta, ea)), Some((tb, eb))) => {
                    assert_eq!(ta.secs().to_bits(), tb.secs().to_bits());
                    assert_eq!(ea, eb);
                }
                (a, b) => panic!("queue lengths diverged: {a:?} vs {b:?}"),
            }
        }
        assert_eq!(reference.events_processed(), sharded.events_processed());
    }

    #[test]
    fn merge_preserves_time_seq_order_across_shards() {
        let mut q = ShardedQueue::new(2, 1.0, 4);
        q.push_to(0, 1.0, "a0"); // seq 0
        q.push_to(1, 1.0, "b1"); // seq 1, same time: loses to seq 0
        q.push_to(1, 0.5, "b2"); // earlier time wins outright
        assert_eq!(q.pop().unwrap().1, "b2");
        assert_eq!(q.pop().unwrap().1, "a0");
        assert_eq!(q.pop().unwrap().1, "b1");
        assert!(q.pop().is_none());
    }

    #[test]
    fn matches_reference_on_random_schedules() {
        let mut rng = Pcg64::seeded(41);
        for case in 0..64 {
            let shards = [1, 2, 4, 7][case % 4];
            let n = 50 + (rng.below(200) as usize);
            let schedule: Vec<(usize, f64)> = (0..n)
                .map(|_| {
                    let shard = rng.below(16) as usize;
                    // Coarse times force plenty of exact ties.
                    let t = (rng.below(32) as f64) * 0.25;
                    (shard, t)
                })
                .collect();
            assert_matches_reference(shards, &schedule);
        }
    }

    #[test]
    fn interleaved_push_pop_matches_reference() {
        let mut rng = Pcg64::seeded(43);
        let mut reference: EventQueue<u64> = EventQueue::new();
        let mut sharded: ShardedQueue<u64> = ShardedQueue::new(3, 0.5, 8);
        let mut id = 0u64;
        for _ in 0..2000 {
            if rng.below(3) > 0 || reference.is_empty() {
                // Push relative to the current clock (as the world does).
                let dt = (rng.below(100) as f64) * 0.01;
                let at = reference.now().secs() + dt;
                reference.push_at(at, id);
                sharded.push_to((id % 3) as usize, at, id);
                id += 1;
            } else {
                let a = reference.pop().unwrap();
                let b = sharded.pop().unwrap();
                assert_eq!(a.0.secs().to_bits(), b.0.secs().to_bits());
                assert_eq!(a.1, b.1);
            }
        }
        while let Some(a) = reference.pop() {
            let b = sharded.pop().unwrap();
            assert_eq!(a.0.secs().to_bits(), b.0.secs().to_bits());
            assert_eq!(a.1, b.1);
        }
        assert!(sharded.pop().is_none());
    }

    #[test]
    fn counts_cross_shard_pushes() {
        let mut q = ShardedQueue::new(2, 1.0, 4);
        q.push_to(0, 1.0, 0u32);
        assert_eq!(q.cross_shard_events(), 0); // no event being handled yet
        q.pop();
        q.push_to(0, 2.0, 1u32); // same shard as current: local
        q.push_to(1, 2.0, 2u32); // different shard: cross
        assert_eq!(q.cross_shard_events(), 1);
    }

    #[test]
    fn windows_advance_by_lookahead() {
        let mut q = ShardedQueue::new(1, 1.0, 4);
        for t in [0.0, 0.5, 0.9, 1.5, 2.0, 3.9] {
            q.push_to(0, t, ());
        }
        while q.pop().is_some() {}
        // Windows open at 0.0 (covers 0.5, 0.9), 1.5 (covers 2.0), 3.9.
        assert_eq!(q.sync_windows(), 3);
    }

    #[test]
    fn per_shard_counters_account_for_every_event() {
        let mut q = ShardedQueue::new(4, 1.0, 16);
        for i in 0..100u32 {
            q.push_to((i % 4) as usize, i as f64 * 0.1, i);
        }
        while q.pop().is_some() {}
        assert_eq!(q.per_shard_popped().iter().sum::<u64>(), 100);
        assert_eq!(q.events_processed(), 100);
    }

    #[test]
    #[should_panic(expected = "in the past")]
    fn far_past_push_panics_like_reference() {
        let mut q = ShardedQueue::new(2, 1.0, 4);
        q.push_to(0, 10.0, ());
        q.pop();
        q.push_to(1, 3.0, ());
    }

    #[test]
    fn scoped_parallel_map_preserves_order() {
        let items: Vec<u64> = (0..32).collect();
        let out = scoped_parallel_map(items, |x| x * x);
        for (i, v) in out.iter().enumerate() {
            assert_eq!(*v, (i as u64) * (i as u64));
        }
    }
}
