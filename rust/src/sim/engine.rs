//! Event queue + simulated clock.
//!
//! * Deterministic: ties in time break by insertion sequence, so two runs
//!   with the same seed replay identically (the paper's "identical
//!   interference schedules across configurations", §3.2). This is also
//!   what makes the control plane's ticks reproducible: the world's
//!   `Sample` events fire in a stable order, so every controller —
//!   including the multi-primary arbiter's whole plane — sees the same
//!   snapshots in the same sequence for a fixed seed.
//! * Monotone: popping never returns a time earlier than the clock.

use std::cmp::Ordering;
use std::collections::BinaryHeap;

/// Tolerance for events scheduled in the (numerical) past. An event can
/// legitimately land a few ulps before the clock when its time is
/// re-derived through a different float expression (e.g. a fabric
/// completion recomputed after a rate change); anything further back is
/// a causality bug — under sharding, a cross-shard event landing before
/// the local clock means the lookahead window was violated — so
/// `push_at` clamps only within this epsilon and panics beyond it.
/// Clamps are counted (`clamped_events`) and surfaced on `RunResult`.
pub const PAST_EVENT_EPS_S: f64 = 1e-6;

/// Resolve a requested event time against the current clock under the
/// epsilon-clamp policy above. Shared by [`EventQueue`] and the sharded
/// queue in [`crate::sim::parallel`] so the two engines cannot drift.
#[inline]
pub(crate) fn resolve_event_time(at: f64, now: f64, clamped: &mut u64) -> f64 {
    assert!(at.is_finite(), "non-finite event time {at}");
    if at >= now {
        return at;
    }
    let lag = now - at;
    assert!(
        lag <= PAST_EVENT_EPS_S,
        "event scheduled {lag:.3e}s in the past (at={at}, now={now}): beyond \
         the {PAST_EVENT_EPS_S:.0e}s epsilon this is a causality/synchronization \
         bug, not a numerical hair"
    );
    *clamped += 1;
    now
}

/// Simulated time in seconds.
#[derive(Clone, Copy, Debug, Default, PartialEq, PartialOrd)]
pub struct SimClock(pub f64);

impl SimClock {
    pub fn secs(self) -> f64 {
        self.0
    }

    pub fn micros(self) -> u64 {
        (self.0 * 1e6).round() as u64
    }
}

pub(crate) struct Entry<E> {
    pub(crate) time: f64,
    pub(crate) seq: u64,
    pub(crate) event: E,
}

impl<E> PartialEq for Entry<E> {
    fn eq(&self, other: &Self) -> bool {
        self.time == other.time && self.seq == other.seq
    }
}
impl<E> Eq for Entry<E> {}

impl<E> Ord for Entry<E> {
    fn cmp(&self, other: &Self) -> Ordering {
        // Reverse for min-heap: earliest time first, then lowest seq.
        other
            .time
            .total_cmp(&self.time)
            .then_with(|| other.seq.cmp(&self.seq))
    }
}
impl<E> PartialOrd for Entry<E> {
    fn partial_cmp(&self, other: &Self) -> Option<Ordering> {
        Some(self.cmp(other))
    }
}

/// Min-heap event queue over event payloads `E`.
pub struct EventQueue<E> {
    heap: BinaryHeap<Entry<E>>,
    seq: u64,
    now: f64,
    popped: u64,
    clamped: u64,
}

impl<E> Default for EventQueue<E> {
    fn default() -> Self {
        Self::new()
    }
}

impl<E> EventQueue<E> {
    pub fn new() -> Self {
        Self::with_capacity(0)
    }

    /// Pre-size the heap (e.g. from a scenario's tenant count) so large
    /// worlds don't pay repeated regrow/copy churn while the event
    /// population ramps up early in a run.
    pub fn with_capacity(capacity: usize) -> Self {
        EventQueue {
            heap: BinaryHeap::with_capacity(capacity),
            seq: 0,
            now: 0.0,
            popped: 0,
            clamped: 0,
        }
    }

    /// Current simulated time (time of the last popped event).
    pub fn now(&self) -> SimClock {
        SimClock(self.now)
    }

    /// Schedule `event` at absolute time `at` (>= now). Times up to
    /// [`PAST_EVENT_EPS_S`] in the past are clamped to `now` and counted
    /// (`clamped_events`); anything older panics — a silently-clamped
    /// past event hides the causality bug that produced it. Non-finite
    /// times are rejected in release builds too: `f64::max(NaN, now)`
    /// would silently collapse to `now`, hiding the corruption.
    pub fn push_at(&mut self, at: f64, event: E) {
        let t = resolve_event_time(at, self.now, &mut self.clamped);
        self.heap.push(Entry {
            time: t,
            seq: self.seq,
            event,
        });
        self.seq += 1;
    }

    /// Schedule `event` after `dt` seconds. A NaN or negative delay is a
    /// logic bug in the caller (a NaN would poison the heap order via
    /// `total_cmp`, sorting above every real time), so it is rejected in
    /// release builds as well — not just under `debug_assert!`.
    pub fn push_after(&mut self, dt: f64, event: E) {
        assert!(
            dt.is_finite() && dt >= 0.0,
            "invalid event delay {dt} (must be finite and >= 0)"
        );
        self.push_at(self.now + dt, event);
    }

    /// Pop the next event, advancing the clock. Returns `None` when empty.
    pub fn pop(&mut self) -> Option<(SimClock, E)> {
        let e = self.heap.pop()?;
        debug_assert!(e.time >= self.now, "time went backwards");
        self.now = e.time;
        self.popped += 1;
        Some((SimClock(e.time), e.event))
    }

    /// Time of the next event without popping.
    pub fn peek_time(&self) -> Option<f64> {
        self.heap.peek().map(|e| e.time)
    }

    pub fn len(&self) -> usize {
        self.heap.len()
    }

    pub fn is_empty(&self) -> bool {
        self.heap.is_empty()
    }

    /// Total events dispatched (perf counter for the §Perf harness).
    pub fn events_processed(&self) -> u64 {
        self.popped
    }

    /// Events whose requested time fell within [`PAST_EVENT_EPS_S`] of
    /// the past and were clamped to `now`. Expected to be 0 in healthy
    /// runs; surfaced on `RunResult` so a drift shows up in telemetry
    /// before it becomes a panic.
    pub fn clamped_events(&self) -> u64 {
        self.clamped
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn pops_in_time_order() {
        let mut q = EventQueue::new();
        q.push_at(3.0, "c");
        q.push_at(1.0, "a");
        q.push_at(2.0, "b");
        assert_eq!(q.pop().unwrap().1, "a");
        assert_eq!(q.pop().unwrap().1, "b");
        assert_eq!(q.pop().unwrap().1, "c");
        assert!(q.pop().is_none());
    }

    #[test]
    fn ties_break_by_insertion_order() {
        let mut q = EventQueue::new();
        q.push_at(1.0, "first");
        q.push_at(1.0, "second");
        q.push_at(1.0, "third");
        assert_eq!(q.pop().unwrap().1, "first");
        assert_eq!(q.pop().unwrap().1, "second");
        assert_eq!(q.pop().unwrap().1, "third");
    }

    #[test]
    fn clock_advances_monotonically() {
        let mut q = EventQueue::new();
        q.push_at(5.0, 1u32);
        let (t, _) = q.pop().unwrap();
        assert_eq!(t.secs(), 5.0);
        q.push_after(2.5, 2u32);
        let (t2, _) = q.pop().unwrap();
        assert_eq!(t2.secs(), 7.5);
        assert_eq!(q.now().secs(), 7.5);
    }

    #[test]
    fn push_within_epsilon_of_past_clamps_and_counts() {
        let mut q = EventQueue::new();
        q.push_at(10.0, 1u32);
        q.pop();
        assert_eq!(q.clamped_events(), 0);
        // A numerical hair in the past: clamped to now, counted.
        q.push_at(10.0 - 1e-9, 2u32);
        let (t, _) = q.pop().unwrap();
        assert_eq!(t.secs(), 10.0);
        assert_eq!(q.clamped_events(), 1);
    }

    #[test]
    #[should_panic(expected = "in the past")]
    fn push_far_in_past_panics() {
        let mut q = EventQueue::new();
        q.push_at(10.0, 1u32);
        q.pop();
        // 7 seconds in the past is a causality bug, not float noise.
        q.push_at(3.0, 2u32);
    }

    #[test]
    fn push_exactly_at_now_is_not_a_clamp() {
        let mut q = EventQueue::new();
        q.push_at(5.0, 1u32);
        q.pop();
        q.push_at(5.0, 2u32);
        assert_eq!(q.clamped_events(), 0);
        let (t, _) = q.pop().unwrap();
        assert_eq!(t.secs(), 5.0);
    }

    #[test]
    #[should_panic(expected = "invalid event delay")]
    fn push_after_rejects_nan_delay() {
        let mut q = EventQueue::new();
        q.push_after(f64::NAN, 1u32);
    }

    #[test]
    #[should_panic(expected = "invalid event delay")]
    fn push_after_rejects_negative_delay() {
        let mut q = EventQueue::new();
        q.push_after(-0.5, 1u32);
    }

    #[test]
    #[should_panic(expected = "non-finite event time")]
    fn push_at_rejects_nan_time() {
        let mut q = EventQueue::new();
        q.push_at(f64::NAN, 1u32);
    }

    #[test]
    fn with_capacity_behaves_like_new() {
        let mut q = EventQueue::with_capacity(128);
        assert!(q.is_empty());
        q.push_at(2.0, "b");
        q.push_at(1.0, "a");
        assert_eq!(q.len(), 2);
        assert_eq!(q.pop().unwrap().1, "a");
        assert_eq!(q.pop().unwrap().1, "b");
    }

    #[test]
    fn stress_many_events_ordered() {
        use crate::util::rng::Pcg64;
        let mut rng = Pcg64::seeded(13);
        let mut q = EventQueue::new();
        for i in 0..10_000u32 {
            q.push_at(rng.f64() * 100.0, i);
        }
        let mut last = 0.0;
        while let Some((t, _)) = q.pop() {
            assert!(t.secs() >= last);
            last = t.secs();
        }
        assert_eq!(q.events_processed(), 10_000);
    }
}
