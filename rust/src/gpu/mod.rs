//! NVIDIA A100 + MIG (Multi-Instance GPU) geometry model.
//!
//! Dynamic MIG reconfiguration is the controller's strongest lever (§2.2),
//! so the legality rules it plans against must match the real device:
//! profile sizes, slice placement constraints, and the ~18 s
//! reconfiguration cost (Table 4) are all modeled here.

pub mod mig;
pub mod a100;

pub use a100::{A100Gpu, InstanceId};
pub use mig::MigProfile;
