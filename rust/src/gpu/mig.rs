//! MIG profiles for the A100-80GB.
//!
//! An A100 exposes 7 compute slices (GPCs) and 8 memory slices (10 GB
//! each). Profiles combine `Ng` compute slices with `M` GB of HBM; the
//! hardware only allows instances to start at particular slice offsets
//! (the "profile placement" rules from `nvidia-smi mig -lgipp`).

/// A100-80GB MIG profile set.
#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub enum MigProfile {
    /// `1g.10gb` — 1 compute slice, 10 GB.
    P1g10gb,
    /// `2g.20gb` — 2 compute slices, 20 GB.
    P2g20gb,
    /// `3g.40gb` — 3 compute slices, 40 GB.
    P3g40gb,
    /// `4g.40gb` — 4 compute slices, 40 GB.
    P4g40gb,
    /// `7g.80gb` — the whole GPU.
    P7g80gb,
}

impl MigProfile {
    pub const ALL: [MigProfile; 5] = [
        MigProfile::P1g10gb,
        MigProfile::P2g20gb,
        MigProfile::P3g40gb,
        MigProfile::P4g40gb,
        MigProfile::P7g80gb,
    ];

    /// Compute slices (GPCs) the profile occupies.
    pub fn compute_slices(self) -> usize {
        match self {
            MigProfile::P1g10gb => 1,
            MigProfile::P2g20gb => 2,
            MigProfile::P3g40gb => 3,
            MigProfile::P4g40gb => 4,
            MigProfile::P7g80gb => 7,
        }
    }

    /// HBM capacity in GB.
    pub fn memory_gb(self) -> usize {
        match self {
            MigProfile::P1g10gb => 10,
            MigProfile::P2g20gb => 20,
            MigProfile::P3g40gb => 40,
            MigProfile::P4g40gb => 40,
            MigProfile::P7g80gb => 80,
        }
    }

    /// Legal start offsets on the 7-slice compute die (A100 placement
    /// rules: 1g at any of 0..=6; 2g at even offsets 0/2/4; 3g at 0 or 4;
    /// 4g only at 0; 7g only at 0).
    pub fn legal_starts(self) -> &'static [usize] {
        match self {
            MigProfile::P1g10gb => &[0, 1, 2, 3, 4, 5, 6],
            MigProfile::P2g20gb => &[0, 2, 4],
            MigProfile::P3g40gb => &[0, 4],
            MigProfile::P4g40gb => &[0],
            MigProfile::P7g80gb => &[0],
        }
    }

    /// Effective service-rate multiplier μ(m) relative to 1g (§2.5.2:
    /// "μ(m) ∝ SM cores and memory in profile m"). Compute slices dominate
    /// for the inference tenant; the memory term gives 4g a small edge
    /// over 3g+extra-HBM workloads.
    pub fn mu(self) -> f64 {
        let c = self.compute_slices() as f64;
        let m = self.memory_gb() as f64 / 10.0;
        0.75 * c + 0.25 * m
    }

    /// Next-larger profile in the isolation-upgrade chain, if any.
    pub fn upgrade(self) -> Option<MigProfile> {
        match self {
            MigProfile::P1g10gb => Some(MigProfile::P2g20gb),
            MigProfile::P2g20gb => Some(MigProfile::P3g40gb),
            MigProfile::P3g40gb => Some(MigProfile::P4g40gb),
            MigProfile::P4g40gb => Some(MigProfile::P7g80gb),
            MigProfile::P7g80gb => None,
        }
    }

    /// Next-smaller profile (isolation relaxation), if any.
    pub fn relax(self) -> Option<MigProfile> {
        match self {
            MigProfile::P1g10gb => None,
            MigProfile::P2g20gb => Some(MigProfile::P1g10gb),
            MigProfile::P3g40gb => Some(MigProfile::P2g20gb),
            MigProfile::P4g40gb => Some(MigProfile::P3g40gb),
            MigProfile::P7g80gb => Some(MigProfile::P4g40gb),
        }
    }

    pub fn name(self) -> &'static str {
        match self {
            MigProfile::P1g10gb => "1g.10gb",
            MigProfile::P2g20gb => "2g.20gb",
            MigProfile::P3g40gb => "3g.40gb",
            MigProfile::P4g40gb => "4g.40gb",
            MigProfile::P7g80gb => "7g.80gb",
        }
    }

    /// Inverse of [`MigProfile::name`] (cluster wire protocol).
    pub fn from_name(name: &str) -> Option<MigProfile> {
        MigProfile::ALL.into_iter().find(|p| p.name() == name)
    }
}

impl std::fmt::Display for MigProfile {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(self.name())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn upgrade_chain_is_finite_and_monotone() {
        // §2.5.2: at most |M|-1 upgrades, each strictly increasing μ.
        let mut p = MigProfile::P1g10gb;
        let mut steps = 0;
        while let Some(next) = p.upgrade() {
            assert!(next.mu() > p.mu(), "{next:?} not stronger than {p:?}");
            assert!(next.compute_slices() >= p.compute_slices());
            p = next;
            steps += 1;
        }
        assert_eq!(steps, MigProfile::ALL.len() - 1);
        assert_eq!(p, MigProfile::P7g80gb);
    }

    #[test]
    fn relax_is_inverse_of_upgrade() {
        for p in MigProfile::ALL {
            if let Some(u) = p.upgrade() {
                assert_eq!(u.relax(), Some(p));
            }
        }
    }

    #[test]
    fn legal_starts_fit_on_die() {
        for p in MigProfile::ALL {
            for &s in p.legal_starts() {
                assert!(
                    s + p.compute_slices() <= 7,
                    "{p:?} at {s} exceeds 7 slices"
                );
            }
        }
    }

    #[test]
    fn from_name_roundtrips_every_profile() {
        for p in MigProfile::ALL {
            assert_eq!(MigProfile::from_name(p.name()), Some(p));
        }
        assert_eq!(MigProfile::from_name("8g.96gb"), None);
    }

    #[test]
    fn mu_reflects_paper_ordering() {
        // Bigger profile => strictly larger service rate.
        let mus: Vec<f64> = MigProfile::ALL.iter().map(|p| p.mu()).collect();
        for w in mus.windows(2) {
            assert!(w[1] > w[0]);
        }
    }
}
