//! Per-GPU MIG state: instance table, slice occupancy, reconfiguration.
//!
//! The controller plans against exactly what `nvidia-smi mig` would allow:
//! instances occupy contiguous compute slices at legal start offsets,
//! never overlap, and reconfiguration takes a real-time cost (paper
//! Table 4: 18 ± 6 s on A100; we sample that distribution).

use super::mig::MigProfile;
use crate::util::rng::Pcg64;

/// Identifies a MIG instance on its GPU (stable across unrelated
/// create/destroy on other slices).
#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub struct InstanceId(pub u64);

/// A live MIG instance.
#[derive(Clone, Debug)]
pub struct MigInstance {
    pub id: InstanceId,
    pub profile: MigProfile,
    /// First compute slice occupied.
    pub start: usize,
}

impl MigInstance {
    pub fn slices(&self) -> std::ops::Range<usize> {
        self.start..self.start + self.profile.compute_slices()
    }
}

/// MIG state machine for one A100-80GB.
#[derive(Clone, Debug)]
pub struct A100Gpu {
    pub index: usize,
    instances: Vec<MigInstance>,
    next_id: u64,
}

/// Errors from MIG operations (mirror of `nvidia-smi mig` failures).
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum MigError {
    IllegalStart { profile: MigProfile, start: usize },
    Overlap { start: usize },
    NoSuchInstance(InstanceId),
    NoHeadroom { profile: MigProfile },
}

impl std::fmt::Display for MigError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            MigError::IllegalStart { profile, start } => {
                write!(f, "profile {profile} cannot start at slice {start}")
            }
            MigError::Overlap { start } => write!(f, "slices at {start} already occupied"),
            MigError::NoSuchInstance(id) => write!(f, "no MIG instance {id:?}"),
            MigError::NoHeadroom { profile } => {
                write!(f, "no placement available for {profile}")
            }
        }
    }
}

impl std::error::Error for MigError {}

impl A100Gpu {
    pub fn new(index: usize) -> A100Gpu {
        A100Gpu {
            index,
            instances: Vec::new(),
            next_id: 1,
        }
    }

    pub fn instances(&self) -> &[MigInstance] {
        &self.instances
    }

    pub fn instance(&self, id: InstanceId) -> Option<&MigInstance> {
        self.instances.iter().find(|i| i.id == id)
    }

    /// Occupied compute-slice bitmap.
    fn occupancy(&self) -> [bool; 7] {
        let mut occ = [false; 7];
        for inst in &self.instances {
            for s in inst.slices() {
                occ[s] = true;
            }
        }
        occ
    }

    /// Compute slices still free.
    pub fn free_slices(&self) -> usize {
        self.occupancy().iter().filter(|&&o| !o).count()
    }

    fn fits_at(&self, profile: MigProfile, start: usize) -> bool {
        let occ = self.occupancy();
        (start..start + profile.compute_slices()).all(|s| s < 7 && !occ[s])
    }

    /// All legal placements currently available for `profile`.
    pub fn placements(&self, profile: MigProfile) -> Vec<usize> {
        profile
            .legal_starts()
            .iter()
            .copied()
            .filter(|&s| self.fits_at(profile, s))
            .collect()
    }

    /// Create an instance at an explicit start offset.
    pub fn create_at(&mut self, profile: MigProfile, start: usize) -> Result<InstanceId, MigError> {
        if !profile.legal_starts().contains(&start) {
            return Err(MigError::IllegalStart { profile, start });
        }
        if !self.fits_at(profile, start) {
            return Err(MigError::Overlap { start });
        }
        let id = InstanceId(self.next_id);
        self.next_id += 1;
        self.instances.push(MigInstance { id, profile, start });
        Ok(id)
    }

    /// Create an instance at the first legal placement.
    pub fn create(&mut self, profile: MigProfile) -> Result<InstanceId, MigError> {
        let start = *self
            .placements(profile)
            .first()
            .ok_or(MigError::NoHeadroom { profile })?;
        self.create_at(profile, start)
    }

    /// Destroy an instance, freeing its slices.
    pub fn destroy(&mut self, id: InstanceId) -> Result<MigInstance, MigError> {
        let idx = self
            .instances
            .iter()
            .position(|i| i.id == id)
            .ok_or(MigError::NoSuchInstance(id))?;
        Ok(self.instances.remove(idx))
    }

    /// Can `profile` be placed right now (possibly after destroying `freed`,
    /// which the reconfig planner is about to remove)?
    pub fn can_place_after_destroy(&self, profile: MigProfile, freed: InstanceId) -> bool {
        let mut ghost = self.clone();
        if ghost.destroy(freed).is_err() {
            return false;
        }
        !ghost.placements(profile).is_empty()
    }

    /// Sample a reconfiguration duration in seconds — Table 4: 18 ± 6 s
    /// (clamped to stay positive and under the paper's ≤ 30 s bound §2).
    pub fn reconfig_duration(rng: &mut Pcg64) -> f64 {
        rng.normal_ms(18.0, 3.0).clamp(6.0, 30.0)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn create_and_destroy_roundtrip() {
        let mut g = A100Gpu::new(0);
        let id = g.create(MigProfile::P3g40gb).unwrap();
        assert_eq!(g.free_slices(), 4);
        let inst = g.destroy(id).unwrap();
        assert_eq!(inst.profile, MigProfile::P3g40gb);
        assert_eq!(g.free_slices(), 7);
    }

    #[test]
    fn overlap_rejected() {
        let mut g = A100Gpu::new(0);
        g.create_at(MigProfile::P4g40gb, 0).unwrap();
        assert_eq!(
            g.create_at(MigProfile::P2g20gb, 2),
            Err(MigError::Overlap { start: 2 })
        );
        // 3g at 4 still fits.
        assert!(g.create_at(MigProfile::P3g40gb, 4).is_ok());
        assert_eq!(g.free_slices(), 0);
    }

    #[test]
    fn illegal_start_rejected() {
        let mut g = A100Gpu::new(0);
        assert_eq!(
            g.create_at(MigProfile::P2g20gb, 1),
            Err(MigError::IllegalStart {
                profile: MigProfile::P2g20gb,
                start: 1
            })
        );
    }

    #[test]
    fn classic_mixed_partition() {
        // The paper's static baseline: 3g.40gb (T1) + 2g.20gb + 2g.20gb.
        let mut g = A100Gpu::new(0);
        g.create_at(MigProfile::P3g40gb, 0).unwrap();
        g.create_at(MigProfile::P2g20gb, 4).unwrap();
        // Slices 3 and 6 free; 2g can't legally start at either, 1g can.
        assert!(g.placements(MigProfile::P2g20gb).is_empty());
        assert_eq!(g.placements(MigProfile::P1g10gb), vec![3, 6]);
    }

    #[test]
    fn seven_singles_fill_gpu() {
        let mut g = A100Gpu::new(0);
        for _ in 0..7 {
            g.create(MigProfile::P1g10gb).unwrap();
        }
        assert_eq!(g.free_slices(), 0);
        assert!(matches!(
            g.create(MigProfile::P1g10gb),
            Err(MigError::NoHeadroom { .. })
        ));
    }

    #[test]
    fn reconfig_duration_within_paper_bounds() {
        let mut rng = Pcg64::seeded(9);
        for _ in 0..1000 {
            let d = A100Gpu::reconfig_duration(&mut rng);
            assert!((6.0..=30.0).contains(&d));
        }
    }

    #[test]
    fn can_place_after_destroy_ghost() {
        let mut g = A100Gpu::new(0);
        let t1 = g.create_at(MigProfile::P3g40gb, 0).unwrap();
        g.create_at(MigProfile::P3g40gb, 4).unwrap();
        // 4g fits only if we free the slice-0 instance first.
        assert!(g.placements(MigProfile::P4g40gb).is_empty());
        assert!(g.can_place_after_destroy(MigProfile::P4g40gb, t1));
    }
}
