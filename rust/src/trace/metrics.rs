//! Named monotonic counters and gauges any module can register into.
//!
//! The registry is the flight recorder's whole-run aggregate side:
//! emit sites bump counters ("ctl.decisions", "fabric.flow_completions"),
//! the world folds engine counters in at finish, and the sorted snapshot
//! lands in `RunResult::metrics` — deterministic (BTreeMap order, no
//! wall-clock inputs) but excluded from `fingerprint()` like the shard
//! counters, so observability can grow without invalidating pinned
//! regression fingerprints.

use std::collections::BTreeMap;

/// A registry of named monotonic counters (u64, `inc`) and gauges
/// (f64, last-write-wins `gauge`). Names are free-form dotted paths;
/// keys are interned on first use, so steady-state increments never
/// allocate.
#[derive(Clone, Debug, Default)]
pub struct MetricsRegistry {
    counters: BTreeMap<String, u64>,
    gauges: BTreeMap<String, f64>,
    /// Ring-buffer overwrites: events the recorder dropped (oldest
    /// first) because the preallocated ring was full.
    dropped: u64,
}

impl MetricsRegistry {
    pub fn new() -> MetricsRegistry {
        MetricsRegistry::default()
    }

    /// Bump a monotonic counter by `by`, creating it at 0 on first use.
    pub fn inc(&mut self, name: &str, by: u64) {
        if let Some(c) = self.counters.get_mut(name) {
            *c += by;
        } else {
            self.counters.insert(name.to_string(), by);
        }
    }

    /// Set a gauge (last write wins).
    pub fn gauge(&mut self, name: &str, value: f64) {
        if let Some(g) = self.gauges.get_mut(name) {
            *g = value;
        } else {
            self.gauges.insert(name.to_string(), value);
        }
    }

    /// Current value of a counter (0 if never bumped).
    pub fn counter(&self, name: &str) -> u64 {
        self.counters.get(name).copied().unwrap_or(0)
    }

    /// Current value of a gauge, if set.
    pub fn gauge_value(&self, name: &str) -> Option<f64> {
        self.gauges.get(name).copied()
    }

    /// Events the ring buffer dropped (overwrote) at capacity.
    pub fn dropped_events(&self) -> u64 {
        self.dropped
    }

    /// Record `n` ring-buffer drops (called by the recorder only).
    pub(crate) fn note_dropped(&mut self, n: u64) {
        self.dropped += n;
    }

    /// Sorted `(name, value)` snapshot: counters and gauges merged, plus
    /// `trace.dropped_events`. Counters are widened to f64 (every value
    /// a run produces is far below 2^53, so the widening is exact).
    pub fn snapshot(&self) -> Vec<(String, f64)> {
        let mut out: BTreeMap<String, f64> = BTreeMap::new();
        for (k, v) in &self.counters {
            out.insert(k.clone(), *v as f64);
        }
        for (k, v) in &self.gauges {
            out.insert(k.clone(), *v);
        }
        out.insert("trace.dropped_events".to_string(), self.dropped as f64);
        out.into_iter().collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counters_are_monotonic_and_gauges_overwrite() {
        let mut m = MetricsRegistry::new();
        m.inc("a.count", 2);
        m.inc("a.count", 3);
        m.gauge("b.level", 1.5);
        m.gauge("b.level", 0.5);
        assert_eq!(m.counter("a.count"), 5);
        assert_eq!(m.gauge_value("b.level"), Some(0.5));
        assert_eq!(m.counter("never"), 0);
        assert_eq!(m.gauge_value("never"), None);
    }

    #[test]
    fn snapshot_is_sorted_and_includes_drop_counter() {
        let mut m = MetricsRegistry::new();
        m.inc("z.last", 1);
        m.gauge("a.first", 2.0);
        m.note_dropped(7);
        let snap = m.snapshot();
        let names: Vec<&str> = snap.iter().map(|(k, _)| k.as_str()).collect();
        assert_eq!(names, vec!["a.first", "trace.dropped_events", "z.last"]);
        assert_eq!(snap[1].1, 7.0);
        assert_eq!(m.dropped_events(), 7);
    }
}
