//! Trace export: JSONL streaming and the Chrome trace-event format.
//!
//! [`chrome_trace`] renders recorded events into the Trace Event Format
//! that `chrome://tracing` and Perfetto load: one JSON object with a
//! `traceEvents` array of `ph` B/E (span), `i` (instant), `C` (counter),
//! and `M` (metadata) records, timestamps in microseconds. Lane layout:
//! one pid per host, one tid per tenant / controller / shard plus fixed
//! lanes for the host, arbiter, engine, and fabric. Span integrity is
//! enforced structurally — orphan end-edges (their begin overwritten by
//! the ring) are skipped and spans still open at the end of the event
//! stream are closed at the final timestamp — so `scripts/trace_lint.py`
//! can require matched B/E pairs and per-tid monotonic timestamps.
//!
//! [`jsonl`] is the streaming form: one self-describing JSON object per
//! line per event, in emit order, for ad-hoc tooling.

use std::collections::BTreeMap;

use crate::util::json::Json;

use super::{CtlPhase, TraceEvent};

/// The single simulated host.
const PID: f64 = 1.0;

/// Fixed lanes.
const TID_HOST: u32 = 1;
const TID_ARBITER: u32 = 2;
const TID_ENGINE: u32 = 3;
const TID_FABRIC: u32 = 4;
/// Lane bases: tenant signal lanes, controller lanes, shard lanes.
const TID_TENANT_BASE: u32 = 100;
const TID_CTL_BASE: u32 = 1100;
const TID_SHARD_BASE: u32 = 2100;

pub fn tenant_tid(tenant: u32) -> u32 {
    TID_TENANT_BASE + tenant
}

pub fn controller_tid(tenant: u32) -> u32 {
    TID_CTL_BASE + tenant
}

pub fn shard_tid(shard: u32) -> u32 {
    TID_SHARD_BASE + shard
}

fn record(name: Json, ph: &str, ts: f64, tid: u32, cat: &str, args: Json) -> Json {
    let mut o: BTreeMap<String, Json> = BTreeMap::new();
    o.insert("name".to_string(), name);
    o.insert("ph".to_string(), Json::Str(ph.to_string()));
    o.insert("ts".to_string(), Json::Num(ts));
    o.insert("pid".to_string(), Json::Num(PID));
    o.insert("tid".to_string(), Json::Num(tid as f64));
    o.insert("cat".to_string(), Json::Str(cat.to_string()));
    if args != Json::Null {
        o.insert("args".to_string(), args);
    }
    if ph == "i" {
        // Instant scope: thread.
        o.insert("s".to_string(), Json::Str("t".to_string()));
    }
    Json::Obj(o)
}

fn counter(name: &str, ts: f64, tid: u32, args: Json) -> Json {
    record(Json::Str(name.to_string()), "C", ts, tid, "counter", args)
}

fn micros(t: f64) -> f64 {
    (t * 1e6).round()
}

fn thread_meta(tid: u32, label: String) -> Json {
    let mut o: BTreeMap<String, Json> = BTreeMap::new();
    o.insert("name".to_string(), Json::Str("thread_name".to_string()));
    o.insert("ph".to_string(), Json::Str("M".to_string()));
    o.insert("pid".to_string(), Json::Num(PID));
    o.insert("tid".to_string(), Json::Num(tid as f64));
    o.insert(
        "args".to_string(),
        Json::obj(vec![("name", Json::Str(label))]),
    );
    Json::Obj(o)
}

/// Render recorded events as a Chrome trace-event document.
/// `tenant_names` labels the tenant/controller lanes (index = tenant);
/// missing names fall back to `tenant{i}`. `horizon_s` closes any span
/// still open when the recording stopped.
pub fn chrome_trace(events: &[(f64, TraceEvent)], tenant_names: &[String], horizon_s: f64) -> Json {
    let mut body: Vec<Json> = Vec::new();
    // tid → human lane label, for the metadata prelude.
    let mut lanes: BTreeMap<u32, String> = BTreeMap::new();
    lanes.insert(TID_HOST, "host".to_string());
    // tid → stack of open span names (B/E integrity bookkeeping).
    let mut open: BTreeMap<u32, Vec<&'static str>> = BTreeMap::new();
    let mut last_ts = 0.0f64;

    let tenant_label = |t: u32| -> String {
        tenant_names
            .get(t as usize)
            .cloned()
            .unwrap_or_else(|| format!("tenant{t}"))
    };

    for &(t, ev) in events {
        let ts = micros(t);
        last_ts = last_ts.max(ts);
        match ev {
            TraceEvent::TenantSignal {
                tenant,
                p99_ms,
                miss_rate,
                gbps,
                completed,
            } => {
                let tid = tenant_tid(tenant);
                lanes.entry(tid).or_insert_with(|| tenant_label(tenant));
                body.push(counter(
                    "p99_ms",
                    ts,
                    tid,
                    Json::obj(vec![("value", Json::Num(p99_ms))]),
                ));
                body.push(counter(
                    "miss_rate",
                    ts,
                    tid,
                    Json::obj(vec![("value", Json::Num(miss_rate))]),
                ));
                body.push(counter(
                    "io_gbps",
                    ts,
                    tid,
                    Json::obj(vec![
                        ("value", Json::Num(gbps)),
                        ("completed", Json::Num(completed as f64)),
                    ]),
                ));
            }
            TraceEvent::LinkSignal {
                link,
                gbps,
                utilization,
            } => {
                lanes.entry(TID_FABRIC).or_insert_with(|| "fabric".to_string());
                body.push(counter(
                    &format!("link{link}"),
                    ts,
                    TID_FABRIC,
                    Json::obj(vec![
                        ("gbps", Json::Num(gbps)),
                        ("util", Json::Num(utilization)),
                    ]),
                ));
            }
            TraceEvent::SmUtil { util } => {
                body.push(counter(
                    "sm_util",
                    ts,
                    TID_HOST,
                    Json::obj(vec![("value", Json::Num(util))]),
                ));
            }
            TraceEvent::Decision {
                tenant,
                kind,
                edge,
                p99_ms,
            } => {
                let tid = controller_tid(tenant);
                lanes
                    .entry(tid)
                    .or_insert_with(|| format!("ctl:{}", tenant_label(tenant)));
                body.push(record(
                    Json::Str(kind.as_str().to_string()),
                    "i",
                    ts,
                    tid,
                    "decision",
                    Json::obj(vec![
                        ("edge", Json::Str(edge.as_str().to_string())),
                        ("p99_ms", Json::Num(p99_ms)),
                    ]),
                ));
            }
            TraceEvent::CtlSpan {
                tenant,
                phase,
                begin,
            } => {
                let tid = controller_tid(tenant);
                lanes
                    .entry(tid)
                    .or_insert_with(|| format!("ctl:{}", tenant_label(tenant)));
                push_span_edge(&mut body, &mut open, tid, phase.as_str(), "ctl", ts, begin);
            }
            TraceEvent::Guardrail {
                target,
                kind,
                engaged,
            } => {
                let tid = controller_tid(target);
                lanes
                    .entry(tid)
                    .or_insert_with(|| format!("ctl:{}", tenant_label(target)));
                body.push(record(
                    Json::Str(format!(
                        "{}:{}",
                        kind.as_str(),
                        if engaged { "own" } else { "loosen" }
                    )),
                    "i",
                    ts,
                    tid,
                    "guardrail",
                    Json::obj(vec![("engaged", Json::Bool(engaged))]),
                ));
            }
            TraceEvent::ArbCounters {
                conflicts,
                deferrals,
            } => {
                lanes
                    .entry(TID_ARBITER)
                    .or_insert_with(|| "arbiter".to_string());
                body.push(counter(
                    "arbitration",
                    ts,
                    TID_ARBITER,
                    Json::obj(vec![
                        ("conflicts", Json::Num(conflicts as f64)),
                        ("deferrals", Json::Num(deferrals as f64)),
                    ]),
                ));
            }
            TraceEvent::FabricSolves { recomputes } => {
                lanes.entry(TID_FABRIC).or_insert_with(|| "fabric".to_string());
                body.push(counter(
                    "rate_recomputes",
                    ts,
                    TID_FABRIC,
                    Json::obj(vec![("value", Json::Num(recomputes as f64))]),
                ));
            }
            TraceEvent::FlowsDone { flows } => {
                lanes.entry(TID_FABRIC).or_insert_with(|| "fabric".to_string());
                body.push(record(
                    Json::Str("flows_done".to_string()),
                    "i",
                    ts,
                    TID_FABRIC,
                    "fabric",
                    Json::obj(vec![("flows", Json::Num(flows as f64))]),
                ));
            }
            TraceEvent::ShardWindow {
                shard,
                events: n,
                begin,
            } => {
                let tid = shard_tid(shard);
                lanes.entry(tid).or_insert_with(|| format!("shard{shard}"));
                if begin {
                    push_span_edge(&mut body, &mut open, tid, "window", "engine", ts, true);
                } else if pop_span(&mut open, tid, "window") {
                    body.push(record(
                        Json::Str("window".to_string()),
                        "E",
                        ts,
                        tid,
                        "engine",
                        Json::obj(vec![("events", Json::Num(n as f64))]),
                    ));
                }
            }
            TraceEvent::CrossShard { total } => {
                lanes.entry(TID_ENGINE).or_insert_with(|| "engine".to_string());
                body.push(counter(
                    "cross_shard",
                    ts,
                    TID_ENGINE,
                    Json::obj(vec![("value", Json::Num(total as f64))]),
                ));
            }
            TraceEvent::FaultInjected { kind, subject }
            | TraceEvent::FaultCleared { kind, subject } => {
                let cleared = matches!(ev, TraceEvent::FaultCleared { .. });
                body.push(record(
                    Json::Str(format!(
                        "fault{}:{kind}",
                        if cleared { "_cleared" } else { "" }
                    )),
                    "i",
                    ts,
                    TID_HOST,
                    "fault",
                    Json::obj(vec![
                        ("kind", Json::Num(kind as f64)),
                        ("subject", Json::Num(subject as f64)),
                        ("cleared", Json::Bool(cleared)),
                    ]),
                ));
            }
            TraceEvent::ActionRetry {
                tenant,
                attempt,
                kind,
            } => {
                let tid = controller_tid(tenant);
                lanes
                    .entry(tid)
                    .or_insert_with(|| format!("ctl:{}", tenant_label(tenant)));
                body.push(record(
                    Json::Str(format!("retry:{}", kind.as_str())),
                    "i",
                    ts,
                    tid,
                    "fault",
                    Json::obj(vec![("attempt", Json::Num(attempt as f64))]),
                ));
            }
            TraceEvent::Collective {
                tenant,
                round,
                begin,
            } => {
                let tid = tenant_tid(tenant);
                lanes.entry(tid).or_insert_with(|| tenant_label(tenant));
                if begin {
                    open.entry(tid).or_default().push("allreduce");
                    body.push(record(
                        Json::Str("allreduce".to_string()),
                        "B",
                        ts,
                        tid,
                        "collective",
                        Json::obj(vec![("round", Json::Num(round as f64))]),
                    ));
                } else if pop_span(&mut open, tid, "allreduce") {
                    body.push(record(
                        Json::Str("allreduce".to_string()),
                        "E",
                        ts,
                        tid,
                        "collective",
                        Json::obj(vec![("round", Json::Num(round as f64))]),
                    ));
                }
            }
            TraceEvent::NetLinkSignal {
                link,
                gbps,
                utilization,
            } => {
                lanes.entry(TID_FABRIC).or_insert_with(|| "fabric".to_string());
                body.push(counter(
                    &format!("netlink{link}"),
                    ts,
                    TID_FABRIC,
                    Json::obj(vec![
                        ("gbps", Json::Num(gbps)),
                        ("util", Json::Num(utilization)),
                    ]),
                ));
            }
        }
    }

    // Close spans the recording left open (run ended mid-window).
    let end_ts = last_ts.max(micros(horizon_s));
    for (tid, stack) in &mut open {
        while let Some(name) = stack.pop() {
            let cat = if *tid >= TID_SHARD_BASE { "engine" } else { "ctl" };
            body.push(record(
                Json::Str(name.to_string()),
                "E",
                end_ts,
                *tid,
                cat,
                Json::Null,
            ));
        }
    }

    let mut all: Vec<Json> = Vec::with_capacity(body.len() + lanes.len());
    for (tid, label) in lanes {
        all.push(thread_meta(tid, label));
    }
    all.extend(body);
    Json::obj(vec![
        ("traceEvents", Json::Arr(all)),
        ("displayTimeUnit", Json::Str("ms".to_string())),
    ])
}

fn push_span_edge(
    body: &mut Vec<Json>,
    open: &mut BTreeMap<u32, Vec<&'static str>>,
    tid: u32,
    name: &'static str,
    cat: &str,
    ts: f64,
    begin: bool,
) {
    if begin {
        open.entry(tid).or_default().push(name);
        body.push(record(
            Json::Str(name.to_string()),
            "B",
            ts,
            tid,
            cat,
            Json::Null,
        ));
    } else if pop_span(open, tid, name) {
        body.push(record(
            Json::Str(name.to_string()),
            "E",
            ts,
            tid,
            cat,
            Json::Null,
        ));
    }
}

/// Pop a matching open span; `false` (skip the end edge) when the begin
/// edge was overwritten by the ring.
fn pop_span(open: &mut BTreeMap<u32, Vec<&'static str>>, tid: u32, name: &str) -> bool {
    match open.get_mut(&tid) {
        Some(stack) if stack.last() == Some(&name) => {
            stack.pop();
            true
        }
        _ => false,
    }
}

/// One self-describing JSON object per event per line, in emit order.
pub fn jsonl(events: &[(f64, TraceEvent)]) -> String {
    let mut out = String::new();
    for &(t, ev) in events {
        out.push_str(&event_json(t, ev).to_string());
        out.push('\n');
    }
    out
}

fn event_json(t: f64, ev: TraceEvent) -> Json {
    let base = |kind: &str, mut fields: Vec<(&str, Json)>| -> Json {
        let mut pairs = vec![
            ("t", Json::Num(t)),
            ("event", Json::Str(kind.to_string())),
        ];
        pairs.append(&mut fields);
        Json::obj(pairs)
    };
    match ev {
        TraceEvent::TenantSignal {
            tenant,
            p99_ms,
            miss_rate,
            gbps,
            completed,
        } => base(
            "tenant_signal",
            vec![
                ("tenant", Json::Num(tenant as f64)),
                ("p99_ms", Json::Num(p99_ms)),
                ("miss_rate", Json::Num(miss_rate)),
                ("gbps", Json::Num(gbps)),
                ("completed", Json::Num(completed as f64)),
            ],
        ),
        TraceEvent::LinkSignal {
            link,
            gbps,
            utilization,
        } => base(
            "link_signal",
            vec![
                ("link", Json::Num(link as f64)),
                ("gbps", Json::Num(gbps)),
                ("util", Json::Num(utilization)),
            ],
        ),
        TraceEvent::SmUtil { util } => base("sm_util", vec![("util", Json::Num(util))]),
        TraceEvent::Decision {
            tenant,
            kind,
            edge,
            p99_ms,
        } => base(
            "decision",
            vec![
                ("tenant", Json::Num(tenant as f64)),
                ("kind", Json::Str(kind.as_str().to_string())),
                ("edge", Json::Str(edge.as_str().to_string())),
                ("p99_ms", Json::Num(p99_ms)),
            ],
        ),
        TraceEvent::CtlSpan {
            tenant,
            phase,
            begin,
        } => base(
            "ctl_span",
            vec![
                ("tenant", Json::Num(tenant as f64)),
                ("phase", Json::Str(phase.as_str().to_string())),
                ("begin", Json::Bool(begin)),
            ],
        ),
        TraceEvent::Guardrail {
            target,
            kind,
            engaged,
        } => base(
            "guardrail",
            vec![
                ("target", Json::Num(target as f64)),
                ("kind", Json::Str(kind.as_str().to_string())),
                ("engaged", Json::Bool(engaged)),
            ],
        ),
        TraceEvent::ArbCounters {
            conflicts,
            deferrals,
        } => base(
            "arb_counters",
            vec![
                ("conflicts", Json::Num(conflicts as f64)),
                ("deferrals", Json::Num(deferrals as f64)),
            ],
        ),
        TraceEvent::FabricSolves { recomputes } => base(
            "fabric_solves",
            vec![("recomputes", Json::Num(recomputes as f64))],
        ),
        TraceEvent::FlowsDone { flows } => {
            base("flows_done", vec![("flows", Json::Num(flows as f64))])
        }
        TraceEvent::ShardWindow {
            shard,
            events,
            begin,
        } => base(
            "shard_window",
            vec![
                ("shard", Json::Num(shard as f64)),
                ("events", Json::Num(events as f64)),
                ("begin", Json::Bool(begin)),
            ],
        ),
        TraceEvent::CrossShard { total } => {
            base("cross_shard", vec![("total", Json::Num(total as f64))])
        }
        TraceEvent::FaultInjected { kind, subject } => base(
            "fault_injected",
            vec![
                ("kind", Json::Num(kind as f64)),
                ("subject", Json::Num(subject as f64)),
            ],
        ),
        TraceEvent::FaultCleared { kind, subject } => base(
            "fault_cleared",
            vec![
                ("kind", Json::Num(kind as f64)),
                ("subject", Json::Num(subject as f64)),
            ],
        ),
        TraceEvent::ActionRetry {
            tenant,
            attempt,
            kind,
        } => base(
            "action_retry",
            vec![
                ("tenant", Json::Num(tenant as f64)),
                ("attempt", Json::Num(attempt as f64)),
                ("kind", Json::Str(kind.as_str().to_string())),
            ],
        ),
        TraceEvent::Collective {
            tenant,
            round,
            begin,
        } => base(
            "collective",
            vec![
                ("tenant", Json::Num(tenant as f64)),
                ("round", Json::Num(round as f64)),
                ("begin", Json::Bool(begin)),
            ],
        ),
        TraceEvent::NetLinkSignal {
            link,
            gbps,
            utilization,
        } => base(
            "net_link_signal",
            vec![
                ("link", Json::Num(link as f64)),
                ("gbps", Json::Num(gbps)),
                ("util", Json::Num(utilization)),
            ],
        ),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::trace::{DecisionEdge, DecisionKind};

    fn sample_events() -> Vec<(f64, TraceEvent)> {
        vec![
            (
                1.0,
                TraceEvent::TenantSignal {
                    tenant: 0,
                    p99_ms: 12.0,
                    miss_rate: 0.01,
                    gbps: 3.0,
                    completed: 50,
                },
            ),
            (1.0, TraceEvent::ShardWindow { shard: 0, events: 0, begin: true }),
            (
                2.0,
                TraceEvent::Decision {
                    tenant: 0,
                    kind: DecisionKind::IoThrottle,
                    edge: DecisionEdge::Trigger,
                    p99_ms: 22.0,
                },
            ),
            (
                3.0,
                TraceEvent::ShardWindow {
                    shard: 0,
                    events: 17,
                    begin: false,
                },
            ),
            (
                3.0,
                TraceEvent::CtlSpan {
                    tenant: 0,
                    phase: CtlPhase::Validating,
                    begin: true,
                },
            ),
        ]
    }

    /// (ph, tid, ts) triples of the non-metadata records, in order.
    fn shape(doc: &Json) -> Vec<(String, u32, f64)> {
        doc.get("traceEvents")
            .as_arr()
            .unwrap()
            .iter()
            .filter(|e| e.get("ph").as_str() != Some("M"))
            .map(|e| {
                (
                    e.get("ph").as_str().unwrap().to_string(),
                    e.get("tid").as_usize().unwrap() as u32,
                    e.get("ts").as_f64().unwrap(),
                )
            })
            .collect()
    }

    #[test]
    fn spans_are_balanced_and_timestamps_monotonic_per_tid() {
        let doc = chrome_trace(&sample_events(), &["t1".to_string()], 10.0);
        // Round-trips through the parser (valid JSON).
        let text = doc.to_string();
        let back = Json::parse(&text).unwrap();
        let mut per_tid: std::collections::BTreeMap<u32, (f64, i64)> = Default::default();
        for (ph, tid, ts) in shape(&back) {
            let e = per_tid.entry(tid).or_insert((0.0, 0));
            assert!(ts >= e.0, "ts regressed on tid {tid}");
            e.0 = ts;
            match ph.as_str() {
                "B" => e.1 += 1,
                "E" => {
                    e.1 -= 1;
                    assert!(e.1 >= 0, "E without B on tid {tid}");
                }
                _ => {}
            }
        }
        // The validating span left open at t=3 was closed at the horizon.
        for (tid, (_, depth)) in per_tid {
            assert_eq!(depth, 0, "unbalanced spans on tid {tid}");
        }
    }

    #[test]
    fn orphan_end_edges_are_skipped() {
        // A window end whose begin was overwritten by the ring: no E.
        let doc = chrome_trace(
            &[(1.0, TraceEvent::ShardWindow { shard: 2, events: 4, begin: false })],
            &[],
            5.0,
        );
        assert!(shape(&doc).iter().all(|(ph, _, _)| ph != "E" && ph != "B"));
    }

    #[test]
    fn lanes_carry_thread_names_and_counters_carry_values() {
        let doc = chrome_trace(&sample_events(), &["t1-inference".to_string()], 10.0);
        let evs = doc.get("traceEvents").as_arr().unwrap();
        let names: Vec<&str> = evs
            .iter()
            .filter(|e| e.get("ph").as_str() == Some("M"))
            .filter_map(|e| e.at(&["args", "name"]).as_str())
            .collect();
        assert!(names.contains(&"t1-inference"));
        assert!(names.contains(&"ctl:t1-inference"));
        assert!(names.contains(&"shard0"));
        let p99 = evs
            .iter()
            .find(|e| e.get("name").as_str() == Some("p99_ms"))
            .unwrap();
        assert_eq!(p99.at(&["args", "value"]).as_f64(), Some(12.0));
        // µs timestamps.
        assert_eq!(p99.get("ts").as_f64(), Some(1e6));
    }

    #[test]
    fn collective_spans_balance_and_net_links_render_as_counters() {
        let events = vec![
            (1.0, TraceEvent::Collective { tenant: 2, round: 0, begin: true }),
            (
                1.5,
                TraceEvent::NetLinkSignal { link: 7, gbps: 12.5, utilization: 1.0 },
            ),
            (2.0, TraceEvent::Collective { tenant: 2, round: 0, begin: false }),
        ];
        let doc = chrome_trace(&events, &[], 10.0);
        let mut depth = 0i64;
        for (ph, tid, _) in shape(&doc) {
            if tid == tenant_tid(2) {
                match ph.as_str() {
                    "B" => depth += 1,
                    "E" => depth -= 1,
                    _ => {}
                }
                assert!(depth >= 0);
            }
        }
        assert_eq!(depth, 0, "unbalanced allreduce span");
        let evs = doc.get("traceEvents").as_arr().unwrap();
        let net = evs
            .iter()
            .find(|e| e.get("name").as_str() == Some("netlink7"))
            .expect("net link counter rendered");
        assert_eq!(net.at(&["args", "gbps"]).as_f64(), Some(12.5));
        // JSONL keeps full fidelity for both variants.
        let lines: Vec<Json> = jsonl(&events)
            .lines()
            .map(|l| Json::parse(l).unwrap())
            .collect();
        assert!(lines
            .iter()
            .any(|j| j.get("event").as_str() == Some("collective")));
        assert!(lines
            .iter()
            .any(|j| j.get("event").as_str() == Some("net_link_signal")));
    }

    #[test]
    fn jsonl_is_one_valid_object_per_line() {
        let text = jsonl(&sample_events());
        let lines: Vec<&str> = text.lines().collect();
        assert_eq!(lines.len(), 5);
        for line in lines {
            let j = Json::parse(line).unwrap();
            assert!(j.get("event").as_str().is_some());
            assert!(j.get("t").as_f64().is_some());
        }
    }
}
