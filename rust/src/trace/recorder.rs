//! The preallocated ring buffer behind the flight recorder.
//!
//! Capacity is fixed at construction; when the ring is full the oldest
//! event is overwritten and [`MetricsRegistry::dropped_events`] counts
//! the loss. `emit` never allocates — the non-perturbation story needs
//! the recorder to be cheap, and the zero-cost-when-disabled story
//! (`Option<Recorder>` at each emit site) needs it to be absent.

use super::metrics::MetricsRegistry;
use super::TraceEvent;

/// Default ring capacity: ~256k events (≲ 14 MB), comfortably above a
/// catalog run's signal + decision + window volume so CLI exports see
/// the whole run; sweeps that overflow drop oldest-first and report it.
pub const DEFAULT_CAPACITY: usize = 1 << 18;

/// Flight recorder: a preallocated `(t, event)` ring plus the run's
/// [`MetricsRegistry`].
#[derive(Clone, Debug)]
pub struct Recorder {
    buf: Vec<(f64, TraceEvent)>,
    cap: usize,
    /// Next write slot once the ring has wrapped.
    head: usize,
    pub metrics: MetricsRegistry,
}

impl Recorder {
    /// Recorder with a preallocated ring of `capacity` events.
    pub fn new(capacity: usize) -> Recorder {
        let cap = capacity.max(1);
        Recorder {
            buf: Vec::with_capacity(cap),
            cap,
            head: 0,
            metrics: MetricsRegistry::new(),
        }
    }

    pub fn with_default_capacity() -> Recorder {
        Recorder::new(DEFAULT_CAPACITY)
    }

    /// Append one event at sim-time `t`. O(1), allocation-free: below
    /// capacity it writes into the preallocated tail, at capacity it
    /// overwrites the oldest slot and counts the drop.
    pub fn emit(&mut self, t: f64, ev: TraceEvent) {
        if self.buf.len() < self.cap {
            self.buf.push((t, ev));
        } else {
            self.buf[self.head] = (t, ev);
            self.head = (self.head + 1) % self.cap;
            self.metrics.note_dropped(1);
        }
    }

    /// Events currently retained (≤ capacity).
    pub fn len(&self) -> usize {
        self.buf.len()
    }

    pub fn is_empty(&self) -> bool {
        self.buf.is_empty()
    }

    pub fn capacity(&self) -> usize {
        self.cap
    }

    /// Retained events in emit order (oldest surviving event first).
    pub fn events(&self) -> Vec<(f64, TraceEvent)> {
        let mut out = Vec::with_capacity(self.buf.len());
        out.extend_from_slice(&self.buf[self.head..]);
        out.extend_from_slice(&self.buf[..self.head]);
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn ev(i: u64) -> TraceEvent {
        TraceEvent::FlowsDone { flows: i as u32 }
    }

    #[test]
    fn ring_drops_oldest_at_capacity_and_counts_them() {
        let mut r = Recorder::new(4);
        for i in 0..10u64 {
            r.emit(i as f64, ev(i));
        }
        assert_eq!(r.len(), 4);
        assert_eq!(r.capacity(), 4);
        // The 6 oldest events were overwritten, oldest first…
        assert_eq!(r.metrics.dropped_events(), 6);
        // …and the survivors are the newest 4, still in emit order.
        let kept: Vec<f64> = r.events().iter().map(|(t, _)| *t).collect();
        assert_eq!(kept, vec![6.0, 7.0, 8.0, 9.0]);
        assert_eq!(r.events()[0].1, ev(6));
    }

    #[test]
    fn below_capacity_nothing_drops() {
        let mut r = Recorder::with_default_capacity();
        for i in 0..100u64 {
            r.emit(i as f64, ev(i));
        }
        assert_eq!(r.len(), 100);
        assert_eq!(r.metrics.dropped_events(), 0);
        let evs = r.events();
        assert_eq!(evs.first().map(|(t, _)| *t), Some(0.0));
        assert_eq!(evs.last().map(|(t, _)| *t), Some(99.0));
    }

    #[test]
    fn emit_does_not_grow_the_preallocated_ring() {
        let mut r = Recorder::new(8);
        let cap_before = r.buf.capacity();
        for i in 0..1000u64 {
            r.emit(i as f64, ev(i));
        }
        assert_eq!(r.buf.capacity(), cap_before, "ring must never reallocate");
    }
}
