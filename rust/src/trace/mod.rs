//! The flight recorder: time-resolved observability for the simulated
//! testbed (§2.4: "log all decisions with signal snapshots for audit").
//!
//! `RunResult` answers *what happened* at end-of-run granularity; this
//! module answers *when* and *why*. A [`Recorder`] captures typed,
//! timestamped [`TraceEvent`]s from every layer into a preallocated ring
//! buffer:
//!
//! * per-Δ signal series per tenant (tails, miss-rate, link GB/s, SM
//!   utilization) — one [`TraceEvent::TenantSignal`] per tenant per
//!   sampling tick, plus link and host-level counters;
//! * controller lifecycle — every `AuditLog` decision as a
//!   [`TraceEvent::Decision`], validation/cool-down windows as
//!   begin/end spans, guardrail own/loosen edges, arbitration counters;
//! * fabric events — PS rate-recompute counters and completion-calendar
//!   pops ([`TraceEvent::FlowsDone`]);
//! * sharded-engine windows — per-shard conservative-sync window spans
//!   with per-window event counts, cross-shard delivery counters, and
//!   merge-stall accounting.
//!
//! Alongside the ring, a [`MetricsRegistry`] of named monotonic counters
//! and gauges collects whole-run aggregates; its sorted snapshot lands in
//! `RunResult::metrics` (deterministic, excluded from `fingerprint()`
//! like the shard counters).
//!
//! **The load-bearing invariant:** recording must not perturb the
//! simulation. Every emit site is observation-only — no RNG stream is
//! consumed, no event is scheduled, no float is computed differently —
//! so every catalog fingerprint is byte-identical with recording on vs
//! off. `prop_recording_does_not_perturb_fingerprints` enforces this,
//! and the recorder is zero-cost when disabled: a single
//! `Option<Recorder>` check per emit site, no allocation when `None`.
//!
//! Export paths: JSONL streaming ([`chrome::jsonl`]), Chrome trace-event
//! format loadable in `chrome://tracing` / Perfetto
//! ([`chrome::chrome_trace`]; one pid per host, one tid per
//! tenant/controller/shard), and the per-tenant p99-vs-SLO ASCII
//! timeline of `predserve report --timeline`
//! ([`timeline::render_timeline`]).

pub mod chrome;
pub mod metrics;
pub mod recorder;
pub mod timeline;

pub use chrome::{chrome_trace, jsonl};
pub use metrics::MetricsRegistry;
pub use recorder::Recorder;
pub use timeline::{render_timeline, TimelineRow};

/// Typed action-kind tag shared by the controller audit log and the
/// trace events — the typed replacement for the audit log's stringly
/// kinds. [`DecisionKind::as_str`] renders the exact legacy strings
/// ("mig", "placement", ...), which fingerprinted timelines and the
/// `count_kind(&str)` shim depend on.
#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord)]
pub enum DecisionKind {
    /// Dynamic MIG resize on the tenant's current GPU.
    Mig,
    /// Move to an existing/created instance (placement lever).
    Placement,
    /// Relaxation shrink after sustained stability.
    Relax,
    /// MPS active-thread-percentage cap on a noisy peer.
    MpsQuota,
    /// cgroup io.max throttle (apply or lift).
    IoThrottle,
    /// NUMA CPU pin away from IRQ-heavy cores.
    PinCpu,
    /// Revert to the last-known-good configuration.
    Rollback,
    /// Post-validation persist of a committed change.
    Persist,
}

impl DecisionKind {
    /// The legacy audit-log string for this kind — byte-identical to the
    /// pre-enum tags (they are embedded in `RunResult::fingerprint`).
    pub fn as_str(self) -> &'static str {
        match self {
            DecisionKind::Mig => "mig",
            DecisionKind::Placement => "placement",
            DecisionKind::Relax => "relax",
            DecisionKind::MpsQuota => "mps_quota",
            DecisionKind::IoThrottle => "io_throttle",
            DecisionKind::PinCpu => "pin_cpu",
            DecisionKind::Rollback => "rollback",
            DecisionKind::Persist => "persist",
        }
    }

    /// One-character overlay marker for the ASCII timeline report.
    pub fn marker(self) -> char {
        match self {
            DecisionKind::Mig => 'M',
            DecisionKind::Placement => 'P',
            DecisionKind::Relax => 'x',
            DecisionKind::MpsQuota => 'Q',
            DecisionKind::IoThrottle => 'T',
            DecisionKind::PinCpu => 'C',
            DecisionKind::Rollback => 'R',
            DecisionKind::Persist => 'S',
        }
    }
}

impl std::fmt::Display for DecisionKind {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(self.as_str())
    }
}

/// Typed FSM edge an audit decision was recorded on.
#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord)]
pub enum DecisionEdge {
    /// Persistent violation fired and an action committed.
    Trigger,
    /// Sustained-stability relaxation committed.
    Stable,
    /// Proposal lost arbitration (never executed).
    Defer,
    /// Post-change validation window passed.
    ValidateOk,
    /// Post-change validation window failed (mandatory rollback).
    ValidateFail,
    /// A committed action failed at the platform (fault injection) and
    /// the controller scheduled a backed-off retry. The dwell clock is
    /// restored — a failed change never burns it.
    Retry,
    /// Retries exhausted: the controller fell back to guardrails-only
    /// mode for the rest of the run.
    Degraded,
}

impl DecisionEdge {
    /// The legacy audit-log edge string ("trigger", "validate-ok", ...).
    pub fn as_str(self) -> &'static str {
        match self {
            DecisionEdge::Trigger => "trigger",
            DecisionEdge::Stable => "stable",
            DecisionEdge::Defer => "defer",
            DecisionEdge::ValidateOk => "validate-ok",
            DecisionEdge::ValidateFail => "validate-fail",
            DecisionEdge::Retry => "retry",
            DecisionEdge::Degraded => "degraded",
        }
    }
}

impl std::fmt::Display for DecisionEdge {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(self.as_str())
    }
}

/// Controller FSM phase with sim-time extent (rendered as a begin/end
/// span on the controller's trace lane).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum CtlPhase {
    /// Post-change validation window (§2.4).
    Validating,
    /// Grace period after a change persisted or rolled back.
    Cooldown,
}

impl CtlPhase {
    pub fn as_str(self) -> &'static str {
        match self {
            CtlPhase::Validating => "validating",
            CtlPhase::Cooldown => "cooldown",
        }
    }
}

/// One typed, timestamped flight-recorder event. Fixed-size and `Copy`
/// so the ring buffer never allocates per emit; naming/expansion happens
/// only at export time.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum TraceEvent {
    /// Per-Δ signal sample for one tenant (tails + attributed link GB/s).
    TenantSignal {
        tenant: u32,
        p99_ms: f64,
        miss_rate: f64,
        gbps: f64,
        completed: u64,
    },
    /// Per-Δ utilization/throughput sample for one shared link.
    LinkSignal { link: u32, gbps: f64, utilization: f64 },
    /// Per-Δ host-wide mean SM utilization across GPUs.
    SmUtil { util: f64 },
    /// One audit-log decision (every `AuditLog` entry becomes one of
    /// these). `tenant` is the deciding controller's protected tenant.
    Decision {
        tenant: u32,
        kind: DecisionKind,
        edge: DecisionEdge,
        p99_ms: f64,
    },
    /// Controller FSM phase span edge (validating / cooldown windows).
    CtlSpan {
        tenant: u32,
        phase: CtlPhase,
        begin: bool,
    },
    /// Guardrail actuation edge on the platform: `engaged` is the
    /// own/tighten direction, `!engaged` the loosen/lift direction.
    Guardrail {
        target: u32,
        kind: DecisionKind,
        engaged: bool,
    },
    /// Cumulative arbitration counters at a sampling tick.
    ArbCounters { conflicts: u64, deferrals: u64 },
    /// Cumulative per-link PS rate-vector recompute count at a tick.
    FabricSolves { recomputes: u64 },
    /// A completion-calendar pop drained `flows` finished fabric flows.
    FlowsDone { flows: u32 },
    /// Conservative-sync window span edge for one shard. The `end`
    /// edge carries the events this shard dispatched inside the window.
    ShardWindow { shard: u32, events: u64, begin: bool },
    /// Cumulative cross-shard deliveries at a window edge.
    CrossShard { total: u64 },
    /// A fault from the run's `FaultPlan` began. `kind` is
    /// `FaultSpec::kind_code`, `subject` the link/tenant it targets.
    FaultInjected { kind: u8, subject: u32 },
    /// A timed fault ended (capacity restored, window closed, sensor
    /// back).
    FaultCleared { kind: u8, subject: u32 },
    /// A controller's committed action failed at the platform and a
    /// backed-off retry was scheduled (`attempt` = failures so far).
    ActionRetry {
        tenant: u32,
        attempt: u8,
        kind: DecisionKind,
    },
    /// Ring-allreduce round span edge for a cross-host trainer: `begin`
    /// when round `round` launches its first ring step, `!begin` when
    /// its last segment drains. The differential oracle measures
    /// allreduce wall time from these spans.
    Collective { tenant: u32, round: u32, begin: bool },
    /// Per-Δ throughput/utilization sample for one cluster net link —
    /// the net twin of [`TraceEvent::LinkSignal`]. Observability only:
    /// these never enter `SignalSnapshot`, so the controller cannot see
    /// this contention domain.
    NetLinkSignal { link: u32, gbps: f64, utilization: f64 },
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn decision_kind_strings_match_legacy_audit_tags() {
        // These strings are embedded in RunResult::fingerprint via the
        // timeline — they must never drift.
        let expect = [
            (DecisionKind::Mig, "mig"),
            (DecisionKind::Placement, "placement"),
            (DecisionKind::Relax, "relax"),
            (DecisionKind::MpsQuota, "mps_quota"),
            (DecisionKind::IoThrottle, "io_throttle"),
            (DecisionKind::PinCpu, "pin_cpu"),
            (DecisionKind::Rollback, "rollback"),
            (DecisionKind::Persist, "persist"),
        ];
        for (kind, s) in expect {
            assert_eq!(kind.as_str(), s);
            assert_eq!(kind.to_string(), s);
        }
    }

    #[test]
    fn decision_edge_strings_match_legacy_audit_tags() {
        let expect = [
            (DecisionEdge::Trigger, "trigger"),
            (DecisionEdge::Stable, "stable"),
            (DecisionEdge::Defer, "defer"),
            (DecisionEdge::ValidateOk, "validate-ok"),
            (DecisionEdge::ValidateFail, "validate-fail"),
            (DecisionEdge::Retry, "retry"),
            (DecisionEdge::Degraded, "degraded"),
        ];
        for (edge, s) in expect {
            assert_eq!(edge.as_str(), s);
        }
    }

    #[test]
    fn trace_events_are_fixed_size_and_copy() {
        // The ring preallocates `capacity * size_of::<(f64, TraceEvent)>`
        // and never allocates per emit; a variant growing past this
        // budget deserves a deliberate decision, not an accident.
        assert!(std::mem::size_of::<(f64, TraceEvent)>() <= 56);
        let e = TraceEvent::SmUtil { util: 0.5 };
        let f = e; // Copy
        assert_eq!(e, f);
    }
}
