//! ASCII timeline for `predserve report --timeline`: per-tenant p99 vs
//! SLO over sim time, with controller decisions overlaid.
//!
//! Each latency-sensitive tenant gets one row of `width` columns across
//! `[0, horizon)`. A column shows the worst p99 sampled inside its time
//! bucket, bucketed against the tenant's SLO — blank (no sample), `.`
//! (≤ 0.75·SLO), `:` (≤ SLO), `#` (over SLO) — and committed controller
//! decisions overwrite the bucket with their [`DecisionKind::marker`]
//! character, so a `#…#M:…` run reads as "violated until the MIG resize
//! landed".

use super::{DecisionEdge, TraceEvent};

/// One rendered row: a tenant's display name, SLO target, and trace id.
#[derive(Clone, Debug)]
pub struct TimelineRow {
    pub name: String,
    pub slo_ms: f64,
    pub tenant: u32,
}

/// Render the timeline. Rows render in the order given; tenants without
/// a finite SLO should be filtered out by the caller (best-effort rows
/// would always be blank-vs-∞).
pub fn render_timeline(
    events: &[(f64, TraceEvent)],
    rows: &[TimelineRow],
    horizon_s: f64,
    width: usize,
) -> String {
    let width = width.max(10);
    let horizon = if horizon_s > 0.0 { horizon_s } else { 1.0 };
    let bucket_of = |t: f64| (((t / horizon) * width as f64) as usize).min(width - 1);
    let name_w = rows.iter().map(|r| r.name.len()).max().unwrap_or(6).max(6);

    let mut out = String::new();
    out.push_str(&format!(
        "timeline: {width} cols x {horizon:.0}s ({:.2}s/col); '.' <=0.75*SLO  ':' <=SLO  '#' over  letters = decisions (M mig, P placement, x relax, Q mps, T io, C cpu-pin, R rollback, S persist)\n",
        horizon / width as f64
    ));
    for row in rows {
        // Pass 1: worst p99 per bucket.
        let mut worst: Vec<Option<f64>> = vec![None; width];
        for &(t, ev) in events {
            if let TraceEvent::TenantSignal { tenant, p99_ms, .. } = ev {
                if tenant == row.tenant {
                    let b = bucket_of(t);
                    worst[b] = Some(worst[b].map_or(p99_ms, |w: f64| w.max(p99_ms)));
                }
            }
        }
        let mut cells: Vec<char> = worst
            .iter()
            .map(|w| match w {
                None => ' ',
                Some(p) if *p <= 0.75 * row.slo_ms => '.',
                Some(p) if *p <= row.slo_ms => ':',
                Some(_) => '#',
            })
            .collect();
        // Pass 2: committed decisions overwrite their bucket.
        for &(t, ev) in events {
            if let TraceEvent::Decision {
                tenant, kind, edge, ..
            } = ev
            {
                if tenant == row.tenant && edge != DecisionEdge::Defer {
                    cells[bucket_of(t)] = kind.marker();
                }
            }
        }
        let line: String = cells.into_iter().collect();
        out.push_str(&format!("{:>name_w$} |{line}| slo {:.1}ms\n", row.name, row.slo_ms));
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::trace::DecisionKind;

    fn sig(t: f64, tenant: u32, p99: f64) -> (f64, TraceEvent) {
        (
            t,
            TraceEvent::TenantSignal {
                tenant,
                p99_ms: p99,
                miss_rate: 0.0,
                gbps: 0.0,
                completed: 0,
            },
        )
    }

    #[test]
    fn buckets_severity_and_overlays_decisions() {
        let events = vec![
            sig(1.0, 0, 5.0),   // well under the 20ms SLO → '.'
            sig(31.0, 0, 18.0), // between 0.75*SLO and SLO → ':'
            sig(61.0, 0, 40.0), // violated → '#'
            (
                91.0,
                TraceEvent::Decision {
                    tenant: 0,
                    kind: DecisionKind::Mig,
                    edge: DecisionEdge::Trigger,
                    p99_ms: 40.0,
                },
            ),
            (
                95.0,
                TraceEvent::Decision {
                    tenant: 0,
                    kind: DecisionKind::Placement,
                    edge: DecisionEdge::Defer, // deferred → not drawn
                    p99_ms: 40.0,
                },
            ),
        ];
        let rows = [TimelineRow {
            name: "llm".to_string(),
            slo_ms: 20.0,
            tenant: 0,
        }];
        let out = render_timeline(&events, &rows, 100.0, 10);
        let row_line = out.lines().nth(1).unwrap();
        let cells: &str = row_line.split('|').nth(1).unwrap();
        assert_eq!(cells, ".  :  #  M");
        assert!(row_line.contains("slo 20.0ms"));
        assert!(!cells.contains('P'), "deferred decisions must not render");
    }

    #[test]
    fn events_at_horizon_land_in_last_bucket() {
        let events = vec![sig(100.0, 0, 100.0)];
        let rows = [TimelineRow {
            name: "t".to_string(),
            slo_ms: 10.0,
            tenant: 0,
        }];
        let out = render_timeline(&events, &rows, 100.0, 10);
        let cells = out.lines().nth(1).unwrap().split('|').nth(1).unwrap();
        assert_eq!(cells.chars().last(), Some('#'));
    }
}
