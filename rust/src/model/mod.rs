//! Analytic queueing models from §2.5 — used as oracles in tests and to
//! annotate experiment reports (the paper uses Kingman "qualitatively to
//! explain how saturation inflates tails").

pub mod queueing;

pub use queueing::{kingman_wait, mm1_p99_sojourn, ps_utilization_stable};
