//! Kingman / M/M/1 approximations (§2.5.1) and the Claim-1 stability
//! condition.

/// Kingman (G/G/1) approximation of the mean queueing delay:
/// `E[Wq] ≈ ρ/(1-ρ) · (ca² + cs²)/2 · E[S]`.
///
/// * `lambda` — arrival rate (1/s)
/// * `mean_service_s` — E[S]
/// * `ca2`, `cs2` — squared coefficients of variation of inter-arrival
///   and service times.
///
/// Returns `f64::INFINITY` at/after saturation.
pub fn kingman_wait(lambda: f64, mean_service_s: f64, ca2: f64, cs2: f64) -> f64 {
    let rho = lambda * mean_service_s;
    if rho >= 1.0 {
        return f64::INFINITY;
    }
    rho / (1.0 - rho) * (ca2 + cs2) / 2.0 * mean_service_s
}

/// p99 sojourn time of an M/M/1 queue: `ln(100)/(μ - λ)`. Used to sanity
/// check the simulator's compute-queue tails.
pub fn mm1_p99_sojourn(lambda: f64, mu: f64) -> f64 {
    if mu <= lambda {
        return f64::INFINITY;
    }
    (100.0f64).ln() / (mu - lambda)
}

/// Claim 1 (guardrail stability): with per-tenant throttles `g`, the PS
/// stage is stable iff `Σ g_j < B`. Returns the utilization ρ.
pub fn ps_utilization_stable(caps: &[f64], capacity: f64) -> (f64, bool) {
    let total: f64 = caps.iter().sum();
    let rho = total / capacity;
    (rho, rho < 1.0)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn kingman_mm1_consistency() {
        // For M/M/1 (ca²=cs²=1), Kingman is exact: Wq = ρ/(1-ρ)·S.
        let wq = kingman_wait(50.0, 0.01, 1.0, 1.0);
        let rho: f64 = 0.5;
        assert!((wq - rho / (1.0 - rho) * 0.01).abs() < 1e-12);
    }

    #[test]
    fn kingman_saturation_is_infinite() {
        assert!(kingman_wait(100.0, 0.01, 1.0, 1.0).is_infinite());
        assert!(kingman_wait(150.0, 0.01, 1.0, 1.0).is_infinite());
    }

    #[test]
    fn kingman_grows_with_variability() {
        let low = kingman_wait(50.0, 0.01, 0.5, 0.5);
        let high = kingman_wait(50.0, 0.01, 2.0, 2.0);
        assert!(high > low * 3.0);
    }

    #[test]
    fn mm1_p99() {
        let p99 = mm1_p99_sojourn(80.0, 200.0);
        assert!((p99 - (100.0f64).ln() / 120.0).abs() < 1e-12);
        assert!(mm1_p99_sojourn(200.0, 200.0).is_infinite());
    }

    #[test]
    fn claim1_stability_boundary() {
        let (rho, stable) = ps_utilization_stable(&[3.0, 4.0], 10.0);
        assert!(stable && (rho - 0.7).abs() < 1e-12);
        let (_, unstable) = ps_utilization_stable(&[6.0, 6.0], 10.0);
        assert!(!unstable);
    }

    #[test]
    fn simulator_queue_matches_kingman_order_of_magnitude() {
        // Closed-form vs the fabric's PS queue is checked qualitatively:
        // the §2.5 model is "guidance", so we assert the direction only —
        // doubling ρ more than doubles the wait.
        let w1 = kingman_wait(30.0, 0.01, 1.0, 1.0);
        let w2 = kingman_wait(60.0, 0.01, 1.0, 1.0);
        assert!(w2 > 2.0 * w1);
    }
}
