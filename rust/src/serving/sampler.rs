//! Token sampling: greedy argmax or seeded top-k.

use crate::util::rng::Pcg64;

/// Greedy argmax over one row of logits.
pub fn greedy(logits: &[f32]) -> i32 {
    let mut best = 0usize;
    let mut best_v = f32::NEG_INFINITY;
    for (i, &v) in logits.iter().enumerate() {
        if v > best_v {
            best_v = v;
            best = i;
        }
    }
    best as i32
}

/// Top-k sampling with softmax renormalization over the k survivors.
pub fn top_k(logits: &[f32], k: usize, rng: &mut Pcg64) -> i32 {
    if k == 0 || k >= logits.len() {
        return greedy(logits);
    }
    let mut idx: Vec<usize> = (0..logits.len()).collect();
    idx.sort_unstable_by(|&a, &b| logits[b].partial_cmp(&logits[a]).unwrap());
    idx.truncate(k);
    let max = logits[idx[0]];
    let weights: Vec<f64> = idx
        .iter()
        .map(|&i| ((logits[i] - max) as f64).exp())
        .collect();
    let total: f64 = weights.iter().sum();
    let mut u = rng.f64() * total;
    for (w, &i) in weights.iter().zip(&idx) {
        if u < *w {
            return i as i32;
        }
        u -= w;
    }
    idx[idx.len() - 1] as i32
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn greedy_picks_max() {
        assert_eq!(greedy(&[0.1, 3.0, -2.0, 1.0]), 1);
    }

    #[test]
    fn top_k_respects_support() {
        let mut rng = Pcg64::seeded(1);
        let logits = vec![5.0, 4.0, -100.0, -100.0];
        for _ in 0..100 {
            let t = top_k(&logits, 2, &mut rng);
            assert!(t == 0 || t == 1);
        }
    }

    #[test]
    fn top_k_zero_is_greedy() {
        let mut rng = Pcg64::seeded(2);
        assert_eq!(top_k(&[1.0, 9.0], 0, &mut rng), 1);
    }

    #[test]
    fn top_k_deterministic_with_seed() {
        let logits: Vec<f32> = (0..32).map(|i| (i as f32 * 0.37).sin()).collect();
        let mut a = Pcg64::seeded(3);
        let mut b = Pcg64::seeded(3);
        for _ in 0..50 {
            assert_eq!(top_k(&logits, 8, &mut a), top_k(&logits, 8, &mut b));
        }
    }
}
