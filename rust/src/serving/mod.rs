//! vLLM-style LLM serving engine (the paper's LLM case study substrate).
//!
//! Python never runs here: the engine drives the AOT-compiled HLO
//! executables (prefill + paged decode, with the L1 Pallas paged-attention
//! kernel inside) through [`crate::runtime::ModelRuntime`].
//!
//! Architecture mirrors vLLM:
//! * [`kvcache`] — paged KV block manager (free list, per-sequence page
//!   tables, refcounts for prefix sharing).
//! * [`batcher`] — continuous batching: waiting queue admitted into fixed
//!   batch rows as slots free up, gated by KV page availability.
//! * [`engine`] — the prefill/decode step loop with token streaming,
//!   TTFT/TPOT measurement and greedy/top-k sampling ([`sampler`]).
//! * [`router`] — least-outstanding-requests routing across engine
//!   replicas (used by the 2-node cluster runtime).
//! * [`tokenizer`] — byte-level tokenizer matching the AOT vocab.
//! * [`sim_backend`] — the same batcher/kvcache driven on *simulated*
//!   time by `platform::sim_platform` for request-granularity LLM
//!   tenants (no AOT artifacts; TTFT/TPOT from the sim clock).

pub mod tokenizer;
pub mod sampler;
pub mod kvcache;
pub mod request;
pub mod batcher;
pub mod engine;
pub mod router;
pub mod sim_backend;

pub use engine::{Engine, EngineStats};
pub use kvcache::PagedKvCache;
pub use request::{Completion, RequestId, ServeRequest};
pub use router::Router;
pub use sim_backend::{SimCompletion, SimServing, StepStart};
pub use tokenizer::ByteTokenizer;
