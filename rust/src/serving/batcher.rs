//! Continuous batcher: waiting queue → fixed batch rows (vLLM-style).
//!
//! The AOT executables are compiled for a fixed row count (`batch`), so
//! "continuous batching" here means: whenever a row frees up and the KV
//! pool can host the prompt, the next waiting request is admitted and
//! prefills while other rows keep decoding (prefill runs as its own wave,
//! with occupied rows masked out via `seq_len = 0`).

use std::collections::VecDeque;
use std::time::Instant;

use super::kvcache::{PagedKvCache, SeqId};
use super::request::{RequestId, ServeRequest};

/// A sequence occupying a batch row.
#[derive(Clone, Debug)]
pub struct RunningSeq {
    pub req: ServeRequest,
    pub seq: SeqId,
    pub generated: Vec<i32>,
    /// Token to feed next decode step.
    pub last_token: i32,
    /// Position (0-based) the next decode step writes.
    pub position: usize,
    pub ttft_s: Option<f64>,
    pub prefill_at: Option<Instant>,
}

/// What the engine should do next.
#[derive(Debug, PartialEq, Eq)]
pub enum Work {
    /// Admit + prefill these waiting requests into the given rows.
    Prefill { rows: Vec<usize> },
    /// Run one decode step over the currently running rows.
    Decode,
    /// Nothing to do.
    Idle,
}

/// Row-slot manager.
#[derive(Clone, Debug)]
pub struct Batcher {
    rows: Vec<Option<RunningSeq>>,
    waiting: VecDeque<ServeRequest>,
    admitted_total: u64,
}

impl Batcher {
    pub fn new(batch_rows: usize) -> Batcher {
        Batcher {
            rows: (0..batch_rows).map(|_| None).collect(),
            waiting: VecDeque::new(),
            admitted_total: 0,
        }
    }

    pub fn submit(&mut self, req: ServeRequest) {
        self.waiting.push_back(req);
    }

    pub fn waiting_len(&self) -> usize {
        self.waiting.len()
    }

    pub fn running_len(&self) -> usize {
        self.rows.iter().filter(|r| r.is_some()).count()
    }

    pub fn is_idle(&self) -> bool {
        self.waiting.is_empty() && self.running_len() == 0
    }

    pub fn rows(&self) -> &[Option<RunningSeq>] {
        &self.rows
    }

    pub fn row_mut(&mut self, i: usize) -> &mut Option<RunningSeq> {
        &mut self.rows[i]
    }

    pub fn admitted_total(&self) -> u64 {
        self.admitted_total
    }

    /// Decide the next wave. Prefill takes priority when a row AND pages
    /// are available (prefill-first keeps TTFT low, matching vLLM's
    /// default scheduler).
    pub fn plan(&self, cache: &PagedKvCache) -> Work {
        let free_rows: Vec<usize> = self
            .rows
            .iter()
            .enumerate()
            .filter(|(_, r)| r.is_none())
            .map(|(i, _)| i)
            .collect();
        if !free_rows.is_empty() && !self.waiting.is_empty() {
            // Admit as many as fit (head-of-line order; stop at the first
            // request whose prompt cannot get pages yet).
            let mut rows = Vec::new();
            let mut pages_left = cache.free_pages();
            for (slot, req) in free_rows.iter().zip(self.waiting.iter()) {
                let need = cache.pages_for(req.prompt_tokens.len()).max(1);
                if need > pages_left || !cache.can_admit(req.prompt_tokens.len()) {
                    break;
                }
                pages_left -= need;
                rows.push(*slot);
            }
            if !rows.is_empty() {
                return Work::Prefill { rows };
            }
        }
        if self.running_len() > 0 {
            return Work::Decode;
        }
        Work::Idle
    }

    /// Head of the waiting queue (the request `admit` will pop next).
    pub fn waiting_front(&self) -> Option<&ServeRequest> {
        self.waiting.front()
    }

    /// Pop the next waiting request into `row` (the engine calls this for
    /// each row in a `Work::Prefill` wave, after allocating its pages).
    pub fn admit(&mut self, row: usize, seq: SeqId) -> &mut RunningSeq {
        let req = self.waiting.pop_front().expect("admit without waiting");
        self.admitted_total += 1;
        self.rows[row] = Some(RunningSeq {
            position: req.prompt_tokens.len(),
            req,
            seq,
            generated: Vec::new(),
            last_token: 0,
            ttft_s: None,
            prefill_at: None,
        });
        self.rows[row].as_mut().unwrap()
    }

    /// Free a row, returning the sequence.
    pub fn evict(&mut self, row: usize) -> Option<RunningSeq> {
        self.rows[row].take()
    }

    /// Requests in flight or queued, by id (ordering invariants in tests).
    pub fn inflight_ids(&self) -> Vec<RequestId> {
        let mut ids: Vec<RequestId> = self
            .rows
            .iter()
            .flatten()
            .map(|r| r.req.id)
            .chain(self.waiting.iter().map(|r| r.id))
            .collect();
        ids.sort();
        ids
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::serving::request::SamplingParams;

    fn req(id: u64, prompt: usize) -> ServeRequest {
        ServeRequest {
            id: RequestId(id),
            prompt_tokens: vec![1; prompt],
            params: SamplingParams::default(),
            submitted: Instant::now(),
        }
    }

    #[test]
    fn prefill_planned_when_rows_free() {
        let cache = PagedKvCache::new(64, 16, 4);
        let mut b = Batcher::new(4);
        b.submit(req(1, 10));
        b.submit(req(2, 10));
        match b.plan(&cache) {
            Work::Prefill { rows } => assert_eq!(rows, vec![0, 1]),
            w => panic!("expected prefill, got {w:?}"),
        }
    }

    #[test]
    fn admission_respects_page_budget() {
        let cache = PagedKvCache::new(4, 16, 4); // 3 usable pages
        let mut b = Batcher::new(4);
        b.submit(req(1, 32)); // 2 pages
        b.submit(req(2, 32)); // would exceed
        match b.plan(&cache) {
            Work::Prefill { rows } => assert_eq!(rows.len(), 1),
            w => panic!("{w:?}"),
        }
    }

    #[test]
    fn decode_when_no_free_rows() {
        let mut cache = PagedKvCache::new(64, 16, 4);
        let mut b = Batcher::new(1);
        b.submit(req(1, 10));
        let seq = cache.allocate(10).unwrap();
        b.admit(0, seq);
        b.submit(req(2, 10));
        assert_eq!(b.plan(&cache), Work::Decode);
    }

    #[test]
    fn idle_when_empty() {
        let cache = PagedKvCache::new(64, 16, 4);
        let b = Batcher::new(4);
        assert_eq!(b.plan(&cache), Work::Idle);
        assert!(b.is_idle());
    }

    #[test]
    fn evict_frees_row() {
        let mut cache = PagedKvCache::new(64, 16, 4);
        let mut b = Batcher::new(1);
        b.submit(req(7, 5));
        let seq = cache.allocate(5).unwrap();
        b.admit(0, seq);
        assert_eq!(b.running_len(), 1);
        let r = b.evict(0).unwrap();
        assert_eq!(r.req.id, RequestId(7));
        assert_eq!(b.running_len(), 0);
    }

    #[test]
    fn no_request_lost_or_duplicated() {
        let mut cache = PagedKvCache::new(64, 16, 4);
        let mut b = Batcher::new(2);
        for i in 0..6 {
            b.submit(req(i, 8));
        }
        // Admit two.
        if let Work::Prefill { rows } = b.plan(&cache) {
            for r in rows {
                let seq = cache.allocate(8).unwrap();
                b.admit(r, seq);
            }
        }
        let ids = b.inflight_ids();
        assert_eq!(ids.len(), 6);
        for (i, id) in ids.iter().enumerate() {
            assert_eq!(*id, RequestId(i as u64));
        }
    }
}
