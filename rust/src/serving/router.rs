//! Request router across engine replicas (vllm-project/router-style).
//!
//! Policies: round-robin and least-outstanding-requests (the default for
//! latency-sensitive serving — joins the shortest queue).

/// Routing policy.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Policy {
    RoundRobin,
    LeastOutstanding,
}

/// Tracks outstanding requests per replica and picks targets.
#[derive(Clone, Debug)]
pub struct Router {
    policy: Policy,
    outstanding: Vec<usize>,
    total_routed: Vec<u64>,
    rr_next: usize,
}

impl Router {
    pub fn new(replicas: usize, policy: Policy) -> Router {
        assert!(replicas > 0);
        Router {
            policy,
            outstanding: vec![0; replicas],
            total_routed: vec![0; replicas],
            rr_next: 0,
        }
    }

    pub fn replicas(&self) -> usize {
        self.outstanding.len()
    }

    /// Choose a replica for the next request and account for it.
    pub fn route(&mut self) -> usize {
        let target = match self.policy {
            Policy::RoundRobin => {
                let t = self.rr_next;
                self.rr_next = (self.rr_next + 1) % self.outstanding.len();
                t
            }
            Policy::LeastOutstanding => self
                .outstanding
                .iter()
                .enumerate()
                .min_by_key(|(_, &o)| o)
                .map(|(i, _)| i)
                .unwrap(),
        };
        self.outstanding[target] += 1;
        self.total_routed[target] += 1;
        target
    }

    /// A request completed on `replica`. Completing more requests than
    /// were routed is an accounting desync in the caller — rejected via
    /// [`crate::util::invariant::InvariantError`] in every build profile
    /// (a silent wrap would leak capacity to the broken replica forever).
    pub fn complete(&mut self, replica: usize) {
        if self.outstanding[replica] == 0 {
            crate::util::invariant::InvariantError::new(
                "router completion matches an outstanding request",
                format!("replica={replica} outstanding=0"),
            )
            .panic();
        }
        self.outstanding[replica] -= 1;
    }

    pub fn outstanding(&self, replica: usize) -> usize {
        self.outstanding[replica]
    }

    pub fn total_routed(&self, replica: usize) -> u64 {
        self.total_routed[replica]
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn round_robin_cycles() {
        let mut r = Router::new(3, Policy::RoundRobin);
        assert_eq!(r.route(), 0);
        assert_eq!(r.route(), 1);
        assert_eq!(r.route(), 2);
        assert_eq!(r.route(), 0);
    }

    #[test]
    fn least_outstanding_joins_shortest() {
        let mut r = Router::new(2, Policy::LeastOutstanding);
        assert_eq!(r.route(), 0);
        assert_eq!(r.route(), 1);
        assert_eq!(r.route(), 0); // tie → lowest index
        r.complete(1);
        assert_eq!(r.route(), 1);
    }

    #[test]
    fn balances_under_uneven_completion() {
        let mut r = Router::new(2, Policy::LeastOutstanding);
        // Replica 0 is slow: never completes; replica 1 completes fast.
        for _ in 0..10 {
            let t = r.route();
            if t == 1 {
                r.complete(1);
            }
        }
        assert!(r.total_routed(1) > r.total_routed(0));
        assert!(r.outstanding(0) <= 2, "slow replica overloaded");
    }

    #[test]
    fn tie_break_is_deterministic_lowest_index() {
        // All replicas equal at every depth: the scan order must always
        // resolve ties to the lowest replica index, never a hash order.
        for _ in 0..3 {
            let mut r = Router::new(4, Policy::LeastOutstanding);
            let picks: Vec<usize> = (0..8).map(|_| r.route()).collect();
            assert_eq!(picks, vec![0, 1, 2, 3, 0, 1, 2, 3]);
        }
        // After draining replica 2 specifically, it is strictly shortest.
        let mut r = Router::new(3, Policy::LeastOutstanding);
        for _ in 0..3 {
            r.route();
        }
        r.complete(2);
        assert_eq!(r.route(), 2);
    }

    #[test]
    #[should_panic(expected = "internal invariant violated")]
    fn completion_underflow_is_rejected() {
        let mut r = Router::new(2, Policy::LeastOutstanding);
        r.route(); // replica 0
        r.complete(1); // never routed: accounting desync
    }

    #[test]
    fn conservation_of_outstanding() {
        let mut r = Router::new(4, Policy::LeastOutstanding);
        let mut live = Vec::new();
        for _ in 0..100 {
            live.push(r.route());
        }
        for &t in &live {
            r.complete(t);
        }
        for i in 0..4 {
            assert_eq!(r.outstanding(i), 0);
        }
    }
}
