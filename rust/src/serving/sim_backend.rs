//! Simulation backend for the serving stack: the real [`Batcher`] +
//! [`PagedKvCache`] driven on *simulated* time, no AOT artifacts needed.
//!
//! One [`SimServing`] instance runs per LLM tenant inside
//! `platform::sim_platform`. The platform owns the clock and the cost
//! model; this module owns request lifecycle and KV accounting:
//!
//! * `submit` queues a request (sim-time arrival tracked here — the
//!   wall-clock `ServeRequest::submitted` field is a placeholder);
//! * `begin_step` plans the next continuous-batching wave (prefill-first,
//!   KV-page-gated, exactly the real scheduler) and reports its token
//!   count / PCIe traffic / reference-profile compute time;
//! * `finish_step` applies the wave: TTFT stamps at prefill end, one
//!   generated token + KV append per decode step, completions with
//!   TTFT/TPOT/e2e on max-tokens or KV exhaustion.
//!
//! Everything is deterministic given the call sequence — no RNG, no wall
//! clock — so the platform's bit-compat discipline extends through it.

use std::collections::BTreeMap;
use std::time::Instant;

use crate::tenants::llm::{LlmRequestDims, LlmWorkloadSpec};

use super::batcher::{Batcher, Work};
use super::kvcache::PagedKvCache;
use super::request::{FinishReason, RequestId, SamplingParams, ServeRequest};

/// One planned engine step, priced for the platform's cost model.
#[derive(Clone, Debug, PartialEq)]
pub struct StepStart {
    /// Prefill wave (admissions) vs decode wave.
    pub is_prefill: bool,
    /// Rows participating in the wave.
    pub rows: usize,
    /// Tokens moved through the step (prompt tokens for prefill, one per
    /// running row for decode).
    pub tokens: u64,
    /// PCIe traffic for the step (GB): weight/driver overhead plus
    /// per-token KV/activation streaming.
    pub io_gb: f64,
    /// Compute seconds at the μ-reference profile. The platform scales
    /// by the tenant's actual μ, MPS contention, and service jitter.
    pub ref_compute_s: f64,
}

/// A finished request with sim-time latencies.
#[derive(Clone, Debug, PartialEq)]
pub struct SimCompletion {
    pub id: u64,
    /// Sim-time arrival (s).
    pub arrival: f64,
    /// Sim-time completion (s).
    pub finished: f64,
    /// Time to first token (s) — stamped at prefill-wave end.
    pub ttft_s: f64,
    /// End-to-end latency (s).
    pub e2e_s: f64,
    /// Decode seconds per generated token after the first; 0 for
    /// single-token generations.
    pub tpot_s: f64,
    pub prompt_tokens: usize,
    pub generated: usize,
    pub finish: FinishReason,
}

/// Per-tenant simulated serving engine.
#[derive(Clone, Debug)]
pub struct SimServing {
    spec: LlmWorkloadSpec,
    batcher: Batcher,
    cache: PagedKvCache,
    /// Sim-time arrival per queued/running request (the `ServeRequest`
    /// struct only carries a wall-clock `Instant`).
    arrivals: BTreeMap<u64, f64>,
    /// The wave `begin_step` opened and `finish_step` will apply.
    inflight: Option<InflightStep>,
    completions: Vec<SimCompletion>,
    submitted_total: u64,
    completed_total: u64,
}

#[derive(Clone, Debug)]
struct InflightStep {
    is_prefill: bool,
    rows: Vec<usize>,
}

impl SimServing {
    pub fn new(spec: LlmWorkloadSpec) -> SimServing {
        let batcher = Batcher::new(spec.batch_rows);
        let cache = PagedKvCache::new(spec.kv_pages, spec.kv_page_size, spec.max_pages_per_seq);
        SimServing {
            spec,
            batcher,
            cache,
            arrivals: BTreeMap::new(),
            inflight: None,
            completions: Vec::new(),
            submitted_total: 0,
            completed_total: 0,
        }
    }

    pub fn spec(&self) -> &LlmWorkloadSpec {
        &self.spec
    }

    /// Queue a request. Prompts that can never fit the per-sequence page
    /// table are rejected immediately as `LengthLimit` completions
    /// (zero-latency) instead of deadlocking the head of the queue.
    pub fn submit(&mut self, id: u64, dims: LlmRequestDims, now: f64) {
        self.submitted_total += 1;
        let prompt = dims.prompt_tokens as usize;
        if self.cache.pages_for(prompt).max(1) > self.spec.max_pages_per_seq {
            self.completed_total += 1;
            self.completions.push(SimCompletion {
                id,
                arrival: now,
                finished: now,
                ttft_s: 0.0,
                e2e_s: 0.0,
                tpot_s: 0.0,
                prompt_tokens: prompt,
                generated: 0,
                finish: FinishReason::LengthLimit,
            });
            return;
        }
        self.arrivals.insert(id, now);
        self.batcher.submit(ServeRequest {
            id: RequestId(id),
            prompt_tokens: vec![1; prompt],
            params: SamplingParams {
                top_k: 0,
                seed: 0,
                max_new_tokens: dims.decode_tokens.max(1) as usize,
            },
            // Wall-clock placeholder; sim time lives in `arrivals`.
            submitted: Instant::now(),
        });
    }

    /// Plan and open the next wave, or `None` when idle. At most one
    /// wave may be open — the platform serializes step IO + compute.
    pub fn begin_step(&mut self) -> Option<StepStart> {
        if self.inflight.is_some() {
            crate::util::invariant::InvariantError::new(
                "at most one serving wave in flight",
                "SimServing::begin_step",
            )
            .panic();
        }
        match self.batcher.plan(&self.cache) {
            Work::Idle => None,
            Work::Prefill { rows } => {
                let mut admitted = Vec::with_capacity(rows.len());
                let mut tokens = 0u64;
                for row in rows {
                    let Some(req) = self.batcher.waiting_front() else {
                        break;
                    };
                    let prompt = req.prompt_tokens.len();
                    let Ok(seq) = self.cache.allocate(prompt) else {
                        // `plan` budgeted these pages; hitting this means
                        // the pool drained concurrently — stop admitting,
                        // the request stays queued.
                        break;
                    };
                    self.batcher.admit(row, seq);
                    tokens += prompt as u64;
                    admitted.push(row);
                }
                if admitted.is_empty() {
                    return None;
                }
                let start = StepStart {
                    is_prefill: true,
                    rows: admitted.len(),
                    tokens,
                    io_gb: self.step_io_gb(tokens),
                    ref_compute_s: tokens as f64 / self.spec.prefill_tok_per_s_ref,
                };
                self.inflight = Some(InflightStep {
                    is_prefill: true,
                    rows: admitted,
                });
                Some(start)
            }
            Work::Decode => {
                let rows: Vec<usize> = (0..self.batcher.rows().len())
                    .filter(|&i| self.batcher.rows()[i].is_some())
                    .collect();
                let n = rows.len();
                let tokens = n as u64;
                let step_ms = self.spec.decode_step_ms_ref
                    + self.spec.decode_step_ms_per_row * (n.saturating_sub(1)) as f64;
                let start = StepStart {
                    is_prefill: false,
                    rows: n,
                    tokens,
                    io_gb: self.step_io_gb(tokens),
                    ref_compute_s: step_ms / 1000.0,
                };
                self.inflight = Some(InflightStep {
                    is_prefill: false,
                    rows,
                });
                Some(start)
            }
        }
    }

    fn step_io_gb(&self, tokens: u64) -> f64 {
        self.spec.weight_gb_per_step + self.spec.kv_gb_per_token * tokens as f64
    }

    /// Apply the open wave at sim time `now`: TTFT stamps + first token
    /// for prefill rows, one generated token (and KV append) per decode
    /// row, completions on max-tokens or KV exhaustion.
    pub fn finish_step(&mut self, now: f64) {
        let Some(step) = self.inflight.take() else {
            crate::util::invariant::InvariantError::new(
                "finish_step without an open wave",
                "SimServing::finish_step",
            )
            .panic();
        };
        for row in step.rows {
            let Some(rs) = self.batcher.row_mut(row).as_mut() else {
                continue;
            };
            if step.is_prefill {
                let arrival = self.arrivals[&rs.req.id.0];
                rs.ttft_s = Some(now - arrival);
                rs.generated.push(1);
                rs.position += 1;
                if rs.generated.len() >= rs.req.params.max_new_tokens {
                    self.complete(row, now, FinishReason::MaxTokens);
                }
            } else {
                match self.cache.append_token(rs.seq) {
                    Ok(_) => {
                        rs.generated.push(1);
                        rs.position += 1;
                        if rs.generated.len() >= rs.req.params.max_new_tokens {
                            self.complete(row, now, FinishReason::MaxTokens);
                        }
                    }
                    Err(_) => {
                        // KV pool or page table exhausted: finish early.
                        self.complete(row, now, FinishReason::LengthLimit);
                    }
                }
            }
        }
    }

    fn complete(&mut self, row: usize, now: f64, finish: FinishReason) {
        let rs = self.batcher.evict(row).expect("completing an empty row");
        self.cache.release(rs.seq).expect("releasing a live seq");
        let arrival = self
            .arrivals
            .remove(&rs.req.id.0)
            .expect("completion without arrival record");
        let e2e = now - arrival;
        let ttft = rs.ttft_s.unwrap_or(e2e);
        let generated = rs.generated.len();
        let tpot = if generated > 1 {
            (e2e - ttft) / (generated - 1) as f64
        } else {
            0.0
        };
        self.completed_total += 1;
        self.completions.push(SimCompletion {
            id: rs.req.id.0,
            arrival,
            finished: now,
            ttft_s: ttft,
            e2e_s: e2e,
            tpot_s: tpot,
            prompt_tokens: rs.req.prompt_tokens.len(),
            generated,
            finish,
        });
    }

    /// Take the completions accumulated since the last drain.
    pub fn drain_completions(&mut self) -> Vec<SimCompletion> {
        std::mem::take(&mut self.completions)
    }

    /// Is a wave currently open (between `begin_step` and `finish_step`)?
    pub fn step_open(&self) -> bool {
        self.inflight.is_some()
    }

    /// No queued or running work and no open wave.
    pub fn is_idle(&self) -> bool {
        self.inflight.is_none() && self.batcher.is_idle()
    }

    pub fn queue_len(&self) -> usize {
        self.batcher.waiting_len()
    }

    pub fn running_len(&self) -> usize {
        self.batcher.running_len()
    }

    pub fn free_pages(&self) -> usize {
        self.cache.free_pages()
    }

    pub fn submitted_total(&self) -> u64 {
        self.submitted_total
    }

    pub fn completed_total(&self) -> u64 {
        self.completed_total
    }

    pub fn admitted_total(&self) -> u64 {
        self.batcher.admitted_total()
    }

    pub fn cache(&self) -> &PagedKvCache {
        &self.cache
    }

    pub fn batcher(&self) -> &Batcher {
        &self.batcher
    }

    /// Conservation invariant for property tests: every submitted
    /// request is either queued, running, already completed, or pending
    /// in the undrained completion buffer — none dropped or duplicated.
    pub fn check_conservation(&self) -> Result<(), String> {
        let inflight = self.batcher.inflight_ids().len() as u64;
        if self.submitted_total != self.completed_total + inflight {
            return Err(format!(
                "request leak: submitted {} != completed {} + inflight {}",
                self.submitted_total, self.completed_total, inflight
            ));
        }
        if self.arrivals.len() as u64 != inflight {
            return Err(format!(
                "arrival-record leak: {} records for {} inflight requests",
                self.arrivals.len(),
                inflight
            ));
        }
        self.cache.check_invariants()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tenants::llm::LlmWorkloadSpec;

    fn drive_to_idle(s: &mut SimServing, mut now: f64, dt: f64) -> f64 {
        let mut guard = 0;
        while let Some(_step) = s.begin_step() {
            now += dt;
            s.finish_step(now);
            guard += 1;
            assert!(guard < 100_000, "engine did not drain");
        }
        now
    }

    #[test]
    fn single_request_closed_form_timings() {
        let mut s = SimServing::new(LlmWorkloadSpec::fixed(32, 4));
        s.submit(0, LlmRequestDims { prompt_tokens: 32, decode_tokens: 4 }, 1.0);
        // Prefill wave: 32 tokens.
        let step = s.begin_step().unwrap();
        assert!(step.is_prefill);
        assert_eq!(step.tokens, 32);
        assert_eq!(step.rows, 1);
        let spec = s.spec().clone();
        assert_eq!(step.ref_compute_s, 32.0 / spec.prefill_tok_per_s_ref);
        assert_eq!(
            step.io_gb,
            spec.weight_gb_per_step + spec.kv_gb_per_token * 32.0
        );
        s.finish_step(1.05); // TTFT = 0.05
        // Three decode steps complete the 4-token budget.
        for k in 0..3 {
            let step = s.begin_step().unwrap();
            assert!(!step.is_prefill);
            assert_eq!(step.tokens, 1);
            assert_eq!(step.ref_compute_s, spec.decode_step_ms_ref / 1000.0);
            s.finish_step(1.05 + 0.01 * (k + 1) as f64);
        }
        assert!(s.begin_step().is_none());
        let done = s.drain_completions();
        assert_eq!(done.len(), 1);
        let c = &done[0];
        assert_eq!(c.finish, FinishReason::MaxTokens);
        assert_eq!(c.generated, 4);
        assert!((c.ttft_s - 0.05).abs() < 1e-12);
        assert!((c.e2e_s - 0.08).abs() < 1e-12);
        assert!((c.tpot_s - 0.01).abs() < 1e-12);
        assert_eq!(s.free_pages(), s.spec().kv_pages - 1);
        s.check_conservation().unwrap();
    }

    #[test]
    fn continuous_batching_drains_more_requests_than_rows() {
        let mut s = SimServing::new(LlmWorkloadSpec::fixed(16, 3));
        let n = 3 * s.spec().batch_rows as u64 + 1;
        for i in 0..n {
            s.submit(i, LlmRequestDims { prompt_tokens: 16, decode_tokens: 3 }, 0.0);
        }
        drive_to_idle(&mut s, 0.0, 0.004);
        let done = s.drain_completions();
        assert_eq!(done.len(), n as usize);
        let mut ids: Vec<u64> = done.iter().map(|c| c.id).collect();
        ids.sort_unstable();
        assert_eq!(ids, (0..n).collect::<Vec<_>>());
        assert!(done.iter().all(|c| c.finish == FinishReason::MaxTokens));
        assert!(s.is_idle());
        s.check_conservation().unwrap();
    }

    #[test]
    fn admission_is_kv_page_gated() {
        // 8 rows but only 7 usable pages of 16 tokens: 32-token prompts
        // need 2 pages each => at most 3 admitted per wave.
        let spec = LlmWorkloadSpec {
            kv_pages: 8,
            max_pages_per_seq: 4,
            ..LlmWorkloadSpec::fixed(32, 2)
        };
        let mut s = SimServing::new(spec);
        for i in 0..6 {
            s.submit(i, LlmRequestDims { prompt_tokens: 32, decode_tokens: 2 }, 0.0);
        }
        let step = s.begin_step().unwrap();
        assert!(step.is_prefill);
        assert_eq!(step.rows, 3);
        assert!(s.free_pages() >= 1);
        s.finish_step(0.01);
        drive_to_idle(&mut s, 0.01, 0.005);
        assert_eq!(s.drain_completions().len(), 6);
        s.check_conservation().unwrap();
    }

    #[test]
    fn oversized_prompt_rejected_not_deadlocked() {
        let spec = LlmWorkloadSpec {
            max_pages_per_seq: 2, // 32-token max context
            ..LlmWorkloadSpec::fixed(16, 2)
        };
        let mut s = SimServing::new(spec);
        s.submit(0, LlmRequestDims { prompt_tokens: 64, decode_tokens: 2 }, 0.0);
        s.submit(1, LlmRequestDims { prompt_tokens: 16, decode_tokens: 2 }, 0.0);
        // The oversized request completed immediately as LengthLimit…
        let done = s.drain_completions();
        assert_eq!(done.len(), 1);
        assert_eq!(done[0].finish, FinishReason::LengthLimit);
        assert_eq!(done[0].generated, 0);
        // …and the queue keeps moving.
        assert!(s.begin_step().is_some());
        s.finish_step(0.01);
        drive_to_idle(&mut s, 0.01, 0.005);
        assert_eq!(s.drain_completions().len(), 1);
        s.check_conservation().unwrap();
    }

    #[test]
    fn kv_exhaustion_mid_decode_finishes_with_length_limit() {
        // One sequence, page table capped at 1 page (16 tokens): a
        // 16-token prompt fills it, so the first decode append fails.
        let spec = LlmWorkloadSpec {
            kv_pages: 4,
            max_pages_per_seq: 1,
            ..LlmWorkloadSpec::fixed(16, 8)
        };
        let mut s = SimServing::new(spec);
        s.submit(0, LlmRequestDims { prompt_tokens: 16, decode_tokens: 8 }, 0.0);
        s.begin_step().unwrap();
        s.finish_step(0.01); // prefill: first token out
        s.begin_step().unwrap();
        s.finish_step(0.02); // decode append fails: LengthLimit
        let done = s.drain_completions();
        assert_eq!(done.len(), 1);
        assert_eq!(done[0].finish, FinishReason::LengthLimit);
        assert_eq!(done[0].generated, 1);
        assert_eq!(s.free_pages(), 3);
        s.check_conservation().unwrap();
    }

    #[test]
    fn prefill_preempts_decode_for_waiting_requests() {
        let mut s = SimServing::new(LlmWorkloadSpec::fixed(16, 4));
        s.submit(0, LlmRequestDims { prompt_tokens: 16, decode_tokens: 4 }, 0.0);
        s.begin_step().unwrap();
        s.finish_step(0.01);
        // A new arrival while row 0 decodes: next wave is prefill.
        s.submit(1, LlmRequestDims { prompt_tokens: 16, decode_tokens: 4 }, 0.01);
        let step = s.begin_step().unwrap();
        assert!(step.is_prefill, "prefill-first scheduling");
        s.finish_step(0.02);
        drive_to_idle(&mut s, 0.02, 0.005);
        assert_eq!(s.drain_completions().len(), 2);
        s.check_conservation().unwrap();
    }
}
