//! Byte-level tokenizer matching the AOT model's 288-token vocabulary.
//!
//! Layout: 0 = PAD, 1 = BOS, 2 = EOS, 3..=258 = raw bytes, 259.. unused
//! (vocab rounded to 288 for MXU-friendly unembed shapes).

pub const PAD: i32 = 0;
pub const BOS: i32 = 1;
pub const EOS: i32 = 2;
pub const BYTE_BASE: i32 = 3;

/// Stateless byte tokenizer.
#[derive(Clone, Copy, Debug, Default)]
pub struct ByteTokenizer;

impl ByteTokenizer {
    /// Encode text as `[BOS, byte tokens...]`, truncated to `max_len`.
    pub fn encode(&self, text: &str, max_len: usize) -> Vec<i32> {
        let mut out = Vec::with_capacity(text.len().min(max_len) + 1);
        out.push(BOS);
        for &b in text.as_bytes() {
            if out.len() >= max_len {
                break;
            }
            out.push(BYTE_BASE + b as i32);
        }
        out
    }

    /// Decode token ids back to text (specials skipped, non-byte ids
    /// rendered as U+FFFD).
    pub fn decode(&self, tokens: &[i32]) -> String {
        let mut bytes = Vec::with_capacity(tokens.len());
        for &t in tokens {
            if t >= BYTE_BASE && t < BYTE_BASE + 256 {
                bytes.push((t - BYTE_BASE) as u8);
            } else if t == PAD || t == BOS || t == EOS {
                continue;
            } else {
                bytes.extend_from_slice("\u{fffd}".as_bytes());
            }
        }
        String::from_utf8_lossy(&bytes).into_owned()
    }

    pub fn vocab_size(&self) -> usize {
        288
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_ascii() {
        let t = ByteTokenizer;
        let toks = t.encode("hello world", 64);
        assert_eq!(toks[0], BOS);
        assert_eq!(toks.len(), 12);
        assert_eq!(t.decode(&toks), "hello world");
    }

    #[test]
    fn roundtrip_utf8() {
        let t = ByteTokenizer;
        let s = "héllo →";
        assert_eq!(t.decode(&t.encode(s, 64)), s);
    }

    #[test]
    fn truncates_to_max_len() {
        let t = ByteTokenizer;
        let toks = t.encode("aaaaaaaaaa", 4);
        assert_eq!(toks.len(), 4);
    }

    #[test]
    fn specials_skipped_in_decode() {
        let t = ByteTokenizer;
        assert_eq!(t.decode(&[BOS, BYTE_BASE + b'x' as i32, EOS, PAD]), "x");
    }

    #[test]
    fn tokens_within_vocab() {
        let t = ByteTokenizer;
        for tok in t.encode("\u{00ff}\u{0000}test", 64) {
            assert!((tok as usize) < t.vocab_size());
        }
    }
}
