//! Request / completion types for the serving engine.

use std::time::Instant;

/// Engine-unique request id.
#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub struct RequestId(pub u64);

/// Sampling parameters.
#[derive(Clone, Copy, Debug)]
pub struct SamplingParams {
    /// 0 = greedy; otherwise top-k.
    pub top_k: usize,
    pub seed: u64,
    pub max_new_tokens: usize,
}

impl Default for SamplingParams {
    fn default() -> Self {
        SamplingParams {
            top_k: 0,
            seed: 0,
            max_new_tokens: 16,
        }
    }
}

/// A submitted request.
#[derive(Clone, Debug)]
pub struct ServeRequest {
    pub id: RequestId,
    pub prompt_tokens: Vec<i32>,
    pub params: SamplingParams,
    pub submitted: Instant,
}

/// Why a sequence finished.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum FinishReason {
    Eos,
    MaxTokens,
    /// KV pages exhausted for this sequence (max_seq_len reached).
    LengthLimit,
}

/// A finished request with its timings.
#[derive(Clone, Debug)]
pub struct Completion {
    pub id: RequestId,
    pub generated: Vec<i32>,
    pub finish: FinishReason,
    /// Time to first token (seconds) — the paper's LLM SLO metric.
    pub ttft_s: f64,
    /// End-to-end latency (seconds).
    pub e2e_s: f64,
    /// Decode time per output token (seconds), excluding prefill.
    pub tpot_s: f64,
    pub prompt_len: usize,
}
