//! Paged KV-cache manager (the vLLM block allocator).
//!
//! Owns the page pool geometry the AOT model was compiled against: a
//! shared pool of `num_pages` pages, `page_size` tokens each, per-sequence
//! page tables of `max_pages_per_seq` entries. Page 0 is reserved as the
//! scratch target for inactive batch rows (their decode writes land there
//! and are never read).
//!
//! Refcounted pages support copy-on-write prefix sharing: `fork` clones a
//! table bumping refcounts; a shared page must be copied (by the caller)
//! before being written, via `ensure_exclusive`.

/// Sequence handle within the cache.
#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub struct SeqId(pub u64);

/// Allocation errors.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum KvError {
    OutOfPages,
    SeqLimit,
    NoSuchSeq,
}

/// The scratch page id reserved for inactive batch rows.
pub const SCRATCH_PAGE: i32 = 0;

#[derive(Clone, Debug)]
struct SeqEntry {
    pages: Vec<u32>,
    tokens: usize,
}

/// Paged allocator over the shared pool.
#[derive(Clone, Debug)]
pub struct PagedKvCache {
    page_size: usize,
    num_pages: usize,
    max_pages_per_seq: usize,
    refcount: Vec<u32>,
    free: Vec<u32>,
    seqs: std::collections::BTreeMap<SeqId, SeqEntry>,
    next_seq: u64,
}

impl PagedKvCache {
    pub fn new(num_pages: usize, page_size: usize, max_pages_per_seq: usize) -> PagedKvCache {
        assert!(num_pages > 1);
        let mut refcount = vec![0u32; num_pages];
        refcount[SCRATCH_PAGE as usize] = 1; // permanently reserved
        // LIFO free list over pages 1..num_pages.
        let free = (1..num_pages as u32).rev().collect();
        PagedKvCache {
            page_size,
            num_pages,
            max_pages_per_seq,
            refcount,
            free,
            seqs: std::collections::BTreeMap::new(),
            next_seq: 1,
        }
    }

    /// Pages still allocatable.
    pub fn free_pages(&self) -> usize {
        self.free.len()
    }

    pub fn page_size(&self) -> usize {
        self.page_size
    }

    /// Pages needed for `tokens` tokens.
    pub fn pages_for(&self, tokens: usize) -> usize {
        tokens.div_ceil(self.page_size)
    }

    /// Can a sequence of `tokens` total tokens be admitted right now?
    pub fn can_admit(&self, tokens: usize) -> bool {
        let need = self.pages_for(tokens).max(1);
        need <= self.max_pages_per_seq && need <= self.free.len()
    }

    /// Allocate a sequence with capacity for `tokens` tokens.
    pub fn allocate(&mut self, tokens: usize) -> Result<SeqId, KvError> {
        let need = self.pages_for(tokens).max(1);
        if need > self.max_pages_per_seq {
            return Err(KvError::SeqLimit);
        }
        if need > self.free.len() {
            return Err(KvError::OutOfPages);
        }
        let mut pages = Vec::with_capacity(need);
        for _ in 0..need {
            let p = self.free.pop().unwrap();
            self.refcount[p as usize] = 1;
            pages.push(p);
        }
        let id = SeqId(self.next_seq);
        self.next_seq += 1;
        self.seqs.insert(
            id,
            SeqEntry {
                pages,
                tokens,
            },
        );
        Ok(id)
    }

    /// Grow a sequence by one token, allocating a fresh page on a page
    /// boundary. Returns the (possibly new) page count.
    pub fn append_token(&mut self, id: SeqId) -> Result<usize, KvError> {
        let e = self.seqs.get_mut(&id).ok_or(KvError::NoSuchSeq)?;
        let new_tokens = e.tokens + 1;
        let need = new_tokens.div_ceil(self.page_size);
        if need > e.pages.len() {
            if need > self.max_pages_per_seq {
                return Err(KvError::SeqLimit);
            }
            let Some(p) = self.free.pop() else {
                return Err(KvError::OutOfPages);
            };
            self.refcount[p as usize] = 1;
            e.pages.push(p);
        }
        e.tokens = new_tokens;
        Ok(e.pages.len())
    }

    /// Release a sequence, returning its pages to the pool when their
    /// refcount drains.
    pub fn release(&mut self, id: SeqId) -> Result<(), KvError> {
        let e = self.seqs.remove(&id).ok_or(KvError::NoSuchSeq)?;
        for p in e.pages {
            let rc = &mut self.refcount[p as usize];
            *rc -= 1;
            if *rc == 0 {
                self.free.push(p);
            }
        }
        Ok(())
    }

    /// Fork a sequence (prefix sharing): the clone references the same
    /// pages with bumped refcounts.
    pub fn fork(&mut self, id: SeqId) -> Result<SeqId, KvError> {
        let e = self.seqs.get(&id).ok_or(KvError::NoSuchSeq)?.clone();
        for &p in &e.pages {
            self.refcount[p as usize] += 1;
        }
        let nid = SeqId(self.next_seq);
        self.next_seq += 1;
        self.seqs.insert(nid, e);
        Ok(nid)
    }

    /// Ensure the *last* page of `id` is exclusively owned before a write
    /// (copy-on-write). Returns `Some((old_page, new_page))` when the
    /// caller must copy page contents in the backing store.
    pub fn ensure_exclusive(&mut self, id: SeqId) -> Result<Option<(u32, u32)>, KvError> {
        let e = self.seqs.get_mut(&id).ok_or(KvError::NoSuchSeq)?;
        let Some(&last) = e.pages.last() else {
            return Ok(None);
        };
        if self.refcount[last as usize] <= 1 {
            return Ok(None);
        }
        let Some(fresh) = self.free.pop() else {
            return Err(KvError::OutOfPages);
        };
        self.refcount[fresh as usize] = 1;
        self.refcount[last as usize] -= 1;
        *e.pages.last_mut().unwrap() = fresh;
        Ok(Some((last, fresh)))
    }

    /// Padded page-table row for the AOT executable: `max_pages_per_seq`
    /// entries, unused slots pointing at the scratch page.
    pub fn table_row(&self, id: SeqId) -> Result<Vec<i32>, KvError> {
        let e = self.seqs.get(&id).ok_or(KvError::NoSuchSeq)?;
        let mut row = vec![SCRATCH_PAGE; self.max_pages_per_seq];
        for (i, &p) in e.pages.iter().enumerate() {
            row[i] = p as i32;
        }
        Ok(row)
    }

    /// Scratch row for inactive batch rows.
    pub fn scratch_row(&self) -> Vec<i32> {
        vec![SCRATCH_PAGE; self.max_pages_per_seq]
    }

    pub fn tokens(&self, id: SeqId) -> Option<usize> {
        self.seqs.get(&id).map(|e| e.tokens)
    }

    pub fn live_seqs(&self) -> usize {
        self.seqs.len()
    }

    /// Invariant check (property tests): refcounts consistent with
    /// free list and tables, no page both free and referenced.
    pub fn check_invariants(&self) -> Result<(), String> {
        let mut refs = vec![0u32; self.num_pages];
        refs[SCRATCH_PAGE as usize] += 1;
        for e in self.seqs.values() {
            for &p in &e.pages {
                refs[p as usize] += 1;
            }
            if e.pages.len() > self.max_pages_per_seq {
                return Err("seq exceeds max pages".into());
            }
            if e.tokens.div_ceil(self.page_size) > e.pages.len() {
                return Err("tokens exceed page capacity".into());
            }
        }
        for &p in &self.free {
            if refs[p as usize] != 0 {
                return Err(format!("page {p} both free and referenced"));
            }
            refs[p as usize] = u32::MAX; // mark seen
        }
        for (p, (&rc, &computed)) in self.refcount.iter().zip(refs.iter()).enumerate() {
            if computed == u32::MAX {
                continue; // free page
            }
            if rc != computed {
                return Err(format!("page {p} refcount {rc} != computed {computed}"));
            }
        }
        let accounted = self.free.len()
            + refs
                .iter()
                .filter(|&&r| r != u32::MAX && r > 0)
                .count();
        if accounted != self.num_pages {
            return Err(format!(
                "page leak: {} free + referenced != {}",
                accounted, self.num_pages
            ));
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cache() -> PagedKvCache {
        PagedKvCache::new(64, 16, 4)
    }

    #[test]
    fn allocate_and_release_roundtrip() {
        let mut c = cache();
        let free0 = c.free_pages();
        let id = c.allocate(20).unwrap(); // 2 pages
        assert_eq!(c.free_pages(), free0 - 2);
        assert_eq!(c.tokens(id), Some(20));
        c.release(id).unwrap();
        assert_eq!(c.free_pages(), free0);
        c.check_invariants().unwrap();
    }

    #[test]
    fn append_allocates_on_boundary() {
        let mut c = cache();
        let id = c.allocate(16).unwrap(); // exactly 1 page
        let free = c.free_pages();
        assert_eq!(c.append_token(id).unwrap(), 2); // crosses boundary
        assert_eq!(c.free_pages(), free - 1);
        assert_eq!(c.append_token(id).unwrap(), 2); // within page 2
        c.check_invariants().unwrap();
    }

    #[test]
    fn seq_limit_enforced() {
        let mut c = cache();
        assert_eq!(c.allocate(65), Err(KvError::SeqLimit)); // > 4 pages
        let id = c.allocate(63).unwrap();
        c.append_token(id).unwrap(); // 64 tokens: exactly 4 pages
        assert_eq!(c.append_token(id), Err(KvError::SeqLimit));
    }

    #[test]
    fn out_of_pages() {
        let mut c = PagedKvCache::new(4, 16, 4); // 3 usable pages
        let a = c.allocate(32).unwrap(); // 2 pages
        assert_eq!(c.allocate(32), Err(KvError::OutOfPages));
        c.release(a).unwrap();
        assert!(c.allocate(32).is_ok());
    }

    #[test]
    fn table_row_padded_with_scratch() {
        let mut c = cache();
        let id = c.allocate(17).unwrap(); // 2 pages
        let row = c.table_row(id).unwrap();
        assert_eq!(row.len(), 4);
        assert!(row[0] > 0 && row[1] > 0);
        assert_eq!(row[2], SCRATCH_PAGE);
        assert_eq!(row[3], SCRATCH_PAGE);
    }

    #[test]
    fn fork_shares_then_cow() {
        let mut c = cache();
        let a = c.allocate(16).unwrap();
        let table_a = c.table_row(a).unwrap();
        let b = c.fork(a).unwrap();
        assert_eq!(c.table_row(b).unwrap(), table_a);
        // Writing to b's last page must trigger a copy.
        let cow = c.ensure_exclusive(b).unwrap();
        assert!(cow.is_some());
        let (old, fresh) = cow.unwrap();
        assert_eq!(old as i32, table_a[0]);
        assert_ne!(old, fresh);
        assert_ne!(c.table_row(b).unwrap()[0], table_a[0]);
        // a is untouched and exclusive again.
        assert_eq!(c.ensure_exclusive(a).unwrap(), None);
        c.check_invariants().unwrap();
    }

    #[test]
    fn release_forked_pages_refcounted() {
        let mut c = cache();
        let free0 = c.free_pages();
        let a = c.allocate(16).unwrap();
        let b = c.fork(a).unwrap();
        c.release(a).unwrap();
        assert_eq!(c.free_pages(), free0 - 1); // page still held by b
        c.release(b).unwrap();
        assert_eq!(c.free_pages(), free0);
        c.check_invariants().unwrap();
    }

    #[test]
    fn scratch_page_never_allocated() {
        let mut c = cache();
        let mut seen = std::collections::HashSet::new();
        while let Ok(id) = c.allocate(64) {
            for p in c.table_row(id).unwrap() {
                if p != SCRATCH_PAGE {
                    assert!(seen.insert(p), "page {p} double-allocated");
                    assert_ne!(p, SCRATCH_PAGE);
                }
            }
        }
    }
}
