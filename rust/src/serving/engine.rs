//! The serving engine: continuous-batched prefill/decode over the AOT
//! executables, with real TTFT measurement.
//!
//! One `step()` = one scheduler wave (a prefill batch or a decode step),
//! exactly like vLLM's engine loop. All tensor I/O goes through
//! [`crate::runtime::ModelRuntime`]; the KV pool lives host-side between
//! steps (CPU PJRT; on TPU it would stay device-resident via donation —
//! see DESIGN.md §Perf).

use std::time::Instant;

use anyhow::Result;

use crate::runtime::ModelRuntime;
use crate::util::histogram::Histogram;
use crate::util::invariant::InvariantError;
use crate::util::rng::Pcg64;

use super::batcher::{Batcher, Work};
use super::kvcache::PagedKvCache;
use super::request::{Completion, FinishReason, RequestId, SamplingParams, ServeRequest};
use super::sampler;
use super::tokenizer::{ByteTokenizer, EOS};

/// Aggregate serving metrics (µs histograms).
#[derive(Debug, Default, Clone)]
pub struct EngineStats {
    pub ttft_us: Histogram,
    pub e2e_us: Histogram,
    pub completed: u64,
    pub generated_tokens: u64,
    pub prefill_waves: u64,
    pub decode_steps: u64,
    /// Wall time spent inside PJRT execute calls.
    pub model_time_s: f64,
    /// Total engine step time.
    pub step_time_s: f64,
}

impl EngineStats {
    pub fn throughput_rps(&self, wall_s: f64) -> f64 {
        if wall_s <= 0.0 {
            0.0
        } else {
            self.completed as f64 / wall_s
        }
    }
}

/// The engine.
pub struct Engine {
    rt: ModelRuntime,
    cache: PagedKvCache,
    batcher: Batcher,
    k_pages: Vec<f32>,
    v_pages: Vec<f32>,
    next_id: u64,
    rng: Pcg64,
    pub tokenizer: ByteTokenizer,
    pub stats: EngineStats,
}

impl Engine {
    /// Build from the default artifacts directory.
    pub fn load_default() -> Result<Engine> {
        Ok(Self::new(ModelRuntime::load_default()?))
    }

    pub fn new(rt: ModelRuntime) -> Engine {
        let spec = rt.spec();
        let (k, v) = rt.new_kv_pools();
        Engine {
            cache: PagedKvCache::new(spec.num_pages, spec.page_size, spec.max_pages_per_seq),
            batcher: Batcher::new(spec.batch),
            k_pages: k,
            v_pages: v,
            next_id: 1,
            rng: Pcg64::seeded(0xE47),
            tokenizer: ByteTokenizer,
            rt,
            stats: EngineStats::default(),
        }
    }

    pub fn spec(&self) -> crate::runtime::ModelSpec {
        self.rt.spec()
    }

    /// Submit a text prompt; returns the request id.
    pub fn submit_text(&mut self, text: &str, params: SamplingParams) -> RequestId {
        let spec = self.rt.spec();
        // Leave room for at least one generated token inside max_seq_len.
        let max_prompt = spec.prompt_len.min(spec.max_seq_len() - 1);
        let tokens = self.tokenizer.encode(text, max_prompt);
        self.submit_tokens(tokens, params)
    }

    /// Submit pre-tokenized input.
    pub fn submit_tokens(&mut self, tokens: Vec<i32>, params: SamplingParams) -> RequestId {
        let id = RequestId(self.next_id);
        self.next_id += 1;
        self.batcher.submit(ServeRequest {
            id,
            prompt_tokens: tokens,
            params,
            submitted: Instant::now(),
        });
        id
    }

    pub fn pending(&self) -> usize {
        self.batcher.waiting_len() + self.batcher.running_len()
    }

    pub fn is_idle(&self) -> bool {
        self.batcher.is_idle()
    }

    /// One scheduler wave. Returns completions that finished this step.
    pub fn step(&mut self) -> Result<Vec<Completion>> {
        let t0 = Instant::now();
        let out = match self.batcher.plan(&self.cache) {
            Work::Prefill { rows } => self.do_prefill(rows),
            Work::Decode => self.do_decode(),
            Work::Idle => Ok(Vec::new()),
        };
        self.stats.step_time_s += t0.elapsed().as_secs_f64();
        out
    }

    /// Run until all submitted requests complete; returns all completions.
    pub fn run_to_completion(&mut self) -> Result<Vec<Completion>> {
        let mut done = Vec::new();
        while !self.batcher.is_idle() {
            done.extend(self.step()?);
        }
        Ok(done)
    }

    fn do_prefill(&mut self, rows: Vec<usize>) -> Result<Vec<Completion>> {
        let spec = self.rt.spec();
        self.stats.prefill_waves += 1;

        let mut tokens = vec![0i32; spec.batch * spec.prompt_len];
        let mut seq_lens = vec![0i32; spec.batch];
        let mut table = vec![super::kvcache::SCRATCH_PAGE; spec.batch * spec.max_pages_per_seq];
        // Existing running rows keep seq_len 0 (no KV writes) and scratch
        // tables — the executable leaves their state untouched.
        for &row in &rows {
            // Allocate pages for the prompt, then admit into the row
            // (admit pops the queue head, so peek the front each time).
            let prompt_len = self
                .batcher
                .waiting_front()
                .map(|r| r.prompt_tokens.len())
                .unwrap_or(0);
            let seq = self
                .cache
                .allocate(prompt_len.max(1))
                .map_err(|e| anyhow::anyhow!("kv allocation failed: {e:?}"))?;
            let slot = self.batcher.admit(row, seq);
            let plen = slot.req.prompt_tokens.len().min(spec.prompt_len);
            tokens[row * spec.prompt_len..row * spec.prompt_len + plen]
                .copy_from_slice(&slot.req.prompt_tokens[..plen]);
            seq_lens[row] = plen as i32;
            let trow = self.cache.table_row(slot.seq).map_err(|e| {
                InvariantError::new(
                    "admitted sequence has a kv page-table row",
                    format!("row={row} seq={:?} req={:?}: {e:?}", slot.seq, slot.req.id),
                )
            })?;
            table[row * spec.max_pages_per_seq..(row + 1) * spec.max_pages_per_seq]
                .copy_from_slice(&trow);
        }

        let m0 = Instant::now();
        let out = self
            .rt
            .run_prefill(&tokens, &seq_lens, &table, &self.k_pages, &self.v_pages)?;
        self.stats.model_time_s += m0.elapsed().as_secs_f64();
        self.k_pages = out.k_pages;
        self.v_pages = out.v_pages;

        // Sample the first token for each admitted row.
        let vocab = spec.vocab_size;
        let now = Instant::now();
        for &row in &rows {
            let logits = &out.logits[row * vocab..(row + 1) * vocab];
            let slot = self.batcher.row_mut(row).as_mut().ok_or_else(|| {
                InvariantError::new(
                    "prefill-admitted batch row is occupied at sampling",
                    format!("row={row}"),
                )
            })?;
            let tok = match slot.req.params.top_k {
                0 => sampler::greedy(logits),
                k => {
                    let mut r = Pcg64::new(slot.req.params.seed, slot.req.id.0);
                    sampler::top_k(logits, k, &mut r)
                }
            };
            slot.generated.push(tok);
            slot.last_token = tok;
            slot.ttft_s = Some(now.duration_since(slot.req.submitted).as_secs_f64());
            slot.prefill_at = Some(now);
        }

        // First-token EOS / single-token requests can finish immediately.
        self.collect_finished(&rows)
    }

    fn do_decode(&mut self) -> Result<Vec<Completion>> {
        let spec = self.rt.spec();
        self.stats.decode_steps += 1;

        let mut tokens = vec![0i32; spec.batch];
        let mut positions = vec![0i32; spec.batch];
        let mut table = vec![super::kvcache::SCRATCH_PAGE; spec.batch * spec.max_pages_per_seq];
        let mut active_rows = Vec::new();
        let mut length_capped = Vec::new();

        for row in 0..spec.batch {
            // Reserve capacity for the KV write at `position`; rows that
            // cannot grow finish with LengthLimit before the step.
            let (seq, position, last_token) = match self.batcher.rows()[row].as_ref() {
                Some(s) => (s.seq, s.position, s.last_token),
                None => continue,
            };
            let need_tokens = position + 1;
            if self.cache.tokens(seq).unwrap_or(0) < need_tokens {
                match self.cache.append_token(seq) {
                    Ok(_) => {}
                    Err(_) => {
                        length_capped.push(row);
                        continue;
                    }
                }
            }
            tokens[row] = last_token;
            positions[row] = position as i32;
            let trow = self.cache.table_row(seq).map_err(|e| {
                InvariantError::new(
                    "decoding sequence has a kv page-table row",
                    format!("row={row} seq={seq:?} position={position}: {e:?}"),
                )
            })?;
            table[row * spec.max_pages_per_seq..(row + 1) * spec.max_pages_per_seq]
                .copy_from_slice(&trow);
            active_rows.push(row);
        }

        let mut completions = Vec::new();
        for row in length_capped {
            completions.push(self.finish_row(row, FinishReason::LengthLimit));
        }
        if active_rows.is_empty() {
            return Ok(completions);
        }

        let m0 = Instant::now();
        let out = self
            .rt
            .run_decode(&tokens, &positions, &table, &self.k_pages, &self.v_pages)?;
        self.stats.model_time_s += m0.elapsed().as_secs_f64();
        self.k_pages = out.k_pages;
        self.v_pages = out.v_pages;

        let vocab = spec.vocab_size;
        for &row in &active_rows {
            let logits = &out.logits[row * vocab..(row + 1) * vocab];
            let slot = self.batcher.row_mut(row).as_mut().ok_or_else(|| {
                InvariantError::new(
                    "decode-active batch row is occupied at sampling",
                    format!("row={row}"),
                )
            })?;
            let tok = match slot.req.params.top_k {
                0 => sampler::greedy(logits),
                k => {
                    let mut r = Pcg64::new(
                        slot.req.params.seed ^ slot.position as u64,
                        slot.req.id.0,
                    );
                    sampler::top_k(logits, k, &mut r)
                }
            };
            slot.generated.push(tok);
            slot.last_token = tok;
            slot.position += 1;
        }
        completions.extend(self.collect_finished(&active_rows)?);
        Ok(completions)
    }

    /// Check EOS / max-token termination on the given rows.
    fn collect_finished(&mut self, rows: &[usize]) -> Result<Vec<Completion>> {
        let spec = self.rt.spec();
        let mut done = Vec::new();
        for &row in rows {
            let (finished, reason) = match self.batcher.rows()[row].as_ref() {
                Some(s) => {
                    if *s.generated.last().unwrap_or(&-1) == EOS {
                        (true, FinishReason::Eos)
                    } else if s.generated.len() >= s.req.params.max_new_tokens {
                        (true, FinishReason::MaxTokens)
                    } else if s.position >= spec.max_seq_len() {
                        (true, FinishReason::LengthLimit)
                    } else {
                        (false, FinishReason::Eos)
                    }
                }
                None => continue,
            };
            if finished {
                done.push(self.finish_row(row, reason));
            }
        }
        Ok(done)
    }

    fn finish_row(&mut self, row: usize, finish: FinishReason) -> Completion {
        let slot = self.batcher.evict(row).expect("finish empty row");
        self.cache.release(slot.seq).expect("release");
        let e2e = slot.req.submitted.elapsed().as_secs_f64();
        let ttft = slot.ttft_s.unwrap_or(e2e);
        let n_decode = slot.generated.len().saturating_sub(1);
        let tpot = if n_decode > 0 {
            (e2e - ttft) / n_decode as f64
        } else {
            0.0
        };
        self.stats.completed += 1;
        self.stats.generated_tokens += slot.generated.len() as u64;
        self.stats.ttft_us.record((ttft * 1e6) as u64);
        self.stats.e2e_us.record((e2e * 1e6) as u64);
        Completion {
            id: slot.req.id,
            prompt_len: slot.req.prompt_tokens.len(),
            generated: slot.generated,
            finish,
            ttft_s: ttft,
            e2e_s: e2e,
            tpot_s: tpot,
        }
    }

    /// Sampling RNG access (tests).
    pub fn rng(&mut self) -> &mut Pcg64 {
        &mut self.rng
    }

    pub fn kv_cache(&self) -> &PagedKvCache {
        &self.cache
    }
}
