//! `artifacts/manifest.json` — the compile-time ABI between the JAX AOT
//! path and this runtime. Field-for-field mirror of what aot.py writes.

use crate::util::json::Json;
use anyhow::{anyhow, bail, Context, Result};
use std::path::{Path, PathBuf};

/// Shape+dtype of one executable input/output.
#[derive(Clone, Debug, PartialEq)]
pub struct TensorSig {
    pub shape: Vec<usize>,
    pub dtype: String,
}

impl TensorSig {
    pub fn elements(&self) -> usize {
        self.shape.iter().product()
    }

    fn from_json(j: &Json) -> Result<TensorSig> {
        let shape = j
            .get("shape")
            .as_arr()
            .ok_or_else(|| anyhow!("tensor sig missing shape"))?
            .iter()
            .map(|x| x.as_usize().ok_or_else(|| anyhow!("bad dim")))
            .collect::<Result<Vec<_>>>()?;
        let dtype = j
            .get("dtype")
            .as_str()
            .ok_or_else(|| anyhow!("tensor sig missing dtype"))?
            .to_string();
        Ok(TensorSig { shape, dtype })
    }
}

/// One AOT executable (prefill / decode / smoke).
#[derive(Clone, Debug)]
pub struct ArtifactSig {
    pub file: String,
    pub inputs: Vec<TensorSig>,
    pub outputs: Vec<TensorSig>,
    pub num_params: usize,
    pub sha256: String,
}

/// Model geometry (mirror of python `ModelConfig`).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct ModelSpec {
    pub vocab_size: usize,
    pub d_model: usize,
    pub n_layers: usize,
    pub n_heads: usize,
    pub n_kv_heads: usize,
    pub head_dim: usize,
    pub d_ff: usize,
    pub page_size: usize,
    pub num_pages: usize,
    pub max_pages_per_seq: usize,
    pub batch: usize,
    pub prompt_len: usize,
}

impl ModelSpec {
    pub fn max_seq_len(&self) -> usize {
        self.max_pages_per_seq * self.page_size
    }

    /// Elements of one KV pool tensor [L, P, page, KH, D].
    pub fn kv_pool_elements(&self) -> usize {
        self.n_layers * self.num_pages * self.page_size * self.n_kv_heads * self.head_dim
    }
}

/// Parsed manifest.
#[derive(Clone, Debug)]
pub struct Manifest {
    pub dir: PathBuf,
    pub model: ModelSpec,
    /// Ordered (name, shape) parameter list — the positional ABI.
    pub params: Vec<(String, Vec<usize>)>,
    pub params_bin: String,
    pub prefill: ArtifactSig,
    pub decode: ArtifactSig,
    pub smoke: ArtifactSig,
}

impl Manifest {
    pub fn load(dir: &Path) -> Result<Manifest> {
        let path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&path)
            .with_context(|| format!("reading {} (run `make artifacts`)", path.display()))?;
        let j = Json::parse(&text).map_err(|e| anyhow!("parsing manifest: {e}"))?;
        if j.get("format").as_usize() != Some(1) {
            bail!("unsupported manifest format");
        }

        let m = j.get("model");
        let field = |k: &str| -> Result<usize> {
            m.get(k)
                .as_usize()
                .ok_or_else(|| anyhow!("manifest model missing {k}"))
        };
        let model = ModelSpec {
            vocab_size: field("vocab_size")?,
            d_model: field("d_model")?,
            n_layers: field("n_layers")?,
            n_heads: field("n_heads")?,
            n_kv_heads: field("n_kv_heads")?,
            head_dim: field("head_dim")?,
            d_ff: field("d_ff")?,
            page_size: field("page_size")?,
            num_pages: field("num_pages")?,
            max_pages_per_seq: field("max_pages_per_seq")?,
            batch: field("batch")?,
            prompt_len: field("prompt_len")?,
        };

        let params = j
            .get("params")
            .as_arr()
            .ok_or_else(|| anyhow!("manifest missing params"))?
            .iter()
            .map(|p| {
                let name = p
                    .get("name")
                    .as_str()
                    .ok_or_else(|| anyhow!("param missing name"))?
                    .to_string();
                let shape = p
                    .get("shape")
                    .as_arr()
                    .ok_or_else(|| anyhow!("param missing shape"))?
                    .iter()
                    .map(|d| d.as_usize().ok_or_else(|| anyhow!("bad dim")))
                    .collect::<Result<Vec<_>>>()?;
                Ok((name, shape))
            })
            .collect::<Result<Vec<_>>>()?;

        let artifact = |name: &str| -> Result<ArtifactSig> {
            let a = j.at(&["artifacts", name]);
            if matches!(a, Json::Null) {
                bail!("manifest missing artifact {name}");
            }
            Ok(ArtifactSig {
                file: a
                    .get("file")
                    .as_str()
                    .ok_or_else(|| anyhow!("artifact {name} missing file"))?
                    .to_string(),
                inputs: a
                    .get("inputs")
                    .as_arr()
                    .unwrap_or(&[])
                    .iter()
                    .map(TensorSig::from_json)
                    .collect::<Result<Vec<_>>>()?,
                outputs: a
                    .get("outputs")
                    .as_arr()
                    .unwrap_or(&[])
                    .iter()
                    .map(TensorSig::from_json)
                    .collect::<Result<Vec<_>>>()?,
                num_params: a.get("num_params").as_usize().unwrap_or(0),
                sha256: a.get("sha256").as_str().unwrap_or("").to_string(),
            })
        };

        Ok(Manifest {
            dir: dir.to_path_buf(),
            model,
            params,
            params_bin: j
                .get("params_bin")
                .as_str()
                .ok_or_else(|| anyhow!("manifest missing params_bin"))?
                .to_string(),
            prefill: artifact("prefill")?,
            decode: artifact("decode")?,
            smoke: artifact("smoke")?,
        })
    }

    /// Total f32 elements across all params (size check for params.bin).
    pub fn total_param_elements(&self) -> usize {
        self.params.iter().map(|(_, s)| s.iter().product::<usize>()).sum()
    }

    /// Locate the artifacts directory: `$PREDSERVE_ARTIFACTS`, else
    /// `./artifacts`, else `../artifacts` (tests run from target dirs).
    pub fn default_dir() -> PathBuf {
        if let Ok(d) = std::env::var("PREDSERVE_ARTIFACTS") {
            return PathBuf::from(d);
        }
        for cand in ["artifacts", "../artifacts", "../../artifacts"] {
            let p = PathBuf::from(cand);
            if p.join("manifest.json").exists() {
                return p;
            }
        }
        PathBuf::from("artifacts")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn have_artifacts() -> bool {
        Manifest::default_dir().join("manifest.json").exists()
    }

    #[test]
    fn loads_real_manifest() {
        if !have_artifacts() {
            eprintln!("skipping: run `make artifacts`");
            return;
        }
        let m = Manifest::load(&Manifest::default_dir()).unwrap();
        assert_eq!(m.model.page_size * m.model.max_pages_per_seq, m.model.max_seq_len());
        assert!(m.params.len() > 10);
        assert_eq!(m.prefill.num_params, m.params.len());
        // prefill inputs = params + tokens, seq_lens, page_table, k, v
        assert_eq!(m.prefill.inputs.len(), m.params.len() + 5);
        assert_eq!(m.prefill.outputs.len(), 3);
        // KV pool shapes agree between manifest fields and spec.
        let kv = &m.prefill.inputs[m.params.len() + 3];
        assert_eq!(kv.elements(), m.model.kv_pool_elements());
    }

    #[test]
    fn rejects_missing_dir() {
        assert!(Manifest::load(Path::new("/nonexistent")).is_err());
    }
}
