//! PJRT runtime: loads the AOT HLO-text artifacts produced by
//! `python/compile/aot.py` and executes them on the request path.
//!
//! This is the only place the crate touches XLA. Python never runs at
//! serving time — the artifacts + `params.bin` + `manifest.json` are the
//! complete model. Interchange is HLO *text* (see aot.py / DESIGN.md for
//! the xla_extension-0.5.1 proto-id rationale).
//!
//! Layout:
//! * [`manifest`] — parses `manifest.json`, the positional ABI (param
//!   order, input signatures, KV geometry) shared with the Python side.
//! * [`params`] — loads `params.bin` (raw little-endian f32).
//! * [`pjrt`] — the client wrapper: compile-once, execute-many, with a
//!   buffer-resident parameter cache for the hot decode loop.

pub mod manifest;
pub mod params;
pub mod pjrt;

pub use manifest::{ArtifactSig, Manifest, ModelSpec, TensorSig};
pub use pjrt::{ModelRuntime, StepOutput};
