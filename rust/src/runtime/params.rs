//! Parameter blob loader: `artifacts/params.bin` is raw little-endian f32,
//! concatenated in the exact order of `manifest.params` (the positional ABI
//! with the JAX side — see `python/compile/aot.py`).

use super::manifest::Manifest;
use anyhow::{bail, Context, Result};

/// All model parameters as host vectors, in manifest order.
#[derive(Clone, Debug)]
pub struct ParamSet {
    /// (name, shape, data) in positional order.
    pub tensors: Vec<(String, Vec<usize>, Vec<f32>)>,
}

impl ParamSet {
    pub fn load(manifest: &Manifest) -> Result<ParamSet> {
        let path = manifest.dir.join(&manifest.params_bin);
        let bytes = std::fs::read(&path)
            .with_context(|| format!("reading {}", path.display()))?;
        let expect = manifest.total_param_elements() * 4;
        if bytes.len() != expect {
            bail!(
                "params.bin size mismatch: got {} bytes, manifest implies {}",
                bytes.len(),
                expect
            );
        }
        let mut tensors = Vec::with_capacity(manifest.params.len());
        let mut off = 0usize;
        for (name, shape) in &manifest.params {
            let n: usize = shape.iter().product();
            let mut data = vec![0f32; n];
            for (i, chunk) in bytes[off..off + 4 * n].chunks_exact(4).enumerate() {
                data[i] = f32::from_le_bytes([chunk[0], chunk[1], chunk[2], chunk[3]]);
            }
            off += 4 * n;
            tensors.push((name.clone(), shape.clone(), data));
        }
        Ok(ParamSet { tensors })
    }

    pub fn total_elements(&self) -> usize {
        self.tensors.iter().map(|(_, _, d)| d.len()).sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::manifest::Manifest;

    #[test]
    fn loads_and_is_finite() {
        let dir = Manifest::default_dir();
        if !dir.join("manifest.json").exists() {
            eprintln!("skipping: run `make artifacts`");
            return;
        }
        let m = Manifest::load(&dir).unwrap();
        let p = ParamSet::load(&m).unwrap();
        assert_eq!(p.total_elements(), m.total_param_elements());
        // Norm gains init to exactly 1.0 — spot-check the ABI ordering.
        let ln1 = p
            .tensors
            .iter()
            .find(|(n, _, _)| n == "layer0.ln1")
            .expect("layer0.ln1 present");
        assert!(ln1.2.iter().all(|&x| x == 1.0));
        for (name, _, data) in &p.tensors {
            assert!(data.iter().all(|x| x.is_finite()), "{name} has non-finite");
        }
    }
}
