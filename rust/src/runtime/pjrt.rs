//! PJRT execution wrapper: compile the AOT artifacts once, execute many.
//!
//! The decode hot loop keeps the (large, immutable) parameter tensors
//! resident as device buffers and uploads only the small per-step inputs
//! (tokens/positions/page_table) plus the KV pools — see §Perf in
//! EXPERIMENTS.md for the literal-path vs buffer-path numbers.

use super::manifest::Manifest;
use super::params::ParamSet;
use anyhow::{anyhow, bail, Context, Result};
use xla::{HloModuleProto, Literal, PjRtClient, PjRtLoadedExecutable, XlaComputation};

/// Output of one prefill/decode execution.
#[derive(Debug)]
pub struct StepOutput {
    /// `[batch, vocab]` row-major logits.
    pub logits: Vec<f32>,
    /// Updated KV pools (row-major `[L, P, page, KH, D]`).
    pub k_pages: Vec<f32>,
    pub v_pages: Vec<f32>,
}

/// Compiled model + pre-built parameter literals.
///
/// NOTE: parameters are cached as host *literals*, not device buffers.
/// The PJRT CPU client in `xla` 0.1.6 consumes (donates) input buffers on
/// `execute_b`, so device-resident reuse across calls aborts; the literal
/// path re-uploads per call (≈1.7 MB memcpy for this model — measured in
/// EXPERIMENTS.md §Perf, negligible vs the HLO execution itself).
pub struct ModelRuntime {
    #[allow(dead_code)]
    client: PjRtClient,
    prefill: PjRtLoadedExecutable,
    decode: PjRtLoadedExecutable,
    /// Parameter literals in positional ABI order.
    param_literals: Vec<Literal>,
    pub manifest: Manifest,
}

fn literal_f32(data: &[f32], shape: &[usize]) -> Result<Literal> {
    let dims: Vec<i64> = shape.iter().map(|&d| d as i64).collect();
    Ok(Literal::vec1(data).reshape(&dims)?)
}

fn literal_i32(data: &[i32], shape: &[usize]) -> Result<Literal> {
    let dims: Vec<i64> = shape.iter().map(|&d| d as i64).collect();
    Ok(Literal::vec1(data).reshape(&dims)?)
}

impl ModelRuntime {
    /// Load artifacts from `dir`, compile, and upload parameters.
    pub fn load(dir: &std::path::Path) -> Result<ModelRuntime> {
        let manifest = Manifest::load(dir)?;
        let params = ParamSet::load(&manifest)?;
        let client = PjRtClient::cpu()?;

        let compile = |file: &str| -> Result<PjRtLoadedExecutable> {
            let path = manifest.dir.join(file);
            let proto = HloModuleProto::from_text_file(&path)
                .with_context(|| format!("parsing HLO text {}", path.display()))?;
            let comp = XlaComputation::from_proto(&proto);
            client
                .compile(&comp)
                .with_context(|| format!("compiling {file}"))
        };
        let prefill = compile(&manifest.prefill.file)?;
        let decode = compile(&manifest.decode.file)?;

        // Parameter literals built once; uploaded per call (see struct doc).
        let mut param_literals = Vec::with_capacity(params.tensors.len());
        for (_, shape, data) in &params.tensors {
            param_literals.push(literal_f32(data, shape)?);
        }

        Ok(ModelRuntime {
            client,
            prefill,
            decode,
            param_literals,
            manifest,
        })
    }

    /// Load from the default artifacts directory.
    pub fn load_default() -> Result<ModelRuntime> {
        Self::load(&Manifest::default_dir())
    }

    pub fn spec(&self) -> super::manifest::ModelSpec {
        self.manifest.model
    }

    /// Fresh zeroed KV pool pair.
    pub fn new_kv_pools(&self) -> (Vec<f32>, Vec<f32>) {
        let n = self.manifest.model.kv_pool_elements();
        (vec![0f32; n], vec![0f32; n])
    }

    fn kv_shape(&self) -> Vec<usize> {
        let m = &self.manifest.model;
        vec![m.n_layers, m.num_pages, m.page_size, m.n_kv_heads, m.head_dim]
    }

    fn run(
        &self,
        exe: &PjRtLoadedExecutable,
        extra: Vec<Literal>,
        k_pages: &[f32],
        v_pages: &[f32],
    ) -> Result<StepOutput> {
        let kv_shape = self.kv_shape();
        let mut inputs: Vec<&Literal> = Vec::with_capacity(self.param_literals.len() + 5);
        inputs.extend(self.param_literals.iter());
        let kv_k = literal_f32(k_pages, &kv_shape)?;
        let kv_v = literal_f32(v_pages, &kv_shape)?;
        for lit in &extra {
            inputs.push(lit);
        }
        inputs.push(&kv_k);
        inputs.push(&kv_v);

        let result = exe.execute::<&Literal>(&inputs)?;
        let out = result
            .first()
            .and_then(|r| r.first())
            .ok_or_else(|| anyhow!("empty execution result"))?
            .to_literal_sync()?;
        let (logits_l, k_l, v_l) = out.to_tuple3()?;
        Ok(StepOutput {
            logits: logits_l.to_vec::<f32>()?,
            k_pages: k_l.to_vec::<f32>()?,
            v_pages: v_l.to_vec::<f32>()?,
        })
    }

    /// Execute prefill over a padded prompt batch.
    ///
    /// * `tokens` — `[batch * prompt_len]` row-major, padded.
    /// * `seq_lens` — `[batch]` live prompt lengths (0 for inactive rows).
    /// * `page_table` — `[batch * max_pages_per_seq]` page ids.
    pub fn run_prefill(
        &self,
        tokens: &[i32],
        seq_lens: &[i32],
        page_table: &[i32],
        k_pages: &[f32],
        v_pages: &[f32],
    ) -> Result<StepOutput> {
        let m = &self.manifest.model;
        if tokens.len() != m.batch * m.prompt_len {
            bail!("tokens len {} != batch*prompt_len", tokens.len());
        }
        if seq_lens.len() != m.batch || page_table.len() != m.batch * m.max_pages_per_seq {
            bail!("bad prefill input shapes");
        }
        let extra = vec![
            literal_i32(tokens, &[m.batch, m.prompt_len])?,
            literal_i32(seq_lens, &[m.batch])?,
            literal_i32(page_table, &[m.batch, m.max_pages_per_seq])?,
        ];
        self.run(&self.prefill, extra, k_pages, v_pages)
    }

    /// Execute one decode step.
    ///
    /// * `tokens` — `[batch]` current token per row.
    /// * `positions` — `[batch]` 0-based position of that token.
    pub fn run_decode(
        &self,
        tokens: &[i32],
        positions: &[i32],
        page_table: &[i32],
        k_pages: &[f32],
        v_pages: &[f32],
    ) -> Result<StepOutput> {
        let m = &self.manifest.model;
        if tokens.len() != m.batch || positions.len() != m.batch {
            bail!("bad decode input shapes");
        }
        if page_table.len() != m.batch * m.max_pages_per_seq {
            bail!("bad page table shape");
        }
        let extra = vec![
            literal_i32(tokens, &[m.batch])?,
            literal_i32(positions, &[m.batch])?,
            literal_i32(page_table, &[m.batch, m.max_pages_per_seq])?,
        ];
        self.run(&self.decode, extra, k_pages, v_pages)
    }

    /// Compile + run the smoke artifact (used by tests to validate the
    /// load-execute path independent of the model).
    pub fn smoke_test(dir: &std::path::Path) -> Result<Vec<f32>> {
        let manifest = Manifest::load(dir)?;
        let client = PjRtClient::cpu()?;
        let proto = HloModuleProto::from_text_file(&manifest.dir.join(&manifest.smoke.file))?;
        let exe = client.compile(&XlaComputation::from_proto(&proto))?;
        let x = Literal::vec1(&[1f32, 2., 3., 4.]).reshape(&[2, 2])?;
        let y = Literal::vec1(&[1f32, 1., 1., 1.]).reshape(&[2, 2])?;
        let out = exe.execute::<Literal>(&[x, y])?[0][0]
            .to_literal_sync()?
            .to_tuple1()?;
        Ok(out.to_vec::<f32>()?)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn dir() -> Option<std::path::PathBuf> {
        let d = Manifest::default_dir();
        d.join("manifest.json").exists().then_some(d)
    }

    #[test]
    fn smoke_artifact_executes() {
        let Some(d) = dir() else {
            eprintln!("skipping: run `make artifacts`");
            return;
        };
        let out = ModelRuntime::smoke_test(&d).unwrap();
        assert_eq!(out, vec![5.0, 5.0, 9.0, 9.0]);
    }
}
