//! Tenant workload generators.
//!
//! Three workload *kinds* (the paper's §3.1 archetypes), composable in
//! any count and mix through [`TenantWorkload`]:
//!
//! * **latency-sensitive** — open-loop inference with a p99 SLO, input
//!   sizes from a realistic mixture inducing time-varying PCIe pressure.
//! * **bandwidth-heavy** — ETL cycles: NVMe → host → GPU → back,
//!   sustained PCIe + block-I/O pressure.
//! * **compute-heavy** — synthetic training steps maximizing SM occupancy
//!   on a (possibly MPS-shared) instance, plus gradient-sync transfers.
//!
//! [`InterferenceSchedule`] toggles background tenants on and off (the
//! paper's interference script); every configuration in a comparison
//! replays the identical schedule (§3.2).
//!
//! [`ArrivalProcess`] makes the *arrival side* swappable too: open-loop
//! Poisson (the default, bit-identical to the pre-trace engine), an
//! explicit replayed [`TraceSpec`], or a deterministically
//! [`Envelope`]-modulated Poisson for diurnal/burst synthetic traffic.

pub mod arrivals;
pub mod collective;
pub mod llm;
pub mod schedule;
pub mod spec;
pub mod workload;

pub use arrivals::{ArrivalError, ArrivalProcess, ArrivalState, Envelope, TraceSpec};
pub use collective::CollectiveSpec;
pub use llm::{LlmRequestDims, LlmWorkloadSpec, TokenDist};
pub use schedule::{InterferenceSchedule, Phase};
pub use spec::{
    BwSpec, CompSpec, LsRequest, LsSpec, T1Request, T1Spec, T2Spec, T3Spec, TenantId, TenantKind,
};
pub use workload::{AutoPlacement, PlacementSpec, TenantWorkload, WorkloadSpec};
