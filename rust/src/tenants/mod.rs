//! Tenant workload generators — the paper's three co-located tenants
//! (§3.1 Workloads) plus the interference schedule that toggles the noisy
//! neighbors on and off.
//!
//! * **T1** — latency-sensitive inference (15 ms p99 SLO, batch 1, input
//!   sizes from a realistic mixture inducing time-varying PCIe pressure).
//! * **T2** — bandwidth-heavy ETL: NVMe → host → GPU → back, sustained
//!   PCIe + block-I/O pressure.
//! * **T3** — compute-heavy synthetic training: maximizes SM occupancy on
//!   its (possibly MPS-shared) instance.

pub mod spec;
pub mod schedule;

pub use schedule::{InterferenceSchedule, Phase};
pub use spec::{T1Request, T1Spec, T2Spec, T3Spec, TenantId, TenantKind};
