//! Interference schedule: "an interference script toggles the activity of
//! T2 and T3 to create dynamic periods of contention" (§3.1).
//!
//! Every configuration in a comparison runs the *identical* schedule
//! (§3.2: "All reported comparisons use identical interference schedules
//! across configurations"), which is why the schedule is generated ahead
//! of time from its own RNG stream and stored as explicit phases.

use crate::util::rng::Pcg64;

/// A half-open activity interval `[on, off)` in sim seconds.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct Phase {
    pub on: f64,
    pub off: f64,
}

/// Pre-generated on/off phases for one background tenant.
#[derive(Clone, Debug)]
pub struct InterferenceSchedule {
    pub phases: Vec<Phase>,
    pub horizon: f64,
}

impl InterferenceSchedule {
    /// Generate alternating off/on periods covering `[0, horizon)`.
    /// `mean_off`/`mean_on` are exponential means (seconds); `min_*` floor
    /// each period so phases are long enough for dwell/cool-down dynamics
    /// to matter.
    pub fn generate(
        rng: &mut Pcg64,
        horizon: f64,
        mean_off: f64,
        mean_on: f64,
        min_period: f64,
    ) -> InterferenceSchedule {
        let mut phases = Vec::new();
        let mut t = rng.exp(1.0 / mean_off).max(min_period);
        while t < horizon {
            let on = t;
            let dur = rng.exp(1.0 / mean_on).max(min_period);
            let off = (on + dur).min(horizon);
            phases.push(Phase { on, off });
            t = off + rng.exp(1.0 / mean_off).max(min_period);
        }
        InterferenceSchedule { phases, horizon }
    }

    /// Always-on over the horizon (steady contention experiments, Fig 4
    /// "high contention").
    pub fn always_on(horizon: f64) -> InterferenceSchedule {
        InterferenceSchedule {
            phases: vec![Phase {
                on: 0.0,
                off: horizon,
            }],
            horizon,
        }
    }

    /// Never on (no-contention baseline, Fig 4 "low contention").
    pub fn always_off(horizon: f64) -> InterferenceSchedule {
        InterferenceSchedule {
            phases: Vec::new(),
            horizon,
        }
    }

    /// Deterministic periodic (diurnal-style) schedule: active for
    /// `duty · period` seconds out of every `period`, starting at
    /// `offset` into the cycle. `duty` is clamped to `[0, 1]`. The wave
    /// is a pure phase shift: when `offset > (1 - duty)·period`, the
    /// active window wrapping across t = 0 is kept (clipped to the
    /// horizon), so the realized duty cycle matches `duty`.
    pub fn periodic(horizon: f64, period: f64, duty: f64, offset: f64) -> InterferenceSchedule {
        let duty = duty.clamp(0.0, 1.0);
        let mut phases = Vec::new();
        if period > 0.0 && duty > 0.0 {
            // Start one cycle before the first in-horizon offset so a
            // window straddling t = 0 contributes its clipped tail.
            let mut t = offset.rem_euclid(period) - period;
            while t < horizon {
                let on = t.max(0.0);
                let off = (t + duty * period).min(horizon);
                if off > on {
                    phases.push(Phase { on, off });
                }
                t += period;
            }
        }
        InterferenceSchedule { phases, horizon }
    }

    /// Is the tenant active at time `t`?
    pub fn active_at(&self, t: f64) -> bool {
        self.phases.iter().any(|p| t >= p.on && t < p.off)
    }

    /// Next toggle time strictly after `t` (on or off edge), if any.
    pub fn next_toggle_after(&self, t: f64) -> Option<f64> {
        let mut best: Option<f64> = None;
        for p in &self.phases {
            for edge in [p.on, p.off] {
                if edge > t && best.map(|b| edge < b).unwrap_or(true) {
                    best = Some(edge);
                }
            }
        }
        best
    }

    /// Total active time within `[0, horizon)`.
    pub fn duty_time(&self) -> f64 {
        self.phases.iter().map(|p| p.off - p.on).sum()
    }

    /// Fraction of the horizon the tenant is active.
    pub fn duty_cycle(&self) -> f64 {
        if self.horizon <= 0.0 {
            return 0.0;
        }
        self.duty_time() / self.horizon
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn generated_phases_ordered_and_disjoint() {
        let mut rng = Pcg64::seeded(51);
        let s = InterferenceSchedule::generate(&mut rng, 3600.0, 60.0, 90.0, 10.0);
        assert!(!s.phases.is_empty());
        for w in s.phases.windows(2) {
            assert!(w[0].off <= w[1].on, "phases overlap");
        }
        for p in &s.phases {
            assert!(p.on < p.off);
            assert!(p.off <= 3600.0);
        }
    }

    #[test]
    fn active_at_and_toggles_consistent() {
        let s = InterferenceSchedule {
            phases: vec![Phase { on: 10.0, off: 20.0 }, Phase { on: 30.0, off: 40.0 }],
            horizon: 50.0,
        };
        assert!(!s.active_at(5.0));
        assert!(s.active_at(10.0));
        assert!(s.active_at(19.9));
        assert!(!s.active_at(20.0));
        assert_eq!(s.next_toggle_after(0.0), Some(10.0));
        assert_eq!(s.next_toggle_after(10.0), Some(20.0));
        assert_eq!(s.next_toggle_after(35.0), Some(40.0));
        assert_eq!(s.next_toggle_after(40.0), None);
    }

    #[test]
    fn duty_cycle_matches_means_roughly() {
        let mut rng = Pcg64::seeded(52);
        let s = InterferenceSchedule::generate(&mut rng, 100_000.0, 50.0, 50.0, 5.0);
        let dc = s.duty_cycle();
        assert!((dc - 0.5).abs() < 0.05, "duty cycle {dc}");
    }

    #[test]
    fn always_on_off() {
        assert!(InterferenceSchedule::always_on(10.0).active_at(5.0));
        assert!(!InterferenceSchedule::always_off(10.0).active_at(5.0));
        assert_eq!(InterferenceSchedule::always_on(10.0).duty_cycle(), 1.0);
    }

    #[test]
    fn periodic_schedule_duty_and_offset() {
        let s = InterferenceSchedule::periodic(1000.0, 100.0, 0.4, 10.0);
        assert!((s.duty_cycle() - 0.4).abs() < 0.02, "duty {}", s.duty_cycle());
        assert!(!s.active_at(5.0));
        assert!(s.active_at(15.0));
        assert!(!s.active_at(60.0));
        assert!(s.active_at(115.0));
        // Degenerate inputs produce an empty (always-off) schedule.
        assert!(InterferenceSchedule::periodic(100.0, 0.0, 0.5, 0.0)
            .phases
            .is_empty());
        assert!(InterferenceSchedule::periodic(100.0, 50.0, 0.0, 0.0)
            .phases
            .is_empty());
    }

    #[test]
    fn periodic_schedule_keeps_wraparound_window() {
        // offset 450 with duty 0.6 of a 600 s period: the window from the
        // previous cycle is active on [0, 210) — a pure phase shift, so
        // the realized duty stays ~0.6 over the horizon.
        let s = InterferenceSchedule::periodic(1800.0, 600.0, 0.6, 450.0);
        assert!(s.active_at(100.0), "wrap-around window missing");
        assert!(!s.active_at(300.0));
        assert!(s.active_at(500.0));
        assert!(
            (s.duty_cycle() - 0.6).abs() < 0.02,
            "duty {}",
            s.duty_cycle()
        );
        // Offsets beyond one period are equivalent modulo the period.
        let a = InterferenceSchedule::periodic(1000.0, 100.0, 0.5, 30.0);
        let b = InterferenceSchedule::periodic(1000.0, 100.0, 0.5, 130.0);
        assert_eq!(a.phases, b.phases);
    }

    #[test]
    fn identical_seed_identical_schedule() {
        let mut a = Pcg64::seeded(53);
        let mut b = Pcg64::seeded(53);
        let sa = InterferenceSchedule::generate(&mut a, 1000.0, 30.0, 40.0, 5.0);
        let sb = InterferenceSchedule::generate(&mut b, 1000.0, 30.0, 40.0, 5.0);
        assert_eq!(sa.phases, sb.phases);
    }
}
