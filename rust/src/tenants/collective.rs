//! Ring-collective traffic for cross-host trainers.
//!
//! A distributed data-parallel trainer ends every step with an
//! allreduce over its participants. We model the classic **ring**
//! algorithm: for `N` participants, each allreduce is `2·(N−1)` ring
//! steps (reduce-scatter then allgather), and each ring step moves one
//! `bytes/N` segment from every participant to its successor on the
//! ring — `N` simultaneous segment flows per step, link-disjoint on a
//! directional fabric ([`crate::topo::ClusterTopology`]), chained
//! deterministically through the event queue: the next ring step starts
//! only when all `N` segments of the current one drain.
//!
//! On an otherwise-idle fabric this yields the textbook completion time
//! `2·(N−1)/N · bytes / bottleneck_gbps`, which the integration suite
//! asserts *bitwise* against the simulated trainer — the closed form is
//! the oracle for the whole net-fabric stack.

use crate::topo::ClusterTopology;

/// One trainer's cross-host allreduce shape. Attached to a
/// compute-heavy spec ([`super::spec::CompSpec::collective`]); `None`
/// there (the default, and every pre-cluster scenario) keeps the
/// trainer host-local and the legacy event stream byte-identical.
#[derive(Clone, Debug, PartialEq)]
pub struct CollectiveSpec {
    /// Host indices on the ring, in ring order. Segment `i` flows
    /// `participants[i] → participants[(i+1) % N]`.
    pub participants: Vec<usize>,
    /// Gradient payload per allreduce (GB). Each ring step moves a
    /// `bytes / N` segment per participant.
    pub bytes: f64,
    /// Allreduces per training step (e.g. one per gradient bucket).
    pub rounds: u32,
}

impl CollectiveSpec {
    pub fn ring(participants: Vec<usize>, bytes: f64, rounds: u32) -> CollectiveSpec {
        CollectiveSpec {
            participants,
            bytes,
            rounds,
        }
    }

    pub fn num_participants(&self) -> usize {
        self.participants.len()
    }

    /// Ring steps per allreduce: reduce-scatter + allgather.
    pub fn ring_steps(&self) -> u32 {
        2 * (self.num_participants() as u32 - 1)
    }

    /// Segment size per ring step per participant (GB).
    pub fn segment_gb(&self) -> f64 {
        self.bytes / self.num_participants() as f64
    }

    /// Validate against a cluster: ≥ 2 distinct in-range participants,
    /// positive payload, ≥ 1 round. Returns a human-readable complaint.
    pub fn validate(&self, cluster: &ClusterTopology) -> Result<(), String> {
        if self.participants.len() < 2 {
            return Err(format!(
                "a ring needs >= 2 participants, got {}",
                self.participants.len()
            ));
        }
        for &h in &self.participants {
            if h >= cluster.num_hosts() {
                return Err(format!(
                    "participant host {h} out of range (cluster has {} hosts)",
                    cluster.num_hosts()
                ));
            }
        }
        let mut sorted = self.participants.clone();
        sorted.sort_unstable();
        sorted.dedup();
        if sorted.len() != self.participants.len() {
            return Err("ring participants must be distinct hosts".to_string());
        }
        if !(self.bytes > 0.0) {
            return Err(format!("allreduce payload must be > 0 GB, got {}", self.bytes));
        }
        if self.rounds == 0 {
            return Err("a collective trainer needs >= 1 round per step".to_string());
        }
        Ok(())
    }

    /// Closed-form completion time (s) of one allreduce on an
    /// otherwise-idle fabric whose bottleneck runs at `bottleneck_gbps`,
    /// accumulated ring step by ring step with the *same* float
    /// arithmetic the simulator performs (one addition per ring step),
    /// so oracle tests can assert bitwise equality. Algebraically this
    /// is `2·(N−1)/N · bytes / bottleneck_gbps`.
    pub fn ideal_allreduce_s(&self, bottleneck_gbps: f64) -> f64 {
        let seg_s = self.segment_gb() / bottleneck_gbps;
        let mut t = 0.0;
        for _ in 0..self.ring_steps() {
            t += seg_s;
        }
        t
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ring_shape_arithmetic() {
        let s = CollectiveSpec::ring(vec![0, 1, 2, 3], 4.0, 2);
        assert_eq!(s.num_participants(), 4);
        assert_eq!(s.ring_steps(), 6);
        assert_eq!(s.segment_gb(), 1.0);
    }

    #[test]
    fn closed_form_matches_algebra() {
        let s = CollectiveSpec::ring(vec![0, 1, 2, 3], 4.0, 1);
        let got = s.ideal_allreduce_s(12.5);
        let algebra = 2.0 * 3.0 / 4.0 * 4.0 / 12.5;
        assert!((got - algebra).abs() < 1e-12, "{got} vs {algebra}");
    }

    #[test]
    fn validation_catches_bad_rings() {
        let c = ClusterTopology::leaf_spine(2, 2, 2);
        assert!(CollectiveSpec::ring(vec![0, 2], 1.0, 1).validate(&c).is_ok());
        assert!(CollectiveSpec::ring(vec![0], 1.0, 1).validate(&c).is_err());
        assert!(CollectiveSpec::ring(vec![0, 9], 1.0, 1).validate(&c).is_err());
        assert!(CollectiveSpec::ring(vec![0, 0], 1.0, 1).validate(&c).is_err());
        assert!(CollectiveSpec::ring(vec![0, 1], 0.0, 1).validate(&c).is_err());
        assert!(CollectiveSpec::ring(vec![0, 1], 1.0, 0).validate(&c).is_err());
    }
}
