//! Unified N-tenant workload abstraction.
//!
//! A [`TenantWorkload`] bundles everything the platform needs to drive
//! one tenant: a kind-specific spec ([`WorkloadSpec`]), an activity
//! schedule, and a placement request. Scenarios hold a
//! `Vec<TenantWorkload>` — any count of each kind — instead of the fixed
//! T1/T2/T3 slots of the paper's §3.1 testbed.

use crate::gpu::MigProfile;
use crate::tenants::schedule::InterferenceSchedule;
use crate::tenants::spec::{BwSpec, CompSpec, LsSpec, TenantKind};

/// Kind-tagged tenant spec.
#[derive(Clone, Debug)]
pub enum WorkloadSpec {
    LatencySensitive(LsSpec),
    BandwidthHeavy(BwSpec),
    ComputeHeavy(CompSpec),
}

impl WorkloadSpec {
    pub fn kind(&self) -> TenantKind {
        match self {
            WorkloadSpec::LatencySensitive(_) => TenantKind::LatencySensitive,
            WorkloadSpec::BandwidthHeavy(_) => TenantKind::BandwidthHeavy,
            WorkloadSpec::ComputeHeavy(_) => TenantKind::ComputeHeavy,
        }
    }

    pub fn as_ls(&self) -> Option<&LsSpec> {
        match self {
            WorkloadSpec::LatencySensitive(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_ls_mut(&mut self) -> Option<&mut LsSpec> {
        match self {
            WorkloadSpec::LatencySensitive(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_bw(&self) -> Option<&BwSpec> {
        match self {
            WorkloadSpec::BandwidthHeavy(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_comp(&self) -> Option<&CompSpec> {
        match self {
            WorkloadSpec::ComputeHeavy(s) => Some(s),
            _ => None,
        }
    }

    /// SLO threshold for monitoring: latency-sensitive tenants use their
    /// spec SLO, background tenants are effectively unbounded.
    pub fn slo_ms(&self) -> f64 {
        match self {
            WorkloadSpec::LatencySensitive(s) => s.slo_ms,
            _ => f64::MAX,
        }
    }
}

/// Where a tenant wants to run.
#[derive(Clone, Copy, Debug)]
pub struct PlacementSpec {
    /// GPU index on the host.
    pub gpu: usize,
    /// MIG profile of the tenant's instance.
    pub profile: MigProfile,
    /// Preferred start slice (`None` = first legal fit).
    pub start: Option<usize>,
    /// Share the instance of an *earlier* tenant (MPS co-scheduling —
    /// the naive-placement baseline the controller escapes from). The
    /// peer must be on the same GPU with the same profile/start.
    pub share_with: Option<usize>,
}

impl PlacementSpec {
    pub fn dedicated(gpu: usize, profile: MigProfile) -> PlacementSpec {
        PlacementSpec {
            gpu,
            profile,
            start: None,
            share_with: None,
        }
    }

    pub fn dedicated_at(gpu: usize, profile: MigProfile, start: usize) -> PlacementSpec {
        PlacementSpec {
            gpu,
            profile,
            start: Some(start),
            share_with: None,
        }
    }

    /// MPS co-schedule onto tenant `peer`'s instance. The gpu/profile
    /// here are placeholders — a sharer's real placement is taken from
    /// its peer when the simulated world is built.
    pub fn shared_with(peer: usize) -> PlacementSpec {
        PlacementSpec {
            gpu: 0,
            profile: MigProfile::P4g40gb,
            start: None,
            share_with: Some(peer),
        }
    }
}

/// One tenant in a scenario: spec + schedule + placement.
#[derive(Clone, Debug)]
pub struct TenantWorkload {
    /// Human-readable name ("t1-inference", "etl-west", ...).
    pub name: String,
    pub spec: WorkloadSpec,
    /// Activity schedule. Latency-sensitive tenants are always active
    /// (open-loop arrivals); for background tenants this toggles the
    /// cycle/step loop on and off (the paper's interference script).
    pub schedule: InterferenceSchedule,
    pub placement: PlacementSpec,
}

impl TenantWorkload {
    pub fn latency_sensitive(
        name: impl Into<String>,
        spec: LsSpec,
        placement: PlacementSpec,
    ) -> TenantWorkload {
        TenantWorkload {
            name: name.into(),
            spec: WorkloadSpec::LatencySensitive(spec),
            schedule: InterferenceSchedule::always_on(f64::MAX),
            placement,
        }
    }

    pub fn bandwidth_heavy(
        name: impl Into<String>,
        spec: BwSpec,
        schedule: InterferenceSchedule,
        placement: PlacementSpec,
    ) -> TenantWorkload {
        TenantWorkload {
            name: name.into(),
            spec: WorkloadSpec::BandwidthHeavy(spec),
            schedule,
            placement,
        }
    }

    pub fn compute_heavy(
        name: impl Into<String>,
        spec: CompSpec,
        schedule: InterferenceSchedule,
        placement: PlacementSpec,
    ) -> TenantWorkload {
        TenantWorkload {
            name: name.into(),
            spec: WorkloadSpec::ComputeHeavy(spec),
            schedule,
            placement,
        }
    }

    pub fn kind(&self) -> TenantKind {
        self.spec.kind()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn constructors_tag_kinds() {
        let ls = TenantWorkload::latency_sensitive(
            "svc",
            LsSpec::default(),
            PlacementSpec::dedicated(0, MigProfile::P3g40gb),
        );
        assert_eq!(ls.kind(), TenantKind::LatencySensitive);
        assert_eq!(ls.spec.slo_ms(), 15.0);
        let bw = TenantWorkload::bandwidth_heavy(
            "etl",
            BwSpec::default(),
            InterferenceSchedule::always_on(100.0),
            PlacementSpec::dedicated(1, MigProfile::P3g40gb),
        );
        assert_eq!(bw.kind(), TenantKind::BandwidthHeavy);
        assert_eq!(bw.spec.slo_ms(), f64::MAX);
        let tr = TenantWorkload::compute_heavy(
            "train",
            CompSpec::default(),
            InterferenceSchedule::always_off(100.0),
            PlacementSpec::shared_with(0),
        );
        assert_eq!(tr.kind(), TenantKind::ComputeHeavy);
        assert_eq!(tr.placement.share_with, Some(0));
    }

    #[test]
    fn spec_accessors() {
        let mut s = WorkloadSpec::LatencySensitive(LsSpec::default());
        assert!(s.as_ls().is_some());
        assert!(s.as_bw().is_none());
        s.as_ls_mut().unwrap().arrival_rps = 10.0;
        assert_eq!(s.as_ls().unwrap().arrival_rps, 10.0);
    }
}
