//! Unified N-tenant workload abstraction.
//!
//! A [`TenantWorkload`] bundles everything the platform needs to drive
//! one tenant: a kind-specific spec ([`WorkloadSpec`]), an activity
//! schedule, and a placement request. Scenarios hold a
//! `Vec<TenantWorkload>` — any count of each kind — instead of the fixed
//! T1/T2/T3 slots of the paper's §3.1 testbed.

use crate::gpu::MigProfile;
use crate::tenants::schedule::InterferenceSchedule;
use crate::tenants::spec::{BwSpec, CompSpec, LsSpec, TenantKind};

/// Kind-tagged tenant spec.
#[derive(Clone, Debug)]
pub enum WorkloadSpec {
    LatencySensitive(LsSpec),
    BandwidthHeavy(BwSpec),
    ComputeHeavy(CompSpec),
}

impl WorkloadSpec {
    pub fn kind(&self) -> TenantKind {
        match self {
            WorkloadSpec::LatencySensitive(_) => TenantKind::LatencySensitive,
            WorkloadSpec::BandwidthHeavy(_) => TenantKind::BandwidthHeavy,
            WorkloadSpec::ComputeHeavy(_) => TenantKind::ComputeHeavy,
        }
    }

    pub fn as_ls(&self) -> Option<&LsSpec> {
        match self {
            WorkloadSpec::LatencySensitive(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_ls_mut(&mut self) -> Option<&mut LsSpec> {
        match self {
            WorkloadSpec::LatencySensitive(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_bw(&self) -> Option<&BwSpec> {
        match self {
            WorkloadSpec::BandwidthHeavy(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_bw_mut(&mut self) -> Option<&mut BwSpec> {
        match self {
            WorkloadSpec::BandwidthHeavy(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_comp(&self) -> Option<&CompSpec> {
        match self {
            WorkloadSpec::ComputeHeavy(s) => Some(s),
            _ => None,
        }
    }

    /// Set the arrival process on an arrival-capable spec: requests for
    /// latency-sensitive tenants, cycle triggers for bandwidth-heavy
    /// ones. `Err` for compute-heavy specs, which have no arrival side
    /// (their step loop is closed by construction) — the single dispatch
    /// point behind [`TenantWorkload::arrivals`] and
    /// `ScenarioBuilder::arrivals`.
    pub fn set_arrivals(
        &mut self,
        process: crate::tenants::arrivals::ArrivalProcess,
    ) -> Result<(), TenantKind> {
        match self {
            WorkloadSpec::LatencySensitive(s) => {
                s.arrivals = Some(process);
                Ok(())
            }
            WorkloadSpec::BandwidthHeavy(s) => {
                s.arrivals = Some(process);
                Ok(())
            }
            WorkloadSpec::ComputeHeavy(_) => Err(TenantKind::ComputeHeavy),
        }
    }

    /// SLO threshold for monitoring: latency-sensitive tenants use their
    /// spec SLO, background tenants are effectively unbounded.
    pub fn slo_ms(&self) -> f64 {
        match self {
            WorkloadSpec::LatencySensitive(s) => s.slo_ms,
            _ => f64::MAX,
        }
    }

    /// Planning estimate of the tenant's sustained PCIe demand (GB/s) —
    /// what the workload pushes over its GPU uplink while active. Used by
    /// the auto-placement allocator (`crate::alloc`) to charge expected
    /// load against links before any telemetry exists; it is a coarse
    /// admission-time estimate, not a measurement.
    pub fn expected_pcie_gbps(&self) -> f64 {
        match self {
            WorkloadSpec::LatencySensitive(s) => {
                // Request-granularity LLM tenants charge their serving
                // model's traffic (prefill + decode steps) instead of
                // the flat H2D mixture.
                if let Some(llm) = &s.llm {
                    return llm.mean_pcie_gbps(s.mean_arrival_rps());
                }
                // Mean request H2D size (the size mixture is ~normalized;
                // guard against authored mixes whose weights do not sum
                // to 1) times the arrival rate. `mean_arrival_rps` is
                // exactly `arrival_rps` without an explicit process, so
                // pre-trace layouts are untouched; trace/modulated
                // tenants charge their realized mean rate instead.
                let wsum: f64 = s.size_mix.iter().map(|&(p, _)| p).sum();
                let mean_gb: f64 = s.size_mix.iter().map(|&(p, m)| p * m).sum::<f64>()
                    / wsum.max(1e-9);
                s.mean_arrival_rps() * mean_gb
            }
            WorkloadSpec::BandwidthHeavy(s) => {
                // PCIe bytes per cycle over an estimated cycle duration
                // (transfers at ~10 GB/s effective fair share + transform).
                let cycle_s =
                    (s.read_gb + s.h2d_gb + s.d2h_gb) / 10.0 + s.transform_ms / 1000.0;
                let closed_loop = 1.0 / cycle_s.max(1e-9);
                // Trigger-driven pipelines cycle at most as fast as the
                // trigger process delivers starts.
                let cycles_per_s = match &s.arrivals {
                    None => closed_loop,
                    Some(p) => p.mean_rps().min(closed_loop),
                };
                (s.h2d_gb + s.d2h_gb) * cycles_per_s
            }
            WorkloadSpec::ComputeHeavy(s) => {
                // Gradient sync once per step.
                s.sync_gb / (s.step_ms / 1000.0).max(1e-9)
            }
        }
    }
}

/// Auto-placement request: the tenant declares its resource ask and the
/// allocator (`crate::alloc`) chooses the concrete GPU/profile/slice.
#[derive(Clone, Copy, Debug)]
pub struct AutoPlacement {
    /// Smallest MIG profile the workload can run on.
    pub min_profile: MigProfile,
    /// Expected sustained PCIe demand (GB/s) for link-headroom admission.
    pub expected_pcie_gbps: f64,
}

/// Where a tenant wants to run.
///
/// Three modes, mirroring how the world resolves them:
/// * **pinned** — explicit `gpu`/`profile`(/`start`), used verbatim;
/// * **shared** — `share_with: Some(peer)`: MPS co-scheduling on an
///   earlier tenant's instance (gpu/profile here are placeholders);
/// * **auto** — `auto: Some(..)`: the topology-aware allocator picks the
///   placement at `ScenarioBuilder::build` time (gpu/profile/start here
///   are placeholders until resolution).
#[derive(Clone, Copy, Debug)]
pub struct PlacementSpec {
    /// GPU index on the host.
    pub gpu: usize,
    /// MIG profile of the tenant's instance.
    pub profile: MigProfile,
    /// Preferred start slice (`None` = first legal fit).
    pub start: Option<usize>,
    /// Share the instance of an *earlier* tenant (MPS co-scheduling —
    /// the naive-placement baseline the controller escapes from). The
    /// peer must be on the same GPU with the same profile/start.
    pub share_with: Option<usize>,
    /// Auto-placement request; resolved (and cleared) by the scenario
    /// builder through `crate::alloc`.
    pub auto: Option<AutoPlacement>,
}

impl PlacementSpec {
    pub fn dedicated(gpu: usize, profile: MigProfile) -> PlacementSpec {
        PlacementSpec {
            gpu,
            profile,
            start: None,
            share_with: None,
            auto: None,
        }
    }

    pub fn dedicated_at(gpu: usize, profile: MigProfile, start: usize) -> PlacementSpec {
        PlacementSpec {
            gpu,
            profile,
            start: Some(start),
            share_with: None,
            auto: None,
        }
    }

    /// MPS co-schedule onto tenant `peer`'s instance. The gpu/profile
    /// here are placeholders — a sharer's real placement is taken from
    /// its peer when the simulated world is built.
    pub fn shared_with(peer: usize) -> PlacementSpec {
        PlacementSpec {
            gpu: 0,
            profile: MigProfile::P4g40gb,
            start: None,
            share_with: Some(peer),
            auto: None,
        }
    }

    /// Ask the topology-aware allocator for a placement: the smallest
    /// acceptable profile plus the expected sustained PCIe demand. The
    /// gpu/profile/start fields are placeholders until
    /// `ScenarioBuilder::build` resolves them.
    pub fn auto(min_profile: MigProfile, expected_pcie_gbps: f64) -> PlacementSpec {
        PlacementSpec {
            gpu: 0,
            profile: min_profile,
            start: None,
            share_with: None,
            auto: Some(AutoPlacement {
                min_profile,
                expected_pcie_gbps,
            }),
        }
    }

    /// Is this placement still an unresolved auto request?
    pub fn is_auto(&self) -> bool {
        self.auto.is_some()
    }
}

/// One tenant in a scenario: spec + schedule + placement.
#[derive(Clone, Debug)]
pub struct TenantWorkload {
    /// Human-readable name ("t1-inference", "etl-west", ...).
    pub name: String,
    pub spec: WorkloadSpec,
    /// Activity schedule. Latency-sensitive tenants are always active
    /// (open-loop arrivals); for background tenants this toggles the
    /// cycle/step loop on and off (the paper's interference script).
    pub schedule: InterferenceSchedule,
    pub placement: PlacementSpec,
}

impl TenantWorkload {
    pub fn latency_sensitive(
        name: impl Into<String>,
        spec: LsSpec,
        placement: PlacementSpec,
    ) -> TenantWorkload {
        TenantWorkload {
            name: name.into(),
            spec: WorkloadSpec::LatencySensitive(spec),
            schedule: InterferenceSchedule::always_on(f64::MAX),
            placement,
        }
    }

    /// A latency-sensitive tenant served at request granularity: `llm`
    /// routes every arrival through the simulated continuous-batching
    /// engine (TTFT/TPOT SLOs) instead of the flat latency sample.
    pub fn llm(
        name: impl Into<String>,
        spec: LsSpec,
        llm: crate::tenants::llm::LlmWorkloadSpec,
        placement: PlacementSpec,
    ) -> TenantWorkload {
        let mut spec = spec;
        spec.llm = Some(llm);
        TenantWorkload::latency_sensitive(name, spec, placement)
    }

    pub fn bandwidth_heavy(
        name: impl Into<String>,
        spec: BwSpec,
        schedule: InterferenceSchedule,
        placement: PlacementSpec,
    ) -> TenantWorkload {
        TenantWorkload {
            name: name.into(),
            spec: WorkloadSpec::BandwidthHeavy(spec),
            schedule,
            placement,
        }
    }

    pub fn compute_heavy(
        name: impl Into<String>,
        spec: CompSpec,
        schedule: InterferenceSchedule,
        placement: PlacementSpec,
    ) -> TenantWorkload {
        TenantWorkload {
            name: name.into(),
            spec: WorkloadSpec::ComputeHeavy(spec),
            schedule,
            placement,
        }
    }

    /// A compute-heavy trainer whose steps end in a cross-host ring
    /// allreduce: `collective` names the ring (host indices on the
    /// scenario's cluster fabric), the payload per allreduce, and the
    /// allreduces per step. The scenario must carry a
    /// [`crate::topo::ClusterTopology`] — `ScenarioBuilder::build`
    /// validates the ring against it.
    pub fn collective(
        name: impl Into<String>,
        spec: CompSpec,
        collective: crate::tenants::collective::CollectiveSpec,
        schedule: InterferenceSchedule,
        placement: PlacementSpec,
    ) -> TenantWorkload {
        let mut spec = spec;
        spec.collective = Some(collective);
        TenantWorkload::compute_heavy(name, spec, schedule, placement)
    }

    pub fn kind(&self) -> TenantKind {
        self.spec.kind()
    }

    /// Chainable arrival-process override: requests for a
    /// latency-sensitive tenant, cycle triggers for a bandwidth-heavy
    /// one. Compute-heavy tenants have no arrival side (their step loop
    /// is closed by construction) — asking for one is a spec bug, caught
    /// here rather than silently ignored.
    pub fn arrivals(mut self, process: crate::tenants::arrivals::ArrivalProcess) -> Self {
        if self.spec.set_arrivals(process).is_err() {
            panic!(
                "tenant '{}' is compute-heavy; arrival processes only drive \
                 latency-sensitive requests or bandwidth-heavy cycle triggers",
                self.name
            );
        }
        self
    }

    /// The tenant's explicit arrival process, if any.
    pub fn arrival_process(&self) -> Option<&crate::tenants::arrivals::ArrivalProcess> {
        match &self.spec {
            WorkloadSpec::LatencySensitive(s) => s.arrivals.as_ref(),
            WorkloadSpec::BandwidthHeavy(s) => s.arrivals.as_ref(),
            WorkloadSpec::ComputeHeavy(_) => None,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn constructors_tag_kinds() {
        let ls = TenantWorkload::latency_sensitive(
            "svc",
            LsSpec::default(),
            PlacementSpec::dedicated(0, MigProfile::P3g40gb),
        );
        assert_eq!(ls.kind(), TenantKind::LatencySensitive);
        assert_eq!(ls.spec.slo_ms(), 15.0);
        let bw = TenantWorkload::bandwidth_heavy(
            "etl",
            BwSpec::default(),
            InterferenceSchedule::always_on(100.0),
            PlacementSpec::dedicated(1, MigProfile::P3g40gb),
        );
        assert_eq!(bw.kind(), TenantKind::BandwidthHeavy);
        assert_eq!(bw.spec.slo_ms(), f64::MAX);
        let tr = TenantWorkload::compute_heavy(
            "train",
            CompSpec::default(),
            InterferenceSchedule::always_off(100.0),
            PlacementSpec::shared_with(0),
        );
        assert_eq!(tr.kind(), TenantKind::ComputeHeavy);
        assert_eq!(tr.placement.share_with, Some(0));
    }

    #[test]
    fn llm_constructor_attaches_spec_and_charges_serving_traffic() {
        use crate::tenants::llm::LlmWorkloadSpec;
        let t = TenantWorkload::llm(
            "chat",
            LsSpec::llm_ttft(),
            LlmWorkloadSpec::chat_7b(),
            PlacementSpec::dedicated(0, MigProfile::P3g40gb),
        );
        assert_eq!(t.kind(), TenantKind::LatencySensitive);
        let spec = t.spec.as_ls().unwrap();
        let llm = spec.llm.as_ref().unwrap();
        let want = llm.mean_pcie_gbps(spec.mean_arrival_rps());
        assert_eq!(t.spec.expected_pcie_gbps(), want);
        // Plain LS tenants keep the flat-mixture estimate.
        assert!(LsSpec::default().llm.is_none());
    }

    #[test]
    fn collective_constructor_attaches_the_ring() {
        use crate::tenants::collective::CollectiveSpec;
        let t = TenantWorkload::collective(
            "ddp",
            CompSpec::default(),
            CollectiveSpec::ring(vec![0, 1, 2, 3], 2.0, 1),
            InterferenceSchedule::always_on(100.0),
            PlacementSpec::dedicated(0, MigProfile::P3g40gb),
        );
        assert_eq!(t.kind(), TenantKind::ComputeHeavy);
        let c = t.spec.as_comp().unwrap().collective.as_ref().unwrap();
        assert_eq!(c.num_participants(), 4);
        assert_eq!(c.ring_steps(), 6);
        // Plain trainers stay host-local.
        assert!(CompSpec::default().collective.is_none());
    }

    #[test]
    fn auto_placement_carries_the_ask() {
        let p = PlacementSpec::auto(MigProfile::P2g20gb, 3.5);
        assert!(p.is_auto());
        assert!(p.share_with.is_none());
        let a = p.auto.unwrap();
        assert_eq!(a.min_profile, MigProfile::P2g20gb);
        assert_eq!(a.expected_pcie_gbps, 3.5);
        assert!(!PlacementSpec::dedicated(0, MigProfile::P3g40gb).is_auto());
        assert!(!PlacementSpec::shared_with(0).is_auto());
    }

    #[test]
    fn expected_pcie_estimates_are_positive_and_ordered() {
        let ls = WorkloadSpec::LatencySensitive(LsSpec::default());
        let bw = WorkloadSpec::BandwidthHeavy(BwSpec::default());
        let comp = WorkloadSpec::ComputeHeavy(CompSpec::default());
        // Default T1: 80 rps x ~0.037 GB mean => ~3 GB/s.
        let e_ls = ls.expected_pcie_gbps();
        assert!(e_ls > 1.0 && e_ls < 10.0, "ls estimate {e_ls}");
        // The ETL pipeline is the heaviest PCIe user; the trainer's
        // gradient sync is the lightest.
        let e_bw = bw.expected_pcie_gbps();
        let e_comp = comp.expected_pcie_gbps();
        assert!(e_bw > e_comp, "bw {e_bw} !> comp {e_comp}");
        assert!(e_comp > 0.0);
    }

    #[test]
    fn arrivals_chainer_sets_the_process_per_kind() {
        use crate::tenants::arrivals::{ArrivalProcess, TraceSpec};
        let ls = TenantWorkload::latency_sensitive(
            "svc",
            LsSpec::default(),
            PlacementSpec::dedicated(0, MigProfile::P3g40gb),
        )
        .arrivals(ArrivalProcess::Trace(
            TraceSpec::from_gaps(vec![1.0, 2.0]).unwrap(),
        ));
        assert_eq!(ls.arrival_process().unwrap().label(), "trace");
        let bw = TenantWorkload::bandwidth_heavy(
            "etl",
            BwSpec::default(),
            InterferenceSchedule::always_on(100.0),
            PlacementSpec::dedicated(1, MigProfile::P3g40gb),
        )
        .arrivals(ArrivalProcess::Poisson { rps: 1.5 });
        assert_eq!(bw.arrival_process().unwrap().label(), "poisson");
        // Trigger-gated ETL charges the lower of trigger and closed-loop
        // cycle rate.
        let open = WorkloadSpec::BandwidthHeavy(BwSpec::default()).expected_pcie_gbps();
        let gated = bw.spec.expected_pcie_gbps();
        assert!(gated <= open + 1e-12, "gated {gated} !<= open {open}");
    }

    #[test]
    #[should_panic(expected = "compute-heavy")]
    fn arrivals_chainer_rejects_compute_tenants() {
        use crate::tenants::arrivals::ArrivalProcess;
        let _ = TenantWorkload::compute_heavy(
            "train",
            CompSpec::default(),
            InterferenceSchedule::always_on(100.0),
            PlacementSpec::dedicated(0, MigProfile::P3g40gb),
        )
        .arrivals(ArrivalProcess::Poisson { rps: 1.0 });
    }

    #[test]
    fn spec_accessors() {
        let mut s = WorkloadSpec::LatencySensitive(LsSpec::default());
        assert!(s.as_ls().is_some());
        assert!(s.as_bw().is_none());
        s.as_ls_mut().unwrap().arrival_rps = 10.0;
        assert_eq!(s.as_ls().unwrap().arrival_rps, 10.0);
    }
}
